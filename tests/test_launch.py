"""Launch-layer tests: mesh construction, a miniature dry-run cell
(subprocess, 16 placeholder devices on a 4x4 mesh), the train driver
end-to-end with resume, and the serve driver."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from conftest import SRC, run_spmd_subprocess


def test_make_production_mesh_requires_devices():
    code = """
from repro.launch.mesh import make_production_mesh
try:
    make_production_mesh()
    raise SystemExit("should have raised")
except RuntimeError as e:
    assert "XLA_FLAGS" in str(e)
print("ok")
"""
    run_spmd_subprocess(code, devices=8)


def test_mesh_shapes():
    code = """
from repro.launch.mesh import mesh_shape
assert mesh_shape(False) == ((16, 16), ("data", "model"))
assert mesh_shape(True) == ((2, 16, 16), ("pod", "data", "model"))
print("ok")
"""
    run_spmd_subprocess(code, devices=8)


def test_miniature_dryrun_cell():
    """The dry-run machinery (param specs, cache shardings, lower+compile,
    hlo analysis) on a reduced arch over a 2x4 mesh."""
    run_spmd_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs.base import get_arch, register
from repro.models.model_zoo import build_model
from repro.training import TrainConfig, make_train_step, init_train_state
from repro.distributed.sharding import param_specs, activation_ctx, cache_spec_overrides
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms
import dataclasses

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
cfg = get_arch("gemma3_4b").reduced()  # heterogeneous pattern + tail
lm = build_model(cfg)
tc = TrainConfig(dtype="bfloat16", microbatches=2, remat=True)
state_specs = jax.eval_shape(lambda: init_train_state(lm, jax.random.PRNGKey(0), tc))
pspecs = param_specs(state_specs["params"], mesh, mode="train")
state_sh = {"params": pspecs, "opt": {"m": pspecs, "v": pspecs,
                                      "step": NamedSharding(mesh, P())}}
batch = lm.input_specs(64, 8, "train")
bsh = {k: NamedSharding(mesh, P(("data",), *([None] * (len(v.shape) - 1))))
       for k, v in batch.items()}
with activation_ctx(mesh):
    compiled = jax.jit(make_train_step(lm, tc), in_shardings=(state_sh, bsh)
                       ).lower(state_specs, batch).compile()
st = analyze_hlo(compiled.as_text())
rt = roofline_terms(st)
assert st.dot_flops > 0 and rt["dominant"] in ("compute", "memory", "collective")
# decode cell too
params_b = jax.tree.map(lambda x: jax.ShapeDtypeStruct(
    x.shape, jnp.bfloat16 if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype),
    state_specs["params"])
caches = jax.eval_shape(lambda: lm.init_caches(8, 64, jnp.bfloat16))
csh = jax.tree_util.tree_map_with_path(cache_spec_overrides(mesh, 8), caches)
tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
with activation_ctx(mesh):
    dec = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, dtype=jnp.bfloat16),
                  in_shardings=(param_specs(params_b, mesh, mode="serve"), csh,
                                NamedSharding(mesh, P(("data",), None)),
                                NamedSharding(mesh, P())),
                  donate_argnums=(1,)).lower(params_b, caches, tok,
                                             jax.ShapeDtypeStruct((), jnp.int32)
                                             ).compile()
assert dec.memory_analysis().temp_size_in_bytes > 0
print("ok")
""", devices=8, timeout=600)


def test_train_driver_with_resume(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    args = [sys.executable, "-m", "repro.launch.train", "--arch", "yi_6b",
            "--steps", "8", "--seq-len", "16", "--batch", "4",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"]
    p1 = subprocess.run(args, env=env, capture_output=True, text=True, timeout=600)
    assert p1.returncode == 0, p1.stderr
    out = json.loads(p1.stdout.strip().splitlines()[-1])
    assert out["last_loss"] < out["first_loss"]
    # resume from the step-8 checkpoint and continue
    p2 = subprocess.run(args + ["--resume", "--steps", "10"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert p2.returncode == 0, p2.stderr
    assert "resumed from step 8" in p2.stdout


def test_serve_driver_gust(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "yi_6b",
         "--requests", "2", "--max-new", "3", "--gust", "--density", "0.5",
         "--gust-length", "16"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert p.returncode == 0, p.stderr
    stats = json.loads(p.stdout.strip().splitlines()[-1])
    assert stats["requests"] == 2 and stats["gust"]
    assert all(0 < u <= 1 for u in stats["gust_stream_utilization"].values())
