"""GustPlan lifecycle — the one plan/execute API (ISSUE 3).

Locks the acceptance criteria:
  * every legacy entry point (``spmv``, ``spmm_scheduled``, ``spmm_ragged``,
    ``distributed_spmv``, ``gust_spmm``, ``gust_spmm_auto``, ``GustLinear``,
    serving decode) routes through ``GustPlan.spmv``/``.spmm`` internally;
  * ``to_spec``/``from_spec`` round-trips both layouts bit-identically and
    preserves compact bf16/int16 leaf dtypes;
  * two plans over the same matrix schedule exactly once (content-keyed
    cache);
  * the batch-major ``transpose_io`` fast path is bit-identical to the
    legacy double-transpose round-trip;
  * the deprecated kwarg spellings warn with the new spelling.
"""

import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.formats import coo_from_dense
from repro.core.gust_linear import GustLinear, SparsityConfig
from repro.core.packing import (
    PackedSchedule,
    RaggedSchedule,
    ScheduleCache,
    pack_ragged,
    pack_schedule,
)
from repro.core.plan import GustPlan, PlanConfig, plan
from repro.core.scheduler import schedule

# repro.core re-exports the spmv *function*, shadowing the submodule
import importlib

spmv_mod = importlib.import_module("repro.core.spmv")
from repro.kernels.ops import execute_spmm, gust_spmm, gust_spmm_auto


def random_dense(rng, m, n, density):
    return ((rng.random((m, n)) < density) * rng.standard_normal((m, n))).astype(
        np.float32
    )


def power_law_dense(rng, m, n):
    d = ((rng.random((m, n)) < 0.03) * rng.standard_normal((m, n))).astype(
        np.float32
    )
    rows = rng.choice(m, max(m // 16, 1), replace=False)
    d[rows] = (rng.random((len(rows), n)) < 0.6) * rng.standard_normal(
        (len(rows), n)
    )
    return d


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------


def test_plan_config_normalizes_and_validates():
    cfg = PlanConfig(value_dtype=jnp.bfloat16, index_dtype="int16")
    assert cfg.value_dtype == "bfloat16" and cfg.index_dtype == "int16"
    assert cfg.value_jnp == jnp.bfloat16
    assert PlanConfig.from_dict(cfg.to_dict()) == cfg
    with pytest.raises(ValueError):
        PlanConfig(layout="csr")
    with pytest.raises(ValueError):
        PlanConfig(backend="cuda")
    with pytest.raises(ValueError):
        PlanConfig(colorer="greedy")


# ---------------------------------------------------------------------------
# execution correctness through the plan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["padded", "ragged"])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_plan_matches_dense(layout, backend):
    rng = np.random.default_rng(1)
    dense = random_dense(rng, 48, 64, 0.2)
    x = rng.standard_normal((64, 3)).astype(np.float32)
    p = plan(dense, PlanConfig(l=8, layout=layout, backend=backend), cache=None)
    y = np.asarray(p.spmm(jnp.asarray(x)))
    np.testing.assert_allclose(y, dense @ x, rtol=2e-4, atol=2e-4)
    yv = np.asarray(p.spmv(jnp.asarray(x[:, 0])))
    np.testing.assert_allclose(yv, dense @ x[:, 0], rtol=2e-4, atol=2e-4)


def test_plan_auto_layout_by_measured_waste():
    rng = np.random.default_rng(2)
    p_skew = plan(power_law_dense(rng, 128, 128), PlanConfig(l=8), cache=None)
    assert p_skew.layout == "ragged"
    assert isinstance(p_skew.artifact, RaggedSchedule)
    p_uni = plan(random_dense(rng, 64, 64, 0.3), PlanConfig(l=8), cache=None)
    assert p_uni.layout == "padded"
    assert isinstance(p_uni.artifact, PackedSchedule)
    # threshold is respected
    p_thr = plan(
        power_law_dense(rng, 128, 128),
        PlanConfig(l=8, waste_threshold=1e9),
        cache=None,
    )
    assert p_thr.layout == "padded"


def test_plan_accepts_schedule_and_adopts_its_l():
    rng = np.random.default_rng(3)
    sched = schedule(coo_from_dense(random_dense(rng, 32, 32, 0.3)), 8)
    p = plan(sched, PlanConfig(l=256, backend="jnp"))
    assert p.l == 8 and p.sched is sched


# ---------------------------------------------------------------------------
# schedule-once (content-keyed cache)
# ---------------------------------------------------------------------------


def test_two_plans_over_same_matrix_schedule_once(monkeypatch):
    import repro.core.scheduler as sched_mod

    calls = []
    real = sched_mod.schedule

    def counting(*a, **k):
        calls.append(1)
        return real(*a, **k)

    monkeypatch.setattr(sched_mod, "schedule", counting)
    rng = np.random.default_rng(4)
    dense = random_dense(rng, 48, 48, 0.2)
    v = jnp.asarray(rng.standard_normal(48).astype(np.float32))
    cache = ScheduleCache()
    cfg = PlanConfig(l=8, backend="jnp")
    p1 = plan(coo_from_dense(dense), cfg, cache=cache)
    y1 = np.asarray(p1.spmv(v))
    p2 = plan(coo_from_dense(dense), cfg, cache=cache)
    y2 = np.asarray(p2.spmv(v))
    assert len(calls) == 1, "second plan over identical content re-scheduled"
    assert p2.artifact is p1.artifact, "pack not shared through the cache"
    assert np.array_equal(y1, y2)


def test_plan_packs_lazily():
    rng = np.random.default_rng(5)
    p = plan(random_dense(rng, 32, 32, 0.3), PlanConfig(l=8), cache=None)
    assert p._artifact is None, "plan() must not pack before execution"
    p.cost()  # cost reads the artifact
    assert p._artifact is not None


# ---------------------------------------------------------------------------
# to_spec / from_spec round-trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["padded", "ragged"])
@pytest.mark.parametrize("compact", [False, True])
def test_to_spec_round_trip(layout, compact):
    rng = np.random.default_rng(6)
    dense = random_dense(rng, 48, 64, 0.2)
    x = jnp.asarray(rng.standard_normal((64, 2)).astype(np.float32))
    vd, idd = ("bfloat16", "int16") if compact else ("float32", "int32")
    p = plan(
        dense,
        PlanConfig(l=8, layout=layout, backend="jnp", value_dtype=vd,
                   index_dtype=idd),
        cache=None,
    )
    spec = p.to_spec()
    p2 = GustPlan.from_spec(spec)
    # dtype preservation through the codec
    assert p2.artifact.m_blk.dtype == jnp.dtype(vd)
    assert p2.artifact.col_blk.dtype == jnp.dtype(idd)
    assert p2.config.value_dtype == vd and p2.config.index_dtype == idd
    assert p2.layout == layout and p2.shape == p.shape
    # bit-identical execution from the deserialized plan
    assert np.array_equal(np.asarray(p.spmm(x)), np.asarray(p2.spmm(x)))
    # deserialized plans carry no schedule: cost()/shard() refuse cleanly
    with pytest.raises(ValueError):
        p2.cost()


def test_stack_equalizes_and_stacks_leaves():
    rng = np.random.default_rng(7)
    plans = [
        plan(random_dense(rng, 32, 32, d), PlanConfig(l=8, layout="padded"),
             cache=None)
        for d in (0.1, 0.4)
    ]
    stacked = GustPlan.stack(plans)
    c_pad = max(p.artifact.c_pad for p in plans)
    assert stacked["leaves"]["m_blk"].shape[0] == 2
    assert stacked["meta"][2] == c_pad
    # one layer's slice rebuilds through from_spec
    sl = {k: v[0] for k, v in stacked["leaves"].items()}
    p0 = GustPlan.from_spec({"leaves": sl, "meta": stacked["meta"]})
    x = jnp.asarray(rng.standard_normal((32, 2)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(p0.spmm(x)), np.asarray(plans[0].spmm(x)),
        rtol=1e-5, atol=1e-5,
    )
    with pytest.raises(ValueError):
        GustPlan.stack(
            [plans[0],
             plan(random_dense(rng, 32, 32, 0.2),
                  PlanConfig(l=8, layout="ragged"), cache=None)]
        )


def test_spec_for_shapes():
    cfg = PlanConfig(l=16, layout="ragged", c_blk=8)
    p = GustPlan.spec_for(64, 128, cfg, colors=20.0)
    a = p.artifact
    assert isinstance(a, RaggedSchedule)
    assert a.num_blocks == (64 // 16) * 3  # ceil(20/8) = 3 blocks/window
    assert a.m_blk.shape == (a.num_blocks * 8, 16)
    pp = GustPlan.spec_for(64, 128, PlanConfig(l=16, layout="padded"), colors=20.0)
    assert pp.artifact.c_pad == 24


# ---------------------------------------------------------------------------
# transpose_io fast path (GustLinear's double-transpose removal)
# ---------------------------------------------------------------------------


def test_transpose_io_bit_identity():
    rng = np.random.default_rng(8)
    dense = random_dense(rng, 48, 64, 0.2)
    xb = jnp.asarray(rng.standard_normal((5, 64)).astype(np.float32))  # (B, n)
    for layout in ("padded", "ragged"):
        p = plan(dense, PlanConfig(l=8, layout=layout, backend="jnp"),
                 cache=None)
        legacy = np.asarray(
            execute_spmm(p.artifact, xb.T, use_kernel=False).T
        )
        fast = np.asarray(p.spmm(xb, transpose_io=True))
        assert np.array_equal(legacy, fast), layout


def test_gust_linear_uses_transpose_io_bit_identically():
    rng = np.random.default_rng(9)
    w = rng.standard_normal((48, 64)).astype(np.float32)
    x = jnp.asarray(rng.standard_normal((5, 64)).astype(np.float32))
    gl = GustLinear(w, config=PlanConfig(l=8, backend="jnp"), density=0.25)
    legacy = np.asarray(
        execute_spmm(gl.packed, x.T, use_kernel=False).T
    )
    assert np.array_equal(np.asarray(gl(x)), legacy)


# ---------------------------------------------------------------------------
# every legacy entry point routes through GustPlan (acceptance criterion)
# ---------------------------------------------------------------------------


def test_every_entry_point_routes_through_gust_plan(monkeypatch):
    calls = []
    orig_spmm, orig_spmv = GustPlan.spmm, GustPlan.spmv

    def counting_spmm(self, x, **kw):
        calls.append("spmm")
        return orig_spmm(self, x, **kw)

    def counting_spmv(self, v):
        calls.append("spmv")
        return orig_spmv(self, v)

    monkeypatch.setattr(GustPlan, "spmm", counting_spmm)
    monkeypatch.setattr(GustPlan, "spmv", counting_spmv)

    def hits(fn):
        calls.clear()
        fn()
        return set(calls)

    rng = np.random.default_rng(10)
    dense = random_dense(rng, 32, 32, 0.3)
    coo = coo_from_dense(dense)
    sched = schedule(coo, 8)
    v = jnp.asarray(rng.standard_normal(32).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((32, 2)).astype(np.float32))

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert "spmv" in hits(lambda: spmv_mod.spmv(coo, v, l=8))
        assert "spmm" in hits(
            lambda: gust_spmm_auto(sched, x, use_kernel=False)
        )
    assert "spmm" in hits(lambda: spmv_mod.spmm_scheduled(sched, x))
    assert "spmm" in hits(lambda: spmv_mod.spmm_ragged(pack_ragged(sched), x))
    assert "spmm" in hits(
        lambda: gust_spmm(pack_schedule(sched), x, use_kernel=False)
    )

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    assert "spmv" in hits(
        lambda: spmv_mod.distributed_spmv(sched, v, mesh, axis="data")
    )

    w = rng.standard_normal((16, 32)).astype(np.float32)
    gl = GustLinear(w, config=PlanConfig(l=8, backend="jnp"), density=0.5)
    assert "spmm" in hits(lambda: gl(x.T))


def test_serving_decode_routes_through_gust_plan(monkeypatch):
    from repro.configs.base import get_arch
    from repro.models.model_zoo import build_model
    from repro.serving.gust_serve import (
        GustServeConfig,
        decode_step_gust,
        gustify,
    )

    calls = []
    orig_spmm = GustPlan.spmm

    def counting_spmm(self, x, **kw):
        calls.append("spmm")
        return orig_spmm(self, x, **kw)

    monkeypatch.setattr(GustPlan, "spmm", counting_spmm)

    cfg = get_arch("yi_6b").reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    gcfg = GustServeConfig(density=0.5, gust_length=16)
    gust = gustify(lm, params, gcfg)
    caches = lm.init_caches(1, 8, jnp.float32)
    tok = jnp.zeros((1, 1), jnp.int32)
    calls.clear()
    logits, _ = decode_step_gust(
        lm, params, gust, caches, tok, jnp.int32(0), cfg=gcfg,
        dtype=jnp.float32,
    )
    assert "spmm" in calls, "serving decode bypassed GustPlan"
    assert np.all(np.isfinite(np.asarray(logits)))


# ---------------------------------------------------------------------------
# sharded execution through the plan
# ---------------------------------------------------------------------------


def test_plan_shard_single_device_matches_dense():
    rng = np.random.default_rng(11)
    dense = random_dense(rng, 64, 32, 0.2)
    v = jnp.asarray(rng.standard_normal(32).astype(np.float32))
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    p = plan(dense, PlanConfig(l=8, backend="jnp"), cache=ScheduleCache())
    y = np.asarray(p.shard(mesh, "data").spmv(v))
    np.testing.assert_allclose(y, dense @ v, rtol=1e-4, atol=1e-4)
    with pytest.raises(NotImplementedError):
        p.shard(mesh, "data").spmm(jnp.zeros((32, 2), jnp.float32))


# ---------------------------------------------------------------------------
# cost
# ---------------------------------------------------------------------------


def test_plan_cost_fields():
    rng = np.random.default_rng(12)
    dense = random_dense(rng, 64, 64, 0.2)
    p = plan(dense, PlanConfig(l=8), cache=None)
    c = p.cost()
    assert c.cycles == p.sched.cycles
    assert 0 < c.utilization <= 1
    assert c.waste_ratio >= 1.0
    assert c.layout in ("padded", "ragged")
    assert c.streamed_slots > 0 and c.stream_bytes > 0
    assert c.expected_cycles > 0 and 0 < c.expected_utilization <= 1
    assert c.to_dict()["density"] == pytest.approx(
        p.sched.nnz / dense.size
    )


# ---------------------------------------------------------------------------
# deprecated spellings warn with the new one
# ---------------------------------------------------------------------------


def test_legacy_kwarg_shims_warn():
    rng = np.random.default_rng(13)
    dense = random_dense(rng, 16, 16, 0.3)
    coo = coo_from_dense(dense)
    sched = schedule(coo, 8)
    v = jnp.asarray(rng.standard_normal(16).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((16, 2)).astype(np.float32))
    with pytest.warns(DeprecationWarning, match="colorer"):
        spmv_mod.spmv(coo, v, l=8, method="fast")
    with pytest.warns(DeprecationWarning, match="layout='auto'"):
        gust_spmm_auto(sched, x, use_kernel=False)
    with pytest.warns(DeprecationWarning, match="gust_length"):
        SparsityConfig(enable=True, gust_length=8)
