"""Hypothesis property test: ragged-vs-padded bit-identity of ``gust_spmm``
over random AND power-law-degree matrices, all three colorers, both
load-balance modes (the ISSUE 2 equivalence acceptance).  The sweep/edge
cases live in ``test_ragged.py``; this module needs hypothesis and is
skipped without it (like ``test_scheduler.py``)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.formats import coo_from_dense
from repro.core.scheduler import schedule

from test_ragged import all_paths, assert_equivalent, power_law_dense, \
    random_dense

matrix_strategy = st.tuples(
    st.integers(2, 48),  # m
    st.integers(2, 64),  # n
    st.sampled_from([0.05, 0.2, 0.5]),
    st.sampled_from([4, 8, 16]),  # l
    st.integers(1, 4),  # B
    st.booleans(),  # power-law skew
    st.integers(0, 10_000),  # seed
)


@pytest.mark.parametrize("method", ["paper", "fast", "exact"])
@settings(max_examples=20, deadline=None)
@given(args=matrix_strategy)
def test_ragged_equivalence_property(method, args):
    m, n, density, l, b, skew, seed = args
    rng = np.random.default_rng(seed)
    dense = (
        power_law_dense(rng, m, n, base_density=density * 0.2)
        if skew
        else random_dense(rng, m, n, density)
    )
    x = rng.standard_normal((n, b)).astype(np.float32)
    for lb in (False, True):
        sched = schedule(coo_from_dense(dense), l, load_balance=lb,
                         method=method)
        ys, _, _ = all_paths(sched, x)
        assert_equivalent(ys, dense @ x)
