"""Compact GUST stream (bf16 values + int16 indices — EXPERIMENTS.md
§Perf iteration 8): numerical parity with the f32/int32 stream, kernel
and XLA paths, plus the stream-size accounting."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core.formats import coo_from_dense
from repro.core.scheduler import schedule
from repro.kernels.ops import gust_spmm, pack_schedule
from repro.models.model_zoo import build_model
from repro.serving.gust_serve import GustServeConfig, decode_step_gust, gustify


def test_compact_pack_parity():
    rng = np.random.default_rng(0)
    dense = ((rng.random((96, 128)) < 0.2) * rng.standard_normal((96, 128))).astype(
        np.float32
    )
    x = rng.standard_normal((128, 4)).astype(np.float32)
    sched = schedule(coo_from_dense(dense), 16)
    full = pack_schedule(sched)
    compact = pack_schedule(sched, value_dtype=jnp.bfloat16, index_dtype=jnp.int16)
    assert compact.col_blk.dtype == jnp.int16 and compact.m_blk.dtype == jnp.bfloat16
    y_full = np.asarray(gust_spmm(full, jnp.asarray(x), use_kernel=False))
    for uk in (False, True):
        y_c = np.asarray(
            gust_spmm(compact, jnp.asarray(x), use_kernel=uk)
        ).astype(np.float32)
        err = np.abs(y_c - y_full).max() / (np.abs(y_full).max() + 1e-9)
        assert err < 2e-2, (uk, err)  # bf16 value rounding


def test_compact_gust_decode_close_to_full():
    cfg = get_arch("yi_6b").reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    caches = lm.init_caches(2, 64, jnp.float32)
    toks = jnp.tile(jnp.arange(8, dtype=jnp.int32)[None], (2, 1))
    _, caches = lm.prefill(params, {"tokens": toks}, caches, dtype=jnp.float32)
    tok = jnp.full((2, 1), 3, jnp.int32)
    outs = {}
    for compact in (False, True):
        gcfg = GustServeConfig(density=0.5, gust_length=16, compact=compact)
        gust = gustify(lm, params, gcfg)
        lg, _ = decode_step_gust(lm, params, gust, caches, tok, jnp.int32(8),
                                 cfg=gcfg, dtype=jnp.float32)
        outs[compact] = np.asarray(lg)
        # stream bytes: compact must be exactly half (12 -> 6 B/slot)
        m_blk = gust["mats"]["w_down"]["leaves"]["m_blk"]
        col = gust["mats"]["w_down"]["leaves"]["col_blk"]
        per_slot = m_blk.dtype.itemsize + 2 * col.dtype.itemsize
        assert per_slot == (6 if compact else 12)
    err = np.abs(outs[True] - outs[False]).max() / np.abs(outs[False]).max()
    assert err < 5e-2, err


def test_int16_range_guard():
    """Compact indices require n <= int16 range; every assigned arch's MLP
    dims satisfy it."""
    from repro.configs.base import ARCH_IDS
    for aid in ARCH_IDS:
        cfg = get_arch(aid)
        if cfg.d_ff:
            assert max(cfg.d_ff, cfg.d_model) < 2 ** 15, aid
