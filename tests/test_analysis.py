"""repro.analysis: artifact verifier mutation matrix, policy linter,
kernel audit, and the PlanStore verify-on-load mode.

The verifier tests are mutation tests: each seeds exactly one corruption
into a clean artifact's leaves and asserts exactly that rule fires —
plus a clean pass over both layouts x f32/int8 x both gathers that must
produce zero findings.
"""

import os
import shutil
import subprocess
import sys

import numpy as np
import pytest

from conftest import REPO, SRC

from repro.analysis.verify import verify
from repro.core.formats import COOMatrix
from repro.core.plan import plan
from repro.core.plan_store import PlanStore


L = 8


def _coo(m=96, n=80, nnz=600, seed=3):
    r = np.random.default_rng(seed)
    idx = r.choice(m * n, size=nnz, replace=False)
    rows, cols = idx // n, idx % n
    vals = r.standard_normal(nnz).astype(np.float32)
    order = np.argsort(rows * n + cols)
    return COOMatrix((m, n), rows[order].astype(np.int64),
                     cols[order].astype(np.int64), vals[order])


def _leaves_meta(p):
    """Deep-copied (leaves, meta) wire form of a plan's artifact, safe to
    mutate."""
    spec = p.to_spec()
    leaves = {k: np.array(np.asarray(v)) for k, v in spec["leaves"].items()}
    return leaves, tuple(spec["meta"])


def _fired(leaves, meta):
    return sorted({f.rule for f in verify(leaves, meta)})


@pytest.fixture(scope="module")
def padded_f32():
    return plan(_coo(), l=L, layout="padded", value_dtype="float32",
                cache=None)


@pytest.fixture(scope="module")
def padded_int8():
    return plan(_coo(), l=L, layout="padded", value_dtype="int8",
                cache=None)


@pytest.fixture(scope="module")
def ragged_f32():
    return plan(_coo(), l=L, layout="ragged", value_dtype="float32",
                cache=None)


# ---------------------------------------------------------------------------
# clean artifacts: zero findings across the config matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["padded", "ragged"])
@pytest.mark.parametrize("value_dtype", ["float32", "int8"])
@pytest.mark.parametrize("gather", ["resident", "local"])
def test_clean_artifact_zero_findings(layout, value_dtype, gather):
    p = plan(_coo(), l=L, layout=layout, value_dtype=value_dtype,
             gather=gather, cache=None)
    assert p.verify() == []


def test_clean_bf16_and_balanced():
    for kw in (dict(value_dtype="bfloat16"),
               dict(load_balance=True),
               dict(load_balance=True, layout="ragged",
                    value_dtype="int8")):
        p = plan(_coo(seed=7), l=L, cache=None, **kw)
        assert p.verify() == []


# ---------------------------------------------------------------------------
# one mutation -> exactly one rule
# ---------------------------------------------------------------------------


def test_p01_padding_value_flip(padded_f32):
    leaves, meta = _leaves_meta(padded_f32)
    m, seg = leaves["m_blk"], leaves["seg_blk"]
    c_pad, c_blk = meta[2], meta[5]
    row_zero = (m == 0).all(axis=1)
    target = None
    for r in range(m.shape[0]):
        # a padding row preceded by another padding row in its window,
        # inside a block whose first referenced segment is 0 (so the
        # slot's untouched col/col_loc stay remap-consistent)
        if (row_zero[r] and r % c_pad != 0 and row_zero[r - 1]
                and (r - 1) // c_pad == r // c_pad
                and seg[r // c_blk, 0] == 0):
            target = r
    assert target is not None, "no padded window with >= 2 padding rows"
    leaves["m_blk"][target, 0] = 1.0
    assert _fired(leaves, meta) == ["GUST-P01"]


def _all_padding_block_row(leaves, c_blk):
    m = leaves["m_blk"]
    t_blk = m.shape[0] // c_blk
    blk_zero = (m == 0).reshape(t_blk, -1).all(axis=1)
    ts = np.flatnonzero(blk_zero)
    assert ts.size, "no all-padding block in the stream"
    return int(ts[0]) * c_blk  # first row of the block


def test_p02_padding_col_not_lane(padded_f32):
    leaves, meta = _leaves_meta(padded_f32)
    r = _all_padding_block_row(leaves, meta[5])
    # lane 0 -> the flipped offset l-1 (still fusable, still remapping
    # consistently through the all-padding block's segment-0 table row)
    leaves["col_blk"][r, 0] = L - 1
    leaves["col_loc"][r, 0] = L - 1
    assert _fired(leaves, meta) == ["GUST-P02"]


def test_p03_padding_row_nonzero(padded_f32):
    leaves, meta = _leaves_meta(padded_f32)
    r = _all_padding_block_row(leaves, meta[5])
    leaves["row_blk"][r, 0] = 3
    assert _fired(leaves, meta) == ["GUST-P03"]


def test_p04_fusable_lane_structure(padded_f32):
    leaves, meta = _leaves_meta(padded_f32)
    assert meta[4], "artifact must be fusable for the GUST-P04 test"
    m, col = leaves["m_blk"], leaves["col_blk"]
    target = None
    for r, j in zip(*np.nonzero(m)):
        off = col[r, j] % L
        # moving one column right stays in the segment and leaves the
        # allowed {lane, l-1-lane} set
        if off == j and (off + 1) % L != 0 and off + 1 != L - 1 - j:
            target = (r, j)
            break
    assert target is not None
    r, j = target
    leaves["col_blk"][r, j] += 1
    leaves["col_loc"][r, j] += 1
    assert _fired(leaves, meta) == ["GUST-P04"]


def test_p05_index_dtype_policy(padded_f32):
    leaves, meta = _leaves_meta(padded_f32)
    leaves["col_blk"] = leaves["col_blk"].astype(np.int64)
    assert _fired(leaves, meta) == ["GUST-P05"]


def test_p06_block_starts_monotone(ragged_f32):
    leaves, meta = _leaves_meta(ragged_f32)
    leaves["block_starts"][1] = leaves["block_starts"][0]
    assert _fired(leaves, meta) == ["GUST-P06"]


def test_p07_block_window_ownership(ragged_f32):
    leaves, meta = _leaves_meta(ragged_f32)
    bs = leaves["block_starts"]
    b = int(bs[1])  # first window boundary: swap the blocks around it
    assert 0 < b < leaves["block_window"].shape[0]
    bw = leaves["block_window"]
    bw[b - 1], bw[b] = bw[b], bw[b - 1]
    assert _fired(leaves, meta) == ["GUST-P07"]


def _row_with_two_segments(seg):
    for t in range(seg.shape[0]):
        nz = seg[t][seg[t] > 0]
        if nz.size >= 2:
            return t
    raise AssertionError("no seg_blk row with two nonzero segments")


def test_p08_seg_row_unsorted(padded_f32):
    leaves, meta = _leaves_meta(padded_f32)
    seg = leaves["seg_blk"]
    t = _row_with_two_segments(seg)
    pos = np.flatnonzero(seg[t] > 0)[:2]
    seg[t, pos[0]], seg[t, pos[1]] = seg[t, pos[1]], seg[t, pos[0]]
    assert _fired(leaves, meta) == ["GUST-P08"]


def test_p09_seg_out_of_bounds(padded_f32):
    leaves, meta = _leaves_meta(padded_f32)
    seg = leaves["seg_blk"]
    seg_count = -(-meta[3][1] // L)
    assert meta[6] >= 2, "need S_blk >= 2"
    seg[0, meta[6] - 1] = seg_count  # stays sorted, lands out of bounds
    assert _fired(leaves, meta) == ["GUST-P09"]


def test_p10_col_loc_remap(padded_f32):
    leaves, meta = _leaves_meta(padded_f32)
    m, col, loc, seg = (leaves["m_blk"], leaves["col_blk"],
                        leaves["col_loc"], leaves["seg_blk"])
    c_blk, s_blk = meta[5], meta[6]
    target = None
    for r, j in zip(*np.nonzero(m)):
        t = r // c_blk
        cur = loc[r, j] // L
        alt = cur + 1 if cur + 1 < s_blk else cur - 1
        if alt >= 0 and seg[t, alt] != col[r, j] // L:
            target = (r, j, alt)
            break
    assert target is not None
    r, j, alt = target
    leaves["col_loc"][r, j] = alt * L + loc[r, j] % L
    assert _fired(leaves, meta) == ["GUST-P10"]


def test_p11_scale_leaf_contract(padded_int8):
    leaves, meta = _leaves_meta(padded_int8)
    leaves["scale_blk"] = leaves["scale_blk"].astype(np.float64)
    assert _fired(leaves, meta) == ["GUST-P11"]


def test_p12_padding_block_scale(padded_int8):
    leaves, meta = _leaves_meta(padded_int8)
    r = _all_padding_block_row(leaves, meta[5])
    leaves["scale_blk"][r // meta[5]] = 2.0
    assert _fired(leaves, meta) == ["GUST-P12"]


def test_p13_quantized_peak(padded_int8):
    leaves, meta = _leaves_meta(padded_int8)
    m = leaves["m_blk"]
    c_blk = meta[5]
    t_blk = m.shape[0] // c_blk
    blocks = m.reshape(t_blk, -1)
    t = int(np.flatnonzero((blocks != 0).any(axis=1))[0])
    blk = m[t * c_blk:(t + 1) * c_blk]
    peak = np.abs(blk) == 127
    assert peak.any()
    blk[peak] = (np.sign(blk[peak]) * 126).astype(np.int8)
    assert _fired(leaves, meta) == ["GUST-P13"]


def test_p14_adder_collision(padded_f32):
    leaves, meta = _leaves_meta(padded_f32)
    m, row = leaves["m_blk"], leaves["row_blk"]
    target = None
    for r in range(m.shape[0]):
        real = np.flatnonzero(m[r] != 0)
        if real.size >= 2:
            target = (r, real[0], real[1])
            break
    assert target is not None
    r, j1, j2 = target
    leaves["row_blk"][r, j2] = row[r, j1]
    assert _fired(leaves, meta) == ["GUST-P14"]


def test_p15_row_perm_not_a_permutation(padded_f32):
    leaves, meta = _leaves_meta(padded_f32)
    perm = leaves["row_perm"]
    perm[0] = perm[1]  # duplicate entry: no longer a bijection
    assert _fired(leaves, meta) == ["GUST-P15"]


def test_p16_canonical_coo():
    good = COOMatrix((4, 4), np.array([0, 1, 2]), np.array([1, 0, 3]),
                     np.array([1.0, 2.0, 3.0], np.float32))
    assert verify(good) == []
    dup = COOMatrix((4, 4), np.array([0, 0, 2]), np.array([1, 1, 3]),
                    np.array([1.0, 2.0, 3.0], np.float32))
    assert sorted({f.rule for f in verify(dup)}) == ["GUST-P16"]
    zeros = COOMatrix((4, 4), np.array([0, 1]), np.array([1, 2]),
                      np.array([1.0, 0.0], np.float32))
    assert sorted({f.rule for f in verify(zeros)}) == ["GUST-P16"]


def test_p17_col_out_of_bounds(padded_f32):
    leaves, meta = _leaves_meta(padded_f32)
    m = leaves["m_blk"]
    seg_count = -(-meta[3][1] // L)
    r, j = next(zip(*np.nonzero(m)))
    leaves["col_blk"][r, j] += seg_count * L
    assert _fired(leaves, meta) == ["GUST-P17"]


def test_mutations_on_ragged_layout(ragged_f32):
    """The element rules run identically on the ragged stream (which has
    no all-padding blocks — only padding slots inside real blocks)."""
    leaves, meta = _leaves_meta(ragged_f32)
    m = leaves["m_blk"]
    pads = np.argwhere(m == 0)
    assert pads.size, "ragged stream has no padding slot"
    r, j = pads[0]
    leaves["row_blk"][r, j] = 2
    assert _fired(leaves, meta) == ["GUST-P03"]


# ---------------------------------------------------------------------------
# wiring: GustPlan.verify, PlanStore verify-on-load, CLI
# ---------------------------------------------------------------------------


def test_plan_verify_method(padded_f32):
    findings = padded_f32.verify()
    assert findings == []


def test_store_verify_on_load(tmp_path):
    store = PlanStore(tmp_path / "store")
    p = plan(_coo(), l=L, layout="padded", cache=None, store=store)
    p.artifact  # materialize -> write-behind
    assert store.writes == 1
    key = store.keys()[0]

    # clean artifact: verify-on-load is a normal hit
    checking = PlanStore(tmp_path / "store", verify="load")
    assert checking.get(key) is not None
    assert checking.corrupt == 0

    # corrupt one leaf in place and re-put under the same key
    record = store.get(key)
    spec = record["spec"]
    bad = {k: np.array(v) for k, v in spec["leaves"].items()}
    bad["row_blk"][_all_padding_block_row(bad, 8), 0] = 3
    store.put(key, {"leaves": bad, "meta": spec["meta"],
                    "config": spec["config"]})

    # verify=off serves the corrupt bits; verify=load counts a corrupt
    # miss and never raises
    assert PlanStore(tmp_path / "store").get(key) is not None
    before = (checking.corrupt, checking.misses)
    assert checking.get(key) is None
    assert (checking.corrupt, checking.misses) == (before[0] + 1,
                                                   before[1] + 1)

    # plan() through the verifying store falls back to a fresh pack
    p2 = plan(_coo(), l=L, layout="padded", cache=None, store=checking)
    assert p2.verify() == []


def test_store_verify_arg_validated(tmp_path):
    with pytest.raises(ValueError):
        PlanStore(tmp_path / "s", verify="always")


def test_serve_config_store_verify_field():
    from repro.serving.gust_serve import GustServeConfig

    cfg = GustServeConfig(plan_store="/tmp/x", store_verify="load")
    assert cfg.store_verify == "load"


def test_cli_verify_store(tmp_path):
    store = PlanStore(tmp_path / "store")
    plan(_coo(), l=L, cache=None, store=store).artifact
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "verify",
         str(tmp_path / "store")],
        capture_output=True, text=True, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "1 artifact(s), 0 failing" in out.stdout


# ---------------------------------------------------------------------------
# policy linter
# ---------------------------------------------------------------------------


def test_lint_src_clean():
    from repro.analysis.lint import lint_sources

    assert lint_sources() == []


def _lint_tmp(tree, tmp_path):
    from repro.analysis.lint import lint_sources

    for rel, src in tree.items():
        path = tmp_path / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(src)
    return lint_sources(str(tmp_path), allowlist="/dev/null")


def test_lint_rules_fire(tmp_path):
    findings = _lint_tmp({
        "repro/__init__.py": "import jax\n",
        "repro/core/x.py": (
            "import numpy as np\n"
            "def shiny_new_api():\n"
            "    np.savez('a.npz')\n"
            "    spmv(None, None)\n"
            "    resolve_layout(None, 8, None)\n"
            "_cache = {}\n"
            "def _lookup(backend):\n"
            "    return _cache.get((1, backend))\n"
        ),
    }, tmp_path)
    rules = sorted({f.rule for f in findings})
    assert rules == ["GUST-L01", "GUST-L02", "GUST-L03", "GUST-L04",
                     "GUST-L05", "GUST-L06"]


def test_lint_l07_bare_except_pass_on_serving_path(tmp_path):
    swallow = (
        "def _risky():\n"
        "    try:\n"
        "        pass\n"
        "    except Exception:\n"
        "        pass\n"
    )
    findings = _lint_tmp({"repro/serving/loop.py": swallow}, tmp_path)
    assert [f.rule for f in findings] == ["GUST-L07"]
    assert findings[0].qualname == "_risky"
    # the same swallow off the serving path is not L07's business
    assert _lint_tmp({"repro/graph/x.py": swallow}, tmp_path / "b") == []
    # a handler that *does* something (count, retire, degrade) is fine
    handled = (
        "def _contained():\n"
        "    try:\n"
        "        pass\n"
        "    except Exception as err:\n"
        "        record(err)\n"
    )
    assert _lint_tmp({"repro/serving/ok.py": handled}, tmp_path / "c") == []
    # narrow except-pass is equally fine: L07 targets broad swallows only
    narrow = (
        "def _narrow():\n"
        "    try:\n"
        "        pass\n"
        "    except KeyError:\n"
        "        pass\n"
    )
    assert _lint_tmp({"repro/serving/nrw.py": narrow}, tmp_path / "d") == []


def test_lint_type_checking_import_allowed(tmp_path):
    findings = _lint_tmp({
        "repro/__init__.py": (
            "from typing import TYPE_CHECKING\n"
            "if TYPE_CHECKING:\n"
            "    import jax\n"
        ),
    }, tmp_path)
    assert findings == []


def test_lint_allowlist_silences_exact_site(tmp_path):
    (tmp_path / "allow.txt").write_text(
        "GUST-L02  repro/core/x.py::shiny  # test entry\n")
    from repro.analysis.lint import lint_sources

    (tmp_path / "repro" / "core").mkdir(parents=True)
    (tmp_path / "repro" / "core" / "x.py").write_text(
        "def shiny():\n    pass\n\n\ndef other():\n    pass\n")
    findings = lint_sources(str(tmp_path),
                            allowlist=str(tmp_path / "allow.txt"))
    assert [f.qualname for f in findings] == ["other"]


# ---------------------------------------------------------------------------
# kernel audit
# ---------------------------------------------------------------------------


def test_audit_clean_tree():
    from repro.analysis.kernel_audit import audit_kernels

    result = audit_kernels()
    assert result.ok, [str(f) for f in result.findings]
    builders = {r.builder.split("::")[1] for r in result.reports}
    assert {"make_gust_spmv", "make_gust_spmv_local", "make_gust_spmv_db",
            "make_gust_spmv_local_db", "make_gust_spmv_ragged",
            "make_gust_spmv_ragged_db", "make_gust_spgemm",
            "make_gather_fill"} <= builders
    assert len(result.db_kernels_checked) >= 4
    assert result.subscripts_checked > 0
    assert all(r.vmem_bytes > 0 for r in result.reports)


def test_audit_over_budget_config():
    from repro.analysis.kernel_audit import (DEFAULT_CONFIGS, audit_kernels)

    huge = dict(DEFAULT_CONFIGS[0], name="huge", seg_count=65536, l=256,
                b=8, c_pad=64, num_windows=16)
    result = audit_kernels(configs=(huge,))
    assert any(f.rule == "GUST-K01" for f in result.findings)


def _patched_kernels(tmp_path, old, new):
    kdir = tmp_path / "kernels"
    shutil.copytree(os.path.join(SRC, "repro", "kernels"), kdir,
                    ignore=shutil.ignore_patterns("__pycache__"))
    path = kdir / "gust_spmv.py"
    src = path.read_text()
    assert old in src
    path.write_text(src.replace(old, new))
    return str(kdir)


def test_audit_catches_missing_wait(tmp_path):
    from repro.analysis.kernel_audit import audit_kernels

    kdir = _patched_kernels(tmp_path, "c.wait()", "pass")
    result = audit_kernels(kernels_dir=kdir)
    assert any(f.rule == "GUST-K02" and "_db_kernel" in f.builder
               for f in result.findings)


def test_audit_catches_same_slot_prefetch(tmp_path):
    from repro.analysis.kernel_audit import audit_kernels

    kdir = _patched_kernels(tmp_path, "copies(1 - slot, i + 1)",
                            "copies(slot, i + 1)")
    result = audit_kernels(kernels_dir=kdir)
    assert any(f.rule == "GUST-K02" for f in result.findings)


def test_audit_catches_index_overrun(tmp_path):
    from repro.analysis.kernel_audit import audit_kernels

    kdir = _patched_kernels(
        tmp_path,
        "seg[(w * num_cb + cb) * s_blk + s]",
        "seg[(w * num_cb + cb) * s_blk + s + 1]")
    result = audit_kernels(kernels_dir=kdir)
    assert any(f.rule == "GUST-K03" for f in result.findings)


def test_cli_lint_and_audit():
    env = dict(os.environ, PYTHONPATH=SRC)
    for cmd in ("lint", "audit"):
        out = subprocess.run(
            [sys.executable, "-m", "repro.analysis", cmd],
            capture_output=True, text=True, env=env, cwd=REPO,
        )
        assert out.returncode == 0, out.stdout + out.stderr
        assert "0 finding(s)" in out.stdout
