"""Paper §3.4 statistical bound (Eqs. 9-11) and §2 baseline dataflow
models (Table 1): the bound must dominate the empirical scheduler, the
closed forms must match Table 1, and the utilization ordering of Fig. 7
must reproduce."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.baselines import (
    all_designs,
    model_1d,
    model_adder_tree,
    model_fafnir,
    model_flex_tpu,
    model_gust,
    model_gust_naive,
)
from repro.core.bounds import (
    expected_colors_bound,
    expected_execution_cycles,
    expected_utilization,
)
from repro.core.scheduler import schedule
from repro.data.matrices import (
    REAL_WORLD_SUITE,
    make_real_world_surrogate,
    synth_power_law,
    synth_uniform,
)


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([256, 512, 1024]),
    p=st.sampled_from([0.02, 0.05, 0.1]),
    l=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 100),
)
def test_eq9_bound_dominates_empirical(n, p, l, seed):
    """E[C] bound (Eq. 9) >= mean colors of the actual scheduler on
    uniform matrices (within sampling noise)."""
    coo = synth_uniform(n, p, seed=seed)
    sched = schedule(coo, l, load_balance=False, method="exact")
    mean_colors = sched.total_colors / sched.num_windows
    bound = expected_colors_bound(n, p, l)
    assert mean_colors <= bound * 1.05  # 5% sampling slack


def test_eq10_eq11_consistency():
    n, p, l = 1024, 0.05, 64
    exe = expected_execution_cycles(n, p, l)
    util = expected_utilization(n, p, l)
    # Eq. 11 drops the +2: util ~= (#NZ/l) / exe
    approx = (n * n * p / l) / exe
    assert abs(util - approx) / util < 0.01


def test_eq11_monotonic_in_density_and_length():
    us = [expected_utilization(4096, p, 256) for p in (1e-3, 1e-2, 1e-1)]
    assert us[0] < us[1] < us[2], "denser -> higher utilization"
    ul = [expected_utilization(4096, 1e-2, l) for l in (64, 256, 1024)]
    assert ul[0] > ul[2], "longer GUST -> (slightly) lower utilization"


def test_table1_closed_forms():
    coo = synth_uniform(512, 0.05, seed=0)
    m, n = coo.shape
    assert model_1d(coo, 256).cycles == pytest.approx(m * n / 256 + 257)
    assert model_adder_tree(coo, 256).cycles == pytest.approx(
        m * n / 256 + np.log2(256) + 1
    )
    ft = model_flex_tpu(coo, 16)
    assert ft.cycles >= 3 * 16  # at least one partition
    assert model_fafnir(coo, 128).units == 128 + 448  # paper resource split


def test_fig7_utilization_ordering():
    """GUST EC/LB > GUST EC > all baselines on a sparse matrix; naive GUST
    collapses at higher density (the paper's §3.3 crossover)."""
    coo = synth_uniform(1024, 0.01, seed=2)
    d = all_designs(coo, 256)
    gust_lb = d["gust_ec_lb"].utilization
    gust_ec = d["gust_ec"].utilization
    for k in ("1d", "adder_tree", "flex_tpu", "fafnir", "gust_naive"):
        assert gust_lb > d[k].utilization, k
    assert gust_lb >= gust_ec * 0.999
    # 1D utilization equals density (both definitions reduce to it)
    assert d["1d"].utilization == pytest.approx(coo.density, rel=0.1)


def test_naive_crossover_with_density():
    """Paper: naive GUST becomes worse than 1D beyond density ~0.008 on
    16384^2 matrices — reproduce the crossover direction on 2048^2."""
    lo = synth_uniform(2048, 0.002, seed=3)
    hi = synth_uniform(2048, 0.05, seed=3)
    naive_lo = model_gust_naive(lo, 256)
    naive_hi = model_gust_naive(hi, 256)
    d1_lo, d1_hi = model_1d(lo, 256), model_1d(hi, 256)
    assert naive_lo.cycles < d1_lo.cycles  # sparse: naive still wins
    assert naive_hi.cycles > d1_hi.cycles  # dense: collisions kill it


def test_gust_cycles_match_schedule():
    coo = synth_power_law(512, 0.02, seed=1)
    rep = model_gust(coo, 64, load_balance=True)
    sched = schedule(coo, 64, load_balance=True)
    assert rep.cycles == sched.cycles
    assert rep.utilization == pytest.approx(sched.hardware_utilization, rel=1e-6)


def test_real_world_surrogates_generate():
    spec = REAL_WORLD_SUITE[0]
    coo = make_real_world_surrogate(spec, scale=0.02, seed=0)
    assert coo.nnz > 0
    assert abs(coo.shape[0] - int(spec.dim * 0.02)) <= 1
