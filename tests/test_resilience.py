"""Resilience layer (PR 10): deterministic fault injection, retry/backoff,
request lifecycle hardening, and graceful degradation.

The contracts under test (ROADMAP §Resilience invariants):

* a :class:`FaultPlan` replays **identically** by seed — in-process and
  across a fresh interpreter — and costs one ``None`` check when disabled;
* every request ``ServeLoop`` ever sees ends with exactly one definite
  status (DONE / FAILED / TIMEOUT / SHED / CANCELLED), faults on one
  request never perturb another (survivors are **bitwise** equal to a
  fault-free run), and a contained batched-decode fault is retried
  bitwise;
* the store never serves a torn or corrupt artifact — counted miss,
  fresh re-pack, bitwise-identical execution (the PR 7 warm==cold gate);
* the three fallback chains degrade through ``resolve_fallback`` and are
  counted: ``gather local -> resident`` and ``stored -> fresh`` bitwise,
  ``pallas -> jnp`` tolerance-equal.
"""

import glob
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import SRC
from repro.configs.base import get_arch
from repro.core.formats import coo_from_dense
from repro.core.packing import ScheduleCache
from repro.core.plan import PlanConfig, plan
from repro.core.plan_store import PlanStore
from repro.models.model_zoo import build_model
from repro.resilience import faults
from repro.resilience.fallback import (
    fallback_counters,
    record_fallback,
    resolve_fallback,
)
from repro.resilience.faults import FaultError, FaultPlan, FaultSpec, injected
from repro.resilience.lifecycle import RequestResult, RequestStatus
from repro.resilience.retry import backoff_schedule, retrying
from repro.serving import ServeConfig, ServeLoop


# ---------------------------------------------------------------------------
# fault plan: determinism, zero overhead, scoping
# ---------------------------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("serve.decode", kind="explode")
    with pytest.raises(ValueError):
        FaultSpec("serve.decode", rate=1.5)
    with pytest.raises(ValueError):
        FaultSpec("serve.decode", kind="delay", delay_s=-1.0)
    with pytest.raises(TypeError):
        FaultPlan(["serve.decode"])  # type: ignore[list-item]


def test_trip_disabled_is_noop():
    faults.clear()
    assert not faults.enabled()
    assert faults.trip("serve.decode") is None
    assert faults.trip("not.a.site", tag="x") is None


def _chaos_workload(seed: int):
    """A fixed trip sequence over two sites with partial-rate specs;
    returns the fired record.  Mirrored verbatim in the subprocess
    determinism test below."""
    fp = FaultPlan(
        [
            FaultSpec("serve.decode", rate=0.4, times=-1),
            FaultSpec("store.get", rate=0.25, times=-1, error=OSError),
        ],
        seed=seed,
    )
    with injected(fp):
        for i in range(40):
            for site in ("serve.decode", "store.get"):
                try:
                    faults.trip(site, tag=str(i % 3))
                except Exception:
                    pass
    return fp.fingerprint()


def test_fault_plan_deterministic_in_process():
    a = _chaos_workload(seed=11)
    b = _chaos_workload(seed=11)
    assert a == b
    assert a, "rate=0.4 over 40 hits should have fired at least once"
    assert _chaos_workload(seed=12) != a


def test_fault_plan_deterministic_across_processes():
    code = (
        "import json\n"
        "from repro.resilience.faults import FaultPlan, FaultSpec, injected\n"
        "from repro.resilience import faults\n"
        "fp = FaultPlan([\n"
        "    FaultSpec('serve.decode', rate=0.4, times=-1),\n"
        "    FaultSpec('store.get', rate=0.25, times=-1, error=OSError),\n"
        "], seed=11)\n"
        "with injected(fp):\n"
        "    for i in range(40):\n"
        "        for site in ('serve.decode', 'store.get'):\n"
        "            try:\n"
        "                faults.trip(site, tag=str(i % 3))\n"
        "            except Exception:\n"
        "                pass\n"
        "print(json.dumps(fp.fired))\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    child = [tuple(ev) for ev in json.loads(proc.stdout)]
    assert child == list(_chaos_workload(seed=11))


def test_fault_plan_reset_and_counts():
    fp = FaultPlan([FaultSpec("serve.decode", times=2)], seed=0)
    with injected(fp):
        for _ in range(4):
            try:
                faults.trip("serve.decode")
            except FaultError:
                pass
    assert fp.counts() == {"serve.decode": 2}
    first = fp.fingerprint()
    fp.reset()
    assert fp.fingerprint() == ()
    with injected(fp):
        for _ in range(4):
            try:
                faults.trip("serve.decode")
            except FaultError:
                pass
    assert fp.fingerprint() == first  # exact replay after reset


def test_fault_spec_tag_after_and_delay():
    fp = FaultPlan([
        FaultSpec("serve.slot", tag="7", times=-1),
        FaultSpec("pack.materialize", kind="delay", delay_s=0.0, after=1),
    ])
    with injected(fp):
        assert faults.trip("serve.slot", tag="3") is None  # tag mismatch
        with pytest.raises(FaultError):
            faults.trip("serve.slot", tag="7")
        assert faults.trip("pack.materialize") is None  # armed late (after=1)
        faults.trip("pack.materialize")  # 2nd hit: delay fires (0s sleep)
    assert [ev[1] for ev in fp.fired] == ["serve.slot", "pack.materialize"]


def test_injected_restores_previous_plan():
    outer = FaultPlan([FaultSpec("serve.decode", times=-1)])
    inner = FaultPlan([])
    faults.install(outer)
    try:
        with injected(inner):
            assert faults.trip("serve.decode") is None  # inner has no specs
        with pytest.raises(FaultError):
            faults.trip("serve.decode")  # outer restored
    finally:
        faults.clear()


def test_fault_plan_excluded_from_plan_keys(tmp_path):
    """A FaultPlan is an execution knob (PR 7 sense): content/store keys
    must be identical with and without one installed."""
    coo = coo_from_dense(_random_dense(3))
    pc = PlanConfig(l=32)
    store = PlanStore(str(tmp_path))
    mk, tok = ScheduleCache.matrix_key(coo), PlanStore.config_token(pc)
    k = store.key(mk, pc)
    with injected(FaultPlan([FaultSpec("serve.decode", times=-1)])):
        assert ScheduleCache.matrix_key(coo) == mk
        assert PlanStore.config_token(pc) == tok
        assert store.key(mk, pc) == k


# ---------------------------------------------------------------------------
# retry/backoff
# ---------------------------------------------------------------------------


def test_backoff_schedule_deterministic_and_bounded():
    a = backoff_schedule(6, base_delay=0.1, max_delay=1.0, seed=3)
    assert a == backoff_schedule(6, base_delay=0.1, max_delay=1.0, seed=3)
    for k, d in enumerate(a):
        lo = min(1.0, 0.1 * 2.0 ** k)
        assert lo <= d <= lo * 1.5  # jitter in [0, 0.5)
    assert backoff_schedule(3, base_delay=0.1, jitter=0.0) == (0.1, 0.2, 0.4)
    assert backoff_schedule(2) == (0.0, 0.0)  # training default: no sleeping


def test_retrying_succeeds_after_transients():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retrying(flaky, max_retries=3)() == "ok"
    assert len(calls) == 3


def test_retrying_terminal_message_matches_training_contract():
    def always():
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError, match="step failed after 2 retries"):
        retrying(always, max_retries=2)()


def test_retrying_backoff_schedule_and_elapsed_budget():
    sleeps = []

    def always():
        raise ValueError("down")

    wrapped = retrying(
        always, max_retries=8, retry_on=(ValueError,),
        base_delay=1.0, jitter=0.0, max_elapsed=2.5, sleep=sleeps.append,
    )
    # delays would be 1, 2, 4, ...; the 4s sleep busts the 2.5s budget,
    # so retrying degrades early instead of blocking the serving path
    with pytest.raises(RuntimeError, match="budget"):
        wrapped()
    assert sleeps == [1.0, 2.0]


def test_retrying_respects_retry_on():
    def boom():
        raise KeyError("not transient")

    with pytest.raises(KeyError):
        retrying(boom, max_retries=3, retry_on=(RuntimeError,))()


def test_training_retrying_is_reexport():
    from repro.training.fault_tolerance import retrying as training_retrying

    assert training_retrying is retrying


# ---------------------------------------------------------------------------
# lifecycle + fallback primitives
# ---------------------------------------------------------------------------


def test_request_status_and_result():
    assert str(RequestStatus.TIMEOUT) == "TIMEOUT"
    assert RequestStatus.DONE == "DONE"  # str-enum: JSON/log friendly
    r = RequestResult(3, RequestStatus.DONE, [1, 2], steps=2)
    assert r.ok and r.tokens == [1, 2]
    assert not RequestResult(4, RequestStatus.SHED, []).ok


def test_resolve_fallback_chains():
    assert resolve_fallback("kernel", "pallas") == "jnp"
    assert resolve_fallback("kernel", "jnp") is None  # floor: nowhere to go
    assert resolve_fallback("gather", "local") == "resident"
    assert resolve_fallback("gather", "resident") is None
    assert resolve_fallback("store", "stored") == "fresh"
    with pytest.raises(ValueError):
        resolve_fallback("parser", "x")
    before = fallback_counters["pallas_to_jnp"]
    record_fallback("kernel")
    assert fallback_counters["pallas_to_jnp"] == before + 1


# ---------------------------------------------------------------------------
# plan store under fire
# ---------------------------------------------------------------------------


def _random_dense(seed=0, m=40, n=48, density=0.25):
    rng = np.random.default_rng(seed)
    return ((rng.random((m, n)) < density)
            * rng.standard_normal((m, n))).astype(np.float32)


def _probe(n, b=3, seed=99):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, b)).astype(np.float32))


def test_store_put_crash_never_leaves_torn_file(tmp_path):
    dense = _random_dense(1)
    pc = PlanConfig(l=32)
    store = PlanStore(str(tmp_path))
    p = plan(dense, pc, cache=None, store=store)
    with injected(FaultPlan([FaultSpec("store.put.crash", times=-1)])):
        y = np.asarray(p.spmm(_probe(dense.shape[1])))  # materializes + puts
    # the crash hit between write and fsync: no final artifact may exist,
    # and the stray temp file is cleaned — a reader sees a clean miss
    assert len(store) == 0 and store.writes == 0
    assert glob.glob(os.path.join(str(tmp_path), "*.tmp.*")) == []
    fresh = PlanStore(str(tmp_path))
    assert fresh.get(store.key(ScheduleCache.matrix_key(
        coo_from_dense(dense)), pc)) is None
    # the contained put failure never perturbed execution
    p2 = plan(dense, pc, cache=None, store=PlanStore(str(tmp_path)))
    assert np.array_equal(y, np.asarray(p2.spmm(_probe(dense.shape[1]))))


def test_store_torn_file_is_counted_corrupt_miss(tmp_path):
    dense = _random_dense(2)
    pc = PlanConfig(l=32)
    store = PlanStore(str(tmp_path))
    p = plan(dense, pc, cache=None, store=store)
    y = np.asarray(p.spmm(_probe(dense.shape[1])))
    [key] = store.keys()
    path = store._file(key)
    blob = open(path, "rb").read()
    with open(path, "wb") as f:  # torn write: half the container
        f.write(blob[: len(blob) // 2])
    fresh = PlanStore(str(tmp_path))
    assert fresh.get(key) is None  # never served
    assert fresh.corrupt == 1 and fresh.misses == 1
    # planning through the torn store re-packs fresh, bitwise
    p2 = plan(dense, pc, cache=None, store=fresh)
    assert np.array_equal(y, np.asarray(p2.spmm(_probe(dense.shape[1]))))


def test_store_injected_corruption_is_counted_miss(tmp_path):
    dense = _random_dense(3)
    pc = PlanConfig(l=32)
    store = PlanStore(str(tmp_path))
    plan(dense, pc, cache=None, store=store).spmm(_probe(dense.shape[1]))
    [key] = store.keys()
    with injected(FaultPlan([FaultSpec("store.get.corrupt", kind="corrupt")])):
        assert store.get(key) is None
    assert store.corrupt == 1
    assert store.get(key) is not None  # the file itself is intact


def test_store_read_retry_then_serve(tmp_path):
    """An OSError on the first two read attempts is absorbed by the
    jittered-backoff retry; the third attempt serves the artifact."""
    dense = _random_dense(4)
    pc = PlanConfig(l=32)
    store = PlanStore(str(tmp_path), retry_base_s=0.0)
    plan(dense, pc, cache=None, store=store).spmm(_probe(dense.shape[1]))
    [key] = store.keys()
    with injected(FaultPlan([FaultSpec("store.get", error=OSError, times=2)])):
        rec = store.get(key)
    assert rec is not None
    assert store.io_retries == 2 and store.io_errors == 0


def test_store_read_failure_degrades_stored_to_fresh_bitwise(tmp_path):
    dense = _random_dense(5)
    pc = PlanConfig(l=32)
    warm = PlanStore(str(tmp_path), retry_base_s=0.0)
    p = plan(dense, pc, cache=None, store=warm)
    y = np.asarray(p.spmm(_probe(dense.shape[1])))
    before = fallback_counters["stored_to_fresh"]
    store = PlanStore(str(tmp_path), retry_base_s=0.0)
    with injected(FaultPlan([FaultSpec("store.get", error=OSError, times=-1)])):
        p2 = plan(dense, pc, cache=None, store=store)
        y2 = np.asarray(p2.spmm(_probe(dense.shape[1])))
    assert fallback_counters["stored_to_fresh"] == before + 1
    assert p2.cost().fallback_store == 1  # surfaced on the plan's cost
    assert store.io_errors == 1 and store.misses == 1
    assert np.array_equal(y, y2), "stored->fresh degradation must be bitwise"


# ---------------------------------------------------------------------------
# executor degradation chains
# ---------------------------------------------------------------------------


def test_gather_local_fault_degrades_to_resident_bitwise():
    dense = _random_dense(6, m=64, n=96, density=0.1)
    x = _probe(96)
    y_res = np.asarray(plan(dense, l=32, backend="jnp", gather="resident",
                            cache=None).spmm(x))
    p = plan(dense, l=32, backend="jnp", gather="local", cache=None)
    before = fallback_counters["local_to_resident"]
    with injected(FaultPlan([FaultSpec("gather.local")])):
        y = np.asarray(p.spmm(x))
    assert fallback_counters["local_to_resident"] == before + 1
    assert p.cost().fallback_gather == 1
    assert np.array_equal(y, y_res), "local->resident must be bitwise (PR 5)"
    # the fault was times=1: the next call runs the local path, bitwise too
    assert np.array_equal(np.asarray(p.spmm(x)), y_res)


def test_kernel_fault_degrades_to_jnp_within_tolerance():
    dense = _random_dense(7, m=64, n=96, density=0.1)
    x = _probe(96)
    y_ref = np.asarray(plan(dense, l=32, backend="jnp", gather="resident",
                            cache=None).spmm(x))
    p = plan(dense, l=32, backend="pallas", interpret=True, gather="resident",
             cache=None)
    before = fallback_counters["pallas_to_jnp"]
    with injected(FaultPlan([FaultSpec("kernel.execute", tag="pallas")])):
        y = np.asarray(p.spmm(x))
    assert fallback_counters["pallas_to_jnp"] == before + 1
    assert p.cost().fallback_kernel == 1
    assert np.allclose(y, y_ref, rtol=1e-5, atol=1e-6)


def test_exhausted_fallback_chain_reraises():
    dense = _random_dense(8)
    p = plan(dense, l=32, backend="jnp", gather="resident", cache=None)
    # resident + jnp is the floor: nothing to degrade to -> the original
    # error propagates (callers above serving handle it; ServeLoop's
    # containment turns it into a FAILED retirement)
    with injected(FaultPlan([FaultSpec("kernel.execute", tag="jnp",
                                       times=-1)])):
        with pytest.raises(FaultError):
            p.spmm(_probe(dense.shape[1]))


# ---------------------------------------------------------------------------
# serving lifecycle under fire (small dense model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dense_lm():
    cfg = get_arch("yi_6b").reduced()
    lm = build_model(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


def _mk_loop(dense_lm, **cfg_kw):
    lm, params = dense_lm
    cfg_kw.setdefault("batch", 2)
    cfg_kw.setdefault("seq_len", 32)
    sc = ServeConfig(dtype="float32", **cfg_kw)
    return ServeLoop(lm, params, sc)


PROMPTS = [np.arange(4, dtype=np.int32), np.arange(6, dtype=np.int32) + 3]


def test_enqueue_sheds_structured_at_capacity(dense_lm):
    loop = _mk_loop(dense_lm, queue_capacity=2)
    rids = [loop.enqueue(p, max_new=2) for p in PROMPTS]
    shed = loop.enqueue(np.arange(5, dtype=np.int32), max_new=2)
    res = loop.results[shed]
    assert res.status is RequestStatus.SHED and "queue full" in res.reason
    assert loop.stats["shed"] == 1
    loop.run_to_completion()
    assert all(loop.results[r].status is RequestStatus.DONE for r in rids)
    assert len(loop.results) == 3  # zero lost: every rid is terminal


def test_cancel_pending_and_active(dense_lm):
    loop = _mk_loop(dense_lm, batch=1)
    r0 = loop.enqueue(PROMPTS[0], max_new=8)
    r1 = loop.enqueue(PROMPTS[1], max_new=8)
    loop.step()  # admits r0 (batch=1); r1 stays queued
    assert loop.cancel(r1)
    assert loop.results[r1].status is RequestStatus.CANCELLED
    assert loop.cancel(r0)
    res = loop.results[r0]
    assert res.status is RequestStatus.CANCELLED
    assert len(res.tokens) >= 1  # partial output kept
    assert not loop.cancel(r0)  # already terminal
    assert not loop.cancel(12345)  # unknown
    assert loop.stats["cancelled"] == 2


def test_deadline_steps_times_out_with_bitwise_prefix(dense_lm):
    base = _mk_loop(dense_lm)
    rid = base.submit(PROMPTS[0], max_new=6)
    base.run_to_completion()
    full = base.results[rid].tokens

    loop = _mk_loop(dense_lm)
    rid2 = loop.submit(PROMPTS[0], max_new=6, deadline_steps=2)
    loop.run_to_completion()
    res = loop.results[rid2]
    assert res.status is RequestStatus.TIMEOUT and "step budget" in res.reason
    assert res.tokens == full[: len(res.tokens)] and 1 <= len(res.tokens) < len(full)

    # the ServeConfig default spells the same behavior
    loop = _mk_loop(dense_lm, max_steps_per_request=2)
    rid3 = loop.submit(PROMPTS[0], max_new=6)
    loop.run_to_completion()
    assert loop.results[rid3].status is RequestStatus.TIMEOUT
    assert loop.results[rid3].tokens == res.tokens


def test_slot_fault_retires_one_request_others_bitwise(dense_lm):
    base = _mk_loop(dense_lm)
    b0 = base.submit(PROMPTS[0], max_new=4)
    b1 = base.submit(PROMPTS[1], max_new=4)
    base.run_to_completion()

    loop = _mk_loop(dense_lm)
    with injected(FaultPlan([FaultSpec("serve.slot", tag="1")])):
        r0 = loop.submit(PROMPTS[0], max_new=4)
        r1 = loop.submit(PROMPTS[1], max_new=4)
        loop.run_to_completion()
    assert (r0, r1) == (b0, b1)
    assert loop.results[r1].status is RequestStatus.FAILED
    assert "slot fault" in loop.results[r1].reason
    # PR 4 slot isolation under fire: the survivor is bitwise identical
    assert loop.results[r0].status is RequestStatus.DONE
    assert loop.results[r0].tokens == base.results[b0].tokens


def test_admission_fault_contained(dense_lm):
    base = _mk_loop(dense_lm, batch=1)
    base.enqueue(PROMPTS[0], max_new=3)
    b1 = base.enqueue(PROMPTS[1], max_new=3)
    base.run_to_completion()

    loop = _mk_loop(dense_lm, batch=1)
    with injected(FaultPlan([FaultSpec("serve.admit", tag="0")])):
        r0 = loop.enqueue(PROMPTS[0], max_new=3)
        r1 = loop.enqueue(PROMPTS[1], max_new=3)
        loop.run_to_completion()
    assert loop.results[r0].status is RequestStatus.FAILED
    assert "admission failed" in loop.results[r0].reason
    assert loop.results[r1].status is RequestStatus.DONE
    assert loop.results[r1].tokens == base.results[b1].tokens


def test_decode_fault_contained_and_retried_bitwise(dense_lm):
    base = _mk_loop(dense_lm)
    b0 = base.submit(PROMPTS[0], max_new=4)
    base.run_to_completion()

    loop = _mk_loop(dense_lm)
    with injected(FaultPlan([FaultSpec("serve.decode", times=2)])):
        r0 = loop.submit(PROMPTS[0], max_new=4)
        loop.run_to_completion()
    assert loop.stats["decode_retries"] == 2
    assert loop.results[r0].status is RequestStatus.DONE
    # caches are only rebound after a successful step, so the retried
    # step is bitwise identical — the whole stream matches fault-free
    assert loop.results[r0].tokens == base.results[b0].tokens


def test_persistent_decode_failure_hits_budget_not_livelock(dense_lm):
    loop = _mk_loop(dense_lm, max_step_failures=3)
    with injected(FaultPlan([FaultSpec("serve.decode", times=-1)])):
        r0 = loop.submit(PROMPTS[0], max_new=4)
        r1 = loop.submit(PROMPTS[1], max_new=4)
        loop.run_to_completion()  # must terminate: definite-status contract
    for r in (r0, r1):
        res = loop.results[r]
        assert res.status is RequestStatus.FAILED
        assert "consecutive steps" in res.reason
    assert loop.stats["decode_retries"] == 3


def test_resilience_stats_snapshot(dense_lm):
    loop = _mk_loop(dense_lm)
    rid = loop.submit(PROMPTS[0], max_new=2)
    loop.run_to_completion()
    snap = loop.resilience_stats()
    assert snap["done"] == 1 and snap["failed"] == 0
    assert {"timeouts", "shed", "cancelled", "decode_retries"} <= set(snap)
    assert {k for k in snap if k.startswith("fallback_")} == {
        "fallback_pallas_to_jnp", "fallback_local_to_resident",
        "fallback_stored_to_fresh",
    }
    assert loop.results[rid].ok
