"""Scheduler stress suite (ISSUE 7): adversarial structures + bit-identity.

Validity / completeness / Eq. 1 for all three colorers on the structures
the satellite list calls out — empty windows, single-lane hot columns,
duplicate-heavy degree skew — plus the PR's three bit-identity contracts:

  * parallel window-chunked coloring == serial ``color_edges_fast``;
  * the O(e) ``color_edges_fast`` rewrite == the pre-PR np.unique
    reference (``_color_edges_fast_reference``);
  * ``incremental_schedule`` == a fresh ``schedule`` on the new matrix;
  * the ``color_edges_paper`` done-mask fix == the old sorted-dict loop.

With hypothesis installed the sweeps are property tests; without it a
seeded deterministic slice runs the same bodies (same policy as
``test_quant_property.py`` — CI images may lack hypothesis).
"""

import numpy as np
import pytest

from repro.core.bounds import eq1_colors
from repro.core.formats import COOMatrix, coo_from_dense
from repro.core.scheduler import (
    _build_edges,
    _color_edges_fast_reference,
    _edge_index_dtype,
    color_edges_exact,
    color_edges_fast,
    color_edges_paper,
    color_windows_chunked,
    incremental_schedule,
    reset_sched_counters,
    sched_counters,
    schedule,
    window_fingerprints,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Adversarial matrix generators
# ---------------------------------------------------------------------------


def empty_window_dense(rng, m, n, l, density=0.2):
    """Random matrix with entire row bands (windows) zeroed out — the
    scheduler must keep zero-color windows and exact window offsets."""
    dense = ((rng.random((m, n)) < density)
             * rng.standard_normal((m, n))).astype(np.float32)
    num_windows = -(-m // l)
    kill = rng.random(num_windows) < 0.5
    kill[rng.integers(num_windows)] = True  # at least one empty window
    for w in np.nonzero(kill)[0]:
        dense[w * l: (w + 1) * l] = 0.0
    return dense


def hot_column_dense(rng, m, n, l, density=0.05):
    """One nearly-full column: every window funnels through a single lane,
    so per-window colors must reach that lane's degree (Eq. 1 tight on
    the lane side)."""
    dense = ((rng.random((m, n)) < density)
             * rng.standard_normal((m, n))).astype(np.float32)
    hot = int(rng.integers(n))
    dense[:, hot] = rng.standard_normal(m).astype(np.float32)
    dense[dense[:, hot] == 0.0, hot] = 1.0
    return dense


def duplicate_heavy_dense(rng, m, n, l, density=0.3):
    """Power-law row degrees with columns congruent mod l: many edges per
    (row, lane) pair — the multigraph case where per-vertex degree far
    exceeds the number of distinct neighbors."""
    dense = np.zeros((m, n), np.float32)
    lanes = rng.integers(0, l, size=max(1, l // 2))
    for i in range(m):
        deg = min(n, int(rng.pareto(1.0) * 3) + 1)
        cols = (rng.integers(0, max(1, n // l), size=deg) * l
                + rng.choice(lanes, size=deg)) % n
        dense[i, np.unique(cols)] = rng.standard_normal(
            np.unique(cols).size
        ).astype(np.float32)
    return dense


STRUCTURES = {
    "empty_windows": empty_window_dense,
    "hot_column": hot_column_dense,
    "duplicate_heavy": duplicate_heavy_dense,
}


# ---------------------------------------------------------------------------
# Invariant checkers
# ---------------------------------------------------------------------------


def assert_schedule_invariants(sched, coo, l):
    """Completeness, validity, Eq. 1 — the three contracts every colorer
    must satisfy on every structure."""
    # completeness: every nonzero exactly once, values preserved
    assert int(sched.valid.sum()) == coo.nnz
    np.testing.assert_allclose(
        np.sort(sched.m_sch[sched.valid]), np.sort(coo.vals)
    )
    cyc, lane = np.nonzero(sched.valid)
    # validity: within a cycle no adder receives two partial products
    adders = sched.row_sch[cyc, lane]
    keys = cyc.astype(np.int64) * l + adders
    assert np.unique(keys).size == keys.size, "adder collision"
    # Eq. 1 per window (empty windows must contribute exactly 0 colors)
    wid = np.searchsorted(
        sched.window_starts, np.arange(sched.valid.shape[0]), side="right"
    ) - 1
    wid = wid[cyc]
    for w in range(sched.num_windows):
        sel = wid == w
        used = int(sched.window_starts[w + 1] - sched.window_starts[w])
        if not sel.any():
            assert used == 0, "empty window must occupy zero cycles"
            continue
        row_nnz = np.bincount(adders[sel], minlength=l)
        lane_nnz = np.bincount(lane[sel], minlength=l)
        assert used >= eq1_colors(row_nnz, lane_nnz)


def assert_schedules_bitwise_equal(a, b):
    assert a.l == b.l and a.shape == b.shape and a.nnz == b.nnz
    for f in ("m_sch", "row_sch", "col_sch", "window_starts", "row_perm",
              "valid"):
        fa, fb = getattr(a, f), getattr(b, f)
        assert fa.dtype == fb.dtype, f
        assert np.array_equal(fa, fb), f


# ---------------------------------------------------------------------------
# Adversarial structures x all colorers
# ---------------------------------------------------------------------------


def _adversarial_body(structure, method, seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(8, 48))
    n = int(rng.integers(8, 64))
    l = int(rng.choice([4, 8]))
    dense = STRUCTURES[structure](rng, m, n, l)
    coo = coo_from_dense(dense)
    for lb in (False, True):
        sched = schedule(coo, l, load_balance=lb, method=method)
        assert_schedule_invariants(sched, coo, l)


if HAVE_HYPOTHESIS:

    @pytest.mark.parametrize("method", ["paper", "fast", "exact"])
    @pytest.mark.parametrize("structure", sorted(STRUCTURES))
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_adversarial_structures(structure, method, seed):
        _adversarial_body(structure, method, seed)

else:

    @pytest.mark.parametrize("method", ["paper", "fast", "exact"])
    @pytest.mark.parametrize("structure", sorted(STRUCTURES))
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_adversarial_structures(structure, method, seed):
        _adversarial_body(structure, method, seed)


# ---------------------------------------------------------------------------
# Bit-identity: O(e) fast rewrite vs np.unique reference
# ---------------------------------------------------------------------------


def _edges_for(dense, l, lb=False):
    coo = coo_from_dense(dense)
    win, row_local, lane, _, _, _ = _build_edges(coo, l, lb)
    num_windows = max(-(-dense.shape[0] // l), 1)
    return (win * l + row_local, win * l + lane, win, num_windows)


def _fast_rewrite_body(structure, seed):
    rng = np.random.default_rng(seed)
    dense = STRUCTURES[structure](rng, int(rng.integers(8, 64)),
                                  int(rng.integers(8, 80)), 8)
    row_key, lane_key, _, _ = _edges_for(dense, 8)
    got = color_edges_fast(row_key, lane_key)
    want = _color_edges_fast_reference(row_key, lane_key)
    assert np.array_equal(got, want), "O(e) rewrite diverged from reference"


if HAVE_HYPOTHESIS:

    @pytest.mark.parametrize("structure", sorted(STRUCTURES))
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_fast_rewrite_bit_identical(structure, seed):
        _fast_rewrite_body(structure, seed)

else:

    @pytest.mark.parametrize("structure", sorted(STRUCTURES))
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_fast_rewrite_bit_identical(structure, seed):
        _fast_rewrite_body(structure, seed)


# ---------------------------------------------------------------------------
# Bit-identity: parallel window-chunked coloring vs serial
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workers", [2, 3, 5])
def test_parallel_chunked_bit_identical(workers):
    rng = np.random.default_rng(workers)
    dense = empty_window_dense(rng, 96, 64, 8, density=0.15)
    row_key, lane_key, win, num_windows = _edges_for(dense, 8)
    want = color_edges_fast(row_key, lane_key)
    got = color_windows_chunked(
        row_key, lane_key, win, num_windows, 8, workers=workers
    )
    assert np.array_equal(got, want)


@pytest.mark.parametrize("lb", [False, True])
def test_parallel_schedule_bit_identical(lb):
    rng = np.random.default_rng(7)
    coo = coo_from_dense(duplicate_heavy_dense(rng, 80, 48, 8))
    serial = schedule(coo, 8, load_balance=lb, workers=1)
    par = schedule(coo, 8, load_balance=lb, workers=3)
    assert_schedules_bitwise_equal(serial, par)


def test_parallel_falls_back_serial_below_threshold():
    """workers=None (auto) stays serial under DEFAULT_PARALLEL_MIN_EDGES —
    the counter proves no worker pool span up for a tiny matrix."""
    rng = np.random.default_rng(0)
    coo = coo_from_dense(hot_column_dense(rng, 32, 32, 8))
    reset_sched_counters()
    schedule(coo, 8, load_balance=False)  # workers=None: auto threshold
    assert sched_counters["parallel_chunks"] == 0


# ---------------------------------------------------------------------------
# Bit-identity: paper colorer done-mask fix vs the old sorted-dict loop
# ---------------------------------------------------------------------------


def _paper_colorer_old(row_key, lane_key):
    """Pre-PR-7 ``color_edges_paper``: per color round, ``sorted()`` over a
    dict of remaining rows (the O(rows log rows) hotspot this PR removed).
    Kept inline here as the semantics oracle."""
    e = row_key.shape[0]
    colors = np.full(e, -1, dtype=np.int64)
    row_edges = {}
    for idx in range(e):
        row_edges.setdefault(int(row_key[idx]), []).append(idx)
    clr = 0
    while row_edges:
        matching = set()
        for rk in sorted(row_edges):
            edges = row_edges[rk]
            for pos, eidx in enumerate(edges):
                lk = int(lane_key[eidx])
                if lk not in matching:
                    colors[eidx] = clr
                    matching.add(lk)
                    edges.pop(pos)
                    break
            if not edges:
                del row_edges[rk]
        clr += 1
    return colors


def _paper_fix_body(structure, seed):
    rng = np.random.default_rng(seed)
    dense = STRUCTURES[structure](rng, int(rng.integers(8, 40)),
                                  int(rng.integers(8, 48)), 4)
    row_key, lane_key, _, _ = _edges_for(dense, 4)
    got = color_edges_paper(row_key, lane_key)
    want = _paper_colorer_old(row_key, lane_key)
    assert np.array_equal(got, want), "paper fix changed Listing 1 semantics"


if HAVE_HYPOTHESIS:

    @pytest.mark.parametrize("structure", sorted(STRUCTURES))
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_paper_fix_bit_identical(structure, seed):
        _paper_fix_body(structure, seed)

else:

    @pytest.mark.parametrize("structure", sorted(STRUCTURES))
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_paper_fix_bit_identical(structure, seed):
        _paper_fix_body(structure, seed)


# ---------------------------------------------------------------------------
# Bit-identity: incremental reschedule vs fresh schedule
# ---------------------------------------------------------------------------


def _mutate_windows(rng, dense, l, n_windows):
    """Perturb values + structure inside ``n_windows`` random windows."""
    new = dense.copy()
    num_windows = -(-dense.shape[0] // l)
    dirty = rng.choice(num_windows, size=min(n_windows, num_windows),
                       replace=False)
    for w in dirty:
        rows = slice(w * l, min((w + 1) * l, dense.shape[0]))
        band = new[rows]
        nz = np.nonzero(band)
        if nz[0].size:  # value-only change on half, structural on half
            k = nz[0].size // 2
            band[nz[0][:k], nz[1][:k]] *= 1.5
            band[nz[0][k:], nz[1][k:]] = 0.0
        band[rng.integers(band.shape[0]), rng.integers(band.shape[1])] = 3.25
        new[rows] = band
    return new, np.sort(dirty)


def _incremental_body(method, seed):
    rng = np.random.default_rng(seed)
    dense = duplicate_heavy_dense(rng, 64, 48, 8)
    coo = coo_from_dense(dense)
    old = schedule(coo, 8, load_balance=False, method=method)
    new_dense, expected_dirty = _mutate_windows(rng, dense, 8, 3)
    new_coo = coo_from_dense(new_dense)

    reset_sched_counters()
    inc, dirty, new_hashes = incremental_schedule(
        old, new_coo, old_coo=coo, method=method
    )
    fresh = schedule(new_coo, 8, load_balance=False, method=method)
    assert_schedules_bitwise_equal(inc, fresh)
    # only windows whose content actually changed are recolored
    assert set(dirty) <= set(expected_dirty)
    assert sched_counters["windows_recolored"] == dirty.size
    assert sched_counters["windows_reused"] == old.num_windows - dirty.size
    # chained delta: reuse new_hashes, no old_coo rehash needed
    third, d3 = _mutate_windows(rng, new_dense, 8, 1)
    inc2, dirty2, _ = incremental_schedule(
        inc, coo_from_dense(third), old_hashes=new_hashes, method=method
    )
    assert_schedules_bitwise_equal(inc2, schedule(
        coo_from_dense(third), 8, load_balance=False, method=method
    ))
    assert set(dirty2) <= set(d3)


if HAVE_HYPOTHESIS:

    @pytest.mark.parametrize("method", ["paper", "fast", "exact"])
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_incremental_matches_fresh(method, seed):
        _incremental_body(method, seed)

else:

    @pytest.mark.parametrize("method", ["paper", "fast", "exact"])
    @pytest.mark.parametrize("seed", [0, 1])
    def test_incremental_matches_fresh(method, seed):
        _incremental_body(method, seed)


def test_incremental_identical_matrix_recolors_nothing():
    rng = np.random.default_rng(11)
    coo = coo_from_dense(empty_window_dense(rng, 48, 32, 8))
    old = schedule(coo, 8, load_balance=False)
    reset_sched_counters()
    inc, dirty, _ = incremental_schedule(old, coo, old_coo=coo)
    assert dirty.size == 0
    assert sched_counters["windows_recolored"] == 0
    assert sched_counters["color_calls"] == 0, \
        "no dirty windows -> no colorer invocation at all"
    assert_schedules_bitwise_equal(inc, old)


def test_incremental_rejects_load_balanced_and_reshaped():
    rng = np.random.default_rng(3)
    dense = duplicate_heavy_dense(rng, 32, 32, 8)
    coo = coo_from_dense(dense)
    balanced = schedule(coo, 8, load_balance=True)
    if not np.array_equal(balanced.row_perm, np.arange(32)):
        with pytest.raises(ValueError, match="load_balance=False"):
            incremental_schedule(balanced, coo, old_coo=coo)
    plain = schedule(coo, 8, load_balance=False)
    small = COOMatrix((16, 32), np.zeros(0, np.int64), np.zeros(0, np.int64),
                      np.zeros(0, np.float32))
    with pytest.raises(ValueError, match="shape changed"):
        incremental_schedule(plain, small, old_coo=coo)


def test_window_fingerprints_detect_value_and_structure():
    rng = np.random.default_rng(5)
    dense = duplicate_heavy_dense(rng, 32, 32, 8)
    f0 = window_fingerprints(coo_from_dense(dense), 8)
    bumped = dense.copy()
    nz = np.nonzero(bumped)
    bumped[nz[0][0], nz[1][0]] *= 2.0  # value-only change
    f1 = window_fingerprints(coo_from_dense(bumped), 8)
    w = nz[0][0] // 8
    assert f0[w] != f1[w]
    others = np.arange(f0.shape[0]) != w
    assert np.array_equal(f0[others], f1[others])


# ---------------------------------------------------------------------------
# Index-dtype policy (satellite: halve scheduler peak memory)
# ---------------------------------------------------------------------------


def test_build_edges_int32_when_small():
    rng = np.random.default_rng(1)
    coo = coo_from_dense(hot_column_dense(rng, 40, 40, 8))
    win, row_local, lane, col, val, row_perm = _build_edges(coo, 8, False)
    for arr in (win, row_local, lane, col):
        assert arr.dtype == np.int32, arr.dtype
    assert row_perm.dtype == np.int64  # row_perm feeds jnp gathers as-is
    assert val.dtype == coo.vals.dtype
    # and the schedule built from int32 edges is identical to one built
    # from a forced-int64 path (the dtype is an implementation detail)
    sched = schedule(coo, 8, load_balance=False)
    assert_schedule_invariants(sched, coo, 8)


def test_edge_index_dtype_boundaries():
    assert _edge_index_dtype(100, 100, 1000, 8) == np.int32
    big = np.iinfo(np.int32).max
    assert _edge_index_dtype(big + 1, 100, 1000, 8) == np.int64
    assert _edge_index_dtype(100, big + 1, 1000, 8) == np.int64
    assert _edge_index_dtype(100, 100, big + 1, 8) == np.int64
    # the globalized key bound must fit too, not just m/n/nnz
    assert _edge_index_dtype(big - 4, 100, 1000, 8) == np.int64


# ---------------------------------------------------------------------------
# Degenerate inputs
# ---------------------------------------------------------------------------


def test_empty_edge_stream_all_colorers():
    empty = np.empty(0, dtype=np.int64)
    for colorer in (color_edges_fast, _color_edges_fast_reference,
                    color_edges_paper, color_edges_exact):
        out = colorer(empty, empty)
        assert out.shape == (0,)
    out = color_windows_chunked(empty, empty, empty, 4, 8, workers=4)
    assert out.shape == (0,)


def test_all_zero_matrix_schedules_and_reschedules():
    coo = COOMatrix((16, 16), np.empty(0, np.int64), np.empty(0, np.int64),
                    np.empty(0, np.float32))
    sched = schedule(coo, 4, load_balance=False, workers=2)
    assert sched.nnz == 0
    inc, dirty, _ = incremental_schedule(sched, coo, old_coo=coo)
    assert dirty.size == 0
    assert_schedules_bitwise_equal(inc, sched)
