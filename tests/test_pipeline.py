"""Data-pipeline contract tests: determinism, restartability, host
sharding disjointness."""

import numpy as np
import pytest

from repro.data.pipeline import PipelineConfig, TokenPipeline


def test_determinism_and_restart():
    cfg = PipelineConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=7)
    a = TokenPipeline(cfg)
    batches = [next(a) for _ in range(5)]
    # pure access path reproduces the stream
    for i, b in enumerate(batches):
        np.testing.assert_array_equal(b["tokens"], a.batch_at(i)["tokens"])
    # restore mid-stream
    b = TokenPipeline.restore(cfg, {"step": 3, "seed": 7})
    np.testing.assert_array_equal(next(b)["tokens"], batches[3]["tokens"])


def test_host_sharding_disjoint():
    def host(hid):
        return TokenPipeline(
            PipelineConfig(vocab_size=500, seq_len=16, global_batch=8,
                           num_hosts=4, host_id=hid)
        ).batch_at(0)["tokens"]

    parts = [host(h) for h in range(4)]
    assert all(p.shape == (2, 16) for p in parts)
    # different hosts draw different data
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(parts[i], parts[j])


def test_labels_are_shifted_tokens():
    cfg = PipelineConfig(vocab_size=100, seq_len=16, global_batch=2)
    b = TokenPipeline(cfg).batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["loss_mask"][:, -1] == 0).all()


def test_invalid_configs_rejected():
    with pytest.raises(ValueError):
        PipelineConfig(vocab_size=10, seq_len=4, global_batch=7, num_hosts=2)
    with pytest.raises(ValueError):
        PipelineConfig(vocab_size=10, seq_len=4, global_batch=8, num_hosts=2,
                       host_id=5)


def test_zipf_statistics():
    """Token frequencies should be skewed (Zipf), not uniform."""
    cfg = PipelineConfig(vocab_size=64, seq_len=256, global_batch=16)
    toks = TokenPipeline(cfg).batch_at(0)["tokens"].ravel()
    counts = np.bincount(toks, minlength=64)
    assert counts[:8].sum() > counts[-32:].sum(), "expected head-heavy dist"
