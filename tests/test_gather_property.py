"""Segment-local Buffer-Filler gather: equivalence + format invariants
(ISSUE 5).

The segment-local execution path (pack-time ``seg_blk`` table +
block-local ``col_loc`` columns, streamed x tiles in the kernels) must be
**bit-identical** to the resident path on both layouts — kernel vs kernel
and oracle vs oracle — and the new leaves must survive every packed-
format transformation (``repad_to`` / ``repad_to_blocks``, the
leaves/meta codec, serving stacking) with the bf16/int16 dtype rules
intact.  The hypothesis property test sweeps random and power-law
matrices; the deterministic tests pin the table contract, the
``identity_perm`` scatter-skip, the ``gather="auto"`` decision point and
the new :class:`PlanCost` fields.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.formats import coo_from_dense
from repro.core.packing import (
    PackedSchedule,
    RaggedSchedule,
    pack_ragged,
    pack_schedule,
    packed_from_leaves,
    packed_leaves,
    packed_meta,
    ragged_from_leaves,
    ragged_leaves,
    ragged_meta,
    resolve_gather,
)
from repro.core.plan import GustPlan, PlanConfig, plan
from repro.core.scheduler import schedule
from repro.kernels.ops import execute_spmm

from test_ragged import power_law_dense, random_dense


def both_gathers(art, x, use_kernel):
    """(resident, local) outputs of one artifact through the executor."""
    yr = np.asarray(
        execute_spmm(art, x, use_kernel=use_kernel, gather="resident")
    )
    yl = np.asarray(
        execute_spmm(art, x, use_kernel=use_kernel, gather="local")
    )
    return yr, yl


def assert_local_matches_resident(sched, x, dense_ref):
    xs = jnp.asarray(x)
    for art in (pack_schedule(sched), pack_ragged(sched)):
        for uk in (False, True):
            yr, yl = both_gathers(art, xs, uk)
            tag = (type(art).__name__, "kernel" if uk else "oracle")
            assert np.array_equal(yr, yl), \
                f"local gather diverged from resident: {tag}"
            np.testing.assert_allclose(
                yr, dense_ref, rtol=2e-4, atol=2e-4, err_msg=str(tag)
            )


# ---------------------------------------------------------------------------
# table contract
# ---------------------------------------------------------------------------


def _assert_table_contract(art):
    """seg_blk/col_loc describe exactly the original columns."""
    l, c_blk = art.l, art.c_blk
    col = np.asarray(art.col_blk, np.int64)
    loc = np.asarray(art.col_loc, np.int64)
    tab = np.asarray(art.seg_blk, np.int64)
    assert tab.shape == (col.shape[0] // c_blk, art.s_blk)
    blk = np.repeat(np.arange(tab.shape[0]), c_blk)
    # the table maps every local id back to the slot's global segment,
    # the lane offset is preserved, and local ids are in range
    assert np.all(tab[blk[:, None], loc // l] == col // l)
    assert np.all(loc % l == col % l)
    assert np.all((loc // l >= 0) & (loc // l < art.s_blk))
    # per-block table rows are sorted with 0-padding past the distinct set
    assert np.all(np.diff(np.sort(tab, axis=1), axis=1) >= 0)
    # every table entry is a valid segment id (padding uses segment 0)
    assert np.all((tab >= 0) & (tab < max(art.seg_count, 1)))


@pytest.mark.parametrize("lb", [False, True])
def test_segment_table_contract_both_layouts(lb):
    rng = np.random.default_rng(0)
    dense = power_law_dense(rng, 64, 96)
    sched = schedule(coo_from_dense(dense), 8, load_balance=lb)
    for art in (pack_schedule(sched), pack_ragged(sched)):
        _assert_table_contract(art)
        # identity_perm is exact: it equals the actual permutation check
        assert art.identity_perm == bool(
            np.array_equal(
                np.asarray(art.row_perm),
                np.arange(art.num_windows * art.l),
            )
        )


def test_local_tables_survive_repads():
    rng = np.random.default_rng(1)
    dense = random_dense(rng, 40, 56, 0.25)
    x = jnp.asarray(rng.standard_normal((56, 3)).astype(np.float32))
    sched = schedule(coo_from_dense(dense), 8)
    p = pack_schedule(sched)
    r = pack_ragged(sched)
    gp = p.repad_to(p.c_pad + 16)
    gr = r.repad_to_blocks(r.num_blocks + 4)
    for g in (gp, gr):
        _assert_table_contract(g)
        assert g.s_blk >= 1
    # repadded artifacts still execute bit-identically in both modes
    for art in (gp, gr):
        for uk in (False, True):
            yr, yl = both_gathers(art, x, uk)
            assert np.array_equal(yr, yl)
    # seg-table widening is repad-safe and refuses to shrink
    wide = p.repad_seg_to(p.s_blk + 3)
    assert wide.s_blk == p.s_blk + 3
    _assert_table_contract(wide)
    yr, yl = both_gathers(wide, x, True)
    assert np.array_equal(yr, yl)
    with pytest.raises(ValueError):
        wide.repad_seg_to(p.s_blk)
    assert p.repad_seg_to(p.s_blk) is p


def test_compact_dtypes_through_repads_and_codec():
    """bf16 values / int16 indices survive the new leaves' lifecycle:
    pack -> repad -> codec round-trip, on both layouts."""
    rng = np.random.default_rng(2)
    sched = schedule(coo_from_dense(random_dense(rng, 48, 64, 0.2)), 16)
    x = jnp.asarray(rng.standard_normal((64, 2)).astype(np.float32))
    p = pack_schedule(sched, value_dtype=jnp.bfloat16, index_dtype=jnp.int16)
    r = pack_ragged(sched, value_dtype=jnp.bfloat16, index_dtype=jnp.int16)
    for art, grow in ((p, lambda a: a.repad_to(a.c_pad + 8)),
                      (r, lambda a: a.repad_to_blocks(a.num_blocks + 2))):
        assert art.col_loc.dtype == jnp.int16
        assert art.seg_blk.dtype == jnp.int32  # table is always int32
        g = grow(art)
        assert g.col_loc.dtype == jnp.int16 and g.seg_blk.dtype == jnp.int32
        if isinstance(art, RaggedSchedule):
            q = ragged_from_leaves(ragged_leaves(g), ragged_meta(g))
        else:
            q = packed_from_leaves(packed_leaves(g), packed_meta(g))
        assert q.col_loc.dtype == jnp.int16 and q.s_blk == g.s_blk
        assert q.identity_perm == g.identity_perm
        for uk in (False, True):
            yr, yl = both_gathers(q, x, uk)
            assert np.array_equal(yr, yl)


# ---------------------------------------------------------------------------
# hypothesis property: local == resident, bitwise, everywhere
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

def _property_body(args):
    m, n, density, l, b, skew, lb, compact, seed = args
    rng = np.random.default_rng(seed)
    dense = (
        power_law_dense(rng, m, n, base_density=density * 0.2)
        if skew
        else random_dense(rng, m, n, density)
    )
    x = jnp.asarray(rng.standard_normal((n, b)).astype(np.float32))
    sched = schedule(coo_from_dense(dense), l, load_balance=lb)
    vd, idd = (jnp.bfloat16, jnp.int16) if compact else (jnp.float32,
                                                         jnp.int32)
    for art in (
        pack_schedule(sched, value_dtype=vd, index_dtype=idd),
        pack_ragged(sched, value_dtype=vd, index_dtype=idd),
    ):
        _assert_table_contract(art)
        for uk in (False, True):
            yr, yl = both_gathers(art, x, uk)
            assert np.array_equal(yr, yl), (
                type(art).__name__, uk, m, n, l, lb, compact
            )


if HAVE_HYPOTHESIS:
    matrix_strategy = st.tuples(
        st.integers(2, 48),  # m
        st.integers(2, 64),  # n
        st.sampled_from([0.05, 0.2, 0.5]),
        st.sampled_from([4, 8, 16]),  # l
        st.integers(1, 4),  # B
        st.booleans(),  # power-law skew
        st.booleans(),  # load balance
        st.booleans(),  # compact dtypes
        st.integers(0, 10_000),  # seed
    )

    @settings(max_examples=25, deadline=None)
    @given(args=matrix_strategy)
    def test_local_gather_equivalence_property(args):
        _property_body(args)

else:  # keep a deterministic slice of the sweep without hypothesis

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_local_gather_equivalence_property(seed):
        rng = np.random.default_rng(seed)
        args = (
            int(rng.integers(2, 48)), int(rng.integers(2, 64)),
            [0.05, 0.2, 0.5][seed % 3], [4, 8, 16][seed % 3],
            1 + seed % 4, bool(seed % 2), bool((seed // 2) % 2),
            bool(seed % 2), seed,
        )
        _property_body(args)


# ---------------------------------------------------------------------------
# identity_perm scatter skip
# ---------------------------------------------------------------------------


def test_identity_perm_skips_scatter_bit_identically():
    rng = np.random.default_rng(3)
    dense = random_dense(rng, 48, 64, 0.2)
    x = jnp.asarray(rng.standard_normal((64, 3)).astype(np.float32))
    sched = schedule(coo_from_dense(dense), 8, load_balance=False)
    p = pack_schedule(sched)
    assert p.identity_perm, "load_balance=False pack must flag identity"
    # force the scatter path by clearing the flag; outputs must agree
    import dataclasses as dc

    forced = dc.replace(p, identity_perm=False)
    for uk in (False, True):
        y_fast = np.asarray(execute_spmm(p, x, use_kernel=uk))
        y_scatter = np.asarray(execute_spmm(forced, x, use_kernel=uk))
        assert np.array_equal(y_fast, y_scatter)
    np.testing.assert_allclose(
        np.asarray(execute_spmm(p, x)), dense @ np.asarray(x),
        rtol=2e-4, atol=2e-4,
    )


# ---------------------------------------------------------------------------
# plan surface: gather knob, auto decision, cost fields
# ---------------------------------------------------------------------------


def test_plan_gather_knob_and_auto_decision():
    with pytest.raises(ValueError):
        PlanConfig(gather="vmem")
    rng = np.random.default_rng(4)
    dense = random_dense(rng, 64, 256, 0.05)  # wide: few segs per block
    x = jnp.asarray(rng.standard_normal((256, 2)).astype(np.float32))
    outs = {}
    for mode in ("resident", "local", "auto"):
        p = plan(dense, PlanConfig(l=8, backend="jnp", gather=mode),
                 cache=None)
        outs[mode] = np.asarray(p.spmm(x))
        assert p.gather_mode in ("resident", "local")
    assert np.array_equal(outs["resident"], outs["local"])
    assert np.array_equal(outs["auto"], outs["local"])
    # the auto decision is the one resolve_gather decision point
    p = plan(dense, PlanConfig(l=8), cache=None)
    a = p.artifact
    assert p.gather_mode == resolve_gather(a.s_blk, a.seg_count)


def test_plan_cost_gather_fields():
    rng = np.random.default_rng(5)
    dense = random_dense(rng, 64, 512, 0.03)
    p = plan(dense, PlanConfig(l=8), cache=None)
    c = p.cost()
    a = p.artifact
    assert c.s_blk == a.s_blk
    assert c.locality_ratio == pytest.approx(a.s_blk / a.seg_count)
    # the FLOP ratio between the modes is exactly seg_count / S_blk
    assert c.gather_flops_resident == 4 * c.streamed_slots * a.seg_count
    assert c.gather_flops_local == 4 * c.streamed_slots * a.s_blk
    assert c.gather_flops_resident / c.gather_flops_local == pytest.approx(
        a.seg_count / a.s_blk
    )
    # resident x VMEM scales with matrix width, local with the working set
    assert c.x_vmem_bytes_resident == a.seg_count * p.l * 4
    assert c.x_vmem_bytes_local == a.s_blk * p.l * 4
    assert c.gather in ("resident", "local")
    assert c.to_dict()["s_blk"] == a.s_blk


def test_stack_equalizes_seg_tables_and_flags():
    """Layers with different S_blk / identity_perm must stack: tables are
    widened to the max and the shared static flags are conservative."""
    rng = np.random.default_rng(6)
    plans = [
        plan(random_dense(rng, 32, 128, d), PlanConfig(l=8, layout="padded",
                                                       backend="jnp"),
             cache=None)
        for d in (0.02, 0.4)
    ]
    arts = [p.artifact for p in plans]
    assert arts[0].s_blk != arts[1].s_blk, "fixture should differ in S_blk"
    stacked = GustPlan.stack(plans)
    s_uniform = max(a.s_blk for a in arts)
    assert stacked["leaves"]["seg_blk"].shape[-1] == s_uniform
    meta_s_blk = stacked["meta"][6]
    assert meta_s_blk == s_uniform
    # each layer's slice still executes both gather modes bit-identically
    for i, p in enumerate(plans):
        sl = {k: v[i] for k, v in stacked["leaves"].items()}
        q = GustPlan.from_spec({"leaves": sl, "meta": stacked["meta"]})
        x = jnp.asarray(rng.standard_normal((128, 2)).astype(np.float32))
        yr, yl = both_gathers(q.artifact, x, False)
        assert np.array_equal(yr, yl)
        np.testing.assert_allclose(
            np.asarray(q.spmm(x)), np.asarray(p.spmm(x)),
            rtol=1e-5, atol=1e-5,
        )


def test_wide_matrix_executes_via_local_gather():
    """A width whose resident x footprint exceeds a (scaled-down) VMEM
    budget executes through gather='local' — the end-to-end wide-matrix
    fast path.  The real 16 MB budget is exercised by
    benchmarks/gather_bench.py; here the same inequality is asserted at
    test scale."""
    rng = np.random.default_rng(7)
    m, n, l, b = 32, 4096, 8, 4
    dense = random_dense(rng, m, n, 0.01)
    x = jnp.asarray(rng.standard_normal((n, b)).astype(np.float32))
    p = plan(dense, PlanConfig(l=l, backend="pallas", gather="local"),
             cache=None)
    c = p.cost()
    budget = c.x_vmem_bytes_resident - 1  # resident would not fit
    assert c.x_vmem_bytes_local < budget < c.x_vmem_bytes_resident
    assert p.gather_mode == "local"
    y = np.asarray(p.spmm(x))
    np.testing.assert_allclose(y, dense @ np.asarray(x), rtol=2e-4,
                               atol=2e-4)


def test_resolve_gather_decision_point():
    assert resolve_gather(4, 256) == "local"
    assert resolve_gather(128, 256) == "local"  # ratio 0.5 inclusive
    assert resolve_gather(129, 256) == "resident"
    assert resolve_gather(1, 1) == "resident"
    assert resolve_gather(65, 256, locality_ratio=0.25) == "resident"
    assert resolve_gather(64, 256, locality_ratio=0.25) == "local"
    # below the width floor the resident contraction is cheap enough that
    # tile-streaming grid-step overhead dominates — auto stays resident
    assert resolve_gather(2, 8) == "resident"
    assert resolve_gather(2, 8, min_segs=8) == "local"
    assert resolve_gather(2, 8, min_segs=9) == "resident"
