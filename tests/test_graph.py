"""Graph-analytics workloads (PR 8): PageRank, triangle counting, GNN
feature propagation — each validated against a plain-numpy dense
reference on small graphs.
"""

import numpy as np
import pytest

from repro.core.formats import COOMatrix, coo_from_dense
from repro.core.plan import PlanConfig
from repro.data.matrices import synth_power_law
from repro.graph import feature_propagation, pagerank, triangle_count

CFG = PlanConfig(l=8)


def ring(n):
    """Directed ring: node i -> i+1 (every node has in/out degree 1)."""
    rows = np.arange(n, dtype=np.int64)
    cols = (rows + 1) % n
    return COOMatrix((n, n), rows, cols, np.ones(n, np.float32))


def dense_pagerank(adj, damping=0.85, iters=500):
    A = (adj != 0).astype(np.float64)
    n = A.shape[0]
    deg = A.sum(1)
    P = np.zeros((n, n))
    nz = deg > 0
    P[nz] = A[nz] / deg[nz, None]
    r = np.full(n, 1.0 / n)
    for _ in range(iters):
        dangling = r[~nz].sum() / n
        r = damping * (P.T @ r + dangling) + (1 - damping) / n
        r /= r.sum()
    return r


def test_pagerank_uniform_on_ring():
    pr = pagerank(ring(12), config=CFG)
    assert pr.converged
    np.testing.assert_allclose(pr.scores, np.full(12, 1 / 12), atol=1e-6)
    assert abs(pr.scores.sum() - 1.0) < 1e-6


def test_pagerank_matches_dense_reference():
    rng = np.random.default_rng(0)
    dense = (rng.random((24, 24)) < 0.15).astype(np.float32)
    pr = pagerank(dense, config=CFG, tol=1e-10, max_iter=500)
    np.testing.assert_allclose(pr.scores, dense_pagerank(dense), atol=1e-4)
    assert abs(pr.scores.sum() - 1.0) < 1e-5
    assert pr.top(3).shape == (3,)


def test_pagerank_dangling_nodes():
    # node 2 has no out-edges: its mass redistributes, sum stays 1
    adj = np.zeros((4, 4), np.float32)
    adj[0, 1] = adj[1, 2] = adj[3, 0] = 1.0
    pr = pagerank(adj, config=CFG)
    assert pr.converged
    np.testing.assert_allclose(pr.scores, dense_pagerank(adj), atol=1e-5)


def test_triangle_count_known_graphs():
    # K4 has C(4,3) = 4 triangles, every vertex in 3 of them
    k4 = np.ones((4, 4), np.float32) - np.eye(4, dtype=np.float32)
    tc = triangle_count(k4, config=CFG)
    assert tc.triangles == 4
    assert np.array_equal(tc.per_node, [3, 3, 3, 3])
    assert tc.clustering_coefficient == pytest.approx(1.0)

    # a ring has no triangles (and exercises symmetrization of the
    # directed pattern)
    assert triangle_count(ring(8), config=CFG).triangles == 0

    # triangle + pendant edge: exactly one triangle through nodes 0,1,2
    adj = np.zeros((4, 4), np.float32)
    for i, j in [(0, 1), (1, 2), (2, 0), (2, 3)]:
        adj[i, j] = adj[j, i] = 1.0
    tc = triangle_count(adj, config=CFG)
    assert tc.triangles == 1
    assert np.array_equal(tc.per_node, [1, 1, 1, 0])


def test_triangle_count_matches_trace_reference():
    rng = np.random.default_rng(1)
    dense = (rng.random((20, 20)) < 0.25).astype(np.float32)
    tc = triangle_count(dense, config=CFG)
    # reference: trace(S^3)/6 on the symmetrized simple graph
    S = np.maximum(dense, dense.T)
    np.fill_diagonal(S, 0)
    expected = int(round(np.trace(S @ S @ S) / 6))
    assert tc.triangles == expected
    assert int(tc.per_node.sum()) == 3 * expected
    # self-loops and edge weights must not change the census
    weighted = dense * 7.0 + np.eye(20, dtype=np.float32)
    assert triangle_count(weighted, config=CFG).triangles == expected


def test_feature_propagation_matches_dense_reference():
    rng = np.random.default_rng(2)
    dense = (rng.random((16, 16)) < 0.2).astype(np.float32)
    feats = rng.standard_normal((16, 5)).astype(np.float32)
    out = feature_propagation(dense, feats, num_layers=2, config=CFG)
    # dense reference: A_hat = D^-1/2 (S + I) D^-1/2 over the symmetric
    # simple pattern, applied twice
    S = np.maximum(dense, dense.T).astype(np.float64)
    np.fill_diagonal(S, 0)
    S += np.eye(16)
    d = S.sum(1)
    a_hat = S / np.sqrt(np.outer(d, d))
    ref = a_hat @ (a_hat @ feats.astype(np.float64))
    np.testing.assert_allclose(out, ref, atol=1e-5)
    assert out.shape == feats.shape and out.dtype == np.float32


def test_feature_propagation_isolated_nodes_and_validation():
    adj = np.zeros((6, 6), np.float32)
    adj[0, 1] = 1.0
    feats = np.eye(6, dtype=np.float32)
    out = feature_propagation(adj, feats, num_layers=1)
    # isolated vertices keep their features through the self-loop
    np.testing.assert_allclose(out[2:], feats[2:], atol=1e-6)
    assert np.array_equal(
        feature_propagation(adj, feats, num_layers=0), feats
    )
    with pytest.raises(ValueError, match="features"):
        feature_propagation(adj, np.zeros((3, 2), np.float32))
    with pytest.raises(ValueError, match="square"):
        pagerank(np.zeros((2, 3), np.float32))


def test_workloads_on_synth_suite():
    adj = synth_power_law(48, 0.06, seed=9)
    pr = pagerank(adj, config=CFG)
    assert pr.converged and abs(pr.scores.sum() - 1.0) < 1e-5
    tc = triangle_count(adj, config=CFG)
    S = np.maximum(
        (np.abs(np.asarray(coo_dense(adj))) > 0).astype(np.float64),
        (np.abs(np.asarray(coo_dense(adj))) > 0).astype(np.float64).T,
    )
    np.fill_diagonal(S, 0)
    assert tc.triangles == int(round(np.trace(S @ S @ S) / 6))
    feats = np.random.default_rng(3).standard_normal((48, 4)).astype(np.float32)
    assert feature_propagation(adj, feats, config=CFG).shape == (48, 4)


def coo_dense(coo: COOMatrix) -> np.ndarray:
    from repro.core.formats import dense_from_coo

    return dense_from_coo(coo)
