"""Training-layer tests: loss descent, grad-accumulation equivalence,
checkpoint atomicity + elastic restore, fault-tolerance mechanics,
gradient-compression error feedback."""

import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models.model_zoo import build_model
from repro.training import (
    AdamWConfig,
    CompressionConfig,
    TrainConfig,
    init_train_state,
    latest_step,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.training.checkpoint import list_steps
from repro.training.fault_tolerance import (
    CheckpointPolicy,
    StragglerMonitor,
    retrying,
)


def _setup(arch="yi_6b", **tc_kwargs):
    cfg = get_arch(arch).reduced()
    lm = build_model(cfg)
    tc = TrainConfig(
        opt=AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=100),
        dtype="float32",
        **tc_kwargs,
    )
    state = init_train_state(lm, jax.random.PRNGKey(0), tc)
    pipe = TokenPipeline(
        PipelineConfig(vocab_size=cfg.vocab, seq_len=16, global_batch=8)
    )
    return lm, tc, state, pipe


def test_loss_decreases():
    lm, tc, state, pipe = _setup()
    step = jax.jit(make_train_step(lm, tc))
    losses = []
    for _ in range(6):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_grad_accumulation_equivalence():
    """microbatches=4 must produce (numerically) the same update as a
    single full batch: the loss is a mean over tokens, and accumulation
    averages microbatch gradients."""
    lm, tc1, state1, pipe = _setup(microbatches=1)
    _, tc4, _, _ = _setup(microbatches=4)
    state4 = jax.tree.map(lambda x: x, state1)  # same init
    batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
    s1, m1 = jax.jit(make_train_step(lm, tc1))(state1, batch)
    s4, m4 = jax.jit(make_train_step(lm, tc4))(state4, batch)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_checkpoint_atomicity_and_resume():
    lm, tc, state, pipe = _setup()
    step = jax.jit(make_train_step(lm, tc))
    for _ in range(2):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state, _ = step(state, batch)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 2, state, extra={"pipe": pipe.state_dict()})
        # a partial (uncommitted) dir must be ignored
        os.makedirs(os.path.join(d, "step_000000099"))
        assert latest_step(d) == 2
        restored, extra = restore_checkpoint(d, 2, jax.eval_shape(lambda: state))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert extra["pipe"]["step"] == 2
        # resumed run continues identically to an uninterrupted one
        pipe2 = TokenPipeline.restore(pipe.cfg, extra["pipe"])
        b_resume = {k: jnp.asarray(v) for k, v in next(pipe2).items()}
        b_orig = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        for k in b_orig:
            np.testing.assert_array_equal(np.asarray(b_orig[k]), np.asarray(b_resume[k]))


def test_checkpoint_gc_keeps_last():
    lm, tc, state, _ = _setup()
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            save_checkpoint(d, s, {"x": jnp.zeros(3)})
        CheckpointPolicy(keep_last=2).gc(d)
        assert list_steps(d) == [3, 4]


def test_structure_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"a": jnp.zeros(3), "b": jnp.ones(2)})
        with pytest.raises(ValueError):
            restore_checkpoint(d, 1, {"a": jax.ShapeDtypeStruct((3,), jnp.float32)})
        with pytest.raises(ValueError):
            restore_checkpoint(
                d, 1,
                {"a": jax.ShapeDtypeStruct((4,), jnp.float32),
                 "b": jax.ShapeDtypeStruct((2,), jnp.float32)},
            )


def test_retrying_recovers_from_transient():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("simulated worker loss")
        return x + 1

    out = retrying(flaky, max_retries=3)(41)
    assert out == 42 and calls["n"] == 3
    with pytest.raises(RuntimeError):
        retrying(lambda: (_ for _ in ()).throw(RuntimeError("x")), max_retries=1)()


def test_straggler_detection():
    mon = StragglerMonitor(window=20, threshold=3.0)
    for _ in range(15):
        assert not mon.observe(0.10)
    assert mon.observe(1.0)  # 10x median -> flagged
    assert not mon.observe(0.11)
    assert mon.flags, "straggler step must be recorded"


def test_compression_error_feedback_converges():
    """int8+EF: the residual must capture exactly what quantization lost,
    so sum(deq_t) over steps tracks sum(g_t) (no systematic bias)."""
    from repro.training.compression import compress_grads, init_residual

    cfg = CompressionConfig(enable=True)
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.standard_normal((64, 64)) * 1e-3)}
    residual = init_residual(g_true)
    total_deq = np.zeros((64, 64))
    n = 20
    for _ in range(n):
        deq, residual = compress_grads(g_true, residual, cfg)
        total_deq += np.asarray(deq["w"])
    drift = np.abs(total_deq - n * np.asarray(g_true["w"])).max()
    # with EF the cumulative error stays bounded by one quantization step
    assert drift < float(np.abs(np.asarray(g_true["w"])).max()) * 1.5


def test_compressed_training_still_learns():
    lm, tc, state, pipe = _setup(compression=CompressionConfig(enable=True))
    step = jax.jit(make_train_step(lm, tc))
    losses = []
    for _ in range(6):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
