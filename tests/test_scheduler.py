"""Edge-coloring scheduler: correctness + combinatorial invariants.

The schedule is exact combinatorics; these are property tests over random
sparse matrices (hypothesis) asserting, for every colorer:

  * validity    — no two nonzeros sharing a row (adder) or lane
                  (multiplier) within a window get the same color/cycle;
  * completeness— every nonzero scheduled exactly once;
  * Eq. 1 bound — per-window colors >= max vertex degree; the "exact"
                  (König) colorer achieves it with equality;
  * execution   — spmv over the schedule == dense matvec.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.bounds import eq1_colors
from repro.core.formats import COOMatrix, coo_from_dense
from repro.core.scheduler import (
    color_edges_exact,
    color_edges_fast,
    color_edges_paper,
    schedule,
)
from repro.core.spmv import spmv_scheduled


def random_dense(rng, m, n, density):
    return ((rng.random((m, n)) < density) * rng.standard_normal((m, n))).astype(
        np.float32
    )


matrix_strategy = st.tuples(
    st.integers(2, 60),  # m
    st.integers(2, 80),  # n
    st.sampled_from([0.02, 0.08, 0.2, 0.5]),
    st.integers(2, 16),  # l
    st.integers(0, 10_000),  # seed
)


def _window_slots(sched):
    """Iterate (window, cycle, lane) of real slots."""
    wid = np.searchsorted(
        sched.window_starts, np.arange(sched.valid.shape[0]), side="right"
    ) - 1
    cyc, lane = np.nonzero(sched.valid)
    return wid[cyc], cyc, lane


@pytest.mark.parametrize("method", ["paper", "fast", "exact"])
@settings(max_examples=25, deadline=None)
@given(args=matrix_strategy)
def test_schedule_invariants(method, args):
    m, n, density, l, seed = args
    rng = np.random.default_rng(seed)
    dense = random_dense(rng, m, n, density)
    coo = coo_from_dense(dense)
    for lb in (False, True):
        sched = schedule(coo, l, load_balance=lb, method=method)
        # completeness: every nonzero exactly once, values preserved
        assert int(sched.valid.sum()) == coo.nnz
        vals = np.sort(sched.m_sch[sched.valid])
        assert np.allclose(vals, np.sort(coo.vals))
        # validity: within a cycle, no adder receives two partial products
        cyc, lane = np.nonzero(sched.valid)
        adders = sched.row_sch[cyc, lane]
        keys = cyc.astype(np.int64) * l + adders
        assert np.unique(keys).size == keys.size, "adder collision"
        # (lane collisions are impossible by construction: one slot per
        # (cycle, lane) cell)
        # Eq. 1: per-window colors >= max degree of the window's graph
        wid, cyc2, lane2 = _window_slots(sched)
        rows_local = sched.row_sch[cyc2, lane2]
        for w in range(sched.num_windows):
            sel = wid == w
            if not sel.any():
                continue
            row_nnz = np.bincount(rows_local[sel], minlength=l)
            lane_nnz = np.bincount(lane2[sel], minlength=l)
            used = int(sched.window_starts[w + 1] - sched.window_starts[w])
            assert used >= eq1_colors(row_nnz, lane_nnz)


@settings(max_examples=20, deadline=None)
@given(args=matrix_strategy)
def test_exact_coloring_achieves_koenig_bound(args):
    m, n, density, l, seed = args
    rng = np.random.default_rng(seed)
    coo = coo_from_dense(random_dense(rng, m, n, density))
    if coo.nnz == 0:
        return
    sched = schedule(coo, l, load_balance=False, method="exact")
    wid, cyc, lane = _window_slots(sched)
    rows_local = sched.row_sch[cyc, lane]
    for w in range(sched.num_windows):
        sel = wid == w
        if not sel.any():
            continue
        row_nnz = np.bincount(rows_local[sel], minlength=l)
        lane_nnz = np.bincount(lane[sel], minlength=l)
        used = int(sched.window_starts[w + 1] - sched.window_starts[w])
        assert used == eq1_colors(row_nnz, lane_nnz), "König optimum missed"


@settings(max_examples=15, deadline=None)
@given(args=matrix_strategy)
def test_spmv_matches_dense(args):
    m, n, density, l, seed = args
    rng = np.random.default_rng(seed)
    dense = random_dense(rng, m, n, density)
    coo = coo_from_dense(dense)
    v = rng.standard_normal(n).astype(np.float32)
    ref = dense @ v
    for method in ("fast", "exact"):
        for lb in (False, True):
            sched = schedule(coo, l, load_balance=lb, method=method)
            y = np.asarray(spmv_scheduled(sched, jnp.asarray(v)))
            np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)


def test_paper_and_fast_color_counts_close():
    """Both greedy colorers share maximal-matching structure; their color
    counts agree on a deterministic suite (and never beat König)."""
    rng = np.random.default_rng(3)
    for _ in range(5):
        dense = random_dense(rng, 40, 60, 0.15)
        coo = coo_from_dense(dense)
        s_paper = schedule(coo, 8, load_balance=False, method="paper")
        s_fast = schedule(coo, 8, load_balance=False, method="fast")
        s_exact = schedule(coo, 8, load_balance=False, method="exact")
        assert s_exact.total_colors <= s_fast.total_colors
        assert s_exact.total_colors <= s_paper.total_colors
        # greedy maximal matching is within 2x of optimum (theory)
        assert s_fast.total_colors <= 2 * s_exact.total_colors


def test_load_balance_helps_skewed_matrix():
    """Figure 6 scenario: heavy rows mixed with empty rows — balancing
    must not increase cycles, and usually reduces them."""
    rng = np.random.default_rng(0)
    m, n, l = 64, 64, 8
    dense = np.zeros((m, n), np.float32)
    # alternate dense and empty rows -> terrible unbalanced windows
    for i in range(0, m, 2):
        cols = rng.choice(n, 24, replace=False)
        dense[i, cols] = rng.standard_normal(24)
    coo = coo_from_dense(dense)
    cy_unbal = schedule(coo, l, load_balance=False).cycles
    cy_bal = schedule(coo, l, load_balance=True).cycles
    assert cy_bal <= cy_unbal
    assert cy_bal < cy_unbal  # this construction strictly improves


def test_empty_and_degenerate():
    coo = COOMatrix((4, 4), np.empty(0, np.int64), np.empty(0, np.int64),
                    np.empty(0, np.float32))
    sched = schedule(coo, 4)
    assert sched.nnz == 0
    y = np.asarray(spmv_scheduled(sched, jnp.zeros(4)))
    assert y.shape == (4,)
    # single element
    dense = np.zeros((3, 5), np.float32)
    dense[1, 3] = 2.0
    sched = schedule(coo_from_dense(dense), 4)
    v = np.arange(5, dtype=np.float32)
    np.testing.assert_allclose(np.asarray(spmv_scheduled(sched, jnp.asarray(v))),
                               dense @ v)
