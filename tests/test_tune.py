"""Measured autotuner, executor validation, and cost observability (PR 6).

Covers the satellite contracts around the tuner tentpole:

* ``execute_spmm`` rejects unknown ``gather``/``backend``/``layout``/
  ``pipeline`` strings with one normalized message
  (``kernels.ops.normalize_choice``), and raises a clear error when an
  execute-time ``c_blk`` override cannot apply (segment-local tables and
  per-block scales are built at pack-time ``c_blk``).
* :func:`repro.core.packing.resolve_tuning` is the single tuning
  decision point: fastest measured candidate unless the improvement over
  the baseline is below the margin.
* :meth:`GustPlan.tune` returns a plan no slower than the static
  defaults, records a full :class:`TuneResult`, and memoizes the sweep
  content-keyed in the :class:`ScheduleCache`.
* :meth:`GustPlan.cost` reports the resolved ``(layout, gather,
  backend, pipeline)`` choices and the plan's cache hit/miss counters.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.formats import coo_from_dense
from repro.core.packing import (
    DEFAULT_TUNE_IMPROVEMENT,
    ScheduleCache,
    pack_schedule,
    resolve_tuning,
)
from repro.core.plan import PlanConfig, TuneResult, plan
from repro.core.scheduler import schedule
from repro.kernels.ops import EXECUTE_CHOICES, execute_spmm, normalize_choice

from test_ragged import random_dense


def _mk(seed=0, m=40, n=48, l=8, density=0.25, b=3):
    rng = np.random.default_rng(seed)
    dense = random_dense(rng, m, n, density)
    x = jnp.asarray(rng.standard_normal((n, b)).astype(np.float32))
    return dense, schedule(coo_from_dense(dense), l), x


# ---------------------------------------------------------------------------
# executor rejection: one normalized message per knob
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("knob,bad", [
    ("gather", "vmem"),
    ("gather", "Resident"),
    ("backend", "cuda"),
    ("backend", "xla"),
    ("layout", "dense"),
    ("pipeline", "triple"),
    ("pipeline", "DOUBLE"),
])
def test_execute_rejects_unknown_choice(knob, bad):
    _, sched, x = _mk()
    art = pack_schedule(sched)
    with pytest.raises(ValueError) as ei:
        execute_spmm(art, x, **{knob: bad})
    msg = str(ei.value)
    assert msg == normalize_choice_error(knob, bad), msg


def normalize_choice_error(knob, bad):
    allowed = ", ".join(repr(c) for c in EXECUTE_CHOICES[knob])
    return f"unknown {knob} {bad!r}; expected one of: {allowed}"


@pytest.mark.parametrize("knob", sorted(EXECUTE_CHOICES))
def test_normalize_choice_accepts_known(knob):
    for value in EXECUTE_CHOICES[knob]:
        assert normalize_choice(knob, value) == value
    with pytest.raises(ValueError):
        normalize_choice(knob, "nope")


def test_execute_backend_string_routes():
    _, sched, x = _mk()
    art = pack_schedule(sched)
    y_jnp = np.asarray(execute_spmm(art, x, backend="jnp"))
    y_pal = np.asarray(execute_spmm(art, x, backend="pallas", interpret=True))
    np.testing.assert_allclose(y_jnp, y_pal, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# c_blk execute-time override: clear errors where it cannot apply
# ---------------------------------------------------------------------------


def test_c_blk_override_on_local_padded_raises():
    _, sched, x = _mk()
    art = pack_schedule(sched, c_blk=8)
    with pytest.raises(ValueError, match="pack-time gather tables"):
        execute_spmm(art, x, c_blk=4, gather="local")
    # resident mode may legitimately re-block the padded stream
    y8 = np.asarray(execute_spmm(art, x, c_blk=8, gather="resident"))
    y4 = np.asarray(execute_spmm(art, x, c_blk=4, gather="resident"))
    np.testing.assert_allclose(y8, y4, rtol=1e-5, atol=1e-5)


def test_c_blk_override_on_quantized_raises():
    _, sched, x = _mk()
    art = pack_schedule(sched, c_blk=8, value_dtype=jnp.int8)
    with pytest.raises(ValueError, match="per-block scales"):
        execute_spmm(art, x, c_blk=4, gather="resident")


# ---------------------------------------------------------------------------
# resolve_tuning: the one decision point
# ---------------------------------------------------------------------------


def test_resolve_tuning_picks_fastest_with_margin():
    meas = {"a": 1.0, "b": 0.5, "c": 0.8}
    assert resolve_tuning(meas, "a") == "b"  # 2x beats the default margin
    # below the margin the baseline stands
    assert resolve_tuning({"a": 1.0, "b": 0.99}, "a") == "a"
    assert resolve_tuning(
        {"a": 1.0, "b": 0.5}, "a", min_improvement=3.0
    ) == "a"
    # the baseline itself being fastest is stable
    assert resolve_tuning({"a": 0.1, "b": 0.5}, "a") == "a"
    assert DEFAULT_TUNE_IMPROVEMENT > 1.0


def test_resolve_tuning_validates_inputs():
    with pytest.raises(ValueError):
        resolve_tuning({}, "a")
    with pytest.raises(ValueError):
        resolve_tuning({"b": 1.0}, "a")  # baseline not measured
    with pytest.raises(ValueError):
        resolve_tuning({"a": 0.0}, "a")  # non-positive time


# ---------------------------------------------------------------------------
# GustPlan.tune
# ---------------------------------------------------------------------------


def test_tune_no_slower_than_static_and_memoized():
    dense, sched, x = _mk(m=48, n=64, b=4)
    cache = ScheduleCache()
    p = plan(sched, PlanConfig(l=8, c_blk=4, backend="jnp"), cache=cache)
    tuned = p.tune(x, iters=2, warmup=1)
    r = tuned.tuning
    assert isinstance(r, TuneResult)
    assert r.baseline in r.measurements and r.choice in r.measurements
    # the decision point guarantees the winner never measures slower
    assert r.measurements[r.choice] <= r.measurements[r.baseline]
    assert r.improvement >= 1.0
    # the tuned plan executes correctly and spells its knobs explicitly
    np.testing.assert_allclose(
        np.asarray(tuned.spmm(x)), dense @ np.asarray(x),
        rtol=1e-4, atol=1e-4,
    )
    assert tuned.config.layout in ("padded", "ragged")
    assert tuned.config.gather in ("resident", "local")
    # second tune of the same content is served from the memo
    again = p.tune(x, iters=2, warmup=1)
    assert again.tuning is r
    # a different probe shape is a different sweep
    x2 = jnp.concatenate([x, x], axis=1)
    assert p.tune(x2, iters=1, warmup=1).tuning is not r
    assert r.to_dict()["choice"].startswith("c_blk=")


def test_tune_requires_schedule():
    _, sched, x = _mk()
    from repro.core.plan import GustPlan

    spec_plan = GustPlan.from_spec(
        plan(sched, PlanConfig(l=8), cache=None).to_spec()
    )
    with pytest.raises(ValueError, match="schedule"):
        spec_plan.tune(x)


def test_tune_pruning_skips_predicted_losers():
    _, sched, x = _mk()
    p = plan(sched, PlanConfig(l=8, c_blk=4, backend="jnp"), cache=None)
    tuned = p.tune(x, iters=1, warmup=1, prune_ratio=1.0)
    r = tuned.tuning
    # ratio 1.0 prunes everything that streams more than the best
    # prediction; the baseline is always timed
    assert r.baseline in r.measurements
    for key in r.pruned:
        assert key not in r.measurements
    assert len(r.measurements) + len(r.pruned) == len(r.predicted_bytes)


# ---------------------------------------------------------------------------
# cost observability
# ---------------------------------------------------------------------------


def test_cost_reports_resolved_choices_and_cache_counters():
    _, sched, x = _mk()
    cache = ScheduleCache()
    p = plan(sched, PlanConfig(l=8, backend="pallas", interpret=True),
             cache=cache)
    c = p.cost()
    assert c.backend == "pallas"
    assert c.pipeline == "double"  # auto resolves to double on kernels
    assert c.layout in ("padded", "ragged")
    assert c.gather in ("resident", "local")
    assert c.cache_misses >= 1  # the pack this cost() materialized
    assert c.cache_entries >= 1
    before = c.cache_hits
    p2 = plan(sched, PlanConfig(l=8, backend="pallas", interpret=True),
              cache=cache)
    p2.artifact  # same content -> served from cache
    assert cache.stats()["hits"] > before
    d = c.to_dict()
    for key in ("backend", "pipeline", "cache_hits", "cache_misses"):
        assert key in d
    # jnp backend reports itself and the no-pipeline truth
    c_jnp = plan(sched, PlanConfig(l=8, backend="jnp"), cache=None).cost()
    assert c_jnp.backend == "jnp"
    assert c_jnp.pipeline == "single"
    assert c_jnp.cache_hits == c_jnp.cache_misses == 0


def test_plan_config_pipeline_knob():
    with pytest.raises(ValueError, match="pipeline"):
        PlanConfig(pipeline="quad")
    dense, sched, x = _mk()
    outs = [
        np.asarray(plan(
            sched,
            PlanConfig(l=8, backend="pallas", interpret=True, pipeline=pipe),
            cache=None,
        ).spmm(x))
        for pipe in ("single", "double", "auto")
    ]
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[1], outs[2])
