"""Packing-layer tests: the vectorized ragged→packed conversion in
``core/packing.py`` must be *bit-identical* to the original per-window
Python loop (kept here as the reference), across colorers, empty windows,
non-divisible shapes, and load balancing; plus ``repad_to`` invariants,
the leaves/meta codec round-trip, and the content-keyed ScheduleCache."""

import os
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import REPO

sys.path.insert(0, os.path.join(REPO, "benchmarks"))
from pack_bench import pack_loop_old  # the seed per-window-loop packer

from repro.core.formats import COOMatrix, coo_from_dense
from repro.core.packing import (
    PackedSchedule,
    ScheduleCache,
    pack_schedule,
    packed_from_leaves,
    packed_leaves,
    packed_meta,
    packed_spec,
    schedule_packed,
    stacked_leaf_specs,
    window_ids,
)
from repro.core.scheduler import schedule
from repro.kernels.ops import gust_spmm


def random_dense(rng, m, n, density):
    return ((rng.random((m, n)) < density) * rng.standard_normal((m, n))).astype(
        np.float32
    )


def pack_loop_reference(sched, c_blk=8, value_dtype=jnp.float32,
                        index_dtype=jnp.int32):
    """Equivalence oracle: the seed per-window loop (shared with
    benchmarks/pack_bench.py) plus the dtype/row_perm finishing of the
    seed ``pack_schedule``."""
    l, W = sched.l, sched.num_windows
    m_b, r_b, c_b, fusable = pack_loop_old(sched, c_blk)
    c_pad = m_b.shape[1]

    row_perm = np.arange(W * l, dtype=np.int32)
    row_perm[: sched.row_perm.shape[0]] = sched.row_perm
    return {
        "m_blk": np.asarray(jnp.asarray(m_b.reshape(W * c_pad, l), value_dtype)),
        "col_blk": c_b.reshape(W * c_pad, l).astype(
            np.dtype(jnp.dtype(index_dtype).name)),
        "row_blk": r_b.reshape(W * c_pad, l).astype(
            np.dtype(jnp.dtype(index_dtype).name)),
        "row_perm": row_perm,
        "c_pad": c_pad,
        "fusable": fusable,
    }


def empty_window_matrix():
    """4 windows at l=8; the 2nd and 4th windows hold no nonzeros."""
    rng = np.random.default_rng(7)
    dense = np.zeros((32, 40), np.float32)
    for r in list(range(0, 8)) + list(range(16, 24)):
        cols = rng.choice(40, 5, replace=False)
        dense[r, cols] = rng.standard_normal(5)
    return dense


EQUIV_CASES = [
    (16, 64, 8, 0.1),
    (64, 48, 16, 0.2),
    (100, 130, 32, 0.05),  # m % l != 0, n % l != 0
    (33, 7, 8, 0.5),  # n < l
    (57, 57, 16, 0.3),
]


@pytest.mark.parametrize("method", ["paper", "fast", "exact"])
@pytest.mark.parametrize("lb", [False, True])
@pytest.mark.parametrize("m,n,l,density", EQUIV_CASES)
def test_vectorized_pack_bit_identical(method, lb, m, n, l, density):
    rng = np.random.default_rng(m * 7919 + n)
    dense = random_dense(rng, m, n, density)
    sched = schedule(coo_from_dense(dense), l, load_balance=lb, method=method)
    ref = pack_loop_reference(sched)
    p = pack_schedule(sched)
    assert p.c_pad == ref["c_pad"] and p.fusable == ref["fusable"]
    assert np.array_equal(np.asarray(p.m_blk), ref["m_blk"])
    assert np.array_equal(np.asarray(p.col_blk), ref["col_blk"])
    assert np.array_equal(np.asarray(p.row_blk), ref["row_blk"])
    assert np.array_equal(np.asarray(p.row_perm), ref["row_perm"])


@pytest.mark.parametrize("lb", [False, True])
def test_vectorized_pack_empty_windows_and_empty_matrix(lb):
    for dense in (empty_window_matrix(), np.zeros((24, 16), np.float32)):
        sched = schedule(coo_from_dense(dense), 8, load_balance=lb)
        ref = pack_loop_reference(sched)
        p = pack_schedule(sched)
        for k in ("m_blk", "col_blk", "row_blk", "row_perm"):
            assert np.array_equal(np.asarray(getattr(p, k)), ref[k]), k
        assert p.c_pad == ref["c_pad"]


@pytest.mark.parametrize("value_dtype,index_dtype",
                         [(jnp.float32, jnp.int32), (jnp.bfloat16, jnp.int16)])
def test_vectorized_pack_dtype_variants(value_dtype, index_dtype):
    rng = np.random.default_rng(3)
    dense = random_dense(rng, 48, 64, 0.2)
    sched = schedule(coo_from_dense(dense), 16)
    ref = pack_loop_reference(sched, value_dtype=value_dtype,
                              index_dtype=index_dtype)
    p = pack_schedule(sched, value_dtype=value_dtype, index_dtype=index_dtype)
    assert p.m_blk.dtype == jnp.dtype(value_dtype)
    assert p.col_blk.dtype == jnp.dtype(index_dtype)
    assert np.array_equal(np.asarray(p.m_blk, np.float32),
                          ref["m_blk"].astype(np.float32))
    assert np.array_equal(np.asarray(p.col_blk), ref["col_blk"])


def test_window_ids_vectorized():
    rng = np.random.default_rng(5)
    for dense in (random_dense(rng, 50, 60, 0.1), empty_window_matrix(),
                  np.zeros((12, 12), np.float32)):
        sched = schedule(coo_from_dense(dense), 8, load_balance=False)
        wid_ref = np.zeros(max(sched.total_colors, 1), np.int32)
        ws = sched.window_starts
        for w in range(sched.num_windows):
            wid_ref[ws[w]: ws[w + 1]] = w
        assert np.array_equal(window_ids(sched), wid_ref)


# ---------------------------------------------------------------------------
# repad_to
# ---------------------------------------------------------------------------


def test_repad_to_invariants_and_numerics():
    rng = np.random.default_rng(11)
    dense = random_dense(rng, 40, 56, 0.25)
    x = rng.standard_normal((56, 3)).astype(np.float32)
    sched = schedule(coo_from_dense(dense), 8)
    p = pack_schedule(sched)
    g = p.repad_to(p.c_pad + 16)
    assert g.c_pad == p.c_pad + 16 and g.fusable == p.fusable
    # invariants in the new slots: values 0, cols == lane, rows 0
    W, l = g.num_windows, g.l
    m3 = np.asarray(g.m_blk).reshape(W, g.c_pad, l)
    c3 = np.asarray(g.col_blk).reshape(W, g.c_pad, l)
    r3 = np.asarray(g.row_blk).reshape(W, g.c_pad, l)
    assert np.all(m3[:, p.c_pad:] == 0.0)
    assert np.all(c3[:, p.c_pad:] == np.arange(l, dtype=np.int32))
    assert np.all(r3[:, p.c_pad:] == 0)
    # identical SpMM result, both execution paths
    for uk in (False, True):
        ya = np.asarray(gust_spmm(p, jnp.asarray(x), use_kernel=uk))
        yb = np.asarray(gust_spmm(g, jnp.asarray(x), use_kernel=uk))
        np.testing.assert_allclose(ya, yb, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(ya, dense @ x, rtol=1e-4, atol=1e-4)


def test_repad_to_preserves_compact_dtypes():
    """Regression: the old serving repad closure silently promoted the
    compact int16/bf16 stream to int32/float32 when layers had unequal
    C_pad; repad_to must keep leaf dtypes."""
    rng = np.random.default_rng(2)
    sched = schedule(coo_from_dense(random_dense(rng, 48, 64, 0.2)), 16)
    p = pack_schedule(sched, value_dtype=jnp.bfloat16, index_dtype=jnp.int16)
    g = p.repad_to(p.c_pad + 8)
    assert g.m_blk.dtype == jnp.bfloat16
    assert g.col_blk.dtype == jnp.int16 and g.row_blk.dtype == jnp.int16


def test_repad_to_noop_and_shrink_guard():
    rng = np.random.default_rng(4)
    sched = schedule(coo_from_dense(random_dense(rng, 16, 16, 0.3)), 8)
    p = pack_schedule(sched)
    assert p.repad_to(p.c_pad) is p
    with pytest.raises(ValueError):
        p.repad_to(p.c_pad - 1)


# ---------------------------------------------------------------------------
# leaves/meta codec
# ---------------------------------------------------------------------------


def test_codec_round_trip_and_spec_stacking():
    rng = np.random.default_rng(6)
    sched = schedule(coo_from_dense(random_dense(rng, 30, 44, 0.15)), 8)
    p = pack_schedule(sched)
    q = packed_from_leaves(packed_leaves(p), packed_meta(p))
    assert isinstance(q, PackedSchedule)
    assert packed_meta(q) == packed_meta(p)
    for k, v in packed_leaves(p).items():
        assert np.array_equal(np.asarray(getattr(q, k)), np.asarray(v))
    # spec prototypes stack with a leading reps axis, dtypes preserved
    proto = packed_spec(30, 44, 8, p.c_pad, value_dtype=jnp.bfloat16,
                        index_dtype=jnp.int16)
    stacked = stacked_leaf_specs(proto, reps=3)
    assert stacked["m_blk"].shape == (3, *proto.m_blk.shape)
    assert stacked["m_blk"].dtype == jnp.bfloat16
    assert stacked["col_blk"].dtype == jnp.int16
    assert stacked["row_perm"].dtype == jnp.int32


# ---------------------------------------------------------------------------
# ScheduleCache
# ---------------------------------------------------------------------------


def test_schedule_cache_content_keyed():
    rng = np.random.default_rng(9)
    dense = random_dense(rng, 32, 32, 0.2)
    cache = ScheduleCache()
    s1, p1 = schedule_packed(coo_from_dense(dense), 8, cache=cache)
    # same content, fresh COO objects -> cache hit, same objects back
    s2, p2 = schedule_packed(coo_from_dense(dense.copy()), 8, cache=cache)
    assert s1 is s2 and p1 is p2
    assert cache.hits >= 2  # schedule + packed
    # different packing dtype -> schedule reused, pack recomputed
    _, p3 = schedule_packed(coo_from_dense(dense), 8, cache=cache,
                            value_dtype=jnp.bfloat16, index_dtype=jnp.int16)
    assert p3 is not p1 and p3.m_blk.dtype == jnp.bfloat16
    # different content -> miss
    dense2 = dense.copy()
    dense2[0, 0] += 1.0
    s4, _ = schedule_packed(coo_from_dense(dense2), 8, cache=cache)
    assert s4 is not s1
    # different scheduling params -> miss
    s5, _ = schedule_packed(coo_from_dense(dense), 8, cache=cache,
                            load_balance=False)
    assert s5 is not s1


def test_schedule_cache_eviction_and_bypass():
    rng = np.random.default_rng(10)
    cache = ScheduleCache(maxsize=2)
    mats = [random_dense(rng, 16, 16, 0.3) for _ in range(3)]
    for d in mats:
        cache.schedule(coo_from_dense(d), 8)
    assert len(cache._store) <= 2  # oldest evicted
    # cache=None bypasses entirely
    d = mats[0]
    sa, pa = schedule_packed(coo_from_dense(d), 8, cache=None)
    sb, pb = schedule_packed(coo_from_dense(d), 8, cache=None)
    assert sa is not sb
    assert np.array_equal(np.asarray(pa.m_blk), np.asarray(pb.m_blk))
