"""Pallas kernel sweeps: shapes × dtypes × batch vs the pure-jnp oracle
(kernels/ref.py) and the dense ground truth.  Kernels run interpret=True
on CPU (the kernel body executes in Python) — the TPU BlockSpec tiling is
exercised structurally."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.formats import coo_from_dense
from repro.core.scheduler import schedule
from repro.kernels.gather_fill import make_gather_fill
from repro.kernels.ops import gust_spmm, pack_schedule
from repro.kernels.ref import gather_fill_ref, gust_spmv_ref


def random_dense(rng, m, n, density):
    return ((rng.random((m, n)) < density) * rng.standard_normal((m, n))).astype(
        np.float32
    )


SHAPE_SWEEP = [
    # (m, n, l, B, density)
    (8, 8, 4, 1, 0.3),
    (16, 64, 8, 1, 0.1),
    (64, 48, 16, 4, 0.2),
    (100, 130, 32, 8, 0.05),  # non-divisible m, n
    (33, 7, 8, 2, 0.5),  # n < l
    (256, 256, 32, 3, 0.02),
]


@pytest.mark.parametrize("m,n,l,b,density", SHAPE_SWEEP)
@pytest.mark.parametrize("lb", [False, True])
def test_gust_spmv_kernel_sweep(m, n, l, b, density, lb):
    rng = np.random.default_rng(m * 1000 + n)
    dense = random_dense(rng, m, n, density)
    x = rng.standard_normal((n, b)).astype(np.float32)
    ref = dense @ x
    sched = schedule(coo_from_dense(dense), l, load_balance=lb)
    packed = pack_schedule(sched)
    assert packed.fusable, "scheduler output must satisfy the lane structure"
    y_kernel = np.asarray(gust_spmm(packed, jnp.asarray(x), use_kernel=True))
    y_xla = np.asarray(gust_spmm(packed, jnp.asarray(x), use_kernel=False))
    np.testing.assert_allclose(y_kernel, ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(y_xla, ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(y_kernel, y_xla, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gust_spmv_dtypes(dtype):
    rng = np.random.default_rng(5)
    dense = random_dense(rng, 64, 96, 0.2)
    x = rng.standard_normal((96, 4)).astype(np.float32)
    sched = schedule(coo_from_dense(dense), 16)
    packed = pack_schedule(sched, value_dtype=dtype)
    y = np.asarray(gust_spmm(packed, jnp.asarray(x, dtype))).astype(np.float32)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    ref = dense @ x
    err = np.abs(y - ref).max() / np.abs(ref).max()
    assert err < tol, err


@pytest.mark.parametrize("c_blk", [4, 8, 16])
def test_gust_spmv_block_shapes(c_blk):
    """BlockSpec color-block sweep — different VMEM tile heights must give
    identical results."""
    rng = np.random.default_rng(9)
    dense = random_dense(rng, 48, 64, 0.15)
    x = rng.standard_normal((64, 2)).astype(np.float32)
    sched = schedule(coo_from_dense(dense), 8)
    packed = pack_schedule(sched, c_blk=c_blk)
    y = np.asarray(gust_spmm(packed, jnp.asarray(x), c_blk=c_blk))
    np.testing.assert_allclose(y, dense @ x, rtol=1e-4, atol=1e-4)


def test_kernel_vs_ref_on_packed_blocks():
    """Kernel output == ref.py oracle on the same packed blocks (exact
    same semantics, including padding slots)."""
    rng = np.random.default_rng(11)
    dense = random_dense(rng, 40, 56, 0.25)
    sched = schedule(coo_from_dense(dense), 8)
    packed = pack_schedule(sched)
    x = rng.standard_normal((56, 3)).astype(np.float32)
    seg = packed.seg_count
    xp = jnp.pad(jnp.asarray(x), ((0, seg * 8 - 56), (0, 0)))
    y_ref = np.asarray(
        gust_spmv_ref(
            packed.m_blk, packed.col_blk, packed.row_blk, xp,
            num_windows=packed.num_windows, l=packed.l,
        )
    )
    from repro.kernels.gust_spmv import make_gust_spmv

    x2d = xp.reshape(seg, 8, 3)
    fn = make_gust_spmv(packed.num_windows, packed.c_pad, 8, seg, 3)
    y_k = np.asarray(fn(packed.m_blk, packed.col_blk, packed.row_blk, x2d))
    np.testing.assert_allclose(y_k, y_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("l,seg,b", [(8, 4, 1), (16, 3, 4), (32, 8, 2)])
def test_gather_fill_kernel(l, seg, b):
    rng = np.random.default_rng(l)
    n = seg * l
    total = 16
    x = rng.standard_normal((n, b)).astype(np.float32)
    # build col indices honouring the lane structure (off == lane or
    # l-1-lane), like the scheduler emits
    lanes = np.tile(np.arange(l), (total, 1))
    segs = rng.integers(0, seg, (total, l))
    flip = rng.integers(0, 2, (total, l)).astype(bool)
    offs = np.where(flip, l - 1 - lanes, lanes)
    cols = (segs * l + offs).astype(np.int32)
    fn = make_gather_fill(total, l, seg, b)
    x2d = jnp.asarray(x).reshape(seg, l, b)
    out = np.asarray(fn(jnp.asarray(cols), x2d))
    ref = np.asarray(gather_fill_ref(jnp.asarray(cols), jnp.asarray(x)))
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)
