"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates its REDUCED family-preserving config and runs one
forward/train step + prefill/decode on CPU, asserting output shapes and
finiteness; plus the decode-vs-train consistency property (the cache path
must reproduce the full-sequence forward)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, SHAPES, get_arch
from repro.models.model_zoo import build_model

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B, S, key=KEY, last_token_embed=None, params=None):
    batch = {}
    if cfg.frontend == "embed":
        emb = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        if last_token_embed is not None and params is not None:
            tok_emb = jnp.take(params["embed"]["table"], last_token_embed, axis=0)
            emb = emb.at[:, -1].set(tok_emb)
        batch["embeds"] = emb
    elif cfg.is_encdec:
        batch["src_frames"] = jax.random.normal(key, (B, 16, cfg.d_model))
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch["loss_mask"] = jnp.ones((B, S), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_forward_and_loss(arch_id):
    cfg = get_arch(arch_id).reduced()
    lm = build_model(cfg)
    params = lm.init(KEY)
    B, S = 2, 32
    batch = _batch_for(cfg, B, S)
    logits, aux = lm.train_logits(params, batch, dtype=jnp.float32, remat=True)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), "NaN/inf in logits"
    loss, metrics = lm.loss_fn(params, batch, dtype=jnp.float32)
    assert np.isfinite(float(loss))
    # one real gradient step must produce finite grads
    g = jax.grad(lambda p: lm.loss_fn(p, batch, dtype=jnp.float32)[0])(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all(), "non-finite gradient"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_serve(arch_id):
    cfg = get_arch(arch_id).reduced()
    lm = build_model(cfg)
    params = lm.init(KEY)
    B, S = 2, 16
    batch = _batch_for(cfg, B, S)
    batch.pop("labels"), batch.pop("loss_mask")
    caches = lm.init_caches(B, 48, jnp.float32)
    logits_p, caches = lm.prefill(params, batch, caches, dtype=jnp.float32)
    assert logits_p.shape == (B, 1, cfg.padded_vocab)
    tok = jnp.zeros((B, 1), jnp.int32)
    for step in range(3):
        logits_d, caches = lm.decode_step(
            params, caches, tok, jnp.int32(S + step), dtype=jnp.float32
        )
        assert logits_d.shape == (B, 1, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits_d)).all()


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_matches_train_forward(arch_id):
    """Prefill S tokens + decode token S == full forward at position S."""
    cfg = dataclasses.replace(get_arch(arch_id).reduced(), capacity_factor=16.0)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(1))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab)
    if cfg.frontend == "embed":
        emb = jax.random.normal(jax.random.PRNGKey(3), (B, S + 1, cfg.d_model))
        emb = emb.at[:, S].set(jnp.take(params["embed"]["table"], toks[:, S], axis=0))
        bf, bp = {"embeds": emb}, {"embeds": emb[:, :S]}
    elif cfg.is_encdec:
        src = jax.random.normal(jax.random.PRNGKey(3), (B, 16, cfg.d_model))
        bf = {"src_frames": src, "tokens": toks}
        bp = {"src_frames": src, "tokens": toks[:, :S]}
    else:
        bf, bp = {"tokens": toks}, {"tokens": toks[:, :S]}
    logits_full, _ = lm.train_logits(params, bf, dtype=jnp.float32, remat=False)
    caches = lm.init_caches(B, 64, jnp.float32)
    _, caches = lm.prefill(params, bp, caches, dtype=jnp.float32)
    logits_dec, _ = lm.decode_step(
        params, caches, toks[:, S : S + 1], jnp.int32(S), dtype=jnp.float32
    )
    ref, got = np.asarray(logits_full[:, S]), np.asarray(logits_dec[:, 0])
    err = np.abs(ref - got).max() / (np.abs(ref).max() + 1e-9)
    assert err < 2e-4, f"decode diverges from train forward: {err}"


def test_vocab_padding_masks_logits():
    cfg = get_arch("seamless_m4t_medium").reduced()
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab
    # force a padded vocab in a tiny config
    cfg = dataclasses.replace(cfg, vocab=250)  # padded_vocab = 256
    lm = build_model(cfg)
    params = lm.init(KEY)
    batch = _batch_for(cfg, 2, 8)
    logits, _ = lm.train_logits(params, batch, dtype=jnp.float32, remat=False)
    pad_region = np.asarray(logits[..., cfg.vocab :])
    assert (pad_region <= -1e29).all(), "padding logits must be masked"


def test_long_context_ring_cache_eviction():
    """A local-attention arch decoding past its window must keep matching
    the full forward (ring buffer evicts correctly)."""
    cfg = get_arch("llava_next_mistral_7b").reduced()  # window 16
    lm = build_model(cfg)
    params = lm.init(KEY)
    B, S = 1, 40  # prompt much longer than the window
    emb = jax.random.normal(KEY, (B, S + 1, cfg.d_model))
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    emb = emb.at[:, S].set(jnp.take(params["embed"]["table"], toks[:, S], axis=0))
    logits_full, _ = lm.train_logits(params, {"embeds": emb}, dtype=jnp.float32,
                                     remat=False)
    caches = lm.init_caches(B, 64, jnp.float32)
    _, caches = lm.prefill(params, {"embeds": emb[:, :S]}, caches, dtype=jnp.float32)
    logits_dec, _ = lm.decode_step(params, caches, toks[:, S:S+1], jnp.int32(S),
                                   dtype=jnp.float32)
    err = np.abs(np.asarray(logits_full[:, S]) - np.asarray(logits_dec[:, 0])).max()
    rel = err / np.abs(np.asarray(logits_full[:, S])).max()
    assert rel < 2e-4, rel


def test_sub_quadratic_flags_match_assignment():
    expected_runs_500k = {
        "xlstm_125m", "recurrentgemma_9b", "gemma3_4b",
        "llama4_scout_17b_a16e", "llava_next_mistral_7b",
    }
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id)
        runs = cfg.sub_quadratic and not cfg.is_encdec
        assert runs == (arch_id in expected_runs_500k), arch_id
