"""int8 per-block-scaled values + double-buffered kernels (PR 6).

Two kernel-speed invariants locked here:

* **Quantization is a packed-format property.**  ``value_dtype=int8``
  packs store int8 values with one f32 scale per ``c_blk`` cycle block
  (``scale_blk``); dequant is the single f32 multiply defined by
  :func:`repro.kernels.ref.dequant_ref` and shared bit-exactly by every
  kernel and oracle.  Padding slots quantize to exactly 0, all-zero
  blocks carry scale 1.0, and ``scale_blk`` survives every packed-format
  transformation — ``repad_to`` / ``repad_to_blocks``, the leaves/meta
  codec, and serving ``stack`` — bit-identically.

* **Double-buffering is invisible.**  The two-slot ping/pong kernels
  perform the same f32 additions in the same order as the
  single-buffered kernels, so ``pipeline="double"`` vs ``"single"``
  outputs are equal to the last bit on both layouts, both gather modes,
  and both value dtypes.

The hypothesis property sweeps random/power-law matrices; without
hypothesis a seeded deterministic slice runs instead (same body).
"""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.formats import coo_from_dense
from repro.core.packing import (
    pack_ragged,
    pack_schedule,
    packed_from_leaves,
    packed_leaves,
    packed_meta,
    ragged_from_leaves,
    ragged_leaves,
    ragged_meta,
)
from repro.core.plan import GustPlan, PlanConfig, plan
from repro.core.scheduler import schedule
from repro.kernels.ops import execute_spmm
from repro.kernels.ref import dequant_ref

from test_ragged import power_law_dense, random_dense


def _quant_invariants(art):
    """Pack-time quantization contract on one int8 artifact."""
    assert art.quantized
    m = np.asarray(art.m_blk)
    scale = np.asarray(art.scale_blk)
    assert m.dtype == np.int8
    assert scale.dtype == np.float32
    assert scale.shape == (m.shape[0] // art.c_blk,)
    assert np.all(scale > 0), "scales must be positive (1.0 for zero blocks)"
    # all-zero blocks quantize with the identity scale
    blocks = m.reshape(-1, art.c_blk * art.l)
    zero_blocks = ~np.any(blocks, axis=1)
    orig = np.asarray(dequant_ref(art.m_blk, art.scale_blk, c_blk=art.c_blk))
    zero_orig = ~np.any(
        orig.reshape(-1, art.c_blk * art.l), axis=1
    )
    np.testing.assert_array_equal(zero_blocks, zero_orig)
    assert np.all(scale[zero_blocks] == 1.0)
    # |q| <= 127 and the per-block absmax maps to ~127
    assert np.abs(m).max(initial=0) <= 127


def _assert_all_paths_agree(art, x, dense_ref, tol):
    """single==double bitwise per (gather, backend); kernel ~= oracle."""
    outs = {}
    for gather in ("resident", "local"):
        for pipeline in ("single", "double"):
            outs[(gather, pipeline)] = np.asarray(execute_spmm(
                art, x, use_kernel=True, interpret=True,
                gather=gather, pipeline=pipeline,
            ))
        outs[(gather, "jnp")] = np.asarray(execute_spmm(
            art, x, use_kernel=False, gather=gather,
        ))
        assert np.array_equal(
            outs[(gather, "single")], outs[(gather, "double")]
        ), f"double-buffered kernel diverged bitwise ({gather})"
        # kernel and oracle share bit-identical dequant + partial products
        # but accumulate in different orders -> allclose at f32 epsilon
        np.testing.assert_allclose(
            outs[(gather, "single")], outs[(gather, "jnp")],
            rtol=1e-5, atol=1e-5,
        )
    assert np.array_equal(
        outs[("resident", "single")], outs[("local", "single")]
    ), "local gather diverged from resident on the quantized stream"
    np.testing.assert_allclose(
        outs[("resident", "jnp")], dense_ref, atol=tol, rtol=0
    )
    return outs[("resident", "single")]


def _property_body(args):
    m, n, density, l, b, skew, seed = args
    rng = np.random.default_rng(seed)
    dense = (
        power_law_dense(rng, m, n, base_density=density * 0.2)
        if skew
        else random_dense(rng, m, n, density)
    )
    x = jnp.asarray(rng.standard_normal((n, b)).astype(np.float32))
    sched = schedule(coo_from_dense(dense), l)
    # per-slot quant error <= scale/2; <= c_pad slots accumulate per output
    for art in (
        pack_schedule(sched, value_dtype=jnp.int8),
        pack_ragged(sched, value_dtype=jnp.int8),
    ):
        _quant_invariants(art)
        scale = np.asarray(art.scale_blk)
        slots_per_out = art.m_blk.shape[0] // max(art.num_windows, 1)
        tol = 0.5 * scale.max() * float(np.abs(np.asarray(x)).max()) \
            * max(slots_per_out, 1) + 1e-6
        _assert_all_paths_agree(art, x, dense @ np.asarray(x), tol)
    # f32 stream: double-buffering must be invisible there too
    art32 = pack_schedule(sched)
    assert not art32.quantized and art32.scale_blk is None
    _assert_all_paths_agree(art32, x, dense @ np.asarray(x), 1e-4)


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    matrix_strategy = st.tuples(
        st.integers(2, 40),  # m
        st.integers(2, 48),  # n
        st.sampled_from([0.05, 0.2, 0.5]),
        st.sampled_from([4, 8]),  # l
        st.integers(1, 3),  # B
        st.booleans(),  # power-law skew
        st.integers(0, 10_000),  # seed
    )

    @settings(max_examples=15, deadline=None)
    @given(args=matrix_strategy)
    def test_quant_roundtrip_property(args):
        _property_body(args)

else:  # deterministic slice of the sweep without hypothesis

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_quant_roundtrip_property(seed):
        rng = np.random.default_rng(seed + 17)
        args = (
            int(rng.integers(2, 40)), int(rng.integers(2, 48)),
            [0.05, 0.2, 0.5][seed % 3], [4, 8][seed % 2],
            1 + seed % 3, bool(seed % 2), seed,
        )
        _property_body(args)


# ---------------------------------------------------------------------------
# repad: scales survive, new blocks quantize to exactly zero
# ---------------------------------------------------------------------------


def _mk(seed=5, m=40, n=48, l=8, density=0.25):
    rng = np.random.default_rng(seed)
    dense = random_dense(rng, m, n, density)
    x = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
    return schedule(coo_from_dense(dense), l), x


def test_repad_preserves_scales_padded():
    sched, x = _mk()
    art = pack_schedule(sched, value_dtype=jnp.int8)
    grown = art.repad_to(art.c_pad + 2 * art.c_blk)
    assert grown.quantized
    w = art.num_windows
    old = np.asarray(art.scale_blk).reshape(w, -1)
    new = np.asarray(grown.scale_blk).reshape(w, -1)
    np.testing.assert_array_equal(old, new[:, : old.shape[1]])
    assert np.all(new[:, old.shape[1]:] == 1.0), \
        "padding blocks must carry the identity scale"
    pad_rows = np.asarray(grown.m_blk).reshape(
        w, grown.c_pad, grown.l
    )[:, art.c_pad:]
    assert np.all(pad_rows == 0), "padding slots must quantize to int8 zero"
    y_old = np.asarray(execute_spmm(art, x, use_kernel=True))
    y_new = np.asarray(execute_spmm(grown, x, use_kernel=True))
    assert np.array_equal(y_old, y_new)


def test_repad_preserves_scales_ragged():
    sched, x = _mk()
    art = pack_ragged(sched, value_dtype=jnp.int8)
    grown = art.repad_to_blocks(art.num_blocks + 3)
    assert grown.quantized
    old = np.asarray(art.scale_blk)
    new = np.asarray(grown.scale_blk)
    np.testing.assert_array_equal(old, new[: old.shape[0]])
    assert np.all(new[old.shape[0]:] == 1.0)
    assert np.all(
        np.asarray(grown.m_blk)[art.num_blocks * art.c_blk:] == 0
    )
    y_old = np.asarray(execute_spmm(art, x, use_kernel=True))
    y_new = np.asarray(execute_spmm(grown, x, use_kernel=True))
    assert np.array_equal(y_old, y_new)


def test_repad_quantized_requires_block_aligned_c_pad():
    sched, _ = _mk()
    art = pack_schedule(sched, value_dtype=jnp.int8)
    with pytest.raises(ValueError, match="c_blk"):
        art.repad_to(art.c_pad + 1)


# ---------------------------------------------------------------------------
# codec + stack: scale_blk is a first-class leaf
# ---------------------------------------------------------------------------


def test_codec_roundtrips_scale_blk():
    sched, x = _mk()
    for pack, leaves_fn, meta_fn, from_fn in (
        (pack_schedule, packed_leaves, packed_meta, packed_from_leaves),
        (pack_ragged, ragged_leaves, ragged_meta, ragged_from_leaves),
    ):
        art = pack(sched, value_dtype=jnp.int8)
        art32 = pack(sched)
        leaves, meta = leaves_fn(art), meta_fn(art)
        assert "scale_blk" in leaves
        assert "scale_blk" not in leaves_fn(art32), \
            "f32 packs must not grow a scale leaf"
        assert meta == meta_fn(art32), \
            "quantization must not change the static meta tuple"
        back = from_fn(leaves, meta)
        assert back.quantized
        np.testing.assert_array_equal(
            np.asarray(back.scale_blk), np.asarray(art.scale_blk)
        )
        y0 = np.asarray(execute_spmm(art, x, use_kernel=True))
        y1 = np.asarray(execute_spmm(back, x, use_kernel=True))
        assert np.array_equal(y0, y1)


def test_stack_carries_scales_and_rejects_mixed():
    sched_a, x = _mk(seed=6)
    sched_b, _ = _mk(seed=7)
    cfg = PlanConfig(l=8, value_dtype="int8", layout="padded")
    pa, pb = plan(sched_a, cfg, cache=None), plan(sched_b, cfg, cache=None)
    st = GustPlan.stack([pa, pb])
    assert "scale_blk" in st["leaves"]
    assert st["leaves"]["scale_blk"].shape[0] == 2
    # each layer's slice re-executes identically to its repadded artifact
    for i, p in enumerate((pa, pb)):
        layer = GustPlan.from_spec({
            "leaves": {k: v[i] for k, v in st["leaves"].items()},
            "meta": st["meta"],
        })
        assert layer.config.value_dtype == "int8"
        y_plan = np.asarray(p.spmm(x))
        y_layer = np.asarray(layer.spmm(x))
        assert np.array_equal(y_plan, y_layer)
    p32 = plan(sched_b, dataclasses.replace(cfg, value_dtype="float32"),
               cache=None)
    with pytest.raises(ValueError, match="mixed quantized"):
        GustPlan.stack([pa, p32])


# ---------------------------------------------------------------------------
# dequant semantics: the oracle multiply IS the kernel multiply
# ---------------------------------------------------------------------------


def test_dequant_ref_is_single_f32_multiply():
    rng = np.random.default_rng(11)
    q = rng.integers(-127, 128, (12, 8)).astype(np.int8)
    scale = rng.uniform(0.01, 2.0, (3,)).astype(np.float32)
    out = np.asarray(dequant_ref(jnp.asarray(q), jnp.asarray(scale), c_blk=4))
    expect = q.astype(np.float32) * np.repeat(scale, 4)[:, None]
    assert np.array_equal(out, expect), \
        "dequant must be exactly float32(q) * scale, one multiply"
