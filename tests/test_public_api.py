"""Public API surface of the top-level ``repro`` package.

The exported symbol list is snapshotted below: adding a symbol is a
deliberate one-line diff here; *losing* one (a refactor moving/renaming a
public name) fails loudly instead of silently breaking downstream
imports.  Also locks the laziness contract: ``import repro`` must not
pull jax (entry points like ``repro.launch.dryrun`` pin ``XLA_FLAGS``
before jax initializes).
"""

import subprocess
import sys

import pytest

from conftest import SRC

#: The public surface — update deliberately, with a matching note in
#: ROADMAP.md (§Plan API + deprecation policy).
EXPECTED_EXPORTS = sorted([
    # plan/execute API
    "plan", "reschedule", "GustPlan", "PlanConfig", "PlanCost", "TuneResult",
    # persistent plan artifacts (PR 7)
    "PlanStore",
    # static analysis (PR 9)
    "verify", "Finding",
    # SpGEMM + graph analytics (PR 8)
    "SpgemmCost", "pagerank", "triangle_count", "feature_propagation",
    "PageRankResult", "TriangleCountResult",
    # formats + scheduler
    "COOMatrix", "GustSchedule", "coo_from_dense", "dense_from_coo",
    "schedule",
    # packed layouts + cache
    "PackedSchedule", "RaggedSchedule", "ScheduleCache", "clear_cache",
    # sparse LM serving
    "GustLinear", "SparsityConfig", "prune_by_magnitude", "GustServeConfig",
    # resilience: fault injection + request lifecycle (PR 10)
    "FaultPlan", "FaultSpec", "RequestResult", "RequestStatus",
    # statistical bounds
    "expected_colors_bound", "expected_execution_cycles",
    "expected_utilization",
    # legacy shims (deprecated spellings, still exported)
    "spmv", "spmv_scheduled", "spmm_scheduled", "spmm_ragged",
    "distributed_spmv", "gust_spmm", "gust_spmm_auto",
])


def test_exported_symbol_snapshot():
    import repro

    assert sorted(repro.__all__) == EXPECTED_EXPORTS
    assert sorted(set(dir(repro)) & set(EXPECTED_EXPORTS)) == EXPECTED_EXPORTS


def test_every_export_resolves():
    import repro

    for name in repro.__all__:
        assert getattr(repro, name) is not None, name
    with pytest.raises(AttributeError):
        repro.not_a_symbol


def test_import_repro_is_lazy_no_jax():
    code = (
        "import sys; import repro; "
        "assert 'jax' not in sys.modules, 'import repro pulled jax eagerly'; "
        "assert 'repro.core' not in sys.modules; "
        "print('lazy-ok')"
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr
    assert "lazy-ok" in proc.stdout
