"""Direct coverage of the ``core/formats.py`` conversion helpers (PR 8).

The SpGEMM output path leans on ``dense_from_coo``/``csr_from_coo``
round-trips, duplicate-entry summation and the new
``transpose``/``sorted_by_col`` methods; this module pins them against
plain-numpy references including the empty-row/col and duplicate edge
cases.
"""

import numpy as np
import pytest

from repro.core.formats import (
    COOMatrix,
    coo_from_dense,
    csr_from_coo,
    dense_from_coo,
)


def random_coo(m, n, density, seed, duplicates=0):
    rng = np.random.default_rng(seed)
    dense = ((rng.random((m, n)) < density) * rng.standard_normal((m, n)))
    coo = coo_from_dense(dense.astype(np.float32))
    if duplicates and coo.nnz:
        pick = rng.choice(coo.nnz, min(duplicates, coo.nnz), replace=False)
        coo = COOMatrix(
            coo.shape,
            np.concatenate([coo.rows, coo.rows[pick]]),
            np.concatenate([coo.cols, coo.cols[pick]]),
            np.concatenate([coo.vals, coo.vals[pick]]),
        )
    return coo


@pytest.mark.parametrize("m,n,density", [(1, 1, 1.0), (7, 5, 0.3),
                                         (16, 33, 0.1), (40, 8, 0.5)])
def test_dense_coo_round_trip(m, n, density):
    rng = np.random.default_rng(0)
    dense = ((rng.random((m, n)) < density) * rng.standard_normal((m, n))
             ).astype(np.float32)
    assert np.array_equal(dense_from_coo(coo_from_dense(dense)), dense)


def test_dense_from_coo_sums_duplicates():
    coo = COOMatrix(
        (3, 3),
        np.array([0, 0, 2, 2, 2], np.int64),
        np.array([1, 1, 0, 0, 0], np.int64),
        np.array([1.5, 2.5, 1.0, 1.0, -3.0], np.float32),
    )
    dense = dense_from_coo(coo)
    assert dense[0, 1] == np.float32(1.5) + np.float32(2.5)
    assert dense[2, 0] == np.float32(-1.0)
    assert dense.sum() == dense[0, 1] + dense[2, 0]


def test_empty_matrix_and_empty_rows_cols():
    empty = COOMatrix((4, 6), np.zeros(0, np.int64), np.zeros(0, np.int64),
                      np.zeros(0, np.float32))
    assert empty.nnz == 0 and empty.density == 0.0
    assert np.array_equal(dense_from_coo(empty), np.zeros((4, 6), np.float32))
    indptr, indices, data = csr_from_coo(empty)
    assert np.array_equal(indptr, np.zeros(5, np.int64))
    assert indices.size == 0 and data.size == 0

    # rows 1 and 3, cols 0 and 2 entirely empty
    coo = COOMatrix((4, 3), np.array([0, 2], np.int64),
                    np.array([1, 1], np.int64),
                    np.array([2.0, 3.0], np.float32))
    assert np.array_equal(coo.row_nnz(), [1, 0, 1, 0])
    assert np.array_equal(coo.col_nnz(), [0, 2, 0])
    indptr, _, _ = csr_from_coo(coo)
    assert np.array_equal(indptr, [0, 1, 1, 2, 2])


@pytest.mark.parametrize("dup", [0, 5])
def test_csr_from_coo_matches_dense(dup):
    coo = random_coo(17, 11, 0.3, seed=1, duplicates=dup)
    indptr, indices, data = csr_from_coo(coo)
    assert indptr[0] == 0 and indptr[-1] == coo.nnz
    dense = np.zeros(coo.shape, np.float32)
    for i in range(coo.shape[0]):
        for k in range(indptr[i], indptr[i + 1]):
            dense[i, indices[k]] += data[k]
        # within-row column order is sorted (the sorted_by_row contract)
        row_cols = indices[indptr[i]:indptr[i + 1]]
        assert np.all(np.diff(row_cols) >= 0)
    assert np.allclose(dense, dense_from_coo(coo), atol=1e-6)


def test_sorted_by_col_order_and_content():
    coo = random_coo(13, 9, 0.4, seed=2, duplicates=3)
    s = coo.sorted_by_col()
    keys = s.cols * coo.shape[0] + s.rows
    assert np.all(np.diff(keys) >= 0)  # (col, row) lexicographic
    assert np.array_equal(dense_from_coo(s), dense_from_coo(coo))


@pytest.mark.parametrize("m,n,density,dup", [(6, 6, 0.4, 0), (12, 5, 0.3, 4),
                                             (3, 20, 0.2, 0)])
def test_transpose_round_trip(m, n, density, dup):
    coo = random_coo(m, n, density, seed=3, duplicates=dup)
    t = coo.transpose()
    assert t.shape == (n, m)
    assert np.array_equal(dense_from_coo(t), dense_from_coo(coo).T)
    # transpose emits the transpose's row-major order
    keys = t.rows * np.int64(m) + t.cols
    assert np.all(np.diff(keys) >= 0)
    # double transpose restores the matrix (as a dense equality)
    assert np.array_equal(dense_from_coo(t.transpose()), dense_from_coo(coo))


def test_transpose_empty():
    empty = COOMatrix((2, 5), np.zeros(0, np.int64), np.zeros(0, np.int64),
                      np.zeros(0, np.float32))
    t = empty.transpose()
    assert t.shape == (5, 2) and t.nnz == 0


def test_shape_validation():
    with pytest.raises(ValueError):
        COOMatrix((2, 2), np.array([2], np.int64), np.array([0], np.int64),
                  np.array([1.0], np.float32))
    with pytest.raises(ValueError):
        COOMatrix((2, 2), np.array([0, 1], np.int64), np.array([0], np.int64),
                  np.array([1.0], np.float32))
