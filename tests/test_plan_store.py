"""PlanStore + incremental reschedule: persistence and splice contracts
(ISSUE 7).

The acceptance bar: parallel, incremental, and store-loaded plans are
**bitwise-identical in execution** to a fresh serial plan on both
layouts, both gathers, and both value dtypes; a warm store start does
zero coloring work; loads tolerate corrupt/stale files; the counters
surface on ``GustPlan.cost()``; and ``ScheduleCache`` is LRU-bounded
with counted evictions.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import SRC
from repro.core.formats import coo_from_dense
from repro.core.packing import (
    DEFAULT_SCHEDULE_CACHE_SIZE,
    RaggedSchedule,
    ScheduleCache,
    packed_leaves,
    ragged_leaves,
    splice_ragged_blocks,
)
from repro.core.plan import GustPlan, PlanConfig, plan, reschedule
from repro.core.plan_store import ARTIFACT_KNOBS, FORMAT_VERSION, PlanStore
from repro.core.scheduler import reset_sched_counters, sched_counters


def random_dense(seed=0, m=40, n=48, density=0.25):
    rng = np.random.default_rng(seed)
    return ((rng.random((m, n)) < density)
            * rng.standard_normal((m, n))).astype(np.float32)


def probe(seed, n, b=3):
    rng = np.random.default_rng(seed + 1000)
    return jnp.asarray(rng.standard_normal((n, b)).astype(np.float32))


def leaves_bitwise_equal(a, b):
    assert type(a) is type(b)
    to_leaves = ragged_leaves if isinstance(a, RaggedSchedule) else packed_leaves
    la, lb = to_leaves(a), to_leaves(b)
    assert sorted(la) == sorted(lb)
    for k in la:
        if la[k] is None or lb[k] is None:
            assert la[k] is None and lb[k] is None, k
            continue
        va, vb = np.asarray(la[k]), np.asarray(lb[k])
        assert va.dtype == vb.dtype, k
        assert np.array_equal(va, vb), k


# ---------------------------------------------------------------------------
# Round-trip: both layouts x both gathers x both value dtypes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["padded", "ragged"])
@pytest.mark.parametrize("vdt", ["float32", "int8"])
def test_store_roundtrip_bitwise(tmp_path, layout, vdt):
    dense = random_dense(seed=hash((layout, vdt)) % 100)
    store = PlanStore(str(tmp_path))
    x = probe(0, dense.shape[1])
    outs = {}
    for gather in ("resident", "local"):
        cfg = PlanConfig(l=8, layout=layout, value_dtype=vdt, gather=gather,
                         load_balance=False)
        cold = plan(dense, cfg, cache=None, store=store)
        y_cold = np.asarray(cold.spmm(x))
        # fresh process simulation: no schedule cache, store only
        reset_sched_counters()
        warm = plan(dense, cfg, cache=None, store=store)
        assert warm._store_loaded
        assert warm.sched is None  # artifact-only plan
        assert sched_counters["color_calls"] == 0
        leaves_bitwise_equal(cold.artifact, warm.artifact)
        y_warm = np.asarray(warm.spmm(x))
        assert np.array_equal(y_cold, y_warm)
        outs[gather] = y_cold
    # both gathers share ONE store entry (gather is an execution knob)
    assert len(store) == 1
    assert np.array_equal(outs["resident"], outs["local"])


def test_store_warm_summary_and_stats(tmp_path):
    dense = random_dense(3)
    store = PlanStore(str(tmp_path))
    cfg = PlanConfig(l=8, load_balance=False)
    cold = plan(dense, cfg, cache=None, store=store)
    cold.artifact  # materialize -> write-behind
    warm = plan(dense, cfg, cache=None, store=store)
    assert warm.summary is not None
    assert warm.summary["cycles"] == cold.sched.cycles
    assert warm.summary["nnz"] == cold.sched.nnz
    st = store.stats()
    assert st["hits"] == 1 and st["writes"] == 1 and st["entries"] == 1


def test_cost_surfaces_store_and_cache_counters(tmp_path):
    dense = random_dense(4)
    store = PlanStore(str(tmp_path))
    cache = ScheduleCache()
    cfg = PlanConfig(l=8, load_balance=False)
    p = plan(dense, cfg, cache=cache, store=store)
    p.artifact
    c = p.cost()
    assert c.store_misses == 1 and c.store_hits == 0
    assert c.cache_evictions == 0
    p2 = plan(dense, cfg, cache=cache, store=store)
    assert p2._store_loaded
    # store-loaded plans can't cost() (no schedule) — counters live on the
    # fresh plan's cost and on store.stats()
    assert store.hits == 1


# ---------------------------------------------------------------------------
# Keying: execution knobs excluded, artifact knobs included
# ---------------------------------------------------------------------------


def test_store_key_excludes_execution_knobs():
    dense = random_dense(5)
    mk = ScheduleCache.matrix_key(coo_from_dense(dense))
    base = PlanConfig(l=8, layout="ragged", load_balance=False)
    k0 = PlanStore.key(mk, base)
    import dataclasses
    for field, val in (("backend", "pallas"), ("gather", "local"),
                       ("pipeline", "double"), ("interpret", False)):
        same = dataclasses.replace(base, **{field: val})
        assert PlanStore.key(mk, same) == k0, field
    for field, val in (("l", 16), ("layout", "padded"), ("c_blk", 4),
                       ("value_dtype", "int8"), ("colorer", "exact"),
                       ("load_balance", True)):
        diff = dataclasses.replace(base, **{field: val})
        assert PlanStore.key(mk, diff) != k0, field
    # and the knob list itself is the documented one
    assert set(ARTIFACT_KNOBS) == {
        "l", "colorer", "load_balance", "c_blk", "layout",
        "waste_threshold", "value_dtype", "index_dtype",
    }


# ---------------------------------------------------------------------------
# Corruption / version tolerance
# ---------------------------------------------------------------------------


def test_store_tolerates_corrupt_and_stale(tmp_path):
    dense = random_dense(6)
    store = PlanStore(str(tmp_path))
    cfg = PlanConfig(l=8, load_balance=False)
    p = plan(dense, cfg, cache=None, store=store)
    p.artifact
    key = p._store_key
    path = store._file(key)
    blob = open(path, "rb").read()

    # truncated file -> corrupt, reads as a miss, never raises
    open(path, "wb").write(blob[: len(blob) // 2])
    assert store.get(key) is None
    assert store.corrupt == 1

    # bad magic -> corrupt
    open(path, "wb").write(b"NOTAPLAN" + blob[8:])
    assert store.get(key) is None
    assert store.corrupt == 2

    # version bump -> stale (clean miss, not corrupt)
    stale = blob.replace(
        f'"format_version": {FORMAT_VERSION}'.encode(),
        f'"format_version": {FORMAT_VERSION + 1}'.encode(),
    )
    open(path, "wb").write(stale)
    assert store.get(key) is None
    assert store.stale == 1 and store.corrupt == 2

    # a re-plan rewrites the entry and the warm path recovers
    p2 = plan(dense, cfg, cache=None, store=store)
    p2.artifact
    assert store.get(key) is not None


def test_store_missing_dir_created_and_atomic_tmp_cleanup(tmp_path):
    sub = tmp_path / "a" / "b"
    store = PlanStore(str(sub))
    assert os.path.isdir(str(sub))
    dense = random_dense(7)
    p = plan(dense, PlanConfig(l=8, load_balance=False), cache=None,
             store=store)
    p.artifact
    stray = [f for f in os.listdir(str(sub)) if ".tmp." in f]
    assert stray == [], "atomic write must not leave temp files"


# ---------------------------------------------------------------------------
# Tuning persistence
# ---------------------------------------------------------------------------


def test_tune_result_persists_through_store(tmp_path):
    dense = random_dense(8)
    store = PlanStore(str(tmp_path))
    cache = ScheduleCache()
    cfg = PlanConfig(l=8, load_balance=False)
    p = plan(dense, cfg, cache=cache, store=store)
    tuned = p.tune(probe(8, dense.shape[1]), c_blks=[8], ls=[8], iters=1,
                   warmup=0)
    assert tuned.tuning is not None
    tuned.artifact  # write-behind carries the TuneResult
    warm = plan(dense, tuned.config, cache=None, store=store)
    assert warm._store_loaded
    assert warm.tuning is not None
    assert warm.tuning.choice == tuned.tuning.choice
    leaves_bitwise_equal(warm.artifact, tuned.artifact)


# ---------------------------------------------------------------------------
# New-process round trip (the CI smoke, runnable locally)
# ---------------------------------------------------------------------------


def test_store_roundtrip_new_process(tmp_path):
    dense = random_dense(9)
    np.save(str(tmp_path / "m.npy"), dense)
    store = PlanStore(str(tmp_path / "store"))
    cfg = PlanConfig(l=8, layout="ragged", load_balance=False)
    p = plan(dense, cfg, cache=None, store=store)
    y_parent = np.asarray(p.spmm(probe(9, dense.shape[1])))
    p.artifact  # ensure written
    code = (
        "import numpy as np, jax.numpy as jnp\n"
        "from repro.core.plan import PlanConfig, plan\n"
        "from repro.core.plan_store import PlanStore\n"
        "from repro.core.scheduler import sched_counters\n"
        f"dense = np.load({str(tmp_path / 'm.npy')!r})\n"
        f"store = PlanStore({str(tmp_path / 'store')!r})\n"
        "cfg = PlanConfig(l=8, layout='ragged', load_balance=False)\n"
        "p = plan(dense, cfg, cache=None, store=store)\n"
        "assert p._store_loaded, 'child must warm-start from the store'\n"
        "assert sched_counters['color_calls'] == 0\n"
        "rng = np.random.default_rng(9 + 1000)\n"
        "x = jnp.asarray(rng.standard_normal((dense.shape[1], 3))"
        ".astype(np.float32))\n"
        "np.save(" + repr(str(tmp_path / "y.npy")) + ", np.asarray(p.spmm(x)))\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
    )
    assert proc.returncode == 0, proc.stderr
    y_child = np.load(str(tmp_path / "y.npy"))
    assert np.array_equal(y_parent, y_child)


# ---------------------------------------------------------------------------
# ScheduleCache LRU bound (satellite)
# ---------------------------------------------------------------------------


def test_schedule_cache_lru_bound_and_evictions():
    cache = ScheduleCache(maxsize=2)
    for seed in range(3):
        plan(random_dense(seed + 20, m=16, n=16), PlanConfig(l=4),
             cache=cache)
    st = cache.stats()
    assert st["entries"] == 2
    assert st["evictions"] == 1
    assert cache.evictions == 1
    # LRU: the *oldest* entry was dropped; newest two still hit
    hits0 = cache.hits
    plan(random_dense(22, m=16, n=16), PlanConfig(l=4), cache=cache)
    assert cache.hits == hits0 + 1
    plan(random_dense(20, m=16, n=16), PlanConfig(l=4), cache=cache)
    assert cache.misses >= 4  # oldest was evicted -> re-scheduled
    cache.clear()
    assert cache.evictions == 0 and len(cache._store) == 0


def test_schedule_cache_maxsize_validation_and_env(monkeypatch):
    with pytest.raises(ValueError):
        ScheduleCache(maxsize=0)
    assert ScheduleCache().maxsize == DEFAULT_SCHEDULE_CACHE_SIZE
    monkeypatch.setenv("REPRO_SCHEDULE_CACHE_SIZE", "7")
    assert ScheduleCache().maxsize == 7
    assert ScheduleCache(maxsize=3).maxsize == 3  # explicit beats env


# ---------------------------------------------------------------------------
# reschedule(): incremental plans + ragged splice
# ---------------------------------------------------------------------------


def _mutate(dense, l=8, w=1, seed=0):
    rng = np.random.default_rng(seed + 500)
    new = dense.copy()
    num_windows = -(-dense.shape[0] // l)
    dirty = rng.choice(num_windows, size=w, replace=False)
    for wi in dirty:
        band = new[wi * l: (wi + 1) * l]
        band[band != 0] *= 1.25
        band[rng.integers(band.shape[0]), rng.integers(band.shape[1])] = 2.5
    return new, np.sort(dirty)


@pytest.mark.parametrize("vdt", ["float32", "int8"])
def test_reschedule_splices_ragged_bitwise(vdt):
    dense = random_dense(30)
    cfg = PlanConfig(l=8, layout="ragged", load_balance=False,
                     value_dtype=vdt)
    base = plan(dense, cfg, cache=None)
    base.artifact  # materialize so reschedule can splice
    new_dense, dirty = _mutate(dense, w=2, seed=30)
    reset_sched_counters()
    p = reschedule(base, new_dense)
    fresh = plan(new_dense, cfg, cache=None)
    r = p.resched
    assert not r.full_fallback and r.spliced
    assert r.dirty_windows <= dirty.size + 0  # content diff, not guess
    assert r.reused_windows == r.windows - r.dirty_windows
    assert r.recolored_edges < fresh.sched.nnz, \
        "incremental must recolor strictly fewer edges than a fresh plan"
    assert sched_counters["windows_recolored"] == r.dirty_windows
    leaves_bitwise_equal(p.artifact, fresh.artifact)
    x = probe(30, dense.shape[1])
    assert np.array_equal(np.asarray(p.spmm(x)), np.asarray(fresh.spmm(x)))
    # chained: the returned plan carries fingerprints forward
    third, _ = _mutate(new_dense, w=1, seed=31)
    p2 = reschedule(p, third)
    assert not p2.resched.full_fallback
    leaves_bitwise_equal(p2.artifact, plan(third, cfg, cache=None).artifact)


def test_reschedule_padded_layout_repacks_not_splices():
    dense = random_dense(32)
    cfg = PlanConfig(l=8, layout="padded", load_balance=False)
    base = plan(dense, cfg, cache=None)
    base.artifact
    new_dense, _ = _mutate(dense, seed=32)
    p = reschedule(base, new_dense)
    assert not p.resched.full_fallback and not p.resched.spliced
    fresh = plan(new_dense, cfg, cache=None)
    leaves_bitwise_equal(p.artifact, fresh.artifact)


def test_reschedule_load_balance_full_fallback():
    dense = random_dense(33)
    cfg = PlanConfig(l=8, load_balance=True)
    base = plan(dense, cfg, cache=None)
    new_dense, _ = _mutate(dense, seed=33)
    p = reschedule(base, new_dense)
    assert p.resched.full_fallback
    assert p.resched.dirty_windows == p.resched.windows
    fresh = plan(new_dense, cfg, cache=None)
    leaves_bitwise_equal(p.artifact, fresh.artifact)


def test_reschedule_writes_spliced_artifact_to_store(tmp_path):
    dense = random_dense(34)
    store = PlanStore(str(tmp_path))
    cfg = PlanConfig(l=8, layout="ragged", load_balance=False)
    base = plan(dense, cfg, cache=None, store=store)
    base.artifact
    new_dense, _ = _mutate(dense, seed=34)
    p = reschedule(base, new_dense, store=store)
    assert p.resched.spliced
    assert store.writes == 2  # base + spliced delta
    warm = plan(new_dense, cfg, cache=None, store=store)
    assert warm._store_loaded
    leaves_bitwise_equal(warm.artifact, p.artifact)


def test_reschedule_validation():
    dense = random_dense(35)
    cfg = PlanConfig(l=8, load_balance=False)
    base = plan(dense, cfg, cache=None)
    with pytest.raises(ValueError, match="shape"):
        reschedule(base, np.zeros((8, 8), np.float32))
    with pytest.raises(TypeError):
        reschedule("nope", dense)
    with pytest.raises(TypeError):
        reschedule(base, "nope")


def test_splice_rejects_mismatched_geometry():
    dense = random_dense(36)
    cfg = PlanConfig(l=8, layout="ragged", load_balance=False)
    base = plan(dense, cfg, cache=None)
    art = base.artifact
    assert isinstance(art, RaggedSchedule)
    other = plan(random_dense(37, m=24, n=48), cfg, cache=None)
    with pytest.raises(ValueError):
        splice_ragged_blocks(art, other.sched, np.array([0]))
