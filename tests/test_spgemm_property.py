"""SpGEMM property suite (PR 8): random A·B over every synth generator ×
both layouts × both backends, **bitwise** vs the dense
``dense_from_coo(A) @ dense_from_coo(B)`` reference, plus chained
``plan(A·A)`` re-planability.

The bit-identity regime is exact arithmetic: small-integer-valued f32
inputs make every product and partial sum exactly representable, so any
summation order produces identical floats and all backend/layout
combinations must equal the dense reference bit-for-bit (the ROADMAP
§SpGEMM invariant).  Arbitrary-float inputs are checked to tolerance
(merge orders differ across paths).

The deterministic sweep below always runs (hypothesis is optional in
this container, matching the existing property-suite pattern); the
hypothesis half widens the same property over random geometry when the
library is present.
"""

import numpy as np
import pytest

from repro.core.formats import COOMatrix, coo_from_dense, dense_from_coo
from repro.core.plan import PlanConfig, plan
from repro.data.matrices import (
    synth_banded,
    synth_block_diagonal,
    synth_k_regular,
    synth_power_law,
    synth_uniform,
)

GENERATORS = {
    "uniform": lambda n, seed: synth_uniform(n, 0.08, seed=seed),
    "power_law": lambda n, seed: synth_power_law(n, 0.08, seed=seed),
    "k_regular": lambda n, seed: synth_k_regular(n, 0.08, seed=seed),
    "banded": lambda n, seed: synth_banded(n, int(n * n * 0.08), seed=seed),
    "block": lambda n, seed: synth_block_diagonal(
        n, int(n * n * 0.08), num_blocks=4, seed=seed),
}
COMBOS = [(lay, be) for lay in ("padded", "ragged") for be in ("jnp", "pallas")]


def int_valued(coo: COOMatrix, seed: int) -> COOMatrix:
    """Same pattern, small-integer f32 values (exact arithmetic)."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(-4, 5, coo.nnz).astype(np.float32)
    vals[vals == 0] = 1.0
    return COOMatrix(coo.shape, coo.rows, coo.cols, vals)


def check_bitwise(A: COOMatrix, B: COOMatrix, l: int):
    ref = dense_from_coo(A) @ dense_from_coo(B)
    for layout, backend in COMBOS:
        p = plan(A, PlanConfig(l=l, layout=layout, backend=backend))
        C = p.spgemm(B)
        assert np.array_equal(dense_from_coo(C), ref), (layout, backend)
        # canonical output: deduplicated, row-sorted, no explicit zeros
        keys = C.rows * np.int64(C.shape[1]) + C.cols
        assert np.all(np.diff(keys) > 0)
        assert np.all(C.vals != 0)


@pytest.mark.parametrize("gen", sorted(GENERATORS))
def test_spgemm_bitwise_all_generators(gen):
    A = int_valued(GENERATORS[gen](24, seed=5), seed=6)
    B = int_valued(GENERATORS[gen](24, seed=7), seed=8)
    check_bitwise(A, B, l=8)


def test_spgemm_rectangular():
    rng = np.random.default_rng(0)
    da = (rng.random((19, 13)) < 0.25) * rng.integers(1, 4, (19, 13))
    db = (rng.random((13, 31)) < 0.25) * rng.integers(1, 4, (13, 31))
    check_bitwise(coo_from_dense(da.astype(np.float32)),
                  coo_from_dense(db.astype(np.float32)), l=4)


def test_spgemm_float_values_allclose():
    rng = np.random.default_rng(1)
    da = ((rng.random((20, 20)) < 0.2) * rng.standard_normal((20, 20))
          ).astype(np.float32)
    db = ((rng.random((20, 20)) < 0.2) * rng.standard_normal((20, 20))
          ).astype(np.float32)
    ref = da @ db
    for layout, backend in COMBOS:
        p = plan(da, PlanConfig(l=8, layout=layout, backend=backend))
        C = p.spgemm(coo_from_dense(db))
        np.testing.assert_allclose(dense_from_coo(C), ref, atol=1e-5)


def test_spgemm_chained_replan():
    A = int_valued(synth_power_law(24, 0.1, seed=2), seed=3)
    ref2 = dense_from_coo(A) @ dense_from_coo(A)
    p = plan(A, PlanConfig(l=8))
    AA = p.spgemm(p)  # plan accepted as the B operand
    assert np.array_equal(dense_from_coo(AA), ref2)
    # the sparse result is a first-class planner input: plan and execute
    p2 = plan(AA, PlanConfig(l=8))
    v = np.arange(24, dtype=np.float32) % 5 - 2
    assert np.array_equal(np.asarray(p2.spmv(v)), ref2 @ v)
    # and chains again: (A·A)·A bitwise vs dense
    AAA = p2.spgemm(A)
    assert np.array_equal(dense_from_coo(AAA), ref2 @ dense_from_coo(A))


def test_spgemm_empty_and_empty_rows():
    A = int_valued(synth_uniform(16, 0.1, seed=4), seed=5)
    empty_b = COOMatrix((16, 9), np.zeros(0, np.int64), np.zeros(0, np.int64),
                        np.zeros(0, np.float32))
    C = plan(A, PlanConfig(l=8)).spgemm(empty_b)
    assert C.shape == (16, 9) and C.nnz == 0
    # B with many empty rows (only row 3 populated)
    b = COOMatrix((16, 6), np.array([3, 3], np.int64),
                  np.array([0, 5], np.int64), np.array([2.0, 3.0], np.float32))
    check_bitwise(A, b, l=8)


def test_spgemm_validation():
    A = int_valued(synth_uniform(16, 0.1, seed=6), seed=7)
    p = plan(A, PlanConfig(l=8))
    with pytest.raises(ValueError, match="shape mismatch"):
        p.spgemm(COOMatrix((9, 9), np.zeros(0, np.int64),
                           np.zeros(0, np.int64), np.zeros(0, np.float32)))
    with pytest.raises(TypeError):
        p.spgemm("not a matrix")
    p8 = plan(A, PlanConfig(l=8, value_dtype="int8"))
    with pytest.raises(ValueError, match="quantized"):
        p8.spgemm(A)


def test_spgemm_cost_surface():
    A = int_valued(synth_uniform(32, 0.1, seed=8), seed=9)
    p = plan(A, PlanConfig(l=8))
    cost = p.spgemm_cost(A)
    b_row_nnz = A.row_nnz()
    assert cost.products == int(b_row_nnz[A.cols].sum())
    assert cost.spgemm_flops == 2 * cost.products
    assert cost.dense_flops == 2 * 32 * 32 * 32
    assert cost.scratch_bytes == 8 * 32 * 4  # (l, n_out) f32
    assert cost.k_max == int(b_row_nnz.max())
    C = p.spgemm(A)
    # the balls-in-bins estimate brackets the actual output nnz loosely
    assert 0 < cost.out_nnz_estimate <= 32 * 32
    assert cost.out_nnz_estimate >= C.nnz // 4
    # scheduling stayed content-keyed: spgemm added no cache identity
    d = cost.to_dict()
    assert {"products", "out_nnz_estimate", "scratch_bytes",
            "b_condensed_bytes", "flop_reduction"} <= set(d)


def test_spgemm_does_not_disturb_schedule_cache():
    from repro.core.packing import default_cache

    A = int_valued(synth_uniform(20, 0.1, seed=10), seed=11)
    p = plan(A, PlanConfig(l=8))
    p.artifact  # materialize the lazy pack (plan A's own cache entry)
    before = default_cache.stats()["entries"]
    p.spgemm(A)
    p.spgemm_cost(A)
    # SpGEMM reuses plan A's schedule; it neither schedules B nor adds
    # plan-cache entries of its own
    assert default_cache.stats()["entries"] == before


# -- hypothesis half (optional, widens the same property) -------------------

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


if HAS_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        m=st.integers(2, 28),
        k=st.integers(2, 24),
        n=st.integers(2, 28),
        density=st.sampled_from([0.1, 0.3]),
        l=st.sampled_from([4, 8]),
        seed=st.integers(0, 10_000),
    )
    def test_spgemm_bitwise_property(m, k, n, density, l, seed):
        rng = np.random.default_rng(seed)
        da = (rng.random((m, k)) < density) * rng.integers(1, 5, (m, k))
        db = (rng.random((k, n)) < density) * rng.integers(1, 5, (k, n))
        check_bitwise(coo_from_dense(da.astype(np.float32)),
                      coo_from_dense(db.astype(np.float32)), l=l)
