"""Serving-layer tests: continuous-batching loop, GUST-sparse decode
(identity at density 1.0, Pallas/XLA parity), GustLinear, cache sizing."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core.gust_linear import GustLinear, SparsityConfig, prune_by_magnitude
from repro.models.model_zoo import build_model
from repro.serving import (
    CachePolicy,
    GustServeConfig,
    ServeConfig,
    ServeLoop,
    cache_bytes,
)
from repro.serving.gust_serve import decode_step_gust, dryrun_specs, gustify

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense_lm():
    cfg = get_arch("yi_6b").reduced()
    lm = build_model(cfg)
    return lm, lm.init(KEY)


def test_serve_loop_generates(dense_lm):
    lm, params = dense_lm
    loop = ServeLoop(lm, params, ServeConfig(batch=4, seq_len=64, dtype="float32"))
    rid = loop.submit(np.arange(8, dtype=np.int32), max_new=5)
    loop.run_to_completion()
    out = loop.completed[rid]
    assert len(out) == 6  # first sampled token + 5 decode steps
    assert all(0 <= t < lm.cfg.padded_vocab for t in out)


def test_serve_loop_deterministic_greedy(dense_lm):
    lm, params = dense_lm
    outs = []
    for _ in range(2):
        loop = ServeLoop(lm, params, ServeConfig(batch=2, seq_len=64, dtype="float32"))
        rid = loop.submit(np.arange(6, dtype=np.int32), max_new=4)
        loop.run_to_completion()
        outs.append(loop.completed[rid])
    assert outs[0] == outs[1]


def test_gust_decode_identity_at_full_density(dense_lm):
    lm, params = dense_lm
    gcfg = GustServeConfig(density=1.0, gust_length=16)
    gust = gustify(lm, params, gcfg)
    caches = lm.init_caches(2, 64, jnp.float32)
    toks = jnp.tile(jnp.arange(8, dtype=jnp.int32)[None], (2, 1))
    _, caches = lm.prefill(params, {"tokens": toks}, caches, dtype=jnp.float32)
    tok = jnp.full((2, 1), 3, jnp.int32)
    ld, _ = lm.decode_step(params, caches, tok, jnp.int32(8), dtype=jnp.float32)
    lg, _ = decode_step_gust(lm, params, gust, caches, tok, jnp.int32(8),
                             cfg=gcfg, dtype=jnp.float32)
    err = np.abs(np.asarray(ld) - np.asarray(lg)).max() / np.abs(np.asarray(ld)).max()
    assert err < 1e-4, err
    # full density -> every scheduled slot is a real nonzero along rows
    for st in gust["stats"].values():
        assert st["stream_utilization"] > 0.5


def test_gust_decode_pallas_xla_parity(dense_lm):
    lm, params = dense_lm
    gcfg_x = GustServeConfig(density=0.3, gust_length=16, use_kernel=False)
    gcfg_k = GustServeConfig(density=0.3, gust_length=16, use_kernel=True)
    gust = gustify(lm, params, gcfg_x)
    caches = lm.init_caches(2, 64, jnp.float32)
    toks = jnp.tile(jnp.arange(8, dtype=jnp.int32)[None], (2, 1))
    _, caches = lm.prefill(params, {"tokens": toks}, caches, dtype=jnp.float32)
    tok = jnp.full((2, 1), 3, jnp.int32)
    lx, _ = decode_step_gust(lm, params, gust, caches, tok, jnp.int32(8),
                             cfg=gcfg_x, dtype=jnp.float32)
    lk, _ = decode_step_gust(lm, params, gust, caches, tok, jnp.int32(8),
                             cfg=gcfg_k, dtype=jnp.float32)
    err = np.abs(np.asarray(lx) - np.asarray(lk)).max() / np.abs(np.asarray(lx)).max()
    assert err < 1e-4, err


def test_gust_serve_loop_end_to_end(dense_lm):
    lm, params = dense_lm
    sc = ServeConfig(batch=2, seq_len=64, dtype="float32",
                     gust=GustServeConfig(density=0.5, gust_length=16))
    loop = ServeLoop(lm, params, sc)
    rid = loop.submit(np.arange(8, dtype=np.int32), max_new=4)
    loop.run_to_completion()
    assert len(loop.completed[rid]) == 5


def test_dryrun_specs_shapes(dense_lm):
    lm, _ = dense_lm
    gcfg = GustServeConfig(density=0.1, gust_length=16)
    specs = dryrun_specs(lm, gcfg)
    for name, entry in specs["mats"].items():
        l, w, c_pad, shape, fusable = entry["meta"]
        assert fusable and l == 16
        m_blk = entry["leaves"]["m_blk"]
        assert m_blk.shape == (lm.stack.reps, w * c_pad, l)


def test_gust_linear_vs_dense():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((48, 64)).astype(np.float32)
    x = rng.standard_normal((5, 64)).astype(np.float32)
    gl = GustLinear(w, SparsityConfig(enable=True, density=1.0, gust_length=8))
    y = np.asarray(gl(jnp.asarray(x)))
    np.testing.assert_allclose(y, x @ w.T, rtol=1e-4, atol=1e-4)
    # pruned version equals dense with pruned weights
    gl2 = GustLinear(w, SparsityConfig(enable=True, density=0.25, gust_length=8))
    wp = prune_by_magnitude(w, 0.25)
    y2 = np.asarray(gl2(jnp.asarray(x)))
    np.testing.assert_allclose(y2, x @ wp.T, rtol=1e-4, atol=1e-4)
    assert gl2.nnz <= int(w.size * 0.25) + 1


def test_gust_linear_use_kernel_regression():
    """Regression: use_kernel=True used to pass the ragged GustSchedule to
    kops.gust_spmm (which requires a PackedSchedule) and crash.  Both
    execution paths must run and agree with the pruned dense product."""
    rng = np.random.default_rng(1)
    w = rng.standard_normal((48, 64)).astype(np.float32)
    x = rng.standard_normal((5, 64)).astype(np.float32)
    wp = prune_by_magnitude(w, 0.25)
    ys = {}
    for uk in (False, True):
        gl = GustLinear(w, SparsityConfig(enable=True, density=0.25,
                                          gust_length=8, use_kernel=uk))
        assert gl.packed.fusable
        ys[uk] = np.asarray(gl(jnp.asarray(x)))
        np.testing.assert_allclose(ys[uk], x @ wp.T, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ys[True], ys[False], rtol=1e-5, atol=1e-5)


def test_cache_bytes_accounting():
    cfg = get_arch("yi_6b").reduced()
    lm = build_model(cfg)
    n = cache_bytes(lm, batch=2, seq_len=64, policy=CachePolicy(dtype="bfloat16"))
    # 3 layers(reduced) x k/v (2, 64, 2, 16) bf16 + pos
    assert n > 0
    n32 = cache_bytes(lm, batch=2, seq_len=64, policy=CachePolicy(dtype="float32"))
    assert n32 > n
