"""Serving-layer tests: continuous-batching loop (per-slot prefill +
per-slot positions: concurrent mixed-length serving is bit-identical per
request to solo serving), GUST-sparse decode (identity at density 1.0,
Pallas/XLA parity), GustLinear, cache sizing."""

import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core.gust_linear import GustLinear, SparsityConfig, prune_by_magnitude
from repro.models.model_zoo import build_model
from repro.serving import (
    CachePolicy,
    GustServeConfig,
    ServeConfig,
    ServeLoop,
    cache_bytes,
    cache_specs,
    make_sampler,
)
from repro.serving.gust_serve import decode_step_gust, dryrun_specs, gustify

KEY = jax.random.PRNGKey(0)


def _solo(lm, params, prompt, max_new, *, batch=4, seq_len=64, gust=None):
    """Serve one request alone on an otherwise-idle engine."""
    sc = ServeConfig(batch=batch, seq_len=seq_len, dtype="float32", gust=gust)
    loop = ServeLoop(lm, params, sc)
    rid = loop.submit(np.asarray(prompt, np.int32), max_new=max_new)
    loop.run_to_completion()
    return loop.completed[rid]


@pytest.fixture(scope="module")
def dense_lm():
    cfg = get_arch("yi_6b").reduced()
    lm = build_model(cfg)
    return lm, lm.init(KEY)


def test_serve_loop_generates(dense_lm):
    lm, params = dense_lm
    loop = ServeLoop(lm, params, ServeConfig(batch=4, seq_len=64, dtype="float32"))
    rid = loop.submit(np.arange(8, dtype=np.int32), max_new=5)
    loop.run_to_completion()
    out = loop.completed[rid]
    assert len(out) == 6  # first sampled token + 5 decode steps
    assert all(0 <= t < lm.cfg.padded_vocab for t in out)


def test_serve_loop_deterministic_greedy(dense_lm):
    lm, params = dense_lm
    outs = []
    for _ in range(2):
        loop = ServeLoop(lm, params, ServeConfig(batch=2, seq_len=64, dtype="float32"))
        rid = loop.submit(np.arange(6, dtype=np.int32), max_new=4)
        loop.run_to_completion()
        outs.append(loop.completed[rid])
    assert outs[0] == outs[1]


def test_gust_decode_identity_at_full_density(dense_lm):
    lm, params = dense_lm
    gcfg = GustServeConfig(density=1.0, gust_length=16)
    gust = gustify(lm, params, gcfg)
    caches = lm.init_caches(2, 64, jnp.float32)
    toks = jnp.tile(jnp.arange(8, dtype=jnp.int32)[None], (2, 1))
    _, caches = lm.prefill(params, {"tokens": toks}, caches, dtype=jnp.float32)
    tok = jnp.full((2, 1), 3, jnp.int32)
    ld, _ = lm.decode_step(params, caches, tok, jnp.int32(8), dtype=jnp.float32)
    lg, _ = decode_step_gust(lm, params, gust, caches, tok, jnp.int32(8),
                             cfg=gcfg, dtype=jnp.float32)
    err = np.abs(np.asarray(ld) - np.asarray(lg)).max() / np.abs(np.asarray(ld)).max()
    assert err < 1e-4, err
    # full density -> every scheduled slot is a real nonzero along rows
    for st in gust["stats"].values():
        assert st["stream_utilization"] > 0.5


def test_gust_decode_pallas_xla_parity(dense_lm):
    lm, params = dense_lm
    gcfg_x = GustServeConfig(density=0.3, gust_length=16, use_kernel=False)
    gcfg_k = GustServeConfig(density=0.3, gust_length=16, use_kernel=True)
    gust = gustify(lm, params, gcfg_x)
    caches = lm.init_caches(2, 64, jnp.float32)
    toks = jnp.tile(jnp.arange(8, dtype=jnp.int32)[None], (2, 1))
    _, caches = lm.prefill(params, {"tokens": toks}, caches, dtype=jnp.float32)
    tok = jnp.full((2, 1), 3, jnp.int32)
    lx, _ = decode_step_gust(lm, params, gust, caches, tok, jnp.int32(8),
                             cfg=gcfg_x, dtype=jnp.float32)
    lk, _ = decode_step_gust(lm, params, gust, caches, tok, jnp.int32(8),
                             cfg=gcfg_k, dtype=jnp.float32)
    err = np.abs(np.asarray(lx) - np.asarray(lk)).max() / np.abs(np.asarray(lx)).max()
    assert err < 1e-4, err


def test_gust_serve_loop_end_to_end(dense_lm):
    lm, params = dense_lm
    sc = ServeConfig(batch=2, seq_len=64, dtype="float32",
                     gust=GustServeConfig(density=0.5, gust_length=16))
    loop = ServeLoop(lm, params, sc)
    rid = loop.submit(np.arange(8, dtype=np.int32), max_new=4)
    loop.run_to_completion()
    assert len(loop.completed[rid]) == 5


def test_dryrun_specs_shapes(dense_lm):
    lm, _ = dense_lm
    gcfg = GustServeConfig(density=0.1, gust_length=16)
    specs = dryrun_specs(lm, gcfg)
    for name, entry in specs["mats"].items():
        (l, w, c_pad, shape, fusable, c_blk, s_blk,
         identity_perm) = entry["meta"]
        assert fusable and l == 16
        m_blk = entry["leaves"]["m_blk"]
        assert m_blk.shape == (lm.stack.reps, w * c_pad, l)
        assert entry["leaves"]["seg_blk"].shape[-1] == s_blk


def test_gust_linear_vs_dense():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((48, 64)).astype(np.float32)
    x = rng.standard_normal((5, 64)).astype(np.float32)
    gl = GustLinear(w, SparsityConfig(enable=True, density=1.0, gust_length=8))
    y = np.asarray(gl(jnp.asarray(x)))
    np.testing.assert_allclose(y, x @ w.T, rtol=1e-4, atol=1e-4)
    # pruned version equals dense with pruned weights
    gl2 = GustLinear(w, SparsityConfig(enable=True, density=0.25, gust_length=8))
    wp = prune_by_magnitude(w, 0.25)
    y2 = np.asarray(gl2(jnp.asarray(x)))
    np.testing.assert_allclose(y2, x @ wp.T, rtol=1e-4, atol=1e-4)
    assert gl2.nnz <= int(w.size * 0.25) + 1


def test_gust_linear_use_kernel_regression():
    """Regression: use_kernel=True used to pass the ragged GustSchedule to
    kops.gust_spmm (which requires a PackedSchedule) and crash.  Both
    execution paths must run and agree with the pruned dense product."""
    rng = np.random.default_rng(1)
    w = rng.standard_normal((48, 64)).astype(np.float32)
    x = rng.standard_normal((5, 64)).astype(np.float32)
    wp = prune_by_magnitude(w, 0.25)
    ys = {}
    for uk in (False, True):
        gl = GustLinear(w, SparsityConfig(enable=True, density=0.25,
                                          gust_length=8, use_kernel=uk))
        assert gl.packed.fusable
        ys[uk] = np.asarray(gl(jnp.asarray(x)))
        np.testing.assert_allclose(ys[uk], x @ wp.T, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ys[True], ys[False], rtol=1e-5, atol=1e-5)


def test_second_admission_mid_decode_is_isolated(dense_lm):
    """Regression (ISSUE 4 bug 1): admitting request B while request A is
    mid-decode must not touch A's KV cache.  The old full-batch prefill
    clobbered every slot with B's padded prompt; per-slot prefill writes
    only B's batch row, so A's continuation is bit-identical to solo."""
    lm, params = dense_lm
    pa = np.arange(8, dtype=np.int32)
    pb = np.arange(3, 8, dtype=np.int32)
    solo_a = _solo(lm, params, pa, max_new=8)
    solo_b = _solo(lm, params, pb, max_new=6)
    loop = ServeLoop(lm, params, ServeConfig(batch=4, seq_len=64, dtype="float32"))
    ra = loop.submit(pa, max_new=8)
    for _ in range(3):  # A is now mid-decode
        loop.step()
    rb = loop.submit(pb, max_new=6)
    loop.run_to_completion()
    assert loop.completed[ra] == solo_a
    assert loop.completed[rb] == solo_b


def test_mixed_length_concurrent_matches_solo(dense_lm):
    """Regression (ISSUE 4 bug 2): slots with different prompt lengths
    decode at their OWN positions.  The old step() decoded everyone at
    max(slot.pos), corrupting every shorter request."""
    lm, params = dense_lm
    prompts = [np.arange(5, dtype=np.int32),
               np.arange(11, dtype=np.int32),
               np.arange(2, 9, dtype=np.int32)]
    solos = [_solo(lm, params, p, max_new=6) for p in prompts]
    loop = ServeLoop(lm, params, ServeConfig(batch=4, seq_len=64, dtype="float32"))
    rids = [loop.submit(p, max_new=6) for p in prompts]
    loop.run_to_completion()
    for rid, solo in zip(rids, solos):
        assert loop.completed[rid] == solo


def test_gust_mixed_length_concurrent_matches_solo(dense_lm):
    """The GUST decode path runs through the same per-slot machinery."""
    lm, params = dense_lm
    gcfg = GustServeConfig(density=0.5, gust_length=16)
    prompts = [np.arange(4, dtype=np.int32), np.arange(9, dtype=np.int32)]
    solos = [_solo(lm, params, p, max_new=4, batch=2, gust=gcfg) for p in prompts]
    sc = ServeConfig(batch=2, seq_len=64, dtype="float32", gust=gcfg)
    loop = ServeLoop(lm, params, sc)
    rids = [loop.submit(p, max_new=4) for p in prompts]
    loop.run_to_completion()
    for rid, solo in zip(rids, solos):
        assert loop.completed[rid] == solo


def test_queue_admission_drains_stream(dense_lm):
    """Bounded admission queue: more requests than slots drain through
    step() with no manual slot management; capacity overflow load-sheds
    the newest request as a structured SHED result, not an exception."""
    lm, params = dense_lm
    sc = ServeConfig(batch=2, seq_len=64, dtype="float32", queue_capacity=6)
    loop = ServeLoop(lm, params, sc)
    rng = np.random.default_rng(0)
    rids = [loop.enqueue(rng.integers(0, lm.cfg.vocab, 3 + r).astype(np.int32),
                         max_new=3) for r in range(6)]
    shed_rid = loop.enqueue(np.arange(4, dtype=np.int32), max_new=1)
    shed = loop.results[shed_rid]
    assert shed.status.name == "SHED" and "queue full" in shed.reason
    assert loop.stats["shed"] == 1
    loop.run_to_completion()
    assert not loop.pending
    assert sorted(loop.completed) == sorted(rids)
    assert all(len(loop.completed[r]) == 4 for r in rids)
    # 6 requests on 2 slots: at least 3 waves of decode, fully occupied
    assert loop.stats["prefills"] == 6
    assert loop.occupancy > 0.9


def test_eos_retirement(dense_lm):
    """A slot retires as soon as it samples eos_id."""
    lm, params = dense_lm
    prompt = np.arange(7, dtype=np.int32)
    full = _solo(lm, params, prompt, max_new=8)
    eos = full[2]
    k = full.index(eos)  # first time greedy decode emits it
    sc = ServeConfig(batch=2, seq_len=64, dtype="float32", eos_id=int(eos))
    loop = ServeLoop(lm, params, sc)
    rid = loop.submit(prompt, max_new=8)
    loop.run_to_completion()
    assert loop.completed[rid] == full[: k + 1]


def test_sampler_max_subtracted_large_logits():
    """Regression: the host sampler did np.exp(logits / T) and produced
    inf/NaN for |logits| ~ 1e3.  The on-device sampler is max-subtracted:
    huge logits sample fine, and the argmax-dominant token wins."""
    sampler = make_sampler(1.0)
    logits = jnp.asarray([[1000.0, 0.0, -500.0],
                          [2000.0, 2000.0 - 30.0, 0.0]], jnp.float32)
    rid_step = jnp.asarray([[0, 0], [1, 5]], jnp.int32)
    for seed in range(8):
        out = np.asarray(sampler(logits, jax.random.PRNGKey(seed), rid_step))
        assert out.shape == (2,) and out.dtype == np.int32
        # p(other) ~ e^-1000 and e^-30: the dominant logit must win
        assert out[0] == 0 and out[1] == 0
    greedy = make_sampler(0.0)
    out = np.asarray(greedy(logits, jax.random.PRNGKey(0), rid_step))
    np.testing.assert_array_equal(out, [0, 0])


def test_temperature_serving_is_reproducible(dense_lm):
    """Per-(request, token) sampling keys: same seed -> same stream, and
    a request's sampled continuation doesn't depend on co-scheduling."""
    lm, params = dense_lm
    sc = ServeConfig(batch=2, seq_len=64, dtype="float32", temperature=0.8)
    outs = []
    for _ in range(2):
        loop = ServeLoop(lm, params, sc, seed=7)
        rid = loop.submit(np.arange(6, dtype=np.int32), max_new=5)
        loop.run_to_completion()
        outs.append(loop.completed[rid])
    assert outs[0] == outs[1]
    assert all(0 <= t < lm.cfg.padded_vocab for t in outs[0])


def test_cache_bytes_accounting():
    cfg = get_arch("yi_6b").reduced()
    lm = build_model(cfg)
    n = cache_bytes(lm, batch=2, seq_len=64, policy=CachePolicy(dtype="bfloat16"))
    # 3 layers(reduced) x k/v (2, 64, 2, 16) bf16 + pos
    assert n > 0
    n32 = cache_bytes(lm, batch=2, seq_len=64, policy=CachePolicy(dtype="float32"))
    assert n32 > n


def test_cache_bytes_no_int32_overflow_at_123b_scale():
    """Regression: jnp.prod(jnp.array(shape)) overflowed int32 above 2**31
    elements per leaf.  The 123B config at serving shapes crosses that;
    accounting must match an independent host-side math.prod sum."""
    lm = build_model(get_arch("mistral_large_123b"))
    batch, seq = 8, 32_768
    n = cache_bytes(lm, batch=batch, seq_len=seq)
    expect = sum(
        jnp.dtype(x.dtype).itemsize * math.prod(x.shape)
        for x in jax.tree.leaves(cache_specs(lm, batch, seq))
    )
    assert n == expect
    assert n > 2**31  # the overflow regime: old code went negative/garbage
    assert n % 2 == 0  # bf16 leaves: whole itemsize multiples
