"""Ragged color-block streaming: equivalence + format invariants.

The ragged stream (``core/packing.pack_ragged``) must execute *exactly*
the same math as the padded layout while streaming only real blocks:

  * property test (hypothesis, random + power-law degree matrices, all
    three colorers): ``gust_spmm`` output is **bit-identical** between
    the padded and ragged paths — kernel vs kernel and oracle vs oracle
    (kernel vs oracle stays allclose: the one-hot routing matmul reduces
    in a different order than segment-sum);
  * block-metadata contract: contiguous sorted ``block_window``, per-
    window prefix ``block_starts``, >= 1 block per window, padding slots
    keep the packed-format invariants in each window's final partial
    block;
  * ``pack_auto`` picks by the measured waste ratio; ``gust_spmm_auto``
    routes through the content-keyed cache; kernel builders are memoized
    on geometry.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.formats import coo_from_dense
from repro.core.packing import (
    PackedSchedule,
    RaggedSchedule,
    ScheduleCache,
    pack_auto,
    pack_ragged,
    pack_schedule,
    ragged_from_leaves,
    ragged_leaves,
    ragged_meta,
    ragged_waste_ratio,
)
from repro.core.scheduler import schedule
from repro.core.spmv import spmm_ragged
from repro.kernels.ops import gust_spmm, gust_spmm_auto


def random_dense(rng, m, n, density):
    return ((rng.random((m, n)) < density) * rng.standard_normal((m, n))).astype(
        np.float32
    )


def power_law_dense(rng, m, n, base_density=0.03, heavy_rows=4,
                    heavy_density=0.6):
    """Skewed (power-law-degree surrogate): a few dense rows on a sparse
    background — max window colors far above the mean, the regime where
    the padded layout streams mostly dead cycles."""
    dense = random_dense(rng, m, n, base_density)
    k = min(heavy_rows, m)
    rows = rng.choice(m, k, replace=False)
    dense[rows] = (rng.random((k, n)) < heavy_density) * rng.standard_normal(
        (k, n)
    )
    return dense.astype(np.float32)


def all_paths(sched, x, c_blk=8):
    """y from all four execution paths on one schedule."""
    p = pack_schedule(sched, c_blk)
    r = pack_ragged(sched, c_blk)
    xs = jnp.asarray(x)
    return {
        "pad_kernel": np.asarray(gust_spmm(p, xs, use_kernel=True, c_blk=c_blk)),
        "rag_kernel": np.asarray(gust_spmm(r, xs, use_kernel=True)),
        "pad_xla": np.asarray(gust_spmm(p, xs, use_kernel=False, c_blk=c_blk)),
        "rag_xla": np.asarray(gust_spmm(r, xs, use_kernel=False)),
    }, p, r


def assert_equivalent(ys, ref):
    assert np.array_equal(ys["pad_kernel"], ys["rag_kernel"]), \
        "padded vs ragged kernel not bit-identical"
    assert np.array_equal(ys["pad_xla"], ys["rag_xla"]), \
        "padded vs ragged oracle not bit-identical"
    for k, y in ys.items():
        np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-4, err_msg=k)


# ---------------------------------------------------------------------------
# equivalence sweeps
# ---------------------------------------------------------------------------


SHAPE_SWEEP = [
    # (m, n, l, B, density)
    (16, 64, 8, 1, 0.1),
    (64, 48, 16, 4, 0.2),
    (100, 130, 32, 8, 0.05),  # non-divisible m, n
    (33, 7, 8, 2, 0.5),  # n < l
]


@pytest.mark.parametrize("m,n,l,b,density", SHAPE_SWEEP)
@pytest.mark.parametrize("lb", [False, True])
def test_ragged_vs_padded_sweep(m, n, l, b, density, lb):
    rng = np.random.default_rng(m * 1000 + n)
    dense = random_dense(rng, m, n, density)
    x = rng.standard_normal((n, b)).astype(np.float32)
    sched = schedule(coo_from_dense(dense), l, load_balance=lb)
    ys, _, r = all_paths(sched, x)
    assert r.fusable
    assert_equivalent(ys, dense @ x)


def test_ragged_power_law_streams_fewer_blocks():
    """On the skewed surrogate the ragged stream must be >= 2x smaller
    while remaining bit-identical (the ISSUE 2 acceptance shape)."""
    rng = np.random.default_rng(0)
    dense = power_law_dense(rng, 128, 128, heavy_rows=6)
    x = rng.standard_normal((128, 3)).astype(np.float32)
    sched = schedule(coo_from_dense(dense), 8)
    cpw = np.diff(sched.window_starts)
    assert cpw.max() / max(cpw.mean(), 1e-9) >= 4, "surrogate not skewed"
    ys, p, r = all_paths(sched, x)
    assert_equivalent(ys, dense @ x)
    assert p.m_blk.shape[0] >= 2 * r.m_blk.shape[0], (
        p.m_blk.shape, r.m_blk.shape
    )
    assert ragged_waste_ratio(sched) >= 2.0


@pytest.mark.parametrize("lb", [False, True])
def test_ragged_empty_windows_and_empty_matrix(lb):
    rng = np.random.default_rng(7)
    dense = np.zeros((32, 40), np.float32)
    for row in list(range(0, 8)) + list(range(16, 24)):
        cols = rng.choice(40, 5, replace=False)
        dense[row, cols] = rng.standard_normal(5)
    for d in (dense, np.zeros((24, 16), np.float32)):
        sched = schedule(coo_from_dense(d), 8, load_balance=lb)
        x = rng.standard_normal((d.shape[1], 2)).astype(np.float32)
        ys, _, r = all_paths(sched, x)
        assert_equivalent(ys, d @ x)
        # empty windows still own exactly one (all-padding) block
        assert np.all(np.diff(np.asarray(r.block_starts)) >= 1)


@pytest.mark.parametrize("value_dtype,index_dtype",
                         [(jnp.float32, jnp.int32), (jnp.bfloat16, jnp.int16)])
def test_ragged_dtype_variants(value_dtype, index_dtype):
    rng = np.random.default_rng(3)
    dense = random_dense(rng, 48, 64, 0.2)
    x = rng.standard_normal((64, 2)).astype(np.float32)
    sched = schedule(coo_from_dense(dense), 16)
    r = pack_ragged(sched, value_dtype=value_dtype, index_dtype=index_dtype)
    assert r.m_blk.dtype == jnp.dtype(value_dtype)
    assert r.col_blk.dtype == jnp.dtype(index_dtype)
    p = pack_schedule(sched, value_dtype=value_dtype, index_dtype=index_dtype)
    for uk in (False, True):
        yr = np.asarray(gust_spmm(r, jnp.asarray(x), use_kernel=uk))
        yp = np.asarray(gust_spmm(p, jnp.asarray(x), use_kernel=uk))
        assert np.array_equal(yr, yp)


# ---------------------------------------------------------------------------
# format invariants + metadata contract
# ---------------------------------------------------------------------------


def test_ragged_block_metadata_contract():
    rng = np.random.default_rng(1)
    dense = power_law_dense(rng, 64, 64)
    sched = schedule(coo_from_dense(dense), 8)
    r = pack_ragged(sched, c_blk=8)
    bs = np.asarray(r.block_starts)
    bw = np.asarray(r.block_window)
    cpw = np.diff(sched.window_starts)
    # prefix structure, >= 1 block per window, counts match ceil(C_w/c_blk)
    assert bs[0] == 0 and bs[-1] == r.num_blocks
    bpw = np.diff(bs)
    assert np.all(bpw == np.maximum(-(-cpw // r.c_blk), 1))
    # block_window is the expansion of the prefix (sorted, contiguous)
    assert np.array_equal(bw, np.repeat(np.arange(r.num_windows), bpw))
    # padding slots in each window's final partial block keep the packed-
    # format invariants: value 0, col == own lane, row 0
    m_s = np.asarray(r.m_blk)
    c_s = np.asarray(r.col_blk)
    r_s = np.asarray(r.row_blk)
    lane = np.arange(r.l, dtype=np.int32)
    for w in range(r.num_windows):
        pad_lo = int(bs[w]) * r.c_blk + int(cpw[w])
        pad_hi = int(bs[w + 1]) * r.c_blk
        assert np.all(m_s[pad_lo:pad_hi] == 0.0)
        assert np.all(c_s[pad_lo:pad_hi] == lane)
        assert np.all(r_s[pad_lo:pad_hi] == 0)


def test_repad_to_blocks_invariants_and_numerics():
    rng = np.random.default_rng(11)
    dense = random_dense(rng, 40, 56, 0.25)
    x = rng.standard_normal((56, 3)).astype(np.float32)
    sched = schedule(coo_from_dense(dense), 8)
    r = pack_ragged(sched)
    g = r.repad_to_blocks(r.num_blocks + 4)
    assert g.num_blocks == r.num_blocks + 4
    rows0 = r.num_blocks * r.c_blk
    assert np.all(np.asarray(g.m_blk)[rows0:] == 0.0)
    assert np.all(np.asarray(g.col_blk)[rows0:] == np.arange(g.l))
    assert np.all(np.asarray(g.row_blk)[rows0:] == 0)
    assert np.asarray(g.block_starts)[-1] == g.num_blocks
    # trailing blocks attribute to the last window; stream stays sorted
    assert np.all(np.diff(np.asarray(g.block_window)) >= 0)
    for uk in (False, True):
        ya = np.asarray(gust_spmm(r, jnp.asarray(x), use_kernel=uk))
        yb = np.asarray(gust_spmm(g, jnp.asarray(x), use_kernel=uk))
        assert np.array_equal(ya, yb)
    assert r.repad_to_blocks(r.num_blocks) is r
    with pytest.raises(ValueError):
        r.repad_to_blocks(r.num_blocks - 1)


def test_ragged_compact_repad_preserves_dtypes():
    rng = np.random.default_rng(2)
    sched = schedule(coo_from_dense(random_dense(rng, 48, 64, 0.2)), 16)
    r = pack_ragged(sched, value_dtype=jnp.bfloat16, index_dtype=jnp.int16)
    g = r.repad_to_blocks(r.num_blocks + 2)
    assert g.m_blk.dtype == jnp.bfloat16
    assert g.col_blk.dtype == jnp.int16 and g.row_blk.dtype == jnp.int16


def test_ragged_codec_round_trip():
    rng = np.random.default_rng(6)
    sched = schedule(coo_from_dense(random_dense(rng, 30, 44, 0.15)), 8)
    r = pack_ragged(sched)
    q = ragged_from_leaves(ragged_leaves(r), ragged_meta(r))
    assert isinstance(q, RaggedSchedule)
    assert ragged_meta(q) == ragged_meta(r)
    for k, v in ragged_leaves(r).items():
        assert np.array_equal(np.asarray(getattr(q, k)), np.asarray(v))
    with pytest.raises(ValueError):
        ragged_from_leaves(ragged_leaves(r), ("padded",) + ragged_meta(r)[1:])


def test_spmm_ragged_matches_dense():
    rng = np.random.default_rng(4)
    dense = power_law_dense(rng, 64, 48)
    x = rng.standard_normal((48, 5)).astype(np.float32)
    sched = schedule(coo_from_dense(dense), 8)
    y = np.asarray(spmm_ragged(pack_ragged(sched), jnp.asarray(x)))
    np.testing.assert_allclose(y, dense @ x, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# auto-select + caching
# ---------------------------------------------------------------------------


def test_pack_auto_selects_by_waste():
    rng = np.random.default_rng(5)
    skewed = power_law_dense(rng, 128, 128, heavy_rows=6)
    s_skew = schedule(coo_from_dense(skewed), 8)
    assert isinstance(pack_auto(s_skew), RaggedSchedule)
    # near-uniform windows -> negligible waste -> padded layout
    uniform = random_dense(rng, 64, 64, 0.3)
    s_uni = schedule(coo_from_dense(uniform), 8)
    assert ragged_waste_ratio(s_uni) < 2.0
    assert isinstance(pack_auto(s_uni), PackedSchedule)
    # threshold is respected
    assert isinstance(
        pack_auto(s_skew, waste_threshold=1e9), PackedSchedule
    )


def test_gust_spmm_auto_routes_through_cache():
    rng = np.random.default_rng(8)
    dense = power_law_dense(rng, 64, 64)
    x = rng.standard_normal((64, 2)).astype(np.float32)
    sched = schedule(coo_from_dense(dense), 8)
    cache = ScheduleCache()
    y1 = np.asarray(gust_spmm_auto(sched, jnp.asarray(x), use_kernel=False,
                                   cache=cache))
    assert cache.misses == 1 and cache.hits == 0
    y2 = np.asarray(gust_spmm_auto(sched, jnp.asarray(x), use_kernel=False,
                                   cache=cache))
    assert cache.hits == 1
    assert np.array_equal(y1, y2)
    np.testing.assert_allclose(y1, dense @ x, rtol=1e-4, atol=1e-4)
    # bypass works
    y3 = np.asarray(gust_spmm_auto(sched, jnp.asarray(x), use_kernel=False,
                                   cache=None))
    assert np.array_equal(y1, y3)


def test_schedule_cache_pack_for_ragged_for():
    rng = np.random.default_rng(9)
    sched = schedule(coo_from_dense(random_dense(rng, 32, 32, 0.2)), 8)
    cache = ScheduleCache()
    p1 = cache.pack_for(sched, c_blk=1)
    p2 = cache.pack_for(sched, c_blk=1)
    assert p1 is p2
    r1 = cache.ragged_for(sched, c_blk=1)
    r2 = cache.ragged_for(sched, c_blk=1)
    assert r1 is r2 and r1 is not p1
    assert cache.ragged_for(sched, c_blk=8) is not r1
    # auto_for delegates to the memoized routes (one decision, same object)
    skewed = schedule(coo_from_dense(power_law_dense(rng, 128, 128)), 8)
    a1 = cache.auto_for(skewed)
    assert isinstance(a1, RaggedSchedule)
    assert cache.auto_for(skewed) is a1
    assert cache.auto_for(skewed) is cache.ragged_for(skewed, c_blk=8)
    assert isinstance(cache.auto_for(sched), PackedSchedule)


def test_dryrun_specs_ragged_layout():
    """A ragged config must dry-run the ragged program: spec leaves carry
    the block metadata and the meta tuple is tagged, so decode_step_gust
    lowers the scalar-prefetch-shaped path (the padded/ragged layouts
    lower different programs — validating one does not cover the other)."""
    import jax

    from repro.configs.base import get_arch
    from repro.models.model_zoo import build_model
    from repro.serving.gust_serve import GustServeConfig, dryrun_specs

    lm = build_model(get_arch("yi_6b").reduced())
    cfg = GustServeConfig(density=0.1, gust_length=16, ragged=True)
    specs = dryrun_specs(lm, cfg)
    for entry in specs["mats"].values():
        assert entry["meta"][0] == "ragged"
        leaves = entry["leaves"]
        assert "block_window" in leaves and "block_starts" in leaves
        assert "seg_blk" in leaves and "col_loc" in leaves
        (tag, l, w, c_blk, t_blk, shape, fusable, s_blk,
         identity_perm) = entry["meta"]
        assert leaves["m_blk"].shape == (lm.stack.reps, t_blk * c_blk, l)
        assert leaves["block_starts"].shape == (lm.stack.reps, w + 1)
        # spec round-trips through the codec into a RaggedSchedule
        proto = ragged_from_leaves(
            {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
             for k, v in leaves.items()},
            entry["meta"],
        )
        assert isinstance(proto, RaggedSchedule)


def test_kernel_builders_memoized():
    from repro.kernels.gather_fill import make_gather_fill
    from repro.kernels.gust_spmv import make_gust_spmv
    from repro.kernels.gust_spmv_ragged import make_gust_spmv_ragged

    assert make_gust_spmv(4, 16, 8, 2, 3) is make_gust_spmv(4, 16, 8, 2, 3)
    assert make_gust_spmv(4, 16, 8, 2, 3) is not make_gust_spmv(4, 16, 8, 2, 4)
    assert make_gust_spmv_ragged(6, 3, 8, 2, 1) is make_gust_spmv_ragged(
        6, 3, 8, 2, 1
    )
    assert make_gather_fill(16, 8, 2, 1) is make_gather_fill(16, 8, 2, 1)


# ---------------------------------------------------------------------------
# serving: ragged layer stacking
# ---------------------------------------------------------------------------


def test_serving_ragged_stack_matches_padded():
    import jax

    from repro.configs.base import get_arch
    from repro.models.model_zoo import build_model
    from repro.serving.gust_serve import (
        GustServeConfig,
        decode_step_gust,
        gustify,
    )

    cfg = get_arch("yi_6b").reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    caches = lm.init_caches(2, 64, jnp.float32)
    toks = jnp.tile(jnp.arange(8, dtype=jnp.int32)[None], (2, 1))
    _, caches = lm.prefill(params, {"tokens": toks}, caches, dtype=jnp.float32)
    tok = jnp.full((2, 1), 3, jnp.int32)

    gp = GustServeConfig(density=0.3, gust_length=16, ragged=False)
    gr = GustServeConfig(density=0.3, gust_length=16, ragged=True)
    gust_p = gustify(lm, params, gp)
    gust_r = gustify(lm, params, gr)
    for name, st_p in gust_p["stats"].items():
        st_r = gust_r["stats"][name]
        # ragged stacks never stream more slots, and utilization only rises
        assert st_r["streamed_slots"] <= st_p["streamed_slots"]
        assert st_r["stream_utilization"] >= st_p["stream_utilization"] - 1e-9
        assert gust_r["mats"][name]["meta"][0] == "ragged"
    lp, _ = decode_step_gust(lm, params, gust_p, caches, tok, jnp.int32(8),
                             cfg=gp, dtype=jnp.float32)
    lr, _ = decode_step_gust(lm, params, gust_r, caches, tok, jnp.int32(8),
                             cfg=gr, dtype=jnp.float32)
    assert np.array_equal(np.asarray(lp), np.asarray(lr))


# ---------------------------------------------------------------------------
# distributed: block-balanced sharding
# ---------------------------------------------------------------------------


def test_distributed_spmv_block_balanced_skewed():
    from conftest import run_spmd_subprocess

    run_spmd_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core.formats import coo_from_dense
from repro.core.scheduler import schedule
from repro.core.spmv import distributed_spmv
from repro.core.packing import default_cache
rng = np.random.default_rng(0)
dense = ((rng.random((96, 64)) < 0.05) * rng.standard_normal((96, 64))).astype(np.float32)
rows = rng.choice(96, 5, replace=False)
dense[rows] = (rng.random((5, 64)) < 0.7) * rng.standard_normal((5, 64))
v = rng.standard_normal(64).astype(np.float32)
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
sched = schedule(coo_from_dense(dense), 8)
y = np.asarray(distributed_spmv(sched, jnp.asarray(v), mesh, axis="data"))
np.testing.assert_allclose(y, dense @ v, rtol=1e-4, atol=1e-4)
# second call hits the content-keyed cache instead of re-packing
h0 = default_cache.hits
np.asarray(distributed_spmv(sched, jnp.asarray(v), mesh, axis="data"))
assert default_cache.hits == h0 + 1
# fewer windows than devices still works
d2 = ((rng.random((8, 16)) < 0.4) * rng.standard_normal((8, 16))).astype(np.float32)
v2 = rng.standard_normal(16).astype(np.float32)
y2 = np.asarray(distributed_spmv(schedule(coo_from_dense(d2), 8), jnp.asarray(v2), mesh))
np.testing.assert_allclose(y2, d2 @ v2, rtol=1e-4, atol=1e-4)
print("ok")
""")
