"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on ONE device;
only launch/dryrun.py (and the subprocess-based SPMD tests) use the
512/8-device placeholder worlds."""

import os
import signal
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

#: Per-test wall-clock budget (seconds).  A wedged test — e.g. a fault
#: test spinning on a retry loop — fails loudly instead of hanging CI.
#: SIGALRM-based because the container has no pytest-timeout plugin;
#: override with REPRO_TEST_TIMEOUT (0 disables).
TEST_TIMEOUT_S = int(os.environ.get("REPRO_TEST_TIMEOUT", "600"))


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    # hookwrapper (not wrapper=True) style for pytest>=7.4 compatibility
    if TEST_TIMEOUT_S <= 0 or not hasattr(signal, "SIGALRM"):
        yield
        return

    def on_timeout(signum, frame):
        raise TimeoutError(
            f"test exceeded the global {TEST_TIMEOUT_S}s timeout "
            "(REPRO_TEST_TIMEOUT to override)"
        )

    prev = signal.signal(signal.SIGALRM, on_timeout)
    signal.alarm(TEST_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, prev)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_spmd_subprocess(code: str, devices: int = 8, timeout: int = 300):
    """Run a snippet in a fresh interpreter with a forced device count
    (jax pins the device world at first init, so SPMD tests need their
    own process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"SPMD subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
        )
    return proc.stdout
