"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on ONE device;
only launch/dryrun.py (and the subprocess-based SPMD tests) use the
512/8-device placeholder worlds."""

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_spmd_subprocess(code: str, devices: int = 8, timeout: int = 300):
    """Run a snippet in a fresh interpreter with a forced device count
    (jax pins the device world at first init, so SPMD tests need their
    own process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"SPMD subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
        )
    return proc.stdout
