"""Distribution-layer tests (run in 8-device subprocesses — jax pins the
device world at first init): ring all-reduce == psum, compressed psum,
distributed SpMV == dense, sharding-rule divisibility validity, and a
miniature end-to-end sharded train step."""

import numpy as np
import pytest

from conftest import run_spmd_subprocess


def test_ring_all_reduce_matches_psum():
    run_spmd_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed.collectives import ring_all_reduce, shard_map
mesh = Mesh(np.array(jax.devices()).reshape(8), ("x",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 37, 5))
out = jax.jit(shard_map(lambda xs: ring_all_reduce(xs[0], "x")[None],
    mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
ref = x.sum(0)
assert np.abs(np.asarray(out) - np.asarray(ref)[None]).max() < 1e-4
print("ok")
""")


def test_compressed_psum_error_feedback():
    run_spmd_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed.collectives import compressed_psum, shard_map
mesh = Mesh(np.array(jax.devices()).reshape(8), ("x",))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
def f(xs):
    red, res = compressed_psum(xs[0], jnp.zeros_like(xs[0]), "x")
    return red[None], res[None]
red, res = jax.jit(shard_map(f, mesh=mesh, in_specs=P("x"),
                             out_specs=(P("x"), P("x"))))(x)
ref = np.asarray(x.sum(0))
rel = np.abs(np.asarray(red)[0] - ref).max() / np.abs(ref).max()
assert rel < 0.05, rel
# residual equals what quantization lost locally
print("ok")
""")


def test_distributed_spmv_matches_dense():
    run_spmd_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core.formats import coo_from_dense
from repro.core.scheduler import schedule
from repro.core.spmv import distributed_spmv
rng = np.random.default_rng(0)
dense = ((rng.random((96, 64)) < 0.15) * rng.standard_normal((96, 64))).astype(np.float32)
v = rng.standard_normal(64).astype(np.float32)
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
sched = schedule(coo_from_dense(dense), 8)
y = np.asarray(distributed_spmv(sched, jnp.asarray(v), mesh, axis="data"))
np.testing.assert_allclose(y, dense @ v, rtol=1e-4, atol=1e-4)
print("ok")
""")


def test_param_specs_all_divisible():
    """Every sharded dim in every arch's param specs must divide its mesh
    axis — the invariant that makes .lower() succeed at 256/512 chips."""
    run_spmd_subprocess("""
import jax, numpy as np
from jax.sharding import Mesh
from repro.configs.base import ARCH_IDS, get_arch
from repro.models.model_zoo import build_model
from repro.distributed.sharding import param_specs
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
sizes = {"data": 2, "model": 4}
for arch in ARCH_IDS:
    lm = build_model(get_arch(arch))
    specs = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
    shardings = param_specs(specs, mesh, mode="train")
    flat_sp, _ = jax.tree_util.tree_flatten(shardings)
    flat_sd, _ = jax.tree_util.tree_flatten(specs)
    for sd, sh in zip(flat_sd, flat_sp):
        for dim, axes in enumerate(sh.spec):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            k = 1
            for a in axes:
                k *= sizes[a]
            assert sd.shape[dim] % k == 0, (arch, sd.shape, sh.spec)
print("ok")
""", timeout=600)


def test_sharded_train_step_runs_and_matches_single_device():
    """A reduced model train step on a 2x4 mesh must produce the same
    loss as the single-device run (same math, different layout)."""
    run_spmd_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs.base import get_arch
from repro.models.model_zoo import build_model
from repro.training import TrainConfig, make_train_step, init_train_state
from repro.training.optimizer import AdamWConfig
from repro.distributed.sharding import param_specs, activation_ctx
cfg = get_arch("phi3_mini_3_8b").reduced()
lm = build_model(cfg)
tc = TrainConfig(opt=AdamWConfig(lr=1e-3), dtype="float32", microbatches=2)
state = init_train_state(lm, jax.random.PRNGKey(0), tc)
batch = {
  "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab),
  "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab),
  "loss_mask": jnp.ones((8, 32)),
}
step = make_train_step(lm, tc)
_, m_ref = jax.jit(step)(state, batch)

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
pspecs = param_specs(state["params"], mesh, mode="train")
state_sh = {"params": pspecs,
            "opt": {"m": pspecs, "v": pspecs, "step": NamedSharding(mesh, P())}}
bsh = {k: NamedSharding(mesh, P(("data",), *([None] * (v.ndim - 1))))
       for k, v in batch.items()}
with activation_ctx(mesh):
    _, m_sh = jax.jit(step, in_shardings=(state_sh, bsh))(state, batch)
a, b = float(m_ref["loss"]), float(m_sh["loss"])
assert abs(a - b) / abs(a) < 1e-4, (a, b)
print("ok", a, b)
""", timeout=600)


def test_hlo_analysis_counts_loops():
    run_spmd_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze_hlo
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
def f(w, x):
    def body(c, _):
        return jnp.tanh(c @ w), ()
    c, _ = jax.lax.scan(body, x, None, length=5)
    return c.sum()
compiled = jax.jit(jax.grad(f), in_shardings=(
    NamedSharding(mesh, P(None, "model")), NamedSharding(mesh, P("data", None))
)).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
         jax.ShapeDtypeStruct((16, 64), jnp.float32)).compile()
st = analyze_hlo(compiled.as_text())
# 3 dots of 2*8*16*64 flops, x5 scan iterations
assert st.dot_flops == 3 * 16384 * 5, st.dot_flops
assert st.collective_count.get("all-gather", 0) >= 5
print("ok")
""")
