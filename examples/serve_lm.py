"""Serving example: batched requests against a small model, dense vs
GUST-sparse decode side by side — the paper's technique as a serving
feature (assignment deliverable b; DESIGN.md §4).

Engine build plans every MLP matrix exactly once (``gustify`` ->
``repro.plan``, content-keyed cache) and each decode step executes the
stacked :class:`repro.GustPlan` leaves — schedule once, decode many.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import numpy as np
import jax

from repro.configs.base import get_arch
from repro.models.model_zoo import build_model
from repro.serving import GustServeConfig, ServeConfig, ServeLoop


def main():
    cfg = get_arch("yi_6b").reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # mixed-length prompts, served CONCURRENTLY: per-slot prefill and
    # per-slot positions make each output identical to a solo run
    prompts = [rng.integers(0, cfg.vocab, ln).astype(np.int32)
               for ln in (8, 4, 12, 6)]

    for label, gust in (
        ("dense decode", None),
        ("GUST decode (density 0.5, schedule computed once at load)",
         GustServeConfig(density=0.5, gust_length=16)),
    ):
        sc = ServeConfig(batch=4, seq_len=128, dtype="float32", gust=gust)
        t0 = time.time()
        loop = ServeLoop(lm, params, sc)
        build_s = time.time() - t0
        t0 = time.time()
        rids = [loop.enqueue(pr, max_new=8) for pr in prompts]
        loop.run_to_completion()
        outs = {rid: loop.completed[rid] for rid in rids}
        gen_s = time.time() - t0
        toks = sum(len(v) for v in outs.values())
        print(f"{label}:")
        print(f"  engine build {build_s:.2f}s (includes scheduling for GUST), "
              f"{toks} tokens in {gen_s:.2f}s "
              f"({loop.stats['decode_steps']} decode steps, "
              f"slot occupancy {loop.occupancy:.0%})")
        if gust is not None and loop.gust_tree is not None:
            util = {k: f"{v['stream_utilization']:.2%}"
                    for k, v in loop.gust_tree["stats"].items()}
            print(f"  scheduled-stream utilization per matrix: {util}")
        print(f"  first completion: {list(outs.values())[0]}")


if __name__ == "__main__":
    main()
