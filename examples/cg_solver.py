"""Conjugate-gradient solver on a GUST plan — the paper's §5.3
amortization argument end-to-end: plan ONCE (schedule + pack), run
hundreds of SpMVs against changing vectors inside an iterative solver.

    PYTHONPATH=src python examples/cg_solver.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

import repro


def make_spd(n: int, density: float, seed: int = 0) -> np.ndarray:
    """Sparse symmetric positive-definite system (paper: Ax=y solvers)."""
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < density / 2) * rng.standard_normal((n, n))
    a = (a + a.T).astype(np.float32)
    a[np.arange(n), np.arange(n)] = np.abs(a).sum(1) + 1.0  # diag dominance
    return a


def main():
    n = 512
    a_dense = make_spd(n, 0.05)
    b = np.random.default_rng(1).standard_normal(n).astype(np.float32)

    # preprocessing: one plan (schedule + packed layout), reused by every
    # iteration — the schedule-once/execute-many contract made explicit
    t0 = time.time()
    p = repro.plan(a_dense, repro.PlanConfig(l=64, backend="jnp"))
    cost = p.cost()
    pre_s = time.time() - t0
    print(f"plan: {pre_s:.2f}s ({cost.cycles} modeled cycles/SpMV, "
          f"util={cost.utilization:.1%}, layout={cost.layout})")

    matvec = jax.jit(lambda v: p.spmm(v[:, None])[:, 0])

    # conjugate gradient
    x = jnp.zeros(n)
    r = jnp.asarray(b) - matvec(x)
    p = r
    rs = float(r @ r)
    t0 = time.time()
    for it in range(200):
        ap = matvec(p)
        alpha = rs / float(p @ ap)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = float(r @ r)
        if it % 25 == 0:
            print(f"  iter {it:3d} residual {np.sqrt(rs_new):.3e}")
        if np.sqrt(rs_new) < 1e-5:
            print(f"  converged at iter {it}")
            break
        p = r + (rs_new / rs) * p
        rs = rs_new
    solve_s = time.time() - t0
    err = np.abs(a_dense @ np.asarray(x) - b).max()
    print(f"solve: {solve_s:.2f}s, |Ax-b|_inf = {err:.2e}")
    print(f"amortization: 1 plan ({pre_s:.2f}s) served "
          f"{it+1} SpMVs (paper §5.3: schedule once, solve many)")


if __name__ == "__main__":
    main()
