"""End-to-end LM training driver example: train a ~100M-param yi-family
model for a few hundred steps with checkpointing and fault-tolerance
enabled (assignment deliverable b).

Reduced by default so it runs on one CPU in minutes; on a real mesh the
same driver trains the full config (launch/train.py is the production
entrypoint — this example calls it as a library).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import tempfile

import jax

from repro.configs.base import get_arch, register
from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="yi_6b")
    args = ap.parse_args()

    # a ~100M-param family member: same blocks as yi-6b, scaled down
    base = get_arch(args.arch)
    cfg = dataclasses.replace(
        base,
        name=base.name + "-100m",
        n_layers=6,
        d_model=512,
        n_heads=8,
        n_kv=2,
        d_head=64,
        d_ff=1408,
        vocab=8192,
    )
    register(cfg)
    n_params_est = cfg.n_layers * (
        cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv) * cfg.head_dim
        + cfg.n_heads * cfg.head_dim * cfg.d_model
        + 3 * cfg.d_model * cfg.d_ff
    ) + cfg.vocab * cfg.d_model
    print(f"training {cfg.name}: ~{n_params_est/1e6:.0f}M params, "
          f"{args.steps} steps")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        state, history = run_training(
            cfg.name,
            reduced=False,
            steps=args.steps,
            seq_len=128,
            global_batch=8,
            lr=3e-3,
            microbatches=2,
            ckpt_dir=ckpt_dir,
            ckpt_every=100,
            dtype="float32",
            log_every=25,
        )
    print(f"loss: {history[0]:.3f} -> {history[-1]:.3f} "
          f"({(1 - history[-1]/history[0])*100:.1f}% reduction)")

    # train -> serve handoff: plan one trained MLP matrix through the GUST
    # plan/execute API (this is what gustify does for the whole stack at
    # weight-load time — schedule once, decode many)
    import numpy as np

    import repro

    w = np.asarray(state["params"]["stack"]["reps"][0]["mlp"]["w_down"])[0].T
    gl = repro.GustLinear(
        w, config=repro.PlanConfig(l=64, backend="jnp"), density=0.25
    )
    cost = gl.plan.cost()
    print(f"GUST handoff: w_down {w.shape} pruned to 25% density -> "
          f"{cost.cycles} cycles/SpMV, util={cost.utilization:.1%}, "
          f"layout={cost.layout}")


if __name__ == "__main__":
    main()
