"""Graph analytics on GUST plans: SpGEMM-powered PageRank, triangle
counting and GNN feature propagation over the synthetic matrix suite.

The new subsystem in three workloads:

  * ``GustPlan.spgemm`` — sparse×sparse through A's color-block stream
    (SpArch-style condensed outer products), returning a sparse COO that
    is itself ``repro.plan()``-ed (chained A·A);
  * ``repro.graph.pagerank`` — schedule the transition matrix once, run
    the whole power iteration against that one plan;
  * ``repro.graph.triangle_count`` / ``feature_propagation`` — A·A
    masked by A, and ``Â H`` per GNN layer.

    PYTHONPATH=src python examples/graph_analytics.py
"""

import numpy as np

import repro
from repro.data.matrices import synth_power_law
from repro.graph import feature_propagation, pagerank, triangle_count


def main():
    rng = np.random.default_rng(0)
    n = 512
    adj = synth_power_law(n, 0.02, seed=3)
    cfg = repro.PlanConfig(l=64)
    print(f"graph: {n} nodes, {adj.nnz} edges (power-law)")

    # 1. the SpGEMM primitive: A·A through the plan's color-block stream,
    #    bitwise-checked against the dense reference (integer-valued A)
    pattern = repro.COOMatrix(
        adj.shape, adj.rows, adj.cols, np.ones(adj.nnz, np.float32)
    )
    p = repro.plan(pattern, cfg)
    cost = p.spgemm_cost(pattern)
    aa = p.spgemm(pattern)
    dense_ref = repro.dense_from_coo(pattern) @ repro.dense_from_coo(pattern)
    print(f"spgemm: A·A nnz={aa.nnz} (estimated {cost.out_nnz_estimate}), "
          f"{cost.products} merge ops, "
          f"{cost.flop_reduction:.0f}x fewer FLOPs than dense, "
          f"bitwise vs dense: {np.array_equal(repro.dense_from_coo(aa), dense_ref)}")

    # 2. chained plans: the sparse product re-plans directly
    p2 = repro.plan(aa, cfg)
    v = rng.standard_normal(n).astype(np.float32)
    y = np.asarray(p2.spmv(v))
    print(f"chained plan(A·A): {p2} -> spmv max err "
          f"{np.abs(y - dense_ref @ v).max():.2e}")

    # 3. PageRank: one plan for the transition matrix, many spmv iterations
    pr = pagerank(adj, config=cfg)
    print(f"pagerank: converged={pr.converged} in {pr.iterations} iters "
          f"(residual {pr.residual:.2e}), top nodes: {pr.top(5).tolist()}")

    # 4. triangle census: one spgemm + host-side mask
    tc = triangle_count(adj, config=cfg)
    print(f"triangles: {tc.triangles} "
          f"(clustering coefficient {tc.clustering_coefficient:.4f}, "
          f"A·A nnz {tc.spgemm_nnz})")

    # 5. GNN feature propagation: Â scheduled once, one spmm per layer
    feats = rng.standard_normal((n, 16)).astype(np.float32)
    out = feature_propagation(adj, feats, num_layers=2, config=cfg)
    print(f"gnn propagation: features {feats.shape} -> {out.shape}, "
          f"norm ratio {np.linalg.norm(out) / np.linalg.norm(feats):.3f}")


if __name__ == "__main__":
    main()
