"""Quickstart: schedule a sparse matrix with GUST edge-coloring, run the
SpMV three ways (dense oracle, scheduled XLA, Pallas kernel), and print
the paper's headline metrics for this matrix.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core.baselines import all_designs
from repro.core.formats import coo_from_dense
from repro.core.scheduler import schedule
from repro.core.spmv import spmv_scheduled
from repro.kernels.ops import gust_spmm, pack_schedule


def main():
    rng = np.random.default_rng(0)
    m = n = 1024
    density = 0.02
    dense = ((rng.random((m, n)) < density) * rng.standard_normal((m, n))).astype(
        np.float32
    )
    v = rng.standard_normal(n).astype(np.float32)
    coo = coo_from_dense(dense)
    print(f"matrix: {m}x{n}, nnz={coo.nnz:,}, density={coo.density:.3f}")

    # 1. preprocessing: bipartite edge-coloring schedule (paper Listing 1/2)
    sched = schedule(coo, l=256, load_balance=True)
    print(f"schedule: {sched.num_windows} windows, {sched.total_colors} colors, "
          f"{sched.cycles} cycles, utilization={sched.hardware_utilization:.1%}")

    # 2. execute: scheduled SpMV == dense matvec
    y_ref = dense @ v
    y_sched = np.asarray(spmv_scheduled(sched, jnp.asarray(v)))
    print("scheduled-vs-dense max err:", np.abs(y_sched - y_ref).max())

    # 3. the Pallas TPU kernel (interpret mode on CPU)
    packed = pack_schedule(sched)
    y_kernel = np.asarray(gust_spmm(packed, jnp.asarray(v[:, None])))[:, 0]
    print("kernel-vs-dense max err:   ", np.abs(y_kernel - y_ref).max())

    # 4. the paper's comparison (Fig. 7 on this matrix)
    print("\ndesign comparison (cycles / utilization):")
    for name, rep in all_designs(coo, 256).items():
        print(f"  {name:12s} {rep.cycles:12,.0f} cycles   "
              f"util={rep.utilization:8.4%}")


if __name__ == "__main__":
    main()
