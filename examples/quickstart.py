"""Quickstart: plan a sparse matrix once with GUST edge-coloring, execute
the SpMV many ways through the one plan/execute API, and print the
paper's headline metrics for this matrix.

The whole pipeline is two calls:

    p = repro.plan(matrix, repro.PlanConfig(l=256))   # schedule + pack once
    y = p.spmv(v)                                     # execute many times

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

import repro
from repro.core.baselines import all_designs


def main():
    rng = np.random.default_rng(0)
    m = n = 1024
    density = 0.02
    dense = ((rng.random((m, n)) < density) * rng.standard_normal((m, n))).astype(
        np.float32
    )
    v = rng.standard_normal(n).astype(np.float32)
    print(f"matrix: {m}x{n}, density={density:.3f}")

    # 1. plan: bipartite edge-coloring schedule + packed execution layout,
    #    computed once per matrix (paper §3.3/§5.3 amortization; the plan
    #    is served from a content-keyed cache on repeat calls)
    p = repro.plan(dense, repro.PlanConfig(l=256, layout="auto"))
    cost = p.cost()
    print(f"plan: {p}")
    print(f"schedule: {p.sched.num_windows} windows, "
          f"{p.sched.total_colors} colors, {cost.cycles} cycles, "
          f"utilization={cost.utilization:.1%}")
    print(f"layout: {cost.layout} (padding waste {cost.waste_ratio:.2f}x), "
          f"stream {cost.stream_bytes / 1e6:.1f} MB, "
          f"Eq.10 predicted cycles {cost.expected_cycles:,.0f}")

    # 2. execute: plan SpMV == dense matvec (pure-XLA segment-sum backend)
    y_ref = dense @ v
    y_plan = np.asarray(p.spmv(jnp.asarray(v)))
    print("plan-vs-dense max err:  ", np.abs(y_plan - y_ref).max())

    # 3. same plan, Pallas TPU kernel backend (interpret mode on CPU) and
    #    a multi-vector (decode-batch) execution
    pk = repro.plan(dense, repro.PlanConfig(l=256, backend="pallas"))
    y_kernel = np.asarray(pk.spmm(jnp.asarray(v[:, None])))[:, 0]
    print("kernel-vs-dense max err:", np.abs(y_kernel - y_ref).max())

    # 4. int8 per-block-scaled values: the stream shrinks ~4x on the
    #    value bytes (one f32 scale per c_blk block rides along) and the
    #    kernels dequantize in-register with a single f32 multiply
    p8 = repro.plan(dense, repro.PlanConfig(l=256, value_dtype="int8",
                                            backend="pallas"))
    y_int8 = np.asarray(p8.spmv(jnp.asarray(v)))
    c8 = p8.cost()
    print(f"int8 stream {c8.stream_bytes / 1e6:.1f} MB "
          f"(f32 was {cost.stream_bytes / 1e6:.1f} MB), "
          f"quantization err: {np.abs(y_int8 - y_ref).max():.4f}")

    # 5. measured autotuning: sweep (c_blk, l, layout, gather) against a
    #    probe batch; the fastest measured candidate wins unless the
    #    static defaults hold up (resolve_tuning's margin)
    tuned = p.tune(jnp.asarray(rng.standard_normal((n, 8)), jnp.float32),
                   iters=2)
    r = tuned.tuning
    print(f"tuned: {r.baseline} -> {r.choice} "
          f"({r.improvement:.2f}x measured, "
          f"{len(r.measurements)} candidates timed, {len(r.pruned)} pruned)")

    # 6. the paper's comparison (Fig. 7 on this matrix)
    print("\ndesign comparison (cycles / utilization):")
    for name, rep in all_designs(repro.coo_from_dense(dense), 256).items():
        print(f"  {name:12s} {rep.cycles:12,.0f} cycles   "
              f"util={rep.utilization:8.4%}")

    # 7. SpGEMM: the same plan multiplies by another sparse matrix —
    #    A's color-block stream becomes an outer-product schedule over
    #    B's condensed rows, and the sparse result is itself plan()-able
    AA = p.spgemm(p)  # C = A @ A, emitted as a canonical sparse COO
    sc = p.spgemm_cost(p)
    print(f"\nspgemm: A*A nnz={AA.nnz} "
          f"(density {AA.nnz / (m * n):.4f}), "
          f"{sc.products:,} multiplies vs {sc.dense_flops // 2:,} dense "
          f"({sc.flop_reduction:.1f}x fewer)")
    p2 = repro.plan(AA, repro.PlanConfig(l=256))  # chain: plan the product
    y2 = np.asarray(p2.spmv(jnp.asarray(v)))
    print("chained (A*A)v max err:", np.abs(y2 - dense @ (dense @ v)).max())

    # 8. graph analytics ride on spmv/spgemm: PageRank on this pattern
    pr = repro.pagerank(dense, config=repro.PlanConfig(l=256))
    print(f"pagerank: converged={pr.converged} in {pr.iterations} iters, "
          f"top-3 nodes {pr.top(3).tolist()}")

    # 9. static verification: every packed-format contract (ROADMAP
    #    GUST-Pxx rules) checked over the plan's leaves — pure numpy,
    #    no kernel runs.  The same checks run over a PlanStore directory
    #    as `python -m repro.analysis verify <dir>` (plus `lint` and
    #    `audit` for the source-policy and kernel-resource rules), and
    #    PlanStore(dir, verify="load") re-packs instead of serving any
    #    artifact that fails them.
    findings = p.verify()
    print(f"\nverify: {len(findings)} finding(s) "
          f"({'clean' if not findings else findings[0].rule})")

    # 10. chaos: the same plan under deterministic fault injection
    #     (repro.FaultPlan over the named sites in faults.KNOWN_SITES —
    #     ROADMAP §Resilience invariants).  A failing local-gather path
    #     degrades to the resident gather through one decision point,
    #     counted, bitwise-identical — never an exception.  The full
    #     fault schedule runs in benchmarks/chaos_bench.py.
    from repro.resilience.faults import injected

    p_loc = repro.plan(dense, repro.PlanConfig(l=256, gather="local"))
    chaos = repro.FaultPlan([repro.FaultSpec("gather.local")], seed=0)
    with injected(chaos):
        y_chaos = np.asarray(p_loc.spmv(jnp.asarray(v)))
    print(f"chaos: fired={[f[1] for f in chaos.fired]}, "
          f"fallbacks={p_loc.cost().fallback_gather}, "
          f"bitwise={np.array_equal(y_chaos, y_plan)}")


if __name__ == "__main__":
    main()
