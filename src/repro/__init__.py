"""repro — GUST (graph edge-coloring SpMV acceleration) reproduction.

Public API, exported lazily (PEP 562) so ``import repro`` is instant and
pulls **no** jax/kernel modules — important both for CLI startup and for
entry points like ``repro.launch.dryrun`` that must pin ``XLA_FLAGS``
before jax initializes.  The front door is the plan/execute API:

    >>> import repro
    >>> p = repro.plan(matrix, repro.PlanConfig(l=256, layout="auto"))
    >>> y = p.spmv(v)     # schedule once (cached), execute many

Everything else (formats, scheduler, packing, GustLinear, serving) hangs
off the same lazy table below; submodules (``repro.core``, ``repro.serving``,
...) import as usual.
"""

from typing import TYPE_CHECKING

# symbol -> defining module; resolved on first attribute access
_EXPORTS = {
    # plan/execute API (the front door)
    "plan": "repro.core.plan",
    "reschedule": "repro.core.plan",
    "GustPlan": "repro.core.plan",
    "PlanConfig": "repro.core.plan",
    "PlanCost": "repro.core.plan",
    "TuneResult": "repro.core.plan",
    # persistent plan artifacts (cross-process amortization)
    "PlanStore": "repro.core.plan_store",
    # static artifact verifier (PR 9; pure numpy — see repro.analysis)
    "verify": "repro.analysis.verify",
    "Finding": "repro.analysis.verify",
    # SpGEMM cost surface (the product itself is GustPlan.spgemm)
    "SpgemmCost": "repro.core.spgemm",
    # graph-analytics workloads (PR 8, built on GustPlan.spgemm/spmm)
    "pagerank": "repro.graph.analytics",
    "triangle_count": "repro.graph.analytics",
    "feature_propagation": "repro.graph.analytics",
    "PageRankResult": "repro.graph.analytics",
    "TriangleCountResult": "repro.graph.analytics",
    # formats + scheduler
    "COOMatrix": "repro.core.formats",
    "GustSchedule": "repro.core.formats",
    "coo_from_dense": "repro.core.formats",
    "dense_from_coo": "repro.core.formats",
    "schedule": "repro.core.scheduler",
    # packed layouts + cache
    "PackedSchedule": "repro.core.packing",
    "RaggedSchedule": "repro.core.packing",
    "ScheduleCache": "repro.core.packing",
    "clear_cache": "repro.core.packing",
    # sparse LM serving
    "GustLinear": "repro.core.gust_linear",
    "SparsityConfig": "repro.core.gust_linear",
    "prune_by_magnitude": "repro.core.gust_linear",
    "GustServeConfig": "repro.serving.gust_serve",
    # resilience: fault injection + request lifecycle (PR 10; jax-free)
    "FaultPlan": "repro.resilience.faults",
    "FaultSpec": "repro.resilience.faults",
    "RequestResult": "repro.resilience.lifecycle",
    "RequestStatus": "repro.resilience.lifecycle",
    # statistical bounds (paper Eqs. 9-11)
    "expected_colors_bound": "repro.core.bounds",
    "expected_execution_cycles": "repro.core.bounds",
    "expected_utilization": "repro.core.bounds",
    # legacy execution shims (deprecated spellings route through GustPlan)
    "spmv": "repro.core.spmv",
    "spmv_scheduled": "repro.core.spmv",
    "spmm_scheduled": "repro.core.spmv",
    "spmm_ragged": "repro.core.spmv",
    "distributed_spmv": "repro.core.spmv",
    "gust_spmm": "repro.kernels.ops",
    "gust_spmm_auto": "repro.kernels.ops",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module_name = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") from None
    import importlib

    value = getattr(importlib.import_module(module_name), name)
    globals()[name] = value  # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))


if TYPE_CHECKING:  # static analyzers see the real symbols
    from repro.core.bounds import (  # noqa: F401
        expected_colors_bound,
        expected_execution_cycles,
        expected_utilization,
    )
    from repro.core.formats import (  # noqa: F401
        COOMatrix,
        GustSchedule,
        coo_from_dense,
        dense_from_coo,
    )
    from repro.core.gust_linear import (  # noqa: F401
        GustLinear,
        SparsityConfig,
        prune_by_magnitude,
    )
    from repro.core.packing import (  # noqa: F401
        PackedSchedule,
        RaggedSchedule,
        ScheduleCache,
        clear_cache,
    )
    from repro.core.plan import (  # noqa: F401
        GustPlan,
        PlanConfig,
        PlanCost,
        TuneResult,
        plan,
        reschedule,
    )
    from repro.analysis.verify import Finding, verify  # noqa: F401
    from repro.core.plan_store import PlanStore  # noqa: F401
    from repro.core.spgemm import SpgemmCost  # noqa: F401
    from repro.graph.analytics import (  # noqa: F401
        PageRankResult,
        TriangleCountResult,
        feature_propagation,
        pagerank,
        triangle_count,
    )
    from repro.core.scheduler import schedule  # noqa: F401
    from repro.core.spmv import (  # noqa: F401
        distributed_spmv,
        spmm_ragged,
        spmm_scheduled,
        spmv,
        spmv_scheduled,
    )
    from repro.kernels.ops import gust_spmm, gust_spmm_auto  # noqa: F401
    from repro.resilience.faults import FaultPlan, FaultSpec  # noqa: F401
    from repro.resilience.lifecycle import (  # noqa: F401
        RequestResult,
        RequestStatus,
    )
    from repro.serving.gust_serve import GustServeConfig  # noqa: F401
