"""Distribution layer: named-sharding rules + collective helpers."""
