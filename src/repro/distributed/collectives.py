"""Collective helpers: ring all-reduce (overlap-friendly), bucketing,
compressed cross-pod reductions.

Under GSPMD most collectives are implicit (the sharding rules produce
them), but three patterns need manual control inside ``shard_map`` blocks:

  * ``ring_all_reduce``   — reduce-scatter + all-gather built from
    ``ppermute`` steps.  Unlike a monolithic ``psum``, the 2(k-1)
    permute steps let XLA interleave each hop with compute — the classic
    bandwidth-optimal schedule, used on the scarce cross-pod axis.
  * ``bucketed``          — fuse many small gradient tensors into few
    fixed-size buckets before reducing (latency-bound -> bandwidth-bound).
  * ``compressed_psum``   — int8 + error feedback around a psum (the
    payload that crosses the link is 4× smaller; see
    training/compression.py for the numerics).
"""

from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp

try:  # jax >= 0.6 exports shard_map at the top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map

__all__ = ["shard_map", "ring_all_reduce", "bucketed", "unbucketed",
           "compressed_psum"]


def ring_all_reduce(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Bandwidth-optimal ring all-reduce via ppermute: reduce-scatter
    (k-1 hops) then all-gather (k-1 hops).  Semantically == lax.psum, but
    expressed as individually schedulable sends so XLA can overlap each
    hop with compute.  Must run inside shard_map over ``axis_name``."""
    try:
        k = jax.lax.axis_size(axis_name)
    except AttributeError:  # older jax: psum of a literal folds to the size
        k = jax.lax.psum(1, axis_name)
    if k == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    n = x.shape[0]
    pad = (-n) % k
    xp = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    chunks = xp.reshape((k, (n + pad) // k) + x.shape[1:])
    perm = [(i, (i + 1) % k) for i in range(k)]

    # reduce-scatter: travelling partial sums; after k-1 hops this shard
    # holds the fully-reduced chunk with id (idx+1) % k.
    travelling = chunks[idx]
    for i in range(k - 1):
        travelling = jax.lax.ppermute(travelling, axis_name, perm)
        travelling = travelling + chunks[(idx - i - 1) % k]

    # all-gather: circulate the reduced chunks.
    owned = (idx + 1) % k
    gathered = jnp.zeros_like(chunks).at[owned].set(travelling)
    block = travelling
    for t in range(1, k):
        block = jax.lax.ppermute(block, axis_name, perm)
        gathered = gathered.at[(idx - t + 1) % k].set(block)
    return gathered.reshape((-1,) + x.shape[1:])[:n]


def bucketed(tensors: Sequence[jnp.ndarray], bucket_bytes: int = 1 << 24):
    """Flatten+concat tensors into buckets of ~bucket_bytes.  Returns
    (buckets, spec) where spec reconstructs the originals."""
    flat = [t.reshape(-1) for t in tensors]
    spec = [(t.shape, t.dtype, t.size) for t in tensors]
    buckets: List[jnp.ndarray] = []
    cur: List[jnp.ndarray] = []
    cur_bytes = 0
    for f in flat:
        nbytes = f.size * f.dtype.itemsize
        if cur and cur_bytes + nbytes > bucket_bytes:
            buckets.append(jnp.concatenate([c.astype(jnp.float32) for c in cur]))
            cur, cur_bytes = [], 0
        cur.append(f)
        cur_bytes += nbytes
    if cur:
        buckets.append(jnp.concatenate([c.astype(jnp.float32) for c in cur]))
    return buckets, spec


def unbucketed(buckets: Sequence[jnp.ndarray], spec) -> List[jnp.ndarray]:
    flat = jnp.concatenate(buckets) if len(buckets) > 1 else buckets[0]
    out, off = [], 0
    for shape, dtype, size in spec:
        out.append(flat[off : off + size].reshape(shape).astype(dtype))
        off += size
    return out


def compressed_psum(x: jnp.ndarray, residual: jnp.ndarray, axis_name: str,
                    bits: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8 + error-feedback psum: quantize locally, reduce the dequantized
    payload, return (reduced, new_residual).  Inside shard_map."""
    qmax = float(2 ** (bits - 1) - 1)
    val = x.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(val)) / qmax, 1e-12)
    q = jnp.clip(jnp.round(val / scale), -qmax, qmax)
    deq = q * scale
    new_residual = val - deq
    return jax.lax.psum(deq, axis_name), new_residual
