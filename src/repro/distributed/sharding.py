"""Named-sharding rules: DP / FSDP / TP / EP / SP per architecture & shape.

The mesh is ``(data, model)`` single-pod or ``(pod, data, model)``
multi-pod (launch/mesh.py).  Axis roles:

  * batch          -> ("pod", "data")   (pure DP)
  * parameters     -> 2-D sharded: a TP dim over "model" plus an FSDP dim
                      over "data" wherever divisibility allows — this is
                      what lets 123B-parameter trains and 109B-parameter
                      MoE serving fit 5.8 GB/chip HBM.
  * attention TP   -> query/output heads over "model" *when the head
                      count divides the axis*; otherwise attention weights
                      fall back to FSDP-only and the block's TP comes from
                      the FFN (recorded per-arch in DESIGN.md §7).
  * MoE            -> experts over "model" (EP); token dispatch becomes
                      all-to-all under GSPMD.
  * KV cache       -> batch over DP axes; sequence dim over "model" for
                      global layers (flash-decode style: XLA inserts the
                      partial-softmax all-reduces).
  * SP             -> long-context activations shard the sequence dim over
                      "model" (constrain_activation with seq_sharded=True).

Everything is expressed through two entry points:

  ``param_specs(params, arch, mesh, mode)``  -> pytree of NamedSharding
  ``constrain_activation(x, kind)``          -> with_sharding_constraint
                                                (no-op outside a mesh ctx)
"""

from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "mesh_axis_names",
    "dp_axes",
    "tp_axis",
    "param_specs",
    "batch_specs",
    "cache_spec_overrides",
    "activation_ctx",
    "constrain_activation",
]


def mesh_axis_names(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes(mesh: Mesh):
    """The data-parallel axes: ("pod", "data") if multi-pod else ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh: Mesh) -> str:
    return "model"


# ---------------------------------------------------------------------------
# Parameter sharding
# ---------------------------------------------------------------------------


def _divis(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _leaf_spec(path: str, shape, tp: int, fsdp: int, mode: str) -> P:
    """PartitionSpec for one parameter leaf.  ``path`` is the '/'-joined key
    path; divisibility decides whether a dim actually takes an axis."""
    nd = len(shape)
    spec = [None] * nd
    name = path.rsplit("/", 1)[-1]

    def take(dim: int, axis: str, size: int) -> bool:
        if spec[dim] is None and _divis(shape[dim], size):
            spec[dim] = axis
            return True
        return False

    def fsdp_any(exclude=()):
        # FSDP: shard the largest remaining dim over "data"
        n_elems = 1
        for s_ in shape:
            n_elems *= s_
        if mode != "train" and n_elems * 4 < (1 << 22):
            return  # small serving weights stay replicated over data
        for dim in sorted(range(nd), key=lambda i: -shape[i]):
            if dim not in exclude and take(dim, "data", fsdp):
                return

    if nd == 1:
        return P(None)

    if name == "table":  # embedding (V, d)
        # vocab over model only: FSDP on d would shard the unembed
        # contraction and force logits partial-sum all-reduces over "data"
        take(0, "model", tp)
    elif name == "wq" and nd == 3:  # (d, H, dh): Megatron column-parallel
        take(1, "model", tp)  # needs H % tp == 0 (else FSDP fallback)
        fsdp_any(exclude=(1,))
    elif name in ("wk", "wv") and nd == 3:  # (d, KV, dh)
        take(1, "model", tp)  # dh-TP would break RoPE pairing: skip
        fsdp_any(exclude=(1,))
    elif name == "wo" and nd == 3:  # (H, dh, d): row-parallel
        take(0, "model", tp)
        fsdp_any(exclude=(0,))
    elif name in ("w_gate", "w_up") and nd == 3:  # MoE (E, d, f)
        take(0, "model", tp)  # EP
        fsdp_any(exclude=(0,))
    elif name == "w_down" and nd == 3:  # MoE (E, f, d)
        take(0, "model", tp)
        fsdp_any(exclude=(0,))
    elif name in ("w_gate", "w_up", "w_up_gate") and nd == 2:  # (d, f)
        take(1, "model", tp)
        fsdp_any(exclude=(1,))
    elif name == "w_down" and nd == 2:  # (f, d)
        take(0, "model", tp)
        fsdp_any(exclude=(0,))
    elif name == "router":  # (d, E) — replicated over model (tiny)
        fsdp_any()
    elif name in ("w_x", "w_gate_branch"):  # RG-LRU in-projections (d, w)
        take(1, "model", tp)
        fsdp_any(exclude=(1,))
    elif name in ("w_rgate", "w_igate"):  # (w, w)
        take(1, "model", tp)
        fsdp_any(exclude=(1,))
    elif name == "w_out":  # (w, d)
        take(0, "model", tp)
        fsdp_any(exclude=(0,))
    elif name in ("w_up", "w_ogate") and nd == 2:  # mLSTM (d, di)
        take(1, "model", tp)
        fsdp_any(exclude=(1,))
    elif name in ("wq", "wk", "wv") and nd == 2:  # mLSTM (di, di)
        take(1, "model", tp)
        fsdp_any(exclude=(1,))
    elif name == "w_if":  # (di, 2h)
        fsdp_any()
    elif name == "w_in" and nd == 3:  # sLSTM (d, 4, d)
        take(2, "model", tp)
        fsdp_any(exclude=(2,))
    elif name == "r" and nd == 4:  # sLSTM recurrent (4, h, dh, dh)
        take(1, "model", tp)
    elif name == "conv":  # (W, width)
        take(1, "model", tp)
    else:
        fsdp_any()
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params, mesh: Mesh, mode: str = "train"):
    """Pytree of NamedSharding matching ``params`` (works on
    ShapeDtypeStructs too — used by the dry-run)."""
    tp = mesh.shape.get("model", 1)
    fsdp = mesh.shape.get("data", 1)

    def spec_of(path, leaf):
        # stacked layers add a leading reps dim — strip it for rule matching
        shape = leaf.shape
        ps = _path_str(path)
        stacked = "/reps/" in f"/{ps}/" or re.search(r"(^|/)reps(/|$)", ps)
        if stacked and len(shape) >= 2:
            inner = _leaf_spec(ps, shape[1:], tp, fsdp, mode)
            return NamedSharding(mesh, P(None, *inner))
        return NamedSharding(mesh, _leaf_spec(ps, shape, tp, fsdp, mode))

    return jax.tree_util.tree_map_with_path(spec_of, params)


# ---------------------------------------------------------------------------
# Batch / cache sharding
# ---------------------------------------------------------------------------


def batch_specs(mesh: Mesh, *, seq_sharded: bool = False):
    """NamedSharding for (B, S[, d]) batch inputs: batch over DP axes,
    optionally sequence over "model" (SP for long-context shapes)."""
    dp = dp_axes(mesh)
    seq = "model" if seq_sharded else None
    return NamedSharding(mesh, P(dp, seq))


def cache_spec_overrides(mesh: Mesh, batch: int):
    """Sharding for KV-cache leaves (B, c, KV, dh) and recurrent states:
    batch over DP where divisible, cache sequence dim over "model"."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bspec = dp if batch % max(dp_size, 1) == 0 else None

    def spec_of(path, leaf):
        nd = len(leaf.shape)
        ps = _path_str(path)
        name = ps.rsplit("/", 1)[-1]
        if name == "pos":
            return NamedSharding(mesh, P())
        # structure-first: cache leaves are (B, ...) for tail blocks and
        # (R, B, ...) for the stacked rep caches
        stacked = "/reps/" in f"/{ps}/"
        b_dim = 1 if stacked else 0
        if nd <= b_dim or leaf.shape[b_dim] != batch:
            return NamedSharding(mesh, P(*([None] * nd)))
        spec = [None] * nd
        spec[b_dim] = bspec
        if name in ("k", "v", "ck", "cv") and nd >= b_dim + 4:
            # (.., B, c, KV, dh): shard the cache length over model
            c_len = leaf.shape[b_dim + 1]
            if c_len % mesh.shape.get("model", 1) == 0:
                spec[b_dim + 1] = "model"
        return NamedSharding(mesh, P(*spec))

    return spec_of


# ---------------------------------------------------------------------------
# Activation constraints (contextvar so model code stays mesh-agnostic)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _ActCtx:
    mesh: Mesh
    seq_sharded: bool = False


_ACTIVE: Optional[_ActCtx] = None


@contextlib.contextmanager
def activation_ctx(mesh: Mesh, *, seq_sharded: bool = False):
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = _ActCtx(mesh, seq_sharded)
    try:
        yield
    finally:
        _ACTIVE = prev


def constrain_attn(x: jnp.ndarray, head_dim: int, seq_dim: int) -> jnp.ndarray:
    """Shard dim 0 over DP and either the head dim (TP, preferred) or the
    query-sequence dim (context/sequence parallelism fallback when the
    head count does not divide the model axis — e.g. gemma3's H=8 on a
    16-way axis) over "model".  Used on attention-internal tensors and
    scan carries, whose sharding GSPMD will not otherwise infer — without
    this the blocked-attention backward replicates (B, H, S, T)-sized
    buffers over the model axis."""
    ctx = _ACTIVE
    if ctx is None:
        return x
    dp = dp_axes(ctx.mesh)
    dp_size = 1
    for a in dp:
        dp_size *= ctx.mesh.shape[a]
    tp = ctx.mesh.shape.get("model", 1)
    spec = [None] * x.ndim
    if x.shape[0] % max(dp_size, 1) == 0:
        spec[0] = dp
    if x.shape[head_dim] % tp == 0:
        spec[head_dim] = "model"
    elif x.shape[seq_dim] % tp == 0:
        spec[seq_dim] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec))
    )


def constrain_kv_cache(x: jnp.ndarray) -> jnp.ndarray:
    """Pin a (B, c, KV, dh) cache tensor to its canonical layout: batch
    over DP, cache length over "model".  Applied inside decode/prefill so
    GSPMD never round-trips the cache through another layout (without it
    the partitioner falls back to replicating the full 88-layer stack —
    'involuntary full rematerialization')."""
    ctx = _ACTIVE
    if ctx is None or x.ndim != 4:
        return x
    dp = dp_axes(ctx.mesh)
    dp_size = 1
    for a in dp:
        dp_size *= ctx.mesh.shape[a]
    tp = ctx.mesh.shape.get("model", 1)
    spec = [None] * 4
    if x.shape[0] % max(dp_size, 1) == 0:
        spec[0] = dp
    if x.shape[1] % tp == 0:
        spec[1] = "model"
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, P(*spec)))


def constrain_ep(x: jnp.ndarray) -> jnp.ndarray:
    """Expert-parallel layout for MoE grouped buffers (E, C, ...): experts
    over "model", capacity over DP when divisible.  Without this the
    (E, C, d) dispatch buffer replicates on every chip."""
    ctx = _ACTIVE
    if ctx is None:
        return x
    dp = dp_axes(ctx.mesh)
    dp_size = 1
    for a in dp:
        dp_size *= ctx.mesh.shape[a]
    tp = ctx.mesh.shape.get("model", 1)
    spec = [None] * x.ndim
    if x.shape[0] % tp == 0:
        spec[0] = "model"
    if x.ndim >= 2 and x.shape[1] % max(dp_size, 1) == 0:
        spec[1] = dp
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, P(*spec)))


def constrain_activation(x: jnp.ndarray, kind: str = "btd") -> jnp.ndarray:
    """Annotate an activation.  kind: 'btd' residual stream, 'btv' logits,
    'btd_save' remat-saved carry (sequence-sharded storage: the layer scan
    gathers it back at block entry, so compute stays batch-sharded while
    the 88-layer saved-carry footprint shrinks by the model-axis size),
    'btd_gather' forced batch-only layout.  No-op outside an
    activation_ctx."""
    ctx = _ACTIVE
    if ctx is None:
        return x
    if kind == "btd_save":
        if not ctx.seq_sharded:
            kind = "btd"
        else:
            dp = dp_axes(ctx.mesh)
            dp_size = 1
            for a in dp:
                dp_size *= ctx.mesh.shape[a]
            tp = ctx.mesh.shape.get("model", 1)
            bspec = dp if x.shape[0] % max(dp_size, 1) == 0 else None
            seq = "model" if x.ndim >= 2 and x.shape[1] % tp == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(ctx.mesh, P(bspec, seq, None))
            )
    if kind == "btd_gather":
        dp = dp_axes(ctx.mesh)
        dp_size = 1
        for a in dp:
            dp_size *= ctx.mesh.shape[a]
        bspec = dp if x.shape[0] % max(dp_size, 1) == 0 else None
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(ctx.mesh, P(bspec, None, None))
        )
    dp = dp_axes(ctx.mesh)
    dp_size = 1
    for a in dp:
        dp_size *= ctx.mesh.shape[a]
    b = x.shape[0]
    bspec = dp if b % max(dp_size, 1) == 0 else None
    seq = None
    if ctx.seq_sharded and x.ndim >= 2 and x.shape[1] % ctx.mesh.shape.get("model", 1) == 0:
        seq = "model"
    if kind == "btd" and x.ndim == 3:
        spec = P(bspec, seq, None)
    elif kind == "btv" and x.ndim == 3:
        vshard = x.shape[2] % ctx.mesh.shape.get("model", 1) == 0
        # an axis can appear once per spec: vocab sharding wins over SP
        spec = P(bspec, None if vshard else seq, "model" if vshard else None)
    elif x.ndim >= 1:
        spec = P(*([bspec] + [None] * (x.ndim - 1)))
    else:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
