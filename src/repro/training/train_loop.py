"""train_step factory: remat, mixed precision, µbatch accumulation,
optional gradient compression — one jit-able pure function.

The factory closes over static config and returns

    train_step(state, batch) -> (state, metrics)

with ``state = {"params", "opt", "residual"?}`` a pytree the launcher
shards via distributed.sharding.param_specs.  Microbatching runs as a
``lax.scan`` over gradient accumulation slices so the HLO stays compact
at any accumulation depth.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model_zoo import LM

from .compression import CompressionConfig, compress_grads, init_residual
from .optimizer import AdamWConfig, adamw_update, init_opt_state

__all__ = ["TrainConfig", "make_train_step", "init_train_state"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1  # gradient accumulation
    dtype: str = "bfloat16"  # compute dtype
    remat: bool = True
    compression: CompressionConfig = CompressionConfig()

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


def init_train_state(lm: LM, key, cfg: TrainConfig) -> Dict[str, Any]:
    params = lm.init(key)
    state = {"params": params, "opt": init_opt_state(params)}
    if cfg.compression.enable:
        state["residual"] = init_residual(params)
    return state


def _split_micro(batch, n: int):
    """(B, ...) -> (n, B/n, ...) for scan-based accumulation."""
    def r(x):
        b = x.shape[0]
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(lm: LM, cfg: TrainConfig) -> Callable:
    dtype = cfg.compute_dtype

    def loss_fn(params, micro):
        return lm.loss_fn(params, micro, dtype=dtype, remat=cfg.remat)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if cfg.microbatches > 1:
            micro = _split_micro(batch, cfg.microbatches)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, loss_sum), _ = jax.lax.scan(acc_body, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / cfg.microbatches, g_sum)
            loss = loss_sum / cfg.microbatches
        else:
            (loss, _), grads = grad_fn(params, batch)

        if cfg.compression.enable:
            grads, residual = compress_grads(
                grads, state["residual"], cfg.compression
            )

        params2, opt2, om = adamw_update(cfg.opt, params, grads, state["opt"])
        new_state = {"params": params2, "opt": opt2}
        if cfg.compression.enable:
            new_state["residual"] = residual
        metrics = {"loss": loss, **om}
        return new_state, metrics

    return train_step
