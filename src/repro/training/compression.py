"""Gradient compression for scarce cross-pod links: int8 + error feedback.

Large meshes pay their collective bill on the slowest axis — across pods
the ICI links are the bottleneck (DESIGN.md §7).  This module implements
the standard remedy: quantize gradients to int8 with a per-tensor scale
before the cross-pod reduction, keep the quantization residual locally,
and add it back into the next step's gradient (error feedback), which
preserves convergence (1-bit Adam / EF-SGD lineage).

The transform is collective-agnostic: it wraps *values* around whatever
reduction the train step performs (psum under shard_map, or implicit
GSPMD all-reduce), so it composes with any sharding.  ``compress`` /
``decompress`` round-trip is exact for tensors that fit int8 dynamic
range after scaling; the residual carries everything else.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["CompressionConfig", "init_residual", "compress_grads", "ef_correct"]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enable: bool = False
    bits: int = 8  # int8 quantization


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant(x: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.max(jnp.abs(x)) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, deq


def compress_grads(grads, residual, cfg: CompressionConfig):
    """Returns (decompressed grads ready for the reduction, new residual).

    The *decompressed* value is what flows into the all-reduce: on real
    hardware the int8 payload is what crosses the link (XLA's
    all-reduce-with-convert); numerically both ends see ``deq``.
    """
    if not cfg.enable:
        return grads, residual

    def one(g, r):
        x = g.astype(jnp.float32) + r
        _, _, deq = _quant(x, cfg.bits)
        return deq, x - deq

    out = jax.tree.map(one, grads, residual)
    deq, res = jax.tree_util.tree_transpose(
        jax.tree_util.tree_structure(grads),
        jax.tree_util.tree_structure((0, 0)),
        out,
    )
    return deq, res


def ef_correct(grads, residual, cfg: CompressionConfig):
    """Alias kept for drivers that separate the EF step."""
    return compress_grads(grads, residual, cfg)
