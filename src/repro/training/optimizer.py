"""AdamW with fused update, global-norm clipping, and µbatch accumulation.

Implemented directly (no optax dependency in this container) as pure
pytree transforms.  Optimizer state is f32 (m, v) regardless of param
dtype; the update is a single fused tree_map (one pass over HBM per
tensor, which is what the v5e memory system wants).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), grads), g


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """One fused AdamW step.  Returns (params, state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the per-leaf 3-tuples (tree_transpose is tuple-safe even when
    # the params tree itself contains tuples)
    params2, m2, v2 = jax.tree_util.tree_transpose(
        jax.tree_util.tree_structure(params),
        jax.tree_util.tree_structure((0, 0, 0)),
        out,
    )
    new_state = {"m": m2, "v": v2, "step": step}
    return params2, new_state, {"grad_norm": gnorm, "lr": lr}
