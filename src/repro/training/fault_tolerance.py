"""Fault tolerance for 1000+-node fleets: preemption-safe checkpoint
cadence, bounded retry on transient failures, straggler detection.

The contract with the launcher (launch/train.py):

  * ``CheckpointPolicy`` — periodic + on-signal saves; restore from the
    newest COMMITted step (mid-write crashes leave no partial state).
  * ``retrying`` — wraps a step call; transient errors (the JAX analogues
    of a lost worker: RuntimeError / device errors) are retried from the
    last known-good state up to ``max_retries`` with the step function
    re-jitted, which is exactly the restart-from-checkpoint flow a real
    cluster controller performs, compressed into-process.
  * ``StragglerMonitor`` — rolling per-step wall-time statistics; a step
    slower than ``threshold × median`` flags its host.  On a real fleet
    the flag feeds the scheduler (hot-spare swap); here it feeds metrics
    and is unit-tested against synthetic delay injection.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

__all__ = ["CheckpointPolicy", "retrying", "StragglerMonitor", "Preemption"]


class Preemption(Exception):
    """Raised into the training loop when a preemption signal arrives."""


@dataclasses.dataclass
class CheckpointPolicy:
    every_steps: int = 100
    keep_last: int = 3
    save_on_preemption: bool = True

    def should_save(self, step: int) -> bool:
        return self.every_steps > 0 and step > 0 and step % self.every_steps == 0

    def gc(self, ckpt_dir: str):
        """Delete all but the newest ``keep_last`` committed checkpoints."""
        from .checkpoint import list_steps, _step_dir
        import shutil

        steps = list_steps(ckpt_dir)
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(_step_dir(ckpt_dir, s), ignore_errors=True)


# Bounded retry moved to the jax-free resilience layer (PR 10) so the
# store and serving paths share the same jittered-backoff policy; this
# re-export keeps every training call site unchanged.  Defaults are
# backward-compatible: base_delay=0 means no sleeping, same attempt
# count, same terminal RuntimeError.
from repro.resilience.retry import retrying  # noqa: E402,F401


class StragglerMonitor:
    """Rolling median step-time; flags steps slower than threshold×median.

    On a multi-host fleet each host runs one of these and reports via the
    metrics stream; persistent flags on one host = straggler -> the
    controller swaps it for a hot spare.  The detection logic (the part a
    framework owns) is fully exercised here.
    """

    def __init__(self, window: int = 50, threshold: float = 3.0):
        self.window = window
        self.threshold = threshold
        self._times: Deque[float] = deque(maxlen=window)
        self.flags: List[int] = []
        self._step = 0
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> Tuple[float, bool]:
        dt = time.monotonic() - (self._t0 or time.monotonic())
        flagged = False
        if len(self._times) >= max(self.window // 5, 3):
            med = sorted(self._times)[len(self._times) // 2]
            flagged = dt > self.threshold * med
            if flagged:
                self.flags.append(self._step)
        self._times.append(dt)
        self._step += 1
        return dt, flagged

    def observe(self, dt: float) -> bool:
        """Direct-injection variant for tests and offline analysis."""
        self._t0 = time.monotonic() - dt
        _, flagged = self.stop()
        return flagged


def install_preemption_handler(flag: Dict[str, bool]):
    """SIGTERM -> set flag; the train loop checkpoints and exits cleanly."""

    def handler(signum, frame):
        flag["preempted"] = True

    try:
        signal.signal(signal.SIGTERM, handler)
    except ValueError:
        pass  # non-main thread (tests)
    return flag
