"""Sharded checkpointing: atomic, manifest-driven, elastic on restore.

Layout (one directory per step):

    ckpt_dir/step_000123/
        manifest.json       — tree structure, dtypes, shapes, step, config
        arrays/<idx>.npy    — one file per leaf (host-gathered)
        COMMIT              — written last; a checkpoint without COMMIT is
                              incomplete and ignored (atomicity against
                              preemption mid-write)

Restore is **elastic**: arrays are loaded host-side and re-placed with
``jax.device_put`` under whatever sharding the *new* mesh prescribes, so a
job can come back on a different topology (fewer/more chips) — the
fault-tolerance contract for large fleets.  A checkpoint is pure data; no
mesh information is baked in.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "list_steps"]


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:09d}")


def save_checkpoint(ckpt_dir: str, step: int, state, extra: Optional[Dict] = None):
    """Host-gather every leaf and write atomically (tmp dir + rename +
    COMMIT marker)."""
    leaves, treedef = jax.tree_util.tree_flatten(state)
    os.makedirs(ckpt_dir, exist_ok=True)
    final = _step_dir(ckpt_dir, step)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_ckpt_")
    arrays_dir = os.path.join(tmp, "arrays")
    os.makedirs(arrays_dir)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [],
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(arrays_dir, f"{i}.npy"), arr)
        manifest["leaves"].append(
            {"idx": i, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_steps(ckpt_dir: str) -> List[int]:
    """Committed checkpoint steps, ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, name, "COMMIT")
        ):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(
    ckpt_dir: str,
    step: int,
    like,
    shardings=None,
) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedSharding for elastic re-placement on the current mesh."""
    d = _step_dir(ckpt_dir, step)
    if not os.path.exists(os.path.join(d, "COMMIT")):
        raise FileNotFoundError(f"no committed checkpoint at {d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    if manifest["n_leaves"] != len(like_leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"expected {len(like_leaves)} — structure mismatch"
        )
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    )
    out = []
    for i, ref in enumerate(like_leaves):
        arr = np.load(os.path.join(d, "arrays", f"{i}.npy"))
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != expected {ref.shape}"
            )
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
