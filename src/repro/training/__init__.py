"""Training layer: optimizer, train-step factory, checkpoint, fault tolerance."""

from .optimizer import AdamWConfig, init_opt_state, adamw_update
from .train_loop import TrainConfig, make_train_step, init_train_state
from .checkpoint import save_checkpoint, restore_checkpoint, latest_step
from .compression import CompressionConfig
