"""Serving driver: prefill/decode step factories + continuous batching.

``make_serve_fns`` returns jit-able pure step functions (the things the
dry-run lowers); ``ServeLoop`` is the host-side driver implementing
*correct* continuous batching over fixed decode slots:

  * **Per-slot prefill** — admission runs the new request's prompt as a
    batch-1 prefill and scatters the resulting cache into ONLY its own
    batch row (``LM.insert_slot_caches``); other in-flight slots' KV is
    never touched.
  * **Per-slot positions** — every decode step carries a (B,) position
    vector, so requests with different prompt lengths each attend at
    their own position (``models.attention.decode_step`` masks per row).
  * **On-device sampling** — batched greedy / max-subtracted temperature
    sampling under ``jax.random``; per-(request, token) keys make a
    request's sampled continuation independent of what else is
    co-scheduled in the batch.
  * **Bounded admission queue with counted load-shed** — ``enqueue``
    parks requests up to ``ServeConfig.queue_capacity``; at capacity the
    newest request is rejected with a structured
    :class:`~repro.resilience.RequestResult` (``status=SHED``, counted
    in ``stats``), never an exception.  ``step`` admits into free slots
    and retires sequences on EOS or ``max_new``, so the loop drains a
    request stream without manual slot management.

Request lifecycle hardening (PR 10, ROADMAP §Resilience invariants):
every request the loop ever sees terminates with exactly one
``RequestResult`` in ``results`` carrying a definite status —
DONE / FAILED / TIMEOUT / SHED / CANCELLED.  Per-request deadlines
(decode-step and wall budgets) retire cleanly as TIMEOUT; ``cancel``
retires as CANCELLED; and ``step`` contains faults at three levels:
an admission fault retires only that request FAILED, a batched-decode
fault leaves ALL state untouched (the identical step is retried next
call — decode is a pure function of (caches, toks, pos), so the retry
is bitwise; a consecutive-failure budget retires the active set FAILED
instead of spinning), and a per-slot retirement fault retires only that
slot's request.  The chaos gate (``benchmarks/chaos_bench.py``) holds
the PR 4 slot-isolation contract under fire: surviving requests' token
streams are bitwise equal to a fault-free run.

Per-request outputs are bit-identical to a solo run of the same request
(locked by tests/test_serving.py): decode compute is row-independent and
admission writes are slot-local.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import LM
from repro.resilience import faults
from repro.resilience.fallback import fallback_counters
from repro.resilience.lifecycle import RequestResult, RequestStatus

from .gust_serve import GustServeConfig, decode_step_gust, gustify

__all__ = [
    "ServeConfig",
    "make_serve_fns",
    "make_sampler",
    "ServeLoop",
    "RequestResult",
    "RequestStatus",
]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int
    seq_len: int  # cache capacity
    dtype: str = "bfloat16"
    temperature: float = 0.0  # 0 = greedy
    eos_id: Optional[int] = None  # retire a slot when it samples this token
    queue_capacity: int = 64  # bounded admission queue (full -> counted SHED)
    gust: Optional[GustServeConfig] = None  # None = dense decode
    # default per-request deadlines (enqueue/submit may override per
    # request); None = unbounded.  max_steps_per_request counts decode
    # steps while admitted; max_seconds_per_request is a wall budget.
    max_steps_per_request: Optional[int] = None
    max_seconds_per_request: Optional[float] = None
    # consecutive contained decode-step failures tolerated before the
    # active set is retired FAILED instead of retrying forever
    max_step_failures: int = 8

    @property
    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


def make_serve_fns(lm: LM, cfg: ServeConfig, gust_tree=None):
    """Returns (prefill_fn, decode_fn, init_caches_fn), all pure.

    ``init_caches_fn`` takes an optional batch override (the serve loop
    prefills new requests at batch=1); ``decode_fn`` takes ``pos`` as a
    (B,) int32 vector of per-slot positions (a scalar still works for
    homogeneous callers such as the dry-run).
    """
    dtype = cfg.jnp_dtype

    def init_caches(batch: Optional[int] = None):
        return lm.init_caches(batch or cfg.batch, cfg.seq_len, dtype)

    def prefill_fn(params, batch, caches):
        return lm.prefill(params, batch, caches, dtype=dtype)

    if cfg.gust is not None and cfg.gust.enable:
        if gust_tree is None:
            raise ValueError("gust serving requires a gustify()/dryrun tree")

        def decode_fn(params, caches, tokens, pos):
            return decode_step_gust(
                lm, params, gust_tree, caches, tokens, pos,
                cfg=cfg.gust, dtype=dtype,
            )
    else:

        def decode_fn(params, caches, tokens, pos):
            return lm.decode_step(params, caches, tokens, pos, dtype=dtype)

    return prefill_fn, decode_fn, init_caches


def make_sampler(temperature: float) -> Callable:
    """Jitted batched sampler:
    (logits (B, V), base_key, rid_step (B, 2) int32) -> (B,) int32.

    Greedy at ``temperature <= 0``.  The temperature path subtracts the
    per-row max before scaling, so logits of magnitude ~1e3+ stay finite
    (the host-side ``np.exp(logits / T)`` it replaces overflowed to
    inf/NaN); sampling itself is ``jax.random.categorical``'s Gumbel
    trick, which never exponentiates the logits.  Row r's key is
    ``fold_in(fold_in(base_key, rid_step[r, 0]), rid_step[r, 1])`` —
    per-(request id, token index), derived INSIDE the jit so a decode
    step costs one fused call, not 2B host-side fold_in dispatches.
    """

    def sample(logits, base_key, rid_step):
        logits = logits.astype(jnp.float32)
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        z = (logits - logits.max(axis=-1, keepdims=True)) / temperature

        def one(row, rs):
            key = jax.random.fold_in(jax.random.fold_in(base_key, rs[0]), rs[1])
            return jax.random.categorical(key, row)

        return jax.vmap(one)(z, rid_step).astype(jnp.int32)

    return jax.jit(sample)


@dataclasses.dataclass
class _Slot:
    active: bool = False
    request_id: int = -1
    pos: int = 0
    generated: Optional[List[int]] = None
    max_new: int = 0
    steps: int = 0  # decode steps taken while this request held the slot
    deadline_steps: Optional[int] = None
    deadline_s: Optional[float] = None
    admitted_t: float = 0.0


class ServeLoop:
    """Host-side continuous-batching driver over fixed decode slots.

    Requests are (prompt_tokens, max_new_tokens).  ``submit`` admits
    immediately into a free slot (raising when none is free);
    ``enqueue`` parks the request in the bounded admission queue and
    ``step``/``run_to_completion`` admit as slots free up.  Each
    admission prefills ONLY its own slot (batch-1 prefill + slot-local
    cache insert) and each decode step advances every active slot one
    token at that slot's own position.
    """

    def __init__(self, lm: LM, params, cfg: ServeConfig, seed: int = 0):
        self.lm, self.params, self.cfg = lm, params, cfg
        gust_tree = None
        if cfg.gust is not None and cfg.gust.enable:
            gust_tree = gustify(lm, params, cfg.gust)
        self.gust_tree = gust_tree
        pre, dec, init = make_serve_fns(lm, cfg, gust_tree)
        self._prefill = jax.jit(pre)
        self._decode = jax.jit(dec)
        # donate the full cache: insertion scatters one batch row and the
        # caller rebinds self.caches, so XLA can update in place instead
        # of copying every layer's KV per admission (no-op on CPU)
        self._insert = jax.jit(lm.insert_slot_caches, donate_argnums=0)
        self._sampler = make_sampler(cfg.temperature)
        self.caches = init()
        # immutable batch-1 cache template reused by every admission
        # (prefill is pure, so the template is never mutated)
        self._cache_template_b1 = init(1)
        self.slots = [_Slot() for _ in range(cfg.batch)]
        self._base_key = jax.random.PRNGKey(seed)
        self._next_id = 0
        self.pending: Deque[Tuple] = collections.deque()
        self.completed: Dict[int, List[int]] = {}
        self.results: Dict[int, RequestResult] = {}
        self._decode_failures = 0  # consecutive contained step failures
        self.stats = {
            "decode_steps": 0, "active_slot_steps": 0, "prefills": 0,
            "done": 0, "failed": 0, "timeouts": 0, "shed": 0,
            "cancelled": 0, "decode_retries": 0,
        }

    # -- lifecycle bookkeeping ---------------------------------------------
    def _retire(
        self,
        rid: int,
        status: RequestStatus,
        tokens: Optional[List[int]] = None,
        *,
        reason: str = "",
        steps: int = 0,
    ) -> RequestResult:
        """Record the one terminal result for ``rid`` (first status
        wins) and bump its status counter; DONE additionally lands in
        ``completed`` for back-compat."""
        if rid in self.results:
            return self.results[rid]
        res = RequestResult(rid, status, list(tokens or []), reason, steps)
        self.results[rid] = res
        key = {
            RequestStatus.DONE: "done",
            RequestStatus.FAILED: "failed",
            RequestStatus.TIMEOUT: "timeouts",
            RequestStatus.SHED: "shed",
            RequestStatus.CANCELLED: "cancelled",
        }[status]
        self.stats[key] = self.stats.get(key, 0) + 1
        if status is RequestStatus.DONE:
            self.completed[rid] = res.tokens
        return res

    # -- admission ---------------------------------------------------------
    def enqueue(
        self,
        prompt: np.ndarray,
        max_new: int,
        *,
        deadline_steps: Optional[int] = None,
        deadline_s: Optional[float] = None,
    ) -> int:
        """Park one request in the bounded admission queue.  Returns id.

        At ``queue_capacity`` the request is load-shed (reject-newest
        backpressure): it still gets an id, but terminates immediately
        with a counted ``status=SHED`` result instead of ever being
        admitted — structured rejection, not an exception, so a bursty
        client can't crash the serving path."""
        rid = self._next_id
        self._next_id += 1
        if len(self.pending) >= self.cfg.queue_capacity:
            self._retire(
                rid, RequestStatus.SHED,
                reason=f"admission queue full (capacity {self.cfg.queue_capacity})",
            )
            return rid
        self.pending.append((
            rid, np.asarray(prompt, np.int32), int(max_new),
            deadline_steps, deadline_s,
        ))
        return rid

    def submit(
        self,
        prompt: np.ndarray,
        max_new: int,
        *,
        deadline_steps: Optional[int] = None,
        deadline_s: Optional[float] = None,
    ) -> int:
        """Admit one request into a free slot NOW; runs its prefill.
        Still raises when no slot is free (an immediate-admission caller
        wants the error); an admission *fault* retires the request
        FAILED instead of propagating."""
        free = [i for i, s in enumerate(self.slots) if not s.active]
        if not free:
            raise RuntimeError("no free slots")
        rid = self._next_id
        self._next_id += 1
        try:
            self._admit(
                free[0], rid, np.asarray(prompt, np.int32), int(max_new),
                deadline_steps, deadline_s,
            )
        except Exception as err:  # contained: only this request fails
            self._retire(
                rid, RequestStatus.FAILED, reason=f"admission failed: {err!r}"
            )
        return rid

    def cancel(self, rid: int) -> bool:
        """Explicitly cancel a pending or active request.  Retires it
        with ``status=CANCELLED`` (keeping any tokens generated so far)
        and frees its slot; returns False when ``rid`` is unknown or
        already terminal."""
        if rid in self.results:
            return False
        for entry in self.pending:
            if entry[0] == rid:
                self.pending.remove(entry)
                self._retire(rid, RequestStatus.CANCELLED, reason="cancelled while queued")
                return True
        for i, s in enumerate(self.slots):
            if s.active and s.request_id == rid:
                self._retire(
                    rid, RequestStatus.CANCELLED, s.generated,
                    reason="cancelled while active", steps=s.steps,
                )
                self.slots[i] = _Slot()
                return True
        return False

    def _admit(
        self,
        i: int,
        rid: int,
        prompt: np.ndarray,
        max_new: int,
        deadline_steps: Optional[int] = None,
        deadline_s: Optional[float] = None,
    ):
        """Per-slot prefill: batch-1 prompt pass + slot-local cache insert.

        The prefill jit keys on the exact prompt length, so each distinct
        length in the stream compiles once (exact-length prefill is what
        keeps admission bit-identical to a solo run; length bucketing
        needs masked prefill — see ROADMAP open items)."""
        faults.trip("serve.admit", tag=str(rid))
        logits, one = self._prefill(
            self.params,
            {"tokens": jnp.asarray(prompt)[None]},
            self._cache_template_b1,
        )
        self.caches = self._insert(self.caches, one, i)
        first = int(self._sample_rows(logits[:, -1], [(rid, 0)])[0])
        self.stats["prefills"] += 1
        slot = _Slot(
            True, rid, int(prompt.shape[0]), [first], max_new,
            deadline_steps=(
                deadline_steps if deadline_steps is not None
                else self.cfg.max_steps_per_request
            ),
            deadline_s=(
                deadline_s if deadline_s is not None
                else self.cfg.max_seconds_per_request
            ),
            admitted_t=time.monotonic(),
        )
        if self._finished(slot, first):
            self._retire(rid, RequestStatus.DONE, slot.generated)
        else:
            self.slots[i] = slot

    def _admit_from_queue(self):
        free = [i for i, s in enumerate(self.slots) if not s.active]
        while free and self.pending:
            rid, prompt, max_new, dl_steps, dl_s = self.pending.popleft()
            try:
                self._admit(free.pop(0), rid, prompt, max_new, dl_steps, dl_s)
            except Exception as err:
                # Contained: a faulted admission retires ONLY this
                # request (the slot was never activated, and a partial
                # batch-1 cache insert into an inactive row cannot
                # influence other rows' decode — attention is per-row).
                self._retire(
                    rid, RequestStatus.FAILED,
                    reason=f"admission failed: {err!r}",
                )
            # _admit may complete the request instantly (EOS/max_new=1),
            # leaving the slot free — recompute instead of assuming
            free = [i for i, s in enumerate(self.slots) if not s.active]

    # -- sampling ----------------------------------------------------------
    def _sample_rows(self, logits_rows, rid_step: List[Tuple[int, int]]):
        """Sample one token per row.  ``rid_step[r] = (request_id, token
        index)`` seeds row r's key, making each request's sampled
        continuation independent of which other requests share the batch."""
        return np.asarray(self._sampler(
            logits_rows, self._base_key, jnp.asarray(rid_step, jnp.int32)
        ))

    def _finished(self, slot: _Slot, token: int) -> bool:
        if self.cfg.eos_id is not None and token == self.cfg.eos_id:
            return True
        return len(slot.generated) >= slot.max_new + 1

    # -- decode ------------------------------------------------------------
    def _expire_deadlines(self):
        """Retire every active slot whose decode-step or wall budget has
        expired: clean TIMEOUT with the tokens generated so far."""
        now = time.monotonic()
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            over_steps = s.deadline_steps is not None and s.steps >= s.deadline_steps
            over_wall = s.deadline_s is not None and now - s.admitted_t >= s.deadline_s
            if over_steps or over_wall:
                why = (
                    f"step budget {s.deadline_steps} exhausted" if over_steps
                    else f"wall budget {s.deadline_s}s exhausted"
                )
                self._retire(
                    s.request_id, RequestStatus.TIMEOUT, s.generated,
                    reason=why, steps=s.steps,
                )
                self.slots[i] = _Slot()

    def step(self) -> int:
        """Admit from the queue, then one decode step for all active
        slots (each at its own position); returns #active after retirement.

        No exception escapes: admission faults retire one request
        (``_admit_from_queue``), and a batched decode/sample fault is
        contained HERE with all state untouched — ``self.caches`` is
        only rebound after both succeed, and decode is a pure function
        of (caches, toks, pos), so the retried step next call is bitwise
        identical to the one that faulted.  After
        ``cfg.max_step_failures`` consecutive contained failures the
        active set retires FAILED (definite status) instead of spinning.
        """
        self._admit_from_queue()
        self._expire_deadlines()
        active = [i for i, s in enumerate(self.slots) if s.active]
        if not active:
            return 0
        toks = np.zeros((self.cfg.batch, 1), np.int32)
        pos = np.zeros((self.cfg.batch,), np.int32)
        for i in active:
            toks[i, 0] = self.slots[i].generated[-1]
            pos[i] = self.slots[i].pos
        try:
            faults.trip("serve.decode")
            logits, new_caches = self._decode(
                self.params, self.caches, jnp.asarray(toks), jnp.asarray(pos)
            )
            sampled = self._sample_rows(
                logits[:, 0],
                [
                    # inactive rows sample garbage that is discarded; any
                    # non-negative key seed works (fold_in is uint32)
                    (s.request_id, len(s.generated)) if s.active else (0, 0)
                    for s in self.slots
                ],
            )
        except Exception as err:  # sanctioned containment (GUST-L07 site)
            self.stats["decode_retries"] = self.stats.get("decode_retries", 0) + 1
            self._decode_failures += 1
            if self._decode_failures >= self.cfg.max_step_failures:
                for i in active:
                    s = self.slots[i]
                    self._retire(
                        s.request_id, RequestStatus.FAILED, s.generated,
                        reason=(
                            f"decode failed {self._decode_failures} "
                            f"consecutive steps: {err!r}"
                        ),
                        steps=s.steps,
                    )
                    self.slots[i] = _Slot()
                self._decode_failures = 0
            return len([s for s in self.slots if s.active])
        self._decode_failures = 0
        self.caches = new_caches
        self.stats["decode_steps"] += 1
        self.stats["active_slot_steps"] += len(active)
        for i in active:
            s = self.slots[i]
            try:
                faults.trip("serve.slot", tag=str(s.request_id))
                tok = int(sampled[i])
                s.generated.append(tok)
                s.pos += 1
                s.steps += 1
                if self._finished(s, tok):
                    self._retire(
                        s.request_id, RequestStatus.DONE, s.generated,
                        steps=s.steps,
                    )
                    self.slots[i] = _Slot()
            except Exception as err:  # contained: one slot, one request
                self._retire(
                    s.request_id, RequestStatus.FAILED, s.generated,
                    reason=f"slot fault: {err!r}", steps=s.steps,
                )
                self.slots[i] = _Slot()
        return len([s for s in self.slots if s.active])

    @property
    def occupancy(self) -> float:
        """Mean fraction of decode-slot work spent on live requests."""
        steps = self.stats["decode_steps"]
        if steps == 0:
            return 0.0
        return self.stats["active_slot_steps"] / (steps * self.cfg.batch)

    def resilience_stats(self) -> Dict[str, int]:
        """Lifecycle + degradation counters in one snapshot: terminal
        statuses, contained decode retries, and the process-wide
        fallback counters (``repro.resilience.fallback_counters``) —
        what ``launch/serve.py`` and the chaos benchmark report."""
        out = {
            k: self.stats.get(k, 0)
            for k in (
                "done", "failed", "timeouts", "shed", "cancelled",
                "decode_retries",
            )
        }
        out.update({f"fallback_{k}": v for k, v in fallback_counters.items()})
        return out

    def run_to_completion(self, max_steps: int = 10_000):
        """Drain the admission queue and every active slot.  Bounded:
        with per-request deadlines and the consecutive-failure budget,
        every admitted request reaches a terminal status in finitely
        many steps even under persistent faults."""
        for _ in range(max_steps):
            if self.step() == 0 and not self.pending:
                return
