"""Serving driver: prefill/decode step factories + batched request loop.

``make_serve_fns`` returns jit-able pure step functions (the things the
dry-run lowers); ``ServeLoop`` is the host-side driver that batches
requests, runs prefill for new arrivals and decode for in-flight ones,
applies greedy/temperature sampling, and retires finished sequences —
continuous batching in its simplest correct form.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import LM

from .gust_serve import GustServeConfig, decode_step_gust, gustify

__all__ = ["ServeConfig", "make_serve_fns", "ServeLoop"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int
    seq_len: int  # cache capacity
    dtype: str = "bfloat16"
    temperature: float = 0.0  # 0 = greedy
    gust: Optional[GustServeConfig] = None  # None = dense decode

    @property
    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


def make_serve_fns(lm: LM, cfg: ServeConfig, gust_tree=None):
    """Returns (prefill_fn, decode_fn, init_caches_fn), all pure."""
    dtype = cfg.jnp_dtype

    def init_caches():
        return lm.init_caches(cfg.batch, cfg.seq_len, dtype)

    def prefill_fn(params, batch, caches):
        return lm.prefill(params, batch, caches, dtype=dtype)

    if cfg.gust is not None and cfg.gust.enable:
        if gust_tree is None:
            raise ValueError("gust serving requires a gustify()/dryrun tree")

        def decode_fn(params, caches, tokens, pos):
            return decode_step_gust(
                lm, params, gust_tree, caches, tokens, pos,
                cfg=cfg.gust, dtype=dtype,
            )
    else:

        def decode_fn(params, caches, tokens, pos):
            return lm.decode_step(params, caches, tokens, pos, dtype=dtype)

    return prefill_fn, decode_fn, init_caches


@dataclasses.dataclass
class _Slot:
    active: bool = False
    request_id: int = -1
    pos: int = 0
    generated: Optional[List[int]] = None
    max_new: int = 0


class ServeLoop:
    """Host-side continuous-batching driver over fixed decode slots.

    Requests are (prompt_tokens, max_new_tokens).  For simplicity each
    admission runs a (batched) prefill of the whole current slot set; the
    decode step then advances every active slot one token per call.
    """

    def __init__(self, lm: LM, params, cfg: ServeConfig, seed: int = 0):
        self.lm, self.params, self.cfg = lm, params, cfg
        gust_tree = None
        if cfg.gust is not None and cfg.gust.enable:
            gust_tree = gustify(lm, params, cfg.gust)
        self.gust_tree = gust_tree
        pre, dec, init = make_serve_fns(lm, cfg, gust_tree)
        self._prefill = jax.jit(pre)
        self._decode = jax.jit(dec)
        self.caches = init()
        self.slots = [_Slot() for _ in range(cfg.batch)]
        self._rng = np.random.default_rng(seed)
        self._next_id = 0
        self.completed: Dict[int, List[int]] = {}

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        """Admit one request into a free slot; runs its prefill. Returns id."""
        free = [i for i, s in enumerate(self.slots) if not s.active]
        if not free:
            raise RuntimeError("no free slots")
        i = free[0]
        rid = self._next_id
        self._next_id += 1
        b = self.cfg.batch
        toks = np.zeros((b, prompt.shape[0]), np.int32)
        toks[i] = prompt
        logits, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}, self.caches
        )
        # NOTE: batched prefill refreshes every slot's cache with the padded
        # prompt; correct single-request flow (slot admission happens one at
        # a time between decode bursts).  Multi-slot isolation is exercised
        # in tests via one-request-at-a-time admission.
        self.caches = caches
        first = self._sample(np.asarray(logits)[i, -1])
        self.slots[i] = _Slot(True, rid, int(prompt.shape[0]), [int(first)], max_new)
        return rid

    def _sample(self, logits_row: np.ndarray) -> int:
        if self.cfg.temperature <= 0:
            return int(np.argmax(logits_row))
        p = np.exp(logits_row / self.cfg.temperature)
        p /= p.sum()
        return int(self._rng.choice(p.shape[0], p=p))

    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        active = [i for i, s in enumerate(self.slots) if s.active]
        if not active:
            return 0
        toks = np.zeros((self.cfg.batch, 1), np.int32)
        for i in active:
            toks[i, 0] = self.slots[i].generated[-1]
        pos = max(self.slots[i].pos for i in active)
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(toks), jnp.int32(pos)
        )
        logits = np.asarray(logits)
        for i in active:
            s = self.slots[i]
            s.generated.append(self._sample(logits[i, 0]))
            s.pos += 1
            if len(s.generated) >= s.max_new + 1:
                self.completed[s.request_id] = s.generated
                self.slots[i] = _Slot()
        return len([s for s in self.slots if s.active])

    def run_to_completion(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if self.step() == 0:
                return
