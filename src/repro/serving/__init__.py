"""Serving layer: KV-cache policy, serve loop, GUST-sparse decode."""

from .kv_cache import CachePolicy, cache_specs, cache_shardings, cache_bytes
from .serve_loop import (
    RequestResult,
    RequestStatus,
    ServeConfig,
    ServeLoop,
    make_sampler,
    make_serve_fns,
)
from .gust_serve import GustServeConfig, gustify, decode_step_gust, dryrun_specs
