"""KV-cache management for serving: allocation, sharding, accounting.

The cache *structure* lives with the blocks (models/attention.py defines
dense and ring-buffer caches; models/recurrent.py the recurrent states;
models/transformer.py stacks them).  This module owns the serving-side
concerns: sizing/accounting per (arch × shape), dtype policy, and the
NamedSharding placement used by the dry-run and the serve driver.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.distributed.sharding import cache_spec_overrides
from repro.models.model_zoo import LM

__all__ = ["CachePolicy", "cache_specs", "cache_shardings", "cache_bytes"]


@dataclasses.dataclass(frozen=True)
class CachePolicy:
    dtype: str = "bfloat16"  # KV dtype (recurrent f32 states keep f32)

    @property
    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


def cache_specs(lm: LM, batch: int, seq_len: int, policy: CachePolicy = CachePolicy()):
    """ShapeDtypeStruct pytree of the serving cache (no allocation)."""
    return jax.eval_shape(
        lambda: lm.init_caches(batch, seq_len, policy.jnp_dtype)
    )


def cache_shardings(lm: LM, mesh: Mesh, batch: int, seq_len: int,
                    policy: CachePolicy = CachePolicy()):
    """NamedSharding pytree: batch over DP, cache sequence over model."""
    specs = cache_specs(lm, batch, seq_len, policy)
    spec_of = cache_spec_overrides(mesh, batch)
    return jax.tree_util.tree_map_with_path(spec_of, specs)


def cache_bytes(lm: LM, batch: int, seq_len: int,
                policy: CachePolicy = CachePolicy()) -> int:
    """Total cache footprint (all layers, all sequences).

    Host-side accounting stays host-side: ``math.prod`` over the Python
    shape tuple, in arbitrary-precision ints.  (The previous
    ``jnp.prod(jnp.array(shape))`` dispatched device work per leaf and
    overflowed int32 for caches above 2**31 elements — i.e. exactly the
    123B-scale configs this helper exists to size.)
    """
    specs = cache_specs(lm, batch, seq_len, policy)
    return sum(
        jnp.dtype(x.dtype).itemsize * math.prod(x.shape)
        for x in jax.tree.leaves(specs)
    )
