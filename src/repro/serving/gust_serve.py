"""GUST-sparse serving: the paper's technique as a first-class feature.

Decode-time LM inference is matvec-dominated.  ``gustify`` converts a
trained model's MLP weights into GUST plans (magnitude pruning ->
``repro.plan`` -> packed blocks), **once**, at weight-load time — the
paper's §3.3/§5.3 amortization ("the scheduling for each matrix only
needs to be computed once ... even if the vector changes").
``decode_step_gust`` then mirrors the model's decode step but routes each
layer's MLP matvecs through :meth:`GustPlan.spmm`.

Layer stacking is :meth:`GustPlan.stack`: per-layer packed artifacts are
equalized to a uniform stream length (padded layout: uniform C_pad via
``repad_to``; ragged layout: uniform block count via ``repad_to_blocks``)
so the leaves stack along the reps axis and the layer scan stays a single
compact HLO — the GUST plan is literally part of the serving checkpoint.
With ``GustServeConfig.ragged`` the stack holds ragged color-block
streams, so skewed pruned matrices stop streaming dead padding cycles
through every decode step.  The wire format is the plan's
``to_spec``/``from_spec`` leaves/meta codec, shared with ``dryrun_specs``.

Applies to pattern-length-1 dense archs (phi3/yi/mistral-large/llava/
gemma3 would need per-position stacks — gemma3 and the MoE archs run the
per-expert variant documented in DESIGN.md §5).  ``dryrun_specs`` sizes
the schedule stream from the paper's Eq. 9 bound
(:meth:`GustPlan.spec_for`) so the 512-chip dry-run lowers the GUST
decode path without running the scheduler.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.bounds import expected_colors_bound
from repro.core.formats import COOMatrix
from repro.core.gust_linear import prune_by_magnitude
from repro.core.packing import default_cache, stacked_leaf_specs
from repro.core.plan import GustPlan, PlanConfig, plan
from repro.core.plan_store import PlanStore
from repro.models import transformer as T
from repro.models.layers import apply_norm
from repro.models.model_zoo import LM
from repro.resilience.fallback import fallback_counters

__all__ = ["GustServeConfig", "gustify", "decode_step_gust", "dryrun_specs"]

_MLP_MATS = ("w_gate", "w_up", "w_down")


@dataclasses.dataclass(frozen=True)
class GustServeConfig:
    enable: bool = True
    density: float = 0.1
    gust_length: int = 256
    load_balance: bool = True
    method: str = "fast"
    use_kernel: bool = False  # Pallas path (interpret on CPU) vs XLA path
    compact: bool = False  # bf16 values + int16 indices: 12 -> 6 B/slot,
    # the TPU analogue of the paper's (64 + log l)-bit packed stream
    ragged: bool = False  # ragged color-block streams: per-layer stacks
    # hold only real cycle blocks (pruned LLM matrices are skewed — the
    # padded layout streams every window at the heaviest window's C_pad)
    gather: str = "auto"  # Buffer-Filler mode: "resident" (whole x in
    # VMEM), "local" (stream only each block's S_blk referenced x tiles —
    # the wide-d_ff fast path), or "auto" (measured locality ratio)
    plan_store: Optional[str] = None  # directory for the persistent
    # PlanStore: warm server starts load packed plans off disk instead of
    # re-paying the edge coloring (the paper's §5.3 amortization extended
    # across process boundaries)
    store_verify: str = "off"  # "load" runs the static artifact verifier
    # (repro.analysis) on every store read: a failing artifact is a
    # counted corrupt miss and gets re-packed — never served, never an
    # exception
    mats: Tuple[str, ...] = _MLP_MATS

    @property
    def value_dtype(self):
        return jnp.bfloat16 if self.compact else jnp.float32

    @property
    def index_dtype(self):
        return jnp.int16 if self.compact else jnp.int32

    @property
    def plan_config(self) -> PlanConfig:
        """These knobs in the one canonical spelling — every serving path
        (gustify, decode, dry-run specs) plans through this config."""
        return PlanConfig(
            l=self.gust_length,
            colorer=self.method,
            load_balance=self.load_balance,
            c_blk=8,
            layout="ragged" if self.ragged else "padded",
            backend="pallas" if self.use_kernel else "jnp",
            gather=self.gather,
            interpret=True,
            value_dtype=jnp.dtype(self.value_dtype).name,
            index_dtype=jnp.dtype(self.index_dtype).name,
        )


def _prune_to_coo(w: np.ndarray, cfg: GustServeConfig) -> COOMatrix:
    """w: (d_in, d_out) layer weight; GUST computes y = M x with
    M = w^T (d_out, d_in)."""
    m = prune_by_magnitude(np.asarray(w, np.float32).T, cfg.density)
    rows, cols = np.nonzero(m)
    return COOMatrix(m.shape, rows.astype(np.int64), cols.astype(np.int64),
                     m[rows, cols].astype(np.float32))


def _plan_cycles(p: GustPlan) -> int:
    """Cycle count for stats: store-loaded plans carry no GustSchedule
    (the coloring never ran), only the persisted ``summary`` sidecar."""
    if p.sched is not None:
        return int(p.sched.cycles)
    if p.summary is not None and "cycles" in p.summary:
        return int(p.summary["cycles"])
    return -1  # loaded artifact predates summary sidecars


def gustify(lm: LM, params, cfg: GustServeConfig, *,
            store: Optional[PlanStore] = None) -> Dict:
    """Build stacked GUST plans for every rep-layer MLP matrix.

    Returns ``{"mats": {name: {"leaves": {...(R, ...)}, "meta": static
    layout tuple}}, "stats": {...}}`` — per matrix, the
    :meth:`GustPlan.stack` of one plan per layer.

    With ``cfg.plan_store`` (or an explicit ``store``), plans read
    through the persistent :class:`PlanStore`: a warm start rebuilds
    every stacked artifact from disk with zero coloring work.
    """
    if len(lm.stack.pattern) != 1 or lm.stack.pattern[0].kind != "attn_mlp":
        raise ValueError(
            "gustify currently targets homogeneous dense stacks "
            f"(got pattern {[b.kind for b in lm.stack.pattern]})"
        )
    if store is None and cfg.plan_store is not None:
        store = PlanStore(cfg.plan_store, verify=cfg.store_verify)
    mlp_params = params["stack"]["reps"][0]["mlp"]
    reps = lm.stack.reps
    pc = cfg.plan_config
    out: Dict = {"mats": {}, "stats": {}}
    fb0 = dict(fallback_counters)  # attribute downgrades to this build
    for name in cfg.mats:
        w_stack = np.asarray(mlp_params[name])  # (R, d_in, d_out)
        # one plan per layer, through the content-keyed cache: re-gustifying
        # the same weights (e.g. a compact re-export) reuses the schedule
        plans = [
            plan(_prune_to_coo(w_stack[r], cfg), pc, cache=default_cache,
                 store=store)
            for r in range(reps)
        ]
        stacked = GustPlan.stack(plans)
        out["mats"][name] = stacked
        # uniform stream size after stacking = max over layers (stack()
        # equalizes to it); read off the artifacts, not meta positions
        if cfg.ragged:
            size_stat = {
                "num_blocks": max(p.artifact.num_blocks for p in plans)
            }
        else:
            size_stat = {"c_pad": max(p.artifact.c_pad for p in plans)}
        leaves = stacked["leaves"]
        nnz = int(np.count_nonzero(np.asarray(leaves["m_blk"])))
        slots = leaves["m_blk"].size
        out["stats"][name] = {
            "cycles_per_layer": [_plan_cycles(p) for p in plans],
            "stream_utilization": nnz / max(slots, 1),
            "streamed_slots": int(slots),
            **size_stat,
        }
    if store is not None:
        out["stats"]["plan_store"] = store.stats()
    fb = {k: v - fb0[k] for k, v in fallback_counters.items() if v - fb0[k]}
    if fb:
        # degradations applied while building (e.g. stored -> fresh on a
        # failing store read): counted, surfaced, never an exception
        out["stats"]["fallbacks"] = fb
    return out


def _gust_mlp(gust_slice, metas, x, mlp_kind: str, cfg: GustServeConfig):
    """x: (B, 1, d).  SwiGLU/GeGLU with every matvec through GUST."""
    b = x.shape[0]
    xt = x[:, 0].T.astype(jnp.float32)  # (d, B)
    act = jax.nn.silu if mlp_kind == "swiglu" else jax.nn.gelu
    pc = cfg.plan_config

    def mv(name, v):
        # one layer's slice of the stacked plan, rebuilt through the
        # leaves/meta codec — the same GustPlan route every entry point takes
        p = GustPlan.from_spec(
            {"leaves": gust_slice[name], "meta": metas[name]}, config=pc
        )
        return p.spmm(v)

    g = act(mv("w_gate", xt).astype(jnp.float32))
    u = mv("w_up", xt).astype(jnp.float32)
    h = (g * u)  # (f, B)
    y = mv("w_down", h)  # (d, B)
    return y.T[:, None, :].astype(x.dtype)  # (B, 1, d)


def decode_step_gust(lm: LM, params, gust, caches, tokens, pos, *,
                     cfg: GustServeConfig, dtype=jnp.bfloat16):
    """Mirror of LM.decode_step with the per-layer MLP routed through GUST.

    ``gust`` is the pytree produced by :func:`gustify` (or dryrun_specs).
    ``pos`` is a scalar or (B,) vector of per-slot positions — the GUST
    path shares the continuous-batching machinery (slot-local caches,
    per-row attention masks) with the dense decode, so mixed-length
    request batches serve correctly through ``ServeLoop`` here too.
    """
    sc = lm.stack
    bc = sc.pattern[0]
    x = lm._embed_tokens(params, tokens, dtype)
    metas = {k: v["meta"] for k, v in gust["mats"].items()}
    gust_leaves = {k: v["leaves"] for k, v in gust["mats"].items()}

    def body(x, xs):
        p_sl, c_sl, g_sl = xs
        h = apply_norm(p_sl["ln_attn"], x, kind=bc.norm_kind)
        from repro.models import attention as A

        y, cache = A.decode_step(p_sl["attn"], h, bc.attn, c_sl, pos)
        x = x + y
        h = apply_norm(p_sl["ln_mlp"], x, kind=bc.norm_kind)
        x = x + _gust_mlp(g_sl, metas, h, bc.mlp_kind, cfg)
        return x, cache

    x, rep_caches = jax.lax.scan(
        body, x, (params["stack"]["reps"][0], caches["reps"][0], gust_leaves)
    )
    new_caches = {"reps": (rep_caches,), "tail": caches["tail"]}
    logits = lm._logits(params, x)
    return logits, new_caches


def dryrun_specs(lm: LM, cfg: GustServeConfig) -> Dict:
    """ShapeDtypeStruct stand-in for the gust pytree, with the scheduled
    stream sized from Eq. 9: C = E[colors] bound at the pruned density —
    the dry-run proof that the GUST decode path lowers and fits.  Each
    matrix is a :meth:`GustPlan.spec_for` plan (honoring ``cfg.ragged``:
    a ragged config dry-runs the ragged program, the bound sizing every
    window's block count), stacked across reps by the shared codec."""
    reps = lm.stack.reps
    d = lm.cfg.d_model
    f = lm.cfg.d_ff
    pc = cfg.plan_config
    out: Dict = {"mats": {}, "stats": {}}
    for name in cfg.mats:
        m, n = (d, f) if name == "w_down" else (f, d)
        proto = GustPlan.spec_for(
            m, n, pc, colors=expected_colors_bound(n, cfg.density, pc.l)
        )
        spec = proto.to_spec()
        out["mats"][name] = {
            "leaves": stacked_leaf_specs(proto.artifact, reps),
            "meta": spec["meta"],
        }
    return out
