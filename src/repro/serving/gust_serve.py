"""GUST-sparse serving: the paper's technique as a first-class feature.

Decode-time LM inference is matvec-dominated.  ``gustify`` converts a
trained model's MLP weights into the GUST scheduled format (magnitude
pruning -> edge-coloring schedule -> packed blocks), **once**, at
weight-load time — the paper's §3.3/§5.3 amortization ("the scheduling
for each matrix only needs to be computed once ... even if the vector
changes").  ``decode_step_gust`` then mirrors the model's decode step but
routes each layer's MLP matvecs through the GUST SpMV path.

Layer stacking: packed schedules are padded to a *uniform* color count
C_pad across layers (``PackedSchedule.repad_to``) so the leaves stack
along the reps axis and the layer scan stays a single compact HLO — the
GUST schedule is literally part of the serving checkpoint.  With
``GustServeConfig.ragged`` the stack holds ragged color-block streams
instead: layers are equalized to the longest layer's *block count*
(``RaggedSchedule.repad_to_blocks``) rather than the heaviest window's
C_pad, so skewed pruned matrices stop streaming dead padding cycles
through every decode step.  The ragged→packed conversion, the leaves/meta
codec shared with ``dryrun_specs``, and the content-keyed schedule cache
all live in ``repro.core.packing`` (see its module docstring for the
format lifecycle and invariants).

Applies to pattern-length-1 dense archs (phi3/yi/mistral-large/llava/
gemma3 would need per-position stacks — gemma3 and the MoE archs run the
per-expert variant documented in DESIGN.md §5).  ``dryrun_specs`` sizes
the schedule stream from the paper's Eq. 9 bound so the 512-chip dry-run
lowers the GUST decode path without running the scheduler.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.bounds import expected_colors_bound
from repro.core.formats import COOMatrix
from repro.core.gust_linear import prune_by_magnitude
from repro.core.packing import (
    default_cache,
    packed_from_leaves,
    packed_leaves,
    packed_meta,
    packed_spec,
    ragged_from_leaves,
    ragged_leaves,
    ragged_meta,
    ragged_spec,
    schedule_packed,
    stacked_leaf_specs,
)
from repro.kernels.ops import gust_spmm
from repro.models import transformer as T
from repro.models.layers import apply_norm
from repro.models.model_zoo import LM

__all__ = ["GustServeConfig", "gustify", "decode_step_gust", "dryrun_specs"]

_MLP_MATS = ("w_gate", "w_up", "w_down")


@dataclasses.dataclass(frozen=True)
class GustServeConfig:
    enable: bool = True
    density: float = 0.1
    gust_length: int = 256
    load_balance: bool = True
    method: str = "fast"
    use_kernel: bool = False  # Pallas path (interpret on CPU) vs XLA path
    compact: bool = False  # bf16 values + int16 indices: 12 -> 6 B/slot,
    # the TPU analogue of the paper's (64 + log l)-bit packed stream
    ragged: bool = False  # ragged color-block streams: per-layer stacks
    # hold only real cycle blocks (pruned LLM matrices are skewed — the
    # padded layout streams every window at the heaviest window's C_pad)
    mats: Tuple[str, ...] = _MLP_MATS

    @property
    def value_dtype(self):
        return jnp.bfloat16 if self.compact else jnp.float32

    @property
    def index_dtype(self):
        return jnp.int16 if self.compact else jnp.int32


def _prune_to_coo(w: np.ndarray, cfg: GustServeConfig) -> COOMatrix:
    """w: (d_in, d_out) layer weight; GUST computes y = M x with
    M = w^T (d_out, d_in)."""
    m = prune_by_magnitude(np.asarray(w, np.float32).T, cfg.density)
    rows, cols = np.nonzero(m)
    return COOMatrix(m.shape, rows.astype(np.int64), cols.astype(np.int64),
                     m[rows, cols].astype(np.float32))


def gustify(lm: LM, params, cfg: GustServeConfig) -> Dict:
    """Build stacked packed schedules for every rep-layer MLP matrix.

    Returns ``{"mats": {name: {"leaves": {...(R, ...)}, "meta": PackedSchedule
    prototype}}, "stats": {...}}``.
    """
    if len(lm.stack.pattern) != 1 or lm.stack.pattern[0].kind != "attn_mlp":
        raise ValueError(
            "gustify currently targets homogeneous dense stacks "
            f"(got pattern {[b.kind for b in lm.stack.pattern]})"
        )
    mlp_params = params["stack"]["reps"][0]["mlp"]
    reps = lm.stack.reps
    out: Dict = {"mats": {}, "stats": {}}
    for name in cfg.mats:
        w_stack = np.asarray(mlp_params[name])  # (R, d_in, d_out)
        packs = []
        cycles = []
        for r in range(reps):
            # schedule + pack through the content-keyed cache: re-gustifying
            # the same weights (e.g. a compact re-export) reuses the schedule
            coo = _prune_to_coo(w_stack[r], cfg)
            if cfg.ragged:
                sched, packed = default_cache.ragged_packed(
                    coo, cfg.gust_length, load_balance=cfg.load_balance,
                    method=cfg.method, c_blk=8,
                    value_dtype=cfg.value_dtype, index_dtype=cfg.index_dtype,
                )
            else:
                sched, packed = schedule_packed(
                    coo, cfg.gust_length, load_balance=cfg.load_balance,
                    method=cfg.method, c_blk=8,
                    value_dtype=cfg.value_dtype, index_dtype=cfg.index_dtype,
                )
            cycles.append(sched.cycles)
            packs.append(packed)
        if cfg.ragged:
            # equalize stream length so leaves stack: grow every layer to
            # the longest layer's block count with all-padding blocks
            t_uniform = max(p.num_blocks for p in packs)
            packs = [p.repad_to_blocks(t_uniform) for p in packs]
            leaf_fn, meta = ragged_leaves, ragged_meta(packs[0])
            size_stat = {"num_blocks": t_uniform}
        else:
            # re-pad every layer to the uniform c_pad so leaves stack
            c_uniform = max(p.c_pad for p in packs)
            packs = [p.repad_to(c_uniform) for p in packs]
            leaf_fn, meta = packed_leaves, packed_meta(packs[0])
            size_stat = {"c_pad": c_uniform}
        leaves = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[leaf_fn(p) for p in packs]
        )
        out["mats"][name] = {"leaves": leaves, "meta": meta}
        nnz = int(np.count_nonzero(np.asarray(leaves["m_blk"])))
        slots = leaves["m_blk"].size
        out["stats"][name] = {
            "cycles_per_layer": cycles,
            "stream_utilization": nnz / max(slots, 1),
            "streamed_slots": int(slots),
            **size_stat,
        }
    return out


def _gust_mlp(gust_slice, metas, x, mlp_kind: str, cfg: GustServeConfig):
    """x: (B, 1, d).  SwiGLU/GeGLU with every matvec through GUST."""
    b = x.shape[0]
    xt = x[:, 0].T.astype(jnp.float32)  # (d, B)
    act = jax.nn.silu if mlp_kind == "swiglu" else jax.nn.gelu

    def mv(name, v):
        meta = metas[name]
        rebuild = ragged_from_leaves if meta[0] == "ragged" else packed_from_leaves
        return gust_spmm(
            rebuild(gust_slice[name], meta), v, use_kernel=cfg.use_kernel
        )

    g = act(mv("w_gate", xt).astype(jnp.float32))
    u = mv("w_up", xt).astype(jnp.float32)
    h = (g * u)  # (f, B)
    y = mv("w_down", h)  # (d, B)
    return y.T[:, None, :].astype(x.dtype)  # (B, 1, d)


def decode_step_gust(lm: LM, params, gust, caches, tokens, pos, *,
                     cfg: GustServeConfig, dtype=jnp.bfloat16):
    """Mirror of LM.decode_step with the per-layer MLP routed through GUST.

    ``gust`` is the pytree produced by :func:`gustify` (or dryrun_specs).
    """
    sc = lm.stack
    bc = sc.pattern[0]
    x = lm._embed_tokens(params, tokens, dtype)
    metas = {k: v["meta"] for k, v in gust["mats"].items()}
    gust_leaves = {k: v["leaves"] for k, v in gust["mats"].items()}

    def body(x, xs):
        p_sl, c_sl, g_sl = xs
        h = apply_norm(p_sl["ln_attn"], x, kind=bc.norm_kind)
        from repro.models import attention as A

        y, cache = A.decode_step(p_sl["attn"], h, bc.attn, c_sl, pos)
        x = x + y
        h = apply_norm(p_sl["ln_mlp"], x, kind=bc.norm_kind)
        x = x + _gust_mlp(g_sl, metas, h, bc.mlp_kind, cfg)
        return x, cache

    x, rep_caches = jax.lax.scan(
        body, x, (params["stack"]["reps"][0], caches["reps"][0], gust_leaves)
    )
    new_caches = {"reps": (rep_caches,), "tail": caches["tail"]}
    logits = lm._logits(params, x)
    return logits, new_caches


def dryrun_specs(lm: LM, cfg: GustServeConfig) -> Dict:
    """ShapeDtypeStruct stand-in for the gust pytree, with the scheduled
    stream sized from Eq. 9: C = E[colors] bound at the pruned density —
    the dry-run proof that the GUST decode path lowers and fits.  Honors
    ``cfg.ragged``: a ragged config dry-runs the ragged program (the
    Eq. 9 bound sizes every window's block count, so the spec'd stream is
    ``W * ceil(C/c_blk)`` blocks)."""
    reps = lm.stack.reps
    d = lm.cfg.d_model
    f = lm.cfg.d_ff
    l = cfg.gust_length
    out: Dict = {"mats": {}, "stats": {}}
    for name in cfg.mats:
        m, n = (d, f) if name == "w_down" else (f, d)
        c = expected_colors_bound(n, cfg.density, l)
        if cfg.ragged:
            bpw = max(-(-int(np.ceil(c)) // 8), 1)
            num_blocks = max(-(-m // l), 1) * bpw
            proto = ragged_spec(m, n, l, num_blocks, c_blk=8,
                                value_dtype=cfg.value_dtype,
                                index_dtype=cfg.index_dtype)
            meta = ragged_meta(proto)
        else:
            c_pad = max(-(-int(np.ceil(c)) // 8) * 8, 8)
            proto = packed_spec(m, n, l, c_pad, value_dtype=cfg.value_dtype,
                                index_dtype=cfg.index_dtype)
            meta = packed_meta(proto)
        out["mats"][name] = {
            "leaves": stacked_leaf_specs(proto, reps),
            "meta": meta,
        }
    return out
