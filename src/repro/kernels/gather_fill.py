"""Buffer-Filler vector-gather Pallas kernel.

The paper's Buffer Filler holds the input vector on-chip and fills each
multiplier's vector FIFO with ``v[Col_sch[c, j]]`` (§3.3, "Streaming the
Inputs").  This kernel is the standalone TPU analogue: the vector sits
resident in VMEM in segment-major layout and the scheduled column indices
stream through, producing the gathered vector stream ``V_sch``.

It exists as its own kernel for two reasons: (a) it lets the gather logic
be tested/swept independently of the routing matmul, and (b) it is the
building block for the *unfused* execution path (gather kernel -> XLA
elementwise/segment ops), which is the honest TPU analogue of GUST's
hardware pipeline stages when fusion is disabled.

Gather mechanism (same as the flagship kernel): the scheduler only ever
maps a column to its own lane (``off == lane``) or — after load-balance
step 3 — to the lane-reversed slot (``off == l-1-lane``), so the gather
decomposes into a one-hot over the ``S = ceil(n/l)`` column segments plus
a straight/flipped select.  No random access is ever issued.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["make_gather_fill"]


def _kernel(col_ref, xs_ref, out_ref, *, l, seg_count, c_blk, b):
    col_blk = col_ref[...].astype(jnp.int32)  # (C_blk, l) int
    xs = xs_ref[...].astype(jnp.float32)  # (S, l, B)
    xf = xs[:, ::-1, :]  # lane-reversed layout, derived in-kernel

    seg = col_blk // l
    off = col_blk - seg * l
    lane = jax.lax.broadcasted_iota(jnp.int32, (c_blk, l), 1)
    flip = (off != lane).astype(jnp.float32)

    seg_t = seg.T  # (l, C_blk)
    onehot = (
        seg_t[:, :, None]
        == jax.lax.broadcasted_iota(jnp.int32, (l, c_blk, seg_count), 2)
    ).astype(jnp.float32)
    dnums = (((2,), (0,)), ((0,), (1,)))
    g_s = jax.lax.dot_general(onehot, xs, dnums, preferred_element_type=jnp.float32)
    g_f = jax.lax.dot_general(onehot, xf, dnums, preferred_element_type=jnp.float32)
    fsel = flip.T[:, :, None]
    out = g_s * (1.0 - fsel) + g_f * fsel  # (l, C_blk, B)
    out_ref[...] = out.transpose(1, 0, 2)  # (C_blk, l, B)


@functools.lru_cache(maxsize=256)
def make_gather_fill(
    total_rows: int,
    l: int,
    seg_count: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
):
    """pallas_call producing ``V_sch`` of shape (total_rows, l, B) from
    ``Col_sch`` (total_rows, l) and the VMEM-resident vector.  Memoized on
    geometry like :func:`repro.kernels.gust_spmv.make_gust_spmv`."""
    if total_rows % c_blk:
        raise ValueError("total_rows must be a multiple of c_blk")
    grid = (total_rows // c_blk,)
    kernel = functools.partial(_kernel, l=l, seg_count=seg_count, c_blk=c_blk, b=b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c_blk, l), lambda i: (i, 0)),
            pl.BlockSpec((seg_count, l, b), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((c_blk, l, b), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((total_rows, l, b), jnp.float32),
        interpret=interpret,
    )
