"""Pallas TPU kernel for GUST SpGEMM: color-block outer products over
condensed B rows with a VMEM dense-row accumulator.

SpArch organizes sparse×sparse as streamed outer products with condensed
partial-result merging; GUST's color-block stream is already exactly that
schedule — each ``(c_blk, l)`` block is a conflict-free set of multiply
lanes whose "vector gather" generalizes from one x element to one row of
B.  This kernel runs ``C = A @ B`` from A's packed schedule stream (either
layout, viewed as the ragged block stream) and B in the *condensed-row*
format built by :func:`repro.core.spgemm.condense_rows`: every row of B
padded to ``k_max`` ``(value, column)`` pairs, ``(R, k_max)`` value and
column planes.  Streaming condensed B costs ``R·k_max·8`` bytes instead
of the ``R·n_out·4`` a densified B would — the SpArch condensing win.

Per grid step (one stream block, scalar-prefetch steering identical to
``gust_spmv_ragged``):

  1. **condensed gather** — one-hot over B's ``R`` rows on the MXU fetches
     the block's ``(c_blk·l, k_max)`` value/column pairs (columns ride the
     same matmul as exact small integers in f32);
  2. **multipliers** — VPU multiply by the block's A values;
  3. **merge** — each slot's partial products densify into its output row
     through a weighted one-hot over ``n_out`` columns, then the crossbar
     routing matmul scatters slot rows onto adder rows; the result
     accumulates in a ``(l, n_out)`` **VMEM scratch row accumulator**
     that integrates across the window's blocks and dumps to the output
     tile on the window's last block (the paper's integrate-then-dump,
     with a dense row per adder instead of a scalar).

Collision-freedom of the edge coloring is what keeps the routing matmul
exact, just as in SpMV: within a cycle each adder row receives at most
one partial row.  The pure-jnp oracle is
:func:`repro.kernels.ref.gust_spgemm_ref`; kernel and oracle agree
bitwise on exact-arithmetic (integer-valued) inputs where every
summation order produces the same floats, and to float tolerance
otherwise (their merge orders differ — segment-sum vs blocked one-hot).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["make_gust_spgemm"]


def _kernel(bw_ref, bs_ref, m_ref, col_ref, row_ref, bv_ref, bc_ref, y_ref,
            acc_scr, *, l, r_rows, k_max, n_out, c_blk):
    t = pl.program_id(0)
    w = bw_ref[t]
    slots = c_blk * l

    m_blk = m_ref[...].astype(jnp.float32)  # (c_blk, l)
    col_flat = col_ref[...].astype(jnp.int32).reshape(slots)
    row_flat = row_ref[...].astype(jnp.int32).reshape(slots)

    # ---- condensed gather: one-hot over B's rows on the MXU -------------
    onehot_r = (
        col_flat[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (slots, r_rows), 1)
    ).astype(jnp.float32)  # (slots, R)
    dnums = (((1,), (0,)), ((), ()))
    bv = jax.lax.dot_general(
        onehot_r, bv_ref[...].astype(jnp.float32), dnums,
        preferred_element_type=jnp.float32,
    )  # (slots, k_max)
    # column ids ride the same one-hot matmul as exact f32 integers
    # (n_out < 2^24), then cast back
    bc = jax.lax.dot_general(
        onehot_r, bc_ref[...].astype(jnp.float32), dnums,
        preferred_element_type=jnp.float32,
    ).astype(jnp.int32)  # (slots, k_max)

    # ---- multipliers (VPU) ----------------------------------------------
    partial = m_blk.reshape(slots, 1) * bv  # (slots, k_max)

    # ---- merge: densify each slot's partial row, route onto adders ------
    onehot_n = (
        bc[:, :, None]
        == jax.lax.broadcasted_iota(jnp.int32, (slots, k_max, n_out), 2)
    ).astype(jnp.float32)
    slot_rows = jnp.sum(partial[:, :, None] * onehot_n, axis=1)  # (slots, n_out)
    onehot_row = (
        row_flat[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (slots, l), 1)
    ).astype(jnp.float32)
    acc = jax.lax.dot_general(
        onehot_row, slot_rows, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (l, n_out)

    # ---- VMEM scratch row accumulator: integrate across the window's
    # blocks, dump on its last one ----------------------------------------
    first = t == bs_ref[w]

    @pl.when(first)
    def _init():
        acc_scr[...] = acc

    @pl.when(jnp.logical_not(first))
    def _accum():
        acc_scr[...] += acc

    @pl.when(t == bs_ref[w + 1] - 1)
    def _dump():
        y_ref[...] = acc_scr[...][None]


@functools.lru_cache(maxsize=256)
def make_gust_spgemm(
    num_blocks: int,
    num_windows: int,
    l: int,
    r_rows: int,
    k_max: int,
    n_out: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
):
    """Build the SpGEMM scalar-prefetch pallas_call for one (A stream
    geometry, condensed-B geometry) pair.

    Call signature of the returned function:
    ``fn(block_window, block_starts, m_blk, col_blk, row_blk, b_vals,
    b_cols)`` with the A stream blocks ``(num_blocks * c_blk, l)``
    (``col_blk`` holds ORIGINAL A columns — B row ids), condensed B
    planes ``(r_rows, k_max)`` (f32 values, int32 columns), returning
    ``(num_windows, l, n_out)`` f32 per-window dense row accumulators.

    Both packed layouts execute here: a padded artifact is just the
    ragged stream whose every window owns ``C_pad/c_blk`` blocks
    (``block_window``/``block_starts`` synthesized from the strides), and
    its all-padding blocks contribute exactly zero.

    BlockSpecs:
      * A stream (m/col/row): HBM -> VMEM tiles of (c_blk, l), one real
        block per grid step;
      * condensed B (values + columns): full-array VMEM residency —
        ``R·k_max·8`` bytes, the condensed footprint;
      * y: the (1, l, n_out) tile of ``block_window[t]``, written once
        per window when the scratch accumulator dumps.

    Memoized on geometry, like every other kernel builder.
    """
    grid = (num_blocks,)
    sched_spec = pl.BlockSpec((c_blk, l), lambda t, bw, bs: (t, 0))
    b_spec = pl.BlockSpec((r_rows, k_max), lambda t, bw, bs: (0, 0))
    out_spec = pl.BlockSpec((1, l, n_out), lambda t, bw, bs: (bw[t], 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[sched_spec, sched_spec, sched_spec, b_spec, b_spec],
        out_specs=out_spec,
        scratch_shapes=[pltpu.VMEM((l, n_out), jnp.float32)],
    )
    kernel = functools.partial(
        _kernel, l=l, r_rows=r_rows, k_max=k_max, n_out=n_out, c_blk=c_blk
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, n_out), jnp.float32),
        interpret=interpret,
    )
