"""Pallas TPU kernels for the GUST hot path (validated via interpret=True).

  gust_spmv.py   -- flagship: fused gather + one-hot MXU routing SpMV
  gather_fill.py -- standalone Buffer-Filler vector gather
  ops.py         -- jit'd public wrappers + packed-format utilities
  ref.py         -- pure-jnp oracles (same block semantics, no Pallas)
"""

from .ops import PackedSchedule, pack_schedule, packed_spec, gust_spmm
