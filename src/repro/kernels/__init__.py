"""Pallas TPU kernels for the GUST hot path (validated via interpret=True).

  gust_spmv.py        -- flagship: fused gather + one-hot MXU routing SpMV
                         over the padded (W, C_pad/c_blk) grid
  gust_spmv_ragged.py -- ragged color-block streaming variant: 1-D
                         scalar-prefetch grid over real blocks only
  gather_fill.py      -- standalone Buffer-Filler vector gather
  ops.py              -- jit'd public wrappers + padded/ragged dispatch
  ref.py              -- pure-jnp oracles (same block semantics, no Pallas)
"""

from .ops import (
    PackedSchedule,
    RaggedSchedule,
    pack_schedule,
    packed_spec,
    gust_spmm,
    gust_spmm_auto,
)
