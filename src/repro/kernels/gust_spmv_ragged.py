"""Ragged color-block streaming Pallas TPU kernel for GUST SpMV.

The padded flagship kernel (``gust_spmv.py``) runs a dense
``(W, C_pad/c_blk)`` grid: every window executes the color-block count of
the *heaviest* window, so on skewed (power-law) matrices most grid steps
stream and multiply all-zero padding blocks.  This kernel executes the
ragged block stream built by :func:`repro.core.packing.pack_ragged`
instead: a **1-D grid over the real blocks only** (``T_blk`` steps,
``T_blk = Σ_w max(ceil(C_w / c_blk), 1)``), driven by scalar prefetch
(``pltpu.PrefetchScalarGridSpec``).

Two scalar-prefetch operands derived from ``window_starts`` steer the
pipeline before each kernel body runs:

  block_window (T_blk,)  — window id of block ``t``; indexes the output
                           BlockSpec so block ``t`` lands on its window's
                           (1, l, B) accumulator tile;
  block_starts (W + 1,)  — per-window block prefix; ``t ==
                           block_starts[block_window[t]]`` marks a
                           window's first block.

Blocks of one window are contiguous in the stream, so the output tile is
revisited across exactly that window's blocks: the accumulator
initializes on the window's first block and is flushed when the grid
moves to the next window's tile — the paper's integrate-then-dump, minus
the dead padding cycles.  The per-block math (fused Buffer-Filler gather,
VPU multiply, one-hot routing matmul) is shared with the padded kernel
(:func:`repro.kernels.gust_spmv.block_accumulate`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .gust_spmv import block_accumulate

__all__ = ["make_gust_spmv_ragged"]


def _kernel(bw_ref, bs_ref, m_ref, col_ref, row_ref, xs_ref, xf_ref, y_ref,
            *, l, seg_count, c_blk, b):
    t = pl.program_id(0)
    w = bw_ref[t]
    acc = block_accumulate(
        m_ref, col_ref, row_ref, xs_ref, xf_ref,
        l=l, seg_count=seg_count, c_blk=c_blk, b=b,
    )
    is_first = t == bs_ref[w]

    @pl.when(is_first)
    def _init():
        y_ref[...] = acc

    @pl.when(jnp.logical_not(is_first))
    def _accum():
        y_ref[...] += acc


@functools.lru_cache(maxsize=256)
def make_gust_spmv_ragged(
    num_blocks: int,
    num_windows: int,
    l: int,
    seg_count: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
):
    """Build the scalar-prefetch pallas_call for a ragged-stream geometry.

    Call signature of the returned function:
    ``fn(block_window, block_starts, m_blk, col_blk, row_blk, xs, xf)``
    with the stream blocks ``(num_blocks * c_blk, l)`` and the two x
    layouts ``(seg_count, l, b)``; returns ``(num_windows, l, b)`` f32
    per-window accumulators.

    BlockSpecs:
      * schedule stream (m/col/row): HBM -> VMEM tiles of (c_blk, l), one
        real block per grid step — no padding blocks are ever streamed;
      * x (straight + flipped): full-array VMEM residency;
      * y: the (1, l, B) accumulator tile of ``block_window[t]``,
        revisited across that window's contiguous blocks.

    Memoized on geometry, like the padded builder.
    """
    grid = (num_blocks,)
    sched_spec = pl.BlockSpec((c_blk, l), lambda t, bw, bs: (t, 0))
    x_spec = pl.BlockSpec((seg_count, l, b), lambda t, bw, bs: (0, 0, 0))
    out_spec = pl.BlockSpec((1, l, b), lambda t, bw, bs: (bw[t], 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[sched_spec, sched_spec, sched_spec, x_spec, x_spec],
        out_specs=out_spec,
    )
    kernel = functools.partial(
        _kernel, l=l, seg_count=seg_count, c_blk=c_blk, b=b
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )
