"""Ragged color-block streaming Pallas TPU kernels for GUST SpMV.

The padded flagship kernel (``gust_spmv.py``) runs a dense
``(W, C_pad/c_blk)`` grid: every window executes the color-block count of
the *heaviest* window, so on skewed (power-law) matrices most grid steps
stream and multiply all-zero padding blocks.  These kernels execute the
ragged block stream built by :func:`repro.core.packing.pack_ragged`
instead: a **grid over the real blocks only** (``T_blk`` steps,
``T_blk = Σ_w max(ceil(C_w / c_blk), 1)``), driven by scalar prefetch
(``pltpu.PrefetchScalarGridSpec``).

Two scalar-prefetch operands derived from ``window_starts`` steer the
pipeline before each kernel body runs:

  block_window (T_blk,)  — window id of block ``t``; indexes the output
                           BlockSpec so block ``t`` lands on its window's
                           (1, l, B) accumulator tile;
  block_starts (W + 1,)  — per-window block prefix; ``t ==
                           block_starts[block_window[t]]`` marks a
                           window's first block.

Blocks of one window are contiguous in the stream, so the output tile is
revisited across exactly that window's blocks: the accumulator
initializes on the window's first block and is flushed when the grid
moves to the next window's tile — the paper's integrate-then-dump, minus
the dead padding cycles.

Like the padded flagship, the Buffer-Filler gather runs in one of two
modes (shared math in :mod:`repro.kernels.gust_spmv`):

  * **resident** (:func:`make_gust_spmv_ragged`): x fully VMEM-resident,
    one-hot contraction over all ``seg_count`` segments;
  * **segment-local** (:func:`make_gust_spmv_ragged_local`): a third
    scalar-prefetch operand — the pack-time ``seg_blk`` table — steers an
    inner ``S_blk`` grid dimension that streams only the x tiles block
    ``t`` references, shrinking per-block gather work from O(seg_count)
    to O(S_blk) and x VMEM residency to a single (1, l, B) tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .gust_spmv import block_accumulate, gather_local_step, route_rows

__all__ = ["make_gust_spmv_ragged", "make_gust_spmv_ragged_local"]


def _kernel(bw_ref, bs_ref, m_ref, col_ref, row_ref, xs_ref, y_ref,
            *, l, seg_count, c_blk, b):
    t = pl.program_id(0)
    w = bw_ref[t]
    acc = block_accumulate(
        m_ref, col_ref, row_ref, xs_ref,
        l=l, seg_count=seg_count, c_blk=c_blk, b=b,
    )
    is_first = t == bs_ref[w]

    @pl.when(is_first)
    def _init():
        y_ref[...] = acc

    @pl.when(jnp.logical_not(is_first))
    def _accum():
        y_ref[...] += acc


@functools.lru_cache(maxsize=256)
def make_gust_spmv_ragged(
    num_blocks: int,
    num_windows: int,
    l: int,
    seg_count: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
):
    """Build the resident-gather scalar-prefetch pallas_call for a
    ragged-stream geometry.

    Call signature of the returned function:
    ``fn(block_window, block_starts, m_blk, col_blk, row_blk, xs)``
    with the stream blocks ``(num_blocks * c_blk, l)`` and the straight
    x layout ``(seg_count, l, b)`` (the lane-reversed layout is derived
    in-kernel); returns ``(num_windows, l, b)`` f32 per-window
    accumulators.

    BlockSpecs:
      * schedule stream (m/col/row): HBM -> VMEM tiles of (c_blk, l), one
        real block per grid step — no padding blocks are ever streamed;
      * x (straight): full-array VMEM residency;
      * y: the (1, l, b) accumulator tile of ``block_window[t]``,
        revisited across that window's contiguous blocks.

    Memoized on geometry, like the padded builder.
    """
    grid = (num_blocks,)
    sched_spec = pl.BlockSpec((c_blk, l), lambda t, bw, bs: (t, 0))
    x_spec = pl.BlockSpec((seg_count, l, b), lambda t, bw, bs: (0, 0, 0))
    out_spec = pl.BlockSpec((1, l, b), lambda t, bw, bs: (bw[t], 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[sched_spec, sched_spec, sched_spec, x_spec],
        out_specs=out_spec,
    )
    kernel = functools.partial(
        _kernel, l=l, seg_count=seg_count, c_blk=c_blk, b=b
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )


def _local_kernel(bw_ref, bs_ref, seg_ref, m_ref, col_ref, row_ref, xt_ref,
                  y_ref, g_scr, *, l, s_blk, c_blk, b):
    t, s = pl.program_id(0), pl.program_id(1)
    w = bw_ref[t]

    @pl.when(s == 0)
    def _zero():
        g_scr[...] = jnp.zeros_like(g_scr)

    gather_local_step(col_ref, xt_ref, s, g_scr, l=l, c_blk=c_blk)

    @pl.when(s == s_blk - 1)
    def _flush():
        m_blk = m_ref[...].astype(jnp.float32)  # (C_blk, l)
        partial = m_blk.T[:, :, None] * g_scr[...]  # (l, C_blk, B)
        acc = route_rows(
            partial, row_ref[...].astype(jnp.int32), c_blk=c_blk, l=l, b=b
        )
        is_first = t == bs_ref[w]

        @pl.when(is_first)
        def _init():
            y_ref[...] = acc

        @pl.when(jnp.logical_not(is_first))
        def _accum():
            y_ref[...] += acc


@functools.lru_cache(maxsize=256)
def make_gust_spmv_ragged_local(
    num_blocks: int,
    num_windows: int,
    l: int,
    s_blk: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
):
    """Build the segment-local scalar-prefetch pallas_call for a
    ragged-stream geometry.

    Call signature of the returned function:
    ``fn(block_window, block_starts, seg_flat, m_blk, col_loc, row_blk,
    xs)`` — ``seg_flat`` is the pack-time segment table flattened to
    ``(T_blk * S_blk,)`` int32 and ``col_loc`` the block-local columns.
    Grid ``(num_blocks, S_blk)``: the inner dimension streams the x tile
    of segment ``seg_flat[t*S_blk + s]`` (one (1, l, B) tile in VMEM per
    step), the gathered block accumulates in VMEM scratch, and the
    multiply + routing matmul fire on the last tile.  Combines the
    ragged stream's "no dead padding cycles" with the segment-local
    gather's O(S_blk) per-block cost — the full GUST utilization story.
    """
    grid = (num_blocks, s_blk)
    sched_spec = pl.BlockSpec((c_blk, l), lambda t, s, bw, bs, seg: (t, 0))
    x_spec = pl.BlockSpec(
        (1, l, b), lambda t, s, bw, bs, seg: (seg[t * s_blk + s], 0, 0)
    )
    out_spec = pl.BlockSpec(
        (1, l, b), lambda t, s, bw, bs, seg: (bw[t], 0, 0)
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[sched_spec, sched_spec, sched_spec, x_spec],
        out_specs=out_spec,
        scratch_shapes=[pltpu.VMEM((l, c_blk, b), jnp.float32)],
    )
    kernel = functools.partial(
        _local_kernel, l=l, s_blk=s_blk, c_blk=c_blk, b=b
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )
