"""Ragged color-block streaming Pallas TPU kernels for GUST SpMV.

The padded flagship kernel (``gust_spmv.py``) runs a dense
``(W, C_pad/c_blk)`` grid: every window executes the color-block count of
the *heaviest* window, so on skewed (power-law) matrices most grid steps
stream and multiply all-zero padding blocks.  These kernels execute the
ragged block stream built by :func:`repro.core.packing.pack_ragged`
instead: a **grid over the real blocks only** (``T_blk`` steps,
``T_blk = Σ_w max(ceil(C_w / c_blk), 1)``), driven by scalar prefetch
(``pltpu.PrefetchScalarGridSpec``).

Two scalar-prefetch operands derived from ``window_starts`` steer the
pipeline before each kernel body runs:

  block_window (T_blk,)  — window id of block ``t``; indexes the output
                           BlockSpec so block ``t`` lands on its window's
                           (1, l, B) accumulator tile;
  block_starts (W + 1,)  — per-window block prefix; ``t ==
                           block_starts[block_window[t]]`` marks a
                           window's first block.

Blocks of one window are contiguous in the stream, so the output tile is
revisited across exactly that window's blocks: the accumulator
initializes on the window's first block and is flushed when the grid
moves to the next window's tile — the paper's integrate-then-dump, minus
the dead padding cycles.

Like the padded flagship, the Buffer-Filler gather runs in one of two
modes (shared math in :mod:`repro.kernels.gust_spmv`):

  * **resident** (:func:`make_gust_spmv_ragged`): x fully VMEM-resident,
    one-hot contraction over all ``seg_count`` segments;
  * **segment-local** (:func:`make_gust_spmv_ragged_local`): a third
    scalar-prefetch operand — the pack-time ``seg_blk`` table — steers an
    inner ``S_blk`` grid dimension that streams only the x tiles block
    ``t`` references, shrinking per-block gather work from O(seg_count)
    to O(S_blk) and x VMEM residency to a single (1, l, B) tile.

Double-buffered variants (PR 6), bitwise-identical to their
single-buffered twins (same f32 additions in the same order):

  * :func:`make_gust_spmv_ragged_db`: grid ``(W,)``; each window walks
    its own block range ``block_starts[w]:block_starts[w+1]`` in an
    in-kernel fori_loop, ping/ponging the schedule block triple through
    manual async copies so the DMA of block ``t+1`` overlaps the math of
    block ``t`` (``block_window`` is not needed — the window IS the grid
    step);
  * :func:`make_gust_spmv_ragged_local_db`: grid ``(num_blocks,)``; the
    ``S_blk`` x tiles of each block ping/pong through VMEM scratch with
    the column decode hoisted out of the tile loop.

Every builder takes ``quantized=True`` to accept an int8 value stream
plus the per-block scale column ``scale_blk.reshape(T_blk, 1)`` (dequant
fused into the accumulate — see ``gust_spmv.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .gust_spmv import (
    _local_flush,
    block_accumulate,
    block_math,
    decode_local_cols,
    gather_local_step,
    local_tile_delta,
    stream_copy,
)

__all__ = [
    "make_gust_spmv_ragged",
    "make_gust_spmv_ragged_local",
    "make_gust_spmv_ragged_db",
    "make_gust_spmv_ragged_local_db",
]


def _accumulate_out(y_ref, acc, first):
    @pl.when(first)
    def _init():
        y_ref[...] = acc

    @pl.when(jnp.logical_not(first))
    def _accum():
        y_ref[...] += acc


def _kernel(bw_ref, bs_ref, m_ref, col_ref, row_ref, xs_ref, y_ref,
            *, l, seg_count, c_blk, b, scale_ref=None):
    t = pl.program_id(0)
    w = bw_ref[t]
    acc = block_accumulate(
        m_ref, col_ref, row_ref, xs_ref,
        l=l, seg_count=seg_count, c_blk=c_blk, b=b,
        scale=None if scale_ref is None else scale_ref[0, 0],
    )
    _accumulate_out(y_ref, acc, t == bs_ref[w])


def _kernel_q(bw_ref, bs_ref, m_ref, col_ref, row_ref, scale_ref, xs_ref,
              y_ref, *, l, seg_count, c_blk, b):
    _kernel(bw_ref, bs_ref, m_ref, col_ref, row_ref, xs_ref, y_ref,
            l=l, seg_count=seg_count, c_blk=c_blk, b=b, scale_ref=scale_ref)


@functools.lru_cache(maxsize=256)
def make_gust_spmv_ragged(
    num_blocks: int,
    num_windows: int,
    l: int,
    seg_count: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
    quantized: bool = False,
):
    """Build the resident-gather scalar-prefetch pallas_call for a
    ragged-stream geometry.

    Call signature of the returned function:
    ``fn(block_window, block_starts, m_blk, col_blk, row_blk, xs)``
    with the stream blocks ``(num_blocks * c_blk, l)`` and the straight
    x layout ``(seg_count, l, b)`` (the lane-reversed layout is derived
    in-kernel); returns ``(num_windows, l, b)`` f32 per-window
    accumulators.  With ``quantized=True`` the scale column
    ``scale_blk.reshape(T_blk, 1)`` is inserted after the row block.

    BlockSpecs:
      * schedule stream (m/col/row): HBM -> VMEM tiles of (c_blk, l), one
        real block per grid step — no padding blocks are ever streamed;
      * x (straight): full-array VMEM residency;
      * y: the (1, l, b) accumulator tile of ``block_window[t]``,
        revisited across that window's contiguous blocks.

    Memoized on geometry, like the padded builder.
    """
    grid = (num_blocks,)
    sched_spec = pl.BlockSpec((c_blk, l), lambda t, bw, bs: (t, 0))
    x_spec = pl.BlockSpec((seg_count, l, b), lambda t, bw, bs: (0, 0, 0))
    out_spec = pl.BlockSpec((1, l, b), lambda t, bw, bs: (bw[t], 0, 0))

    in_specs = [sched_spec, sched_spec, sched_spec]
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1), lambda t, bw, bs: (t, 0)))
    in_specs.append(x_spec)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
    )
    kernel = functools.partial(
        _kernel_q if quantized else _kernel,
        l=l, seg_count=seg_count, c_blk=c_blk, b=b,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )


def _local_kernel(bw_ref, bs_ref, seg_ref, m_ref, col_ref, row_ref, xt_ref,
                  y_ref, g_scr, *, l, s_blk, c_blk, b, scale_ref=None):
    t, s = pl.program_id(0), pl.program_id(1)
    w = bw_ref[t]

    @pl.when(s == 0)
    def _zero():
        g_scr[...] = jnp.zeros_like(g_scr)

    gather_local_step(col_ref, xt_ref, s, g_scr, l=l, c_blk=c_blk)

    @pl.when(s == s_blk - 1)
    def _flush():
        _local_flush(
            m_ref, row_ref, g_scr[...], y_ref, t == bs_ref[w],
            l=l, c_blk=c_blk, b=b,
            scale=None if scale_ref is None else scale_ref[0, 0],
        )


def _local_kernel_q(bw_ref, bs_ref, seg_ref, m_ref, col_ref, row_ref,
                    scale_ref, xt_ref, y_ref, g_scr, *, l, s_blk, c_blk, b):
    _local_kernel(bw_ref, bs_ref, seg_ref, m_ref, col_ref, row_ref, xt_ref,
                  y_ref, g_scr, l=l, s_blk=s_blk, c_blk=c_blk, b=b,
                  scale_ref=scale_ref)


@functools.lru_cache(maxsize=256)
def make_gust_spmv_ragged_local(
    num_blocks: int,
    num_windows: int,
    l: int,
    s_blk: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
    quantized: bool = False,
):
    """Build the segment-local scalar-prefetch pallas_call for a
    ragged-stream geometry.

    Call signature of the returned function:
    ``fn(block_window, block_starts, seg_flat, m_blk, col_loc, row_blk,
    xs)`` — ``seg_flat`` is the pack-time segment table flattened to
    ``(T_blk * S_blk,)`` int32 and ``col_loc`` the block-local columns.
    With ``quantized=True`` the scale column is inserted after the row
    block.  Grid ``(num_blocks, S_blk)``: the inner dimension streams the
    x tile of segment ``seg_flat[t*S_blk + s]`` (one (1, l, B) tile in
    VMEM per step), the gathered block accumulates in VMEM scratch, and
    the multiply + routing matmul fire on the last tile.  Combines the
    ragged stream's "no dead padding cycles" with the segment-local
    gather's O(S_blk) per-block cost — the full GUST utilization story.
    """
    grid = (num_blocks, s_blk)
    sched_spec = pl.BlockSpec((c_blk, l), lambda t, s, bw, bs, seg: (t, 0))
    x_spec = pl.BlockSpec(
        (1, l, b), lambda t, s, bw, bs, seg: (seg[t * s_blk + s], 0, 0)
    )
    out_spec = pl.BlockSpec(
        (1, l, b), lambda t, s, bw, bs, seg: (bw[t], 0, 0)
    )

    in_specs = [sched_spec, sched_spec, sched_spec]
    if quantized:
        in_specs.append(
            pl.BlockSpec((1, 1), lambda t, s, bw, bs, seg: (t, 0))
        )
    in_specs.append(x_spec)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=[pltpu.VMEM((l, c_blk, b), jnp.float32)],
    )
    kernel = functools.partial(
        _local_kernel_q if quantized else _local_kernel,
        l=l, s_blk=s_blk, c_blk=c_blk, b=b,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Double-buffered variants.
# ---------------------------------------------------------------------------


def _db_kernel(bs_ref, m_ref, col_ref, row_ref, xs_ref, y_ref,
               m_scr, col_scr, row_scr, sems,
               *, l, seg_count, c_blk, b, scale_ref=None):
    """Grid (W,): window ``w`` walks its own ragged block range in a
    fori_loop, the schedule block triple double-buffered through manual
    async copies.  Same f32 additions in the same order as the
    single-buffered ragged kernel's revisited accumulator tile —
    bitwise identical."""
    w = pl.program_id(0)
    t0 = bs_ref[w]
    count = bs_ref[w + 1] - t0

    def copies(slot, t):
        start = t * c_blk
        return (
            stream_copy(m_ref, m_scr, sems.at[slot, 0], slot, start, c_blk),
            stream_copy(col_ref, col_scr, sems.at[slot, 1], slot, start,
                        c_blk),
            stream_copy(row_ref, row_scr, sems.at[slot, 2], slot, start,
                        c_blk),
        )

    for c in copies(0, t0):
        c.start()

    def body(i, acc):
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < count)
        def _prefetch():
            for c in copies(1 - slot, t0 + i + 1):
                c.start()

        for c in copies(slot, t0 + i):
            c.wait()
        m_blk = m_scr[slot].astype(jnp.float32)
        if scale_ref is not None:
            m_blk = m_blk * scale_ref[t0 + i, 0]
        return acc + block_math(
            m_blk,
            col_scr[slot].astype(jnp.int32),
            row_scr[slot].astype(jnp.int32),
            xs_ref[...].astype(jnp.float32),
            l=l, seg_count=seg_count, c_blk=c_blk, b=b,
        )

    y_ref[...] = jax.lax.fori_loop(
        0, count, body, jnp.zeros((1, l, b), jnp.float32)
    )


def _db_kernel_q(bs_ref, m_ref, col_ref, row_ref, scale_ref, xs_ref, y_ref,
                 m_scr, col_scr, row_scr, sems, *, l, seg_count, c_blk, b):
    _db_kernel(bs_ref, m_ref, col_ref, row_ref, xs_ref, y_ref,
               m_scr, col_scr, row_scr, sems,
               l=l, seg_count=seg_count, c_blk=c_blk, b=b,
               scale_ref=scale_ref)


@functools.lru_cache(maxsize=256)
def make_gust_spmv_ragged_db(
    num_blocks: int,
    num_windows: int,
    l: int,
    seg_count: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
    quantized: bool = False,
    value_dtype: str = "float32",
    index_dtype: str = "int32",
):
    """Double-buffered twin of :func:`make_gust_spmv_ragged`, grid
    ``(W,)``.  Call signature:
    ``fn(block_starts, m_blk, col_blk, row_blk, [scale2d,] xs)`` —
    ``block_window`` is not needed (the window is the grid step; its
    block range comes from ``block_starts`` alone).  The schedule stream
    lives in ANY-space memory and ping/pongs through VMEM scratch sized
    at the stream's actual dtypes; when quantized the (T_blk, 1) scale
    column sits whole in VMEM."""
    vdt, idt = jnp.dtype(value_dtype), jnp.dtype(index_dtype)

    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    in_specs = [any_spec, any_spec, any_spec]
    if quantized:
        in_specs.append(pl.BlockSpec((num_blocks, 1), lambda w, bs: (0, 0)))
    in_specs.append(
        pl.BlockSpec((seg_count, l, b), lambda w, bs: (0, 0, 0))
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_windows,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, l, b), lambda w, bs: (w, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, c_blk, l), vdt),
            pltpu.VMEM((2, c_blk, l), idt),
            pltpu.VMEM((2, c_blk, l), idt),
            pltpu.SemaphoreType.DMA((2, 3)),
        ],
    )
    kernel = functools.partial(
        _db_kernel_q if quantized else _db_kernel,
        l=l, seg_count=seg_count, c_blk=c_blk, b=b,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )


def _local_db_kernel(bw_ref, bs_ref, seg_ref, m_ref, col_ref, row_ref,
                     xs_ref, y_ref, xt_scr, sems,
                     *, l, s_blk, c_blk, b, scale_ref=None):
    """Grid (num_blocks,): schedule blocks pipeline-managed, the block's
    S_blk x tiles double-buffered through manual async copies with the
    column decode hoisted out of the tile loop (the ragged twin of the
    padded ``_local_db_kernel``)."""
    t = pl.program_id(0)
    w = bw_ref[t]

    def copy(slot, s):
        return stream_copy(
            xs_ref, xt_scr, sems.at[slot], slot, seg_ref[t * s_blk + s], 1
        )

    copy(0, 0).start()
    local_seg, fsel = decode_local_cols(
        col_ref[...].astype(jnp.int32), l=l, c_blk=c_blk
    )

    def body(s, g):
        slot = jax.lax.rem(s, 2)

        @pl.when(s + 1 < s_blk)
        def _prefetch():
            copy(1 - slot, s + 1).start()

        copy(slot, s).wait()
        tile = xt_scr[slot].astype(jnp.float32)[0]  # (l, B)
        return g + local_tile_delta(local_seg, fsel, tile, s)

    g = jax.lax.fori_loop(
        0, s_blk, body, jnp.zeros((l, c_blk, b), jnp.float32)
    )
    _local_flush(
        m_ref, row_ref, g, y_ref, t == bs_ref[w],
        l=l, c_blk=c_blk, b=b,
        scale=None if scale_ref is None else scale_ref[0, 0],
    )


def _local_db_kernel_q(bw_ref, bs_ref, seg_ref, m_ref, col_ref, row_ref,
                       scale_ref, xs_ref, y_ref, xt_scr, sems,
                       *, l, s_blk, c_blk, b):
    _local_db_kernel(bw_ref, bs_ref, seg_ref, m_ref, col_ref, row_ref,
                     xs_ref, y_ref, xt_scr, sems,
                     l=l, s_blk=s_blk, c_blk=c_blk, b=b,
                     scale_ref=scale_ref)


@functools.lru_cache(maxsize=256)
def make_gust_spmv_ragged_local_db(
    num_blocks: int,
    num_windows: int,
    l: int,
    s_blk: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
    quantized: bool = False,
    x_dtype: str = "float32",
):
    """Double-buffered twin of :func:`make_gust_spmv_ragged_local`: same
    call signature and bitwise-identical output, grid ``(num_blocks,)``
    (the ``S_blk`` inner dimension collapses into the kernel).  x lives
    in ANY-space memory; the block's referenced tiles ping/pong through
    a two-slot VMEM scratch so the fetch of tile ``s+1`` overlaps the
    gather of tile ``s``."""
    xdt = jnp.dtype(x_dtype)
    sched_spec = pl.BlockSpec((c_blk, l), lambda t, bw, bs, seg: (t, 0))
    in_specs = [sched_spec, sched_spec, sched_spec]
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1), lambda t, bw, bs, seg: (t, 0)))
    in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(num_blocks,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, l, b), lambda t, bw, bs, seg: (bw[t], 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, 1, l, b), xdt),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    kernel = functools.partial(
        _local_db_kernel_q if quantized else _local_db_kernel,
        l=l, s_blk=s_blk, c_blk=c_blk, b=b,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )
