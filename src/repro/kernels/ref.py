"""Pure-jnp oracles for every Pallas kernel (same packed-block semantics).

These mirror the kernels' contracts exactly — same inputs, same outputs —
with no Pallas, no BlockSpecs, no one-hot tricks: direct gathers and
scatter-adds.  Every kernel test sweeps shapes/dtypes and asserts
``assert_allclose(kernel(...), ref(...))``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gust_spmv_ref", "gust_spmv_ragged_ref", "gather_fill_ref"]


def gather_fill_ref(
    col_blocks: jnp.ndarray,  # (T, l) int32 original column indices
    x_padded: jnp.ndarray,  # (S*l, B) zero-padded vector
) -> jnp.ndarray:
    """Oracle for the Buffer Filler: plain gather ``x[col]``, (T, l, B)."""
    return jnp.take(x_padded.astype(jnp.float32), col_blocks.astype(jnp.int32), axis=0)


def gust_spmv_ref(
    m_blocks: jnp.ndarray,  # (W*C_pad, l) values (0 in padding)
    col_blocks: jnp.ndarray,  # (W*C_pad, l) int32
    row_blocks: jnp.ndarray,  # (W*C_pad, l) int32 adder index
    x_padded: jnp.ndarray,  # (S*l, B)
    *,
    num_windows: int,
    l: int,
) -> jnp.ndarray:
    """Oracle for the flagship kernel: gather, multiply, scatter-add into
    per-window accumulators.  Returns (W, l, B) f32."""
    total = m_blocks.shape[0]
    c_pad = total // num_windows
    v_sch = gather_fill_ref(col_blocks, x_padded)  # (T, l, B)
    partial = m_blocks.astype(jnp.float32)[:, :, None] * v_sch
    window = jnp.arange(total, dtype=jnp.int32) // c_pad
    adder = window[:, None] * l + row_blocks.astype(jnp.int32)  # (T, l)
    b = x_padded.shape[1]
    y = jax.ops.segment_sum(
        partial.reshape(-1, b),
        adder.reshape(-1),
        num_segments=num_windows * l,
    )
    return y.reshape(num_windows, l, b)


def gust_spmv_ragged_ref(
    m_blocks: jnp.ndarray,  # (T_blk*c_blk, l) values (0 in padding)
    col_blocks: jnp.ndarray,  # (T_blk*c_blk, l) int32
    row_blocks: jnp.ndarray,  # (T_blk*c_blk, l) int32 adder index
    block_window: jnp.ndarray,  # (T_blk,) int32 window id of each block
    x_padded: jnp.ndarray,  # (S*l, B)
    *,
    num_windows: int,
    l: int,
    c_blk: int,
) -> jnp.ndarray:
    """Oracle for the ragged scalar-prefetch kernel: same gather/multiply,
    with the window of each stream row read from ``block_window`` instead
    of a fixed ``C_pad`` stride.  Returns (W, l, B) f32."""
    v_sch = gather_fill_ref(col_blocks, x_padded)  # (T, l, B)
    partial = m_blocks.astype(jnp.float32)[:, :, None] * v_sch
    window = jnp.repeat(block_window.astype(jnp.int32), c_blk)  # (T,)
    adder = window[:, None] * l + row_blocks.astype(jnp.int32)  # (T, l)
    b = x_padded.shape[1]
    y = jax.ops.segment_sum(
        partial.reshape(-1, b),
        adder.reshape(-1),
        num_segments=num_windows * l,
    )
    return y.reshape(num_windows, l, b)
