"""Pure-jnp oracles for every Pallas kernel (same packed-block semantics).

These mirror the kernels' contracts exactly — same inputs, same outputs —
with no Pallas, no BlockSpecs, no one-hot tricks: direct gathers and
scatter-adds.  Every kernel test sweeps shapes/dtypes and asserts
``assert_allclose(kernel(...), ref(...))``.

The segment-local twins (``*_local_ref``) replace the direct gather
``x[col]`` with the two-step segment-table gather the local kernels run
— x tiles selected by ``seg_blk``, then a block-local index — and share
every instruction downstream, so local-vs-resident bit-identity is an
oracle-level property too (the gathered values are equal bitwise: the
table maps each local id back to the slot's original global column).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "gust_spmv_ref",
    "gust_spmv_ragged_ref",
    "gust_spmv_local_ref",
    "gust_spmv_ragged_local_ref",
    "gather_fill_ref",
    "gather_fill_local_ref",
    "dequant_ref",
    "gust_spgemm_ref",
]


def dequant_ref(
    m_blocks: jnp.ndarray,  # (T*c_blk, l) int8 quantized values
    scale_blk: jnp.ndarray,  # (T,) f32 per-block scales
    *,
    c_blk: int,
) -> jnp.ndarray:
    """The one definition of int8 dequant semantics, shared bit-exactly by
    kernels and oracles: ``v̂ = float32(q) * scale`` — a single f32
    multiply by the slot's block scale, nothing else (no rounding, no
    intermediate cast).  The kernels perform the same multiply on their
    (c_blk, l) value tile before the accumulate, so kernel and oracle
    dequantized values are bitwise equal.  Padding slots store q == 0 and
    dequantize to exactly 0.0, preserving the zero-contribution
    invariant."""
    scale = jnp.repeat(scale_blk.astype(jnp.float32), c_blk)  # (T*c_blk,)
    return m_blocks.astype(jnp.float32) * scale[:, None]


def gather_fill_ref(
    col_blocks: jnp.ndarray,  # (T, l) int32 original column indices
    x_padded: jnp.ndarray,  # (S*l, B) zero-padded vector
) -> jnp.ndarray:
    """Oracle for the Buffer Filler: plain gather ``x[col]``, (T, l, B)."""
    return jnp.take(x_padded.astype(jnp.float32), col_blocks.astype(jnp.int32), axis=0)


def gather_fill_local_ref(
    col_loc: jnp.ndarray,  # (T*c_blk, l) block-local column indices
    seg_blk: jnp.ndarray,  # (T, S_blk) int32 per-block segment table
    x_padded: jnp.ndarray,  # (S*l, B) zero-padded vector
    *,
    l: int,
    c_blk: int,
) -> jnp.ndarray:
    """Oracle for the segment-local Buffer Filler: gather each block's
    ``S_blk`` x tiles by the segment table, then index them block-locally
    — ``x[seg_blk[t, col_loc // l] * l + col_loc % l]``.  Bit-identical
    to :func:`gather_fill_ref` on the same stream because the table maps
    every local id back to the slot's original column."""
    seg_blk = seg_blk.astype(jnp.int32)
    t_blk, s_blk = seg_blk.shape
    b = x_padded.shape[1]
    tiles = x_padded.astype(jnp.float32).reshape(-1, l, b)[seg_blk]
    # tiles: (T, S_blk, l, B) -> local address space (T, S_blk*l, B)
    tiles = tiles.reshape(t_blk, s_blk * l, b)
    rows = col_loc.shape[0]
    blk = jnp.arange(rows, dtype=jnp.int32) // c_blk
    return tiles[blk[:, None], col_loc.astype(jnp.int32), :]  # (rows, l, B)


def gust_spgemm_ref(
    m_blocks: jnp.ndarray,  # (T*c_blk, l) A values (0 in padding)
    col_blocks: jnp.ndarray,  # (T*c_blk, l) int32 ORIGINAL A columns (B row ids)
    row_blocks: jnp.ndarray,  # (T*c_blk, l) int32 adder index
    window: jnp.ndarray,  # (T*c_blk,) int32 window id of each stream row
    b_vals: jnp.ndarray,  # (R, k_max) condensed B row values (0 in padding)
    b_cols: jnp.ndarray,  # (R, k_max) int32 condensed B row columns (0 in padding)
    *,
    num_windows: int,
    l: int,
    n_out: int,
) -> jnp.ndarray:
    """Oracle for the SpGEMM kernel: sparse×sparse through A's color-block
    stream as an outer-product schedule over B's condensed rows.

    Each scheduled slot ``(a = A[i, j], row, col=j)`` gathers B's condensed
    row ``j`` — its ``k_max`` padded ``(value, column)`` pairs — multiplies
    the values by ``a``, and merges every partial product into the dense
    per-window row accumulator at ``(window*l + row, b_col)``.  Padding A
    slots carry ``a == 0`` and padding B entries carry ``value == 0``, so
    both contribute exactly zero (the packed-format zero-contribution
    invariant extends to the product).  Returns ``(W, l, n_out)`` f32 —
    the same per-window accumulator shape as the SpMV oracles with the
    vector batch replaced by B's output columns."""
    col = col_blocks.astype(jnp.int32)
    bv = jnp.take(b_vals.astype(jnp.float32), col, axis=0)  # (T, l, k_max)
    bc = jnp.take(b_cols.astype(jnp.int32), col, axis=0)  # (T, l, k_max)
    partial = m_blocks.astype(jnp.float32)[:, :, None] * bv  # (T, l, k_max)
    adder = window.astype(jnp.int32)[:, None] * l + row_blocks.astype(
        jnp.int32
    )  # (T, l)
    idx = adder[:, :, None] * n_out + bc  # (T, l, k_max)
    y = jax.ops.segment_sum(
        partial.reshape(-1),
        idx.reshape(-1),
        num_segments=num_windows * l * n_out,
    )
    return y.reshape(num_windows, l, n_out)


def _window_accumulate(
    m_blocks: jnp.ndarray,  # (T, l) values (0 in padding)
    v_sch: jnp.ndarray,  # (T, l, B) gathered vector stream
    row_blocks: jnp.ndarray,  # (T, l) int32 adder index
    window: jnp.ndarray,  # (T,) int32 window id of each stream row
    *,
    num_windows: int,
    l: int,
) -> jnp.ndarray:
    """Shared multiply + scatter-add of every oracle: identical
    instructions downstream of the gather keep the resident/local oracle
    pair bit-identical by construction."""
    partial = m_blocks.astype(jnp.float32)[:, :, None] * v_sch
    adder = window[:, None] * l + row_blocks.astype(jnp.int32)  # (T, l)
    b = v_sch.shape[-1]
    y = jax.ops.segment_sum(
        partial.reshape(-1, b),
        adder.reshape(-1),
        num_segments=num_windows * l,
    )
    return y.reshape(num_windows, l, b)


def _padded_windows(total: int, num_windows: int) -> jnp.ndarray:
    c_pad = total // num_windows
    return jnp.arange(total, dtype=jnp.int32) // c_pad


def gust_spmv_ref(
    m_blocks: jnp.ndarray,  # (W*C_pad, l) values (0 in padding)
    col_blocks: jnp.ndarray,  # (W*C_pad, l) int32
    row_blocks: jnp.ndarray,  # (W*C_pad, l) int32 adder index
    x_padded: jnp.ndarray,  # (S*l, B)
    *,
    num_windows: int,
    l: int,
    scale_blk: jnp.ndarray = None,  # (T_blk,) f32 when the stream is int8
    c_blk: int = 8,
) -> jnp.ndarray:
    """Oracle for the flagship kernel: gather, multiply, scatter-add into
    per-window accumulators.  ``scale_blk`` dequantizes an int8 stream
    first (:func:`dequant_ref`).  Returns (W, l, B) f32."""
    if scale_blk is not None:
        m_blocks = dequant_ref(m_blocks, scale_blk, c_blk=c_blk)
    v_sch = gather_fill_ref(col_blocks, x_padded)  # (T, l, B)
    window = _padded_windows(m_blocks.shape[0], num_windows)
    return _window_accumulate(
        m_blocks, v_sch, row_blocks, window, num_windows=num_windows, l=l
    )


def gust_spmv_local_ref(
    m_blocks: jnp.ndarray,  # (W*C_pad, l) values (0 in padding)
    col_loc: jnp.ndarray,  # (W*C_pad, l) block-local columns
    row_blocks: jnp.ndarray,  # (W*C_pad, l) int32 adder index
    seg_blk: jnp.ndarray,  # (T_blk, S_blk) segment table
    x_padded: jnp.ndarray,  # (S*l, B)
    *,
    num_windows: int,
    l: int,
    c_blk: int,
    scale_blk: jnp.ndarray = None,  # (T_blk,) f32 when the stream is int8
) -> jnp.ndarray:
    """Segment-local oracle for the padded layout (gather via the
    pack-time table; same accumulate).  Returns (W, l, B) f32."""
    if scale_blk is not None:
        m_blocks = dequant_ref(m_blocks, scale_blk, c_blk=c_blk)
    v_sch = gather_fill_local_ref(col_loc, seg_blk, x_padded, l=l, c_blk=c_blk)
    window = _padded_windows(m_blocks.shape[0], num_windows)
    return _window_accumulate(
        m_blocks, v_sch, row_blocks, window, num_windows=num_windows, l=l
    )


def gust_spmv_ragged_ref(
    m_blocks: jnp.ndarray,  # (T_blk*c_blk, l) values (0 in padding)
    col_blocks: jnp.ndarray,  # (T_blk*c_blk, l) int32
    row_blocks: jnp.ndarray,  # (T_blk*c_blk, l) int32 adder index
    block_window: jnp.ndarray,  # (T_blk,) int32 window id of each block
    x_padded: jnp.ndarray,  # (S*l, B)
    *,
    num_windows: int,
    l: int,
    c_blk: int,
    scale_blk: jnp.ndarray = None,  # (T_blk,) f32 when the stream is int8
) -> jnp.ndarray:
    """Oracle for the ragged scalar-prefetch kernel: same gather/multiply,
    with the window of each stream row read from ``block_window`` instead
    of a fixed ``C_pad`` stride.  Returns (W, l, B) f32."""
    if scale_blk is not None:
        m_blocks = dequant_ref(m_blocks, scale_blk, c_blk=c_blk)
    v_sch = gather_fill_ref(col_blocks, x_padded)  # (T, l, B)
    window = jnp.repeat(block_window.astype(jnp.int32), c_blk)  # (T,)
    return _window_accumulate(
        m_blocks, v_sch, row_blocks, window, num_windows=num_windows, l=l
    )


def gust_spmv_ragged_local_ref(
    m_blocks: jnp.ndarray,  # (T_blk*c_blk, l) values (0 in padding)
    col_loc: jnp.ndarray,  # (T_blk*c_blk, l) block-local columns
    row_blocks: jnp.ndarray,  # (T_blk*c_blk, l) int32 adder index
    seg_blk: jnp.ndarray,  # (T_blk, S_blk) segment table
    block_window: jnp.ndarray,  # (T_blk,) int32 window id of each block
    x_padded: jnp.ndarray,  # (S*l, B)
    *,
    num_windows: int,
    l: int,
    c_blk: int,
    scale_blk: jnp.ndarray = None,  # (T_blk,) f32 when the stream is int8
) -> jnp.ndarray:
    """Segment-local oracle for the ragged stream.  Returns (W, l, B)."""
    if scale_blk is not None:
        m_blocks = dequant_ref(m_blocks, scale_blk, c_blk=c_blk)
    v_sch = gather_fill_local_ref(col_loc, seg_blk, x_padded, l=l, c_blk=c_blk)
    window = jnp.repeat(block_window.astype(jnp.int32), c_blk)
    return _window_accumulate(
        m_blocks, v_sch, row_blocks, window, num_windows=num_windows, l=l
    )
