"""Flagship Pallas TPU kernel: windowed scheduled GUST SpMV.

TPU adaptation of the paper's three hardware levels (DESIGN.md §2):

  multipliers  -> VPU elementwise multiply of the scheduled value block
                  with the gathered vector block;
  Buffer Filler-> two execution modes for the per-slot gather
                  ``v[Col_sch]``, both fused in-kernel (the scheduler
                  only ever assigns a column to its own lane or the
                  lane-reversed position — load-balance step 3 — so the
                  gather is a segment one-hot / segment-select plus a
                  straight/flipped select, never random access):

                  * **resident** (``make_gust_spmv``): the vector lives
                    whole in VMEM and each block contracts a one-hot
                    over all ``seg_count = ceil(n/l)`` column segments —
                    O(seg_count) gather work per slot, O(n) VMEM;
                  * **segment-local** (``make_gust_spmv_local``): the
                    pack-time ``seg_blk`` table (scalar-prefetched)
                    steers the pipeline to stream only the ``S_blk``
                    x tiles a block actually references — one (1, l, B)
                    tile per inner grid step — and the contraction
                    shrinks to the block-local segments: O(S_blk) gather
                    work per slot, O(l·B) VMEM.  This is the paper's
                    Buffer-Filler locality story (touch only the vector
                    entries a window needs) and removes the
                    VMEM-residency cap on matrix *width*.

  crossbar +   -> a one-hot routing matmul on the MXU:
  adders          ``y_win += OneHot(Row_sch_blk)^T @ P_flat``.
                  Collision-freedom of the edge coloring is what makes this
                  exact — within a cycle each adder (output row) receives at
                  most one partial product, so the one-hot rows never
                  overlap within a cycle and the matmul loses nothing.

Grid: resident ``(num_windows, num_color_blocks)``; segment-local adds an
inner ``S_blk`` dimension that walks the block's x tiles.  Dimension 1
(and 2) are reductions — the output window tile initializes at the first
color block and accumulates across the rest, the Pallas analogue of the
adders' integrate-then-dump (the "dump signal" is the final grid step).

Double-buffered variants (PR 6).  The ``*_db`` builders collapse the
reduction grid dimensions into an in-kernel ``fori_loop`` and overlap the
fetch of step ``i+1`` with the accumulate of step ``i`` through manual
async copies (:func:`pltpu.make_async_copy`) into a two-slot ping/pong
VMEM scratch — the classic latency-hiding pipeline:

  * :func:`make_gust_spmv_db` streams the **schedule block triple**
    (m/col/row) from ANY-space memory, two ``(c_blk, l)`` tiles in
    flight, x VMEM-resident;
  * :func:`make_gust_spmv_local_db` keeps the schedule blocks
    pipeline-managed and ping/pongs the **x tiles** the block references
    (steered by the scalar-prefetched ``seg_blk`` table), with the
    column decode hoisted out of the tile loop.

Both are bit-identical to their single-buffered twins: the ``fori_loop``
carry performs the same f32 additions in the same order as the revisited
output tile / gather scratch.

Quantized variants (PR 6).  Every builder takes ``quantized=True`` to
accept an int8 value stream plus the pack-time per-block scale column
``scale_blk.reshape(T_blk, 1)``: the dequant ``float32(q) * scale`` is
fused into the accumulate (one extra VPU multiply per block), bit-exact
with :func:`repro.kernels.ref.dequant_ref`.

All arithmetic accumulates in f32 regardless of input dtype (MXU-native).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "make_gust_spmv",
    "make_gust_spmv_local",
    "make_gust_spmv_db",
    "make_gust_spmv_local_db",
    "block_accumulate",
    "block_math",
    "route_rows",
    "decode_local_cols",
    "local_tile_delta",
]


def route_rows(partial, row_blk, *, c_blk, l, b):
    """Crossbar + adders: one-hot routing matmul on the MXU.  ``partial``
    is the (l, C_blk, B) multiplied block; returns its (1, l, B)
    contribution to the window accumulator.  Padding slots carry m==0 and
    row==0, contributing exactly zero."""
    p_flat = partial.transpose(1, 0, 2).reshape(c_blk * l, b)
    row_flat = row_blk.reshape(c_blk * l)
    onehot_row = (
        row_flat[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (c_blk * l, l), 1)
    ).astype(jnp.float32)
    # (l, B) = (C_blk*l, l)^T @ (C_blk*l, B)
    return jax.lax.dot_general(
        onehot_row,
        p_flat,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[None]  # (1, l, B)


def block_math(m_blk, col_blk, row_blk, xs, *, l, seg_count, c_blk, b):
    """Value-level core of the *resident* per-block math: fused
    Buffer-Filler gather + VPU multiply + one-hot routing matmul, on
    already-loaded (and already-dequantized) arrays.  ``m_blk`` is the
    (C_blk, l) f32 value block, ``xs`` the (S, l, B) straight-layout x;
    the lane-reversed layout is derived here.  Returns the block's
    (1, l, B) f32 contribution to its window accumulator."""
    xf = xs[:, ::-1, :]  # (S, l, B) lane-reversed, derived in-kernel

    # ---- Buffer Filler: fused vector gather -----------------------------
    seg = col_blk // l  # (C_blk, l)
    off = col_blk - seg * l
    lane = jax.lax.broadcasted_iota(jnp.int32, (c_blk, l), 1)
    flip = (off != lane).astype(jnp.float32)  # 1.0 where lane-reversed

    # One-hot over column segments, contracted per lane (lane is a batch
    # dim): g[j, c, b] = Σ_s [seg[c,j]==s] · x[s, j, b].
    seg_t = seg.T  # (l, C_blk)
    onehot = (
        seg_t[:, :, None]
        == jax.lax.broadcasted_iota(jnp.int32, (l, c_blk, seg_count), 2)
    ).astype(jnp.float32)  # (l, C_blk, S)
    dnums = (((2,), (0,)), ((0,), (1,)))  # contract S; batch over lane j
    g_straight = jax.lax.dot_general(
        onehot, xs, dnums, preferred_element_type=jnp.float32
    )  # (l, C_blk, B)
    g_flip = jax.lax.dot_general(
        onehot, xf, dnums, preferred_element_type=jnp.float32
    )
    fsel = flip.T[:, :, None]  # (l, C_blk, 1)
    x_sel = g_straight * (1.0 - fsel) + g_flip * fsel  # (l, C_blk, B)

    # ---- multipliers (VPU) ----------------------------------------------
    partial = m_blk.T[:, :, None] * x_sel  # (l, C_blk, B)

    # ---- crossbar + adders ----------------------------------------------
    return route_rows(partial, row_blk, c_blk=c_blk, l=l, b=b)


def block_accumulate(m_ref, col_ref, row_ref, xs_ref, *, l, seg_count,
                     c_blk, b, scale=None):
    """Shared per-block math of the padded and ragged *resident* kernels,
    reading from refs.  ``scale`` (scalar f32 or None) fuses the int8
    dequant into the value load."""
    m_blk = m_ref[...].astype(jnp.float32)  # (C_blk, l)
    if scale is not None:
        m_blk = m_blk * scale
    return block_math(
        m_blk,
        col_ref[...].astype(jnp.int32),
        row_ref[...].astype(jnp.int32),
        xs_ref[...].astype(jnp.float32),
        l=l, seg_count=seg_count, c_blk=c_blk, b=b,
    )


def decode_local_cols(col_loc, *, l, c_blk):
    """Decode the block-local column block once per block (hoisted out of
    the tile loop by the double-buffered local kernel): returns
    ``(local_seg (C_blk, l) int32, fsel (l, C_blk, 1) f32)`` — the local
    segment of every slot and its straight/flipped lane select."""
    local_seg = col_loc // l
    off = col_loc - local_seg * l
    lane = jax.lax.broadcasted_iota(jnp.int32, (c_blk, l), 1)
    flip = (off != lane).astype(jnp.float32)
    return local_seg, flip.T[:, :, None]


def local_tile_delta(local_seg, fsel, tile, s):
    """Contribution of one streamed x tile to the (l, C_blk, B) gather
    accumulator: a slot contributes exactly when its local segment id
    equals ``s``.  ``tile`` is the (l, B) straight-layout tile; the
    lane-reversed layout is derived here.  After all ``S_blk`` tiles the
    accumulator equals the resident kernel's ``x_sel`` bitwise (each
    slot's value added once, zeros otherwise)."""
    tile_rev = tile[::-1, :]  # lane-reversed, derived in-kernel
    sel = tile[:, None, :] * (1.0 - fsel) + tile_rev[:, None, :] * fsel
    mask = (local_seg == s).astype(jnp.float32)  # (C_blk, l)
    return mask.T[:, :, None] * sel  # (l, C_blk, B)


def gather_local_step(col_ref, xt_ref, s, g_scr, *, l, c_blk):
    """One segment-local gather step, shared by the padded and ragged
    local kernels: accumulate into the (l, C_blk, B) scratch the
    contribution of the single streamed x tile ``xt_ref`` (the block's
    ``s``-th referenced segment)."""
    col_loc = col_ref[...].astype(jnp.int32)  # (C_blk, l)
    local_seg, fsel = decode_local_cols(col_loc, l=l, c_blk=c_blk)
    tile = xt_ref[...].astype(jnp.float32)[0]  # (l, B) straight
    g_scr[...] += local_tile_delta(local_seg, fsel, tile, s)


def _kernel(m_ref, col_ref, row_ref, xs_ref, y_ref, *, l, seg_count, c_blk,
            b):
    cb = pl.program_id(1)
    acc = block_accumulate(
        m_ref, col_ref, row_ref, xs_ref,
        l=l, seg_count=seg_count, c_blk=c_blk, b=b,
    )

    @pl.when(cb == 0)
    def _init():
        y_ref[...] = acc

    @pl.when(cb != 0)
    def _accum():
        y_ref[...] += acc


def _kernel_q(m_ref, col_ref, row_ref, scale_ref, xs_ref, y_ref, *, l,
              seg_count, c_blk, b):
    cb = pl.program_id(1)
    acc = block_accumulate(
        m_ref, col_ref, row_ref, xs_ref,
        l=l, seg_count=seg_count, c_blk=c_blk, b=b, scale=scale_ref[0, 0],
    )

    @pl.when(cb == 0)
    def _init():
        y_ref[...] = acc

    @pl.when(cb != 0)
    def _accum():
        y_ref[...] += acc


@functools.lru_cache(maxsize=256)
def make_gust_spmv(
    num_windows: int,
    c_pad: int,
    l: int,
    seg_count: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
    quantized: bool = False,
):
    """Build the resident-gather pallas_call for a fixed packed-schedule
    geometry.

    Memoized on geometry (all args are hashable scalars): ``gust_spmm``
    calls this on every trace, and direct callers (tests, the unfused
    path) would otherwise rebuild the kernel closure — and retrace it —
    on every invocation.

    BlockSpecs:
      * schedule stream (m/col/row): HBM -> VMEM tiles of (c_blk, l), one
        per grid step — the Buffer Filler pipeline;
      * x (straight only; the flip is derived in-kernel): full-array VMEM
        residency;
      * y: one (1, l, B) accumulator tile per window, revisited across the
        color-block (reduction) grid dimension.

    With ``quantized=True`` the returned function takes the per-block
    scale column ``scale_blk.reshape(T_blk, 1)`` between the row block
    and x: ``fn(m_blk, col_blk, row_blk, scale2d, xs)``.
    """
    if c_pad % c_blk:
        raise ValueError("c_pad must be a multiple of c_blk")
    num_cb = c_pad // c_blk
    grid = (num_windows, num_cb)

    sched_spec = pl.BlockSpec(
        (c_blk, l), lambda w, cb: (w * num_cb + cb, 0)
    )
    x_spec = pl.BlockSpec((seg_count, l, b), lambda w, cb: (0, 0, 0))
    out_spec = pl.BlockSpec((1, l, b), lambda w, cb: (w, 0, 0))

    in_specs = [sched_spec, sched_spec, sched_spec]
    if quantized:
        in_specs.append(
            pl.BlockSpec((1, 1), lambda w, cb: (w * num_cb + cb, 0))
        )
    in_specs.append(x_spec)
    kernel = functools.partial(
        _kernel_q if quantized else _kernel,
        l=l, seg_count=seg_count, c_blk=c_blk, b=b,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )


def _local_flush(m_ref, row_ref, g, y_ref, first, *, l, c_blk, b, scale):
    """Shared flush of the single-buffered local kernels: dequant (when
    quantized) + VPU multiply of the gathered block + routing matmul,
    then init-or-accumulate the window tile."""
    m_blk = m_ref[...].astype(jnp.float32)  # (C_blk, l)
    if scale is not None:
        m_blk = m_blk * scale
    partial = m_blk.T[:, :, None] * g  # (l, C_blk, B)
    acc = route_rows(
        partial, row_ref[...].astype(jnp.int32), c_blk=c_blk, l=l, b=b
    )

    @pl.when(first)
    def _init():
        y_ref[...] = acc

    @pl.when(jnp.logical_not(first))
    def _accum():
        y_ref[...] += acc


def _local_kernel(seg_ref, m_ref, col_ref, row_ref, xt_ref, y_ref, g_scr,
                  *, l, s_blk, c_blk, b):
    cb, s = pl.program_id(1), pl.program_id(2)

    @pl.when(s == 0)
    def _zero():
        g_scr[...] = jnp.zeros_like(g_scr)

    gather_local_step(col_ref, xt_ref, s, g_scr, l=l, c_blk=c_blk)

    @pl.when(s == s_blk - 1)
    def _flush():
        _local_flush(m_ref, row_ref, g_scr[...], y_ref, cb == 0,
                     l=l, c_blk=c_blk, b=b, scale=None)


def _local_kernel_q(seg_ref, m_ref, col_ref, row_ref, scale_ref, xt_ref,
                    y_ref, g_scr, *, l, s_blk, c_blk, b):
    cb, s = pl.program_id(1), pl.program_id(2)

    @pl.when(s == 0)
    def _zero():
        g_scr[...] = jnp.zeros_like(g_scr)

    gather_local_step(col_ref, xt_ref, s, g_scr, l=l, c_blk=c_blk)

    @pl.when(s == s_blk - 1)
    def _flush():
        _local_flush(m_ref, row_ref, g_scr[...], y_ref, cb == 0,
                     l=l, c_blk=c_blk, b=b, scale=scale_ref[0, 0])


@functools.lru_cache(maxsize=256)
def make_gust_spmv_local(
    num_windows: int,
    c_pad: int,
    l: int,
    s_blk: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
    quantized: bool = False,
):
    """Build the segment-local pallas_call for a padded-schedule geometry.

    Call signature of the returned function:
    ``fn(seg_flat, m_blk, col_loc, row_blk, xs)`` where ``seg_flat`` is
    the pack-time segment table flattened to ``(T_blk * S_blk,)`` int32
    (scalar-prefetched: it steers the x-tile pipeline before each body
    runs), ``col_loc`` holds the block-local columns, and ``xs`` is the
    straight-layout x ``(seg_count, l, B)`` — which stays in HBM-sized
    memory; only one (1, l, B) tile is in VMEM per grid step.  With
    ``quantized=True`` the scale column ``scale_blk.reshape(T_blk, 1)``
    is inserted after the row block.

    Grid ``(num_windows, c_pad/c_blk, S_blk)``: the inner dimension walks
    the ``S_blk`` x tiles the block references (``seg_flat[t*S_blk+s]``),
    accumulating the gathered block in VMEM scratch; the multiply +
    routing matmul fire on the last tile.  Gather work per block is
    O(S_blk · C_blk · l) instead of the resident kernel's
    O(seg_count · C_blk · l), and x VMEM residency is one tile instead of
    the whole vector — the wide-matrix fast path.
    """
    if c_pad % c_blk:
        raise ValueError("c_pad must be a multiple of c_blk")
    num_cb = c_pad // c_blk
    grid = (num_windows, num_cb, s_blk)

    sched_spec = pl.BlockSpec(
        (c_blk, l), lambda w, cb, s, seg: (w * num_cb + cb, 0)
    )
    x_spec = pl.BlockSpec(
        (1, l, b),
        lambda w, cb, s, seg: (seg[(w * num_cb + cb) * s_blk + s], 0, 0),
    )
    out_spec = pl.BlockSpec((1, l, b), lambda w, cb, s, seg: (w, 0, 0))

    in_specs = [sched_spec, sched_spec, sched_spec]
    if quantized:
        in_specs.append(
            pl.BlockSpec((1, 1), lambda w, cb, s, seg: (w * num_cb + cb, 0))
        )
    in_specs.append(x_spec)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=[pltpu.VMEM((l, c_blk, b), jnp.float32)],
    )
    kernel = functools.partial(
        _local_kernel_q if quantized else _local_kernel,
        l=l, s_blk=s_blk, c_blk=c_blk, b=b,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Double-buffered variants: manual async-copy ping/pong pipelines.
# ---------------------------------------------------------------------------


def stream_copy(src_ref, scr_ref, sem, slot, start_row, rows):
    """Async-copy descriptor for one stream tile: rows
    ``start_row : start_row + rows`` of ``src_ref`` (ANY-space) into slot
    ``slot`` of the (2, rows, ...) ping/pong scratch, tracked by the
    (already slot-indexed) DMA semaphore ``sem``.  ``.start()`` on the
    descriptor kicks the DMA; an identically-constructed descriptor's
    ``.wait()`` blocks on its completion."""
    return pltpu.make_async_copy(
        src_ref.at[pl.ds(start_row, rows)],
        scr_ref.at[slot],
        sem,
    )


def _db_kernel(m_ref, col_ref, row_ref, xs_ref, y_ref,
               m_scr, col_scr, row_scr, sems,
               *, l, seg_count, c_blk, num_cb, b, scale_ref=None):
    """Double-buffered resident kernel body: grid (W,), the color-block
    reduction runs as an in-kernel fori_loop whose ping/pong scratch
    holds two schedule block triples — the DMA of triple ``i+1`` overlaps
    the gather/multiply/route of triple ``i``.  The f32 additions happen
    in the same order as the single-buffered kernel's revisited output
    tile, so the result is bitwise identical."""
    w = pl.program_id(0)

    def copies(slot, blk):
        start = (w * num_cb + blk) * c_blk
        return (
            stream_copy(m_ref, m_scr, sems.at[slot, 0], slot, start, c_blk),
            stream_copy(col_ref, col_scr, sems.at[slot, 1], slot, start,
                        c_blk),
            stream_copy(row_ref, row_scr, sems.at[slot, 2], slot, start,
                        c_blk),
        )

    for c in copies(0, 0):
        c.start()

    def body(i, acc):
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < num_cb)
        def _prefetch():
            for c in copies(1 - slot, i + 1):
                c.start()

        for c in copies(slot, i):
            c.wait()
        m_blk = m_scr[slot].astype(jnp.float32)
        if scale_ref is not None:
            m_blk = m_blk * scale_ref[w * num_cb + i, 0]
        return acc + block_math(
            m_blk,
            col_scr[slot].astype(jnp.int32),
            row_scr[slot].astype(jnp.int32),
            xs_ref[...].astype(jnp.float32),
            l=l, seg_count=seg_count, c_blk=c_blk, b=b,
        )

    y_ref[...] = jax.lax.fori_loop(
        0, num_cb, body, jnp.zeros((1, l, b), jnp.float32)
    )


def _db_kernel_q(m_ref, col_ref, row_ref, scale_ref, xs_ref, y_ref,
                 m_scr, col_scr, row_scr, sems, *, l, seg_count, c_blk,
                 num_cb, b):
    _db_kernel(
        m_ref, col_ref, row_ref, xs_ref, y_ref, m_scr, col_scr, row_scr,
        sems, l=l, seg_count=seg_count, c_blk=c_blk, num_cb=num_cb, b=b,
        scale_ref=scale_ref,
    )


@functools.lru_cache(maxsize=256)
def make_gust_spmv_db(
    num_windows: int,
    c_pad: int,
    l: int,
    seg_count: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
    quantized: bool = False,
    value_dtype: str = "float32",
    index_dtype: str = "int32",
):
    """Double-buffered twin of :func:`make_gust_spmv`: same call
    signature and bitwise-identical output, but the schedule stream
    (m/col/row) is fetched by manual async copies into a two-slot
    ping/pong scratch so the DMA of color block ``i+1`` overlaps the
    math of block ``i``, and the whole per-window reduction runs in one
    grid step (grid ``(W,)`` instead of ``(W, num_cb)``).

    The scratch dtypes must match the operands, so the builder takes the
    stream's ``value_dtype``/``index_dtype`` names (the geometry memo now
    includes them).  When ``quantized``, the (T_blk, 1) scale column is
    small enough to sit whole in VMEM and is indexed per block inside the
    loop."""
    if c_pad % c_blk:
        raise ValueError("c_pad must be a multiple of c_blk")
    num_cb = c_pad // c_blk
    t_blk = num_windows * num_cb
    vdt, idt = jnp.dtype(value_dtype), jnp.dtype(index_dtype)

    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    in_specs = [any_spec, any_spec, any_spec]
    if quantized:
        in_specs.append(pl.BlockSpec((t_blk, 1), lambda w: (0, 0)))
    in_specs.append(pl.BlockSpec((seg_count, l, b), lambda w: (0, 0, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(num_windows,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, l, b), lambda w: (w, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, c_blk, l), vdt),
            pltpu.VMEM((2, c_blk, l), idt),
            pltpu.VMEM((2, c_blk, l), idt),
            pltpu.SemaphoreType.DMA((2, 3)),
        ],
    )
    kernel = functools.partial(
        _db_kernel_q if quantized else _db_kernel,
        l=l, seg_count=seg_count, c_blk=c_blk, num_cb=num_cb, b=b,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )


def _local_db_body(seg_ref, m_ref, col_ref, row_ref, xs_ref, y_ref,
                   xt_scr, sems, t, first, *, l, s_blk, c_blk, b, scale):
    """Shared double-buffered segment-local block: ping/pong the S_blk x
    tiles of stream block ``t`` (``seg_ref[t*s_blk + s]`` steers each
    copy), accumulating the gathered block in a fori_loop carry — the
    same f32 additions, in the same order, as the single-buffered
    kernel's gather scratch.  The column decode is hoisted out of the
    tile loop (one decode per block instead of one per tile)."""

    def copy(slot, s):
        return stream_copy(
            xs_ref, xt_scr, sems.at[slot], slot, seg_ref[t * s_blk + s], 1
        )

    copy(0, 0).start()
    local_seg, fsel = decode_local_cols(
        col_ref[...].astype(jnp.int32), l=l, c_blk=c_blk
    )

    def body(s, g):
        slot = jax.lax.rem(s, 2)

        @pl.when(s + 1 < s_blk)
        def _prefetch():
            copy(1 - slot, s + 1).start()

        copy(slot, s).wait()
        tile = xt_scr[slot].astype(jnp.float32)[0]  # (l, B)
        return g + local_tile_delta(local_seg, fsel, tile, s)

    g = jax.lax.fori_loop(
        0, s_blk, body, jnp.zeros((l, c_blk, b), jnp.float32)
    )
    _local_flush(m_ref, row_ref, g, y_ref, first,
                 l=l, c_blk=c_blk, b=b, scale=scale)


def _local_db_kernel(seg_ref, m_ref, col_ref, row_ref, xs_ref, y_ref,
                     xt_scr, sems, *, l, s_blk, c_blk, num_cb, b):
    w, cb = pl.program_id(0), pl.program_id(1)
    _local_db_body(seg_ref, m_ref, col_ref, row_ref, xs_ref, y_ref,
                   xt_scr, sems, w * num_cb + cb, cb == 0,
                   l=l, s_blk=s_blk, c_blk=c_blk, b=b, scale=None)


def _local_db_kernel_q(seg_ref, m_ref, col_ref, row_ref, scale_ref, xs_ref,
                       y_ref, xt_scr, sems, *, l, s_blk, c_blk, num_cb, b):
    w, cb = pl.program_id(0), pl.program_id(1)
    _local_db_body(seg_ref, m_ref, col_ref, row_ref, xs_ref, y_ref,
                   xt_scr, sems, w * num_cb + cb, cb == 0,
                   l=l, s_blk=s_blk, c_blk=c_blk, b=b,
                   scale=scale_ref[0, 0])


@functools.lru_cache(maxsize=256)
def make_gust_spmv_local_db(
    num_windows: int,
    c_pad: int,
    l: int,
    s_blk: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
    quantized: bool = False,
    x_dtype: str = "float32",
):
    """Double-buffered twin of :func:`make_gust_spmv_local`: same call
    signature and bitwise-identical output.  The schedule blocks stay
    pipeline-managed (one (c_blk, l) triple per grid step), x lives in
    ANY-space memory, and the block's ``S_blk`` referenced tiles are
    fetched by manual async copies into a two-slot ping/pong scratch —
    the fetch of tile ``s+1`` overlaps the gather of tile ``s``, and the
    ``S_blk`` inner grid dimension collapses into the kernel (grid
    ``(W, num_cb)`` instead of ``(W, num_cb, S_blk)``), which also hoists
    the column decode and the flush's scratch round-trip out of the tile
    loop."""
    if c_pad % c_blk:
        raise ValueError("c_pad must be a multiple of c_blk")
    num_cb = c_pad // c_blk
    grid = (num_windows, num_cb)
    xdt = jnp.dtype(x_dtype)

    sched_spec = pl.BlockSpec(
        (c_blk, l), lambda w, cb, seg: (w * num_cb + cb, 0)
    )
    in_specs = [sched_spec, sched_spec, sched_spec]
    if quantized:
        in_specs.append(
            pl.BlockSpec((1, 1), lambda w, cb, seg: (w * num_cb + cb, 0))
        )
    in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, l, b), lambda w, cb, seg: (w, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, 1, l, b), xdt),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    kernel = functools.partial(
        _local_db_kernel_q if quantized else _local_db_kernel,
        l=l, s_blk=s_blk, c_blk=c_blk, num_cb=num_cb, b=b,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )
