"""Flagship Pallas TPU kernel: windowed scheduled GUST SpMV.

TPU adaptation of the paper's three hardware levels (DESIGN.md §2):

  multipliers  -> VPU elementwise multiply of the scheduled value block
                  with the gathered vector block;
  Buffer Filler-> two execution modes for the per-slot gather
                  ``v[Col_sch]``, both fused in-kernel (the scheduler
                  only ever assigns a column to its own lane or the
                  lane-reversed position — load-balance step 3 — so the
                  gather is a segment one-hot / segment-select plus a
                  straight/flipped select, never random access):

                  * **resident** (``make_gust_spmv``): the vector lives
                    whole in VMEM and each block contracts a one-hot
                    over all ``seg_count = ceil(n/l)`` column segments —
                    O(seg_count) gather work per slot, O(n) VMEM;
                  * **segment-local** (``make_gust_spmv_local``): the
                    pack-time ``seg_blk`` table (scalar-prefetched)
                    steers the pipeline to stream only the ``S_blk``
                    x tiles a block actually references — one (1, l, B)
                    tile per inner grid step — and the contraction
                    shrinks to the block-local segments: O(S_blk) gather
                    work per slot, O(l·B) VMEM.  This is the paper's
                    Buffer-Filler locality story (touch only the vector
                    entries a window needs) and removes the
                    VMEM-residency cap on matrix *width*.

  crossbar +   -> a one-hot routing matmul on the MXU:
  adders          ``y_win += OneHot(Row_sch_blk)^T @ P_flat``.
                  Collision-freedom of the edge coloring is what makes this
                  exact — within a cycle each adder (output row) receives at
                  most one partial product, so the one-hot rows never
                  overlap within a cycle and the matmul loses nothing.

Grid: resident ``(num_windows, num_color_blocks)``; segment-local adds an
inner ``S_blk`` dimension that walks the block's x tiles.  Dimension 1
(and 2) are reductions — the output window tile initializes at the first
color block and accumulates across the rest, the Pallas analogue of the
adders' integrate-then-dump (the "dump signal" is the final grid step).

The scheduled stream (``m/col/row`` blocks) is what flows HBM->VMEM, tile
by tile, double-buffered by the Pallas pipeline — exactly the paper's
two-step Buffer Filler pipeline.  The lane-reversed x layout is derived
*in-kernel* from the straight layout (``xs[:, ::-1, :]`` on the VMEM
tile), so only one copy of x ever crosses HBM->VMEM.

All arithmetic accumulates in f32 regardless of input dtype (MXU-native).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "make_gust_spmv",
    "make_gust_spmv_local",
    "block_accumulate",
    "route_rows",
]


def route_rows(partial, row_blk, *, c_blk, l, b):
    """Crossbar + adders: one-hot routing matmul on the MXU.  ``partial``
    is the (l, C_blk, B) multiplied block; returns its (1, l, B)
    contribution to the window accumulator.  Padding slots carry m==0 and
    row==0, contributing exactly zero."""
    p_flat = partial.transpose(1, 0, 2).reshape(c_blk * l, b)
    row_flat = row_blk.reshape(c_blk * l)
    onehot_row = (
        row_flat[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (c_blk * l, l), 1)
    ).astype(jnp.float32)
    # (l, B) = (C_blk*l, l)^T @ (C_blk*l, B)
    return jax.lax.dot_general(
        onehot_row,
        p_flat,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[None]  # (1, l, B)


def block_accumulate(m_ref, col_ref, row_ref, xs_ref, *, l, seg_count,
                     c_blk, b):
    """Shared per-block math of the padded and ragged *resident* kernels:
    fused Buffer-Filler gather + VPU multiply + one-hot routing matmul.
    The lane-reversed x layout is derived in-kernel.  Returns the block's
    (1, l, B) contribution to its window accumulator."""
    m_blk = m_ref[...].astype(jnp.float32)  # (C_blk, l)
    col_blk = col_ref[...].astype(jnp.int32)  # (C_blk, l) int
    row_blk = row_ref[...].astype(jnp.int32)  # (C_blk, l) int
    xs = xs_ref[...].astype(jnp.float32)  # (S, l, B) straight layout
    xf = xs[:, ::-1, :]  # (S, l, B) lane-reversed, derived in-kernel

    # ---- Buffer Filler: fused vector gather -----------------------------
    seg = col_blk // l  # (C_blk, l)
    off = col_blk - seg * l
    lane = jax.lax.broadcasted_iota(jnp.int32, (c_blk, l), 1)
    flip = (off != lane).astype(jnp.float32)  # 1.0 where lane-reversed

    # One-hot over column segments, contracted per lane (lane is a batch
    # dim): g[j, c, b] = Σ_s [seg[c,j]==s] · x[s, j, b].
    seg_t = seg.T  # (l, C_blk)
    onehot = (
        seg_t[:, :, None]
        == jax.lax.broadcasted_iota(jnp.int32, (l, c_blk, seg_count), 2)
    ).astype(jnp.float32)  # (l, C_blk, S)
    dnums = (((2,), (0,)), ((0,), (1,)))  # contract S; batch over lane j
    g_straight = jax.lax.dot_general(
        onehot, xs, dnums, preferred_element_type=jnp.float32
    )  # (l, C_blk, B)
    g_flip = jax.lax.dot_general(
        onehot, xf, dnums, preferred_element_type=jnp.float32
    )
    fsel = flip.T[:, :, None]  # (l, C_blk, 1)
    x_sel = g_straight * (1.0 - fsel) + g_flip * fsel  # (l, C_blk, B)

    # ---- multipliers (VPU) ----------------------------------------------
    partial = m_blk.T[:, :, None] * x_sel  # (l, C_blk, B)

    # ---- crossbar + adders ----------------------------------------------
    return route_rows(partial, row_blk, c_blk=c_blk, l=l, b=b)


def gather_local_step(col_ref, xt_ref, s, g_scr, *, l, c_blk):
    """One segment-local gather step, shared by the padded and ragged
    local kernels: accumulate into the (l, C_blk, B) scratch the
    contribution of the single streamed x tile ``xt_ref`` (the block's
    ``s``-th referenced segment).  ``col_ref`` holds the *block-local*
    columns (``col_loc``): a slot contributes exactly when its local
    segment id equals ``s``, so after ``S_blk`` steps the scratch equals
    the resident kernel's ``x_sel`` bitwise (each slot's value is added
    once, zeros otherwise)."""
    col_loc = col_ref[...].astype(jnp.int32)  # (C_blk, l)
    local_seg = col_loc // l
    off = col_loc - local_seg * l
    lane = jax.lax.broadcasted_iota(jnp.int32, (c_blk, l), 1)
    flip = (off != lane).astype(jnp.float32)
    tile = xt_ref[...].astype(jnp.float32)[0]  # (l, B) straight
    tile_rev = tile[::-1, :]  # lane-reversed, derived in-kernel
    fsel = flip.T[:, :, None]  # (l, C_blk, 1)
    sel = tile[:, None, :] * (1.0 - fsel) + tile_rev[:, None, :] * fsel
    mask = (local_seg == s).astype(jnp.float32)  # (C_blk, l)
    g_scr[...] += mask.T[:, :, None] * sel  # (l, C_blk, B)


def _kernel(m_ref, col_ref, row_ref, xs_ref, y_ref, *, l, seg_count, c_blk,
            b):
    cb = pl.program_id(1)
    acc = block_accumulate(
        m_ref, col_ref, row_ref, xs_ref,
        l=l, seg_count=seg_count, c_blk=c_blk, b=b,
    )

    @pl.when(cb == 0)
    def _init():
        y_ref[...] = acc

    @pl.when(cb != 0)
    def _accum():
        y_ref[...] += acc


@functools.lru_cache(maxsize=256)
def make_gust_spmv(
    num_windows: int,
    c_pad: int,
    l: int,
    seg_count: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
):
    """Build the resident-gather pallas_call for a fixed packed-schedule
    geometry.

    Memoized on geometry (all args are hashable scalars): ``gust_spmm``
    calls this on every trace, and direct callers (tests, the unfused
    path) would otherwise rebuild the kernel closure — and retrace it —
    on every invocation.

    BlockSpecs:
      * schedule stream (m/col/row): HBM -> VMEM tiles of (c_blk, l), one
        per grid step — the Buffer Filler pipeline;
      * x (straight only; the flip is derived in-kernel): full-array VMEM
        residency;
      * y: one (1, l, B) accumulator tile per window, revisited across the
        color-block (reduction) grid dimension.
    """
    if c_pad % c_blk:
        raise ValueError("c_pad must be a multiple of c_blk")
    num_cb = c_pad // c_blk
    grid = (num_windows, num_cb)

    sched_spec = pl.BlockSpec(
        (c_blk, l), lambda w, cb: (w * num_cb + cb, 0)
    )
    x_spec = pl.BlockSpec((seg_count, l, b), lambda w, cb: (0, 0, 0))
    out_spec = pl.BlockSpec((1, l, b), lambda w, cb: (w, 0, 0))

    kernel = functools.partial(
        _kernel, l=l, seg_count=seg_count, c_blk=c_blk, b=b
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[sched_spec, sched_spec, sched_spec, x_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )


def _local_kernel(seg_ref, m_ref, col_ref, row_ref, xt_ref, y_ref, g_scr,
                  *, l, s_blk, c_blk, b):
    cb, s = pl.program_id(1), pl.program_id(2)

    @pl.when(s == 0)
    def _zero():
        g_scr[...] = jnp.zeros_like(g_scr)

    gather_local_step(col_ref, xt_ref, s, g_scr, l=l, c_blk=c_blk)

    @pl.when(s == s_blk - 1)
    def _flush():
        m_blk = m_ref[...].astype(jnp.float32)  # (C_blk, l)
        partial = m_blk.T[:, :, None] * g_scr[...]  # (l, C_blk, B)
        acc = route_rows(
            partial, row_ref[...].astype(jnp.int32),
            c_blk=c_blk, l=l, b=b,
        )

        @pl.when(cb == 0)
        def _init():
            y_ref[...] = acc

        @pl.when(cb != 0)
        def _accum():
            y_ref[...] += acc


@functools.lru_cache(maxsize=256)
def make_gust_spmv_local(
    num_windows: int,
    c_pad: int,
    l: int,
    s_blk: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
):
    """Build the segment-local pallas_call for a padded-schedule geometry.

    Call signature of the returned function:
    ``fn(seg_flat, m_blk, col_loc, row_blk, xs)`` where ``seg_flat`` is
    the pack-time segment table flattened to ``(T_blk * S_blk,)`` int32
    (scalar-prefetched: it steers the x-tile pipeline before each body
    runs), ``col_loc`` holds the block-local columns, and ``xs`` is the
    straight-layout x ``(seg_count, l, B)`` — which stays in HBM-sized
    memory; only one (1, l, B) tile is in VMEM per grid step.

    Grid ``(num_windows, c_pad/c_blk, S_blk)``: the inner dimension walks
    the ``S_blk`` x tiles the block references (``seg_flat[t*S_blk+s]``),
    accumulating the gathered block in VMEM scratch; the multiply +
    routing matmul fire on the last tile.  Gather work per block is
    O(S_blk · C_blk · l) instead of the resident kernel's
    O(seg_count · C_blk · l), and x VMEM residency is one tile instead of
    the whole vector — the wide-matrix fast path.
    """
    if c_pad % c_blk:
        raise ValueError("c_pad must be a multiple of c_blk")
    num_cb = c_pad // c_blk
    grid = (num_windows, num_cb, s_blk)

    sched_spec = pl.BlockSpec(
        (c_blk, l), lambda w, cb, s, seg: (w * num_cb + cb, 0)
    )
    x_spec = pl.BlockSpec(
        (1, l, b),
        lambda w, cb, s, seg: (seg[(w * num_cb + cb) * s_blk + s], 0, 0),
    )
    out_spec = pl.BlockSpec((1, l, b), lambda w, cb, s, seg: (w, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[sched_spec, sched_spec, sched_spec, x_spec],
        out_specs=out_spec,
        scratch_shapes=[pltpu.VMEM((l, c_blk, b), jnp.float32)],
    )
    kernel = functools.partial(
        _local_kernel, l=l, s_blk=s_blk, c_blk=c_blk, b=b
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )
