"""Flagship Pallas TPU kernel: windowed scheduled GUST SpMV.

TPU adaptation of the paper's three hardware levels (DESIGN.md §2):

  multipliers  -> VPU elementwise multiply of the scheduled value block
                  with the gathered vector block;
  Buffer Filler-> the vector lives resident in VMEM; the per-slot gather
                  ``v[Col_sch]`` is fused in-kernel as a *segment one-hot
                  contraction* (the scheduler only ever assigns a column to
                  its own lane or the lane-reversed position — load-balance
                  step 3 — so a one-hot over the ``n/l`` column segments
                  plus a straight/flipped select reconstructs the gather
                  without random access);
  crossbar +   -> a one-hot routing matmul on the MXU:
  adders          ``y_win += OneHot(Row_sch_blk)^T @ P_flat``.
                  Collision-freedom of the edge coloring is what makes this
                  exact — within a cycle each adder (output row) receives at
                  most one partial product, so the one-hot rows never
                  overlap within a cycle and the matmul loses nothing.

Grid: ``(num_windows, num_color_blocks)``; dimension 1 is a reduction —
the output window tile initializes at the first color block and
accumulates across the rest, which is the Pallas analogue of the adders'
integrate-then-dump (the "dump signal" is the final grid step).

The scheduled stream (``m/col/row`` blocks) is what flows HBM->VMEM, tile
by tile, double-buffered by the Pallas pipeline — exactly the paper's
two-step Buffer Filler pipeline.  The dense vector/activation ``x`` is
resident in VMEM for the whole call (the paper: "GUST stores the whole
input vector as the first step").

All arithmetic accumulates in f32 regardless of input dtype (MXU-native).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["make_gust_spmv", "block_accumulate"]


def block_accumulate(m_ref, col_ref, row_ref, xs_ref, xf_ref, *, l, seg_count,
                     c_blk, b):
    """Shared per-block math of the padded and ragged kernels: fused
    Buffer-Filler gather + VPU multiply + one-hot routing matmul.  Returns
    the block's (1, l, B) contribution to its window accumulator."""
    m_blk = m_ref[...].astype(jnp.float32)  # (C_blk, l)
    col_blk = col_ref[...].astype(jnp.int32)  # (C_blk, l) int
    row_blk = row_ref[...].astype(jnp.int32)  # (C_blk, l) int
    xs = xs_ref[...].astype(jnp.float32)  # (S, l, B) straight layout
    xf = xf_ref[...].astype(jnp.float32)  # (S, l, B) lane-reversed layout

    # ---- Buffer Filler: fused vector gather -----------------------------
    seg = col_blk // l  # (C_blk, l)
    off = col_blk - seg * l
    lane = jax.lax.broadcasted_iota(jnp.int32, (c_blk, l), 1)
    flip = (off != lane).astype(jnp.float32)  # 1.0 where lane-reversed

    # One-hot over column segments, contracted per lane (lane is a batch
    # dim): g[j, c, b] = Σ_s [seg[c,j]==s] · x[s, j, b].
    seg_t = seg.T  # (l, C_blk)
    onehot = (
        seg_t[:, :, None]
        == jax.lax.broadcasted_iota(jnp.int32, (l, c_blk, seg_count), 2)
    ).astype(jnp.float32)  # (l, C_blk, S)
    dnums = (((2,), (0,)), ((0,), (1,)))  # contract S; batch over lane j
    g_straight = jax.lax.dot_general(
        onehot, xs, dnums, preferred_element_type=jnp.float32
    )  # (l, C_blk, B)
    g_flip = jax.lax.dot_general(
        onehot, xf, dnums, preferred_element_type=jnp.float32
    )
    fsel = flip.T[:, :, None]  # (l, C_blk, 1)
    x_sel = g_straight * (1.0 - fsel) + g_flip * fsel  # (l, C_blk, B)

    # ---- multipliers (VPU) ----------------------------------------------
    partial = m_blk.T[:, :, None] * x_sel  # (l, C_blk, B)

    # ---- crossbar + adders: one-hot routing matmul (MXU) ------------------
    p_flat = partial.transpose(1, 0, 2).reshape(c_blk * l, b)
    row_flat = row_blk.reshape(c_blk * l)
    onehot_row = (
        row_flat[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (c_blk * l, l), 1)
    ).astype(jnp.float32)
    # (l, B) = (C_blk*l, l)^T @ (C_blk*l, B); padding slots carry m==0 and
    # row==0, contributing exactly zero.
    return jax.lax.dot_general(
        onehot_row,
        p_flat,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[None]  # (1, l, B)


def _kernel(m_ref, col_ref, row_ref, xs_ref, xf_ref, y_ref, *, l, seg_count, c_blk, b):
    cb = pl.program_id(1)
    acc = block_accumulate(
        m_ref, col_ref, row_ref, xs_ref, xf_ref,
        l=l, seg_count=seg_count, c_blk=c_blk, b=b,
    )

    @pl.when(cb == 0)
    def _init():
        y_ref[...] = acc

    @pl.when(cb != 0)
    def _accum():
        y_ref[...] += acc


@functools.lru_cache(maxsize=256)
def make_gust_spmv(
    num_windows: int,
    c_pad: int,
    l: int,
    seg_count: int,
    b: int,
    *,
    c_blk: int = 8,
    interpret: bool = True,
):
    """Build the pallas_call for a fixed packed-schedule geometry.

    Memoized on geometry (all args are hashable scalars): ``gust_spmm``
    calls this on every trace, and direct callers (tests, the unfused
    path) would otherwise rebuild the kernel closure — and retrace it —
    on every invocation.

    BlockSpecs:
      * schedule stream (m/col/row): HBM -> VMEM tiles of (c_blk, l), one
        per grid step — the Buffer Filler pipeline;
      * x (straight + flipped): full-array VMEM residency;
      * y: one (1, l, B) accumulator tile per window, revisited across the
        color-block (reduction) grid dimension.
    """
    if c_pad % c_blk:
        raise ValueError("c_pad must be a multiple of c_blk")
    num_cb = c_pad // c_blk
    grid = (num_windows, num_cb)

    sched_spec = pl.BlockSpec(
        (c_blk, l), lambda w, cb: (w * num_cb + cb, 0)
    )
    x_spec = pl.BlockSpec((seg_count, l, b), lambda w, cb: (0, 0, 0))
    out_spec = pl.BlockSpec((1, l, b), lambda w, cb: (w, 0, 0))

    kernel = functools.partial(
        _kernel, l=l, seg_count=seg_count, c_blk=c_blk, b=b
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[sched_spec, sched_spec, sched_spec, x_spec, x_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((num_windows, l, b), jnp.float32),
        interpret=interpret,
    )
