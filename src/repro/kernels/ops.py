"""jit'd public wrappers around the GUST Pallas kernels.

``pack_schedule`` turns a host-side :class:`~repro.core.formats.GustSchedule`
into a :class:`PackedSchedule` — a JAX pytree of fixed-shape arrays (the
ragged per-window color counts padded to a common ``C_pad``).  Because it
is a pytree of plain arrays it can be sharded, donated, checkpointed, and
— crucially for the multi-pod dry-run — described by ShapeDtypeStructs
sized from the paper's Eq. 9/10 expected-color bound without ever running
the scheduler.

``gust_spmm`` executes ``y = M @ x`` for ``x: (n, B)`` through either the
fused Pallas kernel (``use_kernel=True``) or the pure-XLA packed path
(identical math, used as the dry-run/serving default on non-TPU backends
and as the kernel oracle).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import GustSchedule

from .gust_spmv import make_gust_spmv
from .ref import gust_spmv_ref

__all__ = ["PackedSchedule", "pack_schedule", "gust_spmm", "packed_spec"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedSchedule:
    """Fixed-shape GUST scheduled format (pytree).

    Arrays (leaves):
      m_blk:   (W * C_pad, l) values; 0.0 in padding slots.
      col_blk: (W * C_pad, l) int32 original column index; padding slots
               hold the slot's own lane (in-bounds, straight layout).
      row_blk: (W * C_pad, l) int32 adder index; 0 in padding slots.
      row_perm:(W * l,) int32 — original row of each scheduled row position
               (identity-extended past m).

    Static (aux):
      l, num_windows, c_pad, shape=(m, n), fusable (lane structure verified
      for the fused in-kernel gather).
    """

    m_blk: jnp.ndarray
    col_blk: jnp.ndarray
    row_blk: jnp.ndarray
    row_perm: jnp.ndarray
    l: int
    num_windows: int
    c_pad: int
    shape: Tuple[int, int]
    fusable: bool

    def tree_flatten(self):
        leaves = (self.m_blk, self.col_blk, self.row_blk, self.row_perm)
        aux = (self.l, self.num_windows, self.c_pad, self.shape, self.fusable)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    @property
    def seg_count(self) -> int:
        return -(-self.shape[1] // self.l)

    @property
    def stream_bytes(self) -> int:
        """HBM bytes of the scheduled stream (value f32 + col i32 + row i32)."""
        return int(self.m_blk.size) * (4 + 4 + 4)


def pack_schedule(
    sched: GustSchedule, c_blk: int = 8, value_dtype=jnp.float32,
    index_dtype=jnp.int32,
) -> PackedSchedule:
    """Pad the ragged per-window schedule to (W, C_pad, l) blocks.

    C_pad = max window colors, rounded up to a multiple of ``c_blk``.  The
    padding cost is real on hardware too (lanes idle while the heaviest
    window drains) and is already counted by the cycle model through Eq. 1.
    """
    l, W = sched.l, sched.num_windows
    m, n = sched.shape
    cpw = np.diff(sched.window_starts)
    c_max = int(cpw.max()) if W else 1
    c_pad = max(-(-c_max // c_blk) * c_blk, c_blk)

    m_b = np.zeros((W, c_pad, l), dtype=np.float32)
    r_b = np.zeros((W, c_pad, l), dtype=np.int32)
    c_b = np.tile(np.arange(l, dtype=np.int32), (W, c_pad, 1))
    for w in range(W):
        s, t = sched.window_starts[w], sched.window_starts[w + 1]
        m_b[w, : t - s] = sched.m_sch[s:t]
        r_b[w, : t - s] = sched.row_sch[s:t]
        c_b[w, : t - s] = sched.col_sch[s:t]

    # Verify the lane structure the fused gather relies on: every slot's
    # column offset is its lane or the reversed lane.
    lane = np.arange(l, dtype=np.int32)[None, None, :]
    off = c_b % l
    fusable = bool(np.all((off == lane) | (off == l - 1 - lane)))

    row_perm = np.arange(W * l, dtype=np.int32)
    row_perm[: sched.row_perm.shape[0]] = sched.row_perm

    return PackedSchedule(
        m_blk=jnp.asarray(m_b.reshape(W * c_pad, l), value_dtype),
        col_blk=jnp.asarray(c_b.reshape(W * c_pad, l), index_dtype),
        row_blk=jnp.asarray(r_b.reshape(W * c_pad, l), index_dtype),
        row_perm=jnp.asarray(row_perm),
        l=l,
        num_windows=W,
        c_pad=c_pad,
        shape=(m, n),
        fusable=fusable,
    )


def packed_spec(
    m: int,
    n: int,
    l: int,
    c_pad: int,
    value_dtype=jnp.float32,
) -> PackedSchedule:
    """ShapeDtypeStruct stand-in for a PackedSchedule — used by the dry-run
    (no allocation).  ``c_pad`` is typically sized from the Eq. 9 bound:
    ``expected_colors_bound(n, density, l)`` rounded up."""
    W = max(-(-m // l), 1)
    sds = jax.ShapeDtypeStruct
    return PackedSchedule(
        m_blk=sds((W * c_pad, l), value_dtype),
        col_blk=sds((W * c_pad, l), jnp.int32),
        row_blk=sds((W * c_pad, l), jnp.int32),
        row_perm=sds((W * l,), jnp.int32),
        l=l,
        num_windows=W,
        c_pad=c_pad,
        shape=(m, n),
        fusable=True,
    )


def _prep_x(x: jnp.ndarray, n: int, l: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Zero-pad x to (S*l, B) and produce straight + lane-reversed VMEM
    layouts (S, l, B)."""
    seg_count = -(-n // l)
    pad = seg_count * l - n
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    x2d = xp.reshape(seg_count, l, -1)
    return x2d, x2d[:, ::-1, :]


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret", "c_blk"))
def gust_spmm(
    packed: PackedSchedule,
    x: jnp.ndarray,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
    c_blk: int = 8,
) -> jnp.ndarray:
    """``y = M @ x`` from the packed scheduled format; x (n, B) -> y (m, B)."""
    m, n = packed.shape
    if x.ndim != 2 or x.shape[0] != n:
        raise ValueError(f"expected x of shape ({n}, B), got {x.shape}")
    l, W = packed.l, packed.num_windows
    b = x.shape[1]

    if use_kernel and packed.fusable:
        x2d, x2f = _prep_x(x, n, l)
        fn = make_gust_spmv(
            W, packed.c_pad, l, packed.seg_count, b, c_blk=c_blk, interpret=interpret
        )
        y_win = fn(packed.m_blk, packed.col_blk, packed.row_blk, x2d, x2f)
    else:
        seg_count = -(-n // l)
        xp = jnp.pad(x, ((0, seg_count * l - n), (0, 0)))
        y_win = gust_spmv_ref(
            packed.m_blk,
            packed.col_blk,
            packed.row_blk,
            xp,
            num_windows=W,
            l=l,
        )
    y_sorted = y_win.reshape(W * l, b)
    out = jnp.zeros((max(m, W * l), b), jnp.float32)
    out = out.at[packed.row_perm].set(y_sorted)
    return out[:m].astype(x.dtype)
