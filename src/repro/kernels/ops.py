"""jit'd public wrappers around the GUST Pallas kernels.

The packed scheduled format itself lives in :mod:`repro.core.packing` —
the single home of the ragged→packed conversion (vectorized packing,
repadding, the leaves/meta codec, and the content-keyed schedule cache).
``PackedSchedule`` / ``pack_schedule`` / ``packed_spec`` are re-exported
here for compatibility; this module only owns the *execution* entry
point.

``gust_spmm`` executes ``y = M @ x`` for ``x: (n, B)`` through either the
fused Pallas kernel (``use_kernel=True``) or the pure-XLA packed path
(identical math, used as the dry-run/serving default on non-TPU backends
and as the kernel oracle).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.packing import PackedSchedule, pack_schedule, packed_spec

from .gust_spmv import make_gust_spmv
from .ref import gust_spmv_ref

__all__ = ["PackedSchedule", "pack_schedule", "gust_spmm", "packed_spec"]


def _prep_x(x: jnp.ndarray, n: int, l: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Zero-pad x to (S*l, B) and produce straight + lane-reversed VMEM
    layouts (S, l, B)."""
    seg_count = -(-n // l)
    pad = seg_count * l - n
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    x2d = xp.reshape(seg_count, l, -1)
    return x2d, x2d[:, ::-1, :]


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret", "c_blk"))
def gust_spmm(
    packed: PackedSchedule,
    x: jnp.ndarray,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
    c_blk: int = 8,
) -> jnp.ndarray:
    """``y = M @ x`` from the packed scheduled format; x (n, B) -> y (m, B)."""
    m, n = packed.shape
    if x.ndim != 2 or x.shape[0] != n:
        raise ValueError(f"expected x of shape ({n}, B), got {x.shape}")
    l, W = packed.l, packed.num_windows
    b = x.shape[1]

    if use_kernel and packed.fusable:
        x2d, x2f = _prep_x(x, n, l)
        fn = make_gust_spmv(
            W, packed.c_pad, l, packed.seg_count, b, c_blk=c_blk, interpret=interpret
        )
        y_win = fn(packed.m_blk, packed.col_blk, packed.row_blk, x2d, x2f)
    else:
        seg_count = -(-n // l)
        xp = jnp.pad(x, ((0, seg_count * l - n), (0, 0)))
        y_win = gust_spmv_ref(
            packed.m_blk,
            packed.col_blk,
            packed.row_blk,
            xp,
            num_windows=W,
            l=l,
        )
    y_sorted = y_win.reshape(W * l, b)
    out = jnp.zeros((max(m, W * l), b), jnp.float32)
    out = out.at[packed.row_perm].set(y_sorted)
    return out[:m].astype(x.dtype)
