"""Execution layer of the GUST scheduled format + legacy entry shims.

The packed scheduled format itself lives in :mod:`repro.core.packing`;
the plan/execute API lives in :mod:`repro.core.plan` (one decision point
for layout/backend/shard choice).  This module owns only the jitted
executor, :func:`execute_spmm`, which runs ``y = M @ x`` from **either**
fixed-shape layout — a padded :class:`PackedSchedule` (dense
``(W, C_pad/c_blk)`` grid) or a ragged :class:`RaggedSchedule` block
stream (1-D scalar-prefetch grid over real blocks only) — through the
Pallas kernels (``use_kernel=True``) or the pure-XLA segment-sum path
(identical math; the kernel oracle and the default off TPU).

``gust_spmm`` / ``gust_spmm_auto`` remain as thin compatibility shims
that construct a :class:`~repro.core.plan.GustPlan` and delegate — new
code should call ``repro.plan(...).spmm(x)`` directly.
"""

from __future__ import annotations

import functools
import warnings
from typing import Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.formats import GustSchedule
from repro.core.packing import (
    PackedSchedule,
    RaggedSchedule,
    default_cache,
    pack_schedule,
    packed_spec,
    resolve_gather,
)

from .gust_spmv import make_gust_spmv, make_gust_spmv_local
from .gust_spmv_ragged import (
    make_gust_spmv_ragged,
    make_gust_spmv_ragged_local,
)
from .ref import (
    gust_spmv_local_ref,
    gust_spmv_ragged_local_ref,
    gust_spmv_ragged_ref,
    gust_spmv_ref,
)

__all__ = [
    "PackedSchedule",
    "RaggedSchedule",
    "pack_schedule",
    "execute_spmm",
    "gust_spmm",
    "gust_spmm_auto",
    "packed_spec",
]


def _prep_x(x: jnp.ndarray, n: int, l: int) -> jnp.ndarray:
    """Zero-pad x to (S*l, B) and reshape to the straight segment-major
    VMEM layout (S, l, B).  The lane-reversed layout the fused gather
    selects against is derived in-kernel (``xs[:, ::-1, :]``), so only
    one copy of x crosses HBM->VMEM."""
    seg_count = -(-n // l)
    pad = seg_count * l - n
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    return xp.reshape(seg_count, l, -1)


def _seg_flat(packed) -> jnp.ndarray:
    """The pack-time segment table flattened to (T_blk * S_blk,) int32 —
    the scalar-prefetch operand steering the local kernels' x-tile
    pipeline."""
    return jnp.asarray(packed.seg_blk, jnp.int32).reshape(-1)


@functools.partial(
    jax.jit,
    static_argnames=("use_kernel", "interpret", "c_blk", "transpose_io",
                     "gather"),
)
def execute_spmm(
    packed: Union[PackedSchedule, RaggedSchedule],
    x: jnp.ndarray,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
    c_blk: int = 8,
    transpose_io: bool = False,
    gather: str = "auto",
) -> jnp.ndarray:
    """``y = M @ x`` from either fixed-shape scheduled layout;
    x (n, B) -> y (m, B).

    ``c_blk`` only applies to the padded layout (a ragged stream's block
    height is baked in at pack time).  ``transpose_io=True`` takes and
    returns batch-major arrays instead — x (B, n) -> y (B, m) — with both
    transposes inside this jit (XLA fuses them into the gather/scatter),
    so batch-major callers never materialize a transposed copy.

    ``gather`` selects the Buffer-Filler mode: ``"resident"`` (x whole in
    VMEM, one-hot over every column segment), ``"local"`` (stream only
    the ``S_blk`` x tiles each block references via the pack-time segment
    table — O(S_blk) gather work per slot instead of O(seg_count), no
    whole-x VMEM residency), or ``"auto"`` (the
    :func:`~repro.core.packing.resolve_gather` locality-ratio decision).
    Both modes are bit-identical.  The local path runs at the pack-time
    block height (``packed.c_blk`` — the granularity its tables were
    built for); a padded-layout ``c_blk`` override only applies to the
    resident path."""
    if gather not in ("resident", "local", "auto"):
        raise ValueError(
            f"gather must be 'resident', 'local' or 'auto', got {gather!r}"
        )
    m, n = packed.shape
    if transpose_io:
        if x.ndim != 2 or x.shape[1] != n:
            raise ValueError(
                f"expected batch-major x of shape (B, {n}) with "
                f"transpose_io=True, got {x.shape}"
            )
        x = x.T
    elif x.ndim != 2 or x.shape[0] != n:
        raise ValueError(f"expected x of shape ({n}, B), got {x.shape}")
    l, W = packed.l, packed.num_windows
    b = x.shape[1]
    ragged = isinstance(packed, RaggedSchedule)
    if gather == "auto":
        gather = resolve_gather(packed.s_blk, packed.seg_count)

    if use_kernel and packed.fusable:
        x2d = _prep_x(x, n, l)
        if ragged:
            if gather == "local":
                fn = make_gust_spmv_ragged_local(
                    packed.num_blocks, W, l, packed.s_blk, b,
                    c_blk=packed.c_blk, interpret=interpret,
                )
                y_win = fn(
                    packed.block_window, packed.block_starts,
                    _seg_flat(packed),
                    packed.m_blk, packed.col_loc, packed.row_blk, x2d,
                )
            else:
                fn = make_gust_spmv_ragged(
                    packed.num_blocks, W, l, packed.seg_count, b,
                    c_blk=packed.c_blk, interpret=interpret,
                )
                y_win = fn(
                    packed.block_window, packed.block_starts,
                    packed.m_blk, packed.col_blk, packed.row_blk, x2d,
                )
        elif gather == "local":
            fn = make_gust_spmv_local(
                W, packed.c_pad, l, packed.s_blk, b, c_blk=packed.c_blk,
                interpret=interpret,
            )
            y_win = fn(
                _seg_flat(packed),
                packed.m_blk, packed.col_loc, packed.row_blk, x2d,
            )
        else:
            fn = make_gust_spmv(
                W, packed.c_pad, l, packed.seg_count, b, c_blk=c_blk,
                interpret=interpret,
            )
            y_win = fn(packed.m_blk, packed.col_blk, packed.row_blk, x2d)
    else:
        seg_count = -(-n // l)
        xp = jnp.pad(x, ((0, seg_count * l - n), (0, 0)))
        if ragged:
            if gather == "local":
                y_win = gust_spmv_ragged_local_ref(
                    packed.m_blk,
                    packed.col_loc,
                    packed.row_blk,
                    packed.seg_blk,
                    packed.block_window,
                    xp,
                    num_windows=W,
                    l=l,
                    c_blk=packed.c_blk,
                )
            else:
                y_win = gust_spmv_ragged_ref(
                    packed.m_blk,
                    packed.col_blk,
                    packed.row_blk,
                    packed.block_window,
                    xp,
                    num_windows=W,
                    l=l,
                    c_blk=packed.c_blk,
                )
        elif gather == "local":
            y_win = gust_spmv_local_ref(
                packed.m_blk,
                packed.col_loc,
                packed.row_blk,
                packed.seg_blk,
                xp,
                num_windows=W,
                l=l,
                c_blk=packed.c_blk,
            )
        else:
            y_win = gust_spmv_ref(
                packed.m_blk,
                packed.col_blk,
                packed.row_blk,
                xp,
                num_windows=W,
                l=l,
            )
    y_sorted = y_win.reshape(W * l, b)
    if packed.identity_perm:
        # load_balance=False packs carry the identity permutation: the
        # scheduled row order IS the output order, so skip the scatter
        # (bit-identical: zeros.at[arange].set(y) == y)
        y = y_sorted[:m].astype(x.dtype)
    else:
        out = jnp.zeros((max(m, W * l), b), jnp.float32)
        out = out.at[packed.row_perm].set(y_sorted)
        y = out[:m].astype(x.dtype)
    return y.T if transpose_io else y


def gust_spmm(
    packed: Union[PackedSchedule, RaggedSchedule],
    x: jnp.ndarray,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
    c_blk: int = 8,
) -> jnp.ndarray:
    """Legacy packed-entry shim: ``y = M @ x``, x (n, B) -> y (m, B).

    Routes through :class:`~repro.core.plan.GustPlan` (the single
    execution path); prefer ``repro.plan(matrix, ...).spmm(x)``."""
    from repro.core.plan import GustPlan

    return GustPlan.from_artifact(
        packed,
        backend="pallas" if use_kernel else "jnp",
        interpret=interpret,
        c_blk=c_blk,
    ).spmm(x)


def gust_spmm_auto(
    sched: GustSchedule,
    x: jnp.ndarray,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
    c_blk: int = 8,
    waste_threshold: float = None,
    cache=default_cache,
) -> jnp.ndarray:
    """Deprecated schedule-level shim: auto-select ragged vs padded by the
    measured waste ratio, pack through the content-keyed cache, execute.

    Use ``repro.plan(schedule, PlanConfig(layout="auto", ...)).spmm(x)``
    instead — the plan owns the one layout/backend decision point."""
    warnings.warn(
        "gust_spmm_auto(sched, x, use_kernel=...) is deprecated; use "
        "repro.plan(sched, PlanConfig(layout='auto', backend='pallas'|'jnp'"
        ", c_blk=...)).spmm(x)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.core.plan import PlanConfig, plan

    p = plan(
        sched,
        PlanConfig(
            l=sched.l,
            layout="auto",
            backend="pallas" if use_kernel else "jnp",
            interpret=interpret,
            c_blk=c_blk,
            waste_threshold=waste_threshold,
        ),
        cache=cache,
    )
    return p.spmm(x)
