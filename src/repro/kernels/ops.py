"""Execution layer of the GUST scheduled format + legacy entry shims.

The packed scheduled format itself lives in :mod:`repro.core.packing`;
the plan/execute API lives in :mod:`repro.core.plan` (one decision point
for layout/backend/shard choice).  This module owns only the jitted
executor, :func:`execute_spmm`, which runs ``y = M @ x`` from **either**
fixed-shape layout — a padded :class:`PackedSchedule` (dense
``(W, C_pad/c_blk)`` grid) or a ragged :class:`RaggedSchedule` block
stream (1-D scalar-prefetch grid over real blocks only) — through the
Pallas kernels (``use_kernel=True``) or the pure-XLA segment-sum path
(identical math; the kernel oracle and the default off TPU).

``gust_spmm`` / ``gust_spmm_auto`` remain as thin compatibility shims
that construct a :class:`~repro.core.plan.GustPlan` and delegate — new
code should call ``repro.plan(...).spmm(x)`` directly.
"""

from __future__ import annotations

import functools
import warnings
from typing import Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.formats import GustSchedule
from repro.core.packing import (
    PackedSchedule,
    RaggedSchedule,
    default_cache,
    pack_schedule,
    packed_spec,
    resolve_gather,
)
from repro.resilience import faults

from .gust_spmv import (
    make_gust_spmv,
    make_gust_spmv_db,
    make_gust_spmv_local,
    make_gust_spmv_local_db,
)
from .gust_spmv_ragged import (
    make_gust_spmv_ragged,
    make_gust_spmv_ragged_db,
    make_gust_spmv_ragged_local,
    make_gust_spmv_ragged_local_db,
)
from .ref import (
    gust_spmv_local_ref,
    gust_spmv_ragged_local_ref,
    gust_spmv_ragged_ref,
    gust_spmv_ref,
)

__all__ = [
    "PackedSchedule",
    "RaggedSchedule",
    "pack_schedule",
    "execute_spmm",
    "gust_spmm",
    "gust_spmm_auto",
    "packed_spec",
    "normalize_choice",
]

#: Legal values of every string knob the executor (and PlanConfig)
#: accepts — the one place rejection messages are defined.
EXECUTE_CHOICES = {
    "gather": ("resident", "local", "auto"),
    "backend": ("pallas", "jnp"),
    "layout": ("padded", "ragged", "auto"),
    "pipeline": ("single", "double", "auto"),
}


def normalize_choice(name: str, value: str, allowed: Tuple[str, ...] = None):
    """Validate a string knob against its allowed values, raising the one
    normalized rejection message every caller shares::

        unknown <name> 'x'; expected one of: 'a', 'b'

    Returns the value unchanged so call sites can validate inline.  The
    old failure mode for a typo'd ``gather``/``backend``/``layout`` was a
    late, opaque kernel- or trace-time error; this fails fast at the API
    edge instead."""
    if allowed is None:
        allowed = EXECUTE_CHOICES[name]
    if value not in allowed:
        raise ValueError(
            f"unknown {name} {value!r}; expected one of: "
            + ", ".join(repr(a) for a in allowed)
        )
    return value


def _prep_x(x: jnp.ndarray, n: int, l: int) -> jnp.ndarray:
    """Zero-pad x to (S*l, B) and reshape to the straight segment-major
    VMEM layout (S, l, B).  The lane-reversed layout the fused gather
    selects against is derived in-kernel (``xs[:, ::-1, :]``), so only
    one copy of x crosses HBM->VMEM."""
    seg_count = -(-n // l)
    pad = seg_count * l - n
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    return xp.reshape(seg_count, l, -1)


def _seg_flat(packed) -> jnp.ndarray:
    """The pack-time segment table flattened to (T_blk * S_blk,) int32 —
    the scalar-prefetch operand steering the local kernels' x-tile
    pipeline."""
    return jnp.asarray(packed.seg_blk, jnp.int32).reshape(-1)


def _scale2d(packed) -> jnp.ndarray:
    """The per-block scale leaf as the (T_blk, 1) f32 column the
    quantized kernels take."""
    return jnp.asarray(packed.scale_blk, jnp.float32).reshape(-1, 1)


def execute_spmm(
    packed: Union[PackedSchedule, RaggedSchedule],
    x: jnp.ndarray,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
    c_blk: int = 8,
    transpose_io: bool = False,
    gather: str = "auto",
    pipeline: str = "auto",
    backend: str = None,
    layout: str = "auto",
) -> jnp.ndarray:
    """``y = M @ x`` — host-side dispatch wrapper around the jitted
    executor core.

    The wrapper exists so the resilience fault sites (``kernel.execute``
    tagged with the effective backend, and ``gather.local`` when the
    resolved Buffer-Filler mode is local — ROADMAP §Resilience
    invariants) fire on every *call*, not once per trace: a Python-level
    trip inside the jitted body would only ever fire at trace time.
    With no FaultPlan installed the extra cost is one module-global
    check; all math, validation, and dispatch live in the core (see its
    docstring for the knob semantics)."""
    if faults.enabled():
        eff_kernel = use_kernel if backend is None else backend == "pallas"
        faults.trip("kernel.execute", tag="pallas" if eff_kernel else "jnp")
        eff_gather = gather
        if eff_gather == "auto":
            eff_gather = resolve_gather(packed.s_blk, packed.seg_count)
        if eff_gather == "local":
            faults.trip("gather.local")
    return _execute_spmm_impl(
        packed,
        x,
        use_kernel=use_kernel,
        interpret=interpret,
        c_blk=c_blk,
        transpose_io=transpose_io,
        gather=gather,
        pipeline=pipeline,
        backend=backend,
        layout=layout,
    )


@functools.partial(
    jax.jit,
    static_argnames=("use_kernel", "interpret", "c_blk", "transpose_io",
                     "gather", "pipeline", "backend", "layout"),
)
def _execute_spmm_impl(
    packed: Union[PackedSchedule, RaggedSchedule],
    x: jnp.ndarray,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
    c_blk: int = 8,
    transpose_io: bool = False,
    gather: str = "auto",
    pipeline: str = "auto",
    backend: str = None,
    layout: str = "auto",
) -> jnp.ndarray:
    """``y = M @ x`` from either fixed-shape scheduled layout;
    x (n, B) -> y (m, B).

    ``c_blk`` only applies to the padded layout (a ragged stream's block
    height is baked in at pack time), and there only to the
    *unquantized resident* path — the local path runs at the pack-time
    block height its gather tables were built for, and a quantized
    stream's scales are per pack-time block; both raise ``ValueError``
    on a mismatched override instead of silently ignoring it.
    ``transpose_io=True`` takes and returns batch-major arrays instead —
    x (B, n) -> y (B, m) — with both transposes inside this jit (XLA
    fuses them into the gather/scatter), so batch-major callers never
    materialize a transposed copy.

    ``gather`` selects the Buffer-Filler mode: ``"resident"`` (x whole in
    VMEM, one-hot over every column segment), ``"local"`` (stream only
    the ``S_blk`` x tiles each block references via the pack-time segment
    table — O(S_blk) gather work per slot instead of O(seg_count), no
    whole-x VMEM residency), or ``"auto"`` (the
    :func:`~repro.core.packing.resolve_gather` locality-ratio decision).
    Both modes are bit-identical.

    ``pipeline`` selects the kernel fetch pipeline: ``"single"`` (one
    tile in flight, the reduction as extra grid dimensions) or
    ``"double"`` (two-slot ping/pong async copies overlapping the fetch
    of tile ``i+1`` with the math of tile ``i``, the reduction as an
    in-kernel loop).  ``"auto"`` means double on the kernel path.  The
    two are bit-identical; the jnp path ignores the knob.

    ``backend`` optionally overrides ``use_kernel`` with the plan-level
    spelling: ``"pallas"`` / ``"jnp"`` (``None`` keeps ``use_kernel``).
    ``layout`` is an assertion, not a choice — the layout is carried by
    the artifact's type; naming the wrong one raises instead of silently
    running the other stream.  Unknown ``gather``/``pipeline``/
    ``backend``/``layout`` strings raise the normalized
    :func:`normalize_choice` rejection."""
    normalize_choice("gather", gather)
    normalize_choice("pipeline", pipeline)
    normalize_choice("layout", layout)
    if backend is not None:
        normalize_choice("backend", backend)
        use_kernel = backend == "pallas"
    actual_layout = (
        "ragged" if isinstance(packed, RaggedSchedule) else "padded"
    )
    if layout not in ("auto", actual_layout):
        raise ValueError(
            f"layout={layout!r} requested but the packed artifact is "
            f"{actual_layout} (the layout is decided at pack time)"
        )
    m, n = packed.shape
    if transpose_io:
        if x.ndim != 2 or x.shape[1] != n:
            raise ValueError(
                f"expected batch-major x of shape (B, {n}) with "
                f"transpose_io=True, got {x.shape}"
            )
        x = x.T
    elif x.ndim != 2 or x.shape[0] != n:
        raise ValueError(f"expected x of shape ({n}, B), got {x.shape}")
    l, W = packed.l, packed.num_windows
    b = x.shape[1]
    ragged = isinstance(packed, RaggedSchedule)
    quant = packed.scale_blk is not None
    if gather == "auto":
        gather = resolve_gather(packed.s_blk, packed.seg_count)
    if not ragged and c_blk != packed.c_blk:
        if gather == "local":
            raise ValueError(
                f"c_blk={c_blk} override on the padded local path is not "
                f"executable: the pack-time gather tables were built at "
                f"c_blk={packed.c_blk} (re-pack at the desired block "
                f"height, or use gather='resident')"
            )
        if quant:
            raise ValueError(
                f"c_blk={c_blk} override on a quantized stream is not "
                f"executable: the per-block scales are aligned to the "
                f"pack-time c_blk={packed.c_blk} blocks (re-pack at the "
                f"desired block height)"
            )

    if use_kernel and packed.fusable:
        double = pipeline != "single"
        x2d = _prep_x(x, n, l)
        vdt, idt = str(packed.m_blk.dtype), str(packed.col_blk.dtype)
        scale_args = (_scale2d(packed),) if quant else ()
        if ragged:
            if gather == "local":
                if double:
                    fn = make_gust_spmv_ragged_local_db(
                        packed.num_blocks, W, l, packed.s_blk, b,
                        c_blk=packed.c_blk, interpret=interpret,
                        quantized=quant, x_dtype=str(x2d.dtype),
                    )
                else:
                    fn = make_gust_spmv_ragged_local(
                        packed.num_blocks, W, l, packed.s_blk, b,
                        c_blk=packed.c_blk, interpret=interpret,
                        quantized=quant,
                    )
                y_win = fn(
                    packed.block_window, packed.block_starts,
                    _seg_flat(packed),
                    packed.m_blk, packed.col_loc, packed.row_blk,
                    *scale_args, x2d,
                )
            elif double:
                fn = make_gust_spmv_ragged_db(
                    packed.num_blocks, W, l, packed.seg_count, b,
                    c_blk=packed.c_blk, interpret=interpret,
                    quantized=quant, value_dtype=vdt, index_dtype=idt,
                )
                y_win = fn(
                    packed.block_starts,
                    packed.m_blk, packed.col_blk, packed.row_blk,
                    *scale_args, x2d,
                )
            else:
                fn = make_gust_spmv_ragged(
                    packed.num_blocks, W, l, packed.seg_count, b,
                    c_blk=packed.c_blk, interpret=interpret, quantized=quant,
                )
                y_win = fn(
                    packed.block_window, packed.block_starts,
                    packed.m_blk, packed.col_blk, packed.row_blk,
                    *scale_args, x2d,
                )
        elif gather == "local":
            if double:
                fn = make_gust_spmv_local_db(
                    W, packed.c_pad, l, packed.s_blk, b,
                    c_blk=packed.c_blk, interpret=interpret,
                    quantized=quant, x_dtype=str(x2d.dtype),
                )
            else:
                fn = make_gust_spmv_local(
                    W, packed.c_pad, l, packed.s_blk, b,
                    c_blk=packed.c_blk, interpret=interpret, quantized=quant,
                )
            y_win = fn(
                _seg_flat(packed),
                packed.m_blk, packed.col_loc, packed.row_blk,
                *scale_args, x2d,
            )
        else:
            eff_c_blk = packed.c_blk if quant else c_blk
            if double:
                fn = make_gust_spmv_db(
                    W, packed.c_pad, l, packed.seg_count, b,
                    c_blk=eff_c_blk, interpret=interpret,
                    quantized=quant, value_dtype=vdt, index_dtype=idt,
                )
            else:
                fn = make_gust_spmv(
                    W, packed.c_pad, l, packed.seg_count, b, c_blk=eff_c_blk,
                    interpret=interpret, quantized=quant,
                )
            y_win = fn(
                packed.m_blk, packed.col_blk, packed.row_blk,
                *scale_args, x2d,
            )
    else:
        seg_count = -(-n // l)
        xp = jnp.pad(x, ((0, seg_count * l - n), (0, 0)))
        scale_kw = {"scale_blk": packed.scale_blk} if quant else {}
        if ragged:
            if gather == "local":
                y_win = gust_spmv_ragged_local_ref(
                    packed.m_blk,
                    packed.col_loc,
                    packed.row_blk,
                    packed.seg_blk,
                    packed.block_window,
                    xp,
                    num_windows=W,
                    l=l,
                    c_blk=packed.c_blk,
                    **scale_kw,
                )
            else:
                y_win = gust_spmv_ragged_ref(
                    packed.m_blk,
                    packed.col_blk,
                    packed.row_blk,
                    packed.block_window,
                    xp,
                    num_windows=W,
                    l=l,
                    c_blk=packed.c_blk,
                    **scale_kw,
                )
        elif gather == "local":
            y_win = gust_spmv_local_ref(
                packed.m_blk,
                packed.col_loc,
                packed.row_blk,
                packed.seg_blk,
                xp,
                num_windows=W,
                l=l,
                c_blk=packed.c_blk,
                **scale_kw,
            )
        else:
            y_win = gust_spmv_ref(
                packed.m_blk,
                packed.col_blk,
                packed.row_blk,
                xp,
                num_windows=W,
                l=l,
                c_blk=packed.c_blk,
                **scale_kw,
            )
    y_sorted = y_win.reshape(W * l, b)
    if packed.identity_perm:
        # load_balance=False packs carry the identity permutation: the
        # scheduled row order IS the output order, so skip the scatter
        # (bit-identical: zeros.at[arange].set(y) == y)
        y = y_sorted[:m].astype(x.dtype)
    else:
        out = jnp.zeros((max(m, W * l), b), jnp.float32)
        out = out.at[packed.row_perm].set(y_sorted)
        y = out[:m].astype(x.dtype)
    return y.T if transpose_io else y


def gust_spmm(
    packed: Union[PackedSchedule, RaggedSchedule],
    x: jnp.ndarray,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
    c_blk: int = 8,
) -> jnp.ndarray:
    """Legacy packed-entry shim: ``y = M @ x``, x (n, B) -> y (m, B).

    Routes through :class:`~repro.core.plan.GustPlan` (the single
    execution path); prefer ``repro.plan(matrix, ...).spmm(x)``."""
    from repro.core.plan import GustPlan

    return GustPlan.from_artifact(
        packed,
        backend="pallas" if use_kernel else "jnp",
        interpret=interpret,
        c_blk=c_blk,
    ).spmm(x)


def gust_spmm_auto(
    sched: GustSchedule,
    x: jnp.ndarray,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
    c_blk: int = 8,
    waste_threshold: float = None,
    cache=default_cache,
) -> jnp.ndarray:
    """Deprecated schedule-level shim: auto-select ragged vs padded by the
    measured waste ratio, pack through the content-keyed cache, execute.

    Use ``repro.plan(schedule, PlanConfig(layout="auto", ...)).spmm(x)``
    instead — the plan owns the one layout/backend decision point."""
    warnings.warn(
        "gust_spmm_auto(sched, x, use_kernel=...) is deprecated; use "
        "repro.plan(sched, PlanConfig(layout='auto', backend='pallas'|'jnp'"
        ", c_blk=...)).spmm(x)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.core.plan import PlanConfig, plan

    p = plan(
        sched,
        PlanConfig(
            l=sched.l,
            layout="auto",
            backend="pallas" if use_kernel else "jnp",
            interpret=interpret,
            c_blk=c_blk,
            waste_threshold=waste_threshold,
        ),
        cache=cache,
    )
    return p.spmm(x)
