"""jit'd public wrappers around the GUST Pallas kernels.

The packed scheduled format itself lives in :mod:`repro.core.packing` —
the single home of the ragged→packed conversion (vectorized packing,
repadding, the leaves/meta codec, and the content-keyed schedule cache).
``PackedSchedule`` / ``pack_schedule`` / ``packed_spec`` are re-exported
here for compatibility; this module only owns the *execution* entry
point.

``gust_spmm`` executes ``y = M @ x`` for ``x: (n, B)`` from **either**
fixed-shape layout — a padded :class:`PackedSchedule` (dense
``(W, C_pad/c_blk)`` grid) or a ragged :class:`RaggedSchedule` block
stream (1-D scalar-prefetch grid over real blocks only) — through the
Pallas kernels (``use_kernel=True``) or the pure-XLA segment-sum path
(identical math; the dry-run/serving default on non-TPU backends and the
kernel oracle).  The layout choice is made at pack time:
:func:`repro.core.packing.pack_auto` picks ragged when the measured
padding waste ``(W * C_pad) / (T_blk * c_blk)`` crosses its threshold,
and :func:`gust_spmm_auto` wires schedule → auto-pack → execute through
the content-keyed cache.
"""

from __future__ import annotations

import functools
from typing import Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.formats import GustSchedule
from repro.core.packing import (
    PackedSchedule,
    RaggedSchedule,
    default_cache,
    pack_auto,
    pack_schedule,
    packed_spec,
)

from .gust_spmv import make_gust_spmv
from .gust_spmv_ragged import make_gust_spmv_ragged
from .ref import gust_spmv_ragged_ref, gust_spmv_ref

__all__ = [
    "PackedSchedule",
    "RaggedSchedule",
    "pack_schedule",
    "gust_spmm",
    "gust_spmm_auto",
    "packed_spec",
]


def _prep_x(x: jnp.ndarray, n: int, l: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Zero-pad x to (S*l, B) and produce straight + lane-reversed VMEM
    layouts (S, l, B)."""
    seg_count = -(-n // l)
    pad = seg_count * l - n
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    x2d = xp.reshape(seg_count, l, -1)
    return x2d, x2d[:, ::-1, :]


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret", "c_blk"))
def gust_spmm(
    packed: Union[PackedSchedule, RaggedSchedule],
    x: jnp.ndarray,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
    c_blk: int = 8,
) -> jnp.ndarray:
    """``y = M @ x`` from either fixed-shape scheduled layout;
    x (n, B) -> y (m, B).

    ``c_blk`` only applies to the padded layout (a ragged stream's block
    height is baked in at pack time)."""
    m, n = packed.shape
    if x.ndim != 2 or x.shape[0] != n:
        raise ValueError(f"expected x of shape ({n}, B), got {x.shape}")
    l, W = packed.l, packed.num_windows
    b = x.shape[1]
    ragged = isinstance(packed, RaggedSchedule)

    if use_kernel and packed.fusable:
        x2d, x2f = _prep_x(x, n, l)
        if ragged:
            fn = make_gust_spmv_ragged(
                packed.num_blocks, W, l, packed.seg_count, b,
                c_blk=packed.c_blk, interpret=interpret,
            )
            y_win = fn(
                packed.block_window, packed.block_starts,
                packed.m_blk, packed.col_blk, packed.row_blk, x2d, x2f,
            )
        else:
            fn = make_gust_spmv(
                W, packed.c_pad, l, packed.seg_count, b, c_blk=c_blk,
                interpret=interpret,
            )
            y_win = fn(packed.m_blk, packed.col_blk, packed.row_blk, x2d, x2f)
    else:
        seg_count = -(-n // l)
        xp = jnp.pad(x, ((0, seg_count * l - n), (0, 0)))
        if ragged:
            y_win = gust_spmv_ragged_ref(
                packed.m_blk,
                packed.col_blk,
                packed.row_blk,
                packed.block_window,
                xp,
                num_windows=W,
                l=l,
                c_blk=packed.c_blk,
            )
        else:
            y_win = gust_spmv_ref(
                packed.m_blk,
                packed.col_blk,
                packed.row_blk,
                xp,
                num_windows=W,
                l=l,
            )
    y_sorted = y_win.reshape(W * l, b)
    out = jnp.zeros((max(m, W * l), b), jnp.float32)
    out = out.at[packed.row_perm].set(y_sorted)
    return out[:m].astype(x.dtype)


def gust_spmm_auto(
    sched: GustSchedule,
    x: jnp.ndarray,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
    c_blk: int = 8,
    waste_threshold: float = None,
    cache=default_cache,
) -> jnp.ndarray:
    """Schedule-level entry: auto-select ragged vs padded execution by the
    measured waste ratio ``(W * C_pad) / (T_blk * c_blk)``, pack through
    the content-keyed cache (pass ``cache=None`` to bypass), execute.

    Skewed matrices (max window colors >> mean) take the ragged streaming
    path; near-uniform ones keep the simpler padded grid.  The layout
    decision lives in one place — :func:`repro.core.packing.pack_auto` /
    :meth:`ScheduleCache.auto_for` (``waste_threshold=None`` means
    ``DEFAULT_WASTE_THRESHOLD``)."""
    if cache is None:
        packed = pack_auto(sched, c_blk, waste_threshold=waste_threshold)
    else:
        packed = cache.auto_for(
            sched, c_blk=c_blk, waste_threshold=waste_threshold
        )
    return gust_spmm(
        packed, x, use_kernel=use_kernel, interpret=interpret, c_blk=c_blk
    )
