"""Graph analytics over GUST plans: PageRank, triangles, GNN propagation.

Every sparse product here goes through the plan/execute API — the
workloads are deliberately *plan-amortized*: PageRank schedules the
transition matrix once and runs tens of ``spmv`` iterations against it
(the paper's §3.3 amortization story applied to an iterative solver);
triangle counting is one ``GustPlan.spgemm`` (A·A) masked by A's own
pattern; GNN feature propagation schedules the normalized adjacency once
and applies it per layer via ``spmm``.

The adjacency handling is the standard graph normalization zoo:

  * :func:`pagerank` — column-stochastic transition ``P = (D⁻¹ A)ᵀ``
    over the *binarized* pattern, power iteration with uniform
    teleport and dangling-node mass redistribution;
  * :func:`triangle_count` — undirected simple graph: binarize,
    symmetrize (pattern of ``A ∨ Aᵀ``), drop self-loops; triangles =
    ``Σ (A·A) ⊙ A / 6`` (each triangle counted once per ordered vertex
    pair on the closing edge);
  * :func:`feature_propagation` — GCN-style ``Â = D^{-1/2}(A+I)D^{-1/2}``
    applied ``num_layers`` times.

All three accept a dense array or :class:`~repro.core.formats.COOMatrix`
adjacency (any synthetic generator or surrogate from
:mod:`repro.data.matrices` works directly) plus an optional
:class:`~repro.core.plan.PlanConfig` forwarded to every ``plan()`` call.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import numpy as np

from repro.core.formats import COOMatrix, coo_from_dense, dense_from_coo
from repro.core.plan import PlanConfig, plan

__all__ = [
    "PageRankResult",
    "TriangleCountResult",
    "pagerank",
    "triangle_count",
    "feature_propagation",
]


def _as_adjacency(adj) -> COOMatrix:
    if isinstance(adj, COOMatrix):
        coo = adj
    else:
        dense = np.asarray(adj)
        if dense.ndim != 2:
            raise ValueError(f"adjacency must be 2-D, got shape {dense.shape}")
        coo = coo_from_dense(dense)
    if coo.shape[0] != coo.shape[1]:
        raise ValueError(f"adjacency must be square, got {coo.shape}")
    return coo


def _pattern(coo: COOMatrix, *, symmetrize: bool = False,
             drop_diagonal: bool = False) -> COOMatrix:
    """Binarized (0/1 f32) deduplicated pattern of ``coo``; optionally the
    symmetric closure ``A ∨ Aᵀ`` and/or with the diagonal removed."""
    n = coo.shape[0]
    key = coo.rows * np.int64(n) + coo.cols
    if symmetrize:
        key = np.concatenate([key, coo.cols * np.int64(n) + coo.rows])
    key = np.unique(key)
    rows, cols = key // n, key % n
    if drop_diagonal:
        keep = rows != cols
        rows, cols = rows[keep], cols[keep]
    return COOMatrix(
        coo.shape, rows.astype(np.int64), cols.astype(np.int64),
        np.ones(rows.shape[0], np.float32),
    )


@dataclasses.dataclass(frozen=True)
class PageRankResult:
    """Converged (or max-iter) PageRank scores and the iteration trace."""

    scores: np.ndarray  # (n,) f32, sums to 1
    iterations: int
    converged: bool
    residual: float  # final L1 step size

    def top(self, k: int = 10) -> np.ndarray:
        """Node ids of the ``k`` highest-ranked vertices."""
        return np.argsort(-self.scores)[:k]


def pagerank(
    adj,
    *,
    damping: float = 0.85,
    tol: float = 1e-6,
    max_iter: int = 200,
    config: Optional[PlanConfig] = None,
) -> PageRankResult:
    """Plan-amortized PageRank power iteration.

    The transition matrix ``P = (D⁻¹ A)ᵀ`` (column-stochastic, built on
    the binarized pattern via :meth:`COOMatrix.transpose`) is scheduled
    **once**; every iteration is one ``plan.spmv`` plus the scalar
    teleport/dangling correction:

        r ← d·(P r + dangling_mass/n) + (1-d)/n

    Dangling rows (out-degree 0) redistribute their mass uniformly, so
    ``r`` stays a probability vector and the iteration converges for any
    ``0 < damping < 1``.  The iterate is held in float64 host-side (the
    spmv itself runs f32); ``tol`` below ~1e-7·n hits the f32 execution
    noise floor and will report ``converged=False`` at ``max_iter``."""
    A = _pattern(_as_adjacency(adj))
    n = A.shape[0]
    if n == 0:
        return PageRankResult(np.zeros(0, np.float32), 0, True, 0.0)
    deg = A.row_nnz().astype(np.float64)
    dangling = deg == 0
    # P = (D^-1 A)^T: divide each edge by its source out-degree, transpose
    inv = np.zeros(n, np.float64)
    inv[~dangling] = 1.0 / deg[~dangling]
    norm = COOMatrix(A.shape, A.rows, A.cols,
                     (A.vals * inv[A.rows]).astype(np.float32))
    p = plan(norm.transpose(), config)

    r = np.full(n, 1.0 / n, np.float64)
    teleport = (1.0 - damping) / n
    converged, it, resid = False, 0, float("inf")
    for it in range(1, max_iter + 1):
        dangling_mass = float(r[dangling].sum()) / n
        step = np.asarray(p.spmv(r.astype(np.float32)), np.float64)
        r_new = damping * (step + dangling_mass) + teleport
        r_new /= r_new.sum()  # renormalize f32 drift
        resid = float(np.abs(r_new - r).sum())
        r = r_new
        if resid < tol:
            converged = True
            break
    return PageRankResult(r.astype(np.float32), it, converged, resid)


@dataclasses.dataclass(frozen=True)
class TriangleCountResult:
    """Triangle census of the undirected simple graph of ``adj``."""

    triangles: int
    per_node: np.ndarray  # (n,) int64 — triangles through each vertex
    spgemm_nnz: int  # nnz of the A·A product that was masked

    @property
    def clustering_coefficient(self) -> float:
        """Global (transitivity-style) clustering: 3·triangles / open
        wedges, 0.0 on wedge-free graphs."""
        deg = self._degrees
        wedges = float(np.sum(deg * (deg - 1) / 2))
        return 3.0 * self.triangles / wedges if wedges else 0.0

    # set post-init by triangle_count (dataclass-frozen workaround)
    _degrees: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64), repr=False
    )


def triangle_count(
    adj, *, config: Optional[PlanConfig] = None
) -> TriangleCountResult:
    """Count triangles via ``A·A`` masked by ``A`` — the canonical SpGEMM
    workload (one :meth:`GustPlan.spgemm` plus a host-side mask).

    ``adj`` is interpreted as an undirected simple graph: the pattern is
    binarized, symmetrized and stripped of self-loops first.  With A the
    resulting 0/1 symmetric adjacency, ``(A·A)[i, j]`` counts the common
    neighbors of ``i`` and ``j``; restricted to actual edges and summed
    it counts each triangle 6 times (3 edges × 2 directions)."""
    A = _pattern(_as_adjacency(adj), symmetrize=True, drop_diagonal=True)
    n = A.shape[0]
    if A.nnz == 0:
        return TriangleCountResult(
            0, np.zeros(n, np.int64), 0,
            _degrees=np.zeros(n, np.int64),
        )
    p = plan(A, config)
    AA = p.spgemm(A)
    # mask A·A by A's pattern on (row, col) keys
    edge_keys = A.rows * np.int64(n) + A.cols
    prod_keys = AA.rows * np.int64(n) + AA.cols
    on_edge = np.isin(prod_keys, edge_keys)
    masked_vals = AA.vals[on_edge]
    per_node = np.zeros(n, np.int64)
    np.add.at(per_node, AA.rows[on_edge],
              np.rint(masked_vals).astype(np.int64))
    per_node //= 2  # each triangle at vertex i closes 2 of i's edge slots
    total = int(per_node.sum()) // 3
    return TriangleCountResult(
        total, per_node, AA.nnz, _degrees=A.row_nnz(),
    )


def feature_propagation(
    adj,
    features: np.ndarray,
    *,
    num_layers: int = 2,
    add_self_loops: bool = True,
    config: Optional[PlanConfig] = None,
) -> np.ndarray:
    """GCN-style feature propagation: ``H ← Â H`` applied ``num_layers``
    times with ``Â = D^{-1/2}(A + I)D^{-1/2}`` (symmetric normalization
    over the binarized symmetric pattern; isolated vertices keep their
    features through the self-loop).  The normalized adjacency is
    scheduled once; each layer is one :meth:`GustPlan.spmm` over the
    ``(n, F)`` feature block — the SGC simplification (no weights, no
    nonlinearity), i.e. exactly the sparse work of a GNN stack."""
    A = _pattern(_as_adjacency(adj), symmetrize=True, drop_diagonal=True)
    n = A.shape[0]
    H = np.asarray(features, np.float32)
    if H.ndim != 2 or H.shape[0] != n:
        raise ValueError(
            f"features must be (n={n}, F), got {np.asarray(features).shape}"
        )
    if num_layers < 1:
        return H
    rows, cols, vals = A.rows, A.cols, A.vals
    if add_self_loops:
        diag = np.arange(n, dtype=np.int64)
        rows = np.concatenate([rows, diag])
        cols = np.concatenate([cols, diag])
        vals = np.concatenate([vals, np.ones(n, np.float32)])
    deg = np.bincount(rows, weights=vals, minlength=n)
    d_inv_sqrt = np.zeros(n, np.float64)
    nz = deg > 0
    d_inv_sqrt[nz] = 1.0 / np.sqrt(deg[nz])
    norm_vals = (vals * d_inv_sqrt[rows] * d_inv_sqrt[cols]).astype(np.float32)
    a_hat = COOMatrix((n, n), rows, cols, norm_vals)
    p = plan(a_hat, config)
    for _ in range(num_layers):
        H = np.asarray(p.spmm(H), np.float32)
    return H
