"""Graph-analytics workloads on GUST plans (PR 8).

The SpGEMM subsystem's consumer family: PageRank (plan-amortized SpMV
power iteration), triangle counting (``A·A`` masked by ``A``) and GNN
feature propagation (normalized-adjacency ``spmm``), each running every
sparse product through :class:`~repro.core.plan.GustPlan`.
"""

from .analytics import (
    PageRankResult,
    TriangleCountResult,
    feature_propagation,
    pagerank,
    triangle_count,
)

__all__ = [
    "PageRankResult",
    "TriangleCountResult",
    "pagerank",
    "triangle_count",
    "feature_propagation",
]
