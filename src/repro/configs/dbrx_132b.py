"""dbrx-132b [moe] — 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352.  Pure global
attention -> long_500k is SKIPPED (documented, DESIGN.md S5).
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=10752,
    vocab=100_352,
    pattern=("moe_global",),
    d_head=128,
    n_experts=16,
    top_k=4,
    rope_theta=500_000.0,
    source="hf:databricks/dbrx-base",
))
