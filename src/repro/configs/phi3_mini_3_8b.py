"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219].

32L d_model=3072 32H (GQA kv=32 = MHA) d_ff=8192 vocab=32064.  Pure full
attention -> long_500k SKIPPED (DESIGN.md S5).
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32_064,
    pattern=("global",),
    d_head=96,
    source="arXiv:2404.14219",
))
