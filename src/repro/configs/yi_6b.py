"""yi-6b [dense] — llama-arch GQA [arXiv:2403.04652; hf].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.  Pure full
attention -> long_500k SKIPPED (DESIGN.md S5).
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=4,
    d_ff=11008,
    vocab=64_000,
    pattern=("global",),
    d_head=128,
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652",
))
