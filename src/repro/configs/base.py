"""Config system: ArchConfig / ShapeConfig dataclasses + registry.

``ArchConfig`` fully determines a model: layer pattern, attention geometry,
MoE, frontend kind.  ``reduced()`` derives the family-preserving smoke
config (same block pattern, tiny widths) used by per-arch CPU tests.
``ShapeConfig`` is one of the four assigned input shapes.

Registration is import-driven: each ``configs/<arch>.py`` module defines
``CONFIG`` and calls :func:`register`; :func:`get_arch` imports on demand
so ``--arch <id>`` works from every launcher.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

__all__ = [
    "ArchConfig",
    "ShapeConfig",
    "SHAPES",
    "register",
    "get_arch",
    "list_archs",
    "ARCH_IDS",
]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # ssm | hybrid | moe | dense | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    # block pattern: tuple of block-type ids, tiled to n_layers
    #   global | local | chunked | moe_global | moe_chunked | rec | mlstm | slstm
    pattern: Tuple[str, ...] = ("global",)
    d_head: int = 0  # 0 -> d_model // n_heads
    local_window: int = 0  # sliding-window size for 'local' blocks
    chunk_size: int = 0  # chunk size for 'chunked' blocks
    global_cache_cap: int = 0  # decode-cache cap for global layers (long ctx)
    rope_theta: float = 10_000.0
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    mlp_kind: str = "swiglu"
    norm_kind: str = "rms"
    tie_embeddings: bool = True
    emb_scale: bool = False  # gemma-style sqrt(d) embedding scale
    frontend: str = "token"  # token | embed (vlm stub) | encdec (audio stub)
    n_enc_layers: int = 0  # encoder depth for encdec
    enc_seq: int = 0  # encoder (source) length for encdec shapes
    attn_block_size: int = 1024  # online-softmax KV block
    mlstm_expand: int = 2
    source: str = ""  # provenance note

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the embedding/logits shard over any
        mesh axis (seamless's 256206 would otherwise replicate a
        (B, S, V) f32 logits tensor on every chip).  Padding logits are
        masked to -inf in ``LM._logits``."""
        return -(-self.vocab // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.frontend == "encdec"

    @property
    def sub_quadratic(self) -> bool:
        """True if decode memory/compute per token is bounded (can serve
        long_500k): every block is recurrent, windowed, or cap-bounded."""
        for b in self.pattern:
            if b in ("global", "moe_global") and not self.global_cache_cap:
                return False
        return True

    def reduced(self) -> "ArchConfig":
        """Family-preserving smoke config: tiny dims, same pattern."""
        pat = self.pattern
        n_layers = max(len(pat), 2 if len(pat) == 1 else len(pat))
        n_kv = min(self.n_kv, 2)
        n_heads = max(min(self.n_heads, 4) // n_kv * n_kv, n_kv)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers + (1 if len(pat) > 1 else 0),  # force a tail
            d_model=64,
            n_heads=n_heads,
            n_kv=n_kv,
            d_head=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            local_window=min(self.local_window, 16) if self.local_window else 0,
            chunk_size=min(self.chunk_size, 16) if self.chunk_size else 0,
            global_cache_cap=min(self.global_cache_cap, 32)
            if self.global_cache_cap
            else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_enc_layers=min(self.n_enc_layers, 2) if self.n_enc_layers else 0,
            enc_seq=min(self.enc_seq, 16) if self.enc_seq else 0,
            attn_block_size=64,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


ARCH_IDS = (
    "xlstm_125m",
    "recurrentgemma_9b",
    "llama4_scout_17b_a16e",
    "dbrx_132b",
    "gemma3_4b",
    "phi3_mini_3_8b",
    "mistral_large_123b",
    "yi_6b",
    "llava_next_mistral_7b",
    "seamless_m4t_medium",
)

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[_canon(cfg.name)] = cfg
    return cfg


def _canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_arch(name: str) -> ArchConfig:
    key = _canon(name)
    if key not in _REGISTRY:
        importlib.import_module(f"repro.configs.{key}")
    return _REGISTRY[key]


def list_archs():
    return list(ARCH_IDS)
