"""gemma3-4b [dense] — 5:1 local:global, 128k context
[hf:google/gemma-3-1b-pt family; unverified].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.  Pattern: five
sliding-window-1024 layers then one global layer; 34 = 5x6 + 4 tail.
long_500k runs with global-layer decode cache bounded at 32768
(DESIGN.md S5).
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv=4,
    d_ff=10240,
    vocab=262_144,
    pattern=("local", "local", "local", "local", "local", "global"),
    d_head=256,
    local_window=1024,
    global_cache_cap=32_768,
    mlp_kind="geglu",
    emb_scale=True,
    rope_theta=1_000_000.0,
    source="hf:google/gemma-3-4b-pt",
))
