"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2
[arXiv:2402.19427; unverified].

38L d_model=4096 16H (GQA kv=1 = MQA) d_ff=12288 vocab=256000.  Griffin
pattern: two recurrent blocks then one local-attention block (window
2048).  38 = 12x(rec,rec,local) + 2 tail (rec,rec).  Bounded state ->
runs long_500k.
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv=1,
    d_ff=12288,
    vocab=256_000,
    pattern=("rec", "rec", "local"),
    d_head=256,
    local_window=2048,
    mlp_kind="geglu",
    emb_scale=True,
    source="arXiv:2402.19427",
))
