"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0: xLSTM blocks
carry their own projections (mLSTM pre-up-projection pf=2, sLSTM post
gated FFN pf=4/3), so there is no separate transformer MLP.  Fully
recurrent -> O(1) decode state: runs long_500k.
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv=4,
    d_ff=0,
    vocab=50304,
    pattern=("mlstm", "slstm"),
    source="arXiv:2405.04517",
))
