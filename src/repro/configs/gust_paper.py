"""The paper's own accelerator configurations (GUST length-8/-87/-256,
1D-256, Serpens) — re-exported from the hardware model for benchmarks."""

from repro.core.hardware_model import (
    GUST_8,
    GUST_87,
    GUST_256,
    SERPENS,
    SYSTOLIC_1D_256,
)

__all__ = ["GUST_8", "GUST_87", "GUST_256", "SERPENS", "SYSTOLIC_1D_256"]
