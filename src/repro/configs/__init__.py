"""Config registry: one module per assigned architecture + paper configs."""

from .base import ArchConfig, ShapeConfig, SHAPES, get_arch, list_archs, ARCH_IDS
