"""llama4-scout-17b-a16e [moe] — MoE 16e top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.  iRoPE-style
3:1 chunked-local : global attention (chunk 8192); every layer MoE with
16 experts top-1.  long_500k runs with the global layers' decode cache
bounded at 32768 (StreamingLLM-style ring; DESIGN.md S5).
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    d_ff=8192,
    vocab=202_048,
    pattern=("moe_chunked", "moe_chunked", "moe_chunked", "moe_global"),
    d_head=128,
    chunk_size=8192,
    global_cache_cap=32_768,
    n_experts=16,
    top_k=1,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
