"""mistral-large-123b [dense] [hf:mistralai/Mistral-Large-Instruct-2407].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.  The largest
dense arch in the pool; 2-D (FSDP x TP) parameter sharding is what makes
it fit (DESIGN.md S7).  Pure full attention -> long_500k SKIPPED.
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv=8,
    d_ff=28672,
    vocab=32_768,
    pattern=("global",),
    d_head=128,
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
))
