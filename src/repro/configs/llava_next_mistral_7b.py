"""llava-next-mistral-7b [vlm] — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.  Mistral-7B
backbone: sliding-window 4096 attention on every layer.  The vision
frontend (anyres patch tiler + projector) is a STUB: input_specs()
provides precomputed early-fusion embeddings (B, S, d) per the
assignment.  Bounded windows -> runs long_500k.
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=32_000,
    pattern=("local",),
    d_head=128,
    local_window=4096,
    frontend="embed",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
))
