"""seamless-m4t-medium [audio] — encoder-decoder, multimodal
[arXiv:2308.11596; hf].

12L d_model=1024 16H (GQA kv=16 = MHA) d_ff=4096 vocab=256206.  The
speech frontend (conformer feature extractor) is a STUB: input_specs()
provides precomputed frame embeddings (B, S_enc, d).  12 encoder + 12
decoder layers; decoder self-attention is causal-global with
cross-attention into the encoder memory.  long_500k SKIPPED: a 0.5M-frame
source (~4.5 h audio) is out of spec for the model family (DESIGN.md S5).
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=4096,
    vocab=256_206,
    pattern=("xattn",),
    d_head=64,
    mlp_kind="gelu",
    norm_kind="layer",
    frontend="encdec",
    n_enc_layers=12,
    enc_seq=4096,
    source="arXiv:2308.11596",
))
