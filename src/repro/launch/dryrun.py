import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the real step function (train_step /
prefill / decode_step), the real sharding rules, and ShapeDtypeStruct
inputs (no allocation), then proves the distribution config is coherent:

    jit(step, in_shardings=...).lower(**specs).compile()

Success per cell yields ``memory_analysis()`` (fits-per-chip proof),
``cost_analysis()``, and the loop-aware HLO analysis (launch/
hlo_analysis.py) feeding EXPERIMENTS.md §Dry-run / §Roofline.  Results
are cached as JSON under ``results/dryrun/`` (one file per cell) so
repeated invocations only compile what changed.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi_6b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --gust-decode  # GUST cell
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import numpy as np

# jax imported only after XLA_FLAGS is pinned (first two lines).
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCH_IDS, SHAPES, get_arch
from repro.distributed.sharding import (
    activation_ctx,
    cache_spec_overrides,
    dp_axes,
    param_specs,
)
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.models.model_zoo import build_model
from repro.training import TrainConfig, init_train_state, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


# ---------------------------------------------------------------------------
# Cell policies
# ---------------------------------------------------------------------------


def microbatches_for(n_params: int, shape, mesh) -> int:
    """Gradient-accumulation depth: targets per-chip microbatch rows of
    1 (>=15B), 2 (>=3B) or 4 (smaller).  Always >= 1 row per chip."""
    dp = 1
    for a in dp_axes(mesh):
        dp *= mesh.shape[a]
    rows = 1 if n_params > 15e9 else (2 if n_params > 3e9 else 4)
    mb = max(shape.global_batch // (dp * rows), 1)
    while shape.global_batch % (mb * dp) or (shape.global_batch // mb) % dp:
        mb -= 1
    return max(mb, 1)


def skip_reason(arch_id: str, shape_name: str) -> Optional[str]:
    cfg = get_arch(arch_id)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return "pure full attention: long_500k disqualified (DESIGN.md S5)"
    if shape_name == "long_500k" and cfg.is_encdec:
        return "enc-dec: 0.5M-frame source out of family spec (DESIGN.md S5)"
    return None


def _count_params(specs) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(specs)))


def _batch_sharding(mesh, specs: Dict) -> Dict:
    """Batch inputs: shard dim 0 over DP axes only when divisible (the
    long_500k cells run global_batch=1 — all parallelism is model-axis)."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def sh(v):
        lead = dp if v.shape and v.shape[0] % dp_size == 0 else None
        return NamedSharding(mesh, P(lead, *([None] * (len(v.shape) - 1))))

    return {k: sh(v) for k, v in specs.items()}


def _bf16_params(params_specs):
    def cast(x):
        dt = jnp.bfloat16 if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype
        return jax.ShapeDtypeStruct(x.shape, dt)

    return jax.tree.map(cast, params_specs)


# ---------------------------------------------------------------------------
# Cell construction: (step_fn, args_specs, in_shardings)
# ---------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str, mesh):
    cfg = get_arch(arch_id)
    lm = build_model(cfg)
    shape = SHAPES[shape_name]
    dp = dp_axes(mesh)
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        params_specs = jax.eval_shape(lambda: lm.init(key))
        n_params = _count_params(params_specs)
        tc = TrainConfig(
            microbatches=microbatches_for(n_params, shape, mesh),
            dtype="bfloat16",
            remat=True,
        )
        state_specs = jax.eval_shape(lambda: init_train_state(lm, key, tc))
        pspecs = param_specs(state_specs["params"], mesh, mode="train")
        state_sh = {
            "params": pspecs,
            "opt": {"m": pspecs, "v": pspecs, "step": NamedSharding(mesh, P())},
        }
        batch_specs = lm.input_specs(shape.seq_len, shape.global_batch, "train")
        bsh = _batch_sharding(mesh, batch_specs)
        step = make_train_step(lm, tc)
        return step, (state_specs, batch_specs), (state_sh, bsh), {
            "n_params": n_params,
            "microbatches": tc.microbatches,
            "tokens_per_step": shape.global_batch * shape.seq_len,
        }

    params_specs = _bf16_params(jax.eval_shape(lambda: lm.init(key)))
    n_params = _count_params(params_specs)
    pspecs = param_specs(params_specs, mesh, mode="serve")
    cache_specs = jax.eval_shape(
        lambda: lm.init_caches(shape.global_batch, shape.seq_len, jnp.bfloat16)
    )
    csh = jax.tree_util.tree_map_with_path(
        cache_spec_overrides(mesh, shape.global_batch), cache_specs
    )

    if shape.kind == "prefill":
        batch_specs = lm.input_specs(shape.seq_len, shape.global_batch, "prefill")
        bsh = _batch_sharding(mesh, batch_specs)

        def prefill_fn(params, batch, caches):
            return lm.prefill(params, batch, caches, dtype=jnp.bfloat16)

        return prefill_fn, (params_specs, batch_specs, cache_specs), (
            pspecs, bsh, csh,
        ), {"n_params": n_params, "tokens_per_step": shape.global_batch * shape.seq_len}

    # decode
    tok_specs = lm.input_specs(shape.seq_len, shape.global_batch, "decode")
    tok_sh = _batch_sharding(mesh, tok_specs)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())

    def decode_fn(params, caches, tokens, pos):
        return lm.decode_step(params, caches, tokens, pos, dtype=jnp.bfloat16)

    return decode_fn, (params_specs, cache_specs, tok_specs["tokens"], pos_spec), (
        pspecs, csh, tok_sh["tokens"], pos_sh,
    ), {"n_params": n_params, "tokens_per_step": shape.global_batch}


def build_gust_decode_cell(arch_id: str, mesh, density: float = 0.1,
                           gust_length: int = 256):
    """Beyond-assignment cell: the GUST-sparse decode path, schedule stream
    sized from the paper's Eq. 9 bound (``GustPlan.spec_for`` via
    serving/gust_serve.dryrun_specs).  REPRO_GUST_COMPACT/REPRO_GUST_RAGGED
    select the plan's dtype policy and layout (GustServeConfig.plan_config
    is the one spelling of those knobs)."""
    from repro.serving.gust_serve import GustServeConfig, decode_step_gust, dryrun_specs

    cfg = get_arch(arch_id)
    lm = build_model(cfg)
    shape = SHAPES["decode_32k"]
    dp = dp_axes(mesh)
    compact = os.environ.get("REPRO_GUST_COMPACT", "0") == "1"
    ragged = os.environ.get("REPRO_GUST_RAGGED", "0") == "1"
    gcfg = GustServeConfig(density=density, gust_length=gust_length,
                           use_kernel=False, compact=compact, ragged=ragged)
    pc = gcfg.plan_config
    gust_specs = dryrun_specs(lm, gcfg)
    params_specs = _bf16_params(jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0))))
    pspecs = param_specs(params_specs, mesh, mode="serve")
    cache_specs = jax.eval_shape(
        lambda: lm.init_caches(shape.global_batch, shape.seq_len, jnp.bfloat16)
    )
    csh = jax.tree_util.tree_map_with_path(
        cache_spec_overrides(mesh, shape.global_batch), cache_specs
    )
    # only the array leaves are jit arguments; the static meta (shapes,
    # lane geometry) stays a closure constant
    gust_leaves = {k: v["leaves"] for k, v in gust_specs["mats"].items()}
    gust_meta = {k: v["meta"] for k, v in gust_specs["mats"].items()}
    # schedule stream replicated across the mesh here; the distributed
    # row-window split (paper §5.5 parallel GUSTs) is exercised in
    # core.spmv.distributed_spmv tests
    gsh = jax.tree.map(lambda leaf: NamedSharding(mesh, P()), gust_leaves)
    tok_spec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)

    def step(params, gleaves, caches, tokens, pos):
        gust = {"mats": {k: {"leaves": gleaves[k], "meta": gust_meta[k]}
                         for k in gleaves}}
        return decode_step_gust(
            lm, params, gust, caches, tokens, pos, cfg=gcfg, dtype=jnp.bfloat16
        )

    return step, (params_specs, gust_leaves, cache_specs, tok_spec,
                  jax.ShapeDtypeStruct((), jnp.int32)), (
        pspecs, gsh, csh,
        _batch_sharding(mesh, {"tokens": tok_spec})["tokens"],
        NamedSharding(mesh, P()),
    ), {"n_params": _count_params(params_specs), "gust_density": density,
        "gust_layout": pc.layout, "gust_dtypes": (pc.value_dtype, pc.index_dtype),
        "gust_gather": pc.gather,
        # spec plans size the gather table at the worst case (no measured
        # locality); the per-mat S_blk lets the roofline read the x-tile
        # working set without running the scheduler.  Read through the
        # codec (not meta-tuple indices) so meta-layout changes can't
        # silently misreport it.
        "gust_s_blk": {
            k: _spec_artifact(v).s_blk for k, v in gust_specs["mats"].items()
        },
        "tokens_per_step": shape.global_batch}


def _spec_artifact(entry):
    """Rebuild one dryrun_specs mat entry through the leaves/meta codec
    (works on ShapeDtypeStruct leaves; only static attrs are read)."""
    from repro.core.packing import packed_from_leaves, ragged_from_leaves

    meta = tuple(entry["meta"])
    decode = ragged_from_leaves if meta and meta[0] == "ragged" else \
        packed_from_leaves
    return decode(entry["leaves"], meta)


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             gust: bool = False) -> Dict:
    mesh_name = "multi" if multi_pod else "single"
    t0 = time.time()
    rec: Dict = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "gust": gust, "ok": False,
    }
    reason = skip_reason(arch_id, shape_name)
    if reason:
        rec.update(skipped=True, reason=reason, ok=True)
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        if gust:
            step, specs, shardings, meta = build_gust_decode_cell(arch_id, mesh)
            donate = (2,)  # caches updated in place
        else:
            step, specs, shardings, meta = build_cell(arch_id, shape_name, mesh)
            # donate the mutable aggregate: train state / caches — the
            # in-place-update contract every serving/training runtime uses
            kind = SHAPES[shape_name].kind
            donate = {"train": (0,), "prefill": (2,), "decode": (1,)}[kind]
        rec.update(meta)
        # SP: training shards the residual-carry sequence dim over "model"
        # (16x smaller remat saves); serving keeps batch-only activations
        seq_sp = (
            (not gust) and SHAPES[shape_name].kind == "train"
            and os.environ.get("REPRO_SP", "0") == "1"
        )
        with activation_ctx(mesh, seq_sharded=seq_sp):
            lowered = jax.jit(
                step, in_shardings=shardings, donate_argnums=donate
            ).lower(*specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes": int(
                ma.argument_size_in_bytes + ma.temp_size_in_bytes
            ),
        }
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # one dict per device on jax<=0.4.x
            ca = ca[0] if ca else {}
        rec["xla_cost"] = {
            "flops": float(ca.get("flops", -1.0)),
            "bytes": float(ca.get("bytes accessed", -1.0)),
        }
        st = analyze_hlo(compiled.as_text())
        rec["hlo"] = st.to_dict()
        rec["roofline"] = roofline_terms(st)
        rec["timing"] = {"lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2)}
        rec["ok"] = True
    except Exception as e:  # record the failure, don't crash the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=10)
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def cell_path(arch_id: str, shape_name: str, mesh_name: str, gust=False) -> str:
    tag = f"{arch_id}__{shape_name}__{mesh_name}" + ("__gust" if gust else "")
    return os.path.join(RESULTS_DIR, tag + ".json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--gust-decode", action="store_true",
                    help="run the GUST-sparse decode dry-run cell")
    ap.add_argument("--force", action="store_true", help="ignore cache")
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]

    n_fail = 0
    for mesh_name in meshes:
        for arch in archs:
            if args.gust_decode:
                path = cell_path(arch, "decode_32k", mesh_name, gust=True)
                if os.path.exists(path) and not args.force:
                    continue
                rec = run_cell(arch, "decode_32k", mesh_name == "multi", gust=True)
                json.dump(rec, open(path, "w"), indent=1)
                status = "OK" if rec["ok"] else "FAIL"
                print(f"[{status}] {arch} gust-decode {mesh_name} ({rec['wall_s']}s)")
                n_fail += 0 if rec["ok"] else 1
                continue
            for shape in shapes:
                path = cell_path(arch, shape, mesh_name)
                if os.path.exists(path) and not args.force:
                    prev = json.load(open(path))
                    if prev.get("ok"):
                        continue
                rec = run_cell(arch, shape, mesh_name == "multi")
                json.dump(rec, open(path, "w"), indent=1)
                if rec.get("skipped"):
                    print(f"[SKIP] {arch} {shape} {mesh_name}: {rec['reason']}")
                    continue
                status = "OK" if rec["ok"] else "FAIL"
                extra = ""
                if rec["ok"]:
                    peak = rec["memory"]["peak_bytes"] / 2**30
                    dom = rec["roofline"]["dominant"]
                    extra = f" peak={peak:.1f}GiB dom={dom}"
                else:
                    extra = " " + rec["error"][:120]
                print(f"[{status}] {arch} {shape} {mesh_name} ({rec['wall_s']}s){extra}")
                n_fail += 0 if rec["ok"] else 1
    print("dry-run failures:", n_fail)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
