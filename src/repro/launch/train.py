"""End-to-end training driver (real run on the local device set).

Wires every substrate layer together: config registry -> model ->
sharded train step -> deterministic pipeline -> checkpoint policy ->
fault-tolerance wrappers.  On this container it runs reduced configs on
one CPU device; on a fleet the same driver runs the full configs on the
production mesh (launch/mesh.py) — nothing here is CPU-specific.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch yi_6b --reduced \
        --steps 50 --ckpt-dir /tmp/ckpt [--resume]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, get_arch
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models.model_zoo import build_model
from repro.training import (
    AdamWConfig,
    TrainConfig,
    init_train_state,
    latest_step,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.training.fault_tolerance import (
    CheckpointPolicy,
    StragglerMonitor,
    install_preemption_handler,
    retrying,
)

__all__ = ["run_training"]


def _make_batch_fn(lm, cfg, seq_len: int, batch: int, seed: int):
    """Batch source per frontend kind (token / embed / encdec stubs)."""
    pipe = TokenPipeline(
        PipelineConfig(vocab_size=cfg.vocab, seq_len=seq_len, global_batch=batch,
                       seed=seed)
    )
    rng = np.random.default_rng(seed + 1)

    def next_batch(step: int):
        b = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        if cfg.frontend == "embed":
            # early-fusion stub: embeddings derived deterministically
            emb = rng.standard_normal((batch, seq_len, cfg.d_model)).astype(np.float32)
            b = {"embeds": jnp.asarray(emb), "labels": b["labels"],
                 "loss_mask": b["loss_mask"]}
        elif cfg.is_encdec:
            enc_s = min(seq_len, cfg.enc_seq or seq_len)
            src = rng.standard_normal((batch, enc_s, cfg.d_model)).astype(np.float32)
            b["src_frames"] = jnp.asarray(src)
        return b

    return next_batch


def run_training(
    arch: str,
    *,
    reduced: bool = True,
    steps: int = 50,
    seq_len: int = 64,
    global_batch: int = 8,
    lr: float = 1e-3,
    microbatches: int = 1,
    ckpt_dir: str = "",
    ckpt_every: int = 20,
    resume: bool = False,
    seed: int = 0,
    dtype: str = "float32",
    log_every: int = 10,
):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    lm = build_model(cfg)
    tc = TrainConfig(
        opt=AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1), total_steps=steps),
        microbatches=microbatches,
        dtype=dtype,
    )
    state = init_train_state(lm, jax.random.PRNGKey(seed), tc)
    start_step = 0
    if resume and ckpt_dir:
        last = latest_step(ckpt_dir)
        if last is not None:
            like = jax.eval_shape(lambda: state)
            state, extra = restore_checkpoint(ckpt_dir, last, like)
            start_step = last
            print(f"resumed from step {last}")

    step_fn = retrying(jax.jit(make_train_step(lm, tc)), max_retries=2)
    next_batch = _make_batch_fn(lm, cfg, seq_len, global_batch, seed)
    policy = CheckpointPolicy(every_steps=ckpt_every)
    monitor = StragglerMonitor()
    flag = install_preemption_handler({"preempted": False})

    history = []
    for step in range(start_step, steps):
        monitor.start()
        batch = next_batch(step)
        state, metrics = step_fn(state, batch)
        dt, straggler = monitor.stop()
        loss = float(metrics["loss"])
        history.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(
                f"step {step:5d} loss {loss:8.4f} gnorm "
                f"{float(metrics['grad_norm']):7.3f} {dt*1e3:7.1f} ms"
                + (" [straggler]" if straggler else "")
            )
        if ckpt_dir and (policy.should_save(step + 1) or flag["preempted"]):
            save_checkpoint(ckpt_dir, step + 1, state)
            policy.gc(ckpt_dir)
            if flag["preempted"]:
                print("preempted: checkpointed and exiting")
                return state, history
    if ckpt_dir:
        save_checkpoint(ckpt_dir, steps, state)
    return state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()
    _, history = run_training(
        args.arch, reduced=args.reduced, steps=args.steps, seq_len=args.seq_len,
        global_batch=args.batch, lr=args.lr, microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, resume=args.resume,
        dtype=args.dtype,
    )
    print(json.dumps({"first_loss": history[0], "last_loss": history[-1]}))


if __name__ == "__main__":
    main()
