"""Loop-aware analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each computation body **once**,
which under-reports scan-heavy modules by orders of magnitude (a 32-layer
scan × 8-microbatch scan = 256× error).  This parser rebuilds the call
graph — ``while`` bodies/conditions weighted by their trip count, fusion
and ``to_apply`` sites by 1 — and aggregates per-device:

  * ``dot_flops``        — 2·|result|·|contraction| per dot, the MXU term;
  * ``hbm_bytes``        — Σ (operands + result) bytes over top-level ops
    (fusion internals excluded: a fused region reads its operands and
    writes its result once — exactly the HBM-traffic model we want);
  * ``collective_bytes`` — per collective kind, *wire* bytes per device
    using ring equivalents: all-reduce 2·(k-1)/k·n, all-gather /
    reduce-scatter / all-to-all (k-1)/k·n, collective-permute n, with k
    the replica-group size parsed from the op.

Trip counts come from the largest scalar integer constant in the while
condition computation — exact for lax.scan/fori_loop lowerings, which is
everything this framework emits.

All quantities are per-device (the SPMD module is per-device); roofline
terms divide by per-chip peaks directly.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = ["HloStats", "analyze_hlo", "roofline_terms", "HW"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_FREE_OPS = {
    "bitcast", "get-tuple-element", "tuple", "parameter", "constant",
    "after-all", "partition-id", "replica-id", "iota",
}


def _type_bytes(t: str) -> int:
    """Bytes of an HLO type string (tuples summed)."""
    total = 0
    for m in _SHAPE_RE.finditer(t):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(t: str) -> Tuple[List[int], str]:
    m = _SHAPE_RE.search(t)
    if not m:
        return [], ""
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dims, m.group(1)


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    kind: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    # edges: (callee, kind) where kind in {"while_body", "while_cond", "call"}
    edges: List[Tuple[str, str]]
    trip_hint: int = 1  # if this is a while condition: parsed trip count


@dataclasses.dataclass
class HloStats:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    copy_convert_bytes: float = 0.0  # CPU-backend layout/copy artifacts
    dot_bytes: float = 0.0  # operands+results of dot ops only (lower bound)
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    collective_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int)
    )
    notes: List[str] = dataclasses.field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    @property
    def hbm_bytes_fused(self) -> float:
        """HBM-traffic estimate excluding copy/convert ops (layout and
        dtype moves the TPU backend fuses away; the CPU backend leaves
        them as standalone ops and would double-count real traffic)."""
        return self.hbm_bytes - self.copy_convert_bytes

    def to_dict(self) -> Dict:
        return {
            "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
            "copy_convert_bytes": self.copy_convert_bytes,
            "hbm_bytes_fused": self.hbm_bytes_fused,
            "dot_bytes": self.dot_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_count": dict(self.collective_count),
            "total_collective_bytes": self.total_collective_bytes,
            "notes": self.notes,
        }


def _parse_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and "{" in line:
                cur = _Computation(m.group(1), [], [])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if dm:
            name, tstr, kind = dm.group(1), dm.group(2), dm.group(3)
            cur.ops.append(_Op(name, tstr, kind, line))
            if kind == "while":
                body = re.search(r"body=%([\w.\-]+)", line)
                cond = re.search(r"condition=%([\w.\-]+)", line)
                if body:
                    cur.edges.append((body.group(1), "while_body"))
                if cond:
                    cur.edges.append((cond.group(1), "while_cond"))
                # trip count hint: attached to the while op's condition comp
            else:
                for cm in _CALL_ATTR_RE.finditer(line):
                    if "body=" in line or "condition=" in line:
                        pass
                    cur.edges.append((cm.group(1), "call"))
    return comps


def _trip_count(comp: _Computation) -> int:
    """Largest scalar int constant in a while-condition computation — the
    loop bound for counted loops (lax.scan / fori_loop lowerings)."""
    best = 1
    for op in comp.ops:
        for m in _CONST_RE.finditer(op.line):
            best = max(best, int(m.group(1)))
        # compare against constants inside called fusions is handled by the
        # caller passing the fused computation in comps traversal.
    return best


def _operand_names(line: str, kind: str) -> List[str]:
    """Operand %names of an op line (skipping the result-type tuple)."""
    try:
        after = line.split(kind + "(", 1)[1]
    except IndexError:
        return []
    return re.findall(r"%([\w.\-]+)", after.split(")", 1)[0])


def analyze_hlo(text: str) -> HloStats:
    comps = _parse_computations(text)
    stats = HloStats()

    # ---- execution multipliers -------------------------------------------
    entry = None
    for name in comps:
        # the entry computation is referenced by nobody
        entry = name if entry is None else entry
    referenced = {c for comp in comps.values() for c, _ in comp.edges}
    entries = [n for n in comps if n not in referenced]
    mult: Dict[str, float] = defaultdict(float)
    for e in entries:
        mult[e] += 1.0

    # condition-comp trip counts (may live in fusions called by the cond)
    trip_of_cond: Dict[str, int] = {}
    for name, comp in comps.items():
        t = _trip_count(comp)
        for callee, kind in comp.edges:
            if kind == "call" and callee in comps:
                t = max(t, _trip_count(comps[callee]))
        trip_of_cond[name] = t

    # propagate in dependency order (iterate until fixpoint; graphs are DAGs)
    for _ in range(len(comps) + 2):
        changed = False
        new_mult = defaultdict(float)
        for e in entries:
            new_mult[e] += 1.0
        for name, comp in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for callee, kind in comp.edges:
                if callee not in comps:
                    continue
                if kind == "while_body":
                    # trip count comes from the while op's paired condition
                    trip = 1
                    for op in comp.ops:
                        if op.kind == "while" and f"body=%{callee}" in op.line:
                            cm = re.search(r"condition=%([\w.\-]+)", op.line)
                            if cm:
                                trip = trip_of_cond.get(cm.group(1), 1)
                    new_mult[callee] += m * trip
                elif kind == "while_cond":
                    trip = trip_of_cond.get(callee, 1)
                    new_mult[callee] += m * trip
                else:
                    new_mult[callee] += m
        if dict(new_mult) != dict(mult):
            mult = new_mult
            changed = True
        if not changed:
            break

    # symbol table for operand shape resolution, per computation
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        symbols = {op.name: op.type_str for op in comp.ops}
        for op in comp.ops:
            if op.kind == "dot":
                res_dims, _ = _shape_dims(op.type_str)
                ops_n = _operand_names(op.line, op.kind)
                k = 1
                lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
                if lc and ops_n:
                    lhs_t = symbols.get(ops_n[0], "")
                    lhs_dims, _ = _shape_dims(lhs_t)
                    for d in lc.group(1).split(","):
                        if d and int(d) < len(lhs_dims):
                            k *= lhs_dims[int(d)]
                n = 1
                for d in res_dims:
                    n *= d
                stats.dot_flops += m * 2.0 * n * k
            if op.kind in _COLLECTIVES:
                nbytes = 0
                for on in _operand_names(op.line, op.kind):
                    nbytes += _type_bytes(symbols.get(on, ""))
                if nbytes == 0:
                    nbytes = _type_bytes(op.type_str)
                gm = _GROUPS_RE.search(op.line)
                gsize = int(gm.group(2)) if gm else 2
                frac = (gsize - 1) / max(gsize, 1)
                if op.kind == "all-reduce":
                    wire = 2.0 * nbytes * frac
                elif op.kind == "all-gather":
                    wire = _type_bytes(op.type_str) * frac
                elif op.kind == "collective-permute":
                    wire = float(nbytes)
                else:  # reduce-scatter, all-to-all
                    wire = nbytes * frac
                stats.collective_bytes[op.kind] += m * wire
                stats.collective_count[op.kind] += int(m)

        # HBM bytes: only computations that are NOT fusion bodies get
        # per-op traffic (fusion bodies execute inside their caller's op).
        if _is_fusion_body(name, comps):
            continue
        for op in comp.ops:
            if op.kind in _FREE_OPS or op.kind == "while":
                continue
            nbytes = _type_bytes(op.type_str)
            for on in _operand_names(op.line, op.kind):
                nbytes += _type_bytes(symbols.get(on, ""))
            stats.hbm_bytes += m * nbytes
            if op.kind in ("copy", "convert", "transpose", "reshape"):
                stats.copy_convert_bytes += m * nbytes
            if op.kind == "dot":
                stats.dot_bytes += m * nbytes
    return stats


def _is_fusion_body(name: str, comps) -> bool:
    """A computation is a fusion body if some fusion/wrapped op calls it
    via calls=/to_apply= (as opposed to while body/condition)."""
    for comp in comps.values():
        for op in comp.ops:
            if op.kind in ("fusion",) or "calls=" in op.line or "to_apply=" in op.line:
                for cm in _CALL_ATTR_RE.finditer(op.line):
                    if cm.group(1) == name and (
                        "calls=%" + name in op.line or "to_apply=%" + name in op.line
                    ):
                        return True
    return False


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e)
# ---------------------------------------------------------------------------

HW = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link
}


def roofline_terms(stats: HloStats) -> Dict[str, float]:
    """Per-device seconds for each roofline term (module is per-device).

    The memory term is bracketed: ``memory_s`` counts every top-level op's
    operands+result (upper bound — the CPU backend fuses far less than TPU,
    leaving elementwise chains as separate HBM-visible ops), while
    ``memory_s_dots`` counts only matmul traffic (lower bound — what the
    MXU must stream no matter what).  Dominance uses the geometric mean of
    the bracket."""
    compute_s = stats.dot_flops / HW["peak_flops_bf16"]
    memory_up = stats.hbm_bytes_fused / HW["hbm_bw"]
    memory_lo = stats.dot_bytes / HW["hbm_bw"]
    memory_s = (max(memory_lo, 1e-12) * max(memory_up, 1e-12)) ** 0.5
    collective_s = stats.total_collective_bytes / HW["ici_bw"]
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_s_upper": memory_up,
        "memory_s_dots": memory_lo,
        "collective_s": collective_s,
        "dominant": dominant,
    }
