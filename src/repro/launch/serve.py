"""Serving driver: a mixed-length request stream against a (reduced or
full) model, dense or GUST-sparse decode, with continuous batching.

Requests are enqueued up front (bounded admission queue) and the loop
admits into free slots while other requests are mid-decode: per-slot
prefill + per-slot positions make every request's output identical to a
solo run, so batching is purely a throughput knob (reported as
``tok_per_s`` / ``slot_occupancy``; ``--serial`` forces the old
one-request-at-a-time pattern for comparison).

The GUST path plans every MLP matrix once at engine build
(``serving.gust_serve.gustify`` -> ``repro.plan``) and executes each
decode step through the stacked :class:`~repro.core.plan.GustPlan`
leaves; ``--ragged``/``--compact``/``--use-kernel`` map onto the plan's
layout/dtype/backend knobs.  GUST decode shares the continuous-batching
machinery with the dense path.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch yi_6b --reduced \
        --requests 6 --max-new 16 [--gust --density 0.2 --ragged --compact]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models.model_zoo import build_model
from repro.serving import GustServeConfig, ServeConfig, ServeLoop

__all__ = ["run_serving"]


def run_serving(
    arch: str,
    *,
    reduced: bool = True,
    batch: int = 4,
    seq_len: int = 128,
    requests: int = 4,
    prompt_len: int = 8,
    max_new: int = 8,
    gust: bool = False,
    density: float = 0.25,
    gust_length: int = 32,
    use_kernel: bool = False,
    ragged: bool = False,
    compact: bool = False,
    plan_store: str = None,
    serial: bool = False,
    temperature: float = 0.0,
    eos_id=None,
    seed: int = 0,
    deadline_steps: int = None,
    deadline_s: float = None,
):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(seed))
    gcfg = None
    if gust:
        gcfg = GustServeConfig(
            density=density, gust_length=gust_length, use_kernel=use_kernel,
            ragged=ragged, compact=compact, plan_store=plan_store,
        )
    sc = ServeConfig(batch=batch, seq_len=seq_len, dtype="float32", gust=gcfg,
                     temperature=temperature, eos_id=eos_id,
                     queue_capacity=max(requests, 64),
                     max_steps_per_request=deadline_steps,
                     max_seconds_per_request=deadline_s)
    loop = ServeLoop(lm, params, sc, seed=seed)
    rng = np.random.default_rng(seed)
    # mixed-length trace: prompt lengths cycle between prompt_len//2 and
    # prompt_len — exactly the workload per-slot positions exist for
    lengths = [max(1, prompt_len // 2), prompt_len, max(1, 3 * prompt_len // 4)]
    prompts = [
        rng.integers(0, cfg.vocab, lengths[r % len(lengths)]).astype(np.int32)
        for r in range(requests)
    ]
    t0 = time.time()
    done = {}
    if serial:  # one-request-at-a-time baseline
        for prompt in prompts:
            rid = loop.submit(prompt, max_new=max_new)
            loop.run_to_completion()
            done[rid] = loop.completed[rid]
    else:  # continuous batching: enqueue the stream, drain the queue
        rids = [loop.enqueue(prompt, max_new=max_new) for prompt in prompts]
        loop.run_to_completion()
        # non-DONE requests (TIMEOUT under a deadline, SHED past
        # capacity) carry their terminal result instead of completed[]
        done = {
            rid: loop.completed.get(
                rid, loop.results[rid].tokens if rid in loop.results else []
            )
            for rid in rids
        }
    dt = time.time() - t0
    toks = sum(len(v) for v in done.values())
    stats = {
        "requests": len(done),
        "tokens_generated": toks,
        "wall_s": round(dt, 2),
        "tok_per_s": round(toks / dt, 1),
        "decode_steps": loop.stats["decode_steps"],
        "slot_occupancy": round(loop.occupancy, 4),
        "mode": "serial" if serial else "continuous",
        "gust": bool(gust),
        # lifecycle + degradation counters (PR 10): terminal statuses
        # and the process-wide fallback counters
        "resilience": loop.resilience_stats(),
    }
    if gust and loop.gust_tree is not None:
        # per-matrix entries only — "plan_store" is the store's counter dict
        mat_stats = {
            k: v for k, v in loop.gust_tree["stats"].items()
            if k != "plan_store"
        }
        stats["gust_stream_utilization"] = {
            k: round(v["stream_utilization"], 4) for k, v in mat_stats.items()
        }
        stats["gust_streamed_slots"] = {
            k: v["streamed_slots"] for k, v in mat_stats.items()
        }
        if "plan_store" in loop.gust_tree["stats"]:
            stats["gust_plan_store"] = loop.gust_tree["stats"]["plan_store"]
    return done, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--gust", action="store_true")
    ap.add_argument("--density", type=float, default=0.25)
    ap.add_argument("--gust-length", type=int, default=32)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--ragged", action="store_true",
                    help="stack ragged color-block streams (only real "
                    "cycle blocks) instead of the padded C_pad layout")
    ap.add_argument("--compact", action="store_true",
                    help="bf16 values + int16 indices: halves the streamed "
                    "schedule bytes (the paper's packed-word analogue)")
    ap.add_argument("--plan-store", type=str, default=None,
                    help="directory for the persistent PlanStore: warm "
                    "starts load packed plans off disk with zero coloring")
    ap.add_argument("--serial", action="store_true",
                    help="one-request-at-a-time baseline (default is "
                    "continuous batching over the admission queue)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="retire a request when it samples this token")
    ap.add_argument("--deadline-steps", type=int, default=None,
                    help="per-request decode-step budget; expiry retires "
                    "the request with status=TIMEOUT (tokens kept)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock budget in seconds")
    args = ap.parse_args()
    _, stats = run_serving(
        args.arch, batch=args.batch, seq_len=args.seq_len,
        requests=args.requests, prompt_len=args.prompt_len,
        max_new=args.max_new, gust=args.gust, density=args.density,
        gust_length=args.gust_length, use_kernel=args.use_kernel,
        ragged=args.ragged, compact=args.compact,
        plan_store=args.plan_store, serial=args.serial,
        temperature=args.temperature, eos_id=args.eos_id,
        deadline_steps=args.deadline_steps, deadline_s=args.deadline_s,
    )
    print(json.dumps(stats))


if __name__ == "__main__":
    main()
