"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the "pod" axis
is pure data parallelism across the cross-pod links (where gradient
compression and the ring schedules in distributed/collectives.py apply).

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state; callers opt in.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["make_production_mesh", "mesh_shape", "require_devices"]


def mesh_shape(multi_pod: bool = False) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    if multi_pod:
        return (2, 16, 16), ("pod", "data", "model")
    return (16, 16), ("data", "model")


def require_devices(n: int):
    import jax

    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, found {len(devs)} — the dry-run entrypoint "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (launch/dryrun.py does this)"
        )
    return devs[:n]


def make_production_mesh(*, multi_pod: bool = False):
    """The target mesh: (16, 16) single-pod or (2, 16, 16) multi-pod."""
    import jax

    shape, axes = mesh_shape(multi_pod)
    n = int(np.prod(shape))
    devs = require_devices(n)
    try:
        return jax.make_mesh(shape, axes, devices=devs)
    except TypeError:  # older jax.make_mesh without devices kwarg
        return jax.sharding.Mesh(np.array(devs).reshape(shape), axes)
