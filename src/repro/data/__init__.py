from .matrices import synth_uniform, synth_power_law, synth_k_regular, REAL_WORLD_SUITE
from .pipeline import TokenPipeline, PipelineConfig
