"""Deterministic synthetic token pipeline.

Production data pipelines (SSTable/ArrayRecord readers, shuffle buffers,
tokenizers) are host-side; what the training framework needs from them is a
deterministic, restartable, per-host-sharded stream of fixed-shape batches.
This module provides exactly that contract with a synthetic source so every
layer above it (train loop, checkpoint/resume, multi-host sharding) is
exercised for real:

  * **Determinism / restartability** — batch ``i`` is a pure function of
    ``(seed, i)``; resuming from a checkpointed ``step`` reproduces the
    exact stream (the same property a seeded shuffle-buffer pipeline gives
    you, without needing the data on disk).
  * **Per-host sharding** — each host draws only its ``1/num_hosts`` slice
    of the global batch, indexed by ``host_id``; a global batch is the
    concatenation over hosts, so data parallelism sees disjoint data.
  * **Prefetch** — a small lookahead queue mirrors double-buffered host
    pipelines; on CPU it is a correctness no-op but keeps the driver-side
    API identical to production.

Token statistics follow a Zipf distribution over the vocabulary (matching
natural-language frequency structure) so losses move like real training
rather than like uniform noise.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["PipelineConfig", "TokenPipeline"]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    zipf_alpha: float = 1.1  # token-frequency skew
    prefetch: int = 2

    def __post_init__(self):
        if self.global_batch % self.num_hosts:
            raise ValueError(
                f"global_batch {self.global_batch} not divisible by "
                f"num_hosts {self.num_hosts}"
            )
        if not (0 <= self.host_id < self.num_hosts):
            raise ValueError("host_id out of range")

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.num_hosts


class TokenPipeline:
    """Deterministic, restartable, host-sharded token stream.

    ``batch_at(step)`` is the pure-function access path (used for elastic
    resume: any host can reproduce any step).  Iteration with prefetch is
    the driver-facing path.
    """

    def __init__(self, cfg: PipelineConfig, start_step: int = 0):
        self.cfg = cfg
        self._step = start_step
        # Zipf-ish categorical over the vocab, frozen per pipeline.
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_alpha)
        self._probs = p / p.sum()
        self._queue: deque = deque()

    # -- pure access ------------------------------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Host-local batch for global step ``step`` (pure in (seed, step,
        host_id)).  Labels are next-token shifted; last position wraps to
        BOS=0 and is masked by ``loss_mask``."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id])
        )
        shape = (cfg.host_batch, cfg.seq_len)
        tokens = rng.choice(cfg.vocab_size, size=shape, p=self._probs).astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.zeros((cfg.host_batch, 1), np.int32)], axis=1
        )
        loss_mask = np.ones(shape, np.float32)
        loss_mask[:, -1] = 0.0
        return {"tokens": tokens, "labels": labels, "loss_mask": loss_mask}

    # -- iterator with prefetch -------------------------------------------
    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        while len(self._queue) < self.cfg.prefetch:
            self._queue.append(self.batch_at(self._step + len(self._queue)))
        batch = self._queue.popleft()
        self._step += 1
        return batch

    @property
    def step(self) -> int:
        return self._step

    def state_dict(self) -> Dict[str, int]:
        return {"step": self._step, "seed": self.cfg.seed}

    @classmethod
    def restore(cls, cfg: PipelineConfig, state: Dict[str, int]) -> "TokenPipeline":
        if state.get("seed", cfg.seed) != cfg.seed:
            raise ValueError("checkpointed pipeline seed differs from config")
        return cls(cfg, start_step=int(state["step"]))
