"""Sparse-matrix dataset substrate (paper §4 'Dataset').

The paper evaluates on (a) synthetic 16384² matrices with uniform,
power-law and k-regular structure over densities 1e-4..5e-2, and (b) nine
SuiteSparse/SNAP matrices (Table 3).  This container is offline, so the
real-world set is reproduced as *structure-matched surrogates*: same
dimension, same nnz, and a generator matching the published structure
class (FEM banded, electronic-structure block, power-law social graph,
...).  Benchmarks label them as surrogates; the GUST cycle counts are
produced by the same scheduler the paper used, on matrices with the same
summary statistics.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np

from repro.core.formats import COOMatrix

__all__ = [
    "synth_uniform",
    "synth_power_law",
    "synth_k_regular",
    "synth_banded",
    "synth_block_diagonal",
    "RealWorldSpec",
    "REAL_WORLD_SUITE",
    "make_real_world_surrogate",
]


def _dedupe(m: int, n: int, rows: np.ndarray, cols: np.ndarray, rng) -> COOMatrix:
    key = rows.astype(np.int64) * n + cols.astype(np.int64)
    key = np.unique(key)
    rows = (key // n).astype(np.int64)
    cols = (key % n).astype(np.int64)
    vals = rng.standard_normal(rows.shape[0]).astype(np.float32)
    return COOMatrix((m, n), rows, cols, vals)


def synth_uniform(n: int, density: float, seed: int = 0) -> COOMatrix:
    """Uniform Bernoulli sparsity (the §3.4 statistical-bound regime)."""
    rng = np.random.default_rng(seed)
    nnz = int(n * n * density)
    rows = rng.integers(0, n, int(nnz * 1.05) + 8)
    cols = rng.integers(0, n, int(nnz * 1.05) + 8)
    coo = _dedupe(n, n, rows, cols, rng)
    if coo.nnz > nnz:  # trim overdraw
        keep = rng.choice(coo.nnz, nnz, replace=False)
        coo = COOMatrix((n, n), coo.rows[keep], coo.cols[keep], coo.vals[keep])
    return coo


def synth_power_law(n: int, density: float, alpha: float = 2.1, seed: int = 0) -> COOMatrix:
    """Power-law degree distribution (SNAP-style social graphs): both row
    and column indices drawn from a Zipf-like law."""
    rng = np.random.default_rng(seed)
    nnz = int(n * n * density)
    ranks = np.arange(1, n + 1, dtype=np.float64)
    probs = ranks ** (-alpha / 2.0)
    probs /= probs.sum()
    perm_r = rng.permutation(n)
    perm_c = rng.permutation(n)
    rows = perm_r[rng.choice(n, int(nnz * 1.3) + 8, p=probs)]
    cols = perm_c[rng.choice(n, int(nnz * 1.3) + 8, p=probs)]
    coo = _dedupe(n, n, rows, cols, rng)
    if coo.nnz > nnz:
        keep = rng.choice(coo.nnz, nnz, replace=False)
        coo = COOMatrix((n, n), coo.rows[keep], coo.cols[keep], coo.vals[keep])
    return coo


def synth_k_regular(n: int, density: float, seed: int = 0) -> COOMatrix:
    """Every row has exactly k = round(n*density) nonzeros at random
    columns (SNAP k-regular generator analogue)."""
    rng = np.random.default_rng(seed)
    k = max(int(round(n * density)), 1)
    rows = np.repeat(np.arange(n, dtype=np.int64), k)
    cols = np.concatenate(
        [rng.choice(n, k, replace=False) for _ in range(n)]
    ).astype(np.int64)
    vals = rng.standard_normal(rows.shape[0]).astype(np.float32)
    return COOMatrix((n, n), rows, cols, vals)


def synth_banded(n: int, nnz: int, bandwidth_frac: float = 0.02, seed: int = 0) -> COOMatrix:
    """FEM/stencil surrogate: nonzeros cluster near the diagonal."""
    rng = np.random.default_rng(seed)
    bw = max(int(n * bandwidth_frac), 4)
    rows = rng.integers(0, n, int(nnz * 1.2) + 8)
    offs = np.rint(rng.standard_normal(rows.shape[0]) * bw / 3.0).astype(np.int64)
    cols = np.clip(rows + offs, 0, n - 1)
    coo = _dedupe(n, n, rows, cols, rng)
    if coo.nnz > nnz:
        keep = rng.choice(coo.nnz, nnz, replace=False)
        coo = COOMatrix((n, n), coo.rows[keep], coo.cols[keep], coo.vals[keep])
    return coo


def synth_block_diagonal(
    n: int, nnz: int, num_blocks: int = 64, seed: int = 0
) -> COOMatrix:
    """Electronic-structure surrogate (Si41Ge41H72-like): dense-ish blocks
    on the diagonal plus background noise."""
    rng = np.random.default_rng(seed)
    bs = n // num_blocks
    in_block = int(nnz * 0.85)
    blk = rng.integers(0, num_blocks, in_block)
    rows_b = blk * bs + rng.integers(0, bs, in_block)
    cols_b = blk * bs + rng.integers(0, bs, in_block)
    rest = nnz - in_block
    rows_u = rng.integers(0, n, rest)
    cols_u = rng.integers(0, n, rest)
    return _dedupe(
        n, n, np.concatenate([rows_b, rows_u]), np.concatenate([cols_b, cols_u]), rng
    )


@dataclasses.dataclass(frozen=True)
class RealWorldSpec:
    """Table 3 row: surrogate recipe for an offline container."""

    name: str
    dim: int
    nnz: int
    generator: str  # banded | block | power_law | uniform

    @property
    def density(self) -> float:
        return self.nnz / float(self.dim) ** 2


#: Table 3 of the paper.  nnz values scaled by `scale` at generation time so
#: quick benchmarks stay fast; `--full` uses scale=1.
REAL_WORLD_SUITE: Tuple[RealWorldSpec, ...] = (
    RealWorldSpec("crankseg_2", 63_838, 14_148_858, "banded"),
    RealWorldSpec("Si41Ge41H72", 185_639, 15_011_265, "block"),
    RealWorldSpec("TSOPF_RS_b2383", 38_120, 16_171_169, "block"),
    RealWorldSpec("ML_Laplace", 377_002, 27_582_698, "banded"),
    RealWorldSpec("mouse_gene", 45_101, 28_967_291, "uniform"),
    RealWorldSpec("coPapersCiteseer", 434_102, 21_114_892, "power_law"),
    RealWorldSpec("PFlow_742", 742_793, 37_138_461, "banded"),
    RealWorldSpec("googleplus", 107_614, 13_673_453, "power_law"),
    RealWorldSpec("soc_pokec", 1_632_803, 30_622_564, "power_law"),
)


def make_real_world_surrogate(spec: RealWorldSpec, scale: float = 1.0, seed: int = 0) -> COOMatrix:
    """Generate the structure-matched surrogate, optionally scaled down
    (dim and nnz shrink together, preserving density and structure)."""
    dim = max(int(spec.dim * scale), 256)
    nnz = max(int(spec.nnz * scale * scale), 512)
    nnz = min(nnz, dim * dim // 2)
    if spec.generator == "banded":
        return synth_banded(dim, nnz, seed=seed)
    if spec.generator == "block":
        return synth_block_diagonal(dim, nnz, seed=seed)
    if spec.generator == "power_law":
        density = nnz / float(dim) ** 2
        return synth_power_law(dim, density, seed=seed)
    return synth_uniform(dim, nnz / float(dim) ** 2, seed=seed)
