"""GustPlan — the one plan/execute API for every schedule→pack→run path.

The paper's amortization story (§3.3/§5.3) is an FFTW-style *plan*: at
matrix-load time you pay once for the edge-coloring schedule and the
packed execution layout, then execute against any number of vectors.
Before this module that contract was implicit and scattered across seven
entry points (``spmv``, ``spmm_scheduled``, ``spmm_ragged``,
``distributed_spmv``, ``gust_spmm``/``gust_spmm_auto``, ``GustLinear``,
serving), each re-threading its own copy of the layout/backend knobs.
Here it is explicit in the type system:

    >>> import repro
    >>> p = repro.plan(matrix, repro.PlanConfig(l=256, layout="auto"))
    >>> y = p.spmv(v)            # execute many times against one plan
    >>> Y = p.spmm(X)            # multi-vector (decode-batch) execution
    >>> p.shard(mesh).spmv(v)    # k parallel length-l GUSTs (paper §5.5)
    >>> p.cost()                 # measured + Eq. 9-11 predicted cost
    >>> spec = p.to_spec()       # leaves/meta wire format (serving stacks)

Decision points owned by the plan (and nowhere else):

  * **layout** — ``padded`` (dense ``(W, C_pad)`` grid), ``ragged`` (block
    stream of only real cycle blocks), or ``auto`` (pick by the measured
    padding-waste ratio, :data:`~repro.core.packing.DEFAULT_WASTE_THRESHOLD`).
  * **backend** — ``jnp`` (pure-XLA segment-sum), ``pallas`` (fused TPU
    kernel), or ``auto`` (Pallas on TPU when the schedule is fusable).
  * **dtype policy** — value/index leaf dtypes (``bfloat16``/``int16``
    halve the streamed bytes, the paper's packed-word analogue).
  * **sharding** — :meth:`GustPlan.shard` owns the device-major layout
    memoization that ``distributed_spmv`` used to hand-roll.

Packing is lazy: a plan schedules eagerly (the expensive, cache-shared
step) and materializes its packed artifact on first execution, so
schedule-only consumers (cycle models, cost estimates) never pay for
blocks they don't stream.  All caching is content-keyed through
:class:`~repro.core.packing.ScheduleCache`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .formats import COOMatrix, GustSchedule, coo_from_dense
from .packing import (
    PackedSchedule,
    RaggedSchedule,
    ScheduleCache,
    default_cache,
    pack_ragged,
    pack_schedule,
    resolve_gather,
    resolve_tuning,
    packed_from_leaves,
    packed_leaves,
    packed_meta,
    packed_spec,
    ragged_from_leaves,
    ragged_leaves,
    ragged_meta,
    ragged_spec,
    ragged_waste_ratio,
    resolve_layout,
    splice_ragged_blocks,
)
from repro.resilience import faults
from repro.resilience.fallback import record_fallback, resolve_fallback

__all__ = [
    "PlanConfig",
    "PlanCost",
    "TuneResult",
    "GustPlan",
    "plan",
    "reschedule",
    "RescheduleResult",
]

_LAYOUTS = ("padded", "ragged", "auto")
_BACKENDS = ("jnp", "pallas", "auto")
_COLORERS = ("paper", "fast", "exact")
_GATHERS = ("resident", "local", "auto")
_PIPELINES = ("single", "double", "auto")


@dataclasses.dataclass(frozen=True)
class PlanConfig:
    """Every knob of the schedule→pack→execute pipeline, in one frozen type.

    Attributes:
      l:               GUST length (number of multipliers == adders).
      colorer:         edge-coloring method — ``paper`` (Listing 1 greedy),
                       ``fast`` (vectorized equivalent), ``exact`` (König
                       Δ-coloring).
      load_balance:    apply the §3.5 row/lane balancing permutations.
      c_blk:           cycle-block height (pack granularity and padded-
                       kernel VMEM blocking).
      layout:          ``padded`` | ``ragged`` | ``auto`` (measured waste).
      backend:         ``jnp`` | ``pallas`` | ``auto`` (Pallas on TPU when
                       the schedule is fusable).
      gather:          Buffer-Filler mode — ``resident`` (x whole in
                       VMEM, one-hot over every column segment),
                       ``local`` (stream only the ``S_blk`` x tiles each
                       block references via the pack-time segment table),
                       or ``auto`` (segment-local when the measured
                       ``S_blk / seg_count`` locality ratio is low —
                       :func:`~repro.core.packing.resolve_gather`).
      pipeline:        VMEM streaming mode of the Pallas kernels —
                       ``single`` (one tile in flight), ``double``
                       (two-slot ping/pong scratch: the DMA fetching
                       tile ``s+1`` overlaps the accumulate of tile
                       ``s``), or ``auto`` (double on the kernel path).
                       Bit-identical either way; the jnp backend
                       ignores it.
      waste_threshold: padded/ragged stream ratio above which ``auto``
                       picks ragged; ``None`` = the shared default.
      value_dtype:     dtype name of the value leaves (``float32`` |
                       ``bfloat16`` | ``int8``).  ``int8`` turns on
                       pack-time per-block quantization: values are
                       stored int8 with one f32 scale per ``c_blk``
                       cycle block (``scale_blk``), dequantized in-kernel
                       with a single f32 multiply.  Because the scales
                       are aligned to the *pack-time* ``c_blk`` blocks,
                       an execute-time ``c_blk`` override is rejected on
                       quantized plans — re-pack instead.
      index_dtype:     dtype name of the index leaves (``int32`` |
                       ``int16``).
      interpret:       Pallas interpret mode; ``None`` = interpret off TPU.
      mesh_axis:       default mesh axis name for :meth:`GustPlan.shard`.
    """

    l: int = 256
    colorer: str = "fast"
    load_balance: bool = True
    c_blk: int = 8
    layout: str = "auto"
    backend: str = "auto"
    gather: str = "auto"
    pipeline: str = "auto"
    waste_threshold: Optional[float] = None
    value_dtype: str = "float32"
    index_dtype: str = "int32"
    interpret: Optional[bool] = None
    mesh_axis: str = "data"

    def __post_init__(self):
        if self.l < 1:
            raise ValueError(f"l must be >= 1, got {self.l}")
        if self.c_blk < 1:
            raise ValueError(f"c_blk must be >= 1, got {self.c_blk}")
        if self.layout not in _LAYOUTS:
            raise ValueError(f"layout must be one of {_LAYOUTS}, got {self.layout!r}")
        if self.backend not in _BACKENDS:
            raise ValueError(
                f"backend must be one of {_BACKENDS}, got {self.backend!r}"
            )
        if self.colorer not in _COLORERS:
            raise ValueError(
                f"colorer must be one of {_COLORERS}, got {self.colorer!r}"
            )
        if self.gather not in _GATHERS:
            raise ValueError(
                f"gather must be one of {_GATHERS}, got {self.gather!r}"
            )
        if self.pipeline not in _PIPELINES:
            raise ValueError(
                f"pipeline must be one of {_PIPELINES}, got {self.pipeline!r}"
            )
        # normalize dtypes to canonical names so configs hash/compare/
        # serialize stably whether built from strings or jnp dtypes
        object.__setattr__(self, "value_dtype", jnp.dtype(self.value_dtype).name)
        object.__setattr__(self, "index_dtype", jnp.dtype(self.index_dtype).name)

    @property
    def value_jnp(self):
        return jnp.dtype(self.value_dtype)

    @property
    def index_jnp(self):
        return jnp.dtype(self.index_dtype)

    def to_dict(self) -> Dict:
        """Plain-JSON form (the config part of :meth:`GustPlan.to_spec`)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "PlanConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclasses.dataclass(frozen=True)
class PlanCost:
    """Measured + predicted cost of one plan (wraps ``core.bounds``).

    ``cycles``/``utilization`` come from the *actual* schedule (the paper's
    own evaluation path); ``waste_ratio`` is the measured padded/ragged
    stream ratio that drives the ``auto`` layout choice; ``expected_*``
    are the Eq. 9-11 statistical bounds at the matrix's measured density.

    The gather-locality block (PR 5) quantifies both Buffer-Filler modes
    without executing — this is what ``dryrun``/``roofline_report`` read
    to show the segment-local win:

    * ``s_blk`` / ``locality_ratio`` — measured per-block segment working
      set and its ratio to ``seg_count`` (the ``gather="auto"`` signal);
    * ``gather_flops_resident`` / ``gather_flops_local`` — fused-gather
      FLOPs per vector column: ``4 · slots · seg_count`` vs
      ``4 · slots · S_blk`` (two one-hot contractions, 2 flops/MAC);
    * ``x_vmem_bytes_resident`` / ``x_vmem_bytes_local`` — f32 x-tile
      VMEM residency per vector column: the whole padded vector
      (``seg_count · l · 4``) vs one block's tile working set
      (``S_blk · l · 4``) — the resident number is the width cap the
      local mode removes;
    * ``gather`` — the mode this plan resolves to.

    The observability block (PR 6) records *why a path was taken* so
    benchmarks and serving logs can report it without re-deriving the
    resolution logic:

    * ``backend`` / ``pipeline`` — the resolved (never ``auto``) execution
      choices next to the resolved ``layout``/``gather``;
    * ``cache_hits`` / ``cache_misses`` / ``cache_entries`` /
      ``cache_evictions`` — the plan's
      :class:`~repro.core.packing.ScheduleCache` counters at cost time
      (all zero for cache-less plans); evictions count LRU capacity drops
      (PR 7).
    * ``store_hits`` / ``store_misses`` — the plan's attached
      :class:`~repro.core.plan_store.PlanStore` counters (zero when the
      plan was built without ``store=``).

    The resilience block (PR 10) counts graceful-degradation downgrades
    applied on this plan's execution path, each routed through the
    single :func:`repro.resilience.resolve_fallback` decision point:

    * ``fallback_kernel`` — Pallas kernel failures retried on the jnp
      oracle (tolerance-identical);
    * ``fallback_gather`` — local-gather failures retried resident
      (bitwise-identical, PR 5);
    * ``fallback_store`` — store read failures (after jittered-backoff
      retries) served by a fresh pack (bitwise-identical, PR 7).
    """

    cycles: int
    utilization: float
    waste_ratio: float
    layout: str
    streamed_slots: int
    stream_bytes: int
    density: float
    expected_colors: float
    expected_cycles: float
    expected_utilization: float
    gather: str
    s_blk: int
    locality_ratio: float
    gather_flops_resident: int
    gather_flops_local: int
    x_vmem_bytes_resident: int
    x_vmem_bytes_local: int
    backend: str = "jnp"
    pipeline: str = "single"
    cache_hits: int = 0
    cache_misses: int = 0
    cache_entries: int = 0
    cache_evictions: int = 0
    store_hits: int = 0
    store_misses: int = 0
    fallback_kernel: int = 0
    fallback_gather: int = 0
    fallback_store: int = 0

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Record of one measured :meth:`GustPlan.tune` sweep.

    Candidate keys are ``(c_blk, l, layout, gather)`` tuples.  ``choice``
    is the winner picked by the single tuning decision point
    (:func:`~repro.core.packing.resolve_tuning`): the fastest measured
    candidate, unless it fails to beat ``baseline`` — the plan's static
    ``resolve_layout``/``resolve_gather`` resolution — by the margin, in
    which case the baseline stands.  ``cost_consistent`` validates the
    winner against the cost-model ordering: it streams no more bytes
    than the baseline predicted (a ``False`` here flags a measurement
    that contradicts the Eq. 9-11 story and is worth a look, not an
    error).  ``pruned`` lists candidates :class:`PlanCost` rejected
    before timing (predicted stream bytes beyond ``prune_ratio`` × the
    best prediction)."""

    choice: Tuple[int, int, str, str]
    baseline: Tuple[int, int, str, str]
    measurements: Dict[Tuple[int, int, str, str], float]
    predicted_bytes: Dict[Tuple[int, int, str, str], int]
    improvement: float
    cost_consistent: bool
    pruned: Tuple[Tuple[int, int, str, str], ...] = ()

    def to_dict(self) -> Dict:
        key = lambda k: f"c_blk={k[0]},l={k[1]},layout={k[2]},gather={k[3]}"
        return {
            "choice": key(self.choice),
            "baseline": key(self.baseline),
            "measurements": {key(k): v for k, v in self.measurements.items()},
            "predicted_bytes": {
                key(k): v for k, v in self.predicted_bytes.items()
            },
            "improvement": self.improvement,
            "cost_consistent": self.cost_consistent,
            "pruned": [key(k) for k in self.pruned],
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "TuneResult":
        """Inverse of :meth:`to_dict` — how a PlanStore warm start revives
        the recorded sweep on the loaded plan."""

        def parse(s: str) -> Tuple[int, int, str, str]:
            kv = dict(part.split("=", 1) for part in s.split(","))
            return (int(kv["c_blk"]), int(kv["l"]), kv["layout"], kv["gather"])

        return cls(
            choice=parse(d["choice"]),
            baseline=parse(d["baseline"]),
            measurements={parse(k): v for k, v in d["measurements"].items()},
            predicted_bytes={
                parse(k): v for k, v in d["predicted_bytes"].items()
            },
            improvement=d["improvement"],
            cost_consistent=d["cost_consistent"],
            pruned=tuple(parse(k) for k in d.get("pruned", [])),
        )


def plan(
    matrix: Union[np.ndarray, COOMatrix, GustSchedule],
    config: Optional[PlanConfig] = None,
    *,
    cache: Optional[ScheduleCache] = default_cache,
    store=None,
    workers: Optional[int] = None,
    **overrides,
) -> "GustPlan":
    """Schedule ``matrix`` once and return an executable :class:`GustPlan`.

    ``matrix`` may be a dense 2-D array (numpy or jax), a
    :class:`COOMatrix`, or an already-built :class:`GustSchedule` (whose
    ``l`` wins over the config's).  Scheduling is served from ``cache`` (content-keyed; pass
    ``cache=None`` to bypass), so two plans over the same matrix schedule
    exactly once.  Keyword ``overrides`` are applied on top of ``config``:
    ``plan(m, l=64, layout="ragged")``.

    ``store`` (a :class:`~repro.core.plan_store.PlanStore`) extends the
    amortization across processes: on a hit the packed artifact is loaded
    straight off disk — zero coloring or packing work — and on a miss the
    fresh plan persists its artifact (plus any ``TuneResult``) the first
    time the pack materializes.  Store-loaded plans execute bit-
    identically but carry no schedule (``cost()``/``tune()``/``shard()``
    need a fresh plan).  ``workers`` forwards to the window-chunked
    parallel colorer (None = auto); it never affects plan content.
    """
    if config is None:
        config = PlanConfig()
    if overrides:
        config = dataclasses.replace(config, **overrides)

    if isinstance(matrix, GustSchedule):
        sched = matrix
        if sched.l != config.l:
            config = dataclasses.replace(config, l=sched.l)
        return GustPlan(config, sched=sched, cache=cache)

    _source = None

    if isinstance(matrix, (np.ndarray, jax.Array)):
        dense = np.asarray(matrix)
        if dense.ndim != 2:
            raise ValueError(f"dense matrix must be 2-D, got shape {dense.shape}")
        matrix = coo_from_dense(dense)
    if not isinstance(matrix, COOMatrix):
        raise TypeError(
            "plan() takes a dense (numpy or jax) array, a COOMatrix or a "
            f"GustSchedule; got {type(matrix).__name__}"
        )
    _source = matrix  # kept on the plan so tune() can sweep l

    store_key = None
    store_fallbacks = 0
    if store is not None:
        store_key = store.key(ScheduleCache.matrix_key(matrix), config)
        io0 = store.io_errors
        record = store.get(store_key)
        if record is None and store.io_errors > io0:
            # The read failed even after the store's jittered-backoff
            # retries: degrade stored -> fresh (bitwise-identical, the
            # PR 7 warm==cold gate) and count it on the fresh plan.
            record_fallback("store")
            store_fallbacks = 1
        if record is not None:
            spec = record["spec"]
            spec = dict(spec, leaves={
                k: jnp.asarray(v) for k, v in spec["leaves"].items()
            })
            p = GustPlan.from_spec(spec, config=config, cache=cache)
            p._source = matrix
            p._store = store
            p._store_key = store_key
            p._store_loaded = True
            if record.get("tuning"):
                p.tuning = TuneResult.from_dict(record["tuning"])
            p.summary = record.get("summary")
            return p

    if cache is None:
        from .scheduler import schedule as _schedule

        sched = _schedule(
            matrix, config.l, load_balance=config.load_balance,
            method=config.colorer, workers=workers,
        )
    else:
        sched = cache.schedule(
            matrix, config.l, load_balance=config.load_balance,
            method=config.colorer, workers=workers,
        )
    p = GustPlan(config, sched=sched, cache=cache, source=_source)
    p._store = store
    p._store_key = store_key
    p._fallbacks["store"] = store_fallbacks
    return p


class GustPlan:
    """Executable GUST artifact: schedule + packed layout + backend choice.

    Built by :func:`plan` (or :meth:`from_spec` / :meth:`from_artifact`).
    The plan owns the scheduled and packed artifacts for one matrix and is
    the single internal execution route — every legacy entry point
    (``spmv``, ``gust_spmm``, ``GustLinear``, serving, ...) constructs one
    and delegates to :meth:`spmv` / :meth:`spmm`.

    Not a pytree: like a compiled FFTW/cuDNN plan this is a host-side
    handle; its array leaves (``.artifact``) are the pytree that crosses
    into jit.
    """

    def __init__(
        self,
        config: PlanConfig,
        *,
        sched: Optional[GustSchedule] = None,
        artifact: Optional[Union[PackedSchedule, RaggedSchedule]] = None,
        cache: Optional[ScheduleCache] = None,
        mesh=None,
        axis: Optional[str] = None,
        source: Optional[COOMatrix] = None,
    ):
        if sched is None and artifact is None:
            raise ValueError("a GustPlan needs a schedule or a packed artifact")
        self.config = config
        self.sched = sched
        self.cache = cache
        self.mesh = mesh
        self.axis = axis
        self._artifact = artifact
        self._source = source  # COO kept (when known) so tune() can sweep l
        self.tuning: Optional[TuneResult] = None
        # PlanStore attachment (plan(..., store=...)): write-behind fires
        # when a fresh plan first materializes its pack; loaded plans
        # carry the stored schedule summary instead of a schedule.
        self._store = None
        self._store_key: Optional[str] = None
        self._store_loaded = False
        self.summary: Optional[Dict] = None
        # Graceful-degradation counters (PR 10): downgrades applied on
        # *this plan's* execution path, surfaced as PlanCost.fallback_*.
        # Keys mirror resilience.fallback's stages.
        self._fallbacks: Dict[str, int] = {"kernel": 0, "gather": 0, "store": 0}
        # Incremental rescheduling (reschedule()): per-window content
        # fingerprints of the source, and the last delta's stats.
        self._window_hashes: Optional[np.ndarray] = None
        self.resched: Optional["RescheduleResult"] = None

    # -- identity ----------------------------------------------------------

    @property
    def shape(self) -> Tuple[int, int]:
        src = self.sched if self.sched is not None else self._artifact
        return src.shape

    @property
    def l(self) -> int:
        return self.config.l

    @property
    def layout(self) -> str:
        """Resolved layout (``auto`` is decided at pack time)."""
        if self._artifact is not None:
            return (
                "ragged" if isinstance(self._artifact, RaggedSchedule) else "padded"
            )
        if self.config.layout != "auto":
            return self.config.layout
        return resolve_layout(
            self.sched, self.config.c_blk, self.config.waste_threshold
        )

    @property
    def artifact(self) -> Union[PackedSchedule, RaggedSchedule]:
        """The packed execution layout; materialized lazily on first use.
        A fresh plan with an attached store persists the artifact here
        (write-behind) — schedule-only consumers that never pack never
        write either."""
        if self._artifact is None:
            faults.trip("pack.materialize")
            self._artifact = self._pack()
            self._store_put()
        return self._artifact

    def verify(self):
        """Run the static artifact verifier over the packed leaves and
        return the list of :class:`~repro.analysis.verify.Finding`
        violations (empty on a healthy artifact).  Packs a lazy plan;
        pure numpy, never executes a kernel."""
        from repro.analysis.verify import verify as _verify

        return _verify(self.artifact)

    def _store_put(self) -> None:
        """Best-effort write-behind of the packed artifact (plus tuning
        and a schedule summary for loaded-plan observability).  Never
        raises: persistence must not break execution."""
        if self._store is None or self._store_key is None or self._store_loaded:
            return
        try:
            summary = None
            if self.sched is not None:
                summary = {
                    "cycles": int(self.sched.cycles),
                    "nnz": int(self.sched.nnz),
                    "utilization": float(self.sched.hardware_utilization),
                }
            self._store.put(
                self._store_key,
                self.to_spec(),
                tuning=self.tuning.to_dict() if self.tuning else None,
                summary=summary,
            )
        except Exception:
            pass

    @property
    def gather_mode(self) -> str:
        """Resolved Buffer-Filler gather mode (``auto`` is decided from
        the packed artifact's measured ``S_blk / seg_count`` locality —
        reading this packs a lazy plan)."""
        if self.config.gather != "auto":
            return self.config.gather
        a = self.artifact
        return resolve_gather(a.s_blk, a.seg_count)

    def _pack(self):
        c = self.config
        layout = self.layout  # resolves "auto" from the measured waste
        if self.cache is not None:
            route = (
                self.cache.ragged_for if layout == "ragged" else self.cache.pack_for
            )
            return route(
                self.sched, c_blk=c.c_blk, value_dtype=c.value_jnp,
                index_dtype=c.index_jnp,
            )
        fn = pack_ragged if layout == "ragged" else pack_schedule
        return fn(
            self.sched, c.c_blk, value_dtype=c.value_jnp, index_dtype=c.index_jnp
        )

    def _use_kernel(self) -> bool:
        if self.config.backend == "pallas":
            return True
        if self.config.backend == "jnp":
            return False
        return bool(self.artifact.fusable and jax.default_backend() == "tpu")

    def _interpret(self) -> bool:
        if self.config.interpret is not None:
            return self.config.interpret
        return jax.default_backend() != "tpu"

    def _pipeline(self) -> str:
        """Resolved streaming mode: the jnp backend has no tile pipeline
        (``single``); on the kernel path ``auto`` means double-buffered."""
        if not self._use_kernel():
            return "single"
        return "double" if self.config.pipeline == "auto" else self.config.pipeline

    # -- execution ---------------------------------------------------------

    def spmm(self, x: jnp.ndarray, *, transpose_io: bool = False) -> jnp.ndarray:
        """Multi-vector execution: ``x (n, B) -> y (m, B)``.

        With ``transpose_io=True`` the batch dimension leads instead —
        ``x (B, n) -> y (B, m)`` — and both transposes happen *inside* the
        jitted executor, where XLA fuses them into the gather/scatter.
        Callers that are batch-major (``GustLinear``, most LM decode
        paths) previously paid two eagerly-materialized ``.T`` copies per
        call; this fast path removes that round-trip bit-identically.

        Execution failures degrade through the single fallback decision
        point (:func:`repro.resilience.resolve_fallback`, ROADMAP
        §Resilience invariants): a failing ``gather="local"`` path
        retries resident (bitwise-identical, PR 5), then a failing
        Pallas backend retries the jnp oracle (tolerance-identical).
        Every applied downgrade is counted on ``cost().fallback_*``; a
        failure at the floor of the chain propagates to the serve-step
        containment layer.
        """
        if self.mesh is not None:
            raise NotImplementedError(
                "sharded plans execute single vectors; use .spmv(v) "
                "(the §5.5 row-window split concatenates per-device outputs)"
            )
        try:
            return self._execute(
                x, transpose_io, self.config.gather, self._use_kernel()
            )
        except Exception as err:
            return self._degraded_spmm(x, transpose_io, err)

    def _execute(
        self, x, transpose_io: bool, gather: str, use_kernel: bool
    ) -> jnp.ndarray:
        from repro.kernels.ops import execute_spmm

        return execute_spmm(
            self.artifact,
            x,
            use_kernel=use_kernel,
            interpret=self._interpret(),
            c_blk=self.config.c_blk,
            transpose_io=transpose_io,
            gather=gather,
            pipeline=self.config.pipeline,
        )

    def _degraded_spmm(
        self, x, transpose_io: bool, err: BaseException
    ) -> jnp.ndarray:
        """Sanctioned containment site for :meth:`spmm` (lint GUST-L07
        allowlist): walk the fallback chain one step at a time, counting
        each applied downgrade, and re-raise the original error when the
        chain is exhausted."""
        gather = self.config.gather
        if gather == "auto":
            a = self._artifact  # spmm already materialized it, or packing
            if a is None:  # itself failed -> nothing to degrade to
                raise err
            gather = resolve_gather(a.s_blk, a.seg_count)
        use_kernel = self._use_kernel()

        degraded_gather = resolve_fallback("gather", gather)
        if degraded_gather is not None:
            try:
                y = self._execute(x, transpose_io, degraded_gather, use_kernel)
            except Exception:
                pass  # fall through to the kernel leg with gather degraded
            else:
                record_fallback("gather")
                self._fallbacks["gather"] += 1
                return y
            gather = degraded_gather

        if use_kernel and resolve_fallback("kernel", "pallas") == "jnp":
            y = self._execute(x, transpose_io, gather, False)
            record_fallback("kernel")
            self._fallbacks["kernel"] += 1
            if degraded_gather is not None:
                record_fallback("gather")
                self._fallbacks["gather"] += 1
            return y
        raise err

    def spmv(self, v: jnp.ndarray) -> jnp.ndarray:
        """Single-vector execution: ``v (n,) -> y (m,)``.  On a sharded
        plan (:meth:`shard`) this runs k parallel length-l GUSTs over
        contiguous window ranges and concatenates collectives-free."""
        v = jnp.asarray(v)
        m, n = self.shape
        if v.shape != (n,):
            raise ValueError(f"vector shape {v.shape} != ({n},)")
        if self.mesh is not None:
            return self._spmv_sharded(v)
        return self.spmm(v[:, None])[:, 0]

    def spgemm(
        self,
        other,
        *,
        backend: Optional[str] = None,
        interpret: Optional[bool] = None,
    ) -> COOMatrix:
        """Sparse×sparse ``C = A @ B`` through this plan's color-block
        stream (``other``: COOMatrix, dense array, or another plan built
        from its source matrix).  Returns a deduplicated row-sorted
        :class:`COOMatrix` that can itself be ``repro.plan()``-ed —
        chained ``A·A`` analytics (:mod:`repro.graph`) run on the result
        directly.  See :mod:`repro.core.spgemm` for the condensed-B
        outer-product organization and the bit-identity contract
        (ROADMAP §SpGEMM invariants)."""
        from .spgemm import spgemm as _spgemm

        if self.mesh is not None:
            raise NotImplementedError(
                "spgemm on a sharded plan is not supported; call it on "
                "the unsharded plan"
            )
        return _spgemm(self, other, backend=backend, interpret=interpret)

    def spgemm_cost(self, other) -> "SpgemmCost":
        """Predicted cost of ``self @ other`` — output-nnz estimate,
        scratch bytes, merge ops, streamed-FLOP reduction vs dense —
        without packing or executing (the dryrun/roofline entry point
        for SpGEMM).  See :class:`repro.core.spgemm.SpgemmCost`."""
        from .spgemm import spgemm_cost as _spgemm_cost

        return _spgemm_cost(self, other)

    # -- distributed execution (absorbs distributed_spmv) --------------------

    def shard(self, mesh, axis: Optional[str] = None) -> "GustPlan":
        """Return a plan that executes as ``mesh.shape[axis]`` parallel
        length-l GUSTs (paper §5.5: "the Edge-Coloring schedule would not
        need to change").  Devices get contiguous window ranges balanced
        by ragged-stream *block count* (not window count — equal-window
        splits leave most devices idle on skewed matrices).

        The device-major layout (host assembly + upload) is memoized in
        the plan's :class:`ScheduleCache` next to the pack, so repeated
        executions only run the shard_map.  Sharding requires the ragged
        stream; a padded plan re-packs ragged through the cache.
        """
        axis = axis if axis is not None else self.config.mesh_axis
        ragged_art = (
            self._artifact
            if isinstance(self._artifact, RaggedSchedule)
            else None
        )
        if ragged_art is None and self.sched is None:
            raise ValueError(
                "cannot shard a padded spec-plan: the ragged stream needs "
                "the schedule (build the plan with plan(...) or a ragged "
                "artifact)"
            )
        # artifact stays lazy (None unless already ragged): when the
        # device-major layout below is served from the cache, the ragged
        # pack is never even materialized on this host
        return GustPlan(
            dataclasses.replace(self.config, layout="ragged", mesh_axis=axis),
            sched=self.sched,
            artifact=ragged_art,
            cache=self.cache,
            mesh=mesh,
            axis=axis,
        )

    def _spmv_sharded(self, v: jnp.ndarray) -> jnp.ndarray:
        c = self.config
        n_dev = self.mesh.shape[self.axis]
        if self.cache is not None and self.sched is not None:
            # one memo entry per (schedule content, c_blk, dtypes, n_dev);
            # the build closure touches .artifact, so a memo hit skips the
            # ragged pack entirely
            layout = self.cache.memo(
                ("shard_layout", self.cache.schedule_key(self.sched),
                 c.c_blk, c.value_dtype, c.index_dtype, n_dev),
                lambda: _shard_layout(self.artifact, n_dev),
            )
        else:
            layout = _shard_layout(self.artifact, n_dev)
        m_d, r_d, c_d, lw_d, w_max, idx = layout
        fn = _shard_spmv_fn(self.mesh, self.axis, c.l, c.c_blk, w_max)
        y_dev = fn(m_d, r_d, c_d, lw_d, v)
        # Reassemble: device d's first w_cnt[d]*l rows are its window range
        # in order (collectives-free concatenation), then undo the
        # load-balancing row sort.
        m = self.shape[0]
        if self.sched is not None:
            y_sorted = y_dev.reshape(-1)[idx][:m]
            return jnp.zeros((m,), jnp.float32).at[
                jnp.asarray(self.sched.row_perm)
            ].set(y_sorted)
        a = self.artifact
        y_all = y_dev.reshape(-1)[idx]
        out = jnp.zeros((max(m, a.num_windows * a.l),), jnp.float32)
        return out.at[jnp.asarray(a.row_perm)].set(y_all)[:m]

    # -- multi-layer serving -------------------------------------------------

    @staticmethod
    def stack(plans: Sequence["GustPlan"]) -> Dict:
        """Stack the packed artifacts of ``plans`` (one per layer) along a
        leading reps axis for the serving layer-scan: layers are equalized
        to a uniform stream length first (``repad_to`` / ``repad_to_blocks``
        preserve the padding invariants and leaf dtypes).  Returns the
        ``{"leaves", "meta"}`` wire format consumed by
        ``serving.gust_serve.decode_step_gust`` and :meth:`from_spec`."""
        arts = [p.artifact if isinstance(p, GustPlan) else p for p in plans]
        if not arts:
            raise ValueError("stack() needs at least one plan")
        ragged = isinstance(arts[0], RaggedSchedule)
        if any(isinstance(a, RaggedSchedule) != ragged for a in arts):
            raise ValueError("cannot stack mixed padded/ragged layouts")
        quant = arts[0].quantized
        if any(a.quantized != quant for a in arts):
            # the scale_blk leaf exists only on quantized artifacts, so a
            # mixed stack has no common pytree structure
            raise ValueError(
                "cannot stack mixed quantized/unquantized layers: pack "
                "every layer with the same value_dtype"
            )
        if ragged:
            t_uniform = max(a.num_blocks for a in arts)
            arts = [a.repad_to_blocks(t_uniform) for a in arts]
        else:
            c_uniform = max(a.c_pad for a in arts)
            arts = [a.repad_to(c_uniform) for a in arts]
        # equalize the gather-table width too (seg_blk must stack), and
        # make the shared static flags conservative: one meta tuple
        # describes every layer's slice, so identity_perm/fusable hold
        # only if they hold for ALL layers
        s_uniform = max(a.s_blk for a in arts)
        arts = [a.repad_seg_to(s_uniform) for a in arts]
        ident = all(a.identity_perm for a in arts)
        fusable = all(a.fusable for a in arts)
        arts = [
            dataclasses.replace(a, identity_perm=ident, fusable=fusable)
            for a in arts
        ]
        if ragged:
            leaf_fn, meta = ragged_leaves, ragged_meta(arts[0])
        else:
            leaf_fn, meta = packed_leaves, packed_meta(arts[0])
        leaves = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[leaf_fn(a) for a in arts]
        )
        return {"leaves": leaves, "meta": meta}

    # -- serialization (the leaves/meta codec) -------------------------------

    def to_spec(self) -> Dict:
        """``{"leaves", "meta", "config"}`` — the one wire format (shared
        with serving stacks and dry-run specs).  ``leaves`` are the array
        (or ShapeDtypeStruct) pytree at their exact dtypes; ``meta`` +
        ``config`` are static and JSON-able."""
        a = self.artifact
        if isinstance(a, RaggedSchedule):
            leaves, meta = ragged_leaves(a), ragged_meta(a)
        else:
            leaves, meta = packed_leaves(a), packed_meta(a)
        return {"leaves": leaves, "meta": meta, "config": self.config.to_dict()}

    @classmethod
    def from_spec(
        cls,
        spec: Dict,
        *,
        config: Optional[PlanConfig] = None,
        cache: Optional[ScheduleCache] = None,
    ) -> "GustPlan":
        """Rebuild a plan from :meth:`to_spec` output (or one layer's slice
        of a :meth:`stack`).  The schedule itself is not serialized — a
        deserialized plan executes but cannot re-pack or shard."""
        meta = tuple(spec["meta"])
        if meta and meta[0] == "ragged":
            artifact = ragged_from_leaves(spec["leaves"], meta)
        else:
            artifact = packed_from_leaves(spec["leaves"], meta)
        if config is None:
            cfg_dict = spec.get("config")
            config = (
                PlanConfig.from_dict(cfg_dict) if cfg_dict else PlanConfig()
            )
        return cls.from_artifact(artifact, config=config, cache=cache)

    @classmethod
    def from_artifact(
        cls,
        artifact: Union[PackedSchedule, RaggedSchedule],
        *,
        config: Optional[PlanConfig] = None,
        backend: Optional[str] = None,
        interpret: Optional[bool] = None,
        c_blk: Optional[int] = None,
        cache: Optional[ScheduleCache] = None,
        sched: Optional[GustSchedule] = None,
    ) -> "GustPlan":
        """Wrap an already-packed layout in a plan (the route every legacy
        packed-entry shim takes).  Layout/geometry/dtypes are read off the
        artifact; ``backend``/``interpret``/``c_blk`` override the config."""
        if config is None:
            config = PlanConfig()
        ragged = isinstance(artifact, RaggedSchedule)
        config = dataclasses.replace(
            config,
            l=artifact.l,
            layout="ragged" if ragged else "padded",
            # ragged streams and quantized streams (scales aligned to the
            # pack-time blocks) execute at their pack-time c_blk only
            c_blk=artifact.c_blk if (ragged or artifact.quantized) else (
                c_blk if c_blk is not None else config.c_blk
            ),
            backend=backend if backend is not None else config.backend,
            interpret=interpret if interpret is not None else config.interpret,
            value_dtype=jnp.dtype(artifact.m_blk.dtype).name,
            index_dtype=jnp.dtype(artifact.col_blk.dtype).name,
        )
        return cls(config, sched=sched, artifact=artifact, cache=cache)

    @classmethod
    def spec_for(
        cls, m: int, n: int, config: PlanConfig, *, colors: float
    ) -> "GustPlan":
        """Shape-only plan (ShapeDtypeStruct leaves, no allocation) with
        the scheduled stream sized from a per-window color-count estimate
        — typically the Eq. 9 bound.  This is how the multi-pod dry-run
        lowers the GUST decode path without running the scheduler."""
        c = config
        layout = "padded" if c.layout == "auto" else c.layout
        cpb = max(-(-int(np.ceil(colors)) // c.c_blk), 1)
        if layout == "ragged":
            num_blocks = max(-(-m // c.l), 1) * cpb
            artifact = ragged_spec(
                m, n, c.l, num_blocks, c_blk=c.c_blk,
                value_dtype=c.value_jnp, index_dtype=c.index_jnp,
            )
        else:
            artifact = packed_spec(
                m, n, c.l, cpb * c.c_blk, c_blk=c.c_blk,
                value_dtype=c.value_jnp, index_dtype=c.index_jnp,
            )
        return cls(
            dataclasses.replace(c, layout=layout), artifact=artifact
        )

    # -- measured autotuning -------------------------------------------------

    def tune(
        self,
        x_probe: jnp.ndarray,
        *,
        c_blks: Optional[Sequence[int]] = None,
        ls: Optional[Sequence[int]] = None,
        layouts: Sequence[str] = ("padded", "ragged"),
        gathers: Sequence[str] = ("resident", "local"),
        iters: int = 3,
        warmup: int = 1,
        min_improvement: Optional[float] = None,
        prune_ratio: float = 4.0,
    ) -> "GustPlan":
        """Measure ``(c_blk, l, layout, gather)`` candidates against
        ``x_probe`` and return a plan pinned to the winner.

        This is the plan-time analogue of FFTW's ``MEASURE`` mode: the
        sweep prices each candidate with :class:`PlanCost` first (anything
        predicted to stream more than ``prune_ratio`` × the best
        candidate's bytes is pruned untimed), times the surviving jitted
        executors (best-of-``iters`` after ``warmup`` untimed calls), and
        feeds the measurements through the one tuning decision point,
        :func:`~repro.core.packing.resolve_tuning` — the fastest candidate
        wins unless it fails to beat the static
        ``resolve_layout``/``resolve_gather`` baseline by the margin, in
        which case the baseline stands.  The returned plan carries the
        full :class:`TuneResult` on ``.tuning``; its config spells every
        swept knob explicitly (no ``auto``), so ``to_spec()`` round-trips
        the tuned choice.

        The winning choice is memoized content-keyed in the plan's
        :class:`~repro.core.packing.ScheduleCache`, so re-tuning the same
        matrix/probe reuses the recorded sweep instead of re-timing.

        ``ls`` defaults to the plan's own ``l`` (plus ``l/2`` when the
        plan still holds its source matrix — sweeping ``l`` means
        re-scheduling, which only :func:`plan`-built plans can do).
        """
        import time

        if self.sched is None:
            raise ValueError(
                "tune() needs the schedule; deserialized/spec plans carry "
                "only the packed artifact"
            )
        if self.mesh is not None:
            raise NotImplementedError("tune a plan before sharding it")
        x_probe = jnp.asarray(x_probe)
        if x_probe.ndim == 1:
            x_probe = x_probe[:, None]
        c = self.config
        if c_blks is None:
            c_blks = tuple(sorted({4, c.c_blk, 2 * c.c_blk}))
        if ls is None:
            ls = (
                tuple(sorted({c.l, max(c.l // 2, 1)}, reverse=True))
                if self._source is not None
                else (c.l,)
            )
        baseline = (c.c_blk, c.l, self.layout, self.gather_mode)

        def build(key: Tuple[int, int, str, str]) -> "GustPlan":
            cb, l, layout, gather = key
            cfg = dataclasses.replace(
                c, c_blk=cb, l=l, layout=layout, gather=gather
            )
            if l == c.l:
                return GustPlan(
                    cfg, sched=self.sched, cache=self.cache,
                    source=self._source,
                )
            return plan(self._source, cfg, cache=self.cache)

        candidates = {baseline}
        for cb in c_blks:
            for l in ls:
                if l != c.l and self._source is None:
                    continue
                for layout in layouts:
                    for gather in gathers:
                        candidates.add((int(cb), int(l), layout, gather))
        candidates = sorted(candidates)

        def sweep():
            predicted, plans = {}, {}
            for key in candidates:
                p = build(key)
                plans[key] = p
                predicted[key] = int(p.cost().stream_bytes)
            floor = min(predicted.values())
            pruned = tuple(
                k for k in candidates
                if k != baseline and predicted[k] > prune_ratio * floor
            )
            measurements = {}
            for key in candidates:
                if key in pruned:
                    continue
                run = plans[key].spmm
                for _ in range(max(warmup, 1)):
                    jax.block_until_ready(run(x_probe))
                best = float("inf")
                for _ in range(max(iters, 1)):
                    t0 = time.perf_counter()
                    jax.block_until_ready(run(x_probe))
                    best = min(best, time.perf_counter() - t0)
                measurements[key] = best
            choice = resolve_tuning(
                measurements, baseline, min_improvement=min_improvement
            )
            return TuneResult(
                choice=choice,
                baseline=baseline,
                measurements=measurements,
                predicted_bytes=predicted,
                improvement=measurements[baseline] / measurements[choice],
                cost_consistent=predicted[choice] <= predicted[baseline],
                pruned=pruned,
            )

        if self.cache is not None:
            memo_key = (
                "tune", self.cache.schedule_key(self.sched),
                tuple(candidates), tuple(x_probe.shape), str(x_probe.dtype),
                c.value_dtype, c.index_dtype, c.backend, self._interpret(),
                iters, warmup, min_improvement, prune_ratio,
            )
            result = self.cache.memo(memo_key, sweep)
        else:
            result = sweep()
        tuned = build(result.choice)
        tuned.tuning = result
        if self._store is not None and self._source is not None:
            # persist the tuned winner under the *tuned* config's key, so
            # a warm start revives both the artifact and the TuneResult
            tuned._store = self._store
            tuned._store_key = self._store.key(
                ScheduleCache.matrix_key(self._source), tuned.config
            )
        return tuned

    # -- cost ----------------------------------------------------------------

    def cost(self) -> PlanCost:
        """Measured schedule cost + Eq. 9-11 predictions for this plan."""
        from .bounds import (
            expected_colors_bound,
            expected_execution_cycles,
            expected_utilization,
        )

        if self.sched is None:
            raise ValueError(
                "cost() needs the schedule; deserialized/spec plans carry "
                "only the packed artifact"
            )
        m, n = self.shape
        density = self.sched.nnz / float(m * n) if m and n else 0.0
        a = self.artifact
        streamed = (
            a.streamed_slots
            if isinstance(a, RaggedSchedule)
            else int(np.prod(a.m_blk.shape))
        )
        return PlanCost(
            cycles=self.sched.cycles,
            utilization=self.sched.hardware_utilization,
            waste_ratio=ragged_waste_ratio(self.sched, self.config.c_blk),
            layout=self.layout,
            streamed_slots=streamed,
            stream_bytes=a.stream_bytes,
            density=density,
            expected_colors=float(expected_colors_bound(n, density, self.l)),
            expected_cycles=float(expected_execution_cycles(n, density, self.l)),
            expected_utilization=float(expected_utilization(n, density, self.l)),
            gather=self.gather_mode,
            s_blk=a.s_blk,
            locality_ratio=a.s_blk / max(a.seg_count, 1),
            gather_flops_resident=4 * streamed * a.seg_count,
            gather_flops_local=4 * streamed * a.s_blk,
            x_vmem_bytes_resident=a.seg_count * self.l * 4,
            x_vmem_bytes_local=a.s_blk * self.l * 4,
            backend="pallas" if self._use_kernel() else "jnp",
            pipeline=self._pipeline(),
            store_hits=self._store.hits if self._store is not None else 0,
            store_misses=self._store.misses if self._store is not None else 0,
            fallback_kernel=self._fallbacks["kernel"],
            fallback_gather=self._fallbacks["gather"],
            fallback_store=self._fallbacks["store"],
            **{
                f"cache_{k}": v
                for k, v in (
                    self.cache.stats() if self.cache is not None else {}
                ).items()
            },
        )

    def __repr__(self) -> str:
        m, n = self.shape
        packed = "lazy" if self._artifact is None else self.layout
        shard = f", sharded[{self.axis}]" if self.mesh is not None else ""
        return (
            f"GustPlan({m}x{n}, l={self.l}, layout={self.config.layout}"
            f"->{packed}, backend={self.config.backend}{shard})"
        )


# ---------------------------------------------------------------------------
# Distributed execution internals (owned by GustPlan.shard; formerly
# hand-rolled by core.spmv.distributed_spmv).
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _shard_spmv_fn(mesh, axis: str, l: int, c_blk: int, w_max: int):
    """Jitted shard_map program for one (mesh, geometry) — memoized so
    repeated sharded executions reuse jax's trace/compile cache instead of
    paying a fresh closure trace every call."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.collectives import shard_map

    def local(m_blk, r_blk, c_blk_, lw, vec):
        # (1, B_max*cb, l) stream + (1, B_max) local window ids ->
        # per-window segment sum -> (1, W_max * l)
        p = m_blk[0].astype(jnp.float32) * jnp.take(
            vec, c_blk_[0], axis=0, mode="clip"
        )
        window = jnp.repeat(lw[0], c_blk)
        adder = window[:, None] * l + r_blk[0]
        return jax.ops.segment_sum(
            p.reshape(-1), adder.reshape(-1), num_segments=w_max * l
        )[None]

    spec_in = P(axis)  # shard the leading device dim
    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(spec_in, spec_in, spec_in, spec_in, P()),
            out_specs=spec_in,
        )
    )


def _shard_layout(ragged: RaggedSchedule, n_dev: int):
    """Device-major execution layout of a ragged stream for ``n_dev``
    devices: contiguous window ranges balanced by block count, each
    device's blocks padded to the common max.

    Returns ``(m_d, r_d, c_d, lw_d, w_max, idx)`` — the four ``(n_dev,
    ...)`` device arrays for the shard_map, the padded per-device window
    count, and the gather index reassembling the per-device outputs into
    scheduled row order.  Everything here is a pure function of (ragged
    stream, n_dev); :meth:`GustPlan.shard` memoizes it in the
    :class:`ScheduleCache` so repeated executions skip both the host
    assembly and the host->device upload."""
    l, W, cb, t_blk = ragged.l, ragged.num_windows, ragged.c_blk, ragged.num_blocks
    block_starts = np.asarray(ragged.block_starts, np.int64)
    block_window = np.asarray(ragged.block_window, np.int64)

    # Contiguous window boundaries hitting equal block-count targets:
    # device d owns windows [w_bound[d], w_bound[d+1]).
    targets = (np.arange(1, n_dev) * t_blk) // n_dev
    w_bound = np.concatenate(
        [[0], np.searchsorted(block_starts, targets, side="left"), [W]]
    )
    w_bound = np.maximum.accumulate(np.minimum(w_bound, W))
    w_cnt = np.diff(w_bound)
    b_cnt = block_starts[w_bound[1:]] - block_starts[w_bound[:-1]]
    b_max = max(int(b_cnt.max()) if n_dev else 1, 1)
    w_max = max(int(w_cnt.max()) if n_dev else 1, 1)

    # Device-major padded streams; padding blocks keep the packed-format
    # invariants (values 0, columns gather the slot's lane, rows 0) and
    # route to local window 0 — value 0 contributes nothing.
    lane = np.arange(l, dtype=np.int32)
    m_d = np.zeros((n_dev, b_max * cb, l), np.float32)
    r_d = np.zeros((n_dev, b_max * cb, l), np.int32)
    c_d = np.broadcast_to(lane, (n_dev, b_max * cb, l)).copy()
    lw_d = np.zeros((n_dev, b_max), np.int32)
    m_src = np.asarray(ragged.m_blk, np.float32)
    r_src = np.asarray(ragged.row_blk, np.int32)
    c_src = np.asarray(ragged.col_blk, np.int32)
    for d in range(n_dev):
        g0, g1 = int(block_starts[w_bound[d]]), int(block_starts[w_bound[d + 1]])
        rows = (g1 - g0) * cb
        m_d[d, :rows] = m_src[g0 * cb: g1 * cb]
        r_d[d, :rows] = r_src[g0 * cb: g1 * cb]
        c_d[d, :rows] = c_src[g0 * cb: g1 * cb]
        lw_d[d, : g1 - g0] = block_window[g0:g1] - w_bound[d]

    idx = np.concatenate(
        [d * w_max * l + np.arange(w_cnt[d] * l) for d in range(n_dev)]
    ) if W else np.zeros(0, np.int64)
    return (
        jnp.asarray(m_d), jnp.asarray(r_d), jnp.asarray(c_d),
        jnp.asarray(lw_d), w_max, jnp.asarray(idx),
    )


# ---------------------------------------------------------------------------
# Incremental re-planning for drifting sparsity (prune masks, dynamic
# patterns): diff per-window content, recolor only dirty windows, splice
# their packed blocks into the existing stream.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RescheduleResult:
    """What one :func:`reschedule` delta did.

    ``full_fallback`` means the plan was rebuilt from scratch (load-
    balanced config, or no prior fingerprints/source to diff against);
    ``spliced`` means the packed ragged stream was updated in place via
    :func:`~repro.core.packing.splice_ragged_blocks` instead of a full
    repack."""

    windows: int
    dirty_windows: int
    reused_windows: int
    recolored_edges: int
    full_fallback: bool
    spliced: bool

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def reschedule(
    base: GustPlan,
    matrix: Union[np.ndarray, COOMatrix],
    *,
    workers: Optional[int] = None,
    store=None,
) -> GustPlan:
    """Re-plan ``matrix`` incrementally against ``base`` (a plan over the
    previous version of the same matrix).

    Per-window content fingerprints are diffed; only dirty windows are
    recolored (through the same chunked colorer), and — when ``base`` has
    a materialized ragged artifact — only their packed blocks are
    rebuilt, with every clean window's blocks copied bitwise.  The result
    is **bit-identical** to ``plan(matrix, base.config)`` built fresh.

    Incremental reuse requires ``load_balance=False`` (row balancing is a
    global function of the matrix content, so any delta may reassign
    every window); load-balanced configs transparently fall back to a
    full fresh plan, reported via ``.resched.full_fallback``.  Shape
    changes are an error — build a fresh plan.

    The returned plan carries updated fingerprints, so chaining
    ``reschedule(p1, m2)`` → ``reschedule(p2, m3)`` never re-hashes the
    old side.  ``.resched`` holds the delta stats
    (:class:`RescheduleResult`); dirty/reused window totals also
    accumulate in :data:`repro.core.scheduler.sched_counters`."""
    from .scheduler import incremental_schedule, sched_counters

    if not isinstance(base, GustPlan):
        raise TypeError(f"reschedule() needs a GustPlan, got {type(base).__name__}")
    if base.sched is None:
        raise ValueError(
            "reschedule() needs the base plan's schedule; store-loaded/"
            "spec plans carry only the packed artifact — build fresh"
        )
    if isinstance(matrix, (np.ndarray, jax.Array)):
        dense = np.asarray(matrix)
        if dense.ndim != 2:
            raise ValueError(f"dense matrix must be 2-D, got shape {dense.shape}")
        matrix = coo_from_dense(dense)
    if not isinstance(matrix, COOMatrix):
        raise TypeError(
            f"reschedule() takes a dense array or COOMatrix, got "
            f"{type(matrix).__name__}"
        )
    if tuple(matrix.shape) != tuple(base.shape):
        raise ValueError(
            f"reschedule() cannot change the matrix shape "
            f"({tuple(base.shape)} -> {tuple(matrix.shape)}); build a fresh plan"
        )

    cfg = base.config
    W = base.sched.num_windows
    can_diff = base._window_hashes is not None or base._source is not None
    if cfg.load_balance or not can_diff:
        p = plan(matrix, cfg, cache=base.cache, store=store, workers=workers)
        p.resched = RescheduleResult(
            windows=W, dirty_windows=W, reused_windows=0,
            recolored_edges=p.sched.nnz if p.sched is not None else 0,
            full_fallback=True, spliced=False,
        )
        return p

    edges_before = sched_counters["colored_edges"]
    new_sched, dirty, new_hashes = incremental_schedule(
        base.sched,
        matrix,
        old_coo=base._source,
        old_hashes=base._window_hashes,
        method=cfg.colorer,
        workers=workers,
    )
    recolored_edges = sched_counters["colored_edges"] - edges_before

    p = GustPlan(cfg, sched=new_sched, cache=base.cache, source=matrix)
    p._window_hashes = new_hashes
    spliced = False
    if (
        isinstance(base._artifact, RaggedSchedule)
        and p.layout == "ragged"
    ):
        p._artifact = splice_ragged_blocks(
            base._artifact, new_sched, dirty,
            value_dtype=cfg.value_jnp, index_dtype=cfg.index_jnp,
        )
        spliced = True
    if store is not None:
        p._store = store
        p._store_key = store.key(ScheduleCache.matrix_key(matrix), cfg)
        if spliced:
            p._store_put()  # artifact already materialized: write now
    p.resched = RescheduleResult(
        windows=W,
        dirty_windows=int(dirty.size),
        reused_windows=W - int(dirty.size),
        recolored_edges=int(recolored_edges),
        full_fallback=False,
        spliced=spliced,
    )
    return p
