"""Statistical utilization bound (paper §3.4, Eqs. 1-11).

For an N×N uniform-density-p matrix and a length-l GUST, the expected color
count per window is bounded by the expected max of 2l Gaussians:

    E[C]    <= N p + sqrt(2 N p (1-p) log(2 l))                     (Eq. 9)
    E[exec] = (N/l) * E[C] + 2                                      (Eq. 10)
    E[util] = 1 / (1 + sqrt(2 (1-p) log(2l) / (N p)))               (Eq. 11)

(The paper uses natural log — the derivation sets t = sqrt(2 log 2l)/σ.)
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "expected_colors_bound",
    "expected_execution_cycles",
    "expected_utilization",
    "eq1_colors",
]


def expected_colors_bound(n: int, p: float, l: int) -> float:
    """Eq. 9 upper bound on E[C] for one window of an N×N uniform matrix."""
    mu = n * p
    sigma2 = n * p * (1.0 - p)
    return mu + np.sqrt(2.0 * sigma2 * np.log(2.0 * l))


def expected_execution_cycles(n: int, p: float, l: int) -> float:
    """Eq. 10: expected total cycles (N/l windows, +2 pipeline levels)."""
    return (n / l) * expected_colors_bound(n, p, l) + 2.0


def expected_utilization(n: int, p: float, l: int) -> float:
    """Eq. 11 (closed form, drops the +2)."""
    return 1.0 / (1.0 + np.sqrt(2.0 * (1.0 - p) * np.log(2.0 * l) / (n * p)))


def eq1_colors(row_nnz_window: np.ndarray, lane_nnz_window: np.ndarray) -> int:
    """Eq. 1: the König lower bound for one window — max vertex degree of
    the bipartite graph (max row nnz vs max lane nnz)."""
    mr = int(row_nnz_window.max()) if row_nnz_window.size else 0
    ml = int(lane_nnz_window.max()) if lane_nnz_window.size else 0
    return max(mr, ml)
