"""Three-step sort-based load balancing (paper §3.5).

The number of colors a window needs is governed by Eq. 1:

    C_w = max( max_i #NZ(row i),  max_j Σ_b #NZ(column-segment b at lane j) )

so the schedule length is set by the *heaviest* row / lane, not the total
work.  The balancer reduces the spread:

  Step 1: sort matrix rows by #NZ (groups similarly-heavy rows into the same
          window, so no window is held hostage by one dense row mixed with
          empty ones).
  Step 2: within each window, sort the column segments (contiguous blocks of
          ``l`` columns) by their #NZ.
  Step 3: reverse the internal column order of segments at even (1-based)
          sorted positions.  Lane of a column is its intra-segment offset, so
          the reversal flips offsets ``k -> l-1-k`` for alternating segments:
          if heavy segments share a skewed intra-segment distribution, the
          alternation cancels the skew across lanes.  (This matches the
          paper's length-2 example: columns ``1..8`` in segments
          ``(1,2)(3,4)(5,6)(7,8)`` become ``1,2,4,3,5,6,8,7``.)

Only the *lane assignment* changes: ``Col_sch`` always records original
column indices, so the vector gather is untouched.  Step 1 permutes output
rows; the permutation is recorded in ``GustSchedule.row_perm``.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .formats import COOMatrix

__all__ = ["balance_rows", "balance_lanes"]


def balance_rows(coo: COOMatrix) -> Tuple[np.ndarray, np.ndarray]:
    """Step 1.  Returns ``(row_perm, new_rows)`` where
    ``row_perm[scheduled_pos] = original_row`` and ``new_rows`` are the
    per-nonzero scheduled row positions."""
    nnz_per_row = coo.row_nnz()
    # Descending, stable: heavy rows first; ties keep original order.
    row_perm = np.argsort(-nnz_per_row, kind="stable").astype(np.int64)
    inv = np.empty_like(row_perm)
    inv[row_perm] = np.arange(coo.shape[0], dtype=np.int64)
    return row_perm, inv[coo.rows]


def balance_lanes(
    rows_w: np.ndarray, cols: np.ndarray, l: int, n: int
) -> np.ndarray:
    """Steps 2 + 3, applied per window.  ``rows_w`` are *window ids* per
    nonzero (post step-1), ``cols`` original column indices.  Returns the
    lane assignment (0..l-1) per nonzero.

    Default (unbalanced) lane is ``col % l``.  Balancing re-ranks the
    ``ceil(n/l)`` column segments of each window by #NZ and alternately
    reverses intra-segment offsets.
    """
    num_segments = -(-n // l)
    seg = cols // l
    offset = cols - seg * l  # == cols % l

    if rows_w.size == 0:
        return offset.astype(np.int64)

    num_windows = int(rows_w.max()) + 1
    # #NZ per (window, segment)
    flat = rows_w * num_segments + seg
    counts = np.bincount(flat, minlength=num_windows * num_segments).reshape(
        num_windows, num_segments
    )
    # Step 2: rank segments per window by count, descending, stable.
    order = np.argsort(-counts, axis=1, kind="stable")  # rank -> segment
    rank_of = np.empty_like(order)
    rows_idx = np.arange(num_windows)[:, None]
    rank_of[rows_idx, order] = np.arange(num_segments)[None, :]
    # Step 3: even (1-based) sorted positions get reversed internal order.
    ranks = rank_of[rows_w, seg]
    reverse = (ranks % 2) == 1
    lane = np.where(reverse, l - 1 - offset, offset)
    return lane.astype(np.int64)
