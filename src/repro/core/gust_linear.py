"""GustLinear — the paper's technique as a first-class LM feature.

Decode-time LM inference is matvec-dominated: every projection computes
``W @ x`` for a handful of activation vectors.  ``GustLinear`` stores a
magnitude-pruned weight matrix in the GUST scheduled format (schedule
computed once, at weight-load time — paper §3.3/§5.3 amortization) and
executes the matvec through the GUST path (pure-jnp or the Pallas kernel).

Training and prefill stay dense (the paper defers SpMM to future work);
this module is wired into ``serving/`` via ``ArchConfig.sparsity``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .formats import COOMatrix
from .packing import schedule_packed

__all__ = ["SparsityConfig", "GustLinear", "prune_by_magnitude"]


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Serving-time weight-sparsity knobs (off by default)."""

    enable: bool = False
    density: float = 0.1  # fraction of weights kept after magnitude pruning
    gust_length: int = 256
    load_balance: bool = True
    method: str = "fast"  # edge-coloring method
    use_kernel: bool = False  # route through the Pallas kernel


def prune_by_magnitude(w: np.ndarray, density: float) -> np.ndarray:
    """Keep the largest-|w| entries at the requested density."""
    if not 0.0 < density <= 1.0:
        raise ValueError("density must be in (0, 1]")
    k = max(int(round(w.size * density)), 1)
    thresh = np.partition(np.abs(w).ravel(), w.size - k)[w.size - k]
    out = np.where(np.abs(w) >= thresh, w, 0.0)
    return out


class GustLinear:
    """y = W_sparse @ x with W in GUST scheduled format.

    Not a pytree — this is a *serving* artifact built once from trained
    weights (analogous to a compiled engine).  ``__call__`` takes
    ``x: (B, n)`` and returns ``(B, m)``.

    NOTE: construction goes through the process-global content-keyed
    :class:`~repro.core.packing.ScheduleCache`, so the schedule/packed
    arrays outlive this object (bounded by the cache's LRU size).
    Rebuilding a GustLinear over identical weights is then free; call
    :func:`repro.core.packing.clear_cache` to release the memory.
    """

    def __init__(self, w: np.ndarray, cfg: SparsityConfig):
        if w.ndim != 2:
            raise ValueError("GustLinear expects a 2-D weight matrix")
        self.cfg = cfg
        self.shape = w.shape
        w_pruned = prune_by_magnitude(np.asarray(w, np.float32), cfg.density)
        rows, cols = np.nonzero(w_pruned)
        coo = COOMatrix(
            w.shape,
            rows.astype(np.int64),
            cols.astype(np.int64),
            w_pruned[rows, cols].astype(np.float32),
        )
        self.nnz = coo.nnz
        # Schedule AND pack once, at construction (content-keyed cache:
        # rebuilding a GustLinear over identical weights is free).  The
        # packed form is what both execution paths consume.
        self.sched, self.packed = schedule_packed(
            coo, cfg.gust_length, load_balance=cfg.load_balance, method=cfg.method
        )

    @property
    def cycles(self) -> int:
        return self.sched.cycles

    @property
    def hardware_utilization(self) -> float:
        return self.sched.hardware_utilization

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        if x.ndim == 1:
            x = x[None, :]
            squeeze = True
        else:
            squeeze = False
        from repro.kernels import ops as kops

        y = kops.gust_spmm(self.packed, x.T, use_kernel=self.cfg.use_kernel).T
        return y[0] if squeeze else y
