"""GustLinear — the paper's technique as a first-class LM feature.

Decode-time LM inference is matvec-dominated: every projection computes
``W @ x`` for a handful of activation vectors.  ``GustLinear`` stores a
magnitude-pruned weight matrix as a :class:`~repro.core.plan.GustPlan`
(schedule computed once, at weight-load time — paper §3.3/§5.3
amortization) and executes the matvec through the plan's batch-major
``transpose_io`` fast path (no eager ``x.T``/``y.T`` round-trip).

Training and prefill stay dense (the paper defers SpMM to future work);
this module is wired into ``serving/`` via ``ArchConfig.sparsity``.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .formats import COOMatrix
from .packing import default_cache
from .plan import PlanConfig, plan as _plan

__all__ = ["SparsityConfig", "GustLinear", "prune_by_magnitude"]


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Deprecated serving-time weight-sparsity knobs.

    Use :class:`~repro.core.plan.PlanConfig` plus a ``density`` argument:
    ``gust_length`` is spelled ``PlanConfig.l``, ``method`` is
    ``PlanConfig.colorer``, ``use_kernel`` is ``PlanConfig.backend``
    (``"pallas"`` / ``"jnp"``).  Kept as a shim that normalizes to
    :attr:`plan_config`."""

    enable: bool = False
    density: float = 0.1  # fraction of weights kept after magnitude pruning
    gust_length: int = 256
    load_balance: bool = True
    method: str = "fast"  # edge-coloring method
    use_kernel: bool = False  # route through the Pallas kernel

    def __post_init__(self):
        warnings.warn(
            "SparsityConfig is deprecated; use GustLinear(w, "
            "config=PlanConfig(l=..., colorer=..., backend='pallas'|'jnp'), "
            "density=...) — 'gust_length' is spelled 'l', 'method' is "
            "'colorer', 'use_kernel' is backend='pallas'",
            DeprecationWarning,
            stacklevel=3,  # caller -> generated __init__ -> __post_init__
        )

    @property
    def plan_config(self) -> PlanConfig:
        """The normalized spelling of these knobs."""
        return PlanConfig(
            l=self.gust_length,
            colorer=self.method,
            load_balance=self.load_balance,
            layout="padded",
            backend="pallas" if self.use_kernel else "jnp",
            interpret=True,
        )


def prune_by_magnitude(w: np.ndarray, density: float) -> np.ndarray:
    """Keep the largest-|w| entries at the requested density."""
    if not 0.0 < density <= 1.0:
        raise ValueError("density must be in (0, 1]")
    k = max(int(round(w.size * density)), 1)
    thresh = np.partition(np.abs(w).ravel(), w.size - k)[w.size - k]
    out = np.where(np.abs(w) >= thresh, w, 0.0)
    return out


class GustLinear:
    """y = W_sparse @ x with W held as a :class:`GustPlan`.

    Not a pytree — this is a *serving* artifact built once from trained
    weights (analogous to a compiled engine).  ``__call__`` takes
    ``x: (B, n)`` and returns ``(B, m)``.

    Construction: ``GustLinear(w, config=PlanConfig(...), density=0.1)``.
    The legacy positional ``SparsityConfig`` is still accepted and
    normalized through :attr:`SparsityConfig.plan_config`.

    NOTE: construction goes through the process-global content-keyed
    :class:`~repro.core.packing.ScheduleCache`, so the schedule/packed
    arrays outlive this object (bounded by the cache's LRU size).
    Rebuilding a GustLinear over identical weights is then free; call
    :func:`repro.core.packing.clear_cache` to release the memory.
    """

    def __init__(
        self,
        w: np.ndarray,
        cfg: Optional[SparsityConfig] = None,
        *,
        config: Optional[PlanConfig] = None,
        density: Optional[float] = None,
        cache=default_cache,
    ):
        if w.ndim != 2:
            raise ValueError("GustLinear expects a 2-D weight matrix")
        if cfg is not None:
            if config is not None or density is not None:
                raise ValueError(
                    "pass either a legacy SparsityConfig or "
                    "config=PlanConfig(...) + density=..., not both"
                )
            config = cfg.plan_config
            density = cfg.density
        if config is None:
            config = PlanConfig(layout="padded", backend="jnp", interpret=True)
        if density is None:
            density = 0.1
        self.cfg = cfg  # legacy handle (None for plan-config construction)
        self.config = config
        self.density = density
        self.shape = w.shape
        w_pruned = prune_by_magnitude(np.asarray(w, np.float32), density)
        rows, cols = np.nonzero(w_pruned)
        coo = COOMatrix(
            w.shape,
            rows.astype(np.int64),
            cols.astype(np.int64),
            w_pruned[rows, cols].astype(np.float32),
        )
        self.nnz = coo.nnz
        # Plan once, at construction (content-keyed cache: rebuilding a
        # GustLinear over identical weights is free).  Touching .artifact
        # packs eagerly — both execution paths consume the packed form.
        self.plan = _plan(coo, config, cache=cache)
        self.sched = self.plan.sched
        self.packed = self.plan.artifact

    @property
    def cycles(self) -> int:
        return self.sched.cycles

    @property
    def hardware_utilization(self) -> float:
        return self.sched.hardware_utilization

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        if x.ndim == 1:
            x = x[None, :]
            squeeze = True
        else:
            squeeze = False
        # batch-major fast path: both transposes live inside the jitted
        # executor instead of materializing (n, B)/(B, m) copies here
        y = self.plan.spmm(x, transpose_io=True)
        return y[0] if squeeze else y
