"""GUST core: plan/execute API, edge-coloring scheduler, scheduled SpMV,
dataflow models."""

from .formats import COOMatrix, GustSchedule, coo_from_dense, dense_from_coo
from .scheduler import schedule
from .packing import (
    PackedSchedule,
    RaggedSchedule,
    ScheduleCache,
    pack_auto,
    pack_ragged,
    pack_schedule,
    packed_spec,
    ragged_waste_ratio,
    schedule_packed,
)
from .plan import GustPlan, PlanConfig, PlanCost, TuneResult, plan
from .spmv import (
    spmv,
    spmv_scheduled,
    spmm_scheduled,
    spmm_ragged,
    distributed_spmv,
)
from .bounds import (
    expected_colors_bound,
    expected_execution_cycles,
    expected_utilization,
)
from .gust_linear import GustLinear, SparsityConfig, prune_by_magnitude

__all__ = [
    "COOMatrix",
    "GustSchedule",
    "coo_from_dense",
    "dense_from_coo",
    "schedule",
    "GustPlan",
    "PlanConfig",
    "PlanCost",
    "TuneResult",
    "plan",
    "PackedSchedule",
    "RaggedSchedule",
    "ScheduleCache",
    "pack_auto",
    "pack_ragged",
    "pack_schedule",
    "packed_spec",
    "ragged_waste_ratio",
    "schedule_packed",
    "spmv",
    "spmv_scheduled",
    "spmm_scheduled",
    "spmm_ragged",
    "distributed_spmv",
    "expected_colors_bound",
    "expected_execution_cycles",
    "expected_utilization",
    "GustLinear",
    "SparsityConfig",
    "prune_by_magnitude",
]
