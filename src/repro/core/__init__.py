"""GUST core: edge-coloring scheduler, scheduled SpMV, dataflow models."""

from .formats import COOMatrix, GustSchedule, coo_from_dense, dense_from_coo
from .scheduler import schedule
from .packing import (
    PackedSchedule,
    ScheduleCache,
    pack_schedule,
    packed_spec,
    schedule_packed,
)
from .spmv import spmv, spmv_scheduled, spmm_scheduled, distributed_spmv
from .bounds import (
    expected_colors_bound,
    expected_execution_cycles,
    expected_utilization,
)
from .gust_linear import GustLinear, SparsityConfig, prune_by_magnitude

__all__ = [
    "COOMatrix",
    "GustSchedule",
    "coo_from_dense",
    "dense_from_coo",
    "schedule",
    "PackedSchedule",
    "ScheduleCache",
    "pack_schedule",
    "packed_spec",
    "schedule_packed",
    "spmv",
    "spmv_scheduled",
    "spmm_scheduled",
    "distributed_spmv",
    "expected_colors_bound",
    "expected_execution_cycles",
    "expected_utilization",
    "GustLinear",
    "SparsityConfig",
    "prune_by_magnitude",
]
