"""Bipartite-graph edge-coloring scheduler (paper §3.3, Listings 1-2).

Per window (set of ``l`` consecutive scheduled rows) we build a bipartite
multigraph: left vertices = window rows (adders), right vertices = lanes
(multipliers, column mod ``l`` after load balancing), one edge per nonzero.
A proper edge coloring — no two edges sharing a vertex get the same color —
is exactly a collision-free schedule: color = time slot, so no multiplier
consumes two elements in one cycle and no adder receives two partial
products in one cycle.

Three colorers are provided:

  * ``method="paper"`` — the exact greedy of Listing 1: per color, iterate
    left vertices in order, each takes its first remaining edge whose lane
    is unused in the current matching.  Pure Python; used for tests and
    small matrices.
  * ``method="fast"``  — vectorized equivalent: per color round, every
    unmatched row *proposes* its first eligible edge; lane conflicts are
    resolved by row priority; losers re-propose until the matching is
    maximal.  Produces a valid coloring with the same greedy-maximal-
    matching structure, at numpy speed across all windows simultaneously.
  * ``method="exact"`` — optimal Δ-coloring (König) via degree-padding +
    Euler-split recursion.  Beyond-paper option (§Perf); guarantees
    C_w == max-degree, the Eq. 1 lower bound.

All three satisfy: validity, completeness, C_w >= Δ_w (Eq. 1 bound).
"""

from __future__ import annotations

import hashlib
import os
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .formats import COOMatrix, GustSchedule
from .load_balance import balance_lanes, balance_rows

__all__ = [
    "schedule",
    "color_edges_fast",
    "color_edges_paper",
    "color_edges_exact",
    "color_windows_chunked",
    "incremental_schedule",
    "window_fingerprints",
    "resolve_workers",
    "sched_counters",
    "reset_sched_counters",
    "DEFAULT_PARALLEL_MIN_EDGES",
]

#: Host-side observability counters.  ``color_calls`` / ``colored_edges``
#: count invocations of any colorer through :func:`schedule` or
#: :func:`incremental_schedule` — a PlanStore warm start must leave them
#: untouched (the zero-coloring-work gate in ``benchmarks/sched_bench.py``).
#: ``parallel_chunks`` counts chunks actually colored by worker processes
#: (0 when the serial fallback ran), ``windows_recolored`` /
#: ``windows_reused`` track incremental rescheduling.
sched_counters: Dict[str, int] = {
    "color_calls": 0,
    "colored_edges": 0,
    "parallel_chunks": 0,
    "windows_recolored": 0,
    "windows_reused": 0,
}


def reset_sched_counters() -> Dict[str, int]:
    """Zero all scheduler counters; returns the (mutable) counter dict."""
    for k in sched_counters:
        sched_counters[k] = 0
    return sched_counters


#: Below this many edges an automatic (``workers=None``) schedule stays
#: serial: process fan-out + shared-memory setup costs ~tens of ms, which
#: only pays off once coloring itself is in the hundreds-of-ms range.
DEFAULT_PARALLEL_MIN_EDGES = 2_000_000

_ENV_WORKERS = "REPRO_SCHED_WORKERS"


def resolve_workers(workers: Optional[int]) -> int:
    """The one decision point for scheduling concurrency: explicit argument,
    else ``REPRO_SCHED_WORKERS``, else ``os.cpu_count()``."""
    if workers is not None:
        return max(int(workers), 1)
    env = os.environ.get(_ENV_WORKERS, "").strip()
    if env:
        try:
            return max(int(env), 1)
        except ValueError:
            pass
    return max(os.cpu_count() or 1, 1)


# ---------------------------------------------------------------------------
# Edge construction
# ---------------------------------------------------------------------------


def _edge_index_dtype(m: int, n: int, nnz: int, l: int) -> np.dtype:
    """Index-dtype policy for the scheduler's edge arrays: int32 whenever
    every value they hold — row/col indices, nnz, and the globalized keys
    ``win*l + local`` (bounded by ceil(m/l)*l + l) — fits, else int64.
    Halves scheduler peak memory on large (but sub-2G) matrices."""
    num_windows = max(-(-m // l), 1)
    key_bound = num_windows * l + l
    if max(m, n, nnz, key_bound) < np.iinfo(np.int32).max:
        return np.dtype(np.int32)
    return np.dtype(np.int64)


def _build_edges(
    coo: COOMatrix, l: int, load_balance: bool
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (win, row_local, lane, col, val, row_perm) sorted by
    (win, row_local, col) — the LIL order Listing 1 consumes.  Integer
    outputs use :func:`_edge_index_dtype` (int32 when everything fits)."""
    m, n = coo.shape
    idx = _edge_index_dtype(m, n, coo.rows.shape[0], l)
    if load_balance:
        row_perm, new_rows = balance_rows(coo)
        new_rows = new_rows.astype(idx, copy=False)
    else:
        row_perm = np.arange(m, dtype=np.int64)
        new_rows = coo.rows.astype(idx)

    win = new_rows // l
    row_local = new_rows - win * l
    if load_balance:
        lane = balance_lanes(win, coo.cols, l, n).astype(idx, copy=False)
    else:
        lane = (coo.cols % l).astype(idx)

    order = np.lexsort((coo.cols, row_local, win))
    return (
        win[order],
        row_local[order],
        lane[order],
        coo.cols[order].astype(idx),
        coo.vals[order],
        row_perm,
    )


# ---------------------------------------------------------------------------
# Colorers
# ---------------------------------------------------------------------------


def color_edges_paper(row_key: np.ndarray, lane_key: np.ndarray) -> np.ndarray:
    """Listing 1, exact semantics.  ``row_key``/``lane_key`` are globally
    unique per window (caller offsets by window).  Edges must be sorted by
    (row_key, intra-row order).  Returns per-edge colors."""
    e = row_key.shape[0]
    colors = np.full(e, -1, dtype=np.int64)
    # Per-row edge lists (indices into the edge arrays).  ``np.unique``
    # returns rows already ascending, so iterating this list *is* the
    # paper's in-order left-vertex sweep — a ``done`` mask replaces the
    # old per-round ``sorted(dict)`` rebuild (O(rows log rows) per color).
    rows, row_starts = np.unique(row_key, return_index=True)
    bounds = np.append(row_starts, e)
    row_edges = [list(range(bounds[i], bounds[i + 1])) for i in range(rows.shape[0])]
    done = [False] * rows.shape[0]
    remaining = rows.shape[0]
    clr = 0
    while remaining:
        matching = set()
        for i in range(rows.shape[0]):  # iterate left vertices in order
            if done[i]:
                continue
            edges = row_edges[i]
            for pos, eidx in enumerate(edges):
                lk = int(lane_key[eidx])
                if lk not in matching:
                    colors[eidx] = clr
                    matching.add(lk)
                    edges.pop(pos)
                    break  # paper's break: one edge per row per color
            if not edges:
                done[i] = True
                remaining -= 1
        clr += 1
    return colors


def color_edges_fast(row_key: np.ndarray, lane_key: np.ndarray) -> np.ndarray:
    """Vectorized greedy maximal-matching coloring (see module docstring).
    Edges must be sorted by (row_key, intra-row order); keys globally
    unique per window.

    The proposal loop is O(e) per round: candidate indices stay ascending,
    so ``row_key[elig]`` is a sequence of runs and the first edge of each
    run is that row's first eligible edge — a boundary scan replaces the
    old ``np.unique(..., return_index=True)`` sort.  Lane-conflict
    resolution uses an indexed scatter (last write wins on the reversed
    position array == smallest proposal index per lane), which picks the
    same lowest-row winner the old first-occurrence rule picked — colors
    are bit-identical to :func:`_color_edges_fast_reference`."""
    e = row_key.shape[0]
    colors = np.full(e, -1, dtype=np.int64)
    if e == 0:
        return colors
    n_rows = int(row_key.max()) + 1
    n_lanes = int(lane_key.max()) + 1
    alive_idx = np.arange(e, dtype=np.int64)  # sorted by (row, order)
    lane_min_pos = np.empty(n_lanes, dtype=np.int64)  # scratch, per proposal round
    clr = 0
    while alive_idx.size:
        lane_busy = np.zeros(n_lanes, dtype=bool)
        row_done = np.zeros(n_rows, dtype=bool)
        cand = alive_idx
        while cand.size:
            elig = cand[~row_done[row_key[cand]] & ~lane_busy[lane_key[cand]]]
            if elig.size == 0:
                break
            # First eligible edge per row: elig is ascending, edges are
            # row-order sorted, so run starts in row_key[elig] are exactly
            # the first eligible edge per row.
            rk = row_key[elig]
            head = np.empty(elig.size, dtype=bool)
            head[0] = True
            np.not_equal(rk[1:], rk[:-1], out=head[1:])
            proposals = elig[head]
            # Lane conflicts: lower row wins (proposals are row-ascending).
            # Writing positions in reverse makes the *smallest* position
            # per lane the surviving write.
            lk = lane_key[proposals]
            pos = np.arange(proposals.size, dtype=np.int64)
            lane_min_pos[lk[::-1]] = pos[::-1]
            winners = proposals[lane_min_pos[lk] == pos]
            colors[winners] = clr
            lane_busy[lane_key[winners]] = True
            row_done[row_key[winners]] = True
            if winners.size == proposals.size:
                # every proposing row matched; remaining rows had no
                # eligible edge at proposal time -> re-scan survivors once
                cand = elig if elig.size > winners.size else np.empty(0, np.int64)
            else:
                cand = elig  # losers re-propose against updated busy sets
        alive_idx = alive_idx[colors[alive_idx] < 0]
        clr += 1
    return colors


def _color_edges_fast_reference(row_key: np.ndarray, lane_key: np.ndarray) -> np.ndarray:
    """Pre-PR-7 ``color_edges_fast`` inner loop (np.unique-based selection).
    Kept as the bit-identity oracle for the O(e) rewrite and as the serial
    baseline in ``benchmarks/sched_bench.py``."""
    e = row_key.shape[0]
    colors = np.full(e, -1, dtype=np.int64)
    if e == 0:
        return colors
    n_rows = int(row_key.max()) + 1
    n_lanes = int(lane_key.max()) + 1
    alive_idx = np.arange(e, dtype=np.int64)  # sorted by (row, order)
    clr = 0
    while alive_idx.size:
        lane_busy = np.zeros(n_lanes, dtype=bool)
        row_done = np.zeros(n_rows, dtype=bool)
        cand = alive_idx
        while cand.size:
            elig = cand[~row_done[row_key[cand]] & ~lane_busy[lane_key[cand]]]
            if elig.size == 0:
                break
            # First eligible edge per row (edges are row-order sorted).
            _, first = np.unique(row_key[elig], return_index=True)
            proposals = elig[first]
            # Lane conflicts: lower row wins (proposals are row-ascending).
            _, keep = np.unique(lane_key[proposals], return_index=True)
            winners = proposals[keep]
            colors[winners] = clr
            lane_busy[lane_key[winners]] = True
            row_done[row_key[winners]] = True
            if winners.size == proposals.size:
                cand = elig if elig.size > winners.size else np.empty(0, np.int64)
            else:
                cand = elig  # losers re-propose against updated busy sets
        alive_idx = alive_idx[colors[alive_idx] < 0]
        clr += 1
    return colors


def _euler_split(row_key: np.ndarray, lane_key: np.ndarray) -> np.ndarray:
    """Split a bipartite multigraph with all even degrees into two halves of
    equal degree by 2-coloring edges along Eulerian circuits.  Returns a
    0/1 label per edge."""
    e = row_key.shape[0]
    label = np.empty(e, dtype=np.int8)
    # adjacency: node -> list of (edge, other)  (bipartite: offset lanes)
    n_rows = int(row_key.max()) + 1 if e else 0
    lanes_off = lane_key + n_rows
    n_nodes = int(lanes_off.max()) + 1 if e else 0
    adj_head = np.full(n_nodes, -1, dtype=np.int64)
    nxt = np.empty(2 * e, dtype=np.int64)
    ends = np.empty(2 * e, dtype=np.int64)  # node at the far end of half-edge
    eid = np.empty(2 * e, dtype=np.int64)
    for k in range(e):  # build linked adjacency (both directions)
        for half, (a, b) in enumerate(((row_key[k], lanes_off[k]), (lanes_off[k], row_key[k]))):
            h = 2 * k + half
            nxt[h] = adj_head[a]
            adj_head[a] = h
            ends[h] = b
            eid[h] = k
    used = np.zeros(e, dtype=bool)
    for start in range(n_nodes):
        while adj_head[start] != -1 and used[eid[adj_head[start]]]:
            adj_head[start] = nxt[adj_head[start]]
        if adj_head[start] == -1:
            continue
        node, parity = start, 0
        while True:
            h = adj_head[node]
            while h != -1 and used[eid[h]]:
                h = nxt[h]
            adj_head[node] = h
            if h == -1:
                break
            k = eid[h]
            used[k] = True
            label[k] = parity
            parity ^= 1
            node = ends[h]
    return label


def _perfect_matching_regular(
    row_key: np.ndarray, lane_key: np.ndarray, n: int
) -> np.ndarray:
    """Perfect matching of a d-regular bipartite multigraph with ``n`` nodes
    per side (exists by Hall's theorem).  Hopcroft-Karp.  Returns the edge
    index matched to each left node, shape (n,)."""
    order = np.argsort(row_key, kind="stable")
    starts = np.searchsorted(row_key[order], np.arange(n + 1))
    INF = 1 << 60
    match_l = np.full(n, -1, dtype=np.int64)  # left  -> edge idx
    match_r = np.full(n, -1, dtype=np.int64)  # right -> left node
    while True:
        # BFS layers over free left nodes.
        dist = np.full(n, INF, dtype=np.int64)
        queue = [u for u in range(n) if match_l[u] == -1]
        for u in queue:
            dist[u] = 0
        found = False
        qi = 0
        while qi < len(queue):
            u = queue[qi]
            qi += 1
            for ei in order[starts[u] : starts[u + 1]]:
                w = match_r[lane_key[ei]]
                if w == -1:
                    found = True
                elif dist[w] == INF:
                    dist[w] = dist[u] + 1
                    queue.append(w)
        if not found:
            break

        def dfs(u: int) -> bool:
            for ei in order[starts[u] : starts[u + 1]]:
                v = lane_key[ei]
                w = match_r[v]
                if w == -1 or (dist[w] == dist[u] + 1 and dfs(w)):
                    match_l[u] = ei
                    match_r[v] = u
                    return True
            dist[u] = INF
            return False

        for u in range(n):
            if match_l[u] == -1:
                dfs(u)
    if (match_l < 0).any():
        raise AssertionError("regular bipartite graph must have a perfect matching")
    return match_l


def color_edges_exact(row_key: np.ndarray, lane_key: np.ndarray) -> np.ndarray:
    """Optimal Δ-edge-coloring of the bipartite multigraph (König theorem:
    chromatic index of a bipartite multigraph equals its max degree Δ).

    Classical scheme: Δ-regularize with dummy edges, then peel — if the
    current regular degree d is odd, extract a perfect matching (one color)
    and recurse on d-1; if even, Euler-split into two d/2-regular halves.
    Real edges receive exactly Δ colors."""
    e = row_key.shape[0]
    if e == 0:
        return np.empty(0, dtype=np.int64)
    n_rows = int(row_key.max()) + 1
    n_lanes = int(lane_key.max()) + 1
    n = max(n_rows, n_lanes)
    deg_r = np.bincount(row_key, minlength=n)
    deg_l = np.bincount(lane_key, minlength=n)
    delta = int(max(deg_r.max(), deg_l.max()))
    # Δ-regularize: both sides have n nodes, so stub counts match exactly.
    pad_r = np.repeat(np.arange(n, dtype=np.int64), delta - deg_r)
    pad_l = np.repeat(np.arange(n, dtype=np.int64), delta - deg_l)
    assert pad_r.size == pad_l.size == n * delta - e
    rk = np.concatenate([row_key.astype(np.int64), pad_r])
    lk = np.concatenate([lane_key.astype(np.int64), pad_l])
    total = rk.shape[0]
    colors = np.full(total, -1, dtype=np.int64)
    next_color = [0]

    def rec(idx: np.ndarray, d: int):
        if idx.size == 0 or d == 0:
            return
        if d == 1:
            colors[idx] = next_color[0]
            next_color[0] += 1
            return
        if d % 2 == 1:
            sub_match = _perfect_matching_regular(rk[idx], lk[idx], n)
            colors[idx[sub_match]] = next_color[0]
            next_color[0] += 1
            keep = np.ones(idx.size, dtype=bool)
            keep[sub_match] = False
            rec(idx[keep], d - 1)
        else:
            lab = _euler_split(rk[idx], lk[idx])
            rec(idx[lab == 0], d // 2)
            rec(idx[lab == 1], d // 2)

    rec(np.arange(total, dtype=np.int64), delta)
    out = colors[:e]
    assert out.min() >= 0 and out.max() < delta
    return out


_COLORERS = {
    "paper": color_edges_paper,
    "fast": color_edges_fast,
    "exact": color_edges_exact,
}


# ---------------------------------------------------------------------------
# Parallel window-chunked coloring
# ---------------------------------------------------------------------------
#
# Windows are independent coloring problems: globalized keys (win*l + local)
# never collide across windows, and every window's edges receive colors
# 0..C_w-1 regardless of what other windows contain.  Coloring a contiguous
# run of whole windows in one process therefore produces *bit-identical*
# colors to the serial pass — chunk boundaries only have to land on window
# boundaries.  Workers attach a shared int64 buffer holding
# (row_key, lane_key, colors-out), so the only per-chunk IPC is five ints.


def _chunk_bounds(
    win: np.ndarray, num_windows: int, n_chunks: int
) -> Sequence[Tuple[int, int, int]]:
    """Split the edge stream into <= ``n_chunks`` contiguous, window-aligned
    ranges with roughly equal edge counts.  Returns (start, stop, first_win)
    edge-index triples; empty ranges are dropped."""
    e = win.shape[0]
    # Edge offset of each window boundary.
    w_off = np.searchsorted(win, np.arange(num_windows + 1))
    targets = (np.arange(1, n_chunks) * e) // n_chunks
    cut_wins = np.unique(
        np.concatenate(
            [[0], np.searchsorted(w_off, targets, side="left"), [num_windows]]
        )
    )
    cut_wins = cut_wins[cut_wins <= num_windows]
    bounds = []
    for i in range(cut_wins.shape[0] - 1):
        s, t = int(w_off[cut_wins[i]]), int(w_off[cut_wins[i + 1]])
        if t > s:
            bounds.append((s, t, int(cut_wins[i])))
    return bounds


def _color_chunk_worker(shm_name: str, e: int, s: int, t: int, base: int) -> int:
    """Color edges [s, t) of the shared (3, e) buffer in place.  ``base``
    re-localizes the globalized keys so scratch arrays are chunk-sized."""
    from multiprocessing import shared_memory

    shm = shared_memory.SharedMemory(name=shm_name)
    try:
        buf = np.ndarray((3, e), dtype=np.int64, buffer=shm.buf)
        buf[2, s:t] = color_edges_fast(buf[0, s:t] - base, buf[1, s:t] - base)
    finally:
        shm.close()
    return s


def color_windows_chunked(
    row_key: np.ndarray,
    lane_key: np.ndarray,
    win: np.ndarray,
    num_windows: int,
    l: int,
    *,
    workers: Optional[int] = None,
    min_edges: Optional[int] = None,
) -> np.ndarray:
    """Fast coloring with window-chunked process parallelism.

    Bit-identical to ``color_edges_fast(row_key, lane_key)`` by window
    independence (see section comment).  Falls back to the serial colorer
    when parallelism can't help (one worker, too few edges or windows) or
    can't run (no fork start method, shared memory unavailable) — an
    explicit ``workers >= 2`` skips the ``min_edges`` threshold so small
    inputs can exercise the parallel path deterministically."""
    e = row_key.shape[0]
    n_workers = resolve_workers(workers)
    if min_edges is None:
        min_edges = DEFAULT_PARALLEL_MIN_EDGES if workers is None else 0
    if n_workers < 2 or e == 0 or e < min_edges or num_windows < 2:
        return color_edges_fast(row_key, lane_key)

    import multiprocessing as mp

    if "fork" not in mp.get_all_start_methods():
        # spawn would re-import the caller's __main__; not worth the risk
        # for a pure perf path — the serial colorer is always correct.
        return color_edges_fast(row_key, lane_key)

    chunks = _chunk_bounds(win, num_windows, n_chunks=n_workers)
    if len(chunks) < 2:
        return color_edges_fast(row_key, lane_key)

    from concurrent.futures import ProcessPoolExecutor
    from multiprocessing import shared_memory

    try:
        shm = shared_memory.SharedMemory(create=True, size=3 * e * 8)
    except Exception:
        return color_edges_fast(row_key, lane_key)
    try:
        buf = np.ndarray((3, e), dtype=np.int64, buffer=shm.buf)
        np.copyto(buf[0], row_key, casting="safe")
        np.copyto(buf[1], lane_key, casting="safe")
        ctx = mp.get_context("fork")
        with ProcessPoolExecutor(max_workers=len(chunks), mp_context=ctx) as pool:
            futures = [
                pool.submit(_color_chunk_worker, shm.name, e, s, t, base * l)
                for (s, t, base) in chunks
            ]
            for f in futures:
                f.result()
        colors = buf[2].copy()
        sched_counters["parallel_chunks"] += len(chunks)
        return colors
    except Exception:
        return color_edges_fast(row_key, lane_key)
    finally:
        shm.close()
        shm.unlink()


def _color_edges(
    method: str,
    win: np.ndarray,
    row_local: np.ndarray,
    lane: np.ndarray,
    num_windows: int,
    l: int,
    workers: Optional[int],
) -> np.ndarray:
    """Dispatch to the requested colorer over an edge stream sorted by
    (win, row_local, col); counts the call in :data:`sched_counters`."""
    e = win.shape[0]
    sched_counters["color_calls"] += 1
    sched_counters["colored_edges"] += int(e)
    if method == "exact":
        # Per-window exact coloring (windows are independent graphs).
        colors = np.empty(e, dtype=np.int64)
        w_ids, w_starts = np.unique(win, return_index=True)
        bounds = np.append(w_starts, e)
        for i in range(w_ids.shape[0]):
            s, t = bounds[i], bounds[i + 1]
            colors[s:t] = color_edges_exact(row_local[s:t], lane[s:t])
        return colors
    # Globalized keys let one pass color every window at once (the index
    # dtype policy guarantees win*l + local fits the edge dtype).
    row_key = win * l + row_local
    lane_key = win * l + lane
    if method == "fast":
        return color_windows_chunked(
            row_key, lane_key, win, num_windows, l, workers=workers
        )
    return _COLORERS[method](row_key, lane_key)


# ---------------------------------------------------------------------------
# Full scheduling pipeline (Listing 1 + Listing 2)
# ---------------------------------------------------------------------------


def _alloc_tables(c_total: int, l: int, value_dtype):
    """Listing 2 output tables, padding-initialized: value 0, row 0, and
    col == lane.  Padding slots gather v[lane] and multiply by 0 — always
    safe: the execution paths zero-pad v to ceil(n/l)*l (jnp.take clamps
    when not), and col==lane preserves the lane structure the fused kernel
    needs."""
    rows = max(c_total, 1)
    m_sch = np.zeros((rows, l), dtype=value_dtype)
    row_sch = np.zeros((rows, l), dtype=np.int32)
    col_sch = np.tile(np.arange(l, dtype=np.int32), (rows, 1))
    valid = np.zeros((rows, l), dtype=bool)
    return m_sch, row_sch, col_sch, valid


def schedule(
    coo: COOMatrix,
    l: int,
    *,
    load_balance: bool = True,
    method: str = "fast",
    value_dtype=np.float32,
    workers: Optional[int] = None,
) -> GustSchedule:
    """Preprocess a sparse matrix into the GUST scheduled format.

    ``workers`` controls window-chunked parallel coloring for
    ``method="fast"`` (None = auto: ``REPRO_SCHED_WORKERS`` else cpu count,
    applied only above :data:`DEFAULT_PARALLEL_MIN_EDGES` edges).  The
    schedule is bit-identical for every worker count, so ``workers`` is
    *not* part of any cache or store key."""
    if method not in _COLORERS:
        raise ValueError(f"unknown coloring method {method!r}")
    m, n = coo.shape
    num_windows = max(-(-m // l), 1)

    win, row_local, lane, col, val, row_perm = _build_edges(coo, l, load_balance)
    e = win.shape[0]

    if e:
        colors = _color_edges(method, win, row_local, lane, num_windows, l, workers)
    else:
        colors = np.empty(0, dtype=np.int64)

    # Colors per window -> global cycle offsets.
    colors_per_window = np.zeros(num_windows, dtype=np.int64)
    if e:
        np.maximum.at(colors_per_window, win, colors + 1)
    window_starts = np.zeros(num_windows + 1, dtype=np.int64)
    np.cumsum(colors_per_window, out=window_starts[1:])
    c_total = int(window_starts[-1])

    # Listing 2: materialize M_sch / Row_sch / Col_sch.
    m_sch, row_sch, col_sch, valid = _alloc_tables(c_total, l, value_dtype)
    if e:
        gcycle = window_starts[win] + colors
        if valid[gcycle, lane].any() or np.unique(gcycle * l + lane).size != e:
            raise AssertionError("collision in schedule — invalid coloring")
        m_sch[gcycle, lane] = val.astype(value_dtype)
        row_sch[gcycle, lane] = row_local.astype(np.int32)
        col_sch[gcycle, lane] = col.astype(np.int32)
        valid[gcycle, lane] = True

    return GustSchedule(
        l=l,
        shape=(m, n),
        nnz=e,
        m_sch=m_sch,
        row_sch=row_sch,
        col_sch=col_sch,
        window_starts=window_starts,
        row_perm=row_perm,
        valid=valid,
    )


# ---------------------------------------------------------------------------
# Incremental re-scheduling (dirty-window re-coloring)
# ---------------------------------------------------------------------------


def _window_hashes(
    win: np.ndarray,
    row_local: np.ndarray,
    col: np.ndarray,
    val: np.ndarray,
    num_windows: int,
) -> np.ndarray:
    """sha1 fingerprint of each window's edge content.  Hashed over
    canonical dtypes (int64 indices, float64 values) so the fingerprint is
    independent of the edge-array index-dtype policy."""
    e = win.shape[0]
    bounds = np.searchsorted(win, np.arange(num_windows + 1))
    rl64 = np.ascontiguousarray(row_local, dtype=np.int64)
    c64 = np.ascontiguousarray(col, dtype=np.int64)
    v64 = np.ascontiguousarray(val, dtype=np.float64)
    out = np.empty(num_windows, dtype="S20")
    for w in range(num_windows):
        s, t = int(bounds[w]), int(bounds[w + 1])
        h = hashlib.sha1()
        h.update(rl64[s:t].tobytes())
        h.update(c64[s:t].tobytes())
        h.update(v64[s:t].tobytes())
        out[w] = h.digest()
    return out


def window_fingerprints(coo: COOMatrix, l: int) -> np.ndarray:
    """Per-window content fingerprints under the ``load_balance=False``
    window assignment (win = row // l) — the diff key for
    :func:`incremental_schedule`."""
    win, row_local, _, col, val, _ = _build_edges(coo, l, False)
    num_windows = max(-(-coo.shape[0] // l), 1)
    return _window_hashes(win, row_local, col, val, num_windows)


def _ranges(starts: np.ndarray, lengths: np.ndarray) -> np.ndarray:
    """Concatenation of arange(start, start+length) per pair — vectorized
    multi-slice index construction."""
    lengths = np.asarray(lengths, dtype=np.int64)
    total = int(lengths.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    out = np.repeat(np.asarray(starts, dtype=np.int64), lengths)
    resets = np.repeat(np.cumsum(lengths) - lengths, lengths)
    return out + (np.arange(total, dtype=np.int64) - resets)


def incremental_schedule(
    old_sched: GustSchedule,
    new_coo: COOMatrix,
    *,
    old_coo: Optional[COOMatrix] = None,
    old_hashes: Optional[np.ndarray] = None,
    method: str = "fast",
    workers: Optional[int] = None,
) -> Tuple[GustSchedule, np.ndarray, np.ndarray]:
    """Re-schedule ``new_coo`` reusing ``old_sched`` wherever possible.

    Diffs per-window content fingerprints, recolors only the dirty
    windows, and splices their cycles into a fresh global table; clean
    windows' schedule rows are copied verbatim.  Because windows are
    independent coloring problems, the result is **bit-identical** to a
    fresh ``schedule(new_coo, l, load_balance=False, method=...)``.

    Only valid for ``load_balance=False`` schedules: row balancing is a
    global function of the whole matrix, so any content change could
    reassign every window.  ``old_sched.row_perm`` must be the identity.

    Returns ``(new_sched, dirty_windows, new_hashes)``; pass ``new_hashes``
    back as ``old_hashes`` on the next delta to skip re-hashing the old
    side.  Counts windows in ``sched_counters`` (windows_recolored /
    windows_reused)."""
    if method not in _COLORERS:
        raise ValueError(f"unknown coloring method {method!r}")
    l = old_sched.l
    m, n = old_sched.shape
    if tuple(new_coo.shape) != (m, n):
        raise ValueError(
            f"incremental_schedule: shape changed {old_sched.shape} -> "
            f"{tuple(new_coo.shape)}; build a fresh plan instead"
        )
    if not np.array_equal(old_sched.row_perm, np.arange(m)):
        raise ValueError(
            "incremental_schedule requires a load_balance=False schedule "
            "(row_perm must be identity)"
        )
    num_windows = old_sched.num_windows

    win, row_local, lane, col, val, row_perm = _build_edges(new_coo, l, False)
    e = win.shape[0]
    new_hashes = _window_hashes(win, row_local, col, val, num_windows)
    if old_hashes is None:
        if old_coo is None:
            raise ValueError("incremental_schedule needs old_coo or old_hashes")
        old_hashes = window_fingerprints(old_coo, l)
    old_hashes = np.asarray(old_hashes)
    if old_hashes.shape != new_hashes.shape:
        raise ValueError("old_hashes has wrong window count")

    dirty_mask = old_hashes != new_hashes
    dirty = np.nonzero(dirty_mask)[0]
    clean = np.nonzero(~dirty_mask)[0]
    sched_counters["windows_recolored"] += int(dirty.size)
    sched_counters["windows_reused"] += int(clean.size)

    # --- recolor dirty windows only -------------------------------------
    edge_dirty = dirty_mask[win]
    d_idx = np.nonzero(edge_dirty)[0]
    cpw_old = np.diff(old_sched.window_starts)
    cpw_new = cpw_old.copy()
    cpw_new[dirty] = 0  # dirty windows that became empty stay at 0 colors
    if d_idx.size:
        colors_d = _color_edges(
            method,
            win[d_idx],
            row_local[d_idx],
            lane[d_idx],
            num_windows,
            l,
            workers,
        )
        np.maximum.at(cpw_new, win[d_idx], colors_d + 1)

    window_starts = np.zeros(num_windows + 1, dtype=np.int64)
    np.cumsum(cpw_new, out=window_starts[1:])
    c_total = int(window_starts[-1])

    # --- splice: copy clean windows' rows, scatter dirty edges ----------
    m_sch, row_sch, col_sch, valid = _alloc_tables(c_total, l, old_sched.m_sch.dtype)
    if clean.size:
        src = _ranges(old_sched.window_starts[clean], cpw_old[clean])
        dst = _ranges(window_starts[clean], cpw_old[clean])
        m_sch[dst] = old_sched.m_sch[src]
        row_sch[dst] = old_sched.row_sch[src]
        col_sch[dst] = old_sched.col_sch[src]
        valid[dst] = old_sched.valid[src]
    if d_idx.size:
        lane_d = lane[d_idx]
        gcycle = window_starts[win[d_idx]] + colors_d
        if valid[gcycle, lane_d].any() or np.unique(gcycle * l + lane_d).size != d_idx.size:
            raise AssertionError("collision in incremental schedule")
        m_sch[gcycle, lane_d] = val[d_idx].astype(old_sched.m_sch.dtype)
        row_sch[gcycle, lane_d] = row_local[d_idx].astype(np.int32)
        col_sch[gcycle, lane_d] = col[d_idx].astype(np.int32)
        valid[gcycle, lane_d] = True

    new_sched = GustSchedule(
        l=l,
        shape=(m, n),
        nnz=e,
        m_sch=m_sch,
        row_sch=row_sch,
        col_sch=col_sch,
        window_starts=window_starts,
        row_perm=row_perm,
        valid=valid,
    )
    return new_sched, dirty, new_hashes
