"""Bipartite-graph edge-coloring scheduler (paper §3.3, Listings 1-2).

Per window (set of ``l`` consecutive scheduled rows) we build a bipartite
multigraph: left vertices = window rows (adders), right vertices = lanes
(multipliers, column mod ``l`` after load balancing), one edge per nonzero.
A proper edge coloring — no two edges sharing a vertex get the same color —
is exactly a collision-free schedule: color = time slot, so no multiplier
consumes two elements in one cycle and no adder receives two partial
products in one cycle.

Three colorers are provided:

  * ``method="paper"`` — the exact greedy of Listing 1: per color, iterate
    left vertices in order, each takes its first remaining edge whose lane
    is unused in the current matching.  Pure Python; used for tests and
    small matrices.
  * ``method="fast"``  — vectorized equivalent: per color round, every
    unmatched row *proposes* its first eligible edge; lane conflicts are
    resolved by row priority; losers re-propose until the matching is
    maximal.  Produces a valid coloring with the same greedy-maximal-
    matching structure, at numpy speed across all windows simultaneously.
  * ``method="exact"`` — optimal Δ-coloring (König) via degree-padding +
    Euler-split recursion.  Beyond-paper option (§Perf); guarantees
    C_w == max-degree, the Eq. 1 lower bound.

All three satisfy: validity, completeness, C_w >= Δ_w (Eq. 1 bound).
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .formats import COOMatrix, GustSchedule
from .load_balance import balance_lanes, balance_rows

__all__ = ["schedule", "color_edges_fast", "color_edges_paper", "color_edges_exact"]


# ---------------------------------------------------------------------------
# Edge construction
# ---------------------------------------------------------------------------


def _build_edges(
    coo: COOMatrix, l: int, load_balance: bool
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (win, row_local, lane, col, val, row_perm) sorted by
    (win, row_local, col) — the LIL order Listing 1 consumes."""
    m, n = coo.shape
    if load_balance:
        row_perm, new_rows = balance_rows(coo)
    else:
        row_perm = np.arange(m, dtype=np.int64)
        new_rows = coo.rows.astype(np.int64)

    win = new_rows // l
    row_local = new_rows - win * l
    if load_balance:
        lane = balance_lanes(win, coo.cols, l, n)
    else:
        lane = (coo.cols % l).astype(np.int64)

    order = np.lexsort((coo.cols, row_local, win))
    return (
        win[order],
        row_local[order],
        lane[order],
        coo.cols[order].astype(np.int64),
        coo.vals[order],
        row_perm,
    )


# ---------------------------------------------------------------------------
# Colorers
# ---------------------------------------------------------------------------


def color_edges_paper(row_key: np.ndarray, lane_key: np.ndarray) -> np.ndarray:
    """Listing 1, exact semantics.  ``row_key``/``lane_key`` are globally
    unique per window (caller offsets by window).  Edges must be sorted by
    (row_key, intra-row order).  Returns per-edge colors."""
    e = row_key.shape[0]
    colors = np.full(e, -1, dtype=np.int64)
    # Per-row edge lists (indices into the edge arrays).
    rows, row_starts = np.unique(row_key, return_index=True)
    row_edges = {}
    bounds = np.append(row_starts, e)
    for i, r in enumerate(rows):
        row_edges[int(r)] = list(range(bounds[i], bounds[i + 1]))
    clr = 0
    while row_edges:
        matching = set()
        done_rows = []
        for r in sorted(row_edges):  # iterate left vertices in order
            edges = row_edges[r]
            for pos, eidx in enumerate(edges):
                lk = int(lane_key[eidx])
                if lk not in matching:
                    colors[eidx] = clr
                    matching.add(lk)
                    edges.pop(pos)
                    break  # paper's break: one edge per row per color
            if not edges:
                done_rows.append(r)
        for r in done_rows:
            del row_edges[r]
        clr += 1
    return colors


def color_edges_fast(row_key: np.ndarray, lane_key: np.ndarray) -> np.ndarray:
    """Vectorized greedy maximal-matching coloring (see module docstring).
    Edges must be sorted by (row_key, intra-row order); keys globally
    unique per window."""
    e = row_key.shape[0]
    colors = np.full(e, -1, dtype=np.int64)
    if e == 0:
        return colors
    n_rows = int(row_key.max()) + 1
    n_lanes = int(lane_key.max()) + 1
    alive_idx = np.arange(e, dtype=np.int64)  # sorted by (row, order)
    clr = 0
    while alive_idx.size:
        lane_busy = np.zeros(n_lanes, dtype=bool)
        row_done = np.zeros(n_rows, dtype=bool)
        cand = alive_idx
        while cand.size:
            elig = cand[~row_done[row_key[cand]] & ~lane_busy[lane_key[cand]]]
            if elig.size == 0:
                break
            # First eligible edge per row (edges are row-order sorted).
            _, first = np.unique(row_key[elig], return_index=True)
            proposals = elig[first]
            # Lane conflicts: lower row wins (proposals are row-ascending).
            _, keep = np.unique(lane_key[proposals], return_index=True)
            winners = proposals[keep]
            colors[winners] = clr
            lane_busy[lane_key[winners]] = True
            row_done[row_key[winners]] = True
            if winners.size == proposals.size:
                # every proposing row matched; remaining rows had no
                # eligible edge at proposal time -> re-scan survivors once
                cand = elig if elig.size > winners.size else np.empty(0, np.int64)
            else:
                cand = elig  # losers re-propose against updated busy sets
        alive_idx = alive_idx[colors[alive_idx] < 0]
        clr += 1
    return colors


def _euler_split(row_key: np.ndarray, lane_key: np.ndarray) -> np.ndarray:
    """Split a bipartite multigraph with all even degrees into two halves of
    equal degree by 2-coloring edges along Eulerian circuits.  Returns a
    0/1 label per edge."""
    e = row_key.shape[0]
    label = np.empty(e, dtype=np.int8)
    # adjacency: node -> list of (edge, other)  (bipartite: offset lanes)
    n_rows = int(row_key.max()) + 1 if e else 0
    lanes_off = lane_key + n_rows
    n_nodes = int(lanes_off.max()) + 1 if e else 0
    adj_head = np.full(n_nodes, -1, dtype=np.int64)
    nxt = np.empty(2 * e, dtype=np.int64)
    ends = np.empty(2 * e, dtype=np.int64)  # node at the far end of half-edge
    eid = np.empty(2 * e, dtype=np.int64)
    for k in range(e):  # build linked adjacency (both directions)
        for half, (a, b) in enumerate(((row_key[k], lanes_off[k]), (lanes_off[k], row_key[k]))):
            h = 2 * k + half
            nxt[h] = adj_head[a]
            adj_head[a] = h
            ends[h] = b
            eid[h] = k
    used = np.zeros(e, dtype=bool)
    for start in range(n_nodes):
        while adj_head[start] != -1 and used[eid[adj_head[start]]]:
            adj_head[start] = nxt[adj_head[start]]
        if adj_head[start] == -1:
            continue
        node, parity = start, 0
        while True:
            h = adj_head[node]
            while h != -1 and used[eid[h]]:
                h = nxt[h]
            adj_head[node] = h
            if h == -1:
                break
            k = eid[h]
            used[k] = True
            label[k] = parity
            parity ^= 1
            node = ends[h]
    return label


def _perfect_matching_regular(
    row_key: np.ndarray, lane_key: np.ndarray, n: int
) -> np.ndarray:
    """Perfect matching of a d-regular bipartite multigraph with ``n`` nodes
    per side (exists by Hall's theorem).  Hopcroft-Karp.  Returns the edge
    index matched to each left node, shape (n,)."""
    order = np.argsort(row_key, kind="stable")
    starts = np.searchsorted(row_key[order], np.arange(n + 1))
    INF = 1 << 60
    match_l = np.full(n, -1, dtype=np.int64)  # left  -> edge idx
    match_r = np.full(n, -1, dtype=np.int64)  # right -> left node
    while True:
        # BFS layers over free left nodes.
        dist = np.full(n, INF, dtype=np.int64)
        queue = [u for u in range(n) if match_l[u] == -1]
        for u in queue:
            dist[u] = 0
        found = False
        qi = 0
        while qi < len(queue):
            u = queue[qi]
            qi += 1
            for ei in order[starts[u] : starts[u + 1]]:
                w = match_r[lane_key[ei]]
                if w == -1:
                    found = True
                elif dist[w] == INF:
                    dist[w] = dist[u] + 1
                    queue.append(w)
        if not found:
            break

        def dfs(u: int) -> bool:
            for ei in order[starts[u] : starts[u + 1]]:
                v = lane_key[ei]
                w = match_r[v]
                if w == -1 or (dist[w] == dist[u] + 1 and dfs(w)):
                    match_l[u] = ei
                    match_r[v] = u
                    return True
            dist[u] = INF
            return False

        for u in range(n):
            if match_l[u] == -1:
                dfs(u)
    if (match_l < 0).any():
        raise AssertionError("regular bipartite graph must have a perfect matching")
    return match_l


def color_edges_exact(row_key: np.ndarray, lane_key: np.ndarray) -> np.ndarray:
    """Optimal Δ-edge-coloring of the bipartite multigraph (König theorem:
    chromatic index of a bipartite multigraph equals its max degree Δ).

    Classical scheme: Δ-regularize with dummy edges, then peel — if the
    current regular degree d is odd, extract a perfect matching (one color)
    and recurse on d-1; if even, Euler-split into two d/2-regular halves.
    Real edges receive exactly Δ colors."""
    e = row_key.shape[0]
    if e == 0:
        return np.empty(0, dtype=np.int64)
    n_rows = int(row_key.max()) + 1
    n_lanes = int(lane_key.max()) + 1
    n = max(n_rows, n_lanes)
    deg_r = np.bincount(row_key, minlength=n)
    deg_l = np.bincount(lane_key, minlength=n)
    delta = int(max(deg_r.max(), deg_l.max()))
    # Δ-regularize: both sides have n nodes, so stub counts match exactly.
    pad_r = np.repeat(np.arange(n, dtype=np.int64), delta - deg_r)
    pad_l = np.repeat(np.arange(n, dtype=np.int64), delta - deg_l)
    assert pad_r.size == pad_l.size == n * delta - e
    rk = np.concatenate([row_key.astype(np.int64), pad_r])
    lk = np.concatenate([lane_key.astype(np.int64), pad_l])
    total = rk.shape[0]
    colors = np.full(total, -1, dtype=np.int64)
    next_color = [0]

    def rec(idx: np.ndarray, d: int):
        if idx.size == 0 or d == 0:
            return
        if d == 1:
            colors[idx] = next_color[0]
            next_color[0] += 1
            return
        if d % 2 == 1:
            sub_match = _perfect_matching_regular(rk[idx], lk[idx], n)
            colors[idx[sub_match]] = next_color[0]
            next_color[0] += 1
            keep = np.ones(idx.size, dtype=bool)
            keep[sub_match] = False
            rec(idx[keep], d - 1)
        else:
            lab = _euler_split(rk[idx], lk[idx])
            rec(idx[lab == 0], d // 2)
            rec(idx[lab == 1], d // 2)

    rec(np.arange(total, dtype=np.int64), delta)
    out = colors[:e]
    assert out.min() >= 0 and out.max() < delta
    return out


_COLORERS = {
    "paper": color_edges_paper,
    "fast": color_edges_fast,
    "exact": color_edges_exact,
}


# ---------------------------------------------------------------------------
# Full scheduling pipeline (Listing 1 + Listing 2)
# ---------------------------------------------------------------------------


def schedule(
    coo: COOMatrix,
    l: int,
    *,
    load_balance: bool = True,
    method: str = "fast",
    value_dtype=np.float32,
) -> GustSchedule:
    """Preprocess a sparse matrix into the GUST scheduled format."""
    if method not in _COLORERS:
        raise ValueError(f"unknown coloring method {method!r}")
    m, n = coo.shape
    num_windows = max(-(-m // l), 1)

    win, row_local, lane, col, val, row_perm = _build_edges(coo, l, load_balance)
    e = win.shape[0]

    if e:
        if method == "exact":
            # Per-window exact coloring (windows are independent graphs).
            colors = np.empty(e, dtype=np.int64)
            w_ids, w_starts = np.unique(win, return_index=True)
            bounds = np.append(w_starts, e)
            for i in range(w_ids.shape[0]):
                s, t = bounds[i], bounds[i + 1]
                colors[s:t] = color_edges_exact(row_local[s:t], lane[s:t])
        else:
            # Globalized keys let one pass color every window at once.
            row_key = win * l + row_local
            lane_key = win * l + lane
            colors = _COLORERS[method](row_key, lane_key)
    else:
        colors = np.empty(0, dtype=np.int64)

    # Colors per window -> global cycle offsets.
    colors_per_window = np.zeros(num_windows, dtype=np.int64)
    if e:
        np.maximum.at(colors_per_window, win, colors + 1)
    window_starts = np.zeros(num_windows + 1, dtype=np.int64)
    np.cumsum(colors_per_window, out=window_starts[1:])
    c_total = int(window_starts[-1])

    # Listing 2: materialize M_sch / Row_sch / Col_sch.
    m_sch = np.zeros((max(c_total, 1), l), dtype=value_dtype)
    row_sch = np.zeros((max(c_total, 1), l), dtype=np.int32)
    # Padding slots gather v[lane] and multiply by 0 — always safe: the
    # execution paths zero-pad v to ceil(n/l)*l (jnp.take clamps when not),
    # and col==lane preserves the lane structure the fused kernel needs.
    col_sch = np.tile(np.arange(l, dtype=np.int32), (max(c_total, 1), 1))
    valid = np.zeros((max(c_total, 1), l), dtype=bool)
    if e:
        gcycle = window_starts[win] + colors
        if valid[gcycle, lane].any() or np.unique(gcycle * l + lane).size != e:
            raise AssertionError("collision in schedule — invalid coloring")
        m_sch[gcycle, lane] = val.astype(value_dtype)
        row_sch[gcycle, lane] = row_local.astype(np.int32)
        col_sch[gcycle, lane] = col.astype(np.int32)
        valid[gcycle, lane] = True

    return GustSchedule(
        l=l,
        shape=(m, n),
        nnz=e,
        m_sch=m_sch,
        row_sch=row_sch,
        col_sch=col_sch,
        window_starts=window_starts,
        row_perm=row_perm,
        valid=valid,
    )
