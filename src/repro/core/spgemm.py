"""SpGEMM: sparse×sparse through a GUST plan's color-block stream.

The plan/execute machinery of PRs 1-7 schedules ``A`` once into a stream
of conflict-free ``(c_blk, l)`` multiply blocks.  For SpMV each slot
``(a = A[i, j], row, col = j)`` gathers one vector element ``x[j]``;
SpGEMM generalizes the gather target from an element to a **row of B**
(SpArch's streamed-outer-product organization): slot ``(a, row, j)``
contributes ``a · B[j, :]`` to output row ``i``, and the per-window
``(l, B)`` accumulator tile becomes an ``(l, n_out)`` dense-row
accumulator — bounded scratch, merged window by window, never an
``(m, n_out)`` intermediate on the accelerator.

B is carried in the *condensed-row* format (:func:`condense_rows`):
every row padded to ``k_max`` ``(value, column)`` pairs, so the streamed
B bytes scale with ``nnz(B)`` (``R·k_max·8``) instead of the densified
``R·n_out·4``.  Two execution paths share the schedule:

  * **jnp** — :func:`repro.kernels.ref.gust_spgemm_ref`, a segment-sum
    merge over all partial products (the dense-row accumulator realized
    as one scatter-add);
  * **pallas** — :func:`repro.kernels.gust_spgemm.make_gust_spgemm`, the
    scalar-prefetch kernel with a VMEM ``(l, n_out)`` scratch row
    accumulator (integrate across a window's blocks, dump once).

The result is an explicit sparse :class:`~repro.core.formats.COOMatrix`
— deduplicated, row-sorted, numerically-zero entries dropped — that can
itself be ``repro.plan()``-ed, enabling chained ``A·A`` graph analytics
(:mod:`repro.graph`).

Per the plan-API policy (ROADMAP §PR 3) the public entry point is
:meth:`GustPlan.spgemm` / :meth:`GustPlan.spgemm_cost`; this module is
the implementation, not a new front door.  Scheduling of A goes through
the existing ``ScheduleCache``/``PlanStore`` unchanged — SpGEMM adds no
artifact knobs (B arrives per call, like the vector in ``spmv``).

Numerical contract (ROADMAP §SpGEMM invariants): on exact-arithmetic
inputs (integer-valued f32 where every product and partial sum is
exactly representable) the result is **bitwise equal** to the dense
``dense_from_coo(A) @ dense_from_coo(B)`` reference on every
backend × layout combination — any summation order produces the same
floats, so the gates pin the full index/merge logic exactly.  On
arbitrary f32 inputs the paths agree to float tolerance (their merge
orders differ).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .formats import COOMatrix, coo_from_dense

__all__ = [
    "CondensedB",
    "condense_rows",
    "SpgemmCost",
    "spgemm_cost",
    "spgemm",
]


@dataclasses.dataclass(frozen=True)
class CondensedB:
    """B in condensed-row form: every row padded to ``k_max`` pairs.

    ``vals``/``cols`` are ``(r_rows, k_max)`` planes — f32 values and
    int32 output-column ids — with rows padded to ``r_rows =
    ceil(k / l) * l`` so the A stream's padding column slots (which hold
    their own lane index, < l <= r_rows) always gather in-bounds.
    Padding entries hold ``value 0.0, column 0``: zero contribution, the
    packed-format invariant carried over to B."""

    shape: Tuple[int, int]  # original B shape (k, n)
    vals: jnp.ndarray  # (r_rows, k_max) f32
    cols: jnp.ndarray  # (r_rows, k_max) int32
    k_max: int
    r_rows: int

    @property
    def condensed_bytes(self) -> int:
        return int(self.r_rows * self.k_max * (4 + 4))

    @property
    def dense_bytes(self) -> int:
        return int(self.r_rows * self.shape[1] * 4)


def condense_rows(b: COOMatrix, l: int) -> CondensedB:
    """Build the condensed-row planes of ``b`` for a length-``l`` plan.

    Duplicate ``(row, col)`` entries are summed (the
    :func:`~repro.core.formats.dense_from_coo` semantics), rows are
    sorted and each row's entries are column-sorted — the deterministic
    layout both backends read."""
    k, n = b.shape
    r_rows = max(-(-k // l), 1) * l
    if b.nnz == 0:
        return CondensedB(
            shape=(k, n),
            vals=jnp.zeros((r_rows, 1), jnp.float32),
            cols=jnp.zeros((r_rows, 1), jnp.int32),
            k_max=1,
            r_rows=r_rows,
        )
    srt = b.sorted_by_row()
    key = srt.rows * np.int64(n) + srt.cols
    uniq, inv = np.unique(key, return_inverse=True)
    acc = np.zeros(uniq.shape[0], np.float32)
    np.add.at(acc, inv, srt.vals.astype(np.float32))
    rows_u = (uniq // n).astype(np.int64)
    cols_u = (uniq % n).astype(np.int64)
    counts = np.bincount(rows_u, minlength=k)
    k_max = int(max(counts.max(), 1))
    starts = np.concatenate([[0], np.cumsum(counts)])
    pos = np.arange(uniq.shape[0], dtype=np.int64) - starts[rows_u]
    vals = np.zeros((r_rows, k_max), np.float32)
    cols = np.zeros((r_rows, k_max), np.int32)
    vals[rows_u, pos] = acc
    cols[rows_u, pos] = cols_u
    return CondensedB(
        shape=(k, n),
        vals=jnp.asarray(vals),
        cols=jnp.asarray(cols),
        k_max=k_max,
        r_rows=r_rows,
    )


@dataclasses.dataclass(frozen=True)
class SpgemmCost:
    """Predicted cost of one ``A @ B`` product — no execution, no pack.

    ``products`` is the multiply/merge-op count (Σ over nnz(A) of B's
    matching row nnz — every partial product is one merge into the row
    accumulator); ``out_nnz_estimate`` the balls-in-bins estimate of the
    result's nnz; ``scratch_bytes`` the ``(l, n_out)`` f32 VMEM row
    accumulator; ``b_condensed_bytes``/``b_dense_bytes`` the streamed-B
    footprint of the condensed format vs densifying; ``flop_reduction``
    the streamed-FLOP win over a dense ``(m, k) @ (k, n)`` matmul.  This
    is what dryrun/roofline read to show SpGEMM without executing."""

    products: int
    out_nnz_estimate: int
    out_density_estimate: float
    scratch_bytes: int
    b_condensed_bytes: int
    b_dense_bytes: int
    k_max: int
    streamed_slots: int
    spgemm_flops: int
    dense_flops: int
    flop_reduction: float

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def _as_coo(other) -> COOMatrix:
    from .plan import GustPlan

    if isinstance(other, COOMatrix):
        return other
    if isinstance(other, GustPlan):
        if other._source is None:
            raise ValueError(
                "spgemm(other=GustPlan) needs the plan's source matrix; "
                "this plan was built without one (schedule/spec/store "
                "path) — pass the COOMatrix directly"
            )
        return other._source
    if isinstance(other, (np.ndarray, jax.Array)):
        dense = np.asarray(other)
        if dense.ndim != 2:
            raise ValueError(
                f"dense B must be 2-D, got shape {dense.shape}"
            )
        return coo_from_dense(dense)
    raise TypeError(
        "spgemm() takes a COOMatrix, GustPlan or dense array for B; got "
        f"{type(other).__name__}"
    )


def _a_cols(plan_a) -> np.ndarray:
    """Original column index of every real scheduled slot of A."""
    if plan_a._source is not None:
        return np.asarray(plan_a._source.cols, np.int64)
    if plan_a.sched is not None:
        s = plan_a.sched
        return np.asarray(s.col_sch, np.int64)[np.asarray(s.valid)]
    raise ValueError(
        "spgemm_cost() needs the schedule or source matrix; "
        "deserialized/spec plans carry only the packed artifact"
    )


def spgemm_cost(plan_a, other) -> SpgemmCost:
    """Price ``plan_a @ other`` without executing (or packing)."""
    b = _as_coo(other)
    m, k = plan_a.shape
    if b.shape[0] != k:
        raise ValueError(
            f"spgemm shape mismatch: A is {m}x{k}, B is "
            f"{b.shape[0]}x{b.shape[1]}"
        )
    n_out = b.shape[1]
    l = plan_a.l
    b_row_nnz = b.row_nnz()
    a_cols = _a_cols(plan_a)
    products = int(b_row_nnz[a_cols].sum())

    # balls-in-bins output-nnz estimate: row i of C receives
    # prod_i = Σ_{j in A row i} nnz(B[j, :]) candidate columns out of n
    if plan_a._source is not None and n_out:
        src = plan_a._source
        per_row = np.zeros(m, np.float64)
        np.add.at(per_row, src.rows, b_row_nnz[src.cols].astype(np.float64))
        est = float(np.sum(n_out * -np.expm1(per_row * np.log1p(-1.0 / n_out))))
    elif n_out and m:
        per_row = products / float(m)
        est = float(m * n_out * -np.expm1(per_row * np.log1p(-1.0 / n_out)))
    else:
        est = 0.0
    out_nnz = int(min(round(est), m * n_out))

    # streamed A slots at the plan's resolved layout, from the schedule
    # alone (no pack): padded streams W * C_pad, ragged only real blocks
    if plan_a.sched is not None:
        cw = plan_a.sched.colors_per_window
        cb = plan_a.config.c_blk
        if plan_a.layout == "ragged":
            blocks = int(np.maximum(-(-cw // cb), 1).sum())
        else:
            blocks = plan_a.sched.num_windows * max(
                -(-int(cw.max() if cw.size else 1) // cb), 1
            )
        streamed_slots = blocks * cb * l
    else:
        a = plan_a.artifact
        streamed_slots = int(np.prod(a.m_blk.shape))

    r_rows = max(-(-k // l), 1) * l
    k_max = int(max(b_row_nnz.max() if b.nnz else 1, 1))
    spgemm_flops = 2 * products
    dense_flops = 2 * m * k * n_out
    return SpgemmCost(
        products=products,
        out_nnz_estimate=out_nnz,
        out_density_estimate=out_nnz / float(m * n_out) if m and n_out else 0.0,
        scratch_bytes=l * n_out * 4,
        b_condensed_bytes=r_rows * k_max * 8,
        b_dense_bytes=r_rows * n_out * 4,
        k_max=k_max,
        streamed_slots=streamed_slots,
        spgemm_flops=spgemm_flops,
        dense_flops=dense_flops,
        flop_reduction=dense_flops / max(spgemm_flops, 1),
    )


def _stream_view(art):
    """Unified ragged-style view of either packed layout: the flat block
    stream plus the ``block_window``/``block_starts`` steering pair (a
    padded artifact is the stream whose every window owns ``C_pad/c_blk``
    blocks)."""
    from .packing import RaggedSchedule

    if isinstance(art, RaggedSchedule):
        bw = jnp.asarray(art.block_window, jnp.int32)
        bs = jnp.asarray(art.block_starts, jnp.int32)
        return art.num_blocks, bw, bs
    cpb = art.c_pad // art.c_blk
    num_blocks = art.num_windows * cpb
    bw = jnp.repeat(jnp.arange(art.num_windows, dtype=jnp.int32), cpb)
    bs = jnp.arange(art.num_windows + 1, dtype=jnp.int32) * cpb
    return num_blocks, bw, bs


_ref_jit = None


def _spgemm_ref(m_blk, col_blk, row_blk, window, b_vals, b_cols, *,
                num_windows, l, n_out):
    global _ref_jit
    if _ref_jit is None:
        from repro.kernels.ref import gust_spgemm_ref

        _ref_jit = jax.jit(
            gust_spgemm_ref,
            static_argnames=("num_windows", "l", "n_out"),
        )
    return _ref_jit(
        m_blk, col_blk, row_blk, window, b_vals, b_cols,
        num_windows=num_windows, l=l, n_out=n_out,
    )


def spgemm(plan_a, other, *, backend: str = None,
           interpret: bool = None) -> COOMatrix:
    """``C = A @ B`` over plan A's color-block stream; returns a sparse
    deduplicated row-sorted :class:`COOMatrix` (numerically-zero entries
    dropped) that can itself be ``repro.plan()``-ed.

    ``backend`` overrides the plan's resolution (``"jnp"`` |
    ``"pallas"``); the SpGEMM kernel's one-hot row gather does not need
    the lane-``fusable`` structure SpMV's fused gather does, so
    ``backend="auto"`` resolves to Pallas on TPU unconditionally.
    Quantized (int8) plans are rejected — the SpGEMM contract is pinned
    for float streams; re-pack A at f32/bf16."""
    from repro.kernels.ops import normalize_choice

    b_coo = _as_coo(other)
    m, k = plan_a.shape
    if b_coo.shape[0] != k:
        raise ValueError(
            f"spgemm shape mismatch: A is {m}x{k}, B is "
            f"{b_coo.shape[0]}x{b_coo.shape[1]}"
        )
    n_out = b_coo.shape[1]
    art = plan_a.artifact
    if art.quantized:
        raise ValueError(
            "spgemm on an int8-quantized plan is not supported: the "
            "SpGEMM bit-identity contract is pinned for float value "
            "streams (re-pack A with value_dtype='float32')"
        )
    if backend is None:
        backend = plan_a.config.backend
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    normalize_choice("backend", backend)
    if interpret is None:
        interpret = plan_a._interpret()

    l, W, c_blk = art.l, art.num_windows, art.c_blk
    cond = condense_rows(b_coo, l)
    num_blocks, bw, bs = _stream_view(art)
    if backend == "pallas":
        from repro.kernels.gust_spgemm import make_gust_spgemm

        fn = make_gust_spgemm(
            num_blocks, W, l, cond.r_rows, cond.k_max, n_out,
            c_blk=c_blk, interpret=interpret,
        )
        y_win = fn(
            bw, bs,
            jnp.asarray(art.m_blk), jnp.asarray(art.col_blk),
            jnp.asarray(art.row_blk), cond.vals, cond.cols,
        )
    else:
        window = jnp.repeat(bw, c_blk)
        y_win = _spgemm_ref(
            jnp.asarray(art.m_blk), jnp.asarray(art.col_blk),
            jnp.asarray(art.row_blk), window, cond.vals, cond.cols,
            num_windows=W, l=l, n_out=n_out,
        )

    y_sorted = np.asarray(y_win, np.float32).reshape(W * l, n_out)
    if art.identity_perm:
        c_dense = y_sorted[:m]
    else:
        out = np.zeros((max(m, W * l), n_out), np.float32)
        out[np.asarray(art.row_perm)] = y_sorted
        c_dense = out[:m]
    rows, cols = np.nonzero(c_dense)
    return COOMatrix(
        (m, n_out),
        rows.astype(np.int64),
        cols.astype(np.int64),
        c_dense[rows, cols],
    )
