"""GUST SpMV execution (JAX).

The scheduled format turns SpMV into three dense streaming steps — exactly
the paper's three hardware levels:

  1. multiply   : ``P = M_sch * v[Col_sch]``          (the l multipliers)
  2. route      : partial product (c, j) goes to adder ``Row_sch[c, j]``
                  of its window                        (the crossbar)
  3. accumulate : adders integrate per window, dump at window end.

Pure-jnp implementations live here (also serving as the kernel oracle);
``repro.kernels.ops`` provides the Pallas path that fuses 1-3 on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .formats import COOMatrix, GustSchedule
from .packing import RaggedSchedule, window_ids

__all__ = [
    "spmv_dense_ref",
    "spmv_scheduled",
    "spmv",
    "spmm_scheduled",
    "spmm_ragged",
    "distributed_spmv",
]


def spmv_dense_ref(dense: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Oracle: plain dense matvec."""
    return dense @ v


@functools.partial(jax.jit, static_argnames=("m", "l", "num_windows"))
def _spmv_scheduled_impl(
    m_sch: jnp.ndarray,
    row_sch: jnp.ndarray,
    col_sch: jnp.ndarray,
    window_of_cycle: jnp.ndarray,
    row_perm: jnp.ndarray,
    v: jnp.ndarray,
    *,
    m: int,
    l: int,
    num_windows: int,
) -> jnp.ndarray:
    # Level 1: the multipliers.  Buffer Filler == gather by Col_sch.
    v_sch = jnp.take(v, col_sch, axis=0, mode="clip")  # (C_total, l)
    partial = m_sch.astype(jnp.float32) * v_sch.astype(jnp.float32)
    # Levels 2+3: crossbar route + accumulate.  Global adder id is
    # window*l + row_sch; windows never share adders, so one segment-sum
    # implements every window's accumulate/dump.
    adder = window_of_cycle[:, None] * l + row_sch  # (C_total, l)
    y_sorted = jax.ops.segment_sum(
        partial.reshape(-1), adder.reshape(-1), num_segments=num_windows * l
    )
    # Undo the load-balancing row sort: scheduled row s is original row
    # row_perm[s].
    return jnp.zeros((m,), jnp.float32).at[row_perm].set(y_sorted[:m])


def spmv_scheduled(sched: GustSchedule, v: jnp.ndarray) -> jnp.ndarray:
    """SpMV from the scheduled format (pure jnp; oracle for the kernel)."""
    m, n = sched.shape
    if v.shape != (n,):
        raise ValueError(f"vector shape {v.shape} != ({n},)")
    return _spmv_scheduled_impl(
        jnp.asarray(sched.m_sch),
        jnp.asarray(sched.row_sch),
        jnp.asarray(sched.col_sch),
        jnp.asarray(window_ids(sched)),
        jnp.asarray(sched.row_perm),
        v,
        m=m,
        l=sched.l,
        num_windows=sched.num_windows,
    )


def spmm_scheduled(sched: GustSchedule, x: jnp.ndarray) -> jnp.ndarray:
    """Multi-vector SpMV: ``x`` is (n, B) -> (m, B).  This is the decode-
    batch path of :class:`~repro.core.gust_linear.GustLinear` (B independent
    GUST passes sharing one schedule — paper §3.3: the schedule is reused
    for any vector)."""
    m, n = sched.shape
    if x.ndim != 2 or x.shape[0] != n:
        raise ValueError(f"expected (n={n}, B), got {x.shape}")
    return jax.vmap(lambda col: spmv_scheduled(sched, col), in_axes=1, out_axes=1)(x)


@functools.partial(jax.jit, static_argnames=("m", "l", "num_windows", "c_blk"))
def _spmm_ragged_impl(
    m_blk, row_blk, col_blk, block_window, row_perm, x, *, m, l, num_windows,
    c_blk,
):
    # Level 1: multiply the ragged stream (only real blocks) against the
    # gathered vector.  Padding slots carry value 0 / in-bounds lane cols.
    v_sch = jnp.take(x, col_blk.astype(jnp.int32), axis=0, mode="clip")
    partial = m_blk.astype(jnp.float32)[:, :, None] * v_sch.astype(jnp.float32)
    # Levels 2+3: the window of stream row r is block_window[r // c_blk];
    # global adder id = window*l + row, one segment-sum integrates+dumps
    # every window.
    window = jnp.repeat(block_window.astype(jnp.int32), c_blk)
    adder = window[:, None] * l + row_blk.astype(jnp.int32)
    b = x.shape[1]
    y_sorted = jax.ops.segment_sum(
        partial.reshape(-1, b), adder.reshape(-1),
        num_segments=num_windows * l,
    )
    out = jnp.zeros((max(m, num_windows * l), b), jnp.float32)
    return out.at[row_perm].set(y_sorted)[:m]


def spmm_ragged(ragged: RaggedSchedule, x: jnp.ndarray) -> jnp.ndarray:
    """Multi-vector SpMV from the ragged block stream (pure jnp segment-
    sum; oracle for the scalar-prefetch kernel): ``x`` (n, B) -> (m, B).
    Streams ``T_blk * c_blk`` rows instead of the padded ``W * C_pad`` —
    on skewed matrices most of the padded stream is dead cycles."""
    m, n = ragged.shape
    if x.ndim != 2 or x.shape[0] != n:
        raise ValueError(f"expected (n={n}, B), got {x.shape}")
    return _spmm_ragged_impl(
        ragged.m_blk, ragged.row_blk, ragged.col_blk, ragged.block_window,
        ragged.row_perm, x, m=m, l=ragged.l, num_windows=ragged.num_windows,
        c_blk=ragged.c_blk,
    ).astype(x.dtype)


def spmv(
    coo: COOMatrix,
    v: jnp.ndarray,
    l: int = 256,
    *,
    load_balance: bool = True,
    method: str = "fast",
) -> jnp.ndarray:
    """Convenience: schedule + execute in one call.  The schedule is served
    from the process-global content-keyed
    :class:`~repro.core.packing.ScheduleCache`, so repeated calls on the
    same matrix pay for scheduling once — and the schedule stays resident
    (LRU-bounded) after this call returns; use
    :func:`repro.core.packing.clear_cache` to release it."""
    from .packing import default_cache

    return spmv_scheduled(
        default_cache.schedule(coo, l, load_balance=load_balance, method=method), v
    )


# ---------------------------------------------------------------------------
# Distributed SpMV — the paper's §5.5 "k parallel length-l GUSTs".
# ---------------------------------------------------------------------------


def distributed_spmv(
    sched: GustSchedule,
    v: jnp.ndarray,
    mesh: jax.sharding.Mesh,
    axis: str = "data",
    *,
    c_blk: int = 1,
    cache="default",
):
    """Shard row-windows across ``axis`` (each device runs an independent
    length-l GUST over its windows; the schedule is untouched — paper:
    "the Edge-Coloring schedule would not need to change").  The vector is
    replicated; outputs concatenate without collectives because windows own
    disjoint output rows.

    Devices get contiguous window ranges balanced by **block count** of
    the ragged stream (``max(ceil(C_w / c_blk), 1)`` blocks per window),
    not by window count: on skewed (power-law) matrices equal-window
    splits leave most devices idle while one drains the heavy windows,
    and the old padded layout additionally streamed every light window at
    the global ``C_pad``.  Each device executes only its own blocks,
    padded to the max per-device block count (the residual imbalance of a
    contiguous split).

    The ragged pack is served from the content-keyed
    :class:`~repro.core.packing.ScheduleCache` (``cache="default"`` uses
    the process-global one, ``None`` re-packs every call), so repeated
    calls on the same schedule pack exactly once."""
    from .packing import default_cache, pack_ragged

    n_dev = mesh.shape[axis]
    m, n = sched.shape
    l, W = sched.l, sched.num_windows
    if cache == "default":
        cache = default_cache
    if cache is None:
        layout = _shard_layout(pack_ragged(sched, c_blk), n_dev)
    else:
        # the whole device-major layout (host assembly + device upload) is
        # a pure function of (schedule content, c_blk, n_dev) — memoize it
        # next to the ragged pack so repeated calls only run the shard_map
        layout = cache.memo(
            ("shard_layout", cache.schedule_key(sched), c_blk, n_dev),
            lambda: _shard_layout(
                cache.ragged_for(sched, c_blk=c_blk), n_dev
            ),
        )
    m_d, r_d, c_d, lw_d, w_max, idx = layout
    fn = _shard_spmv_fn(mesh, axis, l, c_blk, w_max)
    y_dev = fn(m_d, r_d, c_d, lw_d, v)
    # Reassemble: device d's first w_cnt[d]*l rows are windows
    # w_bound[d]..w_bound[d+1] in order (collectives-free concatenation).
    y_sorted = y_dev.reshape(-1)[idx][:m]
    return jnp.zeros((m,), jnp.float32).at[jnp.asarray(sched.row_perm)].set(y_sorted)


@functools.lru_cache(maxsize=64)
def _shard_spmv_fn(mesh, axis: str, l: int, c_blk: int, w_max: int):
    """Jitted shard_map program for one (mesh, geometry) — memoized so
    repeated ``distributed_spmv`` calls reuse jax's trace/compile cache
    instead of paying a fresh closure trace every call."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.collectives import shard_map

    def local(m_blk, r_blk, c_blk_, lw, vec):
        # (1, B_max*cb, l) stream + (1, B_max) local window ids ->
        # per-window segment sum -> (1, W_max * l)
        p = m_blk[0].astype(jnp.float32) * jnp.take(
            vec, c_blk_[0], axis=0, mode="clip"
        )
        window = jnp.repeat(lw[0], c_blk)
        adder = window[:, None] * l + r_blk[0]
        return jax.ops.segment_sum(
            p.reshape(-1), adder.reshape(-1), num_segments=w_max * l
        )[None]

    spec_in = P(axis)  # shard the leading device dim
    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(spec_in, spec_in, spec_in, spec_in, P()),
            out_specs=spec_in,
        )
    )


def _shard_layout(ragged, n_dev: int):
    """Device-major execution layout of a ragged stream for ``n_dev``
    devices: contiguous window ranges balanced by block count, each
    device's blocks padded to the common max.

    Returns ``(m_d, r_d, c_d, lw_d, w_max, idx)`` — the four ``(n_dev,
    ...)`` device arrays for the shard_map, the padded per-device window
    count, and the gather index reassembling the per-device outputs into
    scheduled row order.  Everything here is a pure function of (ragged
    stream, n_dev); ``distributed_spmv`` memoizes it in the
    ``ScheduleCache`` so repeated calls skip both the host assembly and
    the host->device upload."""
    l, W, cb, t_blk = ragged.l, ragged.num_windows, ragged.c_blk, ragged.num_blocks
    block_starts = np.asarray(ragged.block_starts, np.int64)
    block_window = np.asarray(ragged.block_window, np.int64)

    # Contiguous window boundaries hitting equal block-count targets:
    # device d owns windows [w_bound[d], w_bound[d+1]).
    targets = (np.arange(1, n_dev) * t_blk) // n_dev
    w_bound = np.concatenate(
        [[0], np.searchsorted(block_starts, targets, side="left"), [W]]
    )
    w_bound = np.maximum.accumulate(np.minimum(w_bound, W))
    w_cnt = np.diff(w_bound)
    b_cnt = block_starts[w_bound[1:]] - block_starts[w_bound[:-1]]
    b_max = max(int(b_cnt.max()) if n_dev else 1, 1)
    w_max = max(int(w_cnt.max()) if n_dev else 1, 1)

    # Device-major padded streams; padding blocks keep the packed-format
    # invariants (values 0, columns gather the slot's lane, rows 0) and
    # route to local window 0 — value 0 contributes nothing.
    lane = np.arange(l, dtype=np.int32)
    m_d = np.zeros((n_dev, b_max * cb, l), np.float32)
    r_d = np.zeros((n_dev, b_max * cb, l), np.int32)
    c_d = np.broadcast_to(lane, (n_dev, b_max * cb, l)).copy()
    lw_d = np.zeros((n_dev, b_max), np.int32)
    m_src = np.asarray(ragged.m_blk, np.float32)
    r_src = np.asarray(ragged.row_blk, np.int32)
    c_src = np.asarray(ragged.col_blk, np.int32)
    for d in range(n_dev):
        g0, g1 = int(block_starts[w_bound[d]]), int(block_starts[w_bound[d + 1]])
        rows = (g1 - g0) * cb
        m_d[d, :rows] = m_src[g0 * cb: g1 * cb]
        r_d[d, :rows] = r_src[g0 * cb: g1 * cb]
        c_d[d, :rows] = c_src[g0 * cb: g1 * cb]
        lw_d[d, : g1 - g0] = block_window[g0:g1] - w_bound[d]

    idx = np.concatenate(
        [d * w_max * l + np.arange(w_cnt[d] * l) for d in range(n_dev)]
    ) if W else np.zeros(0, np.int64)
    return (
        jnp.asarray(m_d), jnp.asarray(r_d), jnp.asarray(c_d),
        jnp.asarray(lw_d), w_max, jnp.asarray(idx),
    )
