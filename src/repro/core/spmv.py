"""GUST SpMV execution (JAX).

The scheduled format turns SpMV into three dense streaming steps — exactly
the paper's three hardware levels:

  1. multiply   : ``P = M_sch * v[Col_sch]``          (the l multipliers)
  2. route      : partial product (c, j) goes to adder ``Row_sch[c, j]``
                  of its window                        (the crossbar)
  3. accumulate : adders integrate per window, dump at window end.

Pure-jnp implementations live here (also serving as the kernel oracle);
``repro.kernels.ops`` provides the Pallas path that fuses 1-3 on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .formats import COOMatrix, GustSchedule
from .packing import pack_schedule, window_ids

__all__ = [
    "spmv_dense_ref",
    "spmv_scheduled",
    "spmv",
    "spmm_scheduled",
    "distributed_spmv",
]


def spmv_dense_ref(dense: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Oracle: plain dense matvec."""
    return dense @ v


@functools.partial(jax.jit, static_argnames=("m", "l", "num_windows"))
def _spmv_scheduled_impl(
    m_sch: jnp.ndarray,
    row_sch: jnp.ndarray,
    col_sch: jnp.ndarray,
    window_of_cycle: jnp.ndarray,
    row_perm: jnp.ndarray,
    v: jnp.ndarray,
    *,
    m: int,
    l: int,
    num_windows: int,
) -> jnp.ndarray:
    # Level 1: the multipliers.  Buffer Filler == gather by Col_sch.
    v_sch = jnp.take(v, col_sch, axis=0, mode="clip")  # (C_total, l)
    partial = m_sch.astype(jnp.float32) * v_sch.astype(jnp.float32)
    # Levels 2+3: crossbar route + accumulate.  Global adder id is
    # window*l + row_sch; windows never share adders, so one segment-sum
    # implements every window's accumulate/dump.
    adder = window_of_cycle[:, None] * l + row_sch  # (C_total, l)
    y_sorted = jax.ops.segment_sum(
        partial.reshape(-1), adder.reshape(-1), num_segments=num_windows * l
    )
    # Undo the load-balancing row sort: scheduled row s is original row
    # row_perm[s].
    return jnp.zeros((m,), jnp.float32).at[row_perm].set(y_sorted[:m])


def spmv_scheduled(sched: GustSchedule, v: jnp.ndarray) -> jnp.ndarray:
    """SpMV from the scheduled format (pure jnp; oracle for the kernel)."""
    m, n = sched.shape
    if v.shape != (n,):
        raise ValueError(f"vector shape {v.shape} != ({n},)")
    return _spmv_scheduled_impl(
        jnp.asarray(sched.m_sch),
        jnp.asarray(sched.row_sch),
        jnp.asarray(sched.col_sch),
        jnp.asarray(window_ids(sched)),
        jnp.asarray(sched.row_perm),
        v,
        m=m,
        l=sched.l,
        num_windows=sched.num_windows,
    )


def spmm_scheduled(sched: GustSchedule, x: jnp.ndarray) -> jnp.ndarray:
    """Multi-vector SpMV: ``x`` is (n, B) -> (m, B).  This is the decode-
    batch path of :class:`~repro.core.gust_linear.GustLinear` (B independent
    GUST passes sharing one schedule — paper §3.3: the schedule is reused
    for any vector)."""
    m, n = sched.shape
    if x.ndim != 2 or x.shape[0] != n:
        raise ValueError(f"expected (n={n}, B), got {x.shape}")
    return jax.vmap(lambda col: spmv_scheduled(sched, col), in_axes=1, out_axes=1)(x)


def spmv(
    coo: COOMatrix,
    v: jnp.ndarray,
    l: int = 256,
    *,
    load_balance: bool = True,
    method: str = "fast",
) -> jnp.ndarray:
    """Convenience: schedule + execute in one call.  The schedule is served
    from the process-global content-keyed
    :class:`~repro.core.packing.ScheduleCache`, so repeated calls on the
    same matrix pay for scheduling once — and the schedule stays resident
    (LRU-bounded) after this call returns; use
    :func:`repro.core.packing.clear_cache` to release it."""
    from .packing import default_cache

    return spmv_scheduled(
        default_cache.schedule(coo, l, load_balance=load_balance, method=method), v
    )


# ---------------------------------------------------------------------------
# Distributed SpMV — the paper's §5.5 "k parallel length-l GUSTs".
# ---------------------------------------------------------------------------


def distributed_spmv(
    sched: GustSchedule,
    v: jnp.ndarray,
    mesh: jax.sharding.Mesh,
    axis: str = "data",
):
    """Shard row-windows across ``axis`` (each device runs an independent
    length-l GUST over its windows; the schedule is untouched — paper:
    "the Edge-Coloring schedule would not need to change").  The vector is
    replicated; outputs concatenate without collectives because windows own
    disjoint output rows.

    Windows are padded to a multiple of the axis size with empty windows
    (C_w = 0 contributes zero cycles on real hardware; here zero slots)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.collectives import shard_map

    n_dev = mesh.shape[axis]
    m, n = sched.shape
    l, W = sched.l, sched.num_windows
    W_pad = -(-W // n_dev) * n_dev

    # Canonical packer (c_blk=1 -> C_pad == max window colors), then pad the
    # window axis to a multiple of the device count.  Padded slots keep the
    # packed-format invariants: values 0, columns gather the slot's lane.
    packed = pack_schedule(sched, c_blk=1)
    c_pad = packed.c_pad

    def blocks(a, lane_fill=False):
        a3 = jnp.reshape(a, (W, c_pad, l))
        if W_pad == W:
            return a3
        if lane_fill:
            pad = jnp.broadcast_to(
                jnp.arange(l, dtype=a3.dtype)[None, None, :],
                (W_pad - W, c_pad, l),
            )
            return jnp.concatenate([a3, pad], axis=0)
        return jnp.pad(a3, ((0, W_pad - W), (0, 0), (0, 0)))

    m_b = blocks(packed.m_blk)
    r_b = blocks(packed.row_blk)
    c_b = blocks(packed.col_blk, lane_fill=True)

    def local(m_blk, r_blk, c_blk, vec):
        # (W_loc, c_max, l) -> per-window segment sum -> (W_loc * l,)
        p = m_blk.astype(jnp.float32) * jnp.take(vec, c_blk, axis=0, mode="clip")
        w_loc = m_blk.shape[0]
        adder = jnp.arange(w_loc, dtype=jnp.int32)[:, None, None] * l + r_blk
        return jax.ops.segment_sum(p.reshape(-1), adder.reshape(-1), num_segments=w_loc * l)

    spec_in = P(axis)  # shard leading window dim
    fn = jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(spec_in, spec_in, spec_in, P()),
            out_specs=spec_in,
        )
    )
    y_sorted = fn(m_b, r_b, c_b, v)[: m]
    return jnp.zeros((m,), jnp.float32).at[jnp.asarray(sched.row_perm)].set(y_sorted[:m])
