"""GUST SpMV execution (JAX): the pure-jnp oracle + legacy entry shims.

The scheduled format turns SpMV into three dense streaming steps — exactly
the paper's three hardware levels:

  1. multiply   : ``P = M_sch * v[Col_sch]``          (the l multipliers)
  2. route      : partial product (c, j) goes to adder ``Row_sch[c, j]``
                  of its window                        (the crossbar)
  3. accumulate : adders integrate per window, dump at window end.

:func:`spmv_scheduled` is the raw-schedule oracle the kernel tests
compare against.  Every other entry point here (``spmv``,
``spmm_scheduled``, ``spmm_ragged``, ``distributed_spmv``) is a legacy
shim that constructs a :class:`~repro.core.plan.GustPlan` and delegates —
new code should call ``repro.plan(matrix, config).spmv(v)`` / ``.spmm(x)``
/ ``.shard(mesh)`` directly.
"""

from __future__ import annotations

import functools
import warnings
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .formats import COOMatrix, GustSchedule
from .packing import RaggedSchedule, window_ids

__all__ = [
    "spmv_dense_ref",
    "spmv_scheduled",
    "spmv",
    "spmm_scheduled",
    "spmm_ragged",
    "distributed_spmv",
]


def spmv_dense_ref(dense: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Oracle: plain dense matvec."""
    return dense @ v


@functools.partial(jax.jit, static_argnames=("m", "l", "num_windows"))
def _spmv_scheduled_impl(
    m_sch: jnp.ndarray,
    row_sch: jnp.ndarray,
    col_sch: jnp.ndarray,
    window_of_cycle: jnp.ndarray,
    row_perm: jnp.ndarray,
    v: jnp.ndarray,
    *,
    m: int,
    l: int,
    num_windows: int,
) -> jnp.ndarray:
    # Level 1: the multipliers.  Buffer Filler == gather by Col_sch.
    v_sch = jnp.take(v, col_sch, axis=0, mode="clip")  # (C_total, l)
    partial = m_sch.astype(jnp.float32) * v_sch.astype(jnp.float32)
    # Levels 2+3: crossbar route + accumulate.  Global adder id is
    # window*l + row_sch; windows never share adders, so one segment-sum
    # implements every window's accumulate/dump.
    adder = window_of_cycle[:, None] * l + row_sch  # (C_total, l)
    y_sorted = jax.ops.segment_sum(
        partial.reshape(-1), adder.reshape(-1), num_segments=num_windows * l
    )
    # Undo the load-balancing row sort: scheduled row s is original row
    # row_perm[s].
    return jnp.zeros((m,), jnp.float32).at[row_perm].set(y_sorted[:m])


def spmv_scheduled(sched: GustSchedule, v: jnp.ndarray) -> jnp.ndarray:
    """SpMV from the *raw* (unpacked) scheduled format — the pure-jnp
    oracle the kernel and plan paths are validated against."""
    m, n = sched.shape
    if v.shape != (n,):
        raise ValueError(f"vector shape {v.shape} != ({n},)")
    return _spmv_scheduled_impl(
        jnp.asarray(sched.m_sch),
        jnp.asarray(sched.row_sch),
        jnp.asarray(sched.col_sch),
        jnp.asarray(window_ids(sched)),
        jnp.asarray(sched.row_perm),
        v,
        m=m,
        l=sched.l,
        num_windows=sched.num_windows,
    )


#: Identity-keyed LRU of shim plans: repeated ``spmm_scheduled`` calls on
#: the same schedule object reuse one plan (and its pack) without paying
#: the ScheduleCache's O(nnz) content hash per call.  Entries hold the
#: schedule strongly (via plan.sched), so an id can never be recycled
#: while its entry is alive; the identity re-check below makes a stale
#: hit impossible even after eviction.
_SHIM_PLANS: "OrderedDict[int, object]" = OrderedDict()
_SHIM_PLANS_MAX = 64


def spmm_scheduled(sched: GustSchedule, x: jnp.ndarray) -> jnp.ndarray:
    """Legacy shim: multi-vector SpMV, ``x`` (n, B) -> (m, B).

    Routes through a padded-layout :class:`~repro.core.plan.GustPlan`
    (paper §3.3: the schedule is reused for any vector); prefer
    ``repro.plan(sched, backend=...).spmm(x)``."""
    from .plan import PlanConfig, plan

    p = _SHIM_PLANS.get(id(sched))
    if p is None or p.sched is not sched:
        p = plan(
            sched, PlanConfig(l=sched.l, layout="padded", backend="jnp"),
            cache=None,
        )
        _SHIM_PLANS[id(sched)] = p
        while len(_SHIM_PLANS) > _SHIM_PLANS_MAX:
            _SHIM_PLANS.popitem(last=False)
    else:
        _SHIM_PLANS.move_to_end(id(sched))
    return p.spmm(x)


def spmm_ragged(ragged: RaggedSchedule, x: jnp.ndarray) -> jnp.ndarray:
    """Legacy shim: multi-vector SpMV from the ragged block stream,
    ``x`` (n, B) -> (m, B).  Streams ``T_blk * c_blk`` rows instead of the
    padded ``W * C_pad`` — on skewed matrices most of the padded stream is
    dead cycles.  Routes through :class:`~repro.core.plan.GustPlan`."""
    from .plan import GustPlan

    return GustPlan.from_artifact(ragged, backend="jnp").spmm(x)


def spmv(
    coo: COOMatrix,
    v: jnp.ndarray,
    l: int = 256,
    *,
    load_balance: bool = True,
    method: str = "fast",
) -> jnp.ndarray:
    """Deprecated convenience shim: schedule + execute in one call.

    Use ``repro.plan(coo, PlanConfig(l=..., colorer=...)).spmv(v)`` — the
    plan makes the schedule-once/execute-many contract explicit (and keeps
    the schedule resident in the content-keyed cache exactly as before;
    :func:`repro.core.packing.clear_cache` releases it)."""
    warnings.warn(
        "spmv(coo, v, l=..., method=...) is deprecated; use "
        "repro.plan(coo, PlanConfig(l=..., colorer=..., "
        "load_balance=...)).spmv(v) ('method' is spelled 'colorer', 'l' "
        "stays 'l')",
        DeprecationWarning,
        stacklevel=2,
    )
    from .plan import PlanConfig, plan

    return plan(
        coo,
        PlanConfig(l=l, colorer=method, load_balance=load_balance,
                   backend="jnp"),
    ).spmv(v)


def distributed_spmv(
    sched: GustSchedule,
    v: jnp.ndarray,
    mesh: jax.sharding.Mesh,
    axis: str = "data",
    *,
    c_blk: int = 1,
    cache="default",
):
    """Legacy shim for the paper's §5.5 "k parallel length-l GUSTs": shard
    row-windows across ``axis`` (contiguous window ranges balanced by
    ragged-stream block count; the schedule is untouched — paper: "the
    Edge-Coloring schedule would not need to change").  The vector is
    replicated; outputs concatenate without collectives because windows
    own disjoint output rows.

    Routes through ``repro.plan(sched, ...).shard(mesh, axis).spmv(v)`` —
    the plan owns the device-major layout memoization (``cache="default"``
    uses the process-global :class:`~repro.core.packing.ScheduleCache`,
    ``None`` re-packs every call)."""
    from .packing import default_cache
    from .plan import PlanConfig, plan

    if cache == "default":
        cache = default_cache
    p = plan(
        sched,
        PlanConfig(l=sched.l, layout="ragged", backend="jnp", c_blk=c_blk,
                   mesh_axis=axis),
        cache=cache,
    )
    return p.shard(mesh, axis).spmv(v)
