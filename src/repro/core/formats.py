"""Sparse-matrix storage formats used by GUST.

The paper's preprocessing (§3.3) converts a sparse matrix into the *GUST
scheduled format*: three ``l × C_total`` arrays (we store them transposed as
``C_total × l`` so a "cycle" is a contiguous row — the natural streaming
layout) holding the rearranged values (``M_sch``), the adder index for the
crossbar (``Row_sch`` = original row mod ``l``) and the original column index
used by the Buffer Filler to gather vector elements (``Col_sch``).

Everything here is plain-numpy preprocessing (the paper runs it on a CPU
too); the JAX/Pallas execution layer consumes the resulting arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "COOMatrix",
    "GustSchedule",
    "coo_from_dense",
    "dense_from_coo",
    "csr_from_coo",
]


@dataclasses.dataclass(frozen=True)
class COOMatrix:
    """Coordinate-format sparse matrix (the paper's input representation)."""

    shape: Tuple[int, int]
    rows: np.ndarray  # (nnz,) int64
    cols: np.ndarray  # (nnz,) int64
    vals: np.ndarray  # (nnz,) float

    def __post_init__(self):
        if self.rows.shape != self.cols.shape or self.rows.shape != self.vals.shape:
            raise ValueError("rows/cols/vals must have identical shapes")
        m, n = self.shape
        if self.nnz and (self.rows.max() >= m or self.cols.max() >= n):
            raise ValueError("index out of bounds for declared shape")

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    @property
    def density(self) -> float:
        m, n = self.shape
        return self.nnz / float(m * n) if m and n else 0.0

    def row_nnz(self) -> np.ndarray:
        return np.bincount(self.rows, minlength=self.shape[0]).astype(np.int64)

    def col_nnz(self) -> np.ndarray:
        return np.bincount(self.cols, minlength=self.shape[1]).astype(np.int64)

    def sorted_by_row(self) -> "COOMatrix":
        order = np.lexsort((self.cols, self.rows))
        return COOMatrix(self.shape, self.rows[order], self.cols[order], self.vals[order])

    def sorted_by_col(self) -> "COOMatrix":
        """Entries ordered by (col, row) — the column-major twin of
        :meth:`sorted_by_row` (CSC assembly, transpose chaining)."""
        order = np.lexsort((self.rows, self.cols))
        return COOMatrix(self.shape, self.rows[order], self.cols[order], self.vals[order])

    def transpose(self) -> "COOMatrix":
        """``Aᵀ`` with entries in the transpose's row-major order (so
        ``t.sorted_by_row()`` is a no-op reorder).  Values are shared,
        not copied: ``dense_from_coo(coo.transpose()) ==
        dense_from_coo(coo).T`` including duplicate-entry summation."""
        srt = self.sorted_by_col()
        return COOMatrix(
            (self.shape[1], self.shape[0]), srt.cols, srt.rows, srt.vals
        )


def coo_from_dense(dense: np.ndarray) -> COOMatrix:
    rows, cols = np.nonzero(dense)
    return COOMatrix(dense.shape, rows.astype(np.int64), cols.astype(np.int64), dense[rows, cols])


def dense_from_coo(coo: COOMatrix) -> np.ndarray:
    out = np.zeros(coo.shape, dtype=coo.vals.dtype)
    np.add.at(out, (coo.rows, coo.cols), coo.vals)
    return out


def csr_from_coo(coo: COOMatrix) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(indptr, indices, data) CSR triple — used by baseline dataflow models."""
    srt = coo.sorted_by_row()
    indptr = np.zeros(coo.shape[0] + 1, dtype=np.int64)
    np.add.at(indptr, srt.rows + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, srt.cols.copy(), srt.vals.copy()


@dataclasses.dataclass(frozen=True)
class GustSchedule:
    """The GUST scheduled format (paper §3.3, Listings 1-2).

    A length-``l`` GUST processes the matrix window-by-window (sets of ``l``
    rows).  Cycle ``c`` of window ``w`` lives at global row
    ``window_starts[w] + c`` of the three schedule arrays.

    Attributes:
      l:             accelerator length (number of multipliers == adders).
      shape:         original matrix shape ``(m, n)``.
      nnz:           number of real nonzeros scheduled.
      m_sch:         (C_total, l) float — value entering multiplier ``j`` at a
                     given cycle; 0.0 in padding slots.
      row_sch:       (C_total, l) int32 — adder index (row mod l, post
                     row-permutation); 0 in padding slots (safe: value is 0).
      col_sch:       (C_total, l) int32 — ORIGINAL column index for the
                     vector gather; clipped lane index in padding slots.
      window_starts: (num_windows + 1,) int64 prefix of per-window colors.
      row_perm:      (m,) int64 — ``row_perm[scheduled_pos] = original_row``
                     (identity when load balancing is off).  The SpMV output
                     of scheduled row ``s`` belongs to original row
                     ``row_perm[s]``.
      valid:         (C_total, l) bool — True for real (non-padding) slots.
    """

    l: int
    shape: Tuple[int, int]
    nnz: int
    m_sch: np.ndarray
    row_sch: np.ndarray
    col_sch: np.ndarray
    window_starts: np.ndarray
    row_perm: np.ndarray
    valid: np.ndarray

    @property
    def num_windows(self) -> int:
        return int(self.window_starts.shape[0] - 1)

    @property
    def total_colors(self) -> int:
        return int(self.window_starts[-1])

    @property
    def colors_per_window(self) -> np.ndarray:
        return np.diff(self.window_starts)

    @property
    def cycles(self) -> int:
        """Execution cycles: Σ_w C_w plus the 3-level pipeline fill (paper
        §3.4: 'GUST has 3 levels', i.e. +2)."""
        return self.total_colors + 2

    @property
    def hardware_utilization(self) -> float:
        """#NZ operations per cycle per arithmetic unit (paper §1 / Eq. 11)."""
        return self.nnz / float(self.l * self.cycles) if self.cycles else 0.0

    def window_cycle_of(self, global_cycle: np.ndarray) -> np.ndarray:
        """Map a global schedule row to its window id."""
        return np.searchsorted(self.window_starts, global_cycle, side="right") - 1

    def memory_bytes(self, value_bytes: int = 4) -> int:
        """Footprint of the scheduled stream (M_sch + Row_sch + Col_sch)."""
        c_total = self.total_colors
        row_bits = max(int(np.ceil(np.log2(max(self.l, 2)))), 1)
        col_bits = 32
        per_slot = value_bytes * 8 + row_bits + col_bits
        return int(np.ceil(c_total * self.l * per_slot / 8))
