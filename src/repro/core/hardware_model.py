"""Energy / bandwidth / resource model (paper §4, Tables 2 & 5).

Energy constants for 32-bit quantities, in pJ [Dally '21/'22, as cited]:
  off-chip read 64 / on-chip read 11.84 / off-chip write 64 / on-chip
  write 16 / FP mult or accumulate 10 / movement 160 (off-chip) and 0.95
  (on-chip) per mm.  Distances: 5 mm off-chip<->on-chip, 1 mm between 1D
  neighbours, 129 mm average across the GUST crossbar.

Dynamic power (FPGA synthesis, Table 2): 1D-256 35.3 W, GUST-256 56.9 W,
GUST-87 16.8 W, GUST-8 3.4 W; Serpens 46.2 W.  Clocks: GUST/1D 96 MHz,
Serpens 223 MHz.

Bandwidth (§3.3): a length-l GUST streams (32+32+log2 l)·l + 1 bits per
cycle (matrix values, vector values, row indices, dump) — 18 433 bits for
l = 256, i.e. 224 GB/s at 96 MHz, matching the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from .formats import COOMatrix, GustSchedule

__all__ = [
    "EnergyConstants",
    "HardwareSpec",
    "GUST_256",
    "GUST_87",
    "GUST_8",
    "SYSTOLIC_1D_256",
    "SERPENS",
    "gust_energy_joules",
    "systolic_1d_energy_joules",
    "required_bandwidth_bits_per_s",
    "execution_seconds",
]


@dataclasses.dataclass(frozen=True)
class EnergyConstants:
    """pJ per 32-bit quantity."""

    read_off: float = 64.0
    read_on: float = 11.84
    write_off: float = 64.0
    write_on: float = 16.0
    flop: float = 10.0  # FP multiply or accumulate
    move_off_per_mm: float = 160.0
    move_on_per_mm: float = 0.95
    dist_off_mm: float = 5.0
    dist_1d_mm: float = 1.0
    dist_gust_mm: float = 129.0  # average crossbar traversal


PJ = 1e-12
DEFAULT_ENERGY = EnergyConstants()


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    length: int
    freq_hz: float
    dynamic_power_w: float
    registers: int
    luts: int
    dsps: int

    @property
    def max_bandwidth_bits_per_s(self) -> float:
        return required_bandwidth_bits_per_s(self.length, self.freq_hz)


def required_bandwidth_bits_per_s(l: int, freq_hz: float = 96e6) -> float:
    """§3.3: (32 matrix + 32 vector + log2(l) row-index) bits per lane plus
    the dump wire, per cycle."""
    row_bits = max(int(np.ceil(np.log2(max(l, 2)))), 1)
    return ((64 + row_bits) * l + 1) * freq_hz


GUST_256 = HardwareSpec("gust-256", 256, 96e6, 56.9, 16_400, 888_000, 256)
GUST_87 = HardwareSpec("gust-87", 87, 96e6, 16.8, 5_600, 5_600, 174)
GUST_8 = HardwareSpec("gust-8", 8, 96e6, 3.4, 512, 5_000, 16)
SYSTOLIC_1D_256 = HardwareSpec("1d-256", 256, 96e6, 35.3, 8_200, 132_000, 256)
SERPENS = HardwareSpec("serpens", 256, 223e6, 46.2, 0, 0, 0)


def execution_seconds(cycles: float, spec: HardwareSpec) -> float:
    return cycles / spec.freq_hz


def gust_energy_joules(
    sched: GustSchedule,
    spec: HardwareSpec = GUST_256,
    consts: EnergyConstants = DEFAULT_ENERGY,
) -> float:
    """End-to-end SpMV energy for GUST (§4 accounting):

      * vector preload: n off-chip reads + moves + on-chip writes (the
        Buffer Filler stores the whole vector first), charged with device
        power over the transfer time;
      * scheduled stream: every slot (incl. padding — the stream is dense)
        moves value+col+row bits off-chip->on-chip, buffer write/read;
      * per real NZ: vector on-chip read, multiply, crossbar traversal,
        accumulate;
      * per output row: off-chip write;
      * dynamic power * execution time.
    """
    m, n = sched.shape
    l = spec.length
    c = consts
    slots = sched.total_colors * sched.l
    row_bits = max(int(np.ceil(np.log2(max(sched.l, 2)))), 1)
    words_per_slot = 1.0 + 1.0 + row_bits / 32.0  # value + col idx + row idx

    move_off = c.move_off_per_mm * c.dist_off_mm
    move_on = c.move_on_per_mm * c.dist_gust_mm

    vector_pj = n * (c.read_off + move_off + c.write_on)
    stream_pj = slots * words_per_slot * (c.read_off + move_off + c.write_on + c.read_on)
    compute_pj = sched.nnz * (c.read_on + c.flop + move_on + c.flop)
    output_pj = m * (c.write_off + move_off)

    exec_s = execution_seconds(sched.cycles, spec)
    preload_s = n / (spec.max_bandwidth_bits_per_s / 64.0)  # vector words
    power_j = spec.dynamic_power_w * (exec_s + preload_s)
    return (vector_pj + stream_pj + compute_pj + output_pj) * PJ + power_j


def systolic_1d_energy_joules(
    coo: COOMatrix,
    cycles: float,
    spec: HardwareSpec = SYSTOLIC_1D_256,
    consts: EnergyConstants = DEFAULT_ENERGY,
) -> float:
    """1D baseline: streams the *dense* m×n matrix (zeros included) plus the
    vector; neighbour-to-neighbour moves of 1 mm."""
    m, n = coo.shape
    c = consts
    move_off = c.move_off_per_mm * c.dist_off_mm
    move_on = c.move_on_per_mm * c.dist_1d_mm

    stream_pj = (m * n + n) * (c.read_off + move_off + c.write_on + c.read_on)
    compute_pj = coo.nnz * (2 * c.flop + move_on)
    # zeros still ripple through the array
    ripple_pj = (m * n - coo.nnz) * move_on
    output_pj = m * (c.write_off + move_off)

    power_j = spec.dynamic_power_w * execution_seconds(cycles, spec)
    return (stream_pj + compute_pj + ripple_pj + output_pj) * PJ + power_j
