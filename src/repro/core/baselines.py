"""Dataflow models of the prior designs GUST is compared against (paper §2,
Table 1, Fig. 7) plus the naive-scheduled GUST strawman.

These are *cycle-count models*, exactly how the paper itself evaluates the
designs ("the hardware efficiency of the designs were calculated based on
the dataflow of each specific matrix", §4).  Conventions (paper §4):

  * every design gets 256 multipliers + 256 adders, except Fafnir
    (448 adders + 128 multipliers);
  * utilization = #NZ-ops / (units * cycles) with #NZ-ops = 2*nnz
    (one multiply + one accumulate per nonzero) — this reduces to the
    paper's closed forms, e.g. 1D utilization == density.

Closed forms (Table 1):
  1D:        cycles = m*n/l + l + 1
  AT:        cycles = m*n/l + log2(l) + 1
  Flex-TPU:  ~3 * mapped / l per partition (reconfigure + compute + dump)
  Fafnir:    leaf-streaming + reduction-throughput bound, with an
             index-match stall factor calibrated to the paper's reported
             4.67% average utilization (documented approximation)
  GUST:      Σ_w C_w + 2, from the *actual* scheduler (core.scheduler)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from .formats import COOMatrix

__all__ = [
    "DesignReport",
    "model_1d",
    "model_adder_tree",
    "model_flex_tpu",
    "model_fafnir",
    "model_gust",
    "model_gust_naive",
    "all_designs",
]

#: Index-match stall calibration for Fafnir (paper reports 4.67% average
#: utilization for length-128 Fafnir => ~21x slowdown over perfect leaf
#: streaming; log2(128)/4 * KAPPA ~= 21).
FAFNIR_STALL_KAPPA = 12.2


@dataclasses.dataclass(frozen=True)
class DesignReport:
    design: str
    cycles: float
    units: int
    nnz: int

    @property
    def utilization(self) -> float:
        return 2.0 * self.nnz / (self.units * self.cycles) if self.cycles else 0.0


def model_1d(coo: COOMatrix, l: int = 256) -> DesignReport:
    """1D systolic array [17]: the dense stream costs m*n/l + drain."""
    m, n = coo.shape
    cycles = (m * n) / l + l + 1
    return DesignReport("1d", cycles, 2 * l, coo.nnz)


def model_adder_tree(coo: COOMatrix, l: int = 256) -> DesignReport:
    """Balanced adder tree [4]: same dense stream, log-depth drain."""
    m, n = coo.shape
    cycles = (m * n) / l + np.log2(l) + 1
    return DesignReport("adder_tree", cycles, 2 * l - 1, coo.nnz)


def model_flex_tpu(coo: COOMatrix, l_grid: int = 16) -> DesignReport:
    """Flex-TPU [10]: NZ elements + row separators packed into l×l grids;
    each partition costs ~3l cycles (reconfigure / compute / dump).

    With the paper's resource normalization (256 mult + 256 add) the grid
    is 16×16 = 256 MAC PEs."""
    mapped = coo.nnz + np.count_nonzero(coo.row_nnz())  # separators
    partitions = max(int(np.ceil(mapped / (l_grid * l_grid))), 1)
    cycles = 3.0 * l_grid * partitions
    return DesignReport("flex_tpu", cycles, 2 * l_grid * l_grid, coo.nnz)


def model_fafnir(coo: COOMatrix, l: int = 128) -> DesignReport:
    """Fafnir [1]: l leaf multipliers stream LIL columns (static column->
    leaf assignment, like GUST lanes but unscheduled), internal levels hold
    l/2 adders each (l/2*log2(l) total).  Reduction is gated by row-index
    matching; we model the match-stall with a calibrated multiplier.
    Max attainable utilization is 4/log2(l) (paper §2.2)."""
    lane_nnz = np.bincount(coo.cols % l, minlength=l)
    leaf_bound = float(lane_nnz.max()) if lane_nnz.size else 0.0
    reduce_bound = coo.nnz / (l / 2.0) * (np.log2(l) / 4.0) * FAFNIR_STALL_KAPPA
    cycles = max(leaf_bound, reduce_bound, 1.0)
    units = l + (l // 2) * int(np.log2(l))  # 128 mult + 448 adders
    return DesignReport("fafnir", cycles, units, coo.nnz)


def model_gust(
    coo: COOMatrix,
    l: int = 256,
    *,
    load_balance: bool = True,
    method: str = "fast",
    cache=None,
) -> DesignReport:
    """GUST with edge-coloring (and optionally load balancing): cycles from
    the real scheduler — this is the paper's own evaluation path.

    Goes through :func:`repro.core.plan.plan` (packing is lazy, so a
    cycle-count model never materializes blocks); pass a
    :class:`~repro.core.packing.ScheduleCache` to share schedules with an
    execution path over the same matrix."""
    from .plan import PlanConfig, plan

    p = plan(
        coo,
        PlanConfig(l=l, colorer=method, load_balance=load_balance),
        cache=cache,
    )
    name = "gust_ec_lb" if load_balance else "gust_ec"
    return DesignReport(name, float(p.sched.cycles), 2 * l, coo.nnz)


def model_gust_naive(coo: COOMatrix, l: int = 256) -> DesignReport:
    """GUST hardware with naive scheduling (§3.3): lanes are packed densely
    in column order with no coloring; a buffer row with row-collisions
    serializes at ~2 elements/cycle while every lane stalls.  Calibrated to
    the paper's stated crossover (naive < 1D beyond density 0.008 on
    16384² uniform matrices: 1/0.008 = 125 ≈ l/2 serialization)."""
    m, n = coo.shape
    num_windows = max(-(-m // l), 1)
    win = coo.rows // l
    lane = coo.cols % l
    lane_nnz = np.bincount(win * l + lane, minlength=num_windows * l).reshape(
        num_windows, l
    )
    cycles = 0.0
    for w in range(num_windows):
        depth = int(lane_nnz[w].max())
        if depth == 0:
            continue
        filled = lane_nnz[w]
        # Buffer row d holds sum(filled > d) elements; rows are effectively
        # random -> collision probability ~1 for >2 elements; serialize at 2
        # elements per cycle.
        for d in range(depth):
            k = int(np.count_nonzero(filled > d))
            cycles += 1.0 if k <= 1 else np.ceil(k / 2.0)
    return DesignReport("gust_naive", cycles + 2, 2 * l, coo.nnz)


def all_designs(
    coo: COOMatrix, l: int = 256, *, gust_method: str = "fast"
) -> Dict[str, DesignReport]:
    """Every design of Fig. 7 on one matrix."""
    return {
        r.design: r
        for r in (
            model_1d(coo, l),
            model_adder_tree(coo, l),
            model_flex_tpu(coo, 16),
            model_fafnir(coo, 128),
            model_gust_naive(coo, l),
            model_gust(coo, l, load_balance=False, method=gust_method),
            model_gust(coo, l, load_balance=True, method=gust_method),
        )
    }
