"""PlanStore — persistent, content-keyed GUST plan artifacts.

The paper's amortization story (§5.3) says the schedule is paid once per
matrix; :class:`~repro.core.packing.ScheduleCache` enforces that within a
process, but every *new* server process still re-paid the edge coloring
at weight-load time.  The store extends the amortization across process
boundaries: ``plan(matrix, cfg, store=PlanStore(dir))`` reads a
previously packed artifact straight off disk (zero coloring work — the
``sched_counters`` gate in ``benchmarks/sched_bench.py``) and writes one
back the first time a fresh plan materializes its pack.

Keying and versioning rules (ROADMAP §Scheduler + plan-store invariants):

* The key is ``sha1(matrix content hash | artifact-relevant config)``.
  Artifact-relevant means exactly the knobs that change the packed
  leaves/meta: ``l``, ``colorer``, ``load_balance``, ``c_blk``,
  ``layout``, ``waste_threshold``, ``value_dtype``, ``index_dtype``
  (:data:`ARTIFACT_KNOBS`).  Execution-time knobs (``backend``,
  ``gather``, ``pipeline``, ``interpret``, ``mesh_axis``) and the
  scheduler's ``workers`` count are **excluded** — the same artifact
  executes under any of them, bit-identically.
* Every file carries :data:`FORMAT_VERSION`; a version mismatch is a
  clean miss (counted in ``stale``), never an error — old files are
  simply re-written by the next warm-up.
* Writes are atomic **and durable** (``fsync`` of the same-directory
  temp file before ``os.replace``), so a crashed writer — or a host that
  loses power between write and rename — can leave a stray temp file
  but never a torn artifact at the final path.
* Loads are corruption-tolerant: *any* failure to parse (truncated file,
  bad magic, undecodable header, short array bytes) counts in
  ``corrupt`` and reads as a miss.
* Loads are I/O-fault-tolerant: transient ``OSError`` during the file
  read is retried with jittered exponential backoff
  (:func:`repro.resilience.retrying`); exhausted retries count in
  ``io_errors`` and read as a miss — the caller re-packs fresh
  (``stored → fresh`` fallback), never raises on the serving path.
* Fault-injection sites (``store.get``, ``store.get.corrupt``,
  ``store.put``, ``store.put.crash`` — ROADMAP §Resilience invariants)
  are threaded through ``get``/``put``; with no ``FaultPlan`` installed
  each is a single module-global check.

File format (one plan per file, ``<key>.gustplan``)::

    magic "GUSTPLAN" | header_len uint64-LE | header JSON | raw leaf bytes

The header holds ``{format_version, meta, config, tuning, summary,
arrays: [{name, dtype, shape, offset, nbytes}]}``; leaf bytes follow
concatenated in ``arrays`` order.  A bespoke container instead of
``np.savez`` because the value leaves may be ``bfloat16`` (ml_dtypes),
which numpy's own format can't round-trip; ``np.frombuffer`` with the
jax-resolved dtype can.
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.resilience import faults
from repro.resilience.retry import retrying

__all__ = ["PlanStore", "ARTIFACT_KNOBS", "FORMAT_VERSION"]

FORMAT_VERSION = 1

_MAGIC = b"GUSTPLAN"

#: The PlanConfig fields that determine the packed artifact's content.
ARTIFACT_KNOBS = (
    "l",
    "colorer",
    "load_balance",
    "c_blk",
    "layout",
    "waste_threshold",
    "value_dtype",
    "index_dtype",
)


def _tuplify(x):
    """JSON round-trips tuples (and the nested ``shape``) as lists; meta
    tuples must come back as tuples to compare/splice cleanly."""
    if isinstance(x, list):
        return tuple(_tuplify(v) for v in x)
    return x


class PlanStore:
    """Directory-backed store of packed plan artifacts.

    Thread-compatible and multi-process safe for its intended use
    (read-mostly fleets): concurrent writers of the same key race
    benignly — both write identical bytes and the atomic rename keeps
    whichever lands last.

    Counters: ``hits`` / ``misses`` (surfaced on ``GustPlan.cost()`` as
    ``store_hits`` / ``store_misses``), ``writes``, ``corrupt``
    (unparseable files), ``stale`` (format-version mismatches; a subset
    of misses), ``io_errors`` (reads that exhausted their retry budget;
    also a subset of misses), ``io_retries`` (transient read attempts
    that were retried).

    ``verify="load"`` opts into the static artifact verifier
    (:func:`repro.analysis.verify.verify`) on every successful parse: an
    artifact with any ``GUST-Pxx`` finding is treated exactly like an
    unparseable file — counted in ``corrupt``, read as a miss, never an
    exception — so a bit-rotted entry is re-packed instead of served.
    """

    def __init__(
        self,
        path: str,
        verify: str = "off",
        *,
        read_retries: int = 2,
        retry_base_s: float = 0.01,
        retry_budget_s: float = 2.0,
    ):
        if verify not in ("off", "load"):
            raise ValueError(f"verify must be 'off' or 'load', got {verify!r}")
        self.path = os.fspath(path)
        self.verify = verify
        self.read_retries = read_retries
        self.retry_base_s = retry_base_s
        self.retry_budget_s = retry_budget_s
        os.makedirs(self.path, exist_ok=True)
        self.hits = 0
        self.misses = 0
        self.writes = 0
        self.corrupt = 0
        self.stale = 0
        self.io_errors = 0
        self.io_retries = 0

    # -- keying --------------------------------------------------------------

    @staticmethod
    def config_token(config) -> str:
        """Canonical JSON of the artifact-relevant config subset."""
        knobs = {k: getattr(config, k) for k in ARTIFACT_KNOBS}
        return json.dumps(knobs, sort_keys=True, separators=(",", ":"))

    @classmethod
    def key(cls, matrix_key: str, config) -> str:
        h = hashlib.sha1()
        h.update(f"gust-plan|v{FORMAT_VERSION}|".encode())
        h.update(matrix_key.encode())
        h.update(b"|")
        h.update(cls.config_token(config).encode())
        return h.hexdigest()

    def _file(self, key: str) -> str:
        return os.path.join(self.path, f"{key}.gustplan")

    # -- write ---------------------------------------------------------------

    def put(
        self,
        key: str,
        spec: Dict,
        *,
        tuning: Optional[Dict] = None,
        summary: Optional[Dict] = None,
    ) -> str:
        """Persist a ``GustPlan.to_spec()`` dict (plus optional JSON-able
        ``tuning`` / ``summary`` sidecars) under ``key``.  Atomic and
        durable: the temp file is fsync'd before the rename, so readers
        only ever see complete files — even across a crash mid-write,
        which leaves at most a stray ``.tmp.*`` file (cleaned up here),
        never a torn ``.gustplan``."""
        faults.trip("store.put", tag=key)
        arrays = []
        chunks = []
        offset = 0
        for name in sorted(spec["leaves"]):
            arr = np.ascontiguousarray(np.asarray(spec["leaves"][name]))
            raw = arr.tobytes()
            arrays.append(
                {
                    "name": name,
                    "dtype": jnp.dtype(arr.dtype).name,
                    "shape": list(arr.shape),
                    "offset": offset,
                    "nbytes": len(raw),
                }
            )
            chunks.append(raw)
            offset += len(raw)
        header = json.dumps(
            {
                "format_version": FORMAT_VERSION,
                "meta": list(spec["meta"]),
                "config": spec.get("config"),
                "tuning": tuning,
                "summary": summary,
                "arrays": arrays,
            },
            sort_keys=True,
        ).encode()

        path = self._file(key)
        tmp = f"{path}.tmp.{os.getpid()}.{os.urandom(4).hex()}"
        try:
            with open(tmp, "wb") as f:
                f.write(_MAGIC)
                f.write(len(header).to_bytes(8, "little"))
                f.write(header)
                for raw in chunks:
                    f.write(raw)
                # Simulated crash point: data written but not yet durable.
                # A real crash here must never surface a torn final file —
                # the fsync + rename ordering below guarantees it.
                faults.trip("store.put.crash", tag=key)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self.writes += 1
        return path

    # -- read ----------------------------------------------------------------

    def get(self, key: str) -> Optional[Dict]:
        """Load the record stored under ``key``: ``{"spec": {leaves, meta,
        config}, "tuning", "summary"}`` — or None (miss) when absent,
        version-stale, or corrupt.  Leaves come back as numpy arrays at
        their exact stored dtypes (bfloat16 included)."""
        path = self._file(key)
        if not os.path.exists(path):
            self.misses += 1
            return None
        try:
            blob = self._read_blob(key, path)
        except Exception:
            # Transient I/O exhausted its backoff budget: a counted clean
            # miss — the caller re-packs fresh (stored -> fresh fallback).
            self.io_errors += 1
            self.misses += 1
            return None
        try:
            spec = faults.trip("store.get.corrupt", tag=key)
            if spec is not None and blob:
                # Deterministic header corruption (a payload flip could
                # parse silently): must land as a counted corrupt miss.
                torn = bytearray(blob)
                torn[0] ^= 0xFF
                blob = bytes(torn)
            if blob[: len(_MAGIC)] != _MAGIC:
                raise ValueError("bad magic")
            hlen_at = len(_MAGIC)
            hlen = int.from_bytes(blob[hlen_at : hlen_at + 8], "little")
            body_at = hlen_at + 8 + hlen
            header = json.loads(blob[hlen_at + 8 : body_at].decode())
            if header.get("format_version") != FORMAT_VERSION:
                self.stale += 1
                self.misses += 1
                return None
            leaves = {}
            for rec in header["arrays"]:
                start = body_at + rec["offset"]
                stop = start + rec["nbytes"]
                if stop > len(blob):
                    raise ValueError("truncated array bytes")
                leaves[rec["name"]] = np.frombuffer(
                    blob[start:stop], dtype=jnp.dtype(rec["dtype"])
                ).reshape(rec["shape"])
            spec = {
                "leaves": leaves,
                "meta": _tuplify(header["meta"]),
                "config": header.get("config"),
            }
        except Exception:
            self.corrupt += 1
            self.misses += 1
            return None
        if self.verify == "load":
            try:
                from repro.analysis.verify import verify as _verify

                findings = _verify(leaves, spec["meta"])
            except Exception:
                findings = None  # verifier crash != corrupt artifact
            if findings:
                self.corrupt += 1
                self.misses += 1
                return None
        self.hits += 1
        return {
            "spec": spec,
            "tuning": header.get("tuning"),
            "summary": header.get("summary"),
        }

    def _read_blob(self, key: str, path: str) -> bytes:
        """Read the raw container bytes, retrying transient I/O errors
        with jittered exponential backoff (bounded by
        ``retry_budget_s``).  Each attempt passes through the
        ``store.get`` fault site, so an injected ``times=N`` OSError
        proves the first ``N`` attempts fail and the ``N+1``-th serves."""

        def attempt():
            faults.trip("store.get", tag=key)
            with open(path, "rb") as f:
                return f.read()

        def count_retry(_attempt, _err):
            self.io_retries += 1

        return retrying(
            attempt,
            max_retries=self.read_retries,
            retry_on=(OSError, faults.FaultError),
            on_retry=count_retry,
            base_delay=self.retry_base_s,
            max_elapsed=self.retry_budget_s,
            seed=0,
        )()

    # -- introspection -------------------------------------------------------

    def keys(self):
        """Stored keys, sorted — what ``python -m repro.analysis verify``
        iterates."""
        return sorted(
            name[: -len(".gustplan")]
            for name in os.listdir(self.path)
            if name.endswith(".gustplan")
        )

    def __contains__(self, key: str) -> bool:
        return os.path.exists(self._file(key))

    def __len__(self) -> int:
        return sum(
            1 for name in os.listdir(self.path) if name.endswith(".gustplan")
        )

    def stats(self) -> Dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "writes": self.writes,
            "corrupt": self.corrupt,
            "stale": self.stale,
            "io_errors": self.io_errors,
            "io_retries": self.io_retries,
            "entries": len(self),
        }

    def __repr__(self) -> str:
        return f"PlanStore({self.path!r}, entries={len(self)})"
