"""Canonical ragged→packed conversion for the GUST scheduled format.

This module is the single home of the packed scheduled format: every
execution path (pure-jnp oracle, Pallas kernel, distributed row-window
split, LM serving) consumes :class:`PackedSchedule` built here.  The
conversion is fully vectorized — one scatter by ``window_starts``-derived
global indices instead of a Python loop over windows — so packing is
O(nnz) numpy work even for schedules with 10⁵ windows.

Scheduled format lifecycle
--------------------------

1. **Schedule (ragged).**  ``core.scheduler.schedule`` edge-colors the
   bipartite window graphs and emits a :class:`~repro.core.formats.
   GustSchedule`: three ``(C_total, l)`` arrays plus the per-window color
   prefix ``window_starts``.  Window ``w`` owns the global cycle rows
   ``window_starts[w]:window_starts[w+1]`` — a *ragged* layout (windows
   have different color counts).  Computed once per matrix; reused for
   every vector (paper §3.3/§5.3 amortization).

2. **Pack (fixed-shape).**  :func:`pack_schedule` pads every window to a
   common ``C_pad`` (max window colors rounded up to ``c_blk``) and
   reshapes to ``(W * C_pad, l)`` blocks — a JAX pytree of plain arrays
   that can be jit-ed over, sharded, donated, stacked across layers, and
   described by ``ShapeDtypeStruct`` (:func:`packed_spec`) without running
   the scheduler.

   Packed-format invariants (padding slots):
     * ``m_blk``  is ``0``      — padding contributes nothing to any sum;
     * ``col_blk`` holds the slot's own lane index — the vector gather
       stays in-bounds and preserves the straight-lane structure the
       fused kernel's gather relies on (``col % l ∈ {lane, l-1-lane}``);
     * ``row_blk`` is ``0``     — safe because the value is 0.
   Any transformation of a packed schedule (``repad_to``, layer stacking,
   window padding for the distributed split) must preserve these.

3. **Execute.**  ``kernels.ops.gust_spmm`` (Pallas or XLA),
   ``core.spmv.distributed_spmv`` (k parallel length-l GUSTs), and
   ``serving.gust_serve.decode_step_gust`` all stream the packed blocks.
   Serving stacks per-layer packs along a leading reps axis after
   :meth:`PackedSchedule.repad_to` equalizes ``C_pad``; the leaves/meta
   codec (:func:`packed_leaves` / :func:`packed_meta` /
   :func:`packed_from_leaves`) is the one wire format shared by
   ``gustify`` and the multi-pod dry-run specs.

4. **Cache.**  :class:`ScheduleCache` (module-level instance behind
   :func:`schedule_packed`) keys schedule+pack results on matrix
   *content*, so serving/benchmark paths that re-derive the same pruned
   matrix pay for scheduling exactly once.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .formats import COOMatrix, GustSchedule

__all__ = [
    "PackedSchedule",
    "pack_blocks",
    "pack_schedule",
    "packed_spec",
    "window_ids",
    "packed_leaves",
    "packed_meta",
    "packed_from_leaves",
    "stacked_leaf_specs",
    "ScheduleCache",
    "schedule_packed",
    "default_cache",
    "clear_cache",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedSchedule:
    """Fixed-shape GUST scheduled format (pytree).

    Arrays (leaves):
      m_blk:   (W * C_pad, l) values; 0.0 in padding slots.
      col_blk: (W * C_pad, l) int32 original column index; padding slots
               hold the slot's own lane (in-bounds, straight layout).
      row_blk: (W * C_pad, l) int32 adder index; 0 in padding slots.
      row_perm:(W * l,) int32 — original row of each scheduled row position
               (identity-extended past m).

    Static (aux):
      l, num_windows, c_pad, shape=(m, n), fusable (lane structure verified
      for the fused in-kernel gather).
    """

    m_blk: jnp.ndarray
    col_blk: jnp.ndarray
    row_blk: jnp.ndarray
    row_perm: jnp.ndarray
    l: int
    num_windows: int
    c_pad: int
    shape: Tuple[int, int]
    fusable: bool

    def tree_flatten(self):
        leaves = (self.m_blk, self.col_blk, self.row_blk, self.row_perm)
        aux = (self.l, self.num_windows, self.c_pad, self.shape, self.fusable)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    @property
    def seg_count(self) -> int:
        return -(-self.shape[1] // self.l)

    @property
    def stream_bytes(self) -> int:
        """HBM bytes of the scheduled stream (value f32 + col i32 + row i32)."""
        return int(self.m_blk.size) * (4 + 4 + 4)

    def repad_to(self, c_pad: int) -> "PackedSchedule":
        """Grow the per-window color padding to ``c_pad`` slots.

        Preserves every leaf dtype (a compact int16 stream stays int16)
        and the packed-format invariants: new value slots are 0, new
        column slots gather the slot's own lane, new row slots are 0.
        Used to equalize C_pad across stacked layers in serving.
        """
        if c_pad == self.c_pad:
            return self
        if c_pad < self.c_pad:
            raise ValueError(
                f"cannot shrink c_pad {self.c_pad} -> {c_pad} (real colors "
                "may live in the dropped slots)"
            )
        W, l, extra = self.num_windows, self.l, c_pad - self.c_pad

        def grow(a, pad_row):
            a3 = jnp.asarray(a).reshape(W, self.c_pad, l)
            pad = jnp.broadcast_to(
                jnp.asarray(pad_row, a3.dtype)[None, None, :], (W, extra, l)
            )
            return jnp.concatenate([a3, pad], axis=1).reshape(W * c_pad, l)

        return PackedSchedule(
            m_blk=grow(self.m_blk, np.zeros(l, np.float32)),
            col_blk=grow(self.col_blk, np.arange(l, dtype=np.int32)),
            row_blk=grow(self.row_blk, np.zeros(l, np.int32)),
            row_perm=self.row_perm,
            l=l,
            num_windows=W,
            c_pad=c_pad,
            shape=self.shape,
            fusable=self.fusable,
        )


def window_ids(sched: GustSchedule) -> np.ndarray:
    """Window id of each global schedule cycle, shape (max(C_total, 1),)."""
    wid = np.zeros(max(sched.total_colors, 1), dtype=np.int32)
    ids = np.repeat(
        np.arange(sched.num_windows, dtype=np.int32), sched.colors_per_window
    )
    wid[: ids.shape[0]] = ids
    return wid


def pack_blocks(
    sched: GustSchedule, c_blk: int = 8
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int, bool]:
    """Vectorized core of the ragged→packed conversion (host numpy).

    Returns ``(m_b, c_b, r_b, c_pad, fusable)`` with the three blocks of
    shape ``(W * c_pad, l)``.  Each real cycle row scatters to global
    destination ``window * C_pad + local_cycle`` in one fancy-indexed
    assignment — O(nnz) instead of a Python loop over windows.
    """
    l, W = sched.l, sched.num_windows
    ws = np.asarray(sched.window_starts)
    cpw = np.diff(ws)
    c_max = int(cpw.max()) if W else 1
    c_pad = max(-(-c_max // c_blk) * c_blk, c_blk)
    c_total = int(ws[-1]) if W else 0

    lane = np.arange(l, dtype=np.int32)
    # One backing allocation for all three blocks (f32 and i32 share the
    # itemsize, so the value plane is a reinterpreting view) — noticeably
    # cheaper than three separate page-faulted buffers at large W.
    buf = np.zeros((3, W * c_pad, l), dtype=np.int32)
    m_b = buf[0].view(np.float32)
    r_b = buf[1]
    c_b = buf[2]
    c_b[:] = lane  # padding slots gather v[lane] (packed-format invariant)
    if c_total:
        wid = np.repeat(np.arange(W, dtype=np.int64), cpw)
        dest = wid * c_pad + (np.arange(c_total, dtype=np.int64) - ws[wid])
        m_b[dest] = sched.m_sch[:c_total]
        r_b[dest] = sched.row_sch[:c_total]
        c_b[dest] = sched.col_sch[:c_total]

    # Verify the lane structure the fused gather relies on: every slot's
    # column offset is its lane or the reversed lane.  Checking the ragged
    # source is equivalent to checking the padded blocks (padding slots are
    # lane-valued by construction) and touches ~C_pad/C̄ fewer elements.
    src = sched.col_sch
    off = (src & (l - 1)) if l & (l - 1) == 0 else (src % l)
    fusable = bool(np.all((off == lane[None, :]) | (off == (l - 1 - lane)[None, :])))
    return m_b, c_b, r_b, c_pad, fusable


def pack_schedule(
    sched: GustSchedule, c_blk: int = 8, value_dtype=jnp.float32,
    index_dtype=jnp.int32,
) -> PackedSchedule:
    """Pad the ragged per-window schedule to (W, C_pad, l) blocks.

    C_pad = max window colors, rounded up to a multiple of ``c_blk``.  The
    padding cost is real on hardware too (lanes idle while the heaviest
    window drains) and is already counted by the cycle model through Eq. 1.
    """
    l, W = sched.l, sched.num_windows
    m, n = sched.shape
    m_b, c_b, r_b, c_pad, fusable = pack_blocks(sched, c_blk)

    row_perm = np.arange(W * l, dtype=np.int32)
    row_perm[: sched.row_perm.shape[0]] = sched.row_perm

    return PackedSchedule(
        m_blk=jnp.asarray(m_b, value_dtype),
        col_blk=jnp.asarray(c_b, index_dtype),
        row_blk=jnp.asarray(r_b, index_dtype),
        row_perm=jnp.asarray(row_perm),
        l=l,
        num_windows=W,
        c_pad=c_pad,
        shape=(m, n),
        fusable=fusable,
    )


def packed_spec(
    m: int,
    n: int,
    l: int,
    c_pad: int,
    value_dtype=jnp.float32,
    index_dtype=jnp.int32,
) -> PackedSchedule:
    """ShapeDtypeStruct stand-in for a PackedSchedule — used by the dry-run
    (no allocation).  ``c_pad`` is typically sized from the Eq. 9 bound:
    ``expected_colors_bound(n, density, l)`` rounded up."""
    W = max(-(-m // l), 1)
    sds = jax.ShapeDtypeStruct
    return PackedSchedule(
        m_blk=sds((W * c_pad, l), value_dtype),
        col_blk=sds((W * c_pad, l), index_dtype),
        row_blk=sds((W * c_pad, l), index_dtype),
        row_perm=sds((W * l,), jnp.int32),
        l=l,
        num_windows=W,
        c_pad=c_pad,
        shape=(m, n),
        fusable=True,
    )


# ---------------------------------------------------------------------------
# Leaves/meta codec — the one wire format for serving stacks and dry-runs.
# ---------------------------------------------------------------------------


def packed_leaves(p: PackedSchedule) -> Dict:
    """Array leaves of a packed schedule as a plain dict (jit-able pytree)."""
    return {
        "m_blk": p.m_blk,
        "col_blk": p.col_blk,
        "row_blk": p.row_blk,
        "row_perm": p.row_perm,
    }


def packed_meta(p: PackedSchedule) -> Tuple:
    """Static (non-array) part: ``(l, num_windows, c_pad, shape, fusable)``."""
    return (p.l, p.num_windows, p.c_pad, p.shape, p.fusable)


def packed_from_leaves(leaves: Dict, meta: Tuple) -> PackedSchedule:
    """Inverse of the codec: rebuild a PackedSchedule from leaves + meta."""
    l, w, c_pad, shape, fusable = meta
    return PackedSchedule(
        m_blk=leaves["m_blk"],
        col_blk=leaves["col_blk"],
        row_blk=leaves["row_blk"],
        row_perm=leaves["row_perm"],
        l=l, num_windows=w, c_pad=c_pad, shape=shape, fusable=fusable,
    )


def stacked_leaf_specs(proto: PackedSchedule, reps: int) -> Dict:
    """ShapeDtypeStruct leaves of ``reps`` layer packs stacked on axis 0.

    Works for both real-array and spec prototypes (only .shape/.dtype are
    read) — this is how ``dryrun_specs`` sizes the serving stack without
    running the scheduler."""
    return {
        k: jax.ShapeDtypeStruct((reps, *v.shape), v.dtype)
        for k, v in packed_leaves(proto).items()
    }


# ---------------------------------------------------------------------------
# Content-keyed schedule cache.
# ---------------------------------------------------------------------------


class ScheduleCache:
    """LRU cache of ``schedule(...)`` / ``pack_schedule(...)`` results,
    keyed by matrix *content* (sha1 of shape + COO triples) and the
    scheduling/packing parameters.

    The paper's amortization argument (§5.3) assumes the schedule is
    computed once per matrix; this cache enforces it across independent
    call sites (serving gustify, GustLinear, benchmarks) that re-derive
    the same pruned matrix.

    ``maxsize`` must cover a whole model conversion for the reuse to
    materialize: gustify inserts ``reps * len(mats)`` schedule entries
    plus as many packed entries (2 * 32 * 3 = 192 for a 32-layer stack),
    so the default is sized above that.  Entries hold device arrays —
    tens of MB each at LLM scale — for the process lifetime; call
    :func:`clear_cache` after a one-shot conversion to release them."""

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._store: "OrderedDict[Tuple, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def matrix_key(coo: COOMatrix) -> str:
        h = hashlib.sha1()
        h.update(repr(coo.shape).encode())
        for a in (coo.rows, coo.cols, coo.vals):
            arr = np.ascontiguousarray(a)
            h.update(str(arr.dtype).encode())
            h.update(arr.tobytes())
        return h.hexdigest()

    def _get(self, key: Tuple, build):
        if key in self._store:
            self.hits += 1
            self._store.move_to_end(key)
            return self._store[key]
        self.misses += 1
        val = build()
        self._store[key] = val
        while len(self._store) > self.maxsize:
            self._store.popitem(last=False)
        return val

    def _schedule_for_key(self, mk: str, coo: COOMatrix, l: int,
                          load_balance: bool, method: str) -> GustSchedule:
        from .scheduler import schedule as _schedule

        key = ("sched", mk, l, load_balance, method)
        return self._get(
            key,
            lambda: _schedule(coo, l, load_balance=load_balance, method=method),
        )

    def schedule(
        self, coo: COOMatrix, l: int, *, load_balance: bool = True,
        method: str = "fast",
    ) -> GustSchedule:
        return self._schedule_for_key(
            self.matrix_key(coo), coo, l, load_balance, method
        )

    def packed(
        self, coo: COOMatrix, l: int, *, load_balance: bool = True,
        method: str = "fast", c_blk: int = 8, value_dtype=jnp.float32,
        index_dtype=jnp.int32,
    ) -> Tuple[GustSchedule, PackedSchedule]:
        mk = self.matrix_key(coo)  # O(nnz) hash — computed once per call
        sched = self._schedule_for_key(mk, coo, l, load_balance, method)
        key = (
            "packed", mk, l, load_balance, method, c_blk,
            jnp.dtype(value_dtype).name, jnp.dtype(index_dtype).name,
        )
        packed = self._get(
            key,
            lambda: pack_schedule(
                sched, c_blk=c_blk, value_dtype=value_dtype,
                index_dtype=index_dtype,
            ),
        )
        return sched, packed

    def clear(self):
        self._store.clear()
        self.hits = self.misses = 0


default_cache = ScheduleCache()


def clear_cache() -> None:
    """Drop every cached schedule/packed entry of the module-level cache.

    Cached entries hold device arrays (tens of MB per LLM-scale matrix, up
    to ``maxsize`` of them) for the process lifetime; call this after a
    one-shot conversion (e.g. ``gustify`` at weight-load time) if the
    memory matters more than re-schedule speed."""
    default_cache.clear()


def schedule_packed(
    coo: COOMatrix, l: int, *, load_balance: bool = True, method: str = "fast",
    c_blk: int = 8, value_dtype=jnp.float32, index_dtype=jnp.int32,
    cache: Optional[ScheduleCache] = default_cache,
) -> Tuple[GustSchedule, PackedSchedule]:
    """schedule + pack in one call, served from ``cache`` (content-keyed;
    pass ``cache=None`` to bypass)."""
    if cache is None:
        from .scheduler import schedule as _schedule

        sched = _schedule(coo, l, load_balance=load_balance, method=method)
        return sched, pack_schedule(
            sched, c_blk=c_blk, value_dtype=value_dtype, index_dtype=index_dtype
        )
    return cache.packed(
        coo, l, load_balance=load_balance, method=method, c_blk=c_blk,
        value_dtype=value_dtype, index_dtype=index_dtype,
    )
