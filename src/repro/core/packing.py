"""Canonical ragged→packed conversion for the GUST scheduled format.

This module is the single home of the packed scheduled format: every
execution path (pure-jnp oracle, Pallas kernel, distributed row-window
split, LM serving) consumes :class:`PackedSchedule` built here.  The
conversion is fully vectorized — one scatter by ``window_starts``-derived
global indices instead of a Python loop over windows — so packing is
O(nnz) numpy work even for schedules with 10⁵ windows.

Scheduled format lifecycle
--------------------------

The front door for all of this is the plan/execute API
(:mod:`repro.core.plan`): ``repro.plan(matrix, PlanConfig(...))`` runs
steps 1-2 once (through the cache of step 4) and returns a
:class:`~repro.core.plan.GustPlan` whose ``.spmv``/``.spmm``/``.shard``
run step 3 any number of times — the paper's schedule-once/execute-many
contract as a type.  The steps themselves:

1. **Schedule (ragged).**  ``core.scheduler.schedule`` edge-colors the
   bipartite window graphs and emits a :class:`~repro.core.formats.
   GustSchedule`: three ``(C_total, l)`` arrays plus the per-window color
   prefix ``window_starts``.  Window ``w`` owns the global cycle rows
   ``window_starts[w]:window_starts[w+1]`` — a *ragged* layout (windows
   have different color counts).  Computed once per matrix; reused for
   every vector (paper §3.3/§5.3 amortization).

2. **Pack (fixed-shape).**  Two fixed-shape layouts share the padding
   invariants below:

   * :func:`pack_schedule` (*padded*) pads every window to a common
     ``C_pad`` (max window colors rounded up to ``c_blk``) and reshapes
     to ``(W * C_pad, l)`` blocks — a JAX pytree of plain arrays that can
     be jit-ed over, sharded, donated, stacked across layers, and
     described by ``ShapeDtypeStruct`` (:func:`packed_spec`) without
     running the scheduler.
   * :func:`pack_ragged` (*ragged block stream*) keeps only each window's
     actual ``max(ceil(C_w / c_blk), 1)`` cycle blocks, flattened into one
     ``(T_blk * c_blk, l)`` stream, plus scalar metadata derived from
     ``window_starts``: ``block_window`` (window id of each block,
     ``(T_blk,)``) and ``block_starts`` (per-window block prefix,
     ``(W + 1,)``).  On skewed matrices — where ``max_w C_w`` far exceeds
     the mean — this streams only real work instead of ``W * C_pad``
     mostly-zero rows.  :func:`pack_auto` picks between the two by the
     measured waste ratio ``(W * C_pad) / (T_blk * c_blk)``.

   Packed-format invariants (padding slots, BOTH layouts — in the ragged
   stream they apply to each window's final partial block and to the one
   all-padding block an empty window keeps so its accumulator still
   initializes/dumps):
     * ``m_blk``  is ``0``      — padding contributes nothing to any sum;
     * ``col_blk`` holds the slot's own lane index — the vector gather
       stays in-bounds and preserves the straight-lane structure the
       fused kernel's gather relies on (``col % l ∈ {lane, l-1-lane}``);
     * ``row_blk`` is ``0``     — safe because the value is 0.
   Ragged-stream metadata contract: blocks of one window are contiguous
   (``block_window`` is sorted), window ``w`` owns stream blocks
   ``block_starts[w]:block_starts[w+1]``, every window owns at least one
   block, and stream rows of block ``t`` are ``t*c_blk:(t+1)*c_blk``.
   Any transformation of either layout (``repad_to``,
   ``repad_to_blocks``, layer stacking, window padding for the
   distributed split) must preserve all of the above.

   Gather-locality leaves (PR 5).  Both layouts additionally carry a
   *segment-local* gather table so the kernels can stream only the x
   tiles a block actually references instead of holding all of x
   resident in VMEM:
     * ``seg_blk`` ``(T_blk, S_blk)`` int32 — the distinct column
       segments (``col // l``) referenced by each ``(c_blk, l)`` stream
       block, sorted ascending, padded to the per-schedule fixed
       ``S_blk`` with segment 0 (always in-bounds);
     * ``col_loc`` — ``col_blk`` remapped to block-local segment ids:
       ``col_loc = local_seg * l + (col_blk % l)`` where ``local_seg``
       is the column's position in its block's ``seg_blk`` row.  The
       lane structure is preserved (``col_loc % l == col_blk % l``) and
       padding slots still hold the slot's own lane (segment 0 sorts
       first, so lane-valued padding columns map to local slot 0).
   ``S_blk`` is a static aux field; the tables are a pure function of
   ``(col_blk, l, c_blk)`` (:func:`_local_gather_tables`), which is how
   ``repad_to`` / ``repad_to_blocks`` stay consistent: they recompute
   the tables on the grown stream (bit-identical on the unchanged
   blocks) and never shrink ``S_blk``.  :func:`resolve_gather` is the
   one ``gather="auto"`` decision point: the segment-local path wins
   when ``S_blk / seg_count`` is below the locality ratio.

3. **Execute.**  ``kernels.ops.execute_spmm`` (Pallas or XLA, padded
   *and* ragged, resident or segment-local gather — the latter streams
   only each block's ``S_blk`` referenced x tiles via the pack-time
   ``seg_blk`` table instead of holding all of x in VMEM) streams the
   packed blocks; every entry point reaches it
   through :meth:`GustPlan.spmm`/:meth:`GustPlan.spmv` — including
   sharded execution (:meth:`GustPlan.shard`: k parallel length-l GUSTs
   over window ranges balanced by block count) and
   ``serving.gust_serve.decode_step_gust``.  Serving stacks per-layer
   plans with :meth:`GustPlan.stack` (equalizing stream length via
   :meth:`PackedSchedule.repad_to` / :meth:`RaggedSchedule.
   repad_to_blocks`); the leaves/meta codec (:func:`packed_leaves` /
   :func:`packed_meta` / :func:`packed_from_leaves`, and the ragged
   twins) backs :meth:`GustPlan.to_spec`/``from_spec`` — the one wire
   format shared by ``gustify`` and the multi-pod dry-run specs.

4. **Cache.**  :class:`ScheduleCache` (module-level ``default_cache``
   that :func:`repro.core.plan.plan` schedules and packs through) keys
   results on matrix *content*, so serving/benchmark paths that
   re-derive the same pruned matrix pay for scheduling exactly once.
   The cache is a bounded LRU (``maxsize``, evictions counted); for
   amortization *across processes* the same content keys feed
   :class:`repro.core.plan_store.PlanStore` — ``plan(..., store=...)``
   reads a previously packed artifact straight off disk (write-behind
   happens when a fresh plan first materializes its pack), so a server
   fleet warm-starts without rescheduling or repacking at all.  For
   drifting sparsity within a process, :func:`splice_ragged_blocks`
   re-packs only the windows an incremental reschedule dirtied and
   copies every clean window's blocks bitwise from the old stream.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
from collections import OrderedDict
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .formats import COOMatrix, GustSchedule

__all__ = [
    "PackedSchedule",
    "RaggedSchedule",
    "pack_blocks",
    "pack_schedule",
    "pack_ragged",
    "pack_auto",
    "DEFAULT_WASTE_THRESHOLD",
    "DEFAULT_LOCALITY_RATIO",
    "DEFAULT_LOCAL_MIN_SEGS",
    "resolve_layout",
    "resolve_gather",
    "ragged_waste_ratio",
    "packed_spec",
    "ragged_spec",
    "window_ids",
    "packed_leaves",
    "packed_meta",
    "packed_from_leaves",
    "ragged_leaves",
    "ragged_meta",
    "ragged_from_leaves",
    "stacked_leaf_specs",
    "splice_ragged_blocks",
    "ScheduleCache",
    "DEFAULT_SCHEDULE_CACHE_SIZE",
    "schedule_packed",
    "default_cache",
    "clear_cache",
    "DEFAULT_TUNE_IMPROVEMENT",
    "resolve_tuning",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedSchedule:
    """Fixed-shape GUST scheduled format (pytree).

    Arrays (leaves):
      m_blk:   (W * C_pad, l) values; 0.0 in padding slots.
      col_blk: (W * C_pad, l) int32 original column index; padding slots
               hold the slot's own lane (in-bounds, straight layout).
      row_blk: (W * C_pad, l) int32 adder index; 0 in padding slots.
      row_perm:(W * l,) int32 — original row of each scheduled row position
               (identity-extended past m).
      seg_blk: (T_blk, S_blk) int32 — per-(c_blk, l)-block distinct column
               segments (sorted; padded with segment 0).  T_blk =
               W * C_pad / c_blk.
      col_loc: (W * C_pad, l) col_blk remapped to block-local segment ids
               (``local_seg * l + col % l``; index dtype preserved).
      scale_blk: (T_blk,) f32 per-block dequantization scales, or ``None``
               on unquantized packs.  Present exactly when ``m_blk`` is
               int8: the stored value of slot ``(r, j)`` is
               ``m[r, j] = q[r, j] * scale_blk[r // c_blk]`` with dequant
               fused into the kernel accumulate in f32.  Padding slots
               quantize to exactly 0 (scale of an all-zero block is 1.0),
               so the zero-contribution invariant survives quantization.

    Static (aux):
      l, num_windows, c_pad, shape=(m, n), fusable (lane structure verified
      for the fused in-kernel gather), c_blk (the block height the gather
      tables were built for), s_blk, identity_perm (row_perm is the
      identity — the executor skips the output scatter).
    """

    m_blk: jnp.ndarray
    col_blk: jnp.ndarray
    row_blk: jnp.ndarray
    row_perm: jnp.ndarray
    seg_blk: jnp.ndarray
    col_loc: jnp.ndarray
    l: int
    num_windows: int
    c_pad: int
    shape: Tuple[int, int]
    fusable: bool
    c_blk: int
    s_blk: int
    identity_perm: bool
    scale_blk: Optional[jnp.ndarray] = None

    def tree_flatten(self):
        leaves = (self.m_blk, self.col_blk, self.row_blk, self.row_perm,
                  self.seg_blk, self.col_loc, self.scale_blk)
        aux = (self.l, self.num_windows, self.c_pad, self.shape, self.fusable,
               self.c_blk, self.s_blk, self.identity_perm)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        *arr, scale = leaves
        return cls(*arr, *aux, scale_blk=scale)

    @property
    def seg_count(self) -> int:
        return -(-self.shape[1] // self.l)

    @property
    def quantized(self) -> bool:
        return self.scale_blk is not None

    @property
    def stream_bytes(self) -> int:
        """HBM bytes of the scheduled stream (value + col + row leaves at
        their actual dtypes — an int8 value plane is a quarter of the f32
        one) plus the per-block scales when quantized."""
        extra = (self.scale_blk,) if self.scale_blk is not None else ()
        return sum(
            int(a.size) * jnp.dtype(a.dtype).itemsize
            for a in (self.m_blk, self.col_blk, self.row_blk) + extra
        )

    def repad_to(self, c_pad: int) -> "PackedSchedule":
        """Grow the per-window color padding to ``c_pad`` slots.

        Preserves every leaf dtype (a compact int16 stream stays int16)
        and the packed-format invariants: new value slots are 0, new
        column slots gather the slot's own lane, new row slots are 0.
        Used to equalize C_pad across stacked layers in serving.
        """
        if c_pad == self.c_pad:
            return self
        if c_pad < self.c_pad:
            raise ValueError(
                f"cannot shrink c_pad {self.c_pad} -> {c_pad} (real colors "
                "may live in the dropped slots)"
            )
        W, l, extra = self.num_windows, self.l, c_pad - self.c_pad

        def grow(a, pad_row):
            a3 = jnp.asarray(a).reshape(W, self.c_pad, l)
            pad = jnp.broadcast_to(
                jnp.asarray(pad_row, a3.dtype)[None, None, :], (W, extra, l)
            )
            return jnp.concatenate([a3, pad], axis=1).reshape(W * c_pad, l)

        col_grown = grow(self.col_blk, np.arange(l, dtype=np.int32))
        # gather tables are a pure function of (col, l, c_blk): recomputing
        # on the grown stream is bit-identical on the unchanged blocks, and
        # S_blk never shrinks (all-lane padding rows reference only seg 0)
        seg_blk, col_loc, s_blk = _local_gather_tables(
            np.asarray(col_grown), l, self.c_blk, s_min=self.s_blk
        )
        scale = self.scale_blk
        if scale is not None:
            # scales are per-(c_blk, l) block: the grown padding must land
            # on whole new blocks for the old blocks' scales to stay put
            if c_pad % self.c_blk or self.c_pad % self.c_blk:
                raise ValueError(
                    f"quantized repad_to requires c_pad multiples of c_blk="
                    f"{self.c_blk}, got {self.c_pad} -> {c_pad}"
                )
            old_bpw = self.c_pad // self.c_blk
            new_bpw = c_pad // self.c_blk
            s2 = jnp.asarray(scale).reshape(W, old_bpw)
            pad = jnp.ones((W, new_bpw - old_bpw), s2.dtype)  # all-zero blocks
            scale = jnp.concatenate([s2, pad], axis=1).reshape(-1)
        return PackedSchedule(
            m_blk=grow(self.m_blk, np.zeros(l, np.float32)),
            col_blk=col_grown,
            row_blk=grow(self.row_blk, np.zeros(l, np.int32)),
            row_perm=self.row_perm,
            seg_blk=jnp.asarray(seg_blk),
            col_loc=jnp.asarray(col_loc, self.col_loc.dtype),
            l=l,
            num_windows=W,
            c_pad=c_pad,
            shape=self.shape,
            fusable=self.fusable,
            c_blk=self.c_blk,
            s_blk=s_blk,
            identity_perm=self.identity_perm,
            scale_blk=scale,
        )

    def repad_seg_to(self, s_blk: int) -> "PackedSchedule":
        """Widen the per-block segment table to ``s_blk`` slots (padding
        with segment 0, which no ``col_loc`` entry references).  Used to
        equalize ``S_blk`` across stacked serving layers."""
        return _repad_seg(self, s_blk)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RaggedSchedule:
    """Ragged color-block stream of the GUST scheduled format (pytree).

    Unlike :class:`PackedSchedule` (every window padded to the global
    ``C_pad``), the stream holds only each window's actual
    ``max(ceil(C_w / c_blk), 1)`` cycle blocks, so skewed matrices never
    execute the dead padding cycles of their light windows.

    Arrays (leaves):
      m_blk:        (T_blk * c_blk, l) values; 0.0 in padding slots (the
                    final partial block of each window + the single
                    all-padding block of an empty window).
      col_blk:      (T_blk * c_blk, l) int original column index; padding
                    slots hold the slot's own lane.
      row_blk:      (T_blk * c_blk, l) int adder index; 0 in padding slots.
      row_perm:     (W * l,) int32 — original row of each scheduled row
                    position (identity-extended past m).
      seg_blk:      (T_blk, S_blk) int32 — per-block distinct column
                    segments (sorted; padded with segment 0).
      col_loc:      (T_blk * c_blk, l) col_blk remapped to block-local
                    segment ids (index dtype preserved).
      block_window: (T_blk,) int32 — window id of each stream block
                    (sorted; blocks of one window are contiguous).
      block_starts: (W + 1,) int32 — per-window block prefix: window ``w``
                    owns stream blocks ``block_starts[w]:block_starts[w+1]``
                    (always at least one).
      scale_blk:    (T_blk,) f32 per-block dequantization scales, or
                    ``None`` on unquantized packs (present exactly when
                    ``m_blk`` is int8; padding quantizes to 0 — same
                    contract as :class:`PackedSchedule`).

    Static (aux): l, num_windows, c_blk, num_blocks (= T_blk), shape,
    fusable, s_blk, identity_perm.
    """

    m_blk: jnp.ndarray
    col_blk: jnp.ndarray
    row_blk: jnp.ndarray
    row_perm: jnp.ndarray
    seg_blk: jnp.ndarray
    col_loc: jnp.ndarray
    block_window: jnp.ndarray
    block_starts: jnp.ndarray
    l: int
    num_windows: int
    c_blk: int
    num_blocks: int
    shape: Tuple[int, int]
    fusable: bool
    s_blk: int
    identity_perm: bool
    scale_blk: Optional[jnp.ndarray] = None

    def tree_flatten(self):
        leaves = (self.m_blk, self.col_blk, self.row_blk, self.row_perm,
                  self.seg_blk, self.col_loc,
                  self.block_window, self.block_starts, self.scale_blk)
        aux = (self.l, self.num_windows, self.c_blk, self.num_blocks,
               self.shape, self.fusable, self.s_blk, self.identity_perm)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        *arr, scale = leaves
        return cls(*arr, *aux, scale_blk=scale)

    @property
    def seg_count(self) -> int:
        return -(-self.shape[1] // self.l)

    @property
    def quantized(self) -> bool:
        return self.scale_blk is not None

    @property
    def streamed_slots(self) -> int:
        """(cycle, lane) slots the execution path actually streams."""
        return self.num_blocks * self.c_blk * self.l

    @property
    def stream_bytes(self) -> int:
        """HBM bytes of the scheduled stream (value + col + row leaves at
        their actual dtypes — a compact bf16/int16 stream is ~half the
        f32/i32 one, an int8 value plane a quarter) plus the scalar block
        metadata and the per-block scales when quantized."""
        extra = (self.scale_blk,) if self.scale_blk is not None else ()
        return sum(
            int(a.size) * jnp.dtype(a.dtype).itemsize
            for a in (self.m_blk, self.col_blk, self.row_blk,
                      self.block_window, self.block_starts) + extra
        )

    def repad_to_blocks(self, num_blocks: int) -> "RaggedSchedule":
        """Grow the stream to ``num_blocks`` blocks with all-padding
        trailing blocks (attributed to the last window, whose accumulator
        they extend by zero).  Preserves every leaf dtype and the padding
        invariants; used to equalize stream lengths across stacked
        serving layers."""
        if num_blocks == self.num_blocks:
            return self
        if num_blocks < self.num_blocks:
            raise ValueError(
                f"cannot shrink num_blocks {self.num_blocks} -> {num_blocks}"
                " (real cycles may live in the dropped blocks)"
            )
        l, extra = self.l, num_blocks - self.num_blocks
        rows = extra * self.c_blk
        lane = jnp.arange(l, dtype=self.col_blk.dtype)

        def grow(a, pad_row):
            pad = jnp.broadcast_to(
                jnp.asarray(pad_row, jnp.asarray(a).dtype)[None, :], (rows, l)
            )
            return jnp.concatenate([jnp.asarray(a), pad], axis=0)

        last_w = max(self.num_windows - 1, 0)
        bw = jnp.concatenate([
            jnp.asarray(self.block_window),
            jnp.full((extra,), last_w, self.block_window.dtype),
        ])
        bs = jnp.asarray(self.block_starts).at[-1].set(num_blocks)
        col_grown = grow(self.col_blk, lane)
        # recompute the gather tables on the grown stream (pure function of
        # the column content — bit-identical on the unchanged blocks; the
        # appended all-lane blocks reference only segment 0)
        seg_blk, col_loc, s_blk = _local_gather_tables(
            np.asarray(col_grown), l, self.c_blk, s_min=self.s_blk
        )
        scale = self.scale_blk
        if scale is not None:
            # appended blocks are all padding (value 0): scale 1.0
            scale = jnp.concatenate(
                [jnp.asarray(scale), jnp.ones((extra,), jnp.asarray(scale).dtype)]
            )
        return RaggedSchedule(
            m_blk=grow(self.m_blk, np.zeros(l, np.float32)),
            col_blk=col_grown,
            row_blk=grow(self.row_blk, np.zeros(l, np.int32)),
            row_perm=self.row_perm,
            seg_blk=jnp.asarray(seg_blk),
            col_loc=jnp.asarray(col_loc, self.col_loc.dtype),
            block_window=bw,
            block_starts=bs,
            l=l,
            num_windows=self.num_windows,
            c_blk=self.c_blk,
            num_blocks=num_blocks,
            shape=self.shape,
            fusable=self.fusable,
            s_blk=s_blk,
            identity_perm=self.identity_perm,
            scale_blk=scale,
        )

    def repad_seg_to(self, s_blk: int) -> "RaggedSchedule":
        """Widen the per-block segment table to ``s_blk`` slots (padding
        with segment 0).  The ragged twin of
        :meth:`PackedSchedule.repad_seg_to`."""
        return _repad_seg(self, s_blk)


def _repad_seg(packed, s_blk: int):
    """Shared ``repad_seg_to``: pad ``seg_blk`` columns with segment 0 —
    no ``col_loc`` entry maps to the new slots, so the gathered-but-unused
    tiles contribute nothing (the local kernels mask by local id)."""
    if s_blk == packed.s_blk:
        return packed
    if s_blk < packed.s_blk:
        raise ValueError(
            f"cannot shrink s_blk {packed.s_blk} -> {s_blk} (real segment "
            "ids may live in the dropped table slots)"
        )
    seg = jnp.asarray(packed.seg_blk)
    seg = jnp.concatenate(
        [seg, jnp.zeros((seg.shape[0], s_blk - packed.s_blk), seg.dtype)],
        axis=1,
    )
    return dataclasses.replace(packed, seg_blk=seg, s_blk=s_blk)


def window_ids(sched: GustSchedule) -> np.ndarray:
    """Window id of each global schedule cycle, shape (max(C_total, 1),)."""
    wid = np.zeros(max(sched.total_colors, 1), dtype=np.int32)
    ids = np.repeat(
        np.arange(sched.num_windows, dtype=np.int32), sched.colors_per_window
    )
    wid[: ids.shape[0]] = ids
    return wid


def pack_blocks(
    sched: GustSchedule, c_blk: int = 8
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int, bool]:
    """Vectorized core of the ragged→packed conversion (host numpy).

    Returns ``(m_b, c_b, r_b, c_pad, fusable)`` with the three blocks of
    shape ``(W * c_pad, l)``.  Each real cycle row scatters to global
    destination ``window * C_pad + local_cycle`` in one fancy-indexed
    assignment — O(nnz) instead of a Python loop over windows.
    """
    l, W = sched.l, sched.num_windows
    ws = np.asarray(sched.window_starts)
    cpw = np.diff(ws)
    c_max = int(cpw.max()) if W else 1
    c_pad = max(-(-c_max // c_blk) * c_blk, c_blk)
    c_total = int(ws[-1]) if W else 0

    lane = np.arange(l, dtype=np.int32)
    # One backing allocation for all three blocks (f32 and i32 share the
    # itemsize, so the value plane is a reinterpreting view) — noticeably
    # cheaper than three separate page-faulted buffers at large W.
    buf = np.zeros((3, W * c_pad, l), dtype=np.int32)
    m_b = buf[0].view(np.float32)
    r_b = buf[1]
    c_b = buf[2]
    c_b[:] = lane  # padding slots gather v[lane] (packed-format invariant)
    if c_total:
        wid = np.repeat(np.arange(W, dtype=np.int64), cpw)
        dest = wid * c_pad + (np.arange(c_total, dtype=np.int64) - ws[wid])
        m_b[dest] = sched.m_sch[:c_total]
        r_b[dest] = sched.row_sch[:c_total]
        c_b[dest] = sched.col_sch[:c_total]

    return m_b, c_b, r_b, c_pad, _fusable(sched)


def _fusable(sched: GustSchedule) -> bool:
    """Verify the lane structure the fused gather relies on: every slot's
    column offset is its lane or the reversed lane.  Checking the ragged
    source is equivalent to checking either packed layout (padding slots
    are lane-valued by construction) and touches fewer elements."""
    l = sched.l
    lane = np.arange(l, dtype=np.int32)
    src = sched.col_sch
    off = (src & (l - 1)) if (l & (l - 1)) == 0 else (src % l)
    return bool(np.all((off == lane[None, :]) | (off == (l - 1 - lane)[None, :])))


def _quantize_stream(
    m_b: np.ndarray, c_blk: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-block symmetric int8 quantization of a packed value stream.

    For each ``(c_blk, l)`` block: ``scale = absmax / 127`` (1.0 for
    all-zero blocks, so padding blocks stay well-defined) and
    ``q = clip(rint(v / scale), -127, 127)`` int8.  Exact zeros — every
    padding slot — quantize to exactly 0 regardless of the block scale,
    which is what preserves the packed-format zero-contribution
    invariant.  The dequant semantics the kernels and oracles share
    bit-exactly: ``v̂ = float32(q) * scale`` (both sides perform this one
    f32 multiply, so kernel and oracle agree bitwise).

    Returns ``(q (rows, l) int8, scale (rows // c_blk,) f32)``.
    """
    m_b = np.ascontiguousarray(m_b, np.float32)
    rows, l = m_b.shape
    if rows % c_blk:
        raise ValueError(f"stream rows {rows} not a multiple of c_blk {c_blk}")
    blocks = m_b.reshape(rows // c_blk, c_blk * l)
    absmax = np.abs(blocks).max(axis=1)
    scale = np.where(absmax > 0, absmax / 127.0, 1.0).astype(np.float32)
    q = np.clip(
        np.rint(blocks / scale[:, None].astype(np.float32)), -127, 127
    ).astype(np.int8)
    return q.reshape(rows, l), scale


def _is_int8(value_dtype) -> bool:
    return jnp.dtype(value_dtype) == jnp.dtype(jnp.int8)


def _local_gather_tables(
    col: np.ndarray, l: int, c_blk: int, s_min: int = 1
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Segment-local gather tables of a packed column stream.

    For each ``(c_blk, l)`` block of ``col`` (shape ``(rows, l)``), the
    distinct column segments (``col // l``) it references, sorted
    ascending, padded with segment 0 to the common width
    ``S_blk = max(max distinct per block, s_min)`` — plus the columns
    remapped to block-local segment ids:
    ``col_loc = local_seg * l + col % l``.

    A pure function of ``(col, l, c_blk)``: recomputing on a grown stream
    reproduces the original blocks' tables bitwise, which is what makes
    ``repad_to`` / ``repad_to_blocks`` safe.  Lane-valued padding columns
    live in segment 0, which sorts first, so padding slots always map to
    local slot 0 and ``col_loc`` padding rows equal the lane index.
    Returns ``(seg_blk (T, S_blk) int32, col_loc (rows, l) int32, S_blk)``.
    """
    col = np.asarray(col, np.int64)
    rows = col.shape[0]
    if rows % c_blk:  # virtually pad to a block multiple with lane rows
        lane_rows = np.broadcast_to(
            np.arange(l, dtype=np.int64), (c_blk - rows % c_blk, l)
        )
        col = np.concatenate([col, lane_rows], axis=0)
    t_blk = col.shape[0] // c_blk
    segs = (col // l).reshape(t_blk, c_blk * l)
    order = np.argsort(segs, axis=1, kind="stable")
    srt = np.take_along_axis(segs, order, axis=1)
    first = np.ones_like(srt, dtype=bool)
    if srt.shape[1] > 1:
        first[:, 1:] = srt[:, 1:] != srt[:, :-1]
    loc_sorted = np.cumsum(first, axis=1) - 1  # local id per sorted slot
    loc = np.empty_like(loc_sorted)
    np.put_along_axis(loc, order, loc_sorted, axis=1)
    counts = first.sum(axis=1)
    s_blk = int(max(counts.max() if t_blk else 1, s_min, 1))
    seg_blk = np.zeros((t_blk, s_blk), np.int32)
    r_idx = np.nonzero(first)[0]
    seg_blk[r_idx, loc_sorted[first]] = srt[first]
    col_loc = (
        loc.reshape(col.shape[0], l) * l + (col - (col // l) * l)
    ).astype(np.int32)[:rows]
    return seg_blk, col_loc, s_blk


def _extended_row_perm(sched: GustSchedule) -> np.ndarray:
    """row_perm identity-extended to the full W*l scheduled row positions
    (shared by both fixed-shape layouts)."""
    row_perm = np.arange(sched.num_windows * sched.l, dtype=np.int32)
    row_perm[: sched.row_perm.shape[0]] = sched.row_perm
    return row_perm


def pack_schedule(
    sched: GustSchedule, c_blk: int = 8, value_dtype=jnp.float32,
    index_dtype=jnp.int32,
) -> PackedSchedule:
    """Pad the ragged per-window schedule to (W, C_pad, l) blocks.

    C_pad = max window colors, rounded up to a multiple of ``c_blk``.  The
    padding cost is real on hardware too (lanes idle while the heaviest
    window drains) and is already counted by the cycle model through Eq. 1.
    """
    l, W = sched.l, sched.num_windows
    m, n = sched.shape
    m_b, c_b, r_b, c_pad, fusable = pack_blocks(sched, c_blk)
    row_perm = _extended_row_perm(sched)
    seg_blk, col_loc, s_blk = _local_gather_tables(c_b, l, c_blk)
    scale = None
    if _is_int8(value_dtype):
        m_b, scale = _quantize_stream(m_b, c_blk)
        scale = jnp.asarray(scale)
        value_dtype = jnp.int8

    return PackedSchedule(
        m_blk=jnp.asarray(m_b, value_dtype),
        col_blk=jnp.asarray(c_b, index_dtype),
        row_blk=jnp.asarray(r_b, index_dtype),
        row_perm=jnp.asarray(row_perm),
        seg_blk=jnp.asarray(seg_blk),
        col_loc=jnp.asarray(col_loc, index_dtype),
        l=l,
        num_windows=W,
        c_pad=c_pad,
        shape=(m, n),
        fusable=fusable,
        c_blk=c_blk,
        s_blk=s_blk,
        identity_perm=bool(
            np.array_equal(row_perm, np.arange(W * l, dtype=np.int32))
        ),
        scale_blk=scale,
    )


def _ragged_block_layout(
    sched: GustSchedule, c_blk: int
) -> Tuple[np.ndarray, np.ndarray, int]:
    """(blocks_per_window, block_starts, num_blocks) of the ragged stream.

    Every window keeps ``ceil(C_w / c_blk)`` blocks, floored at one so
    empty windows still own a block (their accumulator tile must
    initialize and dump once — the hardware's minimum one dump per
    window)."""
    cpw = np.diff(np.asarray(sched.window_starts))
    bpw = np.maximum(-(-cpw // c_blk), 1).astype(np.int64)
    block_starts = np.zeros(sched.num_windows + 1, dtype=np.int64)
    np.cumsum(bpw, out=block_starts[1:])
    return bpw, block_starts, int(block_starts[-1])


def ragged_waste_ratio(sched: GustSchedule, c_blk: int = 8) -> float:
    """Padding waste of the padded layout relative to the ragged stream:
    ``(W * C_pad) / (T_blk * c_blk)``.  1.0 means every window already has
    the max color count (padding streams nothing extra); >= ~2 means the
    padded path spends most of its stream on dead cycles."""
    l, W = sched.l, sched.num_windows
    cpw = np.diff(np.asarray(sched.window_starts))
    c_max = int(cpw.max()) if W else 1
    c_pad = max(-(-c_max // c_blk) * c_blk, c_blk)
    _, _, t_blk = _ragged_block_layout(sched, c_blk)
    return (W * c_pad) / float(max(t_blk * c_blk, 1))


def pack_ragged(
    sched: GustSchedule, c_blk: int = 8, value_dtype=jnp.float32,
    index_dtype=jnp.int32,
) -> RaggedSchedule:
    """Flatten the ragged per-window schedule into a (T_blk * c_blk, l)
    block stream holding only real cycle blocks (plus each window's final
    partial-block padding, which keeps the packed-format invariants).

    One fancy-indexed scatter by ``window_starts``-derived destinations —
    O(nnz) host numpy, same as :func:`pack_blocks` — plus O(W) scalar
    metadata (``block_window``, ``block_starts``)."""
    l, W = sched.l, sched.num_windows
    m, n = sched.shape
    ws = np.asarray(sched.window_starts)
    cpw = np.diff(ws)
    c_total = int(ws[-1]) if W else 0
    bpw, block_starts, t_blk = _ragged_block_layout(sched, c_blk)

    lane = np.arange(l, dtype=np.int32)
    # Same one-backing-allocation trick as pack_blocks (f32/i32 share the
    # itemsize, so the value plane is a reinterpreting view).
    buf = np.zeros((3, t_blk * c_blk, l), dtype=np.int32)
    m_b = buf[0].view(np.float32)
    r_b = buf[1]
    c_b = buf[2]
    c_b[:] = lane  # padding slots gather v[lane] (packed-format invariant)
    if c_total:
        wid = np.repeat(np.arange(W, dtype=np.int64), cpw)
        dest = block_starts[wid] * c_blk + (
            np.arange(c_total, dtype=np.int64) - ws[wid]
        )
        m_b[dest] = sched.m_sch[:c_total]
        r_b[dest] = sched.row_sch[:c_total]
        c_b[dest] = sched.col_sch[:c_total]

    block_window = np.repeat(np.arange(W, dtype=np.int32), bpw)
    row_perm = _extended_row_perm(sched)
    seg_blk, col_loc, s_blk = _local_gather_tables(c_b, l, c_blk)
    scale = None
    if _is_int8(value_dtype):
        m_b, scale = _quantize_stream(m_b, c_blk)
        scale = jnp.asarray(scale)
        value_dtype = jnp.int8

    return RaggedSchedule(
        m_blk=jnp.asarray(m_b, value_dtype),
        col_blk=jnp.asarray(c_b, index_dtype),
        row_blk=jnp.asarray(r_b, index_dtype),
        row_perm=jnp.asarray(row_perm),
        seg_blk=jnp.asarray(seg_blk),
        col_loc=jnp.asarray(col_loc, index_dtype),
        block_window=jnp.asarray(block_window),
        block_starts=jnp.asarray(block_starts, jnp.int32),
        l=l,
        num_windows=W,
        c_blk=c_blk,
        num_blocks=t_blk,
        shape=(m, n),
        fusable=_fusable(sched),
        s_blk=s_blk,
        identity_perm=bool(
            np.array_equal(row_perm, np.arange(W * l, dtype=np.int32))
        ),
        scale_blk=scale,
    )


def splice_ragged_blocks(
    old: RaggedSchedule,
    sched: GustSchedule,
    dirty: Sequence[int],
    *,
    value_dtype=jnp.float32,
    index_dtype=jnp.int32,
) -> RaggedSchedule:
    """Incremental ragged repack: windows listed in ``dirty`` are packed
    fresh (via a compact dirty-only sub-schedule), every other window's
    stream blocks — and per-block int8 scales — are copied bitwise from
    ``old``.  The result is **bit-identical** to
    ``pack_ragged(sched, old.c_blk, ...)`` because stream blocks are
    window-local, quantization scales are block-local, and the gather
    tables are a pure function of the spliced column stream
    (:func:`_local_gather_tables` recomputed globally).

    ``old`` must be an un-repadded pack of a schedule that agrees with
    ``sched`` on every clean window (the :func:`~repro.core.scheduler.
    incremental_schedule` contract) and on geometry/dtypes — violations
    raise rather than silently corrupting the stream."""
    l, W, cb = sched.l, sched.num_windows, old.c_blk
    if old.l != l or old.num_windows != W or tuple(old.shape) != tuple(sched.shape):
        raise ValueError("splice: schedule/artifact geometry mismatch")
    quant = _is_int8(value_dtype)
    if quant != old.quantized:
        raise ValueError("splice: quantization mismatch with the old artifact")
    if jnp.dtype(index_dtype) != jnp.dtype(old.col_blk.dtype):
        raise ValueError("splice: index dtype mismatch with the old artifact")
    if not quant and jnp.dtype(value_dtype) != jnp.dtype(old.m_blk.dtype):
        raise ValueError("splice: value dtype mismatch with the old artifact")

    from .scheduler import _ranges

    dirty = np.asarray(dirty, dtype=np.int64)
    dirty_mask = np.zeros(W, dtype=bool)
    dirty_mask[dirty] = True
    clean = np.nonzero(~dirty_mask)[0]

    bpw_new, bs_new, t_new = _ragged_block_layout(sched, cb)
    bs_old = np.asarray(old.block_starts, np.int64)
    bpw_old = np.diff(bs_old)
    if clean.size and not np.array_equal(bpw_old[clean], bpw_new[clean]):
        raise ValueError("splice: clean windows changed block counts")

    m_old = np.asarray(old.m_blk)
    c_old = np.asarray(old.col_blk)
    r_old = np.asarray(old.row_blk)
    m_new = np.zeros((t_new * cb, l), dtype=m_old.dtype)
    c_new = np.empty((t_new * cb, l), dtype=c_old.dtype)
    c_new[:] = np.arange(l, dtype=c_old.dtype)  # padding invariant: col==lane
    r_new = np.zeros((t_new * cb, l), dtype=r_old.dtype)
    scale_new = np.ones((t_new,), np.float32) if quant else None

    if clean.size:
        src = _ranges(bs_old[clean] * cb, bpw_old[clean] * cb)
        dst = _ranges(bs_new[clean] * cb, bpw_new[clean] * cb)
        m_new[dst] = m_old[src]
        c_new[dst] = c_old[src]
        r_new[dst] = r_old[src]
        if quant:
            sb = _ranges(bs_old[clean], bpw_old[clean])
            db = _ranges(bs_new[clean], bpw_new[clean])
            scale_new[db] = np.asarray(old.scale_blk, np.float32)[sb]

    if dirty.size:
        # Pack only the dirty windows: lift their schedule rows into a
        # compact sub-schedule (sub window i == dirty[i]) and pack_ragged
        # it — per-window block content depends only on that window's
        # rows, so the sub-pack's blocks equal the fresh global pack's.
        ws = np.asarray(sched.window_starts)
        cpw = np.diff(ws)
        sub_cpw = cpw[dirty]
        sub_ws = np.zeros(dirty.size + 1, dtype=np.int64)
        np.cumsum(sub_cpw, out=sub_ws[1:])
        rows_src = _ranges(ws[dirty], sub_cpw)
        sub_c = int(sub_ws[-1])
        rows = max(sub_c, 1)
        sub_m = np.zeros((rows, l), dtype=np.asarray(sched.m_sch).dtype)
        sub_r = np.zeros((rows, l), dtype=np.int32)
        sub_col = np.tile(np.arange(l, dtype=np.int32), (rows, 1))
        sub_valid = np.zeros((rows, l), dtype=bool)
        if sub_c:
            sub_m[:sub_c] = np.asarray(sched.m_sch)[rows_src]
            sub_r[:sub_c] = np.asarray(sched.row_sch)[rows_src]
            sub_col[:sub_c] = np.asarray(sched.col_sch)[rows_src]
            sub_valid[:sub_c] = np.asarray(sched.valid)[rows_src]
        sub_sched = GustSchedule(
            l=l,
            shape=(int(dirty.size) * l, sched.shape[1]),
            nnz=int(sub_valid.sum()),
            m_sch=sub_m,
            row_sch=sub_r,
            col_sch=sub_col,
            window_starts=sub_ws,
            row_perm=np.arange(int(dirty.size) * l, dtype=np.int64),
            valid=sub_valid,
        )
        sub = pack_ragged(
            sub_sched, cb, value_dtype=value_dtype, index_dtype=index_dtype
        )
        # sub windows appear in dirty order, so the sub stream maps onto
        # the dirty destinations row-for-row
        dst = _ranges(bs_new[dirty] * cb, bpw_new[dirty] * cb)
        m_new[dst] = np.asarray(sub.m_blk)
        c_new[dst] = np.asarray(sub.col_blk)
        r_new[dst] = np.asarray(sub.row_blk)
        if quant:
            db = _ranges(bs_new[dirty], bpw_new[dirty])
            scale_new[db] = np.asarray(sub.scale_blk, np.float32)

    seg_blk, col_loc, s_blk = _local_gather_tables(c_new, l, cb)
    row_perm = _extended_row_perm(sched)
    return RaggedSchedule(
        m_blk=jnp.asarray(m_new, jnp.int8 if quant else value_dtype),
        col_blk=jnp.asarray(c_new, index_dtype),
        row_blk=jnp.asarray(r_new, index_dtype),
        row_perm=jnp.asarray(row_perm),
        seg_blk=jnp.asarray(seg_blk),
        col_loc=jnp.asarray(col_loc, index_dtype),
        block_window=jnp.asarray(np.repeat(np.arange(W, dtype=np.int32), bpw_new)),
        block_starts=jnp.asarray(bs_new, jnp.int32),
        l=l,
        num_windows=W,
        c_blk=cb,
        num_blocks=t_new,
        shape=sched.shape,
        fusable=_fusable(sched),
        s_blk=s_blk,
        identity_perm=bool(
            np.array_equal(row_perm, np.arange(W * l, dtype=np.int32))
        ),
        scale_blk=jnp.asarray(scale_new) if quant else None,
    )


#: Padded-stream waste (``W * C_pad`` over ``T_blk * c_blk``) above which
#: the ragged layout is chosen — consumed only through
#: :func:`resolve_layout`, the one waste-threshold decision point.
DEFAULT_WASTE_THRESHOLD = 2.0


def resolve_layout(
    sched: GustSchedule, c_blk: int = 8, waste_threshold: float = None
) -> str:
    """The one layout='auto' decision point: ``"ragged"`` when the padded
    layout would stream ``>= waste_threshold`` times more (cycle, lane)
    slots than the ragged stream (skewed matrices), else ``"padded"``
    (near-uniform windows, where the simpler 2-D-grid padded kernel
    wins).  ``waste_threshold=None`` means :data:`DEFAULT_WASTE_THRESHOLD`.
    Every auto caller — :func:`pack_auto`, :meth:`ScheduleCache.auto_for`,
    ``GustPlan.layout`` — delegates here."""
    if waste_threshold is None:
        waste_threshold = DEFAULT_WASTE_THRESHOLD
    return (
        "ragged"
        if ragged_waste_ratio(sched, c_blk) >= waste_threshold
        else "padded"
    )


#: ``S_blk / seg_count`` ratio below which ``gather="auto"`` picks the
#: segment-local path — consumed only through :func:`resolve_gather`, the
#: one gather-mode decision point (the locality twin of
#: :data:`DEFAULT_WASTE_THRESHOLD`).
DEFAULT_LOCALITY_RATIO = 0.5

#: Minimum segment count before ``gather="auto"`` considers the local
#: path at all: below this width the resident contraction is small enough
#: that the local mode's extra per-block grid steps (S_blk tile loads,
#: scratch init/flush) dominate the FLOP saving — measured crossover in
#: ``BENCH_gather.json`` (0.64x at 32 segments, >=1.6x from 128 up).
DEFAULT_LOCAL_MIN_SEGS = 128


def resolve_gather(
    s_blk: int, seg_count: int, locality_ratio: float = None,
    min_segs: int = None,
) -> str:
    """The one ``gather="auto"`` decision point: ``"local"`` when the
    matrix is wide enough for tile streaming to pay for its grid-step
    overhead (``seg_count >= min_segs``) AND the per-block segment
    working set is small relative to the width (``S_blk <=
    locality_ratio * seg_count`` — the regime where streaming only the
    referenced x tiles beats holding all of x resident in VMEM and
    contracting over every segment); else ``"resident"``.  ``None``
    thresholds mean :data:`DEFAULT_LOCALITY_RATIO` /
    :data:`DEFAULT_LOCAL_MIN_SEGS`.  Every auto caller —
    ``kernels.ops.execute_spmm``, ``GustPlan`` — delegates here."""
    if locality_ratio is None:
        locality_ratio = DEFAULT_LOCALITY_RATIO
    if min_segs is None:
        min_segs = DEFAULT_LOCAL_MIN_SEGS
    if seg_count < max(min_segs, 2):
        return "resident"
    return "local" if s_blk <= locality_ratio * seg_count else "resident"


#: A measured tune winner must beat the static-default baseline by this
#: wall-clock factor to displace it — consumed only through
#: :func:`resolve_tuning`, the one measured-tuning decision point (the
#: measured twin of :data:`DEFAULT_WASTE_THRESHOLD` /
#: :data:`DEFAULT_LOCALITY_RATIO`).  The margin absorbs timer noise so
#: ``GustPlan.tune`` is never slower than the static defaults.
DEFAULT_TUNE_IMPROVEMENT = 1.05


def resolve_tuning(
    measurements: Dict, baseline, min_improvement: float = None,
):
    """The one measured-tuning decision point: return the key of the
    fastest candidate in ``measurements`` (a ``{candidate_key: seconds}``
    dict), unless it fails to beat ``baseline``'s own measurement by
    ``min_improvement`` — then the baseline key is returned unchanged.
    Guarantees a tuned plan is never slower than the static
    :func:`resolve_layout`/:func:`resolve_gather` defaults (to timer
    noise), because the baseline is always itself a measured candidate.
    ``None`` means :data:`DEFAULT_TUNE_IMPROVEMENT`.  Every measured-tune
    caller — ``GustPlan.tune`` — delegates here."""
    if min_improvement is None:
        min_improvement = DEFAULT_TUNE_IMPROVEMENT
    if baseline not in measurements:
        raise ValueError(
            f"baseline {baseline!r} missing from measurements "
            f"({sorted(map(repr, measurements))})"
        )
    if not all(t > 0 for t in measurements.values()):
        raise ValueError("measurements must be positive wall-clock seconds")
    best = min(measurements, key=measurements.get)
    if measurements[baseline] / measurements[best] >= min_improvement:
        return best
    return baseline


def pack_auto(
    sched: GustSchedule, c_blk: int = 8, *, waste_threshold: float = None,
    value_dtype=jnp.float32, index_dtype=jnp.int32,
):
    """Pick the execution layout by measured padding waste
    (:func:`resolve_layout`) and materialize only the chosen one."""
    fn = (
        pack_ragged
        if resolve_layout(sched, c_blk, waste_threshold) == "ragged"
        else pack_schedule
    )
    return fn(sched, c_blk, value_dtype=value_dtype, index_dtype=index_dtype)


def _default_spec_s_blk(n: int, l: int, c_blk: int) -> int:
    """Worst-case table width for shape-only specs: a block of c_blk*l
    slots can reference at most that many distinct segments, capped at the
    matrix's segment count."""
    return max(min(-(-n // l), c_blk * l), 1)


def packed_spec(
    m: int,
    n: int,
    l: int,
    c_pad: int,
    value_dtype=jnp.float32,
    index_dtype=jnp.int32,
    c_blk: int = 8,
    s_blk: int = None,
) -> PackedSchedule:
    """ShapeDtypeStruct stand-in for a PackedSchedule — used by the dry-run
    (no allocation).  ``c_pad`` is typically sized from the Eq. 9 bound:
    ``expected_colors_bound(n, density, l)`` rounded up.  ``s_blk=None``
    sizes the gather table at the worst case (no locality assumed)."""
    W = max(-(-m // l), 1)
    if s_blk is None:
        s_blk = _default_spec_s_blk(n, l, c_blk)
    t_blk = -(-(W * c_pad) // c_blk)
    sds = jax.ShapeDtypeStruct
    return PackedSchedule(
        m_blk=sds((W * c_pad, l), value_dtype),
        col_blk=sds((W * c_pad, l), index_dtype),
        row_blk=sds((W * c_pad, l), index_dtype),
        row_perm=sds((W * l,), jnp.int32),
        seg_blk=sds((t_blk, s_blk), jnp.int32),
        col_loc=sds((W * c_pad, l), index_dtype),
        l=l,
        num_windows=W,
        c_pad=c_pad,
        shape=(m, n),
        fusable=True,
        c_blk=c_blk,
        s_blk=s_blk,
        identity_perm=False,
        scale_blk=sds((t_blk,), jnp.float32) if _is_int8(value_dtype) else None,
    )


def ragged_spec(
    m: int,
    n: int,
    l: int,
    num_blocks: int,
    c_blk: int = 8,
    value_dtype=jnp.float32,
    index_dtype=jnp.int32,
    s_blk: int = None,
) -> RaggedSchedule:
    """ShapeDtypeStruct stand-in for a RaggedSchedule — the ragged twin of
    :func:`packed_spec` for dry-runs.  ``num_blocks`` is typically sized
    from the Eq. 9 bound: ``W * ceil(expected_colors_bound / c_blk)``."""
    W = max(-(-m // l), 1)
    if s_blk is None:
        s_blk = _default_spec_s_blk(n, l, c_blk)
    sds = jax.ShapeDtypeStruct
    return RaggedSchedule(
        m_blk=sds((num_blocks * c_blk, l), value_dtype),
        col_blk=sds((num_blocks * c_blk, l), index_dtype),
        row_blk=sds((num_blocks * c_blk, l), index_dtype),
        row_perm=sds((W * l,), jnp.int32),
        seg_blk=sds((num_blocks, s_blk), jnp.int32),
        col_loc=sds((num_blocks * c_blk, l), index_dtype),
        block_window=sds((num_blocks,), jnp.int32),
        block_starts=sds((W + 1,), jnp.int32),
        l=l,
        num_windows=W,
        c_blk=c_blk,
        num_blocks=num_blocks,
        shape=(m, n),
        fusable=True,
        s_blk=s_blk,
        identity_perm=False,
        scale_blk=(
            sds((num_blocks,), jnp.float32) if _is_int8(value_dtype) else None
        ),
    )


# ---------------------------------------------------------------------------
# Leaves/meta codec — the one wire format for serving stacks and dry-runs.
# ---------------------------------------------------------------------------


def packed_leaves(p: PackedSchedule) -> Dict:
    """Array leaves of a packed schedule as a plain dict (jit-able pytree).

    The ``scale_blk`` key is present exactly when the pack is quantized —
    meta tuples stay unchanged, so old serialized stacks round-trip and
    quantization is inferred from the value leaf's dtype."""
    leaves = {
        "m_blk": p.m_blk,
        "col_blk": p.col_blk,
        "row_blk": p.row_blk,
        "row_perm": p.row_perm,
        "seg_blk": p.seg_blk,
        "col_loc": p.col_loc,
    }
    if p.scale_blk is not None:
        leaves["scale_blk"] = p.scale_blk
    return leaves


def packed_meta(p: PackedSchedule) -> Tuple:
    """Static (non-array) part: ``(l, num_windows, c_pad, shape, fusable,
    c_blk, s_blk, identity_perm)``."""
    return (p.l, p.num_windows, p.c_pad, p.shape, p.fusable, p.c_blk,
            p.s_blk, p.identity_perm)


def packed_from_leaves(leaves: Dict, meta: Tuple) -> PackedSchedule:
    """Inverse of the codec: rebuild a PackedSchedule from leaves + meta."""
    l, w, c_pad, shape, fusable, c_blk, s_blk, identity_perm = meta
    return PackedSchedule(
        m_blk=leaves["m_blk"],
        col_blk=leaves["col_blk"],
        row_blk=leaves["row_blk"],
        row_perm=leaves["row_perm"],
        seg_blk=leaves["seg_blk"],
        col_loc=leaves["col_loc"],
        l=l, num_windows=w, c_pad=c_pad, shape=shape, fusable=fusable,
        c_blk=c_blk, s_blk=s_blk, identity_perm=identity_perm,
        scale_blk=leaves.get("scale_blk"),
    )


def ragged_leaves(r: RaggedSchedule) -> Dict:
    """Array leaves of a ragged stream as a plain dict (jit-able pytree).
    ``scale_blk`` present exactly when quantized (see
    :func:`packed_leaves`)."""
    leaves = {
        "m_blk": r.m_blk,
        "col_blk": r.col_blk,
        "row_blk": r.row_blk,
        "row_perm": r.row_perm,
        "seg_blk": r.seg_blk,
        "col_loc": r.col_loc,
        "block_window": r.block_window,
        "block_starts": r.block_starts,
    }
    if r.scale_blk is not None:
        leaves["scale_blk"] = r.scale_blk
    return leaves


def ragged_meta(r: RaggedSchedule) -> Tuple:
    """Static part: ``("ragged", l, num_windows, c_blk, num_blocks, shape,
    fusable, s_blk, identity_perm)``.  The leading tag disambiguates from
    :func:`packed_meta` tuples in serialized serving stacks."""
    return ("ragged", r.l, r.num_windows, r.c_blk, r.num_blocks, r.shape,
            r.fusable, r.s_blk, r.identity_perm)


def ragged_from_leaves(leaves: Dict, meta: Tuple) -> RaggedSchedule:
    """Inverse of the ragged codec."""
    tag, l, w, c_blk, t_blk, shape, fusable, s_blk, identity_perm = meta
    if tag != "ragged":
        raise ValueError(f"not a ragged meta tuple: {meta!r}")
    return RaggedSchedule(
        m_blk=leaves["m_blk"],
        col_blk=leaves["col_blk"],
        row_blk=leaves["row_blk"],
        row_perm=leaves["row_perm"],
        seg_blk=leaves["seg_blk"],
        col_loc=leaves["col_loc"],
        block_window=leaves["block_window"],
        block_starts=leaves["block_starts"],
        l=l, num_windows=w, c_blk=c_blk, num_blocks=t_blk, shape=shape,
        fusable=fusable, s_blk=s_blk, identity_perm=identity_perm,
        scale_blk=leaves.get("scale_blk"),
    )


def stacked_leaf_specs(proto, reps: int) -> Dict:
    """ShapeDtypeStruct leaves of ``reps`` layer packs stacked on axis 0.

    Works for packed and ragged prototypes, real-array or spec (only
    .shape/.dtype are read) — this is how ``dryrun_specs`` sizes the
    serving stack without running the scheduler."""
    leaves = (
        ragged_leaves(proto)
        if isinstance(proto, RaggedSchedule)
        else packed_leaves(proto)
    )
    return {
        k: jax.ShapeDtypeStruct((reps, *v.shape), v.dtype)
        for k, v in leaves.items()
    }


# ---------------------------------------------------------------------------
# Content-keyed schedule cache.
# ---------------------------------------------------------------------------


class ScheduleCache:
    """LRU cache of ``schedule(...)`` / ``pack_schedule(...)`` results,
    keyed by matrix *content* (sha1 of shape + COO triples) and the
    scheduling/packing parameters.

    The paper's amortization argument (§5.3) assumes the schedule is
    computed once per matrix; this cache enforces it across independent
    call sites (serving gustify, GustLinear, benchmarks) that re-derive
    the same pruned matrix.

    ``maxsize`` must cover a whole model conversion for the reuse to
    materialize: gustify inserts ``reps * len(mats)`` schedule entries
    plus as many packed entries (2 * 32 * 3 = 192 for a 32-layer stack),
    so the default (:data:`DEFAULT_SCHEDULE_CACHE_SIZE`, overridable via
    ``REPRO_SCHEDULE_CACHE_SIZE``) is sized above that.  The bound is a
    hard LRU: long-lived servers planning an unbounded matrix stream top
    out at ``maxsize`` live entries, with drops counted in ``evictions``
    (surfaced by :meth:`stats` next to hits/misses).  Entries hold device
    arrays — tens of MB each at LLM scale — for the process lifetime;
    call :func:`clear_cache` after a one-shot conversion to release
    them."""

    def __init__(self, maxsize: Optional[int] = None):
        if maxsize is None:
            env = os.environ.get("REPRO_SCHEDULE_CACHE_SIZE", "").strip()
            maxsize = int(env) if env else DEFAULT_SCHEDULE_CACHE_SIZE
        if maxsize < 1:
            raise ValueError(f"ScheduleCache maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self._store: "OrderedDict[Tuple, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def matrix_key(coo: COOMatrix) -> str:
        h = hashlib.sha1()
        # canonicalize: a shape rebuilt from numpy scalars (e.g. an npz
        # round trip) must hash like the original python-int tuple
        h.update(repr(tuple(int(s) for s in coo.shape)).encode())
        for a in (coo.rows, coo.cols, coo.vals):
            arr = np.ascontiguousarray(a)
            h.update(str(arr.dtype).encode())
            h.update(arr.tobytes())
        return h.hexdigest()

    def _get(self, key: Tuple, build):
        if key in self._store:
            self.hits += 1
            self._store.move_to_end(key)
            return self._store[key]
        self.misses += 1
        val = build()
        self._store[key] = val
        while len(self._store) > self.maxsize:
            self._store.popitem(last=False)
            self.evictions += 1
        return val

    def _schedule_for_key(self, mk: str, coo: COOMatrix, l: int,
                          load_balance: bool, method: str,
                          workers: Optional[int] = None) -> GustSchedule:
        from .scheduler import schedule as _schedule

        # ``workers`` is deliberately NOT part of the key: the schedule is
        # bit-identical for every worker count (chunking invariant).
        key = ("sched", mk, l, load_balance, method)
        return self._get(
            key,
            lambda: _schedule(
                coo, l, load_balance=load_balance, method=method,
                workers=workers,
            ),
        )

    def schedule(
        self, coo: COOMatrix, l: int, *, load_balance: bool = True,
        method: str = "fast", workers: Optional[int] = None,
    ) -> GustSchedule:
        return self._schedule_for_key(
            self.matrix_key(coo), coo, l, load_balance, method, workers
        )

    def packed(
        self, coo: COOMatrix, l: int, *, load_balance: bool = True,
        method: str = "fast", c_blk: int = 8, value_dtype=jnp.float32,
        index_dtype=jnp.int32,
    ) -> Tuple[GustSchedule, PackedSchedule]:
        mk = self.matrix_key(coo)  # O(nnz) hash — computed once per call
        sched = self._schedule_for_key(mk, coo, l, load_balance, method)
        key = (
            "packed", mk, l, load_balance, method, c_blk,
            jnp.dtype(value_dtype).name, jnp.dtype(index_dtype).name,
        )
        packed = self._get(
            key,
            lambda: pack_schedule(
                sched, c_blk=c_blk, value_dtype=value_dtype,
                index_dtype=index_dtype,
            ),
        )
        return sched, packed

    def ragged_packed(
        self, coo: COOMatrix, l: int, *, load_balance: bool = True,
        method: str = "fast", c_blk: int = 8, value_dtype=jnp.float32,
        index_dtype=jnp.int32,
    ) -> Tuple[GustSchedule, "RaggedSchedule"]:
        """Ragged twin of :meth:`packed`: schedule + ragged block stream,
        both served from the matrix-content-keyed store."""
        mk = self.matrix_key(coo)
        sched = self._schedule_for_key(mk, coo, l, load_balance, method)
        key = (
            "ragged", mk, l, load_balance, method, c_blk,
            jnp.dtype(value_dtype).name, jnp.dtype(index_dtype).name,
        )
        ragged = self._get(
            key,
            lambda: pack_ragged(
                sched, c_blk=c_blk, value_dtype=value_dtype,
                index_dtype=index_dtype,
            ),
        )
        return sched, ragged

    @staticmethod
    def schedule_key(sched: GustSchedule) -> str:
        """Content key of an already-built schedule — used by call sites
        that receive a ``GustSchedule`` rather than the source matrix
        (``distributed_spmv``, ``gust_spmm_auto``)."""
        h = hashlib.sha1()
        h.update(repr((sched.l, sched.shape, sched.nnz)).encode())
        for a in (sched.m_sch, sched.row_sch, sched.col_sch,
                  sched.window_starts, sched.row_perm):
            arr = np.ascontiguousarray(a)
            h.update(str(arr.dtype).encode())
            h.update(arr.tobytes())
        return h.hexdigest()

    def pack_for(
        self, sched: GustSchedule, *, c_blk: int = 8,
        value_dtype=jnp.float32, index_dtype=jnp.int32,
    ) -> PackedSchedule:
        """Memoized :func:`pack_schedule` keyed on schedule content —
        repeated executions of the same schedule (every ``distributed_spmv``
        call, serving re-exports) pack exactly once."""
        key = ("pack_for", self.schedule_key(sched), c_blk,
               jnp.dtype(value_dtype).name, jnp.dtype(index_dtype).name)
        return self._get(
            key,
            lambda: pack_schedule(
                sched, c_blk=c_blk, value_dtype=value_dtype,
                index_dtype=index_dtype,
            ),
        )

    def ragged_for(
        self, sched: GustSchedule, *, c_blk: int = 8,
        value_dtype=jnp.float32, index_dtype=jnp.int32,
    ) -> RaggedSchedule:
        """Memoized :func:`pack_ragged` keyed on schedule content."""
        key = ("ragged_for", self.schedule_key(sched), c_blk,
               jnp.dtype(value_dtype).name, jnp.dtype(index_dtype).name)
        return self._get(
            key,
            lambda: pack_ragged(
                sched, c_blk=c_blk, value_dtype=value_dtype,
                index_dtype=index_dtype,
            ),
        )

    def auto_for(
        self, sched: GustSchedule, *, c_blk: int = 8,
        waste_threshold: float = None, value_dtype=jnp.float32,
        index_dtype=jnp.int32,
    ):
        """Cached twin of :func:`pack_auto`: the :func:`resolve_layout`
        decision, delegated to :meth:`ragged_for` / :meth:`pack_for` so
        the chosen layout is memoized on schedule content."""
        route = (
            self.ragged_for
            if resolve_layout(sched, c_blk, waste_threshold) == "ragged"
            else self.pack_for
        )
        return route(
            sched, c_blk=c_blk, value_dtype=value_dtype,
            index_dtype=index_dtype,
        )

    def memo(self, key: Tuple, build):
        """Generic LRU memoization for artifacts *derived from* cached
        entries (e.g. the distributed device-major shard layout, or a
        ``GustPlan.tune`` result).  ``key`` must lead with a tag distinct
        from the built-in routes."""
        return self._get(key, build)

    def stats(self) -> Dict[str, int]:
        """Hit/miss/eviction/entry counters — surfaced on
        ``GustPlan.cost()`` so benchmarks and serving logs can report
        schedule-reuse rates and capacity pressure."""
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "entries": len(self._store),
        }

    def clear(self):
        self._store.clear()
        self.hits = self.misses = self.evictions = 0


#: Default LRU capacity of :class:`ScheduleCache` — generous enough for a
#: whole multi-layer model conversion; override per-process with the
#: ``REPRO_SCHEDULE_CACHE_SIZE`` env var or per-cache with ``maxsize=``.
DEFAULT_SCHEDULE_CACHE_SIZE = 256

default_cache = ScheduleCache()


def clear_cache() -> None:
    """Drop every cached schedule/packed entry of the module-level cache
    (and the ``spmm_scheduled`` shim's identity-keyed plan memo).

    Cached entries hold device arrays (tens of MB per LLM-scale matrix, up
    to ``maxsize`` of them) for the process lifetime; call this after a
    one-shot conversion (e.g. ``gustify`` at weight-load time) if the
    memory matters more than re-schedule speed."""
    default_cache.clear()
    # late import via importlib: spmv imports this module, and the package
    # namespace shadows the submodule with the spmv *function*
    import importlib

    importlib.import_module(__package__ + ".spmv")._SHIM_PLANS.clear()


def schedule_packed(
    coo: COOMatrix, l: int, *, load_balance: bool = True, method: str = "fast",
    c_blk: int = 8, value_dtype=jnp.float32, index_dtype=jnp.int32,
    cache: Optional[ScheduleCache] = default_cache,
) -> Tuple[GustSchedule, PackedSchedule]:
    """schedule + pack in one call, served from ``cache`` (content-keyed;
    pass ``cache=None`` to bypass)."""
    if cache is None:
        from .scheduler import schedule as _schedule

        sched = _schedule(coo, l, load_balance=load_balance, method=method)
        return sched, pack_schedule(
            sched, c_blk=c_blk, value_dtype=value_dtype, index_dtype=index_dtype
        )
    return cache.packed(
        coo, l, load_balance=load_balance, method=method, c_blk=c_blk,
        value_dtype=value_dtype, index_dtype=index_dtype,
    )
