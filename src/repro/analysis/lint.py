"""Policy linter — the repo's written rules as an AST pass (``GUST-Lxx``).

Each rule encodes a policy already stated in ROADMAP.md; the linter makes
it machine-enforced over ``src/`` (CI runs ``python -m repro.analysis
lint`` as a hard-failing step):

* **GUST-L01** (Plan API policy): the lazy packages
  (``repro/__init__.py``, ``repro/analysis/__init__.py``) must not
  import jax or any ``repro.*`` submodule at module scope — only inside
  ``if TYPE_CHECKING:`` or function bodies.  ``import repro`` stays
  jax-free so entry points can pin ``XLA_FLAGS`` first.
* **GUST-L02** (PR 3 API rule): no *new* public free functions — new
  execution features hang off ``GustPlan``.  Every public module-level
  ``def`` must be grandfathered in the allowlist.
* **GUST-L03** (single decision points): ``resolve_layout`` /
  ``resolve_gather`` / ``resolve_tuning`` / ``resolve_fallback`` may
  only be *called* from their sanctioned sites (the allowlist); nothing
  else re-derives the layout/gather/tuning/degradation choice.
* **GUST-L04** (deprecation policy): no new in-repo call sites of the
  deprecated spellings ``spmv`` / ``gust_spmm_auto`` /
  ``SparsityConfig`` — they exist only for downstream callers.
* **GUST-L05** (store format rule): no ``np.savez`` /
  ``np.savez_compressed`` — the plan-store container exists because
  numpy's own format cannot round-trip bfloat16 leaves.
* **GUST-L06** (store/cache key rule): execution knobs (``workers``,
  ``backend``, ``pipeline``) must never appear in a cache/store key
  expression — one artifact serves every execution configuration.
* **GUST-L07** (PR 10 containment rule): on the serving path (serving/,
  launch/serve.py, core/plan*.py, kernels/ops.py, resilience/), a broad
  ``except``/``except Exception`` whose body only swallows
  (``pass``/``...``) is banned outside the sanctioned containment sites
  in the allowlist — fault handling must retire, count, degrade, or
  re-raise; silent swallowing is how requests get lost.

Allowlist format (``lint_allowlist.txt``, same directory)::

    # comment lines and blanks are ignored
    GUST-L02  repro/core/plan.py::plan        # grandfathered: the front door
    GUST-L03  repro/core/plan.py::GustPlan.layout

i.e. ``<rule-id>  <path-relative-to-src>::<qualified name>`` with
``<module>`` as the qualname for module-level statements.  An entry
silences exactly that rule at exactly that site.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, List, Optional, Set, Tuple

__all__ = ["LintFinding", "lint_sources", "LINT_RULES"]

LINT_RULES: Dict[str, str] = {
    "GUST-L01": "lazy package imports jax/repro.* at module scope",
    "GUST-L02": "new public free function (PR 3: features hang off GustPlan)",
    "GUST-L03": "resolve_* called outside its sanctioned decision point",
    "GUST-L04": "call site of a deprecated shim spelling",
    "GUST-L05": "np.savez on artifact paths (bfloat16 cannot round-trip)",
    "GUST-L06": "execution knob (workers/backend/pipeline) in a cache key",
    "GUST-L07": "bare except-pass on the serving path (unsanctioned swallow)",
}

#: Packages whose module scope must stay jax-free (GUST-L01).
_LAZY_PACKAGES = ("repro/__init__.py", "repro/analysis/__init__.py")

#: The single-decision-point functions (GUST-L03).
_DECISION_POINTS = (
    "resolve_layout", "resolve_gather", "resolve_tuning", "resolve_fallback",
)

#: Deprecated spellings whose *call sites* are banned in src/ (GUST-L04).
_DEPRECATED = ("spmv", "gust_spmm_auto", "SparsityConfig")

#: Execution knobs that must never reach a cache/store key (GUST-L06).
_EXEC_KNOBS = ("workers", "backend", "pipeline")

#: Serving-path prefixes where silent exception swallowing is banned
#: (GUST-L07): every file a request's tokens flow through.
_SERVING_PATHS = (
    "repro/serving/",
    "repro/launch/serve.py",
    "repro/core/plan.py",
    "repro/core/plan_store.py",
    "repro/kernels/ops.py",
    "repro/resilience/",
)


@dataclasses.dataclass(frozen=True)
class LintFinding:
    rule: str
    path: str       # relative to the linted source root
    line: int
    qualname: str
    message: str

    @property
    def site(self) -> str:
        return f"{self.path}::{self.qualname}"

    def __str__(self) -> str:
        return f"[{self.rule}] {self.path}:{self.line} ({self.qualname}): " \
               f"{self.message}"


def _default_allowlist() -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "lint_allowlist.txt")


def load_allowlist(path: Optional[str] = None) -> Set[Tuple[str, str]]:
    """Parse the allowlist into ``{(rule, site)}`` pairs."""
    path = path or _default_allowlist()
    entries: Set[Tuple[str, str]] = set()
    if not os.path.exists(path):
        return entries
    with open(path, encoding="utf-8") as f:
        for raw in f:
            line = raw.split("#", 1)[0].strip()
            if not line:
                continue
            parts = line.split(None, 1)
            if len(parts) != 2:
                raise ValueError(f"bad allowlist line: {raw.rstrip()!r}")
            entries.add((parts[0], parts[1].strip()))
    return entries


class _Visitor(ast.NodeVisitor):
    """One file's pass: tracks the qualname scope stack and whether the
    current statement sits under ``if TYPE_CHECKING:``."""

    def __init__(self, relpath: str):
        self.relpath = relpath
        self.scope: List[str] = []
        self.type_checking = 0
        self.findings: List[LintFinding] = []

    # -- helpers ------------------------------------------------------------

    @property
    def qualname(self) -> str:
        return ".".join(self.scope) if self.scope else "<module>"

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(LintFinding(
            rule=rule, path=self.relpath,
            line=getattr(node, "lineno", 0),
            qualname=self.qualname, message=message,
        ))

    # -- scopes -------------------------------------------------------------

    def _visit_scoped(self, node, name: str) -> None:
        self.scope.append(name)
        self.generic_visit(node)
        self.scope.pop()

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._visit_scoped(node, node.name)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        if not self.scope and not node.name.startswith("_"):
            self.scope.append(node.name)  # site = path::function
            self._emit("GUST-L02", node,
                       f"public free function {node.name!r}")
            self.scope.pop()
        self._visit_scoped(node, node.name)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_If(self, node: ast.If) -> None:
        test = node.test
        is_tc = (isinstance(test, ast.Name) and test.id == "TYPE_CHECKING") \
            or (isinstance(test, ast.Attribute) and test.attr == "TYPE_CHECKING")
        if is_tc:
            self.type_checking += 1
            for child in node.body:
                self.visit(child)
            self.type_checking -= 1
            for child in node.orelse:
                self.visit(child)
        else:
            self.generic_visit(node)

    # -- GUST-L01 -----------------------------------------------------------

    def _lazy_package(self) -> bool:
        return self.relpath.replace(os.sep, "/") in _LAZY_PACKAGES

    def _check_eager_import(self, node, module: str) -> None:
        if not self._lazy_package() or self.scope or self.type_checking:
            return
        root = module.split(".", 1)[0]
        if root in ("jax", "jaxlib", "repro"):
            self._emit("GUST-L01", node,
                       f"module-scope import of {module!r} in a lazy package")

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self._check_eager_import(node, alias.name)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module and node.level == 0:
            self._check_eager_import(node, node.module)
        elif node.level:  # relative import inside the lazy package
            if self._lazy_package() and not self.scope \
                    and not self.type_checking:
                self._emit("GUST-L01", node,
                           "module-scope relative import in a lazy package")
        self.generic_visit(node)

    # -- calls: GUST-L03 / L04 / L05 ---------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        name = None
        if isinstance(fn, ast.Name):
            name = fn.id
        elif isinstance(fn, ast.Attribute):
            name = fn.attr
        if name in _DECISION_POINTS:
            self._emit("GUST-L03", node,
                       f"{name}() called here — decision points have "
                       "sanctioned callers only")
        if isinstance(fn, ast.Name) and fn.id in _DEPRECATED:
            self._emit("GUST-L04", node,
                       f"call to deprecated {fn.id!r}")
        elif (isinstance(fn, ast.Attribute) and fn.attr in _DEPRECATED
              and isinstance(fn.value, ast.Name)
              and fn.value.id == "repro"):
            self._emit("GUST-L04", node,
                       f"call to deprecated repro.{fn.attr}")
        if isinstance(fn, ast.Attribute) \
                and fn.attr in ("savez", "savez_compressed") \
                and isinstance(fn.value, ast.Name) \
                and fn.value.id in ("np", "numpy"):
            self._emit("GUST-L05", node,
                       f"np.{fn.attr} cannot round-trip bfloat16 leaves; "
                       "use the PlanStore container")
        # GUST-L06: key expression of a .get/.setdefault on a cache-ish
        # receiver
        if isinstance(fn, ast.Attribute) \
                and fn.attr in ("get", "setdefault", "memo") and node.args:
            self._check_key_expr(node.args[0])
        self.generic_visit(node)

    # -- GUST-L06 -----------------------------------------------------------

    def _check_key_expr(self, expr: ast.AST) -> None:
        for sub in ast.walk(expr):
            knob = None
            if isinstance(sub, ast.Name) and sub.id in _EXEC_KNOBS:
                knob = sub.id
            elif isinstance(sub, ast.Attribute) and sub.attr in _EXEC_KNOBS:
                knob = sub.attr
            if knob:
                self._emit("GUST-L06", sub,
                           f"execution knob {knob!r} inside a cache-key "
                           "expression (one artifact serves all execution "
                           "configs)")

    def visit_Subscript(self, node: ast.Subscript) -> None:
        if isinstance(node.ctx, (ast.Store, ast.Load)):
            self._check_key_expr(node.slice)
        self.generic_visit(node)

    # -- GUST-L07 -----------------------------------------------------------

    def visit_ExceptHandler(self, node: ast.ExceptHandler) -> None:
        rel = self.relpath.replace(os.sep, "/")
        on_serving_path = any(
            rel.startswith(p) if p.endswith("/") else rel == p
            for p in _SERVING_PATHS
        )
        if on_serving_path:
            broad = node.type is None or (
                isinstance(node.type, ast.Name)
                and node.type.id in ("Exception", "BaseException")
            )
            swallows = all(
                isinstance(st, ast.Pass)
                or (isinstance(st, ast.Expr)
                    and isinstance(st.value, ast.Constant)
                    and st.value.value is Ellipsis)
                for st in node.body
            )
            if broad and swallows:
                self._emit(
                    "GUST-L07", node,
                    "broad except that only swallows on the serving path — "
                    "retire/count/degrade/re-raise, or allowlist the "
                    "sanctioned containment site",
                )
        self.generic_visit(node)


def lint_sources(
    src_dir: Optional[str] = None,
    allowlist: Optional[str] = None,
) -> List[LintFinding]:
    """Lint every ``.py`` under ``src_dir`` (default: the ``src`` root
    this package lives in); return non-allowlisted findings."""
    if src_dir is None:
        # .../src/repro/analysis/lint.py -> .../src
        src_dir = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
    allowed = load_allowlist(allowlist)
    findings: List[LintFinding] = []
    for root, _dirs, files in os.walk(src_dir):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(root, fname)
            rel = os.path.relpath(path, src_dir).replace(os.sep, "/")
            with open(path, encoding="utf-8") as f:
                try:
                    tree = ast.parse(f.read(), filename=path)
                except SyntaxError as e:
                    findings.append(LintFinding(
                        rule="GUST-L00", path=rel, line=e.lineno or 0,
                        qualname="<module>", message=f"syntax error: {e}"))
                    continue
            v = _Visitor(rel)
            v.visit(tree)
            findings.extend(v.findings)
    return [f for f in findings if (f.rule, f.site) not in allowed]
