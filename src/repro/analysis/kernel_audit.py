"""Kernel resource/race audit — static ``GUST-Kxx`` checks over the
Pallas kernel builders, from their source AST alone (no jax import, no
kernel execution, runs on any machine).

Three checks per kernel module (``kernels/gust_spmv.py``,
``gust_spmv_ragged.py``, ``gust_spgemm.py``, ``gather_fill.py``):

* **GUST-K01 — VMEM footprint.**  For every ``make_*`` builder, evaluate
  the BlockSpec tile shapes and ``pltpu.VMEM`` scratch shapes under an
  audit config (the builder's local arithmetic — ``num_cb = c_pad //
  c_blk`` etc. — is interpreted symbolically) and report the resulting
  VMEM bytes against the ~16 MB/core budget (pallas_guide.md).
  Pipelined operand/output tiles are counted twice (Pallas
  double-buffers them); ``memory_space=ANY`` operands are free; tile
  element size is taken as 4 bytes (f32 — an upper bound for the int8 /
  bf16 / int16 streams).  An over-budget config is an ``error`` finding:
  the audit configs are chosen to fit, so exceeding the budget means a
  builder's footprint grew.
* **GUST-K02 — DB ping/pong pairing.**  In every double-buffered kernel
  body (a function issuing ``.start()``/``.wait()`` on async-copy
  descriptors around a ``fori_loop``), verify the race-freedom protocol
  structurally: (a) an initial ``.start()`` fills slot 0 before the
  loop; (b) every in-loop ``.start()`` targets the *other* slot
  (``1 - slot``) and sits under a ``pl.when`` bound guard; (c) the loop
  waits on the current slot **before** any read of a ping/pong scratch
  at ``[slot]`` — i.e. every ``make_async_copy`` start has a matching
  semaphore wait before its scratch slot is reused.
* **GUST-K03 — grid-index bounds.**  Every subscript of a
  scalar-prefetch steering table (``seg``/``bw``/``bs`` and their
  ``_ref`` forms, in index-map lambdas and kernel bodies) is evaluated
  at the grid maxima and compared against the table's extent
  (``seg``: blocks×S_blk, ``bw``: num_blocks, ``bs``: num_windows+1).

Entry point: :func:`audit_kernels` → :class:`AuditResult` with
per-builder :class:`KernelReport` rows and :class:`AuditFinding`
violations.  ``python -m repro.analysis audit`` prints the report and
exits nonzero on any finding.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, List, Optional, Tuple

__all__ = [
    "AuditFinding",
    "KernelReport",
    "AuditResult",
    "audit_kernels",
    "VMEM_BUDGET_BYTES",
]

#: ~16 MB of VMEM per TPU core (pallas_guide.md, "Memory Spaces").
VMEM_BUDGET_BYTES = 16 * 2 ** 20

#: Kernel modules under repro/kernels owning pallas builders.
_KERNEL_MODULES = (
    "gust_spmv.py",
    "gust_spmv_ragged.py",
    "gust_spgemm.py",
    "gather_fill.py",
)

#: Scalar-prefetch steering tables and their extents (as expressions
#: over the audit config) per module.
_TABLE_EXTENTS: Dict[str, Dict[str, str]] = {
    "gust_spmv.py": {"seg": "t_blk * s_blk"},
    "gust_spmv_ragged.py": {
        "seg": "num_blocks * s_blk",
        "bw": "num_blocks",
        "bs": "num_windows + 1",
    },
    "gust_spgemm.py": {"bw": "num_blocks", "bs": "num_windows + 1"},
    "gather_fill.py": {},
}

_DTYPE_ITEMSIZE = {
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "float64": 8, "int64": 8,
}

#: Default audit configs: one tiny CI geometry and one serving-shaped
#: geometry (l=256 is the paper's hardware length).  Every builder picks
#: the names its signature mentions.
DEFAULT_CONFIGS: Tuple[Dict[str, object], ...] = (
    dict(name="tiny", num_windows=4, c_pad=16, l=8, seg_count=4, s_blk=4,
         b=8, c_blk=8, num_blocks=8, total_rows=16, r_rows=16, k_max=4,
         n_out=16, value_dtype="float32", index_dtype="int32",
         x_dtype="float32"),
    dict(name="serve256", num_windows=16, c_pad=64, l=256, seg_count=64,
         s_blk=8, b=8, c_blk=8, num_blocks=128, total_rows=1024,
         r_rows=256, k_max=8, n_out=256, value_dtype="int8",
         index_dtype="int16", x_dtype="float32"),
)


@dataclasses.dataclass(frozen=True)
class AuditFinding:
    rule: str        # GUST-K01 | GUST-K02 | GUST-K03
    severity: str    # "error"
    builder: str     # module::function
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.builder}: {self.message}"


@dataclasses.dataclass(frozen=True)
class KernelReport:
    builder: str           # module::function
    config: str            # audit config name
    vmem_bytes: int
    budget: int = VMEM_BUDGET_BYTES
    tiles: Tuple[str, ...] = ()

    @property
    def over_budget(self) -> bool:
        return self.vmem_bytes > self.budget

    def __str__(self) -> str:
        pct = 100.0 * self.vmem_bytes / self.budget
        flag = "  OVER BUDGET" if self.over_budget else ""
        return (f"{self.builder:55s} {self.config:9s} "
                f"{self.vmem_bytes / 2**20:8.3f} MiB ({pct:5.1f}%){flag}")


@dataclasses.dataclass(frozen=True)
class AuditResult:
    reports: Tuple[KernelReport, ...]
    findings: Tuple[AuditFinding, ...]
    db_kernels_checked: Tuple[str, ...]
    subscripts_checked: int

    @property
    def ok(self) -> bool:
        return not self.findings


# ---------------------------------------------------------------------------
# tiny symbolic evaluator over builder-local integer arithmetic
# ---------------------------------------------------------------------------


class _Unsupported(Exception):
    pass


def _eval(node: ast.AST, env: Dict[str, object]):
    if isinstance(node, ast.Constant):
        return node.value
    if isinstance(node, ast.Name):
        if node.id in env:
            return env[node.id]
        raise _Unsupported(node.id)
    if isinstance(node, ast.Tuple):
        return tuple(_eval(e, env) for e in node.elts)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -_eval(node.operand, env)
    if isinstance(node, ast.BinOp):
        left, right = _eval(node.left, env), _eval(node.right, env)
        op = node.op
        if isinstance(op, ast.Add):
            return left + right
        if isinstance(op, ast.Sub):
            return left - right
        if isinstance(op, ast.Mult):
            return left * right
        if isinstance(op, ast.FloorDiv):
            return left // right
        if isinstance(op, ast.Mod):
            return left % right
        if isinstance(op, ast.Div):
            return left / right
    raise _Unsupported(ast.dump(node)[:60])


def _itemsize(node: Optional[ast.AST], env: Dict[str, object]) -> int:
    """Element size of a dtype expression (``jnp.float32``, or a local
    like ``vdt`` bound from ``jnp.dtype(value_dtype)``).  Unknown → 4
    (the f32 upper bound for every stream the kernels carry)."""
    if node is None:
        return 4
    if isinstance(node, ast.Attribute):
        return _DTYPE_ITEMSIZE.get(node.attr, 4)
    if isinstance(node, ast.Name):
        v = env.get(node.id)
        if isinstance(v, int):
            return v
        if isinstance(v, str):
            return _DTYPE_ITEMSIZE.get(v, 4)
    return 4


def _bind_assigns(fn: ast.FunctionDef, env: Dict[str, object]) -> None:
    """Interpret the builder's simple local assignments into ``env``:
    integer arithmetic plus ``jnp.dtype(<name>)`` (bound to its
    itemsize).  Anything richer is skipped."""

    def value_of(node: ast.AST):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "dtype" and node.args:
            name = node.args[0]
            if isinstance(name, ast.Name) and isinstance(env.get(name.id), str):
                return _DTYPE_ITEMSIZE.get(env[name.id], 4)
            if isinstance(name, ast.Constant):
                return _DTYPE_ITEMSIZE.get(name.value, 4)
            raise _Unsupported("dtype")
        return _eval(node, env)

    for stmt in ast.walk(fn):
        if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
            continue
        tgt = stmt.targets[0]
        try:
            if isinstance(tgt, ast.Name):
                env[tgt.id] = value_of(stmt.value)
            elif isinstance(tgt, ast.Tuple) and isinstance(stmt.value, ast.Tuple) \
                    and len(tgt.elts) == len(stmt.value.elts):
                for t, v in zip(tgt.elts, stmt.value.elts):
                    if isinstance(t, ast.Name):
                        env[t.id] = value_of(v)
        except _Unsupported:
            continue


# ---------------------------------------------------------------------------
# GUST-K01: VMEM footprint per builder
# ---------------------------------------------------------------------------


def _builder_footprint(fn: ast.FunctionDef, config: Dict[str, object]):
    """(bytes, tile descriptions) for one ``make_*`` builder under one
    audit config — or None when the config lacks a parameter the builder
    needs (different kernel family)."""
    params = [a.arg for a in fn.args.args] + [a.arg for a in fn.args.kwonlyargs]
    env: Dict[str, object] = {}
    for p in params:
        if p in ("interpret", "quantized"):
            continue
        if p not in config:
            return None
        env[p] = config[p]
    _bind_assigns(fn, env)

    total = 0
    tiles: List[str] = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call) or not isinstance(node.func, ast.Attribute):
            continue
        if node.func.attr == "BlockSpec":
            if not node.args:      # memory_space=ANY: stays in HBM
                continue
            shape = node.args[0]
            if not isinstance(shape, ast.Tuple):
                continue
            try:
                dims = _eval(shape, env)
            except _Unsupported as e:
                raise _Unsupported(f"BlockSpec shape: {e}") from None
            n = 1
            for d in dims:
                n *= int(d)
            total += 2 * n * 4      # pipelined tile, auto double-buffered
            tiles.append(f"tile{tuple(int(d) for d in dims)}x2")
        elif node.func.attr == "VMEM":
            shape = node.args[0]
            try:
                dims = _eval(shape, env)
            except _Unsupported as e:
                raise _Unsupported(f"VMEM scratch shape: {e}") from None
            isz = _itemsize(node.args[1] if len(node.args) > 1 else None, env)
            n = 1
            for d in dims:
                n *= int(d)
            total += n * isz
            tiles.append(f"scratch{tuple(int(d) for d in dims)}@{isz}B")
    return total, tuple(tiles)


# ---------------------------------------------------------------------------
# GUST-K02: DB ping/pong start/wait pairing
# ---------------------------------------------------------------------------

#: helpers that construct async-copy descriptors; index of the slot arg.
_COPY_HELPERS = {"copy": 0, "copies": 0, "stream_copy": 3}


def _slot_kind(node: ast.AST) -> str:
    """Classify a slot expression: the loop's current slot (``slot``),
    the opposite slot (``1 - slot``), a constant (initial fill), or
    unknown."""
    if isinstance(node, ast.Name) and node.id == "slot":
        return "cur"
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub) \
            and isinstance(node.left, ast.Constant) and node.left.value == 1 \
            and isinstance(node.right, ast.Name) and node.right.id == "slot":
        return "alt"
    if isinstance(node, ast.Constant):
        return "const"
    return "unknown"


@dataclasses.dataclass
class _Event:
    line: int
    kind: str        # "start" | "wait" | "read"
    slot: str        # _slot_kind result
    in_body: bool    # inside the fori_loop body fn
    guarded: bool    # inside a pl.when-decorated nested def of body


def _copy_slot_expr(call: ast.Call) -> Optional[ast.AST]:
    fn = call.func
    if isinstance(fn, ast.Name) and fn.id in _COPY_HELPERS:
        idx = _COPY_HELPERS[fn.id]
        if len(call.args) > idx:
            return call.args[idx]
    return None


def _collect_events(fn: ast.FunctionDef) -> List[_Event]:
    events: List[_Event] = []

    def walk(node: ast.AST, stack: Tuple[str, ...]) -> None:
        if isinstance(node, ast.FunctionDef) and node is not fn:
            stack = stack + (node.name,)
        in_body = "body" in stack
        guarded = in_body and stack[-1] != "body"
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("start", "wait") \
                and isinstance(node.func.value, ast.Call):
            slot = _copy_slot_expr(node.func.value)
            if slot is not None:
                events.append(_Event(node.lineno, node.func.attr,
                                     _slot_kind(slot), in_body, guarded))
        if isinstance(node, ast.For) and isinstance(node.iter, ast.Call):
            slot = _copy_slot_expr(node.iter)
            if slot is not None:
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Call) \
                            and isinstance(sub.func, ast.Attribute) \
                            and sub.func.attr in ("start", "wait"):
                        events.append(_Event(sub.lineno, sub.func.attr,
                                             _slot_kind(slot), in_body,
                                             guarded))
        if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name) \
                and node.value.id.endswith("scr") \
                and isinstance(node.ctx, ast.Load):
            if any(isinstance(s, ast.Name) and s.id == "slot"
                   for s in ast.walk(node.slice)):
                events.append(_Event(node.lineno, "read", "cur", in_body,
                                     guarded))
        for child in ast.iter_child_nodes(node):
            walk(child, stack)

    walk(fn, ())
    return sorted(events, key=lambda e: e.line)


def _check_db_pairing(module: str, fn: ast.FunctionDef) -> List[AuditFinding]:
    events = _collect_events(fn)
    if not any(e.kind in ("start", "wait") for e in events):
        return []          # not a manual-DMA kernel
    site = f"{module}::{fn.name}"
    out: List[AuditFinding] = []

    def err(msg: str) -> None:
        out.append(AuditFinding("GUST-K02", "error", site, msg))

    pre = [e for e in events if not e.in_body]
    body = [e for e in events if e.in_body]
    if not any(e.kind == "start" for e in pre):
        err("no initial .start() before the fori_loop — slot 0 is read "
            "without ever being filled")
    for e in body:
        if e.kind == "start":
            if e.slot != "alt":
                err(f"line {e.line}: in-loop .start() targets slot "
                    f"{e.slot!r}, not the opposite slot (1 - slot) — "
                    "overwrites data the current iteration still reads")
            if not e.guarded:
                err(f"line {e.line}: in-loop prefetch .start() is not "
                    "under a pl.when bound guard — runs past the stream "
                    "extent on the last iteration")
    waits = [e for e in body if e.kind == "wait" and e.slot == "cur"]
    reads = [e for e in body if e.kind == "read"]
    if not waits:
        err("fori_loop body never .wait()s on the current slot")
    elif reads and min(r.line for r in reads) < min(w.line for w in waits):
        err(f"line {min(r.line for r in reads)}: ping/pong scratch read "
            "at [slot] before the matching semaphore .wait() — the DMA "
            "may still be in flight")
    return out


# ---------------------------------------------------------------------------
# GUST-K03: steering-table subscript bounds at grid maxima
# ---------------------------------------------------------------------------


def _grid_max_env(config: Dict[str, object]) -> Dict[str, object]:
    env = {k: v for k, v in config.items() if isinstance(v, int)}
    env["num_cb"] = env["c_pad"] // env["c_blk"]
    env["t_blk"] = env["num_windows"] * env["num_cb"]
    # grid / loop variables at their maxima
    env["w"] = env["num_windows"] - 1
    env["cb"] = env["num_cb"] - 1
    env["s"] = env["s_blk"] - 1
    env["t"] = max(env["num_blocks"], env["t_blk"]) - 1
    env["i"] = max(env["num_cb"], env["num_blocks"]) - 1
    env["blk"] = env["num_cb"] - 1
    env["slot"] = 1
    return env


def _check_subscripts(module: str, tree: ast.Module,
                      config: Dict[str, object]):
    tables = _TABLE_EXTENTS.get(module, {})
    if not tables:
        return [], 0
    env = _grid_max_env(config)
    # 't' must stay inside the *family's* block count, not the max of
    # both families: within one module t ranges over its own stream.
    if module == "gust_spmv.py":
        env["t"] = env["t_blk"] - 1
    elif module in ("gust_spmv_ragged.py", "gust_spgemm.py"):
        env["t"] = env["num_blocks"] - 1
    findings: List[AuditFinding] = []
    checked = 0
    for node in ast.walk(tree):
        if not isinstance(node, ast.Subscript) \
                or not isinstance(node.value, ast.Name):
            continue
        base = node.value.id
        key = base[:-4] if base.endswith("_ref") else base
        if key not in tables:
            continue
        try:
            idx = _eval(node.slice, env)
            extent = _eval(ast.parse(tables[key], mode="eval").body, env)
        except _Unsupported:
            continue
        checked += 1
        if not isinstance(idx, int):
            continue
        if idx >= extent or idx < 0:
            findings.append(AuditFinding(
                "GUST-K03", "error", f"{module}:{node.lineno}",
                f"subscript {base}[...] reaches {idx} at the grid maxima "
                f"but the table extent is {extent}"))
    return findings, checked


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def _kernels_dir() -> str:
    return os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "kernels")


def audit_kernels(
    configs: Optional[Tuple[Dict[str, object], ...]] = None,
    kernels_dir: Optional[str] = None,
) -> AuditResult:
    """Run all three static checks over every kernel module; returns the
    footprint reports and the (empty on a healthy tree) finding list."""
    configs = configs or DEFAULT_CONFIGS
    kdir = kernels_dir or _kernels_dir()
    reports: List[KernelReport] = []
    findings: List[AuditFinding] = []
    db_checked: List[str] = []
    subscripts = 0

    for module in _KERNEL_MODULES:
        path = os.path.join(kdir, module)
        if not os.path.exists(path):
            findings.append(AuditFinding(
                "GUST-K01", "error", module, "kernel module missing"))
            continue
        with open(path, encoding="utf-8") as f:
            tree = ast.parse(f.read(), filename=path)

        for fn in tree.body:
            if not isinstance(fn, ast.FunctionDef):
                continue
            if fn.name.startswith("make_"):
                for cfg in configs:
                    try:
                        got = _builder_footprint(fn, cfg)
                    except _Unsupported as e:
                        findings.append(AuditFinding(
                            "GUST-K01", "error", f"{module}::{fn.name}",
                            f"unevaluable VMEM shape under config "
                            f"{cfg['name']}: {e}"))
                        continue
                    if got is None:
                        continue
                    total, tiles = got
                    rep = KernelReport(
                        builder=f"{module}::{fn.name}",
                        config=str(cfg["name"]), vmem_bytes=total,
                        tiles=tiles)
                    reports.append(rep)
                    if rep.over_budget:
                        findings.append(AuditFinding(
                            "GUST-K01", "error", rep.builder,
                            f"VMEM footprint {total / 2**20:.2f} MiB "
                            f"exceeds the {VMEM_BUDGET_BYTES / 2**20:.0f} "
                            f"MiB budget under config {cfg['name']}"))
            # DB pairing runs over every function (the db bodies are
            # private helpers, not builders)
            db = _check_db_pairing(module, fn)
            if db or any(e.kind in ("start", "wait")
                         for e in _collect_events(fn)):
                db_checked.append(f"{module}::{fn.name}")
            findings.extend(db)

        sub_findings, n = _check_subscripts(module, tree, dict(configs[0]))
        findings.extend(sub_findings)
        subscripts += n

    return AuditResult(
        reports=tuple(reports), findings=tuple(findings),
        db_kernels_checked=tuple(db_checked),
        subscripts_checked=subscripts,
    )
