"""CLI for the static analysis legs (all jax-free)::

    python -m repro.analysis verify <store-dir>   # verify every artifact
    python -m repro.analysis lint   [src-dir]     # policy lint over src/
    python -m repro.analysis audit                # kernel resource audit

Exit status is nonzero when any check fails — all three run as
hard-failing CI steps.
"""

from __future__ import annotations

import argparse
import sys


def _cmd_verify(args: argparse.Namespace) -> int:
    # Local imports: the store parser pulls numpy only, never jax.
    from repro.core.plan_store import PlanStore
    from repro.analysis.verify import verify

    store = PlanStore(args.store_dir)
    keys = store.keys()
    if not keys:
        print(f"no artifacts under {args.store_dir}")
        return 0
    bad = 0
    for key in keys:
        record = store.get(key)
        if record is None:
            bad += 1
            print(f"{key}: UNPARSEABLE (counted corrupt by the store)")
            continue
        spec = record["spec"]
        findings = verify(spec["leaves"], spec["meta"])
        if findings:
            bad += 1
            print(f"{key}: {len(findings)} finding(s)")
            for f in findings:
                print(f"  {f}")
        else:
            print(f"{key}: ok")
    print(f"{len(keys)} artifact(s), {bad} failing")
    return 1 if bad else 0


def _cmd_lint(args: argparse.Namespace) -> int:
    from repro.analysis.lint import lint_sources

    findings = lint_sources(args.src_dir, allowlist=args.allowlist)
    for f in findings:
        print(f)
    print(f"lint: {len(findings)} finding(s)")
    return 1 if findings else 0


def _cmd_audit(args: argparse.Namespace) -> int:
    from repro.analysis.kernel_audit import audit_kernels

    result = audit_kernels()
    print("VMEM footprint vs budget (pipelined tiles x2 + scratch):")
    for rep in result.reports:
        print(f"  {rep}")
    print(f"DB ping/pong kernels checked: "
          f"{', '.join(result.db_kernels_checked) or 'none'}")
    print(f"steering-table subscripts bounds-checked: "
          f"{result.subscripts_checked}")
    for f in result.findings:
        print(f)
    print(f"audit: {len(result.findings)} finding(s)")
    return 1 if result.findings else 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="GUST static analysis: artifact verifier, policy "
                    "linter, kernel resource/race audit.",
    )
    sub = parser.add_subparsers(dest="command", required=True)

    p_verify = sub.add_parser("verify", help="verify every artifact in a "
                                             "PlanStore directory")
    p_verify.add_argument("store_dir")
    p_verify.set_defaults(fn=_cmd_verify)

    p_lint = sub.add_parser("lint", help="policy lint over a source tree")
    p_lint.add_argument("src_dir", nargs="?", default=None)
    p_lint.add_argument("--allowlist", default=None)
    p_lint.set_defaults(fn=_cmd_lint)

    p_audit = sub.add_parser("audit", help="kernel VMEM/race/bounds audit")
    p_audit.set_defaults(fn=_cmd_audit)

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
