"""repro.analysis — static verification of GUST artifacts and policies.

Three independent legs, none of which execute a kernel:

* :mod:`repro.analysis.verify` — the artifact verifier.  Every
  machine-checkable packed-format contract from ROADMAP.md (padding
  canonicalization, ragged block metadata, gather tables, scale leaves,
  collision-freedom, index dtypes, canonical COO) as an executable rule
  with a ``GUST-Pxx`` id.  Entry points: :func:`verify` /
  :class:`Finding`, plus ``GustPlan.verify()`` and the ``PlanStore``
  verify-on-load mode.
* :mod:`repro.analysis.lint` — the policy linter.  An AST pass over
  ``src/`` enforcing the repo's written rules (``GUST-Lxx``): lazy
  no-jax top-level package, no new public free functions outside
  ``GustPlan``, single-decision-point ``resolve_*`` call sites, no new
  deprecated-shim call sites, no ``np.savez`` on artifact paths, no
  execution knobs in cache keys.  Grandfathered sites live in
  ``lint_allowlist.txt`` (format documented there and in
  :mod:`repro.analysis.lint`).
* :mod:`repro.analysis.kernel_audit` — the kernel resource/race audit
  (``GUST-Kxx``): per-builder VMEM footprint vs the 16MB budget,
  DB ping/pong semaphore pairing, and grid-index bounds — all from the
  kernel sources' AST, no jax import and no kernel execution.

Like the top-level package, imports resolve lazily (PEP 562): importing
``repro.analysis`` pulls no jax and no kernel modules — the verifier
itself runs on plain numpy leaves.

CLI::

    python -m repro.analysis verify <store-dir>   # artifact store scan
    python -m repro.analysis lint   [src-dir]     # policy lint
    python -m repro.analysis audit                # kernel resource audit
"""

from typing import TYPE_CHECKING

_EXPORTS = {
    "Finding": "repro.analysis.verify",
    "verify": "repro.analysis.verify",
    "verify_artifact": "repro.analysis.verify",
    "lint_sources": "repro.analysis.lint",
    "audit_kernels": "repro.analysis.kernel_audit",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module_name = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro.analysis' has no attribute {name!r}"
        ) from None
    import importlib

    value = getattr(importlib.import_module(module_name), name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))


if TYPE_CHECKING:  # static analyzers see the real symbols
    from repro.analysis.kernel_audit import audit_kernels  # noqa: F401
    from repro.analysis.lint import lint_sources  # noqa: F401
    from repro.analysis.verify import (  # noqa: F401
        Finding,
        verify,
        verify_artifact,
    )
