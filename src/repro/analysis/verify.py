"""Artifact verifier — ROADMAP invariants as executable ``GUST-Pxx`` rules.

Every rule checks a *machine-decidable* contract of the packed scheduled
format (ROADMAP.md invariant sections; each rule cites its section).  The
verifier runs on plain numpy views of the leaves — no jax import, no
kernel execution — so it can gate artifact loads (``PlanStore``
verify-on-load), run in CI, and scan store directories from the
``python -m repro.analysis verify`` CLI.

Padding identification is the one subtle point.  A padding slot is
``(m=0, col=lane, row=0)`` by construction, but from leaves alone a
zero *value* does not always mean padding: an int8 stream's real edges
may quantize to 0 (``rint(v/scale)`` of a tiny value), keeping their
real column/row.  The rules therefore split by stream dtype:

* float streams: a zero-valued slot IS padding (real COO edges are
  nonzero), so canonicalization (GUST-P02/P03) checks every zero slot;
* int8 streams: canonicalization runs at block granularity — a block
  containing any real edge must contain a ``±127`` (``scale =
  absmax/127`` puts the absmax slot exactly there), so an all-zero
  block is provably all-padding and only those are canonicalized.

Real cycles form a per-window *prefix* of the stream (the packer
scatters window ``w``'s ``C_w`` real cycles to its leading rows), which
gives the sound padding-region rule GUST-P01: within a window, no
nonzero row (block, for int8) may follow an all-zero one.  That is what
catches a flipped padding value without knowing the source matrix.

Dependent rules gate on their prerequisites (e.g. the GUST-P10 remap
check only evaluates slots whose column is in-bounds and only when the
segment table itself verified) so one seeded corruption fires exactly
one rule.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = ["Finding", "verify", "verify_artifact", "RULES"]

#: Max offending indices carried per finding (evidence, not a full dump).
_MAX_INDICES = 8

#: rule id -> (severity, ROADMAP section, one-line contract).
RULES: Dict[str, Tuple[str, str, str]] = {
    "GUST-P01": ("error", "Packed-format invariants",
                 "real cycles are a per-window prefix: no nonzero row/block "
                 "after an all-zero one (padding value slots are 0)"),
    "GUST-P02": ("error", "Packed-format invariants",
                 "padding column slots hold their own lane index "
                 "(gather v[lane], in-bounds)"),
    "GUST-P03": ("error", "Packed-format invariants",
                 "padding row slots are 0; every row_blk is in [0, l)"),
    "GUST-P04": ("error", "Packed-format invariants",
                 "fusable lane structure: col % l in {lane, l-1-lane} "
                 "for every slot"),
    "GUST-P05": ("error", "Scheduler + plan-store invariants",
                 "index-dtype policy: col/row/col_loc share one int16/int32 "
                 "dtype; seg_blk is int32; block metadata is integral"),
    "GUST-P06": ("error", "Ragged-stream invariants",
                 "block_starts is a (W+1,) strictly increasing prefix from "
                 "0 to num_blocks (>= 1 block per window)"),
    "GUST-P07": ("error", "Ragged-stream invariants",
                 "block_window is the sorted expansion of block_starts "
                 "(contiguous window ownership)"),
    "GUST-P08": ("error", "Gather-locality invariants",
                 "seg_blk rows are sorted: strictly increasing distinct "
                 "segments then segment-0 padding"),
    "GUST-P09": ("error", "Gather-locality invariants",
                 "seg_blk entries are in-bounds: 0 <= seg < seg_count"),
    "GUST-P10": ("error", "Gather-locality invariants",
                 "col_loc remap: col_loc % l == col % l and "
                 "seg_blk[t, col_loc // l] == col // l for every slot"),
    "GUST-P11": ("error", "Kernel-speed invariants",
                 "scale_blk is (T_blk,) float32, finite and > 0"),
    "GUST-P12": ("error", "Kernel-speed invariants",
                 "all-zero (padding) blocks carry scale exactly 1.0"),
    "GUST-P13": ("error", "Kernel-speed invariants",
                 "an int8 block with any nonzero payload holds a +/-127 "
                 "(scale = absmax/127 pins the absmax slot there)"),
    "GUST-P14": ("error", "Packed-format invariants",
                 "collision-freedom: within a stream row (one window cycle) "
                 "no two real slots share an adder (row_blk)"),
    "GUST-P15": ("error", "Packed-format invariants",
                 "leaf/meta consistency: stream shapes match the meta "
                 "geometry and row_perm is a (identity-when-flagged) "
                 "permutation of the scheduled rows"),
    "GUST-P16": ("error", "SpGEMM invariants",
                 "canonical COO: strictly increasing row*n+col keys, "
                 "in-bounds indices, no explicit zeros"),
    "GUST-P17": ("error", "Gather-locality invariants",
                 "every col_blk is in [0, seg_count*l): the padded-x gather "
                 "stays in-bounds"),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verified-contract violation.

    ``rule`` is the ``GUST-Pxx`` id (see :data:`RULES` and the matching
    ROADMAP.md anchor), ``leaf`` the offending array leaf (or pseudo-leaf
    like ``"meta"``), ``indices`` up to ``_MAX_INDICES`` offending
    positions as index tuples, ``count`` the total violation count.
    """

    rule: str
    severity: str
    leaf: str
    message: str
    indices: Tuple[Tuple[int, ...], ...] = ()
    count: int = 0
    section: str = ""

    def __str__(self) -> str:
        where = f" at {list(self.indices)}" if self.indices else ""
        more = (
            f" (+{self.count - len(self.indices)} more)"
            if self.count > len(self.indices)
            else ""
        )
        return (
            f"[{self.rule}:{self.severity}] {self.leaf}: {self.message}"
            f"{where}{more}"
        )


def _finding(rule: str, leaf: str, message: str,
             where: Optional[np.ndarray] = None) -> Finding:
    severity, section, _ = RULES[rule]
    indices: Tuple[Tuple[int, ...], ...] = ()
    count = 0
    if where is not None:
        idx = np.argwhere(where)
        count = int(idx.shape[0])
        indices = tuple(tuple(int(v) for v in row) for row in idx[:_MAX_INDICES])
    return Finding(rule=rule, severity=severity, leaf=leaf, message=message,
                   indices=indices, count=count, section=section)


# ---------------------------------------------------------------------------
# Input normalization.
# ---------------------------------------------------------------------------


def _normalize(plan_or_leaves, meta) -> Tuple[Dict[str, np.ndarray], Tuple]:
    """Coerce any accepted input to ``(leaves dict of numpy arrays, meta)``.

    Accepts a ``GustPlan`` (packs lazily via ``.artifact``), a
    ``PackedSchedule`` / ``RaggedSchedule`` (duck-typed on
    ``block_starts``), or an explicit ``(leaves, meta)`` pair in the
    plan-store/codec wire format.  Only duck typing — no repro.core
    import, so the verifier stays jax-free.
    """
    obj = plan_or_leaves
    if hasattr(obj, "artifact") and hasattr(obj, "config"):  # GustPlan
        obj = obj.artifact
    if hasattr(obj, "m_blk"):  # PackedSchedule / RaggedSchedule
        leaves = {
            "m_blk": obj.m_blk, "col_blk": obj.col_blk,
            "row_blk": obj.row_blk, "row_perm": obj.row_perm,
            "seg_blk": obj.seg_blk, "col_loc": obj.col_loc,
        }
        if getattr(obj, "scale_blk", None) is not None:
            leaves["scale_blk"] = obj.scale_blk
        if hasattr(obj, "block_starts"):
            leaves["block_window"] = obj.block_window
            leaves["block_starts"] = obj.block_starts
            meta = ("ragged", obj.l, obj.num_windows, obj.c_blk,
                    obj.num_blocks, obj.shape, obj.fusable, obj.s_blk,
                    obj.identity_perm)
        else:
            meta = (obj.l, obj.num_windows, obj.c_pad, obj.shape,
                    obj.fusable, obj.c_blk, obj.s_blk, obj.identity_perm)
    elif isinstance(obj, dict):
        leaves = obj
        if meta is None:
            raise ValueError("verify(leaves_dict, meta): meta is required")
    else:
        raise TypeError(
            "verify() takes a GustPlan, a packed/ragged artifact, a "
            f"(leaves, meta) pair, or a COOMatrix; got {type(obj).__name__}"
        )
    return {k: np.asarray(v) for k, v in leaves.items()}, tuple(meta)


@dataclasses.dataclass
class _Geometry:
    """Meta tuple decoded to one namespace for both layouts."""

    ragged: bool
    l: int
    num_windows: int
    c_blk: int
    shape: Tuple[int, int]
    fusable: bool
    s_blk: int
    identity_perm: bool
    c_pad: int = 0        # padded layout only
    num_blocks: int = 0   # ragged layout only

    @property
    def seg_count(self) -> int:
        return -(-self.shape[1] // self.l)

    @property
    def stream_rows(self) -> int:
        if self.ragged:
            return self.num_blocks * self.c_blk
        return self.num_windows * self.c_pad


def _decode_meta(meta: Tuple) -> _Geometry:
    if meta and meta[0] == "ragged":
        _, l, w, c_blk, t_blk, shape, fusable, s_blk, identity_perm = meta
        return _Geometry(True, int(l), int(w), int(c_blk), tuple(shape),
                         bool(fusable), int(s_blk), bool(identity_perm),
                         num_blocks=int(t_blk))
    l, w, c_pad, shape, fusable, c_blk, s_blk, identity_perm = meta
    return _Geometry(False, int(l), int(w), int(c_blk), tuple(shape),
                     bool(fusable), int(s_blk), bool(identity_perm),
                     c_pad=int(c_pad))


# ---------------------------------------------------------------------------
# Rule implementations.  Each returns a list of findings; dependent rules
# receive the prerequisite verdicts so one corruption fires one rule.
# ---------------------------------------------------------------------------


def _window_of_rows(g: _Geometry,
                    leaves: Dict[str, np.ndarray]) -> np.ndarray:
    """Window id of every stream row (int64), from the layout geometry."""
    rows = np.arange(g.stream_rows, dtype=np.int64)
    if not g.ragged:
        return rows // max(g.c_pad, 1)
    bw = np.asarray(leaves["block_window"], np.int64)
    return bw[np.minimum(rows // g.c_blk, max(bw.shape[0] - 1, 0))]


def _check_meta_shapes(leaves, g: _Geometry) -> List[Finding]:
    out: List[Finding] = []
    rows = g.stream_rows
    for name in ("m_blk", "col_blk", "row_blk", "col_loc"):
        arr = leaves.get(name)
        if arr is None:
            out.append(_finding("GUST-P15", name, "leaf missing"))
        elif arr.shape != (rows, g.l):
            out.append(_finding(
                "GUST-P15", name,
                f"shape {arr.shape} != stream geometry ({rows}, {g.l})",
            ))
    if not g.ragged and g.c_pad % max(g.c_blk, 1):
        out.append(_finding(
            "GUST-P15", "meta",
            f"c_pad {g.c_pad} not a multiple of c_blk {g.c_blk}",
        ))
    vdt = leaves["m_blk"].dtype if "m_blk" in leaves else None
    if vdt is not None and vdt.name not in ("float32", "bfloat16", "int8"):
        out.append(_finding(
            "GUST-P15", "m_blk", f"unsupported value dtype {vdt.name}"))
    quant = vdt is not None and vdt.name == "int8"
    if quant and "scale_blk" not in leaves:
        out.append(_finding(
            "GUST-P15", "scale_blk", "int8 stream without a scale leaf"))
    if not quant and "scale_blk" in leaves:
        out.append(_finding(
            "GUST-P15", "scale_blk",
            f"scale leaf on a non-quantized ({vdt}) stream"))
    perm = leaves.get("row_perm")
    if perm is not None:
        wl = g.num_windows * g.l
        if perm.shape != (wl,):
            out.append(_finding(
                "GUST-P15", "row_perm",
                f"shape {perm.shape} != ({wl},)"))
        elif g.identity_perm:
            if not np.array_equal(perm, np.arange(wl, dtype=perm.dtype)):
                out.append(_finding(
                    "GUST-P15", "row_perm",
                    "identity_perm is set but row_perm is not the identity",
                    np.asarray(perm) != np.arange(wl),
                ))
        elif not np.array_equal(np.sort(np.asarray(perm, np.int64)),
                                np.arange(wl, dtype=np.int64)):
            out.append(_finding(
                "GUST-P15", "row_perm",
                f"not a permutation of arange({wl})"))
    return out


def _check_dtypes(leaves, g: _Geometry) -> List[Finding]:
    out: List[Finding] = []
    idx_dtypes = {leaves[k].dtype.name
                  for k in ("col_blk", "row_blk", "col_loc") if k in leaves}
    if not idx_dtypes <= {"int16", "int32"}:
        out.append(_finding(
            "GUST-P05", "col_blk",
            f"index dtypes {sorted(idx_dtypes)} outside the int16/int32 "
            "policy"))
    elif len(idx_dtypes) > 1:
        out.append(_finding(
            "GUST-P05", "col_blk",
            f"col/row/col_loc dtypes disagree: {sorted(idx_dtypes)}"))
    if "seg_blk" in leaves and leaves["seg_blk"].dtype != np.int32:
        out.append(_finding(
            "GUST-P05", "seg_blk",
            f"seg_blk is {leaves['seg_blk'].dtype.name}, contract says "
            "int32"))
    for name in ("block_window", "block_starts", "row_perm"):
        arr = leaves.get(name)
        if arr is not None and not np.issubdtype(arr.dtype, np.integer):
            out.append(_finding(
                "GUST-P05", name, f"non-integral dtype {arr.dtype.name}"))
    return out


def _check_ragged_meta(leaves, g: _Geometry) -> List[Finding]:
    out: List[Finding] = []
    bs = leaves.get("block_starts")
    bw = leaves.get("block_window")
    if bs is None or bw is None:
        return [_finding("GUST-P06", "block_starts",
                         "ragged artifact missing block metadata leaves")]
    bs = np.asarray(bs, np.int64)
    ok = True
    if bs.shape != (g.num_windows + 1,):
        out.append(_finding(
            "GUST-P06", "block_starts",
            f"shape {bs.shape} != (num_windows+1,) = ({g.num_windows + 1},)"))
        ok = False
    else:
        if bs[0] != 0 or bs[-1] != g.num_blocks:
            out.append(_finding(
                "GUST-P06", "block_starts",
                f"prefix runs {bs[0]}..{bs[-1]}, expected 0..{g.num_blocks}"))
            ok = False
        bad = np.diff(bs) < 1
        if bad.any():
            out.append(_finding(
                "GUST-P06", "block_starts",
                "not strictly increasing (every window owns >= 1 block)",
                bad))
            ok = False
    if ok:
        expect = np.repeat(np.arange(g.num_windows, dtype=np.int64),
                           np.diff(bs))
        bw64 = np.asarray(bw, np.int64)
        if bw64.shape != expect.shape:
            out.append(_finding(
                "GUST-P07", "block_window",
                f"shape {bw64.shape} != (num_blocks,) = {expect.shape}"))
        elif not np.array_equal(bw64, expect):
            out.append(_finding(
                "GUST-P07", "block_window",
                "not the sorted expansion of block_starts (window block "
                "ownership must be contiguous)", bw64 != expect))
    return out


def _padding_masks(leaves, g: _Geometry):
    """(zero_slots, padding_slots, pad_rows, row_zero, window_of_row).

    ``padding_slots`` is the *provable* padding region: every zero slot
    for float streams; for int8 streams only slots in all-zero blocks
    (a block holding any real edge provably holds a +/-127, GUST-P13).
    """
    m = leaves["m_blk"]
    if m.dtype.name == "bfloat16":  # ml_dtypes: compare in f32
        zero = m.astype(np.float32) == 0.0
    else:
        zero = np.asarray(m) == 0
    row_zero = zero.all(axis=1)
    win = _window_of_rows(g, leaves)
    if m.dtype == np.int8:
        t_blk = zero.shape[0] // max(g.c_blk, 1)
        blk_zero = zero[: t_blk * g.c_blk].reshape(t_blk, -1).all(axis=1)
        padding = np.repeat(blk_zero, g.c_blk)[:, None] & zero
    else:
        padding = zero
    return zero, padding, row_zero, win


def _check_padding_prefix(leaves, g: _Geometry, zero, row_zero,
                          win) -> List[Finding]:
    """GUST-P01: within each window nonzero content never follows an
    all-zero row (float) / block (int8)."""
    m = leaves["m_blk"]
    if m.dtype == np.int8:
        t_blk = zero.shape[0] // max(g.c_blk, 1)
        unit_zero = zero[: t_blk * g.c_blk].reshape(t_blk, -1).all(axis=1)
        unit_win = win[:: g.c_blk][:t_blk]
    else:
        unit_zero = row_zero
        unit_win = win
    n_units = unit_zero.shape[0]
    if n_units == 0:
        return []
    # "saw an all-zero unit earlier in my window": units are already
    # window-contiguous, so it's a prefix-count difference.
    first = np.ones(n_units, dtype=bool)
    first[1:] = unit_win[1:] != unit_win[:-1]
    idx = np.arange(n_units)
    start = np.maximum.accumulate(np.where(first, idx, 0))
    cs = np.cumsum(unit_zero)
    zeros_before = (cs - unit_zero) - (cs[start] - unit_zero[start])
    bad = (~unit_zero) & (zeros_before > 0)
    if not bad.any():
        return []
    unit = "block" if m.dtype == np.int8 else "row"
    return [_finding(
        "GUST-P01", "m_blk",
        f"nonzero stream {unit} follows an all-zero {unit} in the same "
        f"window (real cycles must be a prefix; padding values must be 0)",
        bad)]


def _check_padding_canonical(leaves, g: _Geometry, padding) -> List[Finding]:
    out: List[Finding] = []
    lane = np.arange(g.l, dtype=np.int64)
    col = np.asarray(leaves["col_blk"], np.int64)
    row = np.asarray(leaves["row_blk"], np.int64)
    bad_col = padding & (col != lane[None, :])
    if bad_col.any():
        out.append(_finding(
            "GUST-P02", "col_blk",
            "padding slot column != its lane index (padding must gather "
            "v[lane])", bad_col))
    bad_row = padding & (row != 0)
    if bad_row.any():
        out.append(_finding(
            "GUST-P03", "row_blk",
            "padding slot row != 0", bad_row))
    oob_row = (row < 0) | (row >= g.l)
    if oob_row.any():
        out.append(_finding(
            "GUST-P03", "row_blk",
            f"row_blk outside [0, l={g.l})", oob_row))
    return out


def _check_col_bounds(leaves, g: _Geometry) -> List[Finding]:
    col = np.asarray(leaves["col_blk"], np.int64)
    hi = g.seg_count * g.l
    oob = (col < 0) | (col >= hi)
    if not oob.any():
        return []
    return [_finding(
        "GUST-P17", "col_blk",
        f"column outside the padded gather range [0, seg_count*l={hi})",
        oob)]


def _check_fusable(leaves, g: _Geometry) -> List[Finding]:
    if not g.fusable:
        return []
    lane = np.arange(g.l, dtype=np.int64)
    off = np.asarray(leaves["col_blk"], np.int64) % g.l
    bad = (off != lane[None, :]) & (off != (g.l - 1 - lane)[None, :])
    if not bad.any():
        return []
    return [_finding(
        "GUST-P04", "col_blk",
        "fusable flag set but col % l is neither lane nor l-1-lane",
        bad)]


def _check_gather_tables(leaves, g: _Geometry,
                         col_ok: bool) -> List[Finding]:
    out: List[Finding] = []
    seg = leaves.get("seg_blk")
    if seg is None:
        return [_finding("GUST-P09", "seg_blk", "gather table leaf missing")]
    seg = np.asarray(seg, np.int64)
    rows = leaves["m_blk"].shape[0]
    t_blk = -(-rows // max(g.c_blk, 1))
    if seg.shape != (t_blk, g.s_blk):
        return [_finding(
            "GUST-P09", "seg_blk",
            f"shape {seg.shape} != (T_blk, S_blk) = ({t_blk}, {g.s_blk})")]
    oob = (seg < 0) | (seg >= g.seg_count)
    seg_ok = True
    if oob.any():
        out.append(_finding(
            "GUST-P09", "seg_blk",
            f"segment id outside [0, seg_count={g.seg_count})", oob))
        seg_ok = False
    # Sorted structure: a strictly increasing distinct prefix, then 0
    # padding.  0 can only legitimately appear at slot 0, so any later
    # entry must be 0 (padding) or > its predecessor.
    if g.s_blk > 1:
        nxt, prev = seg[:, 1:], seg[:, :-1]
        bad = ~((nxt == 0) | (nxt > prev))
        if bad.any():
            idx = np.zeros_like(seg, dtype=bool)
            idx[:, 1:] = bad
            out.append(_finding(
                "GUST-P08", "seg_blk",
                "row not sorted (distinct ascending segments then "
                "segment-0 padding)", idx))
            seg_ok = False
    # Remap consistency — gated on the table itself and on in-bounds
    # columns so a GUST-P08/P09/P17 corruption doesn't double-fire here.
    if seg_ok and col_ok:
        col = np.asarray(leaves["col_blk"], np.int64)
        loc = np.asarray(leaves["col_loc"], np.int64)
        if loc.shape != col.shape:
            return out + [_finding(
                "GUST-P10", "col_loc",
                f"shape {loc.shape} != col_blk shape {col.shape}")]
        bad_lane = (loc % g.l) != (col % g.l)
        lseg = loc // g.l
        bad_slot = (lseg < 0) | (lseg >= g.s_blk)
        t_of_row = np.minimum(
            np.arange(col.shape[0]) // max(g.c_blk, 1), t_blk - 1
        )
        lookup = seg[t_of_row[:, None],
                     np.clip(lseg, 0, g.s_blk - 1)]
        bad_seg = lookup != (col // g.l)
        bad = bad_lane | bad_slot | bad_seg
        if bad.any():
            out.append(_finding(
                "GUST-P10", "col_loc",
                "local remap broken: need col_loc % l == col % l and "
                "seg_blk[t, col_loc // l] == col // l", bad))
    return out


def _check_scales(leaves, g: _Geometry, zero) -> List[Finding]:
    m = leaves["m_blk"]
    if m.dtype != np.int8:
        return []
    out: List[Finding] = []
    scale = leaves.get("scale_blk")
    if scale is None:
        return []  # GUST-P15 already reported the missing leaf
    rows = m.shape[0]
    t_blk = rows // max(g.c_blk, 1)
    if scale.shape != (t_blk,) or scale.dtype != np.float32:
        return [_finding(
            "GUST-P11", "scale_blk",
            f"expected (T_blk,)=({t_blk},) float32, got {scale.shape} "
            f"{scale.dtype.name}")]
    s = np.asarray(scale, np.float64)
    bad = ~np.isfinite(s) | (s <= 0)
    if bad.any():
        out.append(_finding(
            "GUST-P11", "scale_blk", "scale not finite-positive", bad))
        return out
    blk_zero = zero[: t_blk * g.c_blk].reshape(t_blk, -1).all(axis=1)
    bad_pad = blk_zero & (s != 1.0)
    if bad_pad.any():
        out.append(_finding(
            "GUST-P12", "scale_blk",
            "all-zero (padding) block scale != 1.0", bad_pad))
    q = np.asarray(m[: t_blk * g.c_blk], np.int64).reshape(t_blk, -1)
    bad_peak = (~blk_zero) & (np.abs(q).max(axis=1) != 127)
    if bad_peak.any():
        out.append(_finding(
            "GUST-P13", "m_blk",
            "block with nonzero payload lacks a +/-127 (absmax/127 "
            "quantization pins the absmax slot at +/-127)", bad_peak))
    return out


def _check_collisions(leaves, g: _Geometry, zero) -> List[Finding]:
    """GUST-P14: within a stream row, real slots route to distinct
    adders.  Lane exclusivity is structural in the packed layout; adder
    (row) exclusivity is the paper's collision-freedom."""
    row = np.asarray(leaves["row_blk"], np.int64)
    real = ~zero
    if not real.any():
        return []
    # bucket-count per (stream row, adder) with values clipped in-range
    # (out-of-range already fires GUST-P03)
    r = np.clip(row, 0, g.l - 1)
    rows = row.shape[0]
    keys = np.arange(rows, dtype=np.int64)[:, None] * g.l + r
    counts = np.bincount(keys[real].ravel(), minlength=rows * g.l)
    dup_key = counts > 1
    if not dup_key.any():
        return []
    bad = real & dup_key.reshape(rows, g.l)[
        np.arange(rows)[:, None], r]
    return [_finding(
        "GUST-P14", "row_blk",
        "two real slots of one cycle share an adder (colors must be "
        "collision-free within a window)", bad)]


def _verify_coo(coo) -> List[Finding]:
    """GUST-P16: canonical sparse COO (the SpGEMM output contract)."""
    out: List[Finding] = []
    m, n = coo.shape
    rows = np.asarray(coo.rows, np.int64)
    cols = np.asarray(coo.cols, np.int64)
    vals = np.asarray(coo.vals)
    oob = (rows < 0) | (rows >= m) | (cols < 0) | (cols >= n)
    if oob.any():
        out.append(_finding(
            "GUST-P16", "rows/cols",
            f"index outside {coo.shape}", oob))
        return out
    keys = rows * n + cols
    if keys.shape[0] > 1:
        bad = keys[1:] <= keys[:-1]
        if bad.any():
            idx = np.zeros_like(keys, dtype=bool)
            idx[1:] = bad
            out.append(_finding(
                "GUST-P16", "rows/cols",
                "row*n+col keys not strictly increasing (canonical COO is "
                "deduplicated and row-major sorted)", idx))
    zero = vals == 0
    if zero.any():
        out.append(_finding(
            "GUST-P16", "vals", "explicit zeros in a canonical COO", zero))
    return out


# ---------------------------------------------------------------------------
# Entry point.
# ---------------------------------------------------------------------------


def verify(plan_or_leaves, meta: Optional[Sequence] = None) -> List[Finding]:
    """Verify a packed GUST artifact against every ``GUST-Pxx`` rule.

    ``plan_or_leaves`` may be a ``GustPlan`` (its artifact is packed
    lazily), a ``PackedSchedule`` / ``RaggedSchedule``, a ``COOMatrix``
    (canonical-form check, GUST-P16), or a leaves dict with ``meta`` the
    codec meta tuple.  Returns a list of :class:`Finding` — empty means
    every machine-checkable contract holds.
    """
    if (hasattr(plan_or_leaves, "rows") and hasattr(plan_or_leaves, "vals")
            and not hasattr(plan_or_leaves, "m_blk")):
        return _verify_coo(plan_or_leaves)
    leaves, meta = _normalize(plan_or_leaves, meta)
    g = _decode_meta(meta)

    findings = _check_meta_shapes(leaves, g)
    core = ("m_blk", "col_blk", "row_blk", "col_loc")
    if any(f.leaf in core and f.rule == "GUST-P15" for f in findings):
        return findings  # geometry broken: element rules would misindex
    findings += _check_dtypes(leaves, g)
    if g.ragged:
        ragged_findings = _check_ragged_meta(leaves, g)
        findings += ragged_findings
        if any(f.rule == "GUST-P06" for f in ragged_findings):
            return findings  # window mapping unusable downstream

    zero, padding, row_zero, win = _padding_masks(leaves, g)
    findings += _check_padding_prefix(leaves, g, zero, row_zero, win)
    findings += _check_padding_canonical(leaves, g, padding)
    col_findings = _check_col_bounds(leaves, g)
    findings += col_findings
    findings += _check_fusable(leaves, g)
    findings += _check_gather_tables(leaves, g, col_ok=not col_findings)
    findings += _check_scales(leaves, g, zero)
    findings += _check_collisions(leaves, g, zero)
    return findings


#: Back-compat spelling used by the CLI and PlanStore hook.
verify_artifact = verify
