"""Request lifecycle vocabulary for the serving loop — jax-free.

Every request a :class:`~repro.serving.ServeLoop` ever sees terminates
with exactly one :class:`RequestResult` carrying a definite
:class:`RequestStatus` — the chaos gate (``benchmarks/chaos_bench.py``)
is precisely "no request is ever lost, whatever faults fire".

Statuses:

* ``DONE``      — retired normally (EOS or ``max_new`` reached).
* ``FAILED``    — a contained fault retired this request; other slots'
                  token streams are bitwise unaffected (PR 4 contract).
* ``TIMEOUT``   — the per-request deadline (decode-step or wall budget)
                  expired; tokens generated so far are preserved.
* ``SHED``      — rejected at admission: the bounded queue was full
                  (reject-newest backpressure, counted).
* ``CANCELLED`` — explicitly cancelled via ``ServeLoop.cancel(rid)``.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import List

__all__ = ["RequestStatus", "RequestResult"]


class RequestStatus(str, enum.Enum):
    """Terminal states; ``str``-valued so records JSON-serialize as the
    plain status name."""

    DONE = "DONE"
    FAILED = "FAILED"
    TIMEOUT = "TIMEOUT"
    SHED = "SHED"
    CANCELLED = "CANCELLED"

    def __str__(self) -> str:  # "DONE", not "RequestStatus.DONE"
        return self.value


@dataclasses.dataclass
class RequestResult:
    """Terminal record for one request.

    ``tokens`` holds whatever was generated before retirement (empty for
    SHED); ``reason`` is a human-readable cause for non-DONE statuses;
    ``steps`` counts the decode steps this request was active for.
    """

    rid: int
    status: RequestStatus
    tokens: List[int] = dataclasses.field(default_factory=list)
    reason: str = ""
    steps: int = 0

    @property
    def ok(self) -> bool:
        return self.status is RequestStatus.DONE
