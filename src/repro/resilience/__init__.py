"""``repro.resilience`` — fault injection, retry, lifecycle, fallback.

Deliberately jax-free (like ``repro.analysis``): the store, the serving
loop's control plane, and CI tooling import from here without pulling
the accelerator stack.  Three legs (ROADMAP §Resilience invariants):

* :mod:`.faults`    — deterministic seeded fault injection over named
                      sites (``FaultPlan`` / ``FaultSpec`` / ``trip``).
* :mod:`.retry`     — jittered-exponential-backoff bounded retry
                      (``training.fault_tolerance.retrying`` re-exports
                      this).
* :mod:`.lifecycle` — ``RequestStatus`` / ``RequestResult``: every
                      request terminates with a definite status.
* :mod:`.fallback`  — the single ``resolve_fallback`` decision point
                      plus process-wide downgrade counters.
"""

from repro.resilience.faults import (  # noqa: F401
    KNOWN_SITES,
    FaultError,
    FaultPlan,
    FaultSpec,
    clear,
    enabled,
    injected,
    install,
    trip,
)
from repro.resilience.fallback import (  # noqa: F401
    fallback_counters,
    record_fallback,
    reset_fallback_counters,
    resolve_fallback,
)
from repro.resilience.lifecycle import RequestResult, RequestStatus  # noqa: F401
from repro.resilience.retry import backoff_schedule, retrying  # noqa: F401

__all__ = [
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "KNOWN_SITES",
    "trip",
    "install",
    "clear",
    "injected",
    "enabled",
    "retrying",
    "backoff_schedule",
    "RequestStatus",
    "RequestResult",
    "resolve_fallback",
    "record_fallback",
    "fallback_counters",
    "reset_fallback_counters",
]
