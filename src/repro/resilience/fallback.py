"""Graceful degradation: the single fallback decision point — jax-free.

Mirrors the PR 5 rule that every layout/gather choice flows through one
``resolve_*`` function: **every runtime downgrade flows through**
:func:`resolve_fallback`, is applied by a sanctioned containment site
(lint GUST-L03/L07 allowlists), and is **counted** — surfaced on
``GustPlan.cost()`` (``fallback_*`` fields) and ``ServeLoop`` stats.
Degradation is never silent and never an exception on the serving path.

The degradation order (ROADMAP §Resilience invariants):

* ``kernel``:  pallas → jnp       (tolerance-level equal: the XLA oracle
                                   computes the same math, different op
                                   order — NOT gated bitwise)
* ``gather``:  local → resident   (bitwise equal, PR 5 invariant)
* ``store``:   stored → fresh     (bitwise equal, PR 7 warm==cold gate)

Each chain is one step deep by design — the floor of every chain is the
always-available reference path, so a second failure is a real bug and
*should* propagate to the serve-step containment layer.
"""

from __future__ import annotations

from typing import Dict, Optional

__all__ = [
    "resolve_fallback",
    "record_fallback",
    "fallback_counters",
    "reset_fallback_counters",
]

#: stage -> (degraded-from, degraded-to).  The *only* legal downgrades.
_CHAIN = {
    "kernel": ("pallas", "jnp"),
    "gather": ("local", "resident"),
    "store": ("stored", "fresh"),
}

#: Process-wide downgrade counts, keyed "<from>_to_<to>".  Snapshot /
#: delta these around a region to attribute downgrades to it.
fallback_counters: Dict[str, int] = {
    "pallas_to_jnp": 0,
    "local_to_resident": 0,
    "stored_to_fresh": 0,
}


def resolve_fallback(stage: str, current: str) -> Optional[str]:
    """The one decision point: what does ``current`` degrade to at
    ``stage``?  Returns the downgraded choice, or ``None`` when
    ``current`` is already the floor of its chain (caller must let the
    error propagate to the next containment layer)."""
    chain = _CHAIN.get(stage)
    if chain is None:
        raise ValueError(f"unknown fallback stage {stage!r}; have {sorted(_CHAIN)}")
    src, dst = chain
    return dst if current == src else None


def record_fallback(stage: str) -> str:
    """Count one applied downgrade at ``stage``; returns the counter key
    so call sites can mirror it into their own stats."""
    src, dst = _CHAIN[stage]
    key = f"{src}_to_{dst}"
    fallback_counters[key] += 1
    return key


def reset_fallback_counters() -> Dict[str, int]:
    """Zero the process-wide counters; returns the pre-reset snapshot."""
    snap = dict(fallback_counters)
    for k in fallback_counters:
        fallback_counters[k] = 0
    return snap
