"""Deterministic fault injection — seedable, replayable, zero-cost off.

The serving stack's correctness argument (ROADMAP §Resilience
invariants) is only as strong as the faults it has actually survived.
This module makes fault-time behavior *testable* the same way the
packed-format invariants made schedule-time behavior testable: a
:class:`FaultPlan` maps **named injection sites** (a stable public
contract, listed below) to error/delay/corruption specs, and every
hardened call path calls :func:`trip` at its site.

Design rules:

* **Off by default, zero overhead when disabled.**  No plan installed
  means :func:`trip` is one module-global ``None`` check — no
  allocation, no dict lookup, no string formatting.  A ``FaultPlan`` is
  an execution knob in the PR 7 sense: it never enters a
  ``ScheduleCache``/``PlanStore`` key (it is not part of
  ``PlanConfig`` at all), so injected runs and clean runs share
  artifacts.
* **Deterministic by seed.**  Each spec draws its probabilistic
  triggers from its own ``numpy`` Generator seeded by
  ``sha1(seed | site | spec index)`` — the k-th hit at a site sees the
  same draw regardless of how other sites interleave, in-process and
  across processes.  ``FaultPlan.fired`` records the exact fault
  sequence so every chaos run is replayable and comparable.
* **Sites are a contract.**  Renaming a site silently un-arms every
  chaos test that targets it; the known sites are enumerated in
  :data:`KNOWN_SITES` and new hardened paths must extend it.

Named sites (``tag`` refines the match; ``None`` matches any)::

    store.get          PlanStore.get file read        (tag: store key)
    store.get.corrupt  PlanStore.get post-read        (kind="corrupt")
    store.put          PlanStore.put container write  (tag: store key)
    store.put.crash    PlanStore.put pre-fsync crash  (tag: store key)
    pack.materialize   GustPlan.artifact lazy pack
    kernel.execute     execute_spmm dispatch          (tag: backend)
    gather.local       execute_spmm local-gather path
    serve.admit        ServeLoop._admit               (tag: request id)
    serve.decode       ServeLoop.step batched decode
    serve.slot         ServeLoop.step per-slot retire (tag: request id)

Usage::

    plan = FaultPlan([FaultSpec("serve.decode", times=2)], seed=7)
    with injected(plan):
        loop.run_to_completion()
    assert plan.fired  # the replayable fault sequence
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FaultError",
    "FaultSpec",
    "FaultPlan",
    "KNOWN_SITES",
    "trip",
    "install",
    "clear",
    "injected",
    "enabled",
]

#: The stable injection-site names (ROADMAP §Resilience invariants).
KNOWN_SITES = (
    "store.get",
    "store.get.corrupt",
    "store.put",
    "store.put.crash",
    "pack.materialize",
    "kernel.execute",
    "gather.local",
    "serve.admit",
    "serve.decode",
    "serve.slot",
)

_KINDS = ("error", "delay", "corrupt")


class FaultError(RuntimeError):
    """Default exception an ``error`` spec raises at its site."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault: where, what, how often.

    Attributes:
      site:    injection-site name (see :data:`KNOWN_SITES`).
      kind:    ``error`` (raise), ``delay`` (sleep ``delay_s``), or
               ``corrupt`` (returned to the call site, which applies a
               deterministic corruption — only sites documented as
               ``kind="corrupt"`` honor it).
      times:   trigger at most this many times (``-1`` = every hit).
      after:   skip the first ``after`` eligible hits (arm late).
      rate:    per-hit trigger probability; draws come from the spec's
               own seeded stream, so partial-rate schedules replay
               exactly.
      delay_s: sleep length for ``kind="delay"``.
      error:   exception *type* for ``kind="error"`` (default
               :class:`FaultError`) — e.g. ``OSError`` to exercise an
               I/O retry path.
      tag:     only trip calls carrying this tag (``None`` = any); call
               sites tag with the request id / backend / store key.
    """

    site: str
    kind: str = "error"
    times: int = 1
    after: int = 0
    rate: float = 1.0
    delay_s: float = 0.0
    error: type = FaultError
    tag: Optional[str] = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {self.kind!r}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")


def _spec_seed(seed: int, site: str, index: int) -> int:
    """Process-stable per-spec stream seed (``hash()`` is salted; sha1
    is not)."""
    h = hashlib.sha1(f"gust-fault|{seed}|{site}|{index}".encode()).digest()
    return int.from_bytes(h[:8], "little")


@dataclasses.dataclass
class _SpecState:
    spec: FaultSpec
    rng: np.random.Generator
    hits: int = 0
    trips: int = 0


class FaultPlan:
    """A seeded schedule of faults over the named injection sites.

    ``fired`` is the replayable record: a list of
    ``(sequence, site, tag, kind)`` tuples in trigger order — two runs
    of the same workload under the same plan seed produce the same
    record *and* (by the containment contracts) the same surviving
    outputs.  ``reset()`` rearms the plan for an identical replay.
    """

    def __init__(self, specs: Sequence[FaultSpec], seed: int = 0):
        self.seed = int(seed)
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        for s in self.specs:
            if not isinstance(s, FaultSpec):
                raise TypeError(f"FaultPlan takes FaultSpecs, got {type(s).__name__}")
        self._by_site: Dict[str, List[_SpecState]] = {}
        self.fired: List[Tuple[int, str, Optional[str], str]] = []
        self.reset()

    def reset(self) -> "FaultPlan":
        """Rearm every spec and clear the fired record (exact replay)."""
        self._by_site = {}
        for i, spec in enumerate(self.specs):
            self._by_site.setdefault(spec.site, []).append(
                _SpecState(
                    spec,
                    np.random.default_rng(_spec_seed(self.seed, spec.site, i)),
                )
            )
        self.fired = []
        return self

    # -- the hot path --------------------------------------------------------

    def _trip(self, site: str, tag: Optional[str]) -> Optional[FaultSpec]:
        states = self._by_site.get(site)
        if not states:
            return None
        corrupt: Optional[FaultSpec] = None
        for st in states:
            spec = st.spec
            if spec.tag is not None and spec.tag != tag:
                continue
            st.hits += 1
            if st.hits <= spec.after:
                continue
            if 0 <= spec.times <= st.trips:
                continue
            if spec.rate < 1.0 and st.rng.random() >= spec.rate:
                continue
            st.trips += 1
            self.fired.append((len(self.fired), site, tag, spec.kind))
            if spec.kind == "delay":
                time.sleep(spec.delay_s)
            elif spec.kind == "error":
                raise spec.error(
                    f"injected fault at {site!r}"
                    + (f" (tag={tag!r})" if tag is not None else "")
                )
            elif corrupt is None:
                corrupt = spec
        return corrupt

    # -- introspection -------------------------------------------------------

    def counts(self) -> Dict[str, int]:
        """Trips per site (the chaos-report summary)."""
        out: Dict[str, int] = {}
        for site, states in self._by_site.items():
            n = sum(st.trips for st in states)
            if n:
                out[site] = n
        return out

    def fingerprint(self) -> Tuple[Tuple[int, str, Optional[str], str], ...]:
        """Hashable form of ``fired`` for determinism assertions."""
        return tuple(self.fired)

    def __repr__(self) -> str:
        return (
            f"FaultPlan(seed={self.seed}, specs={len(self.specs)}, "
            f"fired={len(self.fired)})"
        )


# ---------------------------------------------------------------------------
# The ambient active plan.  Injection sites must be reachable from deep
# call stacks (jitted trace bodies, store internals) without threading a
# plan object through every hot-path signature — and the disabled check
# must cost one global read.
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultPlan] = None


def trip(site: str, tag: Optional[str] = None) -> Optional[FaultSpec]:
    """Injection-site hook.  With no plan installed this is a single
    ``None`` check (the zero-overhead contract); with one installed it
    may raise, sleep, or return a ``corrupt`` spec for the caller to
    apply."""
    if _ACTIVE is None:
        return None
    return _ACTIVE._trip(site, tag)


def enabled() -> bool:
    """True when a FaultPlan is installed (callers may skip building
    tags — the only per-call work trip() can't skip itself)."""
    return _ACTIVE is not None


def install(plan: Optional[FaultPlan]) -> Optional[FaultPlan]:
    """Install ``plan`` as the ambient fault plan (None disarms)."""
    global _ACTIVE
    _ACTIVE = plan
    return plan


def clear() -> None:
    """Disarm fault injection (equivalent to ``install(None)``)."""
    install(None)


@contextlib.contextmanager
def injected(plan: FaultPlan):
    """Scope a fault plan: ``with injected(plan): ...`` — always
    disarms on exit, so a crashed chaos test can't poison the suite."""
    prev = _ACTIVE
    install(plan)
    try:
        yield plan
    finally:
        install(prev)
