"""Bounded retry with jittered exponential backoff — jax-free.

Generalizes ``training.fault_tolerance.retrying`` (which re-exports this)
so the store and serving paths can share one retry policy without
importing the training stack.  Additions over the training original:

* **Jittered exponential backoff** — attempt *k* sleeps
  ``min(max_delay, base_delay * 2**k) * (1 + jitter * u)`` with ``u``
  drawn from a seeded stream, so a fleet of retriers doesn't
  thundering-herd a recovering store, and tests replay exact schedules.
* **Max-elapsed budget** — retrying stops early when the *next* sleep
  would push total elapsed time past ``max_elapsed`` seconds; a serving
  path must degrade (ROADMAP §Resilience invariants), not block.

Defaults keep the training semantics exactly: ``base_delay=0`` means no
sleeping and ``max_retries + 1`` total attempts, with the same terminal
``RuntimeError`` message.
"""

from __future__ import annotations

import time
from typing import Callable, Optional, Tuple, Type

import numpy as np

__all__ = ["retrying", "backoff_schedule"]


def backoff_schedule(
    attempts: int,
    *,
    base_delay: float = 0.0,
    max_delay: float = 30.0,
    jitter: float = 0.5,
    seed: Optional[int] = None,
) -> Tuple[float, ...]:
    """The sleep (seconds) before each retry, as ``retrying`` would draw
    it.  Exposed so tests can assert the exact jittered schedule."""
    rng = np.random.default_rng(seed)
    out = []
    for attempt in range(attempts):
        delay = min(max_delay, base_delay * (2.0 ** attempt))
        if jitter > 0:
            delay *= 1.0 + jitter * float(rng.random())
        out.append(delay)
    return tuple(out)


def retrying(
    fn: Callable,
    *,
    max_retries: int = 3,
    retry_on: Tuple[Type[BaseException], ...] = (RuntimeError,),
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
    base_delay: float = 0.0,
    max_delay: float = 30.0,
    jitter: float = 0.5,
    max_elapsed: Optional[float] = None,
    seed: Optional[int] = None,
    sleep: Callable[[float], None] = time.sleep,
):
    """Wrap ``fn`` with bounded, optionally backed-off retry.

    The caller re-supplies the last known-good state on each attempt, so
    a retry is semantically a restart-from-checkpoint (training) or a
    re-read (store).  ``sleep`` is injectable so tests assert schedules
    without wall-clock cost.
    """

    def wrapped(*args, **kwargs):
        rng = np.random.default_rng(seed)
        t0 = time.monotonic()
        err: Optional[BaseException] = None
        for attempt in range(max_retries + 1):
            try:
                return fn(*args, **kwargs)
            except retry_on as e:  # transient: retry from caller's state
                err = e
                if on_retry:
                    on_retry(attempt, e)
                if attempt >= max_retries:
                    break
                delay = min(max_delay, base_delay * (2.0 ** attempt))
                if jitter > 0 and delay > 0:
                    delay *= 1.0 + jitter * float(rng.random())
                if max_elapsed is not None:
                    elapsed = time.monotonic() - t0
                    if elapsed + delay > max_elapsed:
                        raise RuntimeError(
                            f"step failed after {attempt + 1} attempts "
                            f"({elapsed:.3f}s elapsed, budget "
                            f"{max_elapsed}s): {err!r}"
                        ) from err
                if delay > 0:
                    sleep(delay)
        raise RuntimeError(
            f"step failed after {max_retries} retries: {err!r}"
        ) from err

    return wrapped
