"""Core neural layers (functional, pytree params, pure jnp).

Everything is written as ``init_*(key, ...) -> params`` plus a pure apply
function, so models compose into plain pytrees that pjit/GSPMD shards via
the rules in :mod:`repro.distributed.sharding`.  No framework dependency.

Conventions:
  * activations are (B, S, d) (batch, sequence, features);
  * params are f32 by default; the train loop may cast to bf16 compute via
    the ``dtype`` threading in :mod:`repro.training.train_loop`;
  * matmuls accumulate in f32 (``preferred_element_type``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "init_linear",
    "linear",
    "init_norm",
    "rms_norm",
    "layer_norm",
    "init_embedding",
    "embed",
    "unembed",
    "rope",
    "init_mlp",
    "mlp",
]


def _he(key, shape, scale_axis=0, dtype=jnp.float32):
    fan_in = shape[scale_axis]
    return jax.random.normal(key, shape, dtype) * (1.0 / jnp.sqrt(fan_in))


# ---------------------------------------------------------------------------
# Linear / norm / embedding
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False):
    p = {"w": _he(key, (d_in, d_out))}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(p, x: jnp.ndarray) -> jnp.ndarray:
    y = jnp.einsum("...i,io->...o", x, p["w"], preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"]
    return y.astype(x.dtype)


def init_norm(d: int, *, kind: str = "rms"):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layer":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def rms_norm(p, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


def layer_norm(p, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def apply_norm(p, x, *, kind: str = "rms"):
    return rms_norm(p, x) if kind == "rms" else layer_norm(p, x)


def init_embedding(key, vocab: int, d: int):
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(p, tokens: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.take(p["table"], tokens, axis=0).astype(dtype)


def unembed(p, x: jnp.ndarray) -> jnp.ndarray:
    """Tied logits projection: (B, S, d) @ table^T -> (B, S, V), f32."""
    return jnp.einsum(
        "...d,vd->...v", x, p["table"], preferred_element_type=jnp.float32
    )


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(
    x: jnp.ndarray, positions: jnp.ndarray, *, theta: float = 10_000.0
) -> jnp.ndarray:
    """Rotary embedding.  x: (..., S, H, D), positions: broadcastable (S,)
    or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freq[None, :]  # (S, half)
        ang = ang[None, :, None, :]  # (1, S, 1, half)
    else:
        ang = positions.astype(jnp.float32)[..., None] * freq  # (B, S, half)
        ang = ang[:, :, None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain GELU)
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, *, kind: str = "swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": _he(k1, (d, d_ff)),
            "w_up": _he(k2, (d, d_ff)),
            "w_down": _he(k3, (d_ff, d)),
        }
    return {"w_up": _he(k1, (d, d_ff)), "w_down": _he(k2, (d_ff, d))}


def mlp(p, x: jnp.ndarray, *, kind: str = "swiglu") -> jnp.ndarray:
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        g = act(
            jnp.einsum("...d,df->...f", x, p["w_gate"], preferred_element_type=jnp.float32)
        )
        u = jnp.einsum("...d,df->...f", x, p["w_up"], preferred_element_type=jnp.float32)
        h = (g * u).astype(x.dtype)
    else:
        h = jax.nn.gelu(
            jnp.einsum("...d,df->...f", x, p["w_up"], preferred_element_type=jnp.float32)
        ).astype(x.dtype)
    # row-parallel (f sharded over "model"): reduce partial sums on the
    # wire in the activation dtype (Megatron-style bf16 TP all-reduce; the
    # MXU still accumulates f32 within a chip)
    return jnp.einsum("...f,fd->...d", h, p["w_down"],
                      preferred_element_type=x.dtype)
