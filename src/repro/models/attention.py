"""Attention: GQA with global / sliding-window / chunked-local masking.

Layout is TP-first: query/output heads live on a single flat ``H`` axis
(shardable over the "model" mesh axis whenever ``H % tp == 0``), and the
``KV`` heads are broadcast to ``H`` at compute time (``repeat``), so no
einsum ever reshapes a sharded dimension — the MaxText-style GQA
formulation.  KV caches store only the ``KV`` heads.

Three execution regimes, one parameter set:

  * ``attend_train``  — full-sequence causal attention.  For long
    sequences a blocked online-softmax formulation (lax.scan over KV
    blocks) keeps peak memory at O(S·T) instead of O(S²) — the pure-JAX
    equivalent of flash attention, which XLA maps onto MXU-friendly
    block matmuls.
  * ``prefill*``      — train-shaped pass that also materializes the KV
    cache (dense, or ring-buffer for bounded-window layers).
  * ``decode_step``   — one new token against the cache; positions are
    tracked explicitly so ring buffers mask correctly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_attn, constrain_kv_cache

from .layers import _he, rope

__all__ = [
    "AttnSpec",
    "init_attention",
    "attend_train",
    "init_cache",
    "insert_slot",
    "prefill_into_cache",
    "decode_step",
    "cross_kv",
    "attend_cross",
]


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    mode: str = "global"  # global | local | chunked
    window: int = 0  # window size (local) or chunk size (chunked)
    rope_theta: float = 10_000.0
    use_rope: bool = True
    causal: bool = True  # False for encoder self-attention
    block_size: int = 1024  # KV block for the online-softmax path
    max_cache: int = 0  # decode-cache capacity for global layers (0 = seq)

    @property
    def groups(self) -> int:
        return self.n_heads // self.n_kv


def init_attention(key, spec: AttnSpec):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hq, hk, dh = spec.d_model, spec.n_heads, spec.n_kv, spec.d_head
    return {
        "wq": _he(kq, (d, hq, dh)),
        "wk": _he(kk, (d, hk, dh)),
        "wv": _he(kv, (d, hk, dh)),
        "wo": _he(ko, (hq, dh, d), scale_axis=1),
    }


def _expand_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, KV, dh) -> (B, S, H, dh); head h reads kv head h // groups."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def _qkv(p, x, spec: AttnSpec, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"], preferred_element_type=jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"], preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"], preferred_element_type=jnp.float32)
    q, k, v = q.astype(x.dtype), k.astype(x.dtype), v.astype(x.dtype)
    if spec.use_rope:
        q = rope(q, positions, theta=spec.rope_theta)
        k = rope(k, positions, theta=spec.rope_theta)
    return q, k, v


def _mask(spec: AttnSpec, qpos, kpos):
    """Boolean (Sq, Sk) mask from query/key positions (int32)."""
    valid = kpos[None, :] >= 0
    if spec.causal:
        m = kpos[None, :] <= qpos[:, None]
    else:
        m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if spec.mode == "local" and spec.window:
        m &= kpos[None, :] > qpos[:, None] - spec.window
    elif spec.mode == "chunked" and spec.window:
        m &= (kpos[None, :] // spec.window) == (qpos[:, None] // spec.window)
    return m & valid


def _sdpa(q, k_full, v_full, mask, d_head):
    """Direct path. q: (B,Sq,H,dh), k_full/v_full: (B,Sk,H,dh)."""
    scale = 1.0 / jnp.sqrt(d_head).astype(jnp.float32)
    s = jnp.einsum("bqhk,bshk->bhqs", q, k_full, preferred_element_type=jnp.float32)
    s = jnp.where(mask[None, None], s * scale, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqs,bshk->bqhk", p.astype(v_full.dtype), v_full)


def _blocked_sdpa(q, k_full, v_full, spec: AttnSpec, qpos, kpos):
    """Online-softmax over KV blocks; O(S·T) live memory."""
    b, sq, h, dh = q.shape
    sk = k_full.shape[1]
    t = min(spec.block_size, sk)
    nb = -(-sk // t)
    pad = nb * t - sk
    if pad:
        k_full = jnp.pad(k_full, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_full = jnp.pad(v_full, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=-1)
    kb = k_full.reshape(b, nb, t, h, dh).transpose(1, 0, 2, 3, 4)
    vb = v_full.reshape(b, nb, t, h, dh).transpose(1, 0, 2, 3, 4)
    pb = kpos.reshape(nb, t)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    def step(carry, blk):
        m_run, l_run, acc = carry
        kj, vj, pj = blk
        s = (
            jnp.einsum("bqhk,bthk->bhqt", q, kj, preferred_element_type=jnp.float32)
            * scale
        )
        msk = _mask(spec, qpos, pj)  # (Sq, T)
        s = jnp.where(msk[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.exp(jnp.where(jnp.isfinite(m_run), m_run - m_safe, -jnp.inf))
        corr = jnp.where(jnp.isfinite(corr), corr, 0.0)
        l_new = l_run * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqt,bthk->bhqk", p, vj.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    q = constrain_attn(q, 2, 1)  # (B, Sq, H, dh): TP on heads or SP on Sq
    m0 = constrain_attn(jnp.full((b, h, sq), -jnp.inf, jnp.float32), 1, 2)
    l0 = constrain_attn(jnp.zeros((b, h, sq), jnp.float32), 1, 2)
    a0 = constrain_attn(jnp.zeros((b, h, sq, dh), jnp.float32), 1, 2)
    (m_f, l_f, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    o = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return o.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Sq,H,dh)


def _attend(p, q, k, v, spec: AttnSpec, qpos, kpos, x_dtype):
    kf = _expand_kv(k, spec.groups)
    vf = _expand_kv(v, spec.groups)
    sq, sk = q.shape[1], kf.shape[1]
    if max(sq, sk) <= 2 * spec.block_size:
        o = _sdpa(q, kf, vf, _mask(spec, qpos, kpos), spec.d_head)
    else:
        o = _blocked_sdpa(q, kf, vf, spec, qpos, kpos)
    # row-parallel over heads: bf16 wire reduction (see layers.mlp)
    return jnp.einsum(
        "bqhk,hkd->bqd", o, p["wo"], preferred_element_type=x_dtype
    ).astype(x_dtype)


def attend_train(p, x, spec: AttnSpec, positions=None) -> jnp.ndarray:
    """Full-sequence attention (training / encoder / prefill compute)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    q, k, v = _qkv(p, x, spec, positions)
    return _attend(p, q, k, v, spec, positions, positions, x.dtype)


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------


def cross_kv(p, memory, spec: AttnSpec):
    """Project the encoder memory once; reused by every decode step."""
    k = jnp.einsum(
        "bsd,dhk->bshk", memory, p["wk"], preferred_element_type=jnp.float32
    ).astype(memory.dtype)
    v = jnp.einsum(
        "bsd,dhk->bshk", memory, p["wv"], preferred_element_type=jnp.float32
    ).astype(memory.dtype)
    return k, v


def attend_cross(p, x, k, v, spec: AttnSpec) -> jnp.ndarray:
    """Full (non-causal, non-rotary) attention of x over precomputed
    memory K/V.  x: (B, Sq, d); k/v: (B, Sk, KV, dh).  Long memories go
    through the blocked online-softmax path like self-attention."""
    q = jnp.einsum(
        "bsd,dhk->bshk", x, p["wq"], preferred_element_type=jnp.float32
    ).astype(x.dtype)
    qpos = jnp.arange(q.shape[1], dtype=jnp.int32)
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    kf = _expand_kv(k.astype(q.dtype), spec.groups)
    vf = _expand_kv(v.astype(q.dtype), spec.groups)
    if max(q.shape[1], kf.shape[1]) <= 2 * spec.block_size:
        o = _sdpa(q, kf, vf, _mask(spec, qpos, kpos), spec.d_head)
    else:
        o = _blocked_sdpa(q, kf, vf, spec, qpos, kpos)
    return jnp.einsum(
        "bqhk,hkd->bqd", o, p["wo"], preferred_element_type=x.dtype
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# KV cache (dense or ring) + decode
# ---------------------------------------------------------------------------


def cache_len(spec: AttnSpec, seq_len: int) -> int:
    """Physical cache capacity for a layer at a given serving seq_len."""
    if spec.mode in ("local", "chunked") and spec.window:
        return min(spec.window, seq_len)
    if spec.max_cache:
        return min(spec.max_cache, seq_len)
    return seq_len


def init_cache(spec: AttnSpec, batch: int, seq_len: int, dtype=jnp.bfloat16):
    c = cache_len(spec, seq_len)
    shape = (batch, c, spec.n_kv, spec.d_head)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        # original position per cache slot, per sequence: (B, c) so batch
        # rows at different decode positions (continuous batching) mask
        # independently — every cache leaf is batch-leading
        "pos": jnp.full((batch, c), -1, jnp.int32),
    }


def insert_slot(cache, one, slot, axis: int = 0):
    """Slot-local cache insertion: write batch row 0 of the batch-1 cache
    pytree ``one`` into batch row ``slot`` of ``cache``, leaving every
    other row untouched.

    Every cache leaf — dense/ring KV (``k``/``v``/``pos``), cross-attn
    memory (``ck``/``cv``), and the recurrent states — is batch-leading
    (at ``axis``; rep-stacked leaves are ``(R, B, ...)`` so pass
    ``axis=1``), which makes admission in the serving loop a pure pytree
    row scatter: a new request's prefill can never clobber another active
    slot's cache.
    """

    def ins(full, single):
        src = jax.lax.index_in_dim(single, 0, axis=axis, keepdims=False)
        idx = (slice(None),) * axis + (slot,)
        return full.at[idx].set(src.astype(full.dtype))

    return jax.tree.map(ins, cache, one)


def prefill_into_cache(p, x, spec: AttnSpec, cache, start: int = 0):
    """Run attention over a prompt of length S and fill the cache with the
    final ``cache_len`` positions.  Returns (output, cache)."""
    b, s, _ = x.shape
    positions = start + jnp.arange(s, dtype=jnp.int32)
    q, k, v = _qkv(p, x, spec, positions)
    out = _attend(p, q, k, v, spec, positions, positions, x.dtype)

    c = cache["k"].shape[1]
    take = min(c, s)
    tail_pos = positions[s - take :]
    slots = tail_pos % c  # ring placement; identity when c >= S
    cache = {
        "k": cache["k"].at[:, slots].set(k[:, s - take :].astype(cache["k"].dtype)),
        "v": cache["v"].at[:, slots].set(v[:, s - take :].astype(cache["v"].dtype)),
        "pos": cache["pos"].at[:, slots].set(tail_pos),
    }
    return out, cache


def decode_step(p, x, spec: AttnSpec, cache, pos):
    """One token: x (B, 1, d); ``pos`` is a scalar or a (B,) vector of
    per-sequence positions (continuous batching serves mixed-length
    requests, so every batch row decodes at its own position).  Returns
    (y, cache)."""
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.full((b,), pos, jnp.int32)
    positions = pos[:, None]  # (B, 1): per-row rope + mask query positions
    q, k, v = _qkv(p, x, spec, positions)
    c = cache["k"].shape[1]
    slot = pos % c  # (B,) ring placement per sequence
    bidx = jnp.arange(b)
    kc = constrain_kv_cache(
        cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
    )
    vc = constrain_kv_cache(
        cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
    )
    pc = cache["pos"].at[bidx, slot].set(pos)

    # Flash-decode sharding: the cache is the big tensor, so the compute
    # follows ITS layout (sequence over "model").  GQA scores are taken in
    # (KV, G) form — the cache is never head-expanded (an _expand_kv here
    # makes GSPMD reshard/replicate the whole 88-layer stack per step);
    # only the one-token q is reshaped/resharded.  The softmax reduces
    # over the sharded cache length via psums of (B,KV,G,1)-sized partials.
    q5 = q.reshape(b, 1, spec.n_kv, spec.groups, spec.d_head)
    scale = 1.0 / jnp.sqrt(spec.d_head).astype(jnp.float32)
    s = (
        jnp.einsum(
            "bqegk,bsek->begqs", q5, kc.astype(q.dtype),
            preferred_element_type=jnp.float32,
        )
        * scale
    )  # (B, KV, G, 1, c)
    # per-row mask: row i attends under its own query position pos[i]
    # against its own cached key positions pc[i]
    msk = jax.vmap(lambda qp, kp: _mask(spec, qp, kp))(positions, pc)  # (B,1,c)
    s = jnp.where(msk[:, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    o = jnp.einsum(
        "begqs,bsek->bqegk", w.astype(q.dtype), vc.astype(q.dtype)
    ).reshape(b, 1, spec.n_heads, spec.d_head)
    y = jnp.einsum(
        "bqhk,hkd->bqd", o, p["wo"], preferred_element_type=x.dtype
    ).astype(x.dtype)
    return y, {"k": kc, "v": vc, "pos": pc}
