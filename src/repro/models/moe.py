"""Mixture-of-Experts FFN: token-choice top-k routing with capacity.

Dispatch is scatter-based (MegaBlocks-style grouping rather than the
GShard (T, E, C) one-hot einsum): each selected (token, expert) pair gets
a rank within its expert via a cumulative count; pairs past the capacity
are dropped (their combine weight contributes nothing, matching
capacity-bounded token-choice semantics).  The grouped activations
(E, C, d) then run through all experts as one batched einsum — the layout
that experts-sharded (EP) meshes want, since the E dimension is the
sharding axis and the scatter/gather become all-to-alls under GSPMD.

Used by llama4-scout (16e top-1) and dbrx (16e top-4).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_ep

from .layers import _he

__all__ = ["MoESpec", "init_moe", "moe_ffn"]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    min_capacity: int = 4
    router_z_coef: float = 1e-3
    token_chunk: int = 16_384  # dispatch chunk: bounds the (E, C, d)
    # grouped buffer at prefill scale (1M tokens would otherwise need a
    # 64 GiB scatter buffer); capacity is enforced per chunk


def init_moe(key, spec: MoESpec):
    kr, kg, ku, kd = jax.random.split(key, 4)
    e, d, f = spec.n_experts, spec.d_model, spec.d_ff
    return {
        "router": _he(kr, (d, e)),
        "w_gate": _he(kg, (e, d, f), scale_axis=1),
        "w_up": _he(ku, (e, d, f), scale_axis=1),
        "w_down": _he(kd, (e, f, d), scale_axis=1),
    }


def _capacity(tokens: int, spec: MoESpec) -> int:
    c = int(tokens * spec.top_k * spec.capacity_factor / spec.n_experts)
    return max(c, spec.min_capacity)


def moe_ffn(p, x: jnp.ndarray, spec: MoESpec) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(B, S, d) -> ((B, S, d), aux_loss).  aux = load-balance + z-loss.

    Token streams longer than ``token_chunk`` are dispatched chunk by
    chunk (lax.scan): per-chunk capacity keeps the grouped (E, C, d)
    buffer bounded regardless of sequence length."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    if t > spec.token_chunk and t % spec.token_chunk == 0:
        nc = t // spec.token_chunk
        chunks = xf.reshape(nc, spec.token_chunk, d)

        def body(aux_acc, xc):
            yc, aux = _moe_tokens(p, xc, spec)
            return aux_acc + aux, yc

        aux_sum, ys = jax.lax.scan(body, 0.0, chunks)
        return ys.reshape(b, s, d).astype(x.dtype), aux_sum / nc
    out, aux = _moe_tokens(p, xf, spec)
    return out.reshape(b, s, d).astype(x.dtype), aux


def _moe_tokens(p, xf: jnp.ndarray, spec: MoESpec) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(T, d) -> ((T, d), aux)."""
    t, d = xf.shape
    e, k = spec.n_experts, spec.top_k
    cap = _capacity(t, spec)

    logits = jnp.einsum(
        "td,de->te", xf, p["router"], preferred_element_type=jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # rank of each (token, slot) within its expert, computed via a one-hot
    # cumulative sum over the flattened (token-major) selection order
    sel = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)  # (T, k, E)
    flat_sel = sel.reshape(t * k, e)
    rank = jnp.cumsum(flat_sel, axis=0) - flat_sel  # exclusive count
    rank = (rank * flat_sel).sum(-1).reshape(t, k)  # (T, k)
    keep = rank < cap

    dest = expert_idx * cap + rank  # (T, k) slot in (E*C)
    dest = jnp.where(keep, dest, e * cap)  # over-capacity -> dropped

    # Dispatch via the INVERSE index: scatter token ids (4 bytes/slot)
    # instead of token vectors (2d bytes/slot), then gather rows.  The
    # big (E, C, d) buffer is then produced by a gather whose output is
    # EP-sharded, so under GSPMD the d-sized data crosses the mesh once
    # ((T, d) all-gather) rather than as a full (E, C, d) scatter
    # all-reduce — ~2kd/4 ≈ 3000x less index traffic and ~C·E/T less
    # payload traffic (the dbrx train cell's collective term dropped 4x).
    token_of = jnp.repeat(jnp.arange(t), k).reshape(t, k)
    inv = jnp.full((e * cap,), t, jnp.int32)  # t = zero-row sentinel
    inv = inv.at[dest.reshape(-1)].set(
        token_of.reshape(-1).astype(jnp.int32), mode="drop"
    )
    w_slot = jnp.zeros((e * cap,), jnp.float32)
    w_slot = w_slot.at[dest.reshape(-1)].set(
        (gate_vals * keep).reshape(-1).astype(jnp.float32), mode="drop"
    )
    xf_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)])
    gx = constrain_ep(jnp.take(xf_pad, inv, axis=0).reshape(e, cap, d))

    # expert FFN (SwiGLU), batched over experts
    g = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", gx, p["w_gate"], preferred_element_type=jnp.float32)
    )
    u = jnp.einsum("ecd,edf->ecf", gx, p["w_up"], preferred_element_type=jnp.float32)
    y = jnp.einsum(
        "ecf,efd->ecd", (g * u).astype(xf.dtype), p["w_down"],
        preferred_element_type=xf.dtype,
    ).astype(xf.dtype)
    y = constrain_ep(y)

    # combine: weight in place, scatter-add back by token id (drops land
    # on the sentinel row and are sliced off)
    y_w = y.reshape(e * cap, d).astype(jnp.float32) * w_slot[:, None]
    out = jnp.zeros((t + 1, d), jnp.float32).at[inv].add(y_w)[:t]
    out = out.astype(xf.dtype)

    # aux losses: Switch-style load balance + router z-loss
    me = probs.mean(axis=0)  # (E,)
    ce = (sel.sum(1) > 0).astype(jnp.float32).mean(axis=0)  # fraction routed
    lb = e * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = lb + spec.router_z_coef * z
    return out, aux
