"""Recurrent sequence mixers: xLSTM (mLSTM + sLSTM) and RG-LRU (Griffin).

All three expose the same triple of regimes as attention:

  * ``*_train``   — full-sequence parallel/chunkwise form;
  * ``*_prefill`` — train-shaped pass that also returns the recurrent
    state after the last position (the "cache" of recurrent models);
  * ``*_decode``  — one-token state update, O(1) in sequence length (this
    is why these architectures run the ``long_500k`` shape).

Serving contract: every state leaf is **batch-leading** (``(B, ...)``),
so the serving loop's slot-local admission (``attention.insert_slot``,
re-exported here for states) can write one request's freshly-prefilled
state into its batch row without touching any other in-flight slot.
Decode updates are row-independent, so mixed-length continuous batching
is bit-identical per request to a solo run.

mLSTM (arXiv:2405.04517): matrix memory ``C_t = f_t C_{t-1} + i_t v_t
k_t^T`` with exponential gating, evaluated **chunkwise-parallel**: within a
chunk the quadratic stabilized-gate form (MXU matmuls), across chunks an
O(1) state carry — the linear-attention equivalent of flash attention.

sLSTM: scalar memory with recurrent gate connections (block-diagonal R per
head), inherently sequential — lax.scan over time.

RG-LRU (arXiv:2402.19427): gated linear recurrence with input-dependent
decay ``a_t = exp(c · softplus(Λ) · r_t)``; evaluated with an associative
scan in train/prefill and a one-step update at decode.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .attention import insert_slot
from .layers import _he

__all__ = [
    "insert_slot",
    "MLSTMSpec",
    "init_mlstm",
    "mlstm_train",
    "mlstm_init_state",
    "mlstm_decode",
    "SLSTMSpec",
    "init_slstm",
    "slstm_train",
    "slstm_init_state",
    "slstm_decode",
    "RGLRUSpec",
    "init_rglru",
    "rglru_train",
    "rglru_init_state",
    "rglru_decode",
]


# ===========================================================================
# mLSTM
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class MLSTMSpec:
    d_model: int
    n_heads: int
    expand: int = 2  # up-projection factor
    conv_width: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def d_head(self) -> int:
        return self.d_inner // self.n_heads


def init_mlstm(key, spec: MLSTMSpec):
    ks = jax.random.split(key, 8)
    d, di, h = spec.d_model, spec.d_inner, spec.n_heads
    return {
        "w_up": _he(ks[0], (d, di)),
        "w_ogate": _he(ks[1], (d, di)),
        "conv": jax.random.normal(ks[2], (spec.conv_width, di), jnp.float32) * 0.1,
        "wq": _he(ks[3], (di, di)),
        "wk": _he(ks[4], (di, di)),
        "wv": _he(ks[5], (di, di)),
        "w_if": _he(ks[6], (di, 2 * h)),  # input & forget gate pre-acts
        "w_down": _he(ks[7], (di, d)),
        "skip_scale": jnp.ones((di,), jnp.float32),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x: (B, S, D), w: (W, D).  state: (B, W-1, D)
    carries the trailing inputs for decode continuity."""
    width = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i] for i in range(width)
    )
    new_state = xp[:, xp.shape[1] - (width - 1) :]
    return out.astype(x.dtype), new_state


def _mlstm_qkvif(p, x, spec: MLSTMSpec, conv_state=None):
    b, s, _ = x.shape
    h, dh = spec.n_heads, spec.d_head
    up = jnp.einsum("bsd,de->bse", x, p["w_up"], preferred_element_type=jnp.float32)
    up = up.astype(x.dtype)
    conv_out, conv_state = _causal_conv(up, p["conv"], conv_state)
    conv_act = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    q = jnp.einsum("bse,ef->bsf", conv_act, p["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bse,ef->bsf", conv_act, p["wk"]).reshape(b, s, h, dh)
    v = jnp.einsum("bse,ef->bsf", up, p["wv"]).reshape(b, s, h, dh)
    gates = jnp.einsum(
        "bse,eg->bsg", conv_act, p["w_if"], preferred_element_type=jnp.float32
    )
    i_pre, f_pre = gates[..., :h], gates[..., h:]
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    ogate = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", x, p["w_ogate"], preferred_element_type=jnp.float32)
    )
    skip = conv_act * p["skip_scale"]
    return q, k, v, i_pre.astype(jnp.float32), logf, ogate, up, skip, conv_state


def _mlstm_chunk_scan(q, k, v, i_pre, logf, state):
    """Chunkwise-parallel stabilized mLSTM core.

    q/k/v: (B, NC, T, H, D); i_pre/logf: (B, NC, T, H).
    state: (C (B,H,D,D), n (B,H,D), m (B,H)).
    Returns h (B, NC, T, H, D) and the final state.
    """
    b, nc, t, h, d = q.shape
    scale = 1.0 / jnp.sqrt(d)

    def step(carry, xs):
        C, n, m = carry
        qc, kc, vc, ic, lfc = xs  # (B,T,H,D) / (B,T,H)
        F = jnp.cumsum(lfc, axis=1)  # inclusive prefix logf, (B,T,H)
        # intra-chunk decay matrix: D[t,s] = F_t - F_s + i_s for s <= t
        Dm = F[:, :, None] - F[:, None, :] + ic[:, None, :, :]  # (B,T,S,H)
        causal = jnp.tril(jnp.ones((t, t), bool))
        Dm = jnp.where(causal[None, :, :, None], Dm, -jnp.inf)
        # inter-chunk decay for queries: m_prev + F_t
        inter = m[:, None] + F  # (B,T,H)
        m_new_q = jnp.maximum(inter, Dm.max(axis=2))  # (B,T,H)
        m_q = jnp.where(jnp.isfinite(m_new_q), m_new_q, 0.0)

        w_intra = jnp.exp(Dm - m_q[:, :, None, :])  # (B,T,S,H)
        w_inter = jnp.exp(inter - m_q)  # (B,T,H)

        s_qk = (
            jnp.einsum("bthd,bshd->btsh", qc, kc, preferred_element_type=jnp.float32)
            * scale
        )
        intra_num = jnp.einsum("btsh,bshd->bthd", s_qk * w_intra, vc.astype(jnp.float32))
        inter_num = (
            jnp.einsum("bthd,bhde->bthe", qc.astype(jnp.float32), C) * scale
        ) * w_inter[..., None]
        num = intra_num + inter_num

        intra_den = jnp.einsum("btsh,bsh->bth", s_qk * w_intra, jnp.ones((b, t, h)))
        # normalizer: n-vector dotted with q
        inter_den = (
            jnp.einsum("bthd,bhd->bth", qc.astype(jnp.float32), n) * scale
        ) * w_inter
        den = jnp.maximum(jnp.abs(intra_den + inter_den), jnp.exp(-m_q))
        hc = (num / den[..., None]).astype(qc.dtype)

        # state update to end of chunk
        F_T = F[:, -1]  # (B,H)
        decay_k = F_T[:, None] - F + ic  # (B,T,H): F_T - F_s + i_s
        m_next = jnp.maximum(m + F_T, decay_k.max(axis=1))
        w_k = jnp.exp(decay_k - m_next[:, None])  # (B,T,H)
        C_new = jnp.exp(m + F_T - m_next)[:, :, None, None] * C + jnp.einsum(
            "bthd,bthe->bhde", (kc.astype(jnp.float32) * w_k[..., None]), vc.astype(jnp.float32)
        )
        n_new = jnp.exp(m + F_T - m_next)[:, :, None] * n + jnp.einsum(
            "bthd,bth->bhd", kc.astype(jnp.float32), w_k
        )
        return (C_new, n_new, m_next), hc

    xs = tuple(
        a.transpose(1, 0, 2, 3, 4) if a.ndim == 5 else a.transpose(1, 0, 2, 3)
        for a in (q, k, v, i_pre, logf)
    )
    state, hs = jax.lax.scan(step, state, xs)
    return hs.transpose(1, 0, 2, 3, 4), state  # (B,NC,T,H,D)


def mlstm_init_state(spec: MLSTMSpec, batch: int, dtype=jnp.float32):
    h, dh = spec.n_heads, spec.d_head
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
        "conv": jnp.zeros((batch, spec.conv_width - 1, spec.d_inner), dtype),
    }


def mlstm_train(p, x, spec: MLSTMSpec, state=None, return_state: bool = False):
    """(B, S, d) -> (B, S, d); S padded internally to the chunk size."""
    b, s, d = x.shape
    q, k, v, i_pre, logf, ogate, up, skip, conv_state = _mlstm_qkvif(
        p, x, spec, None if state is None else state["conv"]
    )
    t = min(spec.chunk, s)
    nc = -(-s // t)
    pad = nc * t - s

    def pad_t(a, fill=0.0):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2), constant_values=fill)

    if pad:
        q, k, v = pad_t(q), pad_t(k), pad_t(v)
        i_pre, logf = pad_t(i_pre, -1e9), pad_t(logf, 0.0)
    h, dh = spec.n_heads, spec.d_head
    shp = (b, nc, t, h, dh)
    core_state = (
        (state["C"], state["n"], state["m"])
        if state is not None
        else (
            jnp.zeros((b, h, dh, dh), jnp.float32),
            jnp.zeros((b, h, dh), jnp.float32),
            jnp.zeros((b, h), jnp.float32),
        )
    )
    hs, core_state = _mlstm_chunk_scan(
        q.reshape(shp), k.reshape(shp), v.reshape(shp),
        i_pre.reshape(b, nc, t, h), logf.reshape(b, nc, t, h), core_state,
    )
    hflat = hs.reshape(b, nc * t, h * dh)[:, :s]
    y = (ogate.astype(jnp.float32) * (hflat.astype(jnp.float32) + skip.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_down"], preferred_element_type=x.dtype).astype(x.dtype)
    if return_state:
        new_state = {
            "C": core_state[0], "n": core_state[1], "m": core_state[2],
            "conv": conv_state,
        }
        return out, new_state
    return out


def mlstm_decode(p, x, spec: MLSTMSpec, state):
    """One token. x: (B, 1, d)."""
    q, k, v, i_pre, logf, ogate, up, skip, conv_state = _mlstm_qkvif(
        p, x, spec, state["conv"]
    )
    b = x.shape[0]
    h, dh = spec.n_heads, spec.d_head
    q1, k1, v1 = (a[:, 0].astype(jnp.float32) for a in (q, k, v))  # (B,H,D)
    i1, f1 = i_pre[:, 0], logf[:, 0]  # (B,H)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(f1 + m, i1)
    fw = jnp.exp(f1 + m - m_new)[:, :, None, None]
    iw = jnp.exp(i1 - m_new)[:, :, None, None]
    C_new = fw * C + iw * jnp.einsum("bhd,bhe->bhde", k1, v1)
    n_new = fw[..., 0] * n + iw[..., 0] * k1
    scale = 1.0 / jnp.sqrt(dh)
    num = jnp.einsum("bhd,bhde->bhe", q1, C_new) * scale
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", q1, n_new) * scale), jnp.exp(-m_new)
    )
    hvec = (num / den[..., None]).reshape(b, 1, h * dh)
    y = (ogate.astype(jnp.float32) * (hvec + skip.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_down"], preferred_element_type=x.dtype).astype(x.dtype)
    return out, {"C": C_new, "n": n_new, "m": m_new, "conv": conv_state}


# ===========================================================================
# sLSTM
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class SLSTMSpec:
    d_model: int
    n_heads: int
    proj_factor: float = 4.0 / 3.0

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return int(self.d_model * self.proj_factor)


def init_slstm(key, spec: SLSTMSpec):
    ks = jax.random.split(key, 7)
    d, h, dh = spec.d_model, spec.n_heads, spec.d_head
    return {
        # input projections for gates z, i, f, o: (d, 4, d)
        "w_in": _he(ks[0], (d, 4, d)),
        # recurrent block-diagonal per head: (4, h, dh, dh)
        "r": jax.random.normal(ks[1], (4, h, dh, dh), jnp.float32) * (1.0 / jnp.sqrt(dh)),
        "bias": jnp.zeros((4, d), jnp.float32),
        "gn_scale": jnp.ones((d,), jnp.float32),
        "w_up_gate": _he(ks[2], (d, spec.d_ff)),
        "w_up": _he(ks[3], (d, spec.d_ff)),
        "w_down": _he(ks[4], (spec.d_ff, d)),
    }


def slstm_init_state(spec: SLSTMSpec, batch: int, dtype=jnp.float32):
    d = spec.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(p, xt, state, spec: SLSTMSpec):
    """One timestep.  xt: (B, 4, d) pre-activations from the input proj."""
    b = xt.shape[0]
    h_heads = state["h"].reshape(b, spec.n_heads, spec.d_head)
    rec = jnp.einsum("bhk,ghkl->bghl", h_heads.astype(jnp.float32), p["r"])
    rec = rec.reshape(b, 4, spec.d_model)
    pre = xt.astype(jnp.float32) + rec + p["bias"][None]
    z = jnp.tanh(pre[:, 0])
    i_pre, f_pre = pre[:, 1], pre[:, 2]
    o = jax.nn.sigmoid(pre[:, 3])
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    iw = jnp.exp(i_pre - m_new)
    fw = jnp.exp(logf + state["m"] - m_new)
    c_new = fw * state["c"] + iw * z
    n_new = jnp.maximum(fw * state["n"] + iw, jnp.exp(-m_new))
    h_new = o * (c_new / n_new)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new


def _slstm_core(p, x, spec: SLSTMSpec, state):
    b, s, d = x.shape
    xin = jnp.einsum("bsd,dgk->bsgk", x, p["w_in"], preferred_element_type=jnp.float32)

    def step(st, xt):
        st, h = _slstm_cell(p, xt, st, spec)
        return st, h

    state, hs = jax.lax.scan(step, state, xin.transpose(1, 0, 2, 3))
    return hs.transpose(1, 0, 2).astype(x.dtype), state


def _slstm_out(p, x, hs):
    # headwise group-norm then gated FFN projection
    hs32 = hs.astype(jnp.float32)
    mu = hs32.mean(-1, keepdims=True)
    var = hs32.var(-1, keepdims=True)
    hn = ((hs32 - mu) * jax.lax.rsqrt(var + 1e-6) * p["gn_scale"]).astype(x.dtype)
    g = jax.nn.gelu(
        jnp.einsum("bsd,df->bsf", hn, p["w_up_gate"], preferred_element_type=jnp.float32)
    )
    u = jnp.einsum("bsd,df->bsf", hn, p["w_up"], preferred_element_type=jnp.float32)
    return jnp.einsum(
        "bsf,fd->bsd", g * u, p["w_down"], preferred_element_type=jnp.float32
    ).astype(x.dtype)


def slstm_train(p, x, spec: SLSTMSpec, state=None, return_state: bool = False):
    b = x.shape[0]
    if state is None:
        state = slstm_init_state(spec, b)
    hs, state = _slstm_core(p, x, spec, state)
    out = _slstm_out(p, x, hs)
    return (out, state) if return_state else out


def slstm_decode(p, x, spec: SLSTMSpec, state):
    out, state = slstm_train(p, x, spec, state, return_state=True)
    return out, state


# ===========================================================================
# RG-LRU (Griffin recurrent block)
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class RGLRUSpec:
    d_model: int
    d_rnn: int = 0  # 0 -> d_model
    conv_width: int = 4
    c_const: float = 8.0

    @property
    def width(self) -> int:
        return self.d_rnn or self.d_model


def init_rglru(key, spec: RGLRUSpec):
    ks = jax.random.split(key, 6)
    d, w = spec.d_model, spec.width
    # Λ init so that a = exp(-c·softplus(Λ)·r) starts near 0.9..0.999
    lam = jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w)) / spec.c_const))
    return {
        "w_x": _he(ks[0], (d, w)),
        "w_gate_branch": _he(ks[1], (d, w)),
        "conv": jax.random.normal(ks[2], (spec.conv_width, w), jnp.float32) * 0.1,
        "w_rgate": _he(ks[3], (w, w)),
        "w_igate": _he(ks[4], (w, w)),
        "lam": lam,
        "w_out": _he(ks[5], (w, d)),
    }


def rglru_init_state(spec: RGLRUSpec, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, spec.width), jnp.float32),
        "conv": jnp.zeros((batch, spec.conv_width - 1, spec.width), dtype),
    }


def _rglru_gates(p, u, spec: RGLRUSpec):
    """u: (B, S, W) post-conv branch.  Returns (log_a, gated_input)."""
    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", u, p["w_rgate"], preferred_element_type=jnp.float32)
    )
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", u, p["w_igate"], preferred_element_type=jnp.float32)
    )
    log_a = -spec.c_const * jax.nn.softplus(p["lam"])[None, None] * r  # (B,S,W) <= 0
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * u.astype(jnp.float32))
    return log_a, gated


def rglru_train(p, x, spec: RGLRUSpec, state=None, return_state: bool = False):
    """Griffin recurrent block: gated dual-branch with RG-LRU inner scan."""
    b, s, d = x.shape
    if state is None:
        state = rglru_init_state(spec, b)
    branch = jnp.einsum("bsd,dw->bsw", x, p["w_x"], preferred_element_type=jnp.float32).astype(x.dtype)
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p["w_gate_branch"], preferred_element_type=jnp.float32)
    ).astype(x.dtype)
    u, conv_state = _causal_conv(branch, p["conv"], state["conv"])
    log_a, gated = _rglru_gates(p, u, spec)

    # associative scan over time: h_t = a_t h_{t-1} + b_t
    a_seq = jnp.exp(log_a)  # (B,S,W)
    b_seq = gated
    # fold the carried state into the first step
    b_seq = b_seq.at[:, 0].add(a_seq[:, 0] * state["h"])

    def comb(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    _, h_seq = jax.lax.associative_scan(comb, (a_seq, b_seq), axis=1)
    y = (h_seq * gate.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"], preferred_element_type=x.dtype).astype(x.dtype)
    if return_state:
        return out, {"h": h_seq[:, -1], "conv": conv_state}
    return out


def rglru_decode(p, x, spec: RGLRUSpec, state):
    b = x.shape[0]
    branch = jnp.einsum("bsd,dw->bsw", x, p["w_x"], preferred_element_type=jnp.float32).astype(x.dtype)
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p["w_gate_branch"], preferred_element_type=jnp.float32)
    ).astype(x.dtype)
    u, conv_state = _causal_conv(branch, p["conv"], state["conv"])
    log_a, gated = _rglru_gates(p, u, spec)
    h_new = jnp.exp(log_a[:, 0]) * state["h"] + gated[:, 0]
    y = (h_new[:, None] * gate.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"], preferred_element_type=x.dtype).astype(x.dtype)
    return out, {"h": h_new, "conv": conv_state}
