"""Model substrate: layers, attention, recurrent mixers, MoE, stacks."""
