"""ArchConfig -> model: init / train / prefill / decode, all pure fns.

``LM`` is a thin namespace object: it owns no arrays, only the StackCfgs
derived from an ArchConfig, and exposes pure functions that the training
and serving drivers jit under a mesh.

Frontends (assignment: "the modality frontend is a STUB —
``input_specs()`` provides precomputed frame/patch embeddings"):

  * ``token``  — ordinary token LM;
  * ``embed``  — VLM (llava): training consumes precomputed early-fusion
    patch+text embeddings (B, S, d); decode continues from the token
    embedding table (text continuation);
  * ``encdec`` — audio (seamless): encoder over precomputed frame
    embeddings (B, S_enc, d), decoder over tokens with cross-attention.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain_activation

from . import transformer as T
from .layers import apply_norm, embed, init_embedding, init_norm, unembed

__all__ = ["LM", "build_model", "softmax_xent"]


def softmax_xent(logits, labels, mask, z_coef: float = 1e-4):
    """Masked mean cross-entropy + z-loss, computed in f32.

    The gold logit is extracted with a fused masked reduction rather than
    ``take_along_axis``: under a vocab-sharded (TP) logits layout the
    gather would force an all-gather of the full (B, S, V) tensor, while
    the iota-compare-reduce stays sharded and psums a (B, S) scalar."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.where(vocab_iota == labels[..., None], logits, 0.0).sum(-1)
    xent = logz - gold
    zloss = z_coef * (logz ** 2)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = ((xent + zloss) * mask).sum() / denom
    return loss, {"xent": (xent * mask).sum() / denom}


class LM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.stack = T.make_stack_cfg(cfg, cfg.pattern, cfg.n_layers)
        if cfg.is_encdec:
            self.enc_stack = T.make_stack_cfg(cfg, ("enc",), cfg.n_enc_layers)
            self.dec_stack = T.make_stack_cfg(cfg, ("xattn",), cfg.n_layers)
        else:
            self.enc_stack = self.dec_stack = None

    # -- params ------------------------------------------------------------
    def init(self, key) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        p = {
            "embed": init_embedding(ks[0], cfg.padded_vocab, cfg.d_model),
            "final_norm": init_norm(cfg.d_model, kind=cfg.norm_kind),
        }
        if cfg.is_encdec:
            p["encoder"] = T.init_stack(ks[1], self.enc_stack)
            p["enc_norm"] = init_norm(cfg.d_model, kind=cfg.norm_kind)
            p["decoder"] = T.init_stack(ks[2], self.dec_stack)
        else:
            p["stack"] = T.init_stack(ks[1], self.stack)
        if not cfg.tie_embeddings:
            p["lm_head"] = init_embedding(ks[3], cfg.padded_vocab, cfg.d_model)
        return p

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree.leaves(params))

    # -- helpers -----------------------------------------------------------
    def _embed_tokens(self, params, tokens, dtype):
        x = embed(params["embed"], tokens, dtype)
        if self.cfg.emb_scale:
            x = x * jnp.sqrt(float(self.cfg.d_model)).astype(dtype)
        return x

    def _logits(self, params, x):
        x = apply_norm(params["final_norm"], x, kind=self.cfg.norm_kind)
        table = params["lm_head" if "lm_head" in params else "embed"]
        logits = unembed(table, x)
        if self.cfg.padded_vocab != self.cfg.vocab:
            vocab_iota = jax.lax.broadcasted_iota(
                jnp.int32, logits.shape, logits.ndim - 1
            )
            logits = jnp.where(vocab_iota < self.cfg.vocab, logits, -1e30)
        return logits

    def _encode(self, params, src_frames, remat=True):
        h, _ = T.stack_train(
            params["encoder"], src_frames, self.enc_stack, remat=remat
        )
        return apply_norm(params["enc_norm"], h, kind=self.cfg.norm_kind)

    # -- training ----------------------------------------------------------
    def train_logits(self, params, batch, *, dtype=jnp.bfloat16, remat=True):
        cfg = self.cfg
        if cfg.frontend == "embed":
            x = batch["embeds"].astype(dtype)
            x = constrain_activation(x, "btd")
            x, aux = T.stack_train(params["stack"], x, self.stack, remat=remat)
        elif cfg.is_encdec:
            memory = self._encode(params, batch["src_frames"].astype(dtype))
            x = self._embed_tokens(params, batch["tokens"], dtype)
            x = constrain_activation(x, "btd")
            x, aux = T.stack_train(
                params["decoder"], x, self.dec_stack, memory=memory, remat=remat
            )
        else:
            x = self._embed_tokens(params, batch["tokens"], dtype)
            x = constrain_activation(x, "btd")
            x, aux = T.stack_train(params["stack"], x, self.stack, remat=remat)
        x = constrain_activation(x, "btd")
        return self._logits(params, x), aux

    def loss_fn(self, params, batch, *, dtype=jnp.bfloat16, remat=True):
        logits, aux = self.train_logits(params, batch, dtype=dtype, remat=remat)
        logits = constrain_activation(logits, "btv")
        loss, metrics = softmax_xent(logits, batch["labels"], batch["loss_mask"])
        total = loss + 1e-2 * aux
        metrics["aux"] = aux
        return total, metrics

    # -- serving -----------------------------------------------------------
    def _serve_stack(self) -> T.StackCfg:
        return self.dec_stack if self.cfg.is_encdec else self.stack

    def init_caches(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        return T.init_stack_caches(self._serve_stack(), batch, seq_len, dtype)

    def insert_slot_caches(self, caches, one, slot):
        """Slot-local admission: write batch row 0 of the batch-1 cache
        pytree ``one`` (a fresh per-request prefill) into batch row
        ``slot`` of ``caches``.  No other slot's KV/state is touched."""
        return T.insert_slot_caches(caches, one, slot)

    def prefill(self, params, batch, caches, *, dtype=jnp.bfloat16):
        """Process the prompt; returns (last-position logits, caches)."""
        cfg = self.cfg
        memory = None
        if cfg.is_encdec:
            memory = self._encode(params, batch["src_frames"].astype(dtype))
            x = self._embed_tokens(params, batch["tokens"], dtype)
            x, caches = T.stack_prefill(
                params["decoder"], x, self.dec_stack, caches, memory=memory
            )
        elif cfg.frontend == "embed":
            x = batch["embeds"].astype(dtype)
            x, caches = T.stack_prefill(params["stack"], x, self.stack, caches)
        else:
            x = self._embed_tokens(params, batch["tokens"], dtype)
            x = constrain_activation(x, "btd")
            x, caches = T.stack_prefill(params["stack"], x, self.stack, caches)
        logits = self._logits(params, x[:, -1:])
        return logits, caches

    def decode_step(self, params, caches, tokens, pos, *, dtype=jnp.bfloat16):
        """One token for every sequence.  tokens: (B, 1) int32; ``pos`` is
        a scalar or a (B,) int32 vector of per-sequence positions (mixed
        prompt lengths decode each row at its own position)."""
        cfg = self.cfg
        x = self._embed_tokens(params, tokens, dtype)
        stack_params = params["decoder"] if cfg.is_encdec else params["stack"]
        x, caches = T.stack_decode(stack_params, x, self._serve_stack(), caches, pos)
        logits = self._logits(params, x)
        return logits, caches

    # -- input specs (ShapeDtypeStructs for the dry-run) ---------------------
    def input_specs(self, seq_len: int, batch: int, kind: str) -> Dict:
        """Stand-ins for every model input of a given shape cell (weak-type
        correct, shardable, no allocation)."""
        cfg = self.cfg
        sds = jax.ShapeDtypeStruct
        i32, f32 = jnp.int32, jnp.float32
        if kind in ("train", "prefill"):
            specs: Dict = {}
            if cfg.frontend == "embed":
                specs["embeds"] = sds((batch, seq_len, cfg.d_model), jnp.bfloat16)
            elif cfg.is_encdec:
                enc_s = min(seq_len, cfg.enc_seq or seq_len)
                specs["src_frames"] = sds((batch, enc_s, cfg.d_model), jnp.bfloat16)
                specs["tokens"] = sds((batch, seq_len), i32)
            else:
                specs["tokens"] = sds((batch, seq_len), i32)
            if kind == "train":
                specs["labels"] = sds((batch, seq_len), i32)
                specs["loss_mask"] = sds((batch, seq_len), f32)
            return specs
        if kind == "decode":
            return {"tokens": sds((batch, 1), i32)}
        raise ValueError(kind)


def build_model(cfg: ArchConfig) -> LM:
    return LM(cfg)
